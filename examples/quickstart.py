"""Quickstart: the paper's technique in 40 lines.

1. Run a few rounds of OPT-HSFL vs the discard baseline on non-iid data
   through the ``repro.api.Experiment`` facade (Alg. 1+2; any registered
   transmission scheme, any engine).
2. Train a reduced assigned architecture for a handful of steps via the
   public API.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.api import Experiment, registered_schemes
from repro.configs import get_config
from repro.models import build_model
from repro.optim import sgd
from repro.training import create_train_state, make_train_step
from repro.data import make_token_stream

# --- 1. the paper: opportunistic-proactive transmission ---------------------
# Any registered transmission scheme (see repro.core.schemes) runs through
# the one Experiment facade on any engine: "loop" (host reference),
# "fused" (single-jit round) or "sweep" (vectorized grids).
print(f"== OPT-HSFL (the paper) vs discard, 5 rounds, non-iid ==")
print(f"   registered schemes: {', '.join(registered_schemes())}")
for scheme, b in (("opt", 2.0), ("discard", 1.0)):
    log = (Experiment(rounds=5, n_uavs=12, k_select=4, n_train=1200,
                      n_test=300, steps_per_epoch=2, seed=0)
           .with_scheme(scheme, b=b)
           .run(engine="fused"))
    s = log.summary()
    print(f"  {scheme:8s} b={int(b)}: acc={s['final_acc']:.3f} "
          f"comm={s['avg_comm_mb']:.1f} MB/round "
          f"rescued={s['snapshot_rescues']} dropped={s['drops']}")

# --- 2. the framework: any assigned arch via one config id ------------------
print("== reduced hymba-1.5b (hybrid attn+mamba), 5 train steps ==")
cfg = get_config("hymba-1.5b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
opt = sgd(5e-2)
state = create_train_state(params, opt)
step = jax.jit(make_train_step(model, opt))
ds = make_token_stream(8, 32, vocab=cfg.vocab_size)
batch = {"tokens": jnp.asarray(ds.x[:4]), "labels": jnp.asarray(ds.y[:4])}
for i in range(5):
    state, metrics = step(state, batch)
    print(f"  step {i+1}: loss={float(metrics['loss']):.4f}")
print("quickstart OK")
