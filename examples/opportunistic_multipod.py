"""OpportunisticSync across simulated pods — the paper's scheme as a
distributed-training feature (DESIGN.md §2).

Four forced host devices stand in for four pods.  Each pod runs local SGD
(DiLoCo-style) on its shard; at scheduled inner steps it opportunistically
snapshots params to the aggregator when the simulated cross-pod link is good
(eqs. 14-16 verbatim); at the round boundary, pods whose final update was
lost contribute their snapshot instead (masked psum over the pod axis).

Run:  PYTHONPATH=src python examples/opportunistic_multipod.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.core.opportunistic_sync import (OppSyncConfig, channel_trace,
                                           make_opp_sync_round)
from repro.data import make_token_stream
from repro.models import build_model
from repro.optim import sgd
from repro.training import create_train_state, make_train_step

N_PODS, ROUNDS = 4, 6
cfg = OppSyncConfig(inner_steps=6, budget=2, outage_prob=0.3, rate0=1.0)
mesh = jax.make_mesh((N_PODS,), ("pod",))

model = build_model(get_config("llama3.2-1b").reduced())
params = model.init(jax.random.PRNGKey(0))
opt = sgd(5e-2)
train_step = make_train_step(model, opt)
state0 = create_train_state(params, opt, with_opt_sync=True,
                            tau_extra0=cfg.tau_extra0)
stack = lambda t: jax.tree_util.tree_map(
    lambda a: jnp.broadcast_to(a[None], (N_PODS,) + a.shape), t)
state = stack(state0)

B, S = 4, 32
ds = make_token_stream(N_PODS * cfg.inner_steps * B * ROUNDS, S,
                       vocab=model.cfg.vocab_size, seed=0)
state_spec = jax.tree_util.tree_map(lambda _: P("pod"), state)
batch_spec = {"tokens": P("pod"), "labels": P("pod")}
one_round = make_opp_sync_round(cfg, train_step, mesh, state_spec, batch_spec)

rates, outages, arrived = channel_trace(cfg, jax.random.PRNGKey(7),
                                        N_PODS, ROUNDS)
with mesh:
    for r in range(ROUNDS):
        lo = r * N_PODS * cfg.inner_steps * B
        tok = ds.x[lo:lo + N_PODS * cfg.inner_steps * B].reshape(
            N_PODS, cfg.inner_steps, B, S)
        lab = ds.y[lo:lo + N_PODS * cfg.inner_steps * B].reshape(
            N_PODS, cfg.inner_steps, B, S)
        batches = {"tokens": jnp.asarray(tok), "labels": jnp.asarray(lab)}
        state, losses = one_round(
            state, batches, rates[r].reshape(cfg.inner_steps + 1, N_PODS),
            outages[r].reshape(cfg.inner_steps + 1, N_PODS), arrived[r])
        l = np.asarray(losses)
        print(f"round {r+1}: mean inner loss {l.mean():.4f}  "
              f"arrived={np.asarray(arrived[r]).tolist()}")

# all pods end the round with identical (aggregated) params
leaf = jax.tree_util.tree_leaves(state.params)[3]
assert np.allclose(np.asarray(leaf[0]), np.asarray(leaf[-1]), atol=1e-6)
print("pods converged to a common aggregate — OpportunisticSync OK")
