"""End-to-end driver: the paper's full experiment at its native scale.

Reproduces the Fig. 3(b) comparison — OPT-HSFL (b=2) vs Async-HSFL vs
discard — over 30 UAVs with the Rician channel, greedy selection, bursty
interruptions, and FedAvg aggregation.

Everything routes through the ``repro.api.Experiment`` facade.  By default
the whole panel runs on the vectorized sweep engine (core/sweep): one
compiled program per scheme with seeds vmapped, rounds scanned and the
channel realized on-device.  ``--engine loop`` falls back to one fused
per-cell simulation (host-presampled channel; the reference RNG stream).
``--schemes`` takes any registered scheme names (``repro.core.schemes``)
as ``name=b`` pairs.

``--serve`` runs the first scheme of the panel through the long-lived
fault-tolerant aggregation service instead (``serving/fl_server``), with
optional fault injection and crash/resume durability::

    PYTHONPATH=src python examples/uav_fl_sim.py --serve --rounds 10 \
        --faults "dup@r2:c*; crash@r5:close" --ckpt-dir /tmp/fl_ckpt

Run:  PYTHONPATH=src python examples/uav_fl_sim.py [--rounds 100] [--seeds 2]
"""
import argparse
import time

import numpy as np

from repro.api import Experiment, registered_schemes

SCHEMES = (("opt", 2), ("async", 1), ("discard", 1))

ap = argparse.ArgumentParser()
ap.add_argument("--rounds", type=int, default=30)
ap.add_argument("--distribution", default="noniid",
                choices=["iid", "noniid", "imbalanced"])
ap.add_argument("--seed", type=int, default=0)
ap.add_argument("--seeds", type=int, default=1,
                help="number of seeds (stacked on the sweep's sim axis)")
ap.add_argument("--engine", default="sweep", choices=["sweep", "loop"])
ap.add_argument("--schemes", nargs="*", default=None, metavar="NAME=B",
                help="scheme panel as name=b pairs (default: opt=2 async=1 "
                     f"discard=1); registered: {', '.join(registered_schemes())}")
ap.add_argument("--codec", action="store_true",
                help="int8 delta-codec snapshots (kernels/delta_codec): "
                     "payloads shrink ~4x and rescues carry quantization "
                     "noise — runs on either engine")
ap.add_argument("--kernel", default="xla", choices=["xla", "pallas", "im2col"],
                help="CNN hot-path kernel (kernels/fused_cnn): the "
                     "custom-VJP fused step (default), the Pallas suite "
                     "(interpret off-TPU), or the PR-1 autodiff baseline")
ap.add_argument("--precision", default="f32", choices=["f32", "bf16"],
                help="compute precision of the training step (bf16 keeps "
                     "f32 master params and loss)")
ap.add_argument("--block-k", type=int, default=0,
                help="user-tile size of the blocked kernel grid "
                     "(0 = whole selected cohort in one grid step; see "
                     "kernels/fused_cnn.ForwardPolicy.block_k)")
ap.add_argument("--serve", action="store_true",
                help="run the first scheme through the fault-tolerant "
                     "aggregation service (serving/fl_server) instead of "
                     "the batch engines")
ap.add_argument("--faults", default=None, metavar="PLAN",
                help="with --serve: fault plan, e.g. "
                     "'dup@r2:c*; crash@r3:close'")
ap.add_argument("--ckpt-dir", default=None,
                help="with --serve: checkpoint/resume directory (crash "
                     "faults require it)")
ap.add_argument("--quorum", type=float, default=0.0,
                help="with --serve: hold rounds open for late uploads "
                     "until this fraction of scheduled finals arrived")
ap.add_argument("--transport", action="store_true",
                help="with --serve: chunked lossy-wire uploads with "
                     "XOR-parity erasure rescue (core/transport)")
ap.add_argument("--ber-bad", type=float, default=0.0,
                help="with --transport: bit-error rate in the wire's "
                     "bad (burst) state")
ap.add_argument("--parity-k", type=int, default=4,
                help="with --transport: data chunks per XOR parity group")
args = ap.parse_args()

if args.schemes:
    schemes = []
    for kv in args.schemes:
        name, eq, b = kv.partition("=")
        if not eq or not name:
            ap.error(f"--schemes takes NAME=B pairs (e.g. deadline=2), "
                     f"got {kv!r}")
        schemes.append((name, float(b)))
    schemes = tuple(schemes)
else:
    schemes = SCHEMES
seed_list = tuple(args.seed + i for i in range(args.seeds))
results = {}
t0 = time.time()

base = Experiment(rounds=args.rounds, distribution=args.distribution,
                  use_delta_codec=args.codec, kernel=args.kernel,
                  precision=args.precision,
                  block_k=args.block_k).with_seeds(*seed_list)

if args.serve:
    from repro.serving.fl_server import run_with_restarts

    transport = None
    if args.transport:
        from repro.core.transport import TransportConfig
        transport = TransportConfig(parity_k=args.parity_k,
                                    ber_bad=args.ber_bad)
    scheme, b = schemes[0]
    ex = base.with_seeds(args.seed).with_scheme(scheme, b=float(b))
    print(f"--- serving {scheme} (b={b}) on {args.distribution}"
          + (f", faults: {args.faults}" if args.faults else "") + " ---")
    if args.ckpt_dir:
        server, restarts = run_with_restarts(
            ex.to_config(), ckpt_dir=args.ckpt_dir, fault_plan=args.faults,
            quorum=args.quorum, transport=transport, verbose=True)
    else:
        server = ex.serve(faults=args.faults, quorum=args.quorum,
                          transport=transport)
        server.serve(verbose=True)
        restarts = 0
    s = server.log.summary()
    print(f"\n=== served {scheme}: final={s['final_acc']:.4f} "
          f"comm={s['avg_comm_mb']:.1f} MB/round "
          f"rescued={s['snapshot_rescues']} dropped={s['drops']} "
          f"dup_rejected={s['duplicates_rejected']} "
          f"corrupt_rejected={s['corrupt_rejected']} "
          f"retries={s['retries']} restarts={restarts} "
          f"({time.time() - t0:.1f}s) ===")
    if server.metrics_path:
        print(f"metrics log: {server.metrics_path}")
    raise SystemExit(0)

if args.engine == "sweep":
    ex = base
    for s, b in schemes:
        ex = ex.with_scheme(s, b=float(b))
    res = ex.run(engine="sweep", verbose=True)
    if args.codec:
        print(f"[codec] panel compiled as {res.n_programs} programs "
              f"(discard lowered onto opt@b=1)")
    for g in res.groups:
        # seed 0's trajectory represents the scheme (summary averages seeds)
        results[g.scheme] = [g.sim_log(i, 0) for i in range(len(g.sims))]
else:
    for scheme, b in schemes:
        print(f"--- {scheme} (b={b}) on {args.distribution} ---")
        logs = base.with_scheme(scheme, b=float(b)).run(engine="fused",
                                                        verbose=True)
        results[scheme] = logs if isinstance(logs, list) else [logs]

wall = time.time() - t0
print(f"\n=== summary (Fig. 3b, {args.engine} engine, "
      f"{len(seed_list)} seed(s), {wall:.1f}s) ===")
finals = {}
for scheme, logs in results.items():
    s = [log.summary() for log in logs]
    accs = np.stack([[a for a in log.acc_curve if a == a] for log in logs])
    finals[scheme] = float(np.mean([x["final_acc"] for x in s]))
    print(f"{scheme:8s}: final={finals[scheme]:.4f} "
          f"tail_std={np.std(accs[:, -10:], axis=1).mean():.4f} "
          f"comm={np.mean([x['avg_comm_mb'] for x in s]):.1f} MB/round "
          f"rescued={sum(x['snapshot_rescues'] for x in s)} "
          f"dropped={sum(x['drops'] for x in s)}")
if "opt" in finals and "async" in finals:
    print(f"\nOPT - Async accuracy delta: "
          f"{100 * (finals['opt'] - finals['async']):+.2f} pp "
          f"(paper: +3.98 pp at 100 rounds)")
