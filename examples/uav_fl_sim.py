"""End-to-end driver: the paper's full experiment at its native scale.

Reproduces the Fig. 3(b) comparison — OPT-HSFL (b=2) vs Async-HSFL vs
discard — over 30 UAVs with the Rician channel, greedy selection, bursty
interruptions, and FedAvg aggregation.

By default the whole panel runs on the vectorized sweep engine
(core/sweep): one compiled program per scheme with seeds vmapped, rounds
scanned and the channel realized on-device.  ``--engine loop`` falls back
to one ``run_hsfl`` per cell (host-presampled channel; the reference RNG
stream).

Run:  PYTHONPATH=src python examples/uav_fl_sim.py [--rounds 100] [--seeds 2]
"""
import argparse
import time

import numpy as np

SCHEMES = (("opt", 2), ("async", 1), ("discard", 1))

ap = argparse.ArgumentParser()
ap.add_argument("--rounds", type=int, default=30)
ap.add_argument("--distribution", default="noniid",
                choices=["iid", "noniid", "imbalanced"])
ap.add_argument("--seed", type=int, default=0)
ap.add_argument("--seeds", type=int, default=1,
                help="number of seeds (stacked on the sweep's sim axis)")
ap.add_argument("--engine", default="sweep", choices=["sweep", "loop"])
ap.add_argument("--codec", action="store_true",
                help="int8 delta-codec snapshots (kernels/delta_codec): "
                     "payloads shrink ~4x and rescues carry quantization "
                     "noise — runs on either engine")
ap.add_argument("--kernel", default="xla", choices=["xla", "pallas", "im2col"],
                help="CNN hot-path kernel (kernels/fused_cnn): the "
                     "custom-VJP fused step (default), the Pallas suite "
                     "(interpret off-TPU), or the PR-1 autodiff baseline")
ap.add_argument("--precision", default="f32", choices=["f32", "bf16"],
                help="compute precision of the training step (bf16 keeps "
                     "f32 master params and loss)")
args = ap.parse_args()

seed_list = tuple(args.seed + i for i in range(args.seeds))
results = {}
t0 = time.time()

if args.engine == "sweep":
    from repro.core.hsfl import HSFLConfig
    from repro.core.sweep import SweepSpec, run_sweep

    base = HSFLConfig(rounds=args.rounds, distribution=args.distribution,
                      use_delta_codec=args.codec, kernel=args.kernel,
                      precision=args.precision)
    spec = SweepSpec(base=base, seeds=seed_list,
                     schemes=tuple((s, {"b": float(b)}) for s, b in SCHEMES))
    res = run_sweep(spec, verbose=True)
    if args.codec:
        print(f"[codec] panel compiled as {res.n_programs} programs "
              f"(discard lowered onto opt@b=1)")
    for g in res.groups:
        # seed 0's trajectory represents the scheme (summary averages seeds)
        results[g.scheme] = [g.sim_log(i, 0) for i in range(len(g.sims))]
else:
    from repro.core.hsfl import HSFLConfig, run_hsfl

    for scheme, b in SCHEMES:
        print(f"--- {scheme} (b={b}) on {args.distribution} ---")
        results[scheme] = [
            run_hsfl(HSFLConfig(scheme=scheme, b=b, rounds=args.rounds,
                                distribution=args.distribution, seed=sd,
                                use_delta_codec=args.codec,
                                kernel=args.kernel,
                                precision=args.precision),
                     verbose=True)
            for sd in seed_list]

wall = time.time() - t0
print(f"\n=== summary (Fig. 3b, {args.engine} engine, "
      f"{len(seed_list)} seed(s), {wall:.1f}s) ===")
finals = {}
for scheme, logs in results.items():
    s = [log.summary() for log in logs]
    accs = np.stack([[a for a in log.acc_curve if a == a] for log in logs])
    finals[scheme] = float(np.mean([x["final_acc"] for x in s]))
    print(f"{scheme:8s}: final={finals[scheme]:.4f} "
          f"tail_std={np.std(accs[:, -10:], axis=1).mean():.4f} "
          f"comm={np.mean([x['avg_comm_mb'] for x in s]):.1f} MB/round "
          f"rescued={sum(x['snapshot_rescues'] for x in s)} "
          f"dropped={sum(x['drops'] for x in s)}")
print(f"\nOPT - Async accuracy delta: "
      f"{100 * (finals['opt'] - finals['async']):+.2f} pp "
      f"(paper: +3.98 pp at 100 rounds)")
