"""End-to-end driver: the paper's full experiment at its native scale.

Reproduces the Fig. 3(b) comparison — OPT-HSFL (b=2) vs Async-HSFL vs
discard — over 30 UAVs with the Rician channel, greedy selection, bursty
interruptions, and FedAvg aggregation.  ~2 s/round on one CPU core.

Run:  PYTHONPATH=src python examples/uav_fl_sim.py [--rounds 100]
"""
import argparse

import numpy as np

from repro.core.hsfl import HSFLConfig, run_hsfl

ap = argparse.ArgumentParser()
ap.add_argument("--rounds", type=int, default=30)
ap.add_argument("--distribution", default="noniid",
                choices=["iid", "noniid", "imbalanced"])
ap.add_argument("--seed", type=int, default=0)
args = ap.parse_args()

results = {}
for scheme, b in (("opt", 2), ("async", 1), ("discard", 1)):
    print(f"--- {scheme} (b={b}) on {args.distribution} ---")
    log = run_hsfl(HSFLConfig(scheme=scheme, b=b, rounds=args.rounds,
                              distribution=args.distribution,
                              seed=args.seed), verbose=True)
    results[scheme] = log

print("\n=== summary (Fig. 3b) ===")
for scheme, log in results.items():
    s = log.summary()
    accs = [a for a in log.acc_curve if a == a]
    print(f"{scheme:8s}: final={s['final_acc']:.4f} "
          f"tail_std={np.std(accs[-10:]):.4f} "
          f"comm={s['avg_comm_mb']:.1f} MB/round "
          f"rescued={s['snapshot_rescues']} dropped={s['drops']}")
opt_acc = results["opt"].final_acc
async_acc = results["async"].final_acc
print(f"\nOPT - Async accuracy delta: {100*(opt_acc-async_acc):+.2f} pp "
      f"(paper: +3.98 pp at 100 rounds)")
