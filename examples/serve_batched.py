"""Batched serving example: prefill + decode with per-family state.

Serves three different architecture families (dense KV-cache, attention-free
RWKV6 state, hybrid attn+mamba) through the same public API.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import generate

for arch in ("llama3.2-1b", "rwkv6-7b", "hymba-1.5b"):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 12)), jnp.int32)
    t0 = time.time()
    out = generate(model, params, prompt, max_new=16, context_len=32)
    jax.block_until_ready(out)
    print(f"{arch:12s} [{cfg.family:6s}] 4 requests x 16 tokens "
          f"in {time.time()-t0:.2f}s -> {np.asarray(out[0])[:8].tolist()}...")
print("batched serving OK")
