"""Training launcher — single-host data-parallel (CPU-runnable) driver.

Production path: pick an assigned arch (full or --reduced), build the
synthetic LM pipeline, train with AdamW + cosine schedule, checkpoint
periodically.  The multi-pod OpportunisticSync variant lives in
examples/opportunistic_multipod.py (needs forced host devices).

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
      --steps 100 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import ARCH_IDS, get_config
from repro.data import make_token_stream
from repro.models import build_model
from repro.optim import adamw, cosine
from repro.training import create_train_state, make_train_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale variant of the same family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    print(f"arch={cfg.name} reduced={args.reduced} "
          f"layers={cfg.num_layers} d_model={cfg.d_model} "
          f"params~{cfg.param_count()/1e6:.1f}M")

    params = model.init(jax.random.PRNGKey(args.seed))
    opt = adamw(cosine(args.lr, warmup=max(1, args.steps // 10),
                       total=args.steps))
    state = create_train_state(params, opt)

    start = 0
    if args.ckpt_dir and (s := latest_step(args.ckpt_dir)) is not None:
        state = restore_checkpoint(args.ckpt_dir, s, state)
        start = int(state.step)
        print(f"restored checkpoint at step {start}")

    ds = make_token_stream(args.batch * 64, args.seq,
                           vocab=cfg.vocab_size, seed=args.seed)
    step_fn = jax.jit(make_train_step(model, opt, grad_clip=1.0))
    rng = np.random.default_rng(args.seed)

    t0 = time.time()
    for i in range(start, args.steps):
        take = rng.integers(0, len(ds.x), args.batch)
        batch = {"tokens": jnp.asarray(ds.x[take]),
                 "labels": jnp.asarray(ds.y[take])}
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (args.batch, cfg.num_patches, cfg.d_model),
                jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16)
        state, metrics = step_fn(state, batch)
        if (i + 1) % args.log_every == 0 or i == start:
            sps = (i + 1 - start) / (time.time() - t0)
            print(f"step {i+1}/{args.steps} loss={float(metrics['loss']):.4f} "
                  f"ce={float(metrics['ce']):.4f} ({sps:.2f} steps/s)")
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, i + 1, state)
            print(f"saved checkpoint at step {i+1}")
    print(f"done: final loss {float(metrics['loss']):.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
