"""Tuned launch environment: XLA flags + allocator knobs for the hot path.

The fleet-scale engines live or die on the CPU backend's GEMM dispatch.
Two environment-level switches dominate on the bench container (the
olmax ``run.sh`` idiom: set the process environment *before* the runtime
initializes, instead of sprinkling per-call options):

* ``--xla_cpu_use_thunk_runtime=false`` — the legacy XLA:CPU runtime
  keeps the oneDNN-style fused GEMM path that the (default) thunk
  runtime drops for bf16: measured on the stacked cohort epoch at fig3
  scale, f32 falls from 66 to 40 ms/epoch and bf16 from 108 to 43
  ms/epoch when the flag is set, and a raw bf16 ``dot_general`` runs the
  AMX/AVX512-BF16 native path (f32 accumulation inside the GEMM
  microkernel) instead of a 2x-slower-than-f32 emulation.  This flag is
  what makes the ``fused_bf16`` BENCH rows a fast path instead of a
  regression.
* tcmalloc via ``LD_PRELOAD`` — glibc malloc serializes its arena under
  XLA's thread pool; tcmalloc removes the contention (and
  ``TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD`` silences its large-alloc
  spam).  Only applied when the library actually exists on the machine
  (the bench container ships none, TPU VMs do).

``apply_tuned_env()`` mutates ``os.environ`` in-process and must run
before the first jax *dispatch* (XLA parses ``XLA_FLAGS`` when the
backend client is created — at the first traced op, not at ``import
jax``), so benchmarks and ``serve_fl`` call it at the top of ``main()``.
``tuned_env()`` returns the same additions merged over a copy of a base
environment — the benchmark hands that to its measurement subprocesses.

User settings always win: ``XLA_FLAGS`` merging is by flag name (a flag
the user already set, with any value, is never overridden) and plain
variables already present in the environment are left untouched.
"""
from __future__ import annotations

import os
from typing import Dict, Mapping, Optional

__all__ = ["TUNED_XLA_FLAGS", "TUNED_VARS", "merge_xla_flags",
           "find_tcmalloc", "tuned_env", "apply_tuned_env"]

# flag -> why (the table in EXPERIMENTS.md renders from this)
TUNED_XLA_FLAGS: Dict[str, str] = {
    "--xla_cpu_use_thunk_runtime=false":
        "legacy CPU runtime: fused oneDNN GEMMs; native bf16 (AMX/"
        "AVX512-BF16) instead of emulation — f32 66->40 ms/epoch, "
        "bf16 108->43 ms/epoch at fig3 scale",
}

# plain environment variables (set only when absent)
TUNED_VARS: Dict[str, str] = {
    # silence TF/XLA C++ banner noise in benchmark child output
    "TF_CPP_MIN_LOG_LEVEL": "4",
    # tcmalloc prints a warning per >1GiB allocation by default; sweep
    # sims allocate the stacked client datasets in one block
    "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD": str(1 << 40),
}

_TCMALLOC_CANDIDATES = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/libtcmalloc.so.4",
    "/usr/local/lib/libtcmalloc.so",
)


def _flag_name(flag: str) -> str:
    return flag.split("=", 1)[0]


def merge_xla_flags(existing: str, extra=None) -> str:
    """Append tuned flags to an ``XLA_FLAGS`` string without overriding
    any flag (by name) the user already set."""
    if extra is None:
        extra = TUNED_XLA_FLAGS
    have = {_flag_name(f) for f in existing.split()}
    add = [f for f in extra if _flag_name(f) not in have]
    return " ".join(([existing] if existing else []) + add)


def find_tcmalloc() -> Optional[str]:
    for path in _TCMALLOC_CANDIDATES:
        if os.path.exists(path):
            return path
    return None


def tuned_env(base: Optional[Mapping[str, str]] = None) -> Dict[str, str]:
    """A copy of ``base`` (default ``os.environ``) with the tuned launch
    environment merged in — hand this to a measurement subprocess."""
    env = dict(os.environ if base is None else base)
    env["XLA_FLAGS"] = merge_xla_flags(env.get("XLA_FLAGS", ""))
    for var, val in TUNED_VARS.items():
        env.setdefault(var, val)
    tc = find_tcmalloc()
    if tc and "LD_PRELOAD" not in env:
        env["LD_PRELOAD"] = tc
    return env


def apply_tuned_env(verbose: bool = False) -> Dict[str, str]:
    """Merge the tuned environment into ``os.environ`` in-process.

    Call before the first jax dispatch (jit/array op), or the backend
    will already have parsed the un-tuned ``XLA_FLAGS``.  An ``LD_PRELOAD``
    found here cannot retro-load into a running process — it is exported
    for child processes only (the subprocess benches still benefit).
    Returns the variables that changed."""
    new = tuned_env(os.environ)
    changed = {k: v for k, v in new.items() if os.environ.get(k) != v}
    os.environ.update(changed)
    if verbose and changed:
        for k, v in sorted(changed.items()):
            print(f"[launch.env] {k}={v}")
    return changed
