import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and extract the roofline terms.

The two lines above MUST stay first: jax locks the device count on first
init, and only the dry-run may see 512 placeholder devices (smoke tests and
benches keep seeing 1).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.jsonl
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod ...

Per program it records: bytes-per-device (memory_analysis), HLO FLOPs/bytes
(cost_analysis), the collective schedule (parsed from compiled HLO — see
utils/hlo.py), and the three §Roofline terms.
"""
import argparse
import json
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import ARCH_IDS, INPUT_SHAPES, InputShape, ModelConfig, get_config
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.models import build_model
from repro.models import transformer as tf
from repro.models.inputs import input_specs
from repro.optim import adamw
from repro.sharding import rules
from repro.training import create_train_state, make_prefill_step, make_train_step
from repro.utils.hlo import collective_stats, compiled_memory_stats

DRYRUN_OPTS = {"impl": "xla", "moe_dispatch": "scatter", "remat": "none"}


def make_opts(shape_kind: str, multi_pod: bool, moe_dispatch: str = "scatter",
              remat: str = "full") -> dict:
    """Dry-run model options: activation sharding map + production remat."""
    return {
        "impl": "xla",
        "moe_dispatch": moe_dispatch,
        # per-layer remat is the production default for training; forward-only
        # programs have no backward pass to rematerialize
        "remat": remat if shape_kind == "train" else "none",
        "act_sharding": {
            "batch": ("pod", "data") if multi_pod else ("data",),
            "model": "model",
            "model_size": 16,
            "batch_size": (2 if multi_pod else 1) * 16,
        },
    }


def adapt_config(arch: str, shape_name: str,
                 overrides: Optional[dict] = None) -> Optional[ModelConfig]:
    """Resolve the (arch, shape) pair; None = documented skip (DESIGN.md §5)."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "decode" and cfg.is_encoder_only:
        return None                               # hubert: no decode
    if shape_name == "long_500k" and not cfg.is_subquadratic:
        cfg = cfg.with_sliding_window(8192)       # dense long-ctx variant
    # dry-run numerics policy: bf16 storage + f32 AdamW moments
    cfg = cfg.replace(param_dtype="bfloat16", dtype="bfloat16")
    if overrides:
        cfg = cfg.replace(**overrides)
    return cfg


def _shardings(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))


def build_program(cfg: ModelConfig, shape: InputShape, mesh, multi_pod: bool,
                  opts: Optional[dict] = None):
    """Returns (jitted_fn, example_args abstract) ready to .lower()."""
    model = build_model(cfg)
    opts = {**DRYRUN_OPTS, **(opts or {})}
    params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = rules.param_specs(cfg, params_struct)
    in_specs = input_specs(cfg, shape)
    in_sharding_specs = rules.input_sharding_specs(cfg, shape, multi_pod)

    if shape.kind == "train":
        opt = adamw(1e-4, moment_dtype=jnp.bfloat16
                    if opts.get("adam_bf16_moments") else jnp.float32)
        state_struct = jax.eval_shape(
            lambda p: create_train_state(p, opt), params_struct)
        state_specs = rules.train_state_specs(cfg, params_struct)
        step = make_train_step(model, opt, opts)
        fn = jax.jit(step,
                     in_shardings=(_shardings(mesh, state_specs),
                                   _shardings(mesh, in_sharding_specs)),
                     out_shardings=(_shardings(mesh, state_specs), None))
        return fn, (state_struct, in_specs)

    if shape.kind == "prefill":
        step = make_prefill_step(model, opts)
        fn = jax.jit(step,
                     in_shardings=(_shardings(mesh, pspecs),
                                   _shardings(mesh, in_sharding_specs)),
                     out_shardings=NamedSharding(
                         mesh, rules.logits_spec(multi_pod, shape.global_batch)))
        return fn, (params_struct, in_specs)

    # decode: one token against a seq_len-deep cache
    dt = jnp.bfloat16
    state_struct = jax.eval_shape(
        lambda: tf.init_decode_state(cfg, shape.global_batch, shape.seq_len, dt))
    dstate_specs = rules.decode_state_specs(cfg, shape.global_batch, multi_pod)

    def serve_step(params, token, state, position):
        return model.decode(params, token, state, position, opts)

    fn = jax.jit(serve_step,
                 in_shardings=(_shardings(mesh, pspecs),
                               _shardings(mesh, in_sharding_specs)["token"],
                               _shardings(mesh, dstate_specs),
                               _shardings(mesh, in_sharding_specs)["position"]),
                 out_shardings=(None, _shardings(mesh, dstate_specs)))
    return fn, (params_struct, in_specs["token"], state_struct,
                in_specs["position"])


def roofline_terms(cfg: ModelConfig, shape: InputShape, flops: float,
                   hbm_bytes: float, coll_bytes: float,
                   n_chips: int) -> Dict[str, float]:
    compute_s = flops / (n_chips * PEAK_FLOPS_BF16)
    memory_s = hbm_bytes / (n_chips * HBM_BW)
    collective_s = coll_bytes / (n_chips * ICI_BW)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    terms["dominant"] = max(terms, key=terms.get)
    # MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D for MoE; decode: D = batch*1
    n_active = cfg.param_count(active_only=True)
    tokens = shape.global_batch * (shape.seq_len if shape.kind == "train" else
                                   (shape.seq_len if shape.kind == "prefill" else 1))
    mult = 6 if shape.kind == "train" else 2
    terms["model_flops"] = mult * n_active * tokens
    terms["useful_ratio"] = terms["model_flops"] / max(flops, 1.0)
    return terms


def _compile_stats(cfg, shape, mesh, multi_pod, opts) -> Dict[str, Any]:
    """Lower+compile one program and pull raw stats off the artifact."""
    fn, args = build_program(cfg, shape, mesh, multi_pod, opts)
    with mesh:
        lowered = fn.lower(*args)
        compiled = lowered.compile()
    mem = compiled_memory_stats(compiled)
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    hlo = compiled.as_text()
    coll = collective_stats(hlo)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll,
        "coll_bytes": sum(v["bytes"] for v in coll.values()),
        "memory": {k: mem[k] for k in
                   ("argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "peak_memory_in_bytes")},
    }


def run_one(arch: str, shape_name: str, multi_pod: bool,
            opts: Optional[dict] = None, cfg_overrides: Optional[dict] = None,
            verbose: bool = True, calibrate: bool = True) -> Optional[Dict[str, Any]]:
    """Dry-run one (arch, shape, mesh) triple.

    Two-stage measurement (DESIGN/EXPERIMENTS §Dry-run):
    1. the PRODUCTION program (scan-over-layers) proves lowering/compilation
       and gives the memory analysis;
    2. cost_analysis counts while-loop bodies ONCE, so FLOPs / HBM bytes /
       collective bytes come from a calibration pair — the same program
       unrolled at num_layers=1 and 2 — extrapolated affinely:
       X(L) = X(1) + (L-1) * (X(2) - X(1)).
    """
    cfg = adapt_config(arch, shape_name, cfg_overrides)
    shape = INPUT_SHAPES[shape_name]
    if cfg is None:
        if verbose:
            print(f"SKIP {arch} x {shape_name} (documented: encoder-only)")
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skip_documented"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    base_opts = make_opts(shape.kind, multi_pod,
                          (opts or {}).get("moe_dispatch", "scatter"),
                          (opts or {}).get("remat", "full"))
    for k, v in (opts or {}).items():     # extra hillclimb knobs pass through
        if k not in ("moe_dispatch", "remat"):
            base_opts[k] = v
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "multi_pod": multi_pod, "n_chips": n_chips,
                           "opts": {k: v for k, v in base_opts.items()
                                    if k != "act_sharding"},
                           "overrides": cfg_overrides or {}}
    try:
        stats = _compile_stats(cfg, shape, mesh, multi_pod, base_opts)
        rec["lower_compile_s"] = round(time.time() - t0, 1)
        rec["memory"] = stats["memory"]
        rec["collectives_scan_hlo"] = stats["coll"]
        # memory_analysis on the forced-host backend: argument_size is
        # per-device (post-SPMD shards), temp_size aggregates all devices
        per_dev_bytes = (stats["memory"]["argument_size_in_bytes"]
                         + stats["memory"]["temp_size_in_bytes"] / n_chips)
        rec["bytes_per_device"] = per_dev_bytes

        if not calibrate:
            rec["total_compile_s"] = rec["lower_compile_s"]
            rec["status"] = "ok"
            if verbose:
                mesh_name = "2x16x16" if multi_pod else "16x16"
                print(f"OK {arch} x {shape_name} mesh={mesh_name} "
                      f"compile={rec['lower_compile_s']}s "
                      f"mem/dev={per_dev_bytes/2**30:.2f}GiB (lowering proof only)",
                      flush=True)
            return rec
        # calibration pair: unrolled 1- and 2-layer replicas of the config
        cal_opts = dict(base_opts, unroll_layers=True)
        s1 = _compile_stats(cfg.replace(num_layers=1), shape, mesh, multi_pod,
                            cal_opts)
        s2 = _compile_stats(cfg.replace(num_layers=2), shape, mesh, multi_pod,
                            cal_opts)
        L = cfg.num_layers

        def extrap(k1, k2=None):
            a, b = (s1[k1], s2[k1])
            return max(a + (L - 1) * (b - a), 0.0)

        flops = extrap("flops")              # per-device post-SPMD
        hbm = extrap("bytes")
        coll_bytes = extrap("coll_bytes")
        rec["per_layer"] = {"flops": s2["flops"] - s1["flops"],
                            "bytes": s2["bytes"] - s1["bytes"],
                            "coll_bytes": s2["coll_bytes"] - s1["coll_bytes"]}
        rec["hlo_flops_per_device"] = flops
        rec["hlo_bytes_per_device"] = hbm
        rec["coll_bytes_per_device"] = coll_bytes
        rec["roofline"] = roofline_terms(cfg, shape, flops * n_chips,
                                         hbm * n_chips, coll_bytes * n_chips,
                                         n_chips)
        rec["total_compile_s"] = round(time.time() - t0, 1)
        rec["status"] = "ok"
        if verbose:
            r = rec["roofline"]
            mesh_name = "2x16x16" if multi_pod else "16x16"
            print(f"OK {arch} x {shape_name} mesh={mesh_name} "
                  f"compile={rec['total_compile_s']}s "
                  f"mem/dev={per_dev_bytes/2**30:.2f}GiB "
                  f"compute={r['compute_s']*1e3:.2f}ms mem={r['memory_s']*1e3:.2f}ms "
                  f"coll={r['collective_s']*1e3:.2f}ms dom={r['dominant']} "
                  f"useful={r['useful_ratio']:.2f}", flush=True)
    except Exception as e:  # noqa: BLE001 — a failure here is a finding
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"[:500]
        if verbose:
            print(f"FAIL {arch} x {shape_name}: {rec['error']}", flush=True)
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default=None)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    ap.add_argument("--moe-dispatch", default="scatter",
                    choices=["scatter", "dense"])
    ap.add_argument("--remat", default="full", choices=["none", "full", "dots"])
    ap.add_argument("--no-calibrate", action="store_true",
                    help="lowering/memory proof only (multi-pod pass)")
    ap.add_argument("--tuned", action="store_true",
                    help="per-arch production opts from the §Perf hillclimbs")
    args = ap.parse_args(argv)

    pairs = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for mp in meshes:
        for a in archs:
            for s in shapes:
                pairs.append((a, s, mp))

    failures = 0
    for a, s, mp in pairs:
        opts = {"moe_dispatch": args.moe_dispatch, "remat": args.remat}
        if args.tuned:
            from repro.configs.base import tuned_opts
            opts.update(tuned_opts(get_config(a), INPUT_SHAPES[s].kind))
        rec = run_one(a, s, mp, opts, calibrate=not args.no_calibrate)
        failures += rec.get("status") == "fail"
        if args.out and rec:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    print(f"done: {len(pairs)} programs, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
