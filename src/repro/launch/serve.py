"""Serving launcher — batched autoregressive generation driver.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --reduced \
      --batch 4 --prompt-len 16 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.serving import generate


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    if model.decode is None:
        print(f"{cfg.name} is encoder-only: no autoregressive serving "
              "(DESIGN.md §5)")
        return 0

    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    ctx = args.prompt_len + args.max_new

    t0 = time.time()
    out = generate(model, params, prompt, max_new=args.max_new,
                   context_len=ctx, temperature=args.temperature,
                   key=jax.random.PRNGKey(args.seed))
    jax.block_until_ready(out)
    dt = time.time() - t0
    total_new = args.batch * args.max_new
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"new={args.max_new}")
    print(f"generated {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s incl. compile)")
    for b in range(min(2, args.batch)):
        print(f"  request {b}: {np.asarray(out[b])[:16].tolist()} ...")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
