"""Production mesh definitions (functions only — importing this module never
touches jax device state; see the dry-run brief)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_pods: int = 2, n_data: int = 2, n_model: int = 2):
    """Small mesh for CI-scale dry-run tests (8 forced host devices)."""
    return jax.make_mesh((n_pods, n_data, n_model), ("pod", "data", "model"))


def make_sweep_mesh(n_devices: int | None = None):
    """1-D ``("sweep",)`` mesh over the available devices.

    The sweep engine (core/sweep.py) shards the stacked simulation axis
    (seeds × data variants) of a Fig. 3 grid over this axis; each device
    then runs its slice of independent simulations with no cross-device
    collectives (embarrassingly parallel — the ideal mesh axis).
    """
    devs = jax.devices()
    if n_devices is not None:
        devs = devs[:n_devices]
    return jax.make_mesh((len(devs),), ("sweep",), devices=devs)


# TPU v5e hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link
