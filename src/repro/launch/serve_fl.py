"""FL aggregation service launcher — the long-lived serving path.

Runs ``serving/fl_server.FLServer`` under a restart supervisor: injected
(or real) crashes resume from the latest committed msgpack checkpoint and
training continues bit-compatibly.

  # fault-free service, checkpointing every round
  PYTHONPATH=src python -m repro.launch.serve_fl --rounds 20 \
      --scheme opt --ckpt-dir /tmp/fl_ckpt

  # chaos: duplicates + corruption + a mid-training server kill
  PYTHONPATH=src python -m repro.launch.serve_fl --rounds 10 \
      --ckpt-dir /tmp/fl_ckpt \
      --faults "dup@r2:c*; corrupt@r3:c*; crash@r5:checkpoint"

  # seeded random chaos instead of a scripted plan
  PYTHONPATH=src python -m repro.launch.serve_fl --rounds 10 \
      --ckpt-dir /tmp/fl_ckpt --chaos-seed 0 --chaos-dup 0.1 \
      --chaos-corrupt 0.1

Re-running with the same ``--ckpt-dir`` resumes from the latest committed
round (pass ``--fresh`` to wipe and start over).  Per-round metrics append
to ``<ckpt-dir>/metrics.jsonl`` (see EXPERIMENTS.md "Serving & fault
injection").
"""
from __future__ import annotations

import argparse
import os
import shutil

from repro.core.faults import FaultPlan
from repro.core.hsfl import HSFLConfig
from repro.core.schemes import registered_schemes
from repro.core.transport import TransportConfig
from repro.serving.fl_server import FLServer, run_with_restarts


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="long-lived fault-tolerant FL aggregation service")
    ap.add_argument("--scheme", default="opt", choices=registered_schemes())
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--b", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--distribution", default="noniid",
                    choices=["iid", "noniid", "imbalanced"])
    ap.add_argument("--n-uavs", type=int, default=30)
    ap.add_argument("--k-select", type=int, default=10)
    ap.add_argument("--n-train", type=int, default=None,
                    help="shrink the train split (smoke runs)")
    ap.add_argument("--n-test", type=int, default=None)
    ap.add_argument("--steps-per-epoch", type=int, default=None)
    ap.add_argument("--local-epochs", type=int, default=None)
    ap.add_argument("--codec", action="store_true",
                    help="int8 delta-codec snapshots")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint/resume directory (also holds "
                         "metrics.jsonl); omit to run without durability")
    ap.add_argument("--fresh", action="store_true",
                    help="wipe --ckpt-dir before serving")
    ap.add_argument("--faults", default=None, metavar="PLAN",
                    help="fault plan, e.g. 'dup@r2:c*; crash@r3:close' "
                         "(kinds: drop dup corrupt delay crash flip "
                         "partial)")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="seeded random fault plan instead of --faults")
    ap.add_argument("--chaos-dup", type=float, default=0.05)
    ap.add_argument("--chaos-corrupt", type=float, default=0.05)
    ap.add_argument("--chaos-drop", type=float, default=0.0)
    ap.add_argument("--chaos-delay", type=float, default=0.0)
    ap.add_argument("--chaos-flip", type=float, default=0.0,
                    help="per-(round,client) prob of CRC-clean bit flips")
    ap.add_argument("--chaos-partial", type=float, default=0.0,
                    help="per-(round,client) prob of a truncated upload")
    tr = ap.add_argument_group(
        "lossy-wire transport (opt-in chunked uploads; see core/transport)")
    tr.add_argument("--transport", action="store_true",
                    help="chunked resumable uploads + XOR-parity erasure "
                         "rescue over a Gilbert-Elliott burst-error wire")
    tr.add_argument("--chunk-bytes", type=int, default=4096)
    tr.add_argument("--parity-k", type=int, default=4,
                    help="data chunks per XOR parity group (0 = no parity)")
    tr.add_argument("--ber-good", type=float, default=0.0,
                    help="wire bit-error rate in the good channel state")
    tr.add_argument("--ber-bad", type=float, default=0.0,
                    help="wire bit-error rate in the bad (burst) state")
    tr.add_argument("--wire-outage", type=float, default=0.30,
                    help="stationary bad-state probability of the wire")
    tr.add_argument("--wire-persistence", type=float, default=0.70,
                    help="bad-state persistence of the wire")
    ap.add_argument("--quorum", type=float, default=0.0,
                    help="hold the round open for late uploads until this "
                         "fraction of scheduled finals arrived")
    ap.add_argument("--max-restarts", type=int, default=10)
    ap.add_argument("--eval-every", type=int, default=1)
    ap.add_argument("--metrics-path", default=None,
                    help="per-round JSONL log (default: "
                         "<ckpt-dir>/metrics.jsonl)")
    ap.add_argument("--no-tuned-env", action="store_true",
                    help="skip the tuned launch environment "
                         "(repro.launch.env: XLA runtime flags, tcmalloc)")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    if not args.no_tuned_env:
        # before the first jax dispatch: the server's jitted round programs
        # pick up the tuned XLA runtime (see launch/env.py)
        from repro.launch.env import apply_tuned_env
        apply_tuned_env(verbose=not args.quiet)

    if args.faults and args.chaos_seed is not None:
        ap.error("--faults and --chaos-seed are mutually exclusive")
    plan = FaultPlan.parse(args.faults) if args.faults else None
    if args.chaos_seed is not None:
        plan = FaultPlan.random(
            args.chaos_seed, args.rounds, range(args.n_uavs),
            p_dup=args.chaos_dup, p_corrupt=args.chaos_corrupt,
            p_drop=args.chaos_drop, p_delay=args.chaos_delay,
            p_flip=args.chaos_flip, p_partial=args.chaos_partial)
    transport = None
    if args.transport:
        transport = TransportConfig(
            chunk_bytes=args.chunk_bytes, parity_k=args.parity_k,
            ber_good=args.ber_good, ber_bad=args.ber_bad,
            wire_outage_prob=args.wire_outage,
            wire_persistence=args.wire_persistence)
    if args.fresh and args.ckpt_dir and os.path.isdir(args.ckpt_dir):
        shutil.rmtree(args.ckpt_dir)

    small = {k: getattr(args, k) for k in
             ("n_train", "n_test", "steps_per_epoch", "local_epochs")
             if getattr(args, k) is not None}
    cfg = HSFLConfig(scheme=args.scheme, b=args.b, rounds=args.rounds,
                     seed=args.seed, distribution=args.distribution,
                     n_uavs=args.n_uavs, k_select=args.k_select,
                     use_delta_codec=args.codec, **small)
    verbose = not args.quiet
    if plan and verbose:
        print(f"[serve_fl] fault plan: {plan}")
    if args.ckpt_dir:
        server, restarts = run_with_restarts(
            cfg, ckpt_dir=args.ckpt_dir, fault_plan=plan,
            max_restarts=args.max_restarts, quorum=args.quorum,
            eval_every=args.eval_every, metrics_path=args.metrics_path,
            transport=transport, verbose=verbose)
    else:
        server = FLServer(cfg, fault_plan=plan, quorum=args.quorum,
                          eval_every=args.eval_every,
                          metrics_path=args.metrics_path,
                          transport=transport)
        server.serve(verbose=verbose)
        restarts = 0

    s = server.log.summary()
    print(f"[serve_fl] scheme={args.scheme} rounds={s['rounds']} "
          f"final_acc={s['final_acc']:.4f} "
          f"comm={s['avg_comm_mb']:.1f} MB/round "
          f"rescued={s['snapshot_rescues']} drops={s['drops']} "
          f"dup_rejected={s['duplicates_rejected']} "
          f"stale_rejected={s['stale_rejected']} "
          f"corrupt_rejected={s['corrupt_rejected']} "
          f"retries={s['retries']} restarts={restarts}")
    if transport is not None:
        print(f"[serve_fl] transport: chunks={s['chunks_sent']} "
              f"retransmitted={s['chunks_retransmitted']} "
              f"parity_recovered={s['chunks_recovered']} "
              f"transfers_lost={s['transfers_incomplete']}")
    if server.metrics_path:
        print(f"[serve_fl] metrics log: {server.metrics_path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
