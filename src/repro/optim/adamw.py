"""AdamW with fp32 master moments (params may live in bf16)."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.optim.sgd import Optimizer


def adamw(learning_rate: float | Callable, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          moment_dtype=jnp.float32) -> Optimizer:
    """moment_dtype=bfloat16 halves the m/v optimizer-state footprint —
    a §Perf memory lever for frontier-scale training (llama3-405b)."""
    lr_fn = learning_rate if callable(learning_rate) else (lambda _: learning_rate)

    def init(params):
        f32 = lambda p: jnp.zeros(p.shape, moment_dtype)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree_util.tree_map(f32, params),
            "v": jax.tree_util.tree_map(f32, params),
        }

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr = lr_fn(step)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m2 = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            v2 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
            mhat = m2 / bc1
            vhat = v2 / bc2
            u = -lr * (mhat / (jnp.sqrt(vhat) + eps))
            if weight_decay and p is not None and p.ndim >= 2:
                u = u - lr * weight_decay * p.astype(jnp.float32)
            return u, m2.astype(moment_dtype), v2.astype(moment_dtype)

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_p = (treedef.flatten_up_to(params) if params is not None
                  else [None] * len(flat_g))
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        updates = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return updates, {"step": step, "m": new_m, "v": new_v}

    return Optimizer(init, update)
