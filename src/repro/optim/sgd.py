"""SGD (+ optional momentum) — mini-optax style (init/update pairs)."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]   # (grads, state, params)


def apply_updates(params: Any, updates: Any) -> Any:
    return jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype),
                                  params, updates)


def sgd(learning_rate: float | Callable[[jnp.ndarray], jnp.ndarray],
        momentum: float = 0.0) -> Optimizer:
    lr_fn = learning_rate if callable(learning_rate) else (lambda _: learning_rate)

    def init(params):
        state = {"step": jnp.zeros((), jnp.int32)}
        if momentum:
            state["mu"] = jax.tree_util.tree_map(jnp.zeros_like, params)
        return state

    def update(grads, state, params=None):
        del params
        lr = lr_fn(state["step"])
        if momentum:
            mu = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g, state["mu"], grads)
            updates = jax.tree_util.tree_map(lambda m: -lr * m, mu)
            return updates, {"step": state["step"] + 1, "mu": mu}
        updates = jax.tree_util.tree_map(lambda g: -lr * g, grads)
        return updates, {"step": state["step"] + 1}

    return Optimizer(init, update)


def clip_by_global_norm(grads: Any, max_norm: float) -> Any:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree_util.tree_leaves(grads)]
    norm = jnp.sqrt(sum(leaves))
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads)
