from repro.optim.adamw import adamw
from repro.optim.schedule import constant, cosine
from repro.optim.sgd import Optimizer, apply_updates, clip_by_global_norm, sgd

__all__ = ["Optimizer", "adamw", "apply_updates", "clip_by_global_norm",
           "constant", "cosine", "sgd"]
