"""User selection + FL/SL scheduling — Alg. 1 lines 3–5 (greedy, after [6]).

[6]'s exact greedy is not reprinted in this paper; the criteria it names are
"one-round latency, diversity of user resources and energy consumption".  We
implement that as: per UAV compute both the FL and SL one-round latencies
under the relaxed budget (eq. 13); a mode is feasible if its latency ≤ τ_max;
among feasible users greedily pick the K with the best energy-per-sample
utility, assigning each user the cheaper feasible mode (computing-limited
UAVs land on SL exactly as HSFL intends).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import numpy as np

from repro.core import latency as lat


@dataclass
class ScheduledUser:
    index: int
    mode: str                  # "FL" | "SL"
    latency_s: float
    energy_j: float
    rate0_bps: float


def schedule_users(rates0: Sequence[float],
                   devices: Sequence[lat.DeviceProfile],
                   workloads: Sequence[lat.WorkloadProfile],
                   model_bytes: float, ue_model_bytes: float,
                   b: int, tau_max: float, k_select: int,
                   bs_rate_bps: float = 400e6,
                   max_sl: int | None = None) -> List[ScheduledUser]:
    """Greedy selection of ≤ k_select users with FL/SL assignment.

    ``max_sl`` caps SL slots (the BS server co-computes for SL users, so its
    capacity bounds them; [6] balances this — default: half of k_select).
    """
    if max_sl is None:
        max_sl = k_select // 2
    candidates = []
    for i, (r0, dev, wl) in enumerate(zip(rates0, devices, workloads)):
        fl_lat = lat.one_round_latency_fl(dev, wl, b, model_bytes, r0)
        sl_lat = lat.one_round_latency_sl(dev, wl, b, ue_model_bytes, r0,
                                          bs_rate_bps)
        fl_en = lat.energy_fl(dev, wl, lat.uplink_fl(b, model_bytes, r0))
        act = wl.act_bytes_per_sample * wl.samples
        sl_en = lat.energy_sl(dev, wl, lat.uplink_sl(b, ue_model_bytes, act, r0))
        options = {}
        if fl_lat <= tau_max:
            options["FL"] = (fl_lat, fl_en)
        if sl_lat <= tau_max:
            options["SL"] = (sl_lat, sl_en)
        if not options:
            continue
        candidates.append((i, r0, options))

    # utility: samples per joule at the user's cheapest mode (energy
    # efficiency, the paper's stated goal)
    def best_energy(c):
        return min(en for _, en in c[2].values())

    candidates.sort(key=lambda c: workloads[c[0]].samples / max(best_energy(c), 1e-9),
                    reverse=True)

    out: List[ScheduledUser] = []
    sl_used = 0
    for i, r0, options in candidates:
        if len(out) == k_select:
            break
        # prefer the energy-cheaper mode, respecting the SL capacity cap
        order = sorted(options.items(), key=lambda kv: kv[1][1])
        for mode, (l, en) in order:
            if mode == "SL" and sl_used >= max_sl:
                continue
            out.append(ScheduledUser(i, mode, l, en, r0))
            sl_used += mode == "SL"
            break
    return out
