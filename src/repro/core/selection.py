"""User selection + FL/SL scheduling — Alg. 1 lines 3–5 (greedy, after [6]).

[6]'s exact greedy is not reprinted in this paper; the criteria it names are
"one-round latency, diversity of user resources and energy consumption".  We
implement that as: per UAV compute both the FL and SL one-round latencies
under the relaxed budget (eq. 13); a mode is feasible if its latency ≤ τ_max;
among feasible users greedily pick the K with the best energy-per-sample
utility, assigning each user the cheaper feasible mode (computing-limited
UAVs land on SL exactly as HSFL intends).

Two implementations of the same policy:

- ``schedule_users`` — the host reference (Python objects, float64).
- ``select_users_jax`` — the on-device port used inside the scanned sweep
  round (``core/sweep.py``): fully vectorized, works with *traced* b/τ_max
  so a config axis can be vmapped over it, and returns fixed-width (K,)
  slot arrays.  ``tests/test_sweep.py`` pins the two to identical picks.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np

from repro.core import latency as lat


@dataclass
class ScheduledUser:
    index: int
    mode: str                  # "FL" | "SL"
    latency_s: float
    energy_j: float
    rate0_bps: float


def schedule_users(rates0: Sequence[float],
                   devices: Sequence[lat.DeviceProfile],
                   workloads: Sequence[lat.WorkloadProfile],
                   model_bytes: float, ue_model_bytes: float,
                   b: int, tau_max: float, k_select: int,
                   bs_rate_bps: float = 400e6,
                   max_sl: int | None = None) -> List[ScheduledUser]:
    """Greedy selection of ≤ k_select users with FL/SL assignment.

    ``max_sl`` caps SL slots (the BS server co-computes for SL users, so its
    capacity bounds them; [6] balances this — default: half of k_select).
    """
    if max_sl is None:
        max_sl = k_select // 2
    candidates = []
    for i, (r0, dev, wl) in enumerate(zip(rates0, devices, workloads)):
        fl_lat = lat.one_round_latency_fl(dev, wl, b, model_bytes, r0)
        sl_lat = lat.one_round_latency_sl(dev, wl, b, ue_model_bytes, r0,
                                          bs_rate_bps)
        fl_en = lat.energy_fl(dev, wl, lat.uplink_fl(b, model_bytes, r0))
        act = wl.act_bytes_per_sample * wl.samples
        sl_en = lat.energy_sl(dev, wl, lat.uplink_sl(b, ue_model_bytes, act, r0))
        options = {}
        if fl_lat <= tau_max:
            options["FL"] = (fl_lat, fl_en)
        if sl_lat <= tau_max:
            options["SL"] = (sl_lat, sl_en)
        if not options:
            continue
        candidates.append((i, r0, options))

    # utility: samples per joule at the user's cheapest mode (energy
    # efficiency, the paper's stated goal)
    def best_energy(c):
        return min(en for _, en in c[2].values())

    candidates.sort(key=lambda c: workloads[c[0]].samples / max(best_energy(c), 1e-9),
                    reverse=True)

    out: List[ScheduledUser] = []
    sl_used = 0
    for i, r0, options in candidates:
        if len(out) == k_select:
            break
        # prefer the energy-cheaper mode, respecting the SL capacity cap
        order = sorted(options.items(), key=lambda kv: kv[1][1])
        for mode, (l, en) in order:
            if mode == "SL" and sl_used >= max_sl:
                continue
            out.append(ScheduledUser(i, mode, l, en, r0))
            sl_used += mode == "SL"
            break
    return out


# ---------------------------------------------------------------------------
# On-device port of the same greedy (the sweep engine's per-round scheduler)
# ---------------------------------------------------------------------------

def user_latency_energy(rates0, flops, samples, *, b, model_bytes,
                        ue_model_bytes, local_epochs,
                        flops_per_sample=2.0e6, ue_fraction=0.4,
                        act_bytes_per_sample=3136.0,
                        server_flops_per_sec=1.0e12, bs_rate_bps=400e6,
                        power_compute_w=5.0, power_tx_w=0.25, xp=np):
    """Vectorized eqs. (9)–(13) for all N users at once.

    Returns (fl_lat, sl_lat, fl_en, sl_en, tt_fl, tt_sl) — the same numbers
    ``latency.py``'s scalar functions produce for the default
    Device/Workload profiles, but as arrays and with ``b`` possibly traced.
    """
    r0 = xp.maximum(rates0, 1e-9)
    tt_fl = local_epochs * samples * flops_per_sample / flops
    tt_sl = local_epochs * samples * (
        ue_fraction * flops_per_sample / flops
        + (1.0 - ue_fraction) * flops_per_sample / server_flops_per_sec)
    act = act_bytes_per_sample * samples
    up_fl = b * model_bytes * 8.0 / r0
    up_sl = (b * ue_model_bytes + act) * 8.0 / r0
    dl_sl = (ue_model_bytes + act) * 8.0 / bs_rate_bps
    fl_lat = tt_fl + up_fl
    sl_lat = tt_sl + up_sl + dl_sl
    fl_en = tt_fl * power_compute_w + up_fl * power_tx_w
    ue_t = local_epochs * samples * ue_fraction * flops_per_sample / flops
    sl_en = ue_t * power_compute_w + up_sl * power_tx_w
    return fl_lat, sl_lat, fl_en, sl_en, tt_fl, tt_sl


def select_users_jax(rates0, flops, samples, *, b, tau_max, k_select: int,
                     model_bytes: float, ue_model_bytes: float,
                     local_epochs: int, max_sl: int | None = None,
                     **lat_kw) -> Tuple:
    """``schedule_users`` as one traced program (no host round trip).

    ``b``/``tau_max`` may be traced scalars (sweep config axes).  Returns
    fixed-width slot arrays: ``sel`` (K,) int32 user indices in greedy
    order, ``mode_sl`` (K,) bool, ``valid`` (K,) bool (slot occupied),
    ``n_taken`` int32, ``tt_fl``/``tt_sl`` (N,) training times for reuse by
    the round's τ accounting.  Invalid slots point at user 0 and must be
    masked by ``valid`` downstream.
    """
    import jax
    import jax.numpy as jnp

    if max_sl is None:
        max_sl = k_select // 2
    n = rates0.shape[0]
    fl_lat, sl_lat, fl_en, sl_en, tt_fl, tt_sl = user_latency_energy(
        rates0, flops, samples, b=b, model_bytes=model_bytes,
        ue_model_bytes=ue_model_bytes, local_epochs=local_epochs,
        xp=jnp, **lat_kw)

    feas_fl = fl_lat <= tau_max
    feas_sl = sl_lat <= tau_max
    feas_any = feas_fl | feas_sl
    inf = jnp.inf
    best_en = jnp.minimum(jnp.where(feas_fl, fl_en, inf),
                          jnp.where(feas_sl, sl_en, inf))
    utility = jnp.where(feas_any, samples / jnp.maximum(best_en, 1e-9), -inf)
    order = jnp.argsort(-utility, stable=True)     # host sort is stable too

    # the host greedy prefers the energy-cheaper feasible mode; on an
    # fl_en == sl_en tie it takes FL (Python's stable sort keeps the dict's
    # FL-first insertion order), hence the strict <
    prefer_sl = feas_sl & (~feas_fl | (sl_en < fl_en))

    def body(carry, i):
        cnt, slu = carry
        room = cnt < k_select
        capped = slu >= max_sl
        take_sl = prefer_sl[i] & ~capped
        take_fl = feas_fl[i] & (~prefer_sl[i] | capped)
        take = room & feas_any[i] & (take_sl | take_fl)
        take_sl = take & take_sl
        return ((cnt + take.astype(jnp.int32),
                 slu + take_sl.astype(jnp.int32)),
                (take, take_sl))

    (n_taken, _), (take, take_sl) = jax.lax.scan(
        body, (jnp.int32(0), jnp.int32(0)), order)

    # pack taken users (in greedy order) into K fixed slots (n may be < K)
    rank = jnp.cumsum(take.astype(jnp.int32)) - 1
    slot_key = jnp.where(take, rank, n + 1)
    k_eff = min(k_select, n)
    pick = jnp.argsort(slot_key, stable=True)[:k_eff]
    sel = jnp.zeros((k_select,), jnp.int32).at[:k_eff].set(
        order[pick].astype(jnp.int32))
    mode_sl = jnp.zeros((k_select,), bool).at[:k_eff].set(take_sl[pick])
    valid = jnp.arange(k_select) < n_taken
    sel = jnp.where(valid, sel, 0)
    return sel, mode_sl & valid, valid, n_taken, tt_fl, tt_sl
