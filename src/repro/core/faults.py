"""Deterministic fault injection + retry/backoff for the FL serving path.

The paper's premise is that clients are unreliable — uploads arrive late,
stale, corrupted or not at all — and PR 6 turns that from a *simulated*
channel property into a *service* property: ``serving/fl_server.FLServer``
runs a long-lived aggregation loop whose transport is perturbed by a
seeded, fully deterministic :class:`FaultPlan`.

Fault kinds (the grammar below):

  ``drop``     — the client's final upload is black-holed: every attempt
                 times out, retries exhaust, and the round closes without
                 it (the scheme's rescue/delayed path takes over).
  ``dup``      — the final upload is delivered ``1 + count`` times; the
                 server inbox must be idempotent (duplicates rejected,
                 aggregation bit-identical to the single-delivery run).
  ``corrupt``  — the next ``count`` uploads from the client arrive with
                 flipped payload bytes; the CRC check refuses them and the
                 client re-sends under exponential backoff (recoverable).
  ``delay``    — the final upload misses the round deadline and arrives
                 after close with a stale round id; the inbox rejects it
                 unless the quorum policy is still holding the round open.
  ``crash``    — the *server* dies at a named phase of the round
                 (``train`` | ``close`` | ``checkpoint``); a supervisor
                 restarts it from the latest committed msgpack checkpoint.
  ``flip``     — ``count`` *pre-encode* bit flips in the client's upload:
                 the payload CRC is computed after the flip, so the
                 corruption is CRC-clean and sails through the inbox —
                 only Byzantine-robust aggregation (the ``opt_trimmed`` /
                 ``opt_median`` / ``opt_clip`` schemes) can absorb it.
  ``partial``  — the upload is truncated: the last ``count`` chunks never
                 leave the client.  Under the chunked+parity transport
                 (``core.transport``) one missing chunk per parity group
                 rebuilds bitwise at round close; without it the blob
                 fails CRC on every attempt and the upload is lost.

Plan grammar (``FaultPlan.parse`` / ``str(plan)`` round-trip)::

    plan   := event (';' event)*
    event  := kind '@' 'r' ROUND [':' target] ['x' COUNT]
    target := 'c' CLIENT | 'c*'            (client faults; default c*)
            | 'train' | 'close' | 'checkpoint'   (crash phase; default close)

    e.g.  "dup@r2:c1; corrupt@r1:c*x2; crash@r3:checkpoint"

Everything is deterministic: ``FaultPlan.random`` draws from a seeded
``np.random.Generator``, and the retry jitter stream is seeded per
``(seed, round, client)`` so a killed-and-resumed server replays the exact
same fault/retry interleaving (the bit-compatibility contract the chaos
tests pin).
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Callable, Iterable, List, Optional, Sequence, Tuple

import numpy as np

FAULT_KINDS = ("drop", "dup", "corrupt", "delay", "crash", "flip", "partial")
CRASH_PHASES = ("train", "close", "checkpoint")


# ---------------------------------------------------------------------------
# errors
# ---------------------------------------------------------------------------

class TransientUploadError(Exception):
    """A retriable transport failure (timeout, refused payload)."""


class UploadTimeout(TransientUploadError):
    """The attempt exceeded the transport timeout (or was black-holed)."""


class CorruptPayload(TransientUploadError):
    """CRC mismatch: the server refused the payload; the client re-sends."""


class RetriesExhausted(Exception):
    """Every backoff attempt failed; the upload is missed for this round."""

    def __init__(self, attempts: int, last: Exception):
        super().__init__(f"upload failed after {attempts} attempts: {last!r}")
        self.attempts = attempts
        self.last = last


class ServerCrash(Exception):
    """An injected server death; carries where it happened so a supervisor
    can mark the crash consumed and restart from the latest checkpoint."""

    def __init__(self, round_id: int, phase: str):
        super().__init__(f"injected server crash at round {round_id} "
                         f"phase {phase!r}")
        self.round_id = round_id
        self.phase = phase


# ---------------------------------------------------------------------------
# retry / timeout / exponential backoff with jitter
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class BackoffPolicy:
    """Exponential backoff with multiplicative jitter, in *simulated*
    seconds (nothing here sleeps — delays are charged to the round clock).

    Attempt ``k`` (0-based) waits ``min(max_delay, base * factor**k)``
    scaled by ``1 - jitter * u`` with ``u ~ U[0, 1)`` from the caller's
    seeded generator — deterministic under a fixed seed, decorrelated
    across clients.
    """
    max_attempts: int = 4
    base_s: float = 0.05
    factor: float = 2.0
    max_delay_s: float = 2.0
    jitter: float = 0.5
    timeout_s: float = 30.0        # per-attempt transport timeout

    def delay_s(self, attempt: int, rng: np.random.Generator) -> float:
        raw = min(self.max_delay_s, self.base_s * self.factor ** attempt)
        return raw * (1.0 - self.jitter * float(rng.random()))

    def validate(self) -> "BackoffPolicy":
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got "
                             f"{self.max_attempts}")
        if not (0.0 <= self.jitter <= 1.0):
            raise ValueError(f"jitter must lie in [0, 1], got {self.jitter}")
        return self


@dataclass
class RetryResult:
    """Outcome of ``retry_call``: the value plus the accounting the server
    metrics log records (retries, simulated seconds burnt in backoff)."""
    value: object
    attempts: int = 1
    backoff_s: float = 0.0

    @property
    def retries(self) -> int:
        return self.attempts - 1


def retry_call(fn: Callable[[], object], policy: BackoffPolicy,
               rng: np.random.Generator) -> RetryResult:
    """Run ``fn`` under ``policy``: transient failures back off and retry,
    anything else propagates.  Raises :class:`RetriesExhausted` when the
    budget runs out (the caller routes the miss to the scheme's
    rescue/delayed path)."""
    policy.validate()
    backoff = 0.0
    last: Exception = RuntimeError("unreachable")
    for attempt in range(policy.max_attempts):
        try:
            return RetryResult(fn(), attempts=attempt + 1, backoff_s=backoff)
        except TransientUploadError as e:
            last = e
            if attempt + 1 < policy.max_attempts:
                backoff += policy.delay_s(attempt, rng)
    raise RetriesExhausted(policy.max_attempts, last)


def client_rng(seed: int, round_id: int, client_id: int) -> np.random.Generator:
    """The per-(round, client) jitter stream: independent of the simulation
    RNG so fault handling never perturbs the training trajectory, and
    reconstructible after a server restart."""
    return np.random.default_rng(
        np.random.SeedSequence((int(seed), int(round_id), int(client_id))))


# ---------------------------------------------------------------------------
# the fault plan
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FaultEvent:
    kind: str                      # one of FAULT_KINDS
    round: int                     # 1-based round id
    client: Optional[int] = None   # None = every scheduled client
    count: int = 1                 # e.g. number of duplicate deliveries
    phase: str = "close"           # crash only

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"choose from {FAULT_KINDS}")
        if self.kind == "crash" and self.phase not in CRASH_PHASES:
            raise ValueError(f"unknown crash phase {self.phase!r}; "
                             f"choose from {CRASH_PHASES}")
        if self.round < 1:
            raise ValueError(f"rounds are 1-based, got r{self.round}")
        if self.count < 1:
            raise ValueError(f"count must be >= 1, got x{self.count}")

    def __str__(self) -> str:
        if self.kind == "crash":
            return f"crash@r{self.round}:{self.phase}"
        tgt = "c*" if self.client is None else f"c{self.client}"
        x = f"x{self.count}" if self.count != 1 else ""
        return f"{self.kind}@r{self.round}:{tgt}{x}"


_EVENT_RE = re.compile(
    r"^(?P<kind>[a-z]+)@r(?P<round>\d+)"
    r"(?::(?P<target>c\*|c\d+|[a-z]+))?"
    r"(?:x(?P<count>\d+))?$")


@dataclass(frozen=True)
class FaultPlan:
    """An immutable, seedable schedule of injected faults."""
    events: Tuple[FaultEvent, ...] = ()

    # -- construction --------------------------------------------------------
    @classmethod
    def parse(cls, text: str) -> "FaultPlan":
        """Parse the grammar above; '' or 'none' is the empty plan."""
        text = (text or "").strip()
        if not text or text == "none":
            return cls()
        events: List[FaultEvent] = []
        for raw in re.split(r"[;\n]+", text):
            raw = raw.strip()
            if not raw:
                continue
            m = _EVENT_RE.match(raw)
            if not m:
                raise ValueError(
                    f"bad fault event {raw!r}; expected "
                    f"kind@rROUND[:cCLIENT|c*|PHASE][xCOUNT] with kind in "
                    f"{FAULT_KINDS} (e.g. 'dup@r2:c1', 'crash@r3:checkpoint')")
            kind = m.group("kind")
            rnd = int(m.group("round"))
            tgt = m.group("target")
            count = int(m.group("count") or 1)
            if kind == "crash":
                events.append(FaultEvent(kind, rnd,
                                         phase=(tgt or "close")))
            else:
                client = None
                if tgt not in (None, "c*"):
                    if not tgt.startswith("c"):
                        raise ValueError(
                            f"{raw!r}: client faults target 'c<idx>' or "
                            f"'c*', got {tgt!r}")
                    client = int(tgt[1:])
                events.append(FaultEvent(kind, rnd, client=client,
                                         count=count))
        return cls(tuple(events))

    @classmethod
    def random(cls, seed: int, rounds: int, clients: Sequence[int], *,
               p_dup: float = 0.0, p_corrupt: float = 0.0,
               p_drop: float = 0.0, p_delay: float = 0.0,
               p_flip: float = 0.0, p_partial: float = 0.0,
               crash_rounds: Iterable[int] = ()) -> "FaultPlan":
        """A seeded chaos schedule: each (round, client) cell draws each
        fault kind independently; ``crash_rounds`` add one close-phase
        crash each.  Same seed -> same plan, always."""
        rng = np.random.default_rng(seed)
        events: List[FaultEvent] = []
        probs = (("dup", p_dup), ("corrupt", p_corrupt),
                 ("drop", p_drop), ("delay", p_delay),
                 ("flip", p_flip), ("partial", p_partial))
        for t in range(1, rounds + 1):
            for c in clients:
                for kind, p in probs:
                    if p > 0.0 and rng.random() < p:
                        events.append(FaultEvent(kind, t, client=int(c)))
        for t in crash_rounds:
            phase = CRASH_PHASES[int(rng.integers(len(CRASH_PHASES)))]
            events.append(FaultEvent("crash", int(t), phase=phase))
        return cls(tuple(events))

    # -- queries -------------------------------------------------------------
    def count(self, kind: str, round_id: int, client_id: int) -> int:
        """Total injected count of ``kind`` hitting this (round, client)."""
        return sum(e.count for e in self.events
                   if e.kind == kind and e.round == round_id
                   and e.client in (None, client_id))

    def crash_phase(self, round_id: int) -> Optional[str]:
        for e in self.events:
            if e.kind == "crash" and e.round == round_id:
                return e.phase
        return None

    @property
    def recoverable(self) -> bool:
        """True when every fault is *recoverable* — dup/corrupt/crash leave
        the training trajectory bit-identical to the fault-free run (the
        chaos property test's precondition); drop/delay change which
        updates aggregate and so legitimately move the trajectory."""
        return all(e.kind in ("dup", "corrupt", "crash") for e in self.events)

    @property
    def parity_recoverable(self) -> bool:
        """True when every fault is absorbed *bitwise* by the chunked
        transport with XOR parity: the legacy recoverable kinds plus
        ``partial`` events truncating at most one chunk (one parity chunk
        per group rebuilds exactly one missing data chunk).  ``flip`` is
        never bitwise-recoverable — it is CRC-clean by construction and
        only *tolerance*-bounded under robust aggregation."""
        return all(e.kind in ("dup", "corrupt", "crash")
                   or (e.kind == "partial" and e.count == 1)
                   for e in self.events)

    def __str__(self) -> str:
        return ";".join(str(e) for e in self.events)

    def __bool__(self) -> bool:
        return bool(self.events)


def as_fault_plan(plan) -> FaultPlan:
    """Coerce None | str | FaultPlan to a FaultPlan."""
    if plan is None:
        return FaultPlan()
    if isinstance(plan, FaultPlan):
        return plan
    if isinstance(plan, str):
        return FaultPlan.parse(plan)
    raise TypeError(f"fault plan must be a FaultPlan or grammar string, "
                    f"got {type(plan).__name__}")
