"""Wireless channel model, host reference — Section II-A, eqs. (1)–(7).

The equation math lives in ``core/channel_lib`` (one backend-agnostic
implementation shared with the on-device ``FleetState`` path used by the
sweep engine); this module binds it to numpy and keeps the stateful
``UAVFleet`` whose ``np.random.Generator`` stream the fused-vs-host
equivalence tests pin.  Implementation notes on the paper's units
(documented interpretations, see DESIGN.md §2):

- eq. (4) free-space term: the paper prints ``10·log10[4π d² f / c]²``; the
  standard Friis form is ``20·log10(4π d f / c)`` — we treat the inner ``d²``
  as a typo and use the standard form (with the paper's literal form the
  resulting rates are sub-bit/s at 500 m, contradicting Fig. 3's ~10 s model
  uploads).
- eq. (4) additional loss: the printed -(η_l-η_n)/P_LOS term yields −200 dB+
  over most of the cell; we read it as the Holis–Pechac / Al-Hourani
  *expected* additional loss — the P_LOS-weighted mix of the LOS and NLOS
  excess losses.
- noise ``σ² = -174 dBm`` is read as the thermal density -174 dBm/Hz
  integrated over the allocated bandwidth (−174 + 10·log10(n_i·B_uav)).
- the Rician factor "K (mW) 1.8~5 dBm" is read as K in dB, resampled
  uniformly each local round (Section IV).
- eq. (5) uses the *expected* amplitude combination v+s (deterministic given
  K), exactly as printed.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.channel_lib import (C_LIGHT, ChannelParams, dbm_to_watt,
                                    outage_transitions)
from repro.core import channel_lib as _lib

__all__ = [
    "C_LIGHT", "ChannelParams", "UAVFleet", "channel_gain", "dbm_to_watt",
    "distance", "elevation_deg", "outage_transitions", "p_los",
    "path_loss_db", "rate_bps",
]


def distance(pos: np.ndarray, bs_height: float) -> np.ndarray:
    """eq. (1).  pos: (..., 3) UAV coordinates; BS at (0, 0, z0)."""
    return _lib.distance(pos, bs_height, xp=np)


def elevation_deg(pos: np.ndarray, bs_height: float) -> np.ndarray:
    """eq. (2), degrees in [0, 90)."""
    return _lib.elevation_deg(pos, bs_height, xp=np)


def p_los(theta_deg: np.ndarray, p: ChannelParams) -> np.ndarray:
    """eq. (3)."""
    return _lib.p_los(theta_deg, p, xp=np)


def path_loss_db(pos: np.ndarray, p: ChannelParams) -> np.ndarray:
    """eq. (4) (negative dB = attenuation; calibration notes above)."""
    return _lib.path_loss_db(pos, p, xp=np)


def channel_gain(pos: np.ndarray, k_db: np.ndarray, p: ChannelParams) -> np.ndarray:
    """eqs. (5)–(6): linear power gain x expected Rician amplitude (v+s)."""
    return _lib.channel_gain(pos, k_db, p, xp=np)


def rate_bps(pos: np.ndarray, k_db: np.ndarray, p: ChannelParams,
             bandwidth_ratio: float = 1.0) -> np.ndarray:
    """eq. (7): Shannon rate in bits/s for allocated bandwidth n_i·B_uav."""
    return _lib.rate_bps(pos, k_db, p, bandwidth_ratio, xp=np)


# ---------------------------------------------------------------------------
# UAV mobility + per-epoch channel realisation (Section IV dynamics)
# ---------------------------------------------------------------------------

@dataclass
class UAVFleet:
    """Random-flight UAVs inside the cell; channel resampled per local epoch.

    Host-side (numpy) twin of ``channel_lib.FleetState``: same equations and
    transition probabilities, but stateful and driven by a
    ``np.random.Generator`` whose draw order is a compatibility contract
    (the fused-round equivalence tests replay it exactly).
    """
    n: int
    params: ChannelParams = field(default_factory=ChannelParams)
    seed: int = 0
    speed_mps: float = 15.0
    epoch_seconds: float = 1.0

    def __post_init__(self):
        self.rng = np.random.default_rng(self.seed)
        r = self.params.cell_radius_m * np.sqrt(self.rng.random(self.n))
        ang = self.rng.random(self.n) * 2 * np.pi
        z = self.rng.uniform(*self.params.uav_z_range, self.n)
        self.pos = np.stack([r * np.cos(ang), r * np.sin(ang), z], axis=-1)
        self.k_db = self.rng.uniform(*self.params.k_db_range, self.n)
        # Gilbert-Elliott interruption chain (stationary prob = outage_prob);
        # "torrential rain / moving obstacles" persist across epochs, so
        # interruptions are bursty rather than iid (Sec. IV's 30% is the
        # stationary marginal).
        self._bad = self.rng.random(self.n) < self.params.outage_prob

    def resample_fading(self) -> None:
        """New Rician K per local training round (Sec. IV)."""
        self.k_db = self.rng.uniform(*self.params.k_db_range, self.n)

    def move(self, dt: float | None = None) -> None:
        """Random-direction step, reflected into the cell (per local epoch)."""
        dt = self.epoch_seconds if dt is None else dt
        p = self.params
        step = self.rng.standard_normal((self.n, 3))
        step /= np.maximum(np.linalg.norm(step, axis=-1, keepdims=True), 1e-9)
        self.pos = self.pos + step * self.speed_mps * dt
        rad = np.linalg.norm(self.pos[:, :2], axis=-1)
        over = rad > p.cell_radius_m
        if over.any():
            self.pos[over, :2] *= (p.cell_radius_m / rad[over])[:, None]
        self.pos[:, 2] = np.clip(self.pos[:, 2], *p.uav_z_range)

    def rates(self, bandwidth_ratio: float = 1.0) -> np.ndarray:
        """Current per-UAV uplink rate, bits/s (eq. 7)."""
        return rate_bps(self.pos, self.k_db, self.params, bandwidth_ratio)

    def outages(self) -> np.ndarray:
        """Advance the interruption chain one epoch and return the state.

        Transition probabilities come from the shared
        ``channel_lib.outage_transitions`` (go_bad clamped to [0, 1] — the
        solved value exceeds 1 as outage_prob → 1)."""
        p = self.params
        go_bad, stay_bad = outage_transitions(p.outage_prob,
                                              p.outage_persistence)
        u = self.rng.random(self.n)
        self._bad = np.where(self._bad, u < stay_bad, u < go_bad)
        return self._bad.copy()
