"""Wireless channel model — Section II-A, eqs. (1)–(7) + Table I.

Rician fading with elevation-dependent LOS probability and additional path
loss (Holis & Pechac model).  Implementation notes on the paper's units
(documented interpretations, see DESIGN.md §2):

- eq. (4) free-space term: the paper prints ``10·log10[4π d² f / c]²``; the
  standard Friis form is ``20·log10(4π d f / c)`` — we treat the inner ``d²``
  as a typo and use the standard form (with the paper's literal form the
  resulting rates are sub-bit/s at 500 m, contradicting Fig. 3's ~10 s model
  uploads).
- noise ``σ² = -174 dBm`` is read as the thermal density -174 dBm/Hz
  integrated over the allocated bandwidth (−174 + 10·log10(n_i·B_uav)).
- the Rician factor "K (mW) 1.8~5 dBm" is read as K in dB, resampled
  uniformly each local round (Section IV).
- eq. (5) uses the *expected* amplitude combination v+s (deterministic given
  K), exactly as printed.

All functions are pure numpy (host-side control plane); the FL sim composes
them with jitted training steps.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple

import numpy as np

C_LIGHT = 299_792_458.0


@dataclass
class ChannelParams:
    """Table I."""
    p_uav_dbm: float = 24.0
    noise_dbm_per_hz: float = -174.0
    k_db_range: Tuple[float, float] = (1.8, 5.0)
    carrier_hz: float = 2.0e9
    bandwidth_uav_hz: float = 10.0e6
    a0: float = 5.0188           # urban environment parameters
    b0: float = 0.3511
    eta_los_db: float = 21.0     # additional path loss LOS   (η_l)
    eta_nlos_db: float = 1.0     # additional path loss NLOS  (η_n)
    outage_prob: float = 0.30    # complete-interruption probability (Sec. IV)
    outage_persistence: float = 0.70   # Gilbert-Elliott stay-bad per epoch
    cell_radius_m: float = 500.0
    bs_height_m: float = 20.0
    uav_z_range: Tuple[float, float] = (20.0, 80.0)


def dbm_to_watt(dbm: float) -> float:
    return 10.0 ** (dbm / 10.0) * 1e-3


def distance(pos: np.ndarray, bs_height: float) -> np.ndarray:
    """eq. (1).  pos: (..., 3) UAV coordinates; BS at (0, 0, z0)."""
    dz = pos[..., 2] - bs_height
    return np.sqrt(pos[..., 0] ** 2 + pos[..., 1] ** 2 + dz ** 2)


def elevation_deg(pos: np.ndarray, bs_height: float) -> np.ndarray:
    """eq. (2), degrees in [0, 90)."""
    d = np.maximum(distance(pos, bs_height), 1e-6)
    return np.degrees(np.arcsin(np.abs(pos[..., 2] - bs_height) / d))


def p_los(theta_deg: np.ndarray, p: ChannelParams) -> np.ndarray:
    """eq. (3)."""
    return 1.0 / (1.0 + p.a0 * np.exp(-p.b0 * (theta_deg - p.a0)))


def path_loss_db(pos: np.ndarray, p: ChannelParams) -> np.ndarray:
    """eq. (4) (negative dB = attenuation).

    Printed form: -(η_l-η_n)/P_LOS - FSPL - η_n.  With Table I's values the
    1/P_LOS division yields −200..−300 dB of *additional* loss over most of
    the 500 m cell (median rate exactly 0 bit/s) — no experiment in Fig. 3
    could run on that channel, so we read the term as the underlying
    Holis–Pechac / Al-Hourani expected additional loss that [7] defines:
    the P_LOS-weighted mix of the LOS (1 dB) and NLOS (21 dB) excess losses.
    This calibration is recorded in DESIGN.md §2 and EXPERIMENTS.md.
    """
    d = np.maximum(distance(pos, p.bs_height_m), 1.0)
    plos = p_los(elevation_deg(pos, p.bs_height_m), p)
    fspl = 20.0 * np.log10(4.0 * np.pi * d * p.carrier_hz / C_LIGHT)
    eta_los = min(p.eta_los_db, p.eta_nlos_db)       # LOS suffers less
    eta_nlos = max(p.eta_los_db, p.eta_nlos_db)
    extra = plos * eta_los + (1.0 - plos) * eta_nlos
    return -fspl - extra


def channel_gain(pos: np.ndarray, k_db: np.ndarray, p: ChannelParams) -> np.ndarray:
    """eqs. (5)–(6): linear power gain x expected Rician amplitude (v+s)."""
    k_lin = 10.0 ** (np.asarray(k_db) / 10.0)
    v = np.sqrt(k_lin / (k_lin + 1.0))
    s = np.sqrt(1.0 / (2.0 * (k_lin + 1.0)))
    return 10.0 ** (path_loss_db(pos, p) / 10.0) * (v + s)


def rate_bps(pos: np.ndarray, k_db: np.ndarray, p: ChannelParams,
             bandwidth_ratio: float = 1.0) -> np.ndarray:
    """eq. (7): Shannon rate in bits/s for allocated bandwidth n_i·B_uav."""
    bw = bandwidth_ratio * p.bandwidth_uav_hz
    noise_w = dbm_to_watt(p.noise_dbm_per_hz + 10.0 * np.log10(bw))
    snr = channel_gain(pos, k_db, p) * dbm_to_watt(p.p_uav_dbm) / noise_w
    return bw * np.log2(1.0 + snr)


# ---------------------------------------------------------------------------
# UAV mobility + per-epoch channel realisation (Section IV dynamics)
# ---------------------------------------------------------------------------

@dataclass
class UAVFleet:
    """Random-flight UAVs inside the cell; channel resampled per local epoch."""
    n: int
    params: ChannelParams = field(default_factory=ChannelParams)
    seed: int = 0
    speed_mps: float = 15.0
    epoch_seconds: float = 1.0

    def __post_init__(self):
        self.rng = np.random.default_rng(self.seed)
        r = self.params.cell_radius_m * np.sqrt(self.rng.random(self.n))
        ang = self.rng.random(self.n) * 2 * np.pi
        z = self.rng.uniform(*self.params.uav_z_range, self.n)
        self.pos = np.stack([r * np.cos(ang), r * np.sin(ang), z], axis=-1)
        self.k_db = self.rng.uniform(*self.params.k_db_range, self.n)
        # Gilbert-Elliott interruption chain (stationary prob = outage_prob);
        # "torrential rain / moving obstacles" persist across epochs, so
        # interruptions are bursty rather than iid (Sec. IV's 30% is the
        # stationary marginal).
        self._bad = self.rng.random(self.n) < self.params.outage_prob

    def resample_fading(self) -> None:
        """New Rician K per local training round (Sec. IV)."""
        self.k_db = self.rng.uniform(*self.params.k_db_range, self.n)

    def move(self, dt: float | None = None) -> None:
        """Random-direction step, reflected into the cell (per local epoch)."""
        dt = self.epoch_seconds if dt is None else dt
        p = self.params
        step = self.rng.standard_normal((self.n, 3))
        step /= np.maximum(np.linalg.norm(step, axis=-1, keepdims=True), 1e-9)
        self.pos = self.pos + step * self.speed_mps * dt
        rad = np.linalg.norm(self.pos[:, :2], axis=-1)
        over = rad > p.cell_radius_m
        if over.any():
            self.pos[over, :2] *= (p.cell_radius_m / rad[over])[:, None]
        self.pos[:, 2] = np.clip(self.pos[:, 2], *p.uav_z_range)

    def rates(self, bandwidth_ratio: float = 1.0) -> np.ndarray:
        """Current per-UAV uplink rate, bits/s (eq. 7)."""
        return rate_bps(self.pos, self.k_db, self.params, bandwidth_ratio)

    def outages(self) -> np.ndarray:
        """Advance the interruption chain one epoch and return the state.

        stay_bad = outage_persistence; go_bad chosen so the stationary
        marginal equals outage_prob (the paper's 30%)."""
        p = self.params
        stay_bad = p.outage_persistence
        go_bad = p.outage_prob * (1.0 - stay_bad) / max(1.0 - p.outage_prob, 1e-9)
        u = self.rng.random(self.n)
        self._bad = np.where(self._bad, u < stay_bad, u < go_bad)
        return self._bad.copy()
