"""The paper's primary contribution: opportunistic-proactive transmission of
distributed learning model updates (OPT-HSFL), plus the multi-pod
OpportunisticSync generalization and the vectorized sweep engine that runs
whole Fig. 3 grids as single device programs."""
from repro.core.aggregation import aggregate_round, fedavg, fedasync_weight
from repro.core.channel import ChannelParams, UAVFleet, rate_bps
from repro.core.hsfl import HSFLConfig, HSFLSimulation, run_hsfl
from repro.core.opportunistic_sync import OppSyncConfig
from repro.core.schemes import (Scheme, get_scheme, register_scheme,
                                registered_schemes)
from repro.core.sweep import SweepSpec, run_hsfl_on_device, run_sweep
from repro.core.transmission import OppTransmitter, scheduled_epochs

__all__ = [
    "ChannelParams", "HSFLConfig", "HSFLSimulation", "OppSyncConfig",
    "OppTransmitter", "Scheme", "SweepSpec", "UAVFleet", "aggregate_round",
    "fedavg", "fedasync_weight", "get_scheme", "rate_bps",
    "register_scheme", "registered_schemes", "run_hsfl",
    "run_hsfl_on_device", "run_sweep", "scheduled_epochs",
]
