"""The paper's primary contribution: opportunistic-proactive transmission of
distributed learning model updates (OPT-HSFL), plus the multi-pod
OpportunisticSync generalization."""
from repro.core.aggregation import aggregate_round, fedavg, fedasync_weight
from repro.core.channel import ChannelParams, UAVFleet, rate_bps
from repro.core.hsfl import HSFLConfig, HSFLSimulation, run_hsfl
from repro.core.opportunistic_sync import OppSyncConfig
from repro.core.transmission import OppTransmitter, scheduled_epochs

__all__ = [
    "ChannelParams", "HSFLConfig", "HSFLSimulation", "OppSyncConfig",
    "OppTransmitter", "UAVFleet", "aggregate_round", "fedavg",
    "fedasync_weight", "rate_bps", "run_hsfl", "scheduled_epochs",
]
