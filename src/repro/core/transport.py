"""Lossy-wire transport: chunked resumable uploads, burst errors, XOR parity.

The serving path's wire was perfect until now — every upload an atomic
msgpack blob that either lands whole or is CRC-rejected.  This module
models the channel the paper actually assumes:

  - **chunking** — a codec snapshot splits into fixed-size chunks, each
    with its own CRC32 trailer (``make_chunks``).  The transfer is
    content-addressed: ``transfer_id`` is the CRC32 of the whole payload,
    so re-offering identical content resumes instead of re-sending.
  - **budget-driven scheduling** — the eq. 14 probe allowance
    τ_extra = (b−1)·m/r⁰ splits evenly over the scheduled probe epochs;
    each epoch carries ``floor(τ_share·r / (8·chunk_bytes))`` chunks at
    the instantaneous rate (``ChunkedUploader``).  A snapshot one epoch
    cannot afford *accumulates across probe epochs* (resumable partial
    upload) instead of being cancelled by the all-or-nothing eq. 15 gate.
  - **burst errors** — ``LossyWire`` runs its own per-chunk
    Gilbert–Elliott chain (the same ``channel_lib.outage_transitions``
    solver as the fleet outage chain): bits flip at ``ber_bad`` in the
    bad state and ``ber_good`` in the good state.  Chunk CRCs survive
    untouched, so the receiver detects the corruption and NACKs — the
    sender retransmits under the existing ``faults.BackoffPolicy``.
  - **erasure rescue** — systematic XOR parity: every ``parity_k`` data
    chunks are closed by one parity chunk (interleaved, so a truncated
    tail costs at most the newest group's protection).  The receiver
    (``ChunkAssembler``) rebuilds any single missing data chunk per
    group — k-of-(k+1) erasure coding — rescuing incomplete uploads at
    round close.
  - **cross-round resume** — ``TransferLedger`` keeps incomplete
    assemblies keyed by ``(client, transfer_id)`` across rounds; a
    sender can query ``have`` and push only the missing chunks.

Everything here is host-side transport plumbing (numpy/zlib, no jax):
the device engines never see chunks — ``serving/fl_server`` drives this
module and hands fully reassembled payloads to the normal inbox path.
"""
from __future__ import annotations

import zlib
from collections import OrderedDict
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.channel_lib import outage_transitions

__all__ = ["TransportConfig", "Chunk", "ChunkAssembler", "ChunkedUploader",
           "LossyWire", "TransferLedger", "epoch_chunk_budget",
           "make_chunks", "reassemble", "split_payload", "xor_bytes"]


# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TransportConfig:
    """Knobs of the lossy wire (``FLServer(transport=...)``)."""
    chunk_bytes: int = 4096        # data chunk size on the wire
    parity_k: int = 4              # data chunks per XOR parity group; 0 = off
    ber_good: float = 0.0          # per-bit flip probability, GE good state
    ber_bad: float = 0.0           # per-bit flip probability, GE bad state
    wire_outage_prob: float = 0.30   # GE stationary bad-state marginal
    wire_persistence: float = 0.70   # GE stay-bad probability

    def validate(self) -> "TransportConfig":
        if self.chunk_bytes < 1:
            raise ValueError(f"chunk_bytes must be >= 1, got "
                             f"{self.chunk_bytes}")
        if self.parity_k < 0:
            raise ValueError(f"parity_k must be >= 0, got {self.parity_k}")
        for name in ("ber_good", "ber_bad", "wire_outage_prob",
                     "wire_persistence"):
            v = getattr(self, name)
            if not (0.0 <= v <= 1.0):
                raise ValueError(f"{name} must lie in [0, 1], got {v}")
        return self


# ---------------------------------------------------------------------------
# wire format
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Chunk:
    """One wire unit of a transfer.  ``crc`` is computed *before* the wire,
    so in-flight corruption is detectable (and NACKable); ``transfer_id``
    is the CRC32 of the whole payload — the content address the receiver
    verifies after reassembly."""
    transfer_id: int
    index: int                     # data: 0-based position; parity: group id
    kind: str                      # "data" | "parity"
    n_data: int                    # data chunks in the transfer
    payload_len: int               # total payload bytes (trims the tail)
    data: bytes
    crc: int

    @property
    def key(self) -> Tuple[str, int]:
        return (self.kind, self.index)

    def ok(self) -> bool:
        return zlib.crc32(self.data) == self.crc


def split_payload(payload: bytes, chunk_bytes: int) -> List[bytes]:
    """Fixed-size split (last part may be short; empty payload -> [b''])."""
    if chunk_bytes < 1:
        raise ValueError(f"chunk_bytes must be >= 1, got {chunk_bytes}")
    parts = [payload[i:i + chunk_bytes]
             for i in range(0, len(payload), chunk_bytes)]
    return parts or [b""]


def xor_bytes(*parts: bytes) -> bytes:
    """Bytewise XOR, zero-padded to the longest part."""
    n = max(len(p) for p in parts)
    out = np.zeros(n, np.uint8)
    for p in parts:
        a = np.frombuffer(p, np.uint8)
        out[:len(a)] ^= a
    return out.tobytes()


def make_chunks(payload: bytes, cfg: TransportConfig) -> List[Chunk]:
    """Systematic chunking: data chunks in index order, one XOR parity
    chunk closing each group of ``parity_k`` (groups interleaved so a
    truncated upload loses at most the newest group's protection)."""
    tid = zlib.crc32(payload)
    parts = split_payload(payload, cfg.chunk_bytes)
    n = len(parts)
    out: List[Chunk] = []
    group: List[bytes] = []
    for i, part in enumerate(parts):
        out.append(Chunk(tid, i, "data", n, len(payload), part,
                         zlib.crc32(part)))
        group.append(part)
        if cfg.parity_k and (len(group) == cfg.parity_k or i == n - 1):
            p = xor_bytes(*group)
            out.append(Chunk(tid, i // cfg.parity_k, "parity", n,
                             len(payload), p, zlib.crc32(p)))
            group = []
    return out


def reassemble(data: Dict[int, bytes], n_data: int, payload_len: int,
               transfer_id: int) -> bytes:
    """Concatenate the data chunks and verify the content address."""
    missing = [i for i in range(n_data) if i not in data]
    if missing:
        raise ValueError(f"transfer {transfer_id:#010x}: missing data "
                         f"chunks {missing}")
    payload = b"".join(data[i] for i in range(n_data))[:payload_len]
    if zlib.crc32(payload) != transfer_id:
        raise ValueError(f"transfer {transfer_id:#010x}: reassembled "
                         f"payload fails the content CRC")
    return payload


# ---------------------------------------------------------------------------
# receiver side
# ---------------------------------------------------------------------------

class ChunkAssembler:
    """Reassembly state of one transfer: banked chunks, XOR recovery."""

    def __init__(self, transfer_id: int, n_data: int, payload_len: int,
                 parity_k: int, chunk_bytes: int):
        self.transfer_id = int(transfer_id)
        self.n_data = int(n_data)
        self.payload_len = int(payload_len)
        self.parity_k = int(parity_k)
        self.chunk_bytes = int(chunk_bytes)
        self.data: Dict[int, bytes] = {}
        self.parity: Dict[int, bytes] = {}
        self.duplicates = 0
        self.recovered = 0             # data chunks rebuilt via parity

    @classmethod
    def for_chunk(cls, chunk: Chunk, cfg: TransportConfig
                  ) -> "ChunkAssembler":
        return cls(chunk.transfer_id, chunk.n_data, chunk.payload_len,
                   cfg.parity_k, cfg.chunk_bytes)

    def add(self, chunk: Chunk) -> str:
        """Bank a received chunk: 'accepted' | 'duplicate' | 'corrupt'."""
        if chunk.transfer_id != self.transfer_id:
            return "stale"
        if not chunk.ok():
            return "corrupt"
        store = self.data if chunk.kind == "data" else self.parity
        if chunk.index in store:
            self.duplicates += 1
            return "duplicate"
        store[chunk.index] = chunk.data
        return "accepted"

    def have(self) -> Set[Tuple[str, int]]:
        """Chunk keys already banked (the cross-round resume have-set)."""
        return ({("data", i) for i in self.data}
                | {("parity", g) for g in self.parity})

    def _group(self, g: int) -> range:
        return range(g * self.parity_k,
                     min((g + 1) * self.parity_k, self.n_data))

    def _len_of(self, i: int) -> int:
        if i < self.n_data - 1:
            return self.chunk_bytes
        return self.payload_len - (self.n_data - 1) * self.chunk_bytes

    def try_reconstruct(self) -> int:
        """XOR-rebuild every group missing exactly one data chunk whose
        parity arrived (k-of-(k+1) erasure rescue).  Returns the number
        of chunks recovered by this call."""
        if not self.parity_k:
            return 0
        rec = 0
        for g, p in self.parity.items():
            absent = [i for i in self._group(g) if i not in self.data]
            if len(absent) == 1:
                i = absent[0]
                others = [self.data[j] for j in self._group(g) if j != i]
                self.data[i] = xor_bytes(p, *others)[:self._len_of(i)]
                rec += 1
        self.recovered += rec
        return rec

    def complete(self) -> bool:
        return len(self.data) == self.n_data

    def payload(self) -> bytes:
        return reassemble(self.data, self.n_data, self.payload_len,
                          self.transfer_id)


class TransferLedger:
    """Content-addressed store of in-flight reassemblies, persisting
    *across rounds*: a payload re-offered later (same CRC) resumes from
    the chunks already banked instead of starting over.  FIFO-bounded —
    abandoned transfers (e.g. stale snapshots) age out."""

    def __init__(self, max_entries: int = 256):
        self.max_entries = int(max_entries)
        self._asm: "OrderedDict[Tuple[int, int], ChunkAssembler]" = \
            OrderedDict()

    def assembler(self, client_id: int, chunk: Chunk,
                  cfg: TransportConfig) -> ChunkAssembler:
        key = (int(client_id), chunk.transfer_id)
        asm = self._asm.get(key)
        if asm is None:
            asm = ChunkAssembler.for_chunk(chunk, cfg)
            self._asm[key] = asm
            while len(self._asm) > self.max_entries:
                self._asm.popitem(last=False)
        return asm

    def get(self, client_id: int, transfer_id: int
            ) -> Optional[ChunkAssembler]:
        return self._asm.get((int(client_id), int(transfer_id)))

    def pop(self, client_id: int, transfer_id: int) -> None:
        self._asm.pop((int(client_id), int(transfer_id)), None)

    def __len__(self) -> int:
        return len(self._asm)


# ---------------------------------------------------------------------------
# the wire
# ---------------------------------------------------------------------------

class LossyWire:
    """Per-(round, client) Gilbert–Elliott burst-error channel.  Each
    chunk transmission advances the chain one step; the state picks the
    bit-error rate.  CRC trailers ride unharmed, so corruption is always
    *detectable* — the receiver NACKs and the sender retransmits."""

    def __init__(self, cfg: TransportConfig, rng: np.random.Generator):
        self.cfg = cfg
        self.rng = rng
        self.go_bad, self.stay_bad = outage_transitions(
            cfg.wire_outage_prob, cfg.wire_persistence)
        # stationary start, like the fleet chain
        self.bad = bool(rng.random() < cfg.wire_outage_prob)
        self.chunks = 0
        self.corrupted = 0

    def transmit(self, chunk: Chunk) -> Chunk:
        """One chunk over the wire; returns it possibly bit-flipped."""
        self.bad = bool(self.rng.random()
                        < (self.stay_bad if self.bad else self.go_bad))
        self.chunks += 1
        ber = self.cfg.ber_bad if self.bad else self.cfg.ber_good
        if ber <= 0.0 or not chunk.data:
            return chunk
        nbits = len(chunk.data) * 8
        flips = int(self.rng.binomial(nbits, min(ber, 1.0)))
        if flips == 0:
            return chunk
        buf = np.frombuffer(chunk.data, np.uint8).copy()
        pos = self.rng.integers(0, nbits, size=flips)
        np.bitwise_xor.at(buf, pos // 8,
                          (1 << (pos % 8)).astype(np.uint8))
        self.corrupted += 1
        return replace(chunk, data=buf.tobytes())


# ---------------------------------------------------------------------------
# sender side
# ---------------------------------------------------------------------------

def epoch_chunk_budget(tau_s: float, rate_bps: float,
                       chunk_bytes: int) -> int:
    """Chunks one probe epoch affords: ⌊τ·r / (8·chunk_bytes)⌋ — the
    eq. 14/15 airtime budget expressed in wire units."""
    if tau_s <= 0.0 or rate_bps <= 0.0:
        return 0
    return int((tau_s * rate_bps) / (8.0 * chunk_bytes))


class ChunkedUploader:
    """Client-side resumable snapshot uploader (Alg. 2 under chunking).

    The eq. 14 allowance τ_extra splits evenly over the scheduled probe
    epochs (``tau_share``); each epoch carries
    ``min(pending, ⌊τ_share·r / (8·chunk_bytes)⌋)`` chunks, charged
    against the remaining allowance at their true airtime.  A transfer
    the current epoch cannot finish *stays in flight* and resumes at the
    next probe — the resumable alternative to ``OppTransmitter``'s
    all-or-nothing eq. 15 cancel."""

    def __init__(self, cfg: TransportConfig, tau_extra: float,
                 n_probes: int):
        self.cfg = cfg
        self.tau_left = float(tau_extra)
        self.tau_share = float(tau_extra) / max(int(n_probes), 1)
        self.chunks: List[Chunk] = []
        self.cursor = 0
        self.seq = 0                  # transfers started (snapshot nonce)

    @property
    def idle(self) -> bool:
        """No transfer in flight (never started, or fully handed off)."""
        return self.cursor >= len(self.chunks)

    @property
    def transfer_id(self) -> Optional[int]:
        return self.chunks[0].transfer_id if self.chunks else None

    def begin(self, payload: bytes) -> None:
        """Start a fresh transfer (only when idle — an in-flight snapshot
        is never abandoned mid-upload)."""
        if not self.idle:
            raise RuntimeError("a transfer is still in flight")
        self.seq += 1
        self.chunks = make_chunks(payload, self.cfg)
        self.cursor = 0

    def finish(self) -> None:
        """Close out the current transfer (handed off or abandoned); the
        next scheduled probe may ``begin`` a fresh snapshot."""
        self.chunks = []
        self.cursor = 0

    def take_epoch(self, rate_bps: float) -> List[Chunk]:
        """The chunks this probe epoch's budget affords, charged to the
        remaining eq. 14 allowance at their true airtime."""
        tau = min(self.tau_share, self.tau_left)
        n = min(len(self.chunks) - self.cursor,
                epoch_chunk_budget(tau, rate_bps, self.cfg.chunk_bytes))
        out = self.chunks[self.cursor:self.cursor + n]
        self.cursor += n
        sent = sum(len(c.data) for c in out)
        self.tau_left -= sent * 8.0 / max(rate_bps, 1e-9)
        return out
