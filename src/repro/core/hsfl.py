"""HSFL + OPT simulation driver — Algorithms 1 & 2 end to end.

Faithful reproduction of Section IV: 30 UAVs, 10 selected/round, B rounds,
e=6 local epochs, batch 10, lr 0.01, 5-layer CNN, Rician channel with
per-round K resampling, per-epoch path-loss variation (fleet movement) and
30% complete-interruption probability.  Schemes:

  'opt'      — OPT-HSFL (this paper): intermediate snapshots during local
               training rescue delayed finals (Alg. 2).
  'discard'  — HSFL with delayed updates dropped (the b=1 / dashed baseline).
  'async'    — Async-HSFL: delayed updates arrive next round and aggregate
               with the polynomial staleness weight α(s+1)^(−a) [3].

SL users train mathematically identically to FL users (SL with synchronized
FedAvg produces the same updates — the split only moves *where* layers run);
what differs is the latency/energy/payload accounting: SL transmits b·m_l +
m_a (eq. 13) and pays the BS round trip, exactly as costed in core/latency.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import latency as lat
from repro.core.aggregation import aggregate_round
from repro.core.channel import ChannelParams, UAVFleet
from repro.core.metrics import RoundLog, SimLog
from repro.core.selection import schedule_users
from repro.core.transmission import OppTransmitter
from repro.data.synthetic import Dataset, make_digits
from repro.data.partition import partition
from repro.models import cnn as cnn_mod
from repro.models import module as m
from repro.training.loss import accuracy, cross_entropy


@dataclass
class HSFLConfig:
    scheme: str = "opt"            # opt | discard | async
    distribution: str = "noniid"   # iid | noniid | imbalanced
    n_uavs: int = 30
    k_select: int = 10
    rounds: int = 100              # B
    local_epochs: int = 6          # e
    b: int = 2                     # transmission budget
    tau_max: float = 9.0           # seconds
    batch_size: int = 10
    lr: float = 0.01
    steps_per_epoch: int = 4       # fixed-size local epoch (single compile)
    n_train: int = 6000
    n_test: int = 1000
    cut_stage: int = 2             # SL cut: conv stages on the UE
    seed: int = 0
    # nominal payload scale: the paper's CNN transmits ~10 MB class models;
    # ours is ~1.8 MB — latency realism keeps τ_max in the paper's 8–11 s
    # regime via this override (accuracy math is unaffected).
    model_bytes: float = 10e6
    ue_model_fraction: float = 0.25
    compress_ratio: float = 1.0    # <1 when the delta codec is enabled
    schedule_override: tuple = ()  # manual opportunistic schedule (Sec. III-B)
    # UAV on-board compute range (FLOP/s).  Sec. IV doesn't specify device
    # compute; the default straddles the paper's 8-11 s tau_max sweep so the
    # participation cliff (Fig. 3d) is observable.
    flops_range: tuple = (0.8e8, 4e8)
    channel: ChannelParams = field(default_factory=ChannelParams)
    async_alpha: float = 0.4
    async_a: float = 0.5


def _heterogeneous_devices(n: int, rng: np.random.Generator,
                           flops_range=(1.5e8, 6e8)) -> List[lat.DeviceProfile]:
    return [lat.DeviceProfile(flops_per_sec=float(rng.uniform(*flops_range)))
            for _ in range(n)]


def _sample_epoch(ds: Dataset, cfg: HSFLConfig, rng: np.random.Generator):
    """Fixed-shape epoch batches (steps, bs, ...) — one jit compile total."""
    need = cfg.steps_per_epoch * cfg.batch_size
    idx = rng.permutation(len(ds))
    while len(idx) < need:
        idx = np.concatenate([idx, rng.permutation(len(ds))])
    idx = idx[:need].reshape(cfg.steps_per_epoch, cfg.batch_size)
    return jnp.asarray(ds.x[idx]), jnp.asarray(ds.y[idx])


class HSFLSimulation:
    """Host-side control plane composing jitted local training."""

    def __init__(self, cfg: HSFLConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        full = make_digits(cfg.n_train + cfg.n_test, seed=cfg.seed)
        self.test = Dataset(full.x[cfg.n_train:], full.y[cfg.n_train:])
        train = Dataset(full.x[:cfg.n_train], full.y[:cfg.n_train])
        self.clients = partition(train, cfg.n_uavs, cfg.distribution, cfg.seed)
        self.fleet = UAVFleet(cfg.n_uavs, cfg.channel, seed=cfg.seed + 1)
        self.devices = _heterogeneous_devices(cfg.n_uavs, self.rng,
                                              cfg.flops_range)
        self.workloads = [
            lat.WorkloadProfile(local_epochs=cfg.local_epochs,
                                samples=len(c)) for c in self.clients]
        self.params = cnn_mod.init_cnn(jax.random.PRNGKey(cfg.seed))
        self._test_x = jnp.asarray(self.test.x)
        self._test_y = jnp.asarray(self.test.y)
        self._build_jits()

    # -- jitted kernels ----------------------------------------------------
    def _build_jits(self):
        lr = self.cfg.lr

        def epoch_fn(params, xs, ys):
            def step(p, batch):
                bx, by = batch

                def loss(q):
                    logits = cnn_mod.forward(q, bx)
                    return cross_entropy(logits, by)

                g = jax.grad(loss)(p)
                p = jax.tree_util.tree_map(lambda w, gg: w - lr * gg, p, g)
                return p, ()

            params, _ = jax.lax.scan(step, params, (xs, ys))
            return params

        def eval_fn(params, x, y):
            logits = cnn_mod.forward(params, x)
            return cross_entropy(logits, y), accuracy(logits, y)

        # all selected users advance one epoch at once: params stacked (K,...)
        self._epoch_all = jax.jit(jax.vmap(epoch_fn))
        self._eval = jax.jit(eval_fn)

    def evaluate(self) -> Tuple[float, float]:
        l, a = self._eval(self.params, self._test_x, self._test_y)
        return float(l), float(a)

    # -- one communication round -------------------------------------------
    def run_round(self, t: int, carry_delayed: List[tuple]) -> Tuple[RoundLog, List[tuple]]:
        cfg = self.cfg
        self.fleet.resample_fading()           # per local-round K (Sec. IV)
        rates0 = self.fleet.rates()
        ue_bytes = cfg.model_bytes * cfg.ue_model_fraction
        sched = schedule_users(
            rates0, self.devices, self.workloads,
            cfg.model_bytes, ue_bytes, cfg.b, cfg.tau_max, cfg.k_select)

        log = RoundLog(round=t, selected=len(sched))
        if not sched:
            self.params = aggregate_round([], carry_delayed, self.params,
                                          cfg.scheme, cfg.async_alpha, cfg.async_a)
            return log, []
        txs: Dict[int, OppTransmitter] = {}
        for u in sched:
            payload = cfg.model_bytes if u.mode == "FL" else ue_bytes
            txs[u.index] = OppTransmitter(
                payload, cfg.local_epochs, cfg.b, u.rate0_bps,
                compress_ratio=cfg.compress_ratio,
                schedule_override=cfg.schedule_override)

        # stacked per-user params (K, ...): everyone starts from the global.
        # Pad K to a small bucket so the vmapped epoch compiles O(1) times.
        K = min(cfg.k_select, 2 * ((len(sched) + 1) // 2))
        stacked = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (K,) + a.shape), self.params)

        def user_tree(i: int):
            return jax.tree_util.tree_map(lambda a: a[i], stacked)

        # local training: epochs advance in lockstep; channel drifts per epoch
        for e_t in range(1, cfg.local_epochs + 1):
            self.fleet.move()                  # path loss varies per epoch
            rates = self.fleet.rates()
            outages = self.fleet.outages()
            eb = [_sample_epoch(self.clients[u.index], cfg, self.rng)
                  for u in sched]
            while len(eb) < K:                 # pad unused slots (ignored)
                eb.append(eb[0])
            xs = jnp.stack([b[0] for b in eb])
            ys = jnp.stack([b[1] for b in eb])
            stacked = self._epoch_all(stacked, xs, ys)
            if cfg.scheme == "opt" and cfg.b > 1:
                for i, u in enumerate(sched):
                    if e_t in txs[u.index].schedule:
                        txs[u.index].maybe_transmit(
                            e_t, float(rates[u.index]),
                            bool(outages[u.index]), user_tree(i))

        # final uploads
        arrived: List[object] = []
        new_delayed: List[tuple] = []
        rates = self.fleet.rates()
        outages = self.fleet.outages()
        for i, u in enumerate(sched):
            tx = txs[u.index]
            tr_time = (lat.train_time_fl(self.devices[u.index], self.workloads[u.index])
                       if u.mode == "FL" else
                       lat.train_time_sl(self.devices[u.index], self.workloads[u.index]))
            ok = tx.final_upload(float(rates[u.index]), bool(outages[u.index]),
                                 tr_time, cfg.tau_max)
            if ok:
                arrived.append(user_tree(i))
                log.arrived_final += 1
            elif cfg.scheme == "opt" and tx.snapshot is not None:
                arrived.append(tx.snapshot)     # the paper's rescue
                log.used_snapshot += 1
            elif cfg.scheme == "async":
                new_delayed.append((user_tree(i), 1))      # max delay 1
                log.delayed += 1
            else:
                log.dropped += 1
            log.bytes_sent += tx.bytes_sent
            if u.mode == "SL" and tx.events:
                # one-off activation payload m_a rides the SL uplink (eq. 12)
                log.bytes_sent += self.workloads[u.index].act_bytes_per_sample \
                    * self.workloads[u.index].samples

        self.params = aggregate_round(
            arrived, carry_delayed, self.params, cfg.scheme,
            cfg.async_alpha, cfg.async_a)
        return log, new_delayed

    # -- full simulation -----------------------------------------------------
    def run(self, eval_every: int = 1, verbose: bool = False) -> SimLog:
        sim = SimLog()
        delayed: List[tuple] = []
        for t in range(1, self.cfg.rounds + 1):
            log, delayed = self.run_round(t, delayed)
            if t % eval_every == 0 or t == self.cfg.rounds:
                log.test_loss, log.test_acc = self.evaluate()
            sim.add(log)
            if verbose and (t % 10 == 0 or t == 1):
                print(f"[{self.cfg.scheme}/{self.cfg.distribution} b={self.cfg.b}] "
                      f"round {t}: acc={log.test_acc:.4f} loss={log.test_loss:.4f} "
                      f"rescued={log.used_snapshot} dropped={log.dropped}")
        return sim


def run_hsfl(cfg: HSFLConfig, verbose: bool = False) -> SimLog:
    return HSFLSimulation(cfg).run(verbose=verbose)
