"""HSFL + OPT simulation driver — Algorithms 1 & 2 end to end.

Faithful reproduction of Section IV: 30 UAVs, 10 selected/round, B rounds,
e=6 local epochs, batch 10, lr 0.01, 5-layer CNN, Rician channel with
per-round K resampling, per-epoch path-loss variation (fleet movement) and
30% complete-interruption probability.  Schemes:

  'opt'      — OPT-HSFL (this paper): intermediate snapshots during local
               training rescue delayed finals (Alg. 2).
  'discard'  — HSFL with delayed updates dropped (the b=1 / dashed baseline).
  'async'    — Async-HSFL: delayed updates arrive next round and aggregate
               with the polynomial staleness weight α(s+1)^(−a) [3].

SL users train mathematically identically to FL users (SL with synchronized
FedAvg produces the same updates — the split only moves *where* layers run);
what differs is the latency/energy/payload accounting: SL transmits b·m_l +
m_a (eq. 13) and pays the BS round trip, exactly as costed in core/latency.

Two round engines share the control plane:

  fused (default) — ``core/fused_round``: channel + batches presampled
      host-side once per round, then the whole round (vmapped users, scanned
      epochs, on-device OPT scheduler, masked-mean aggregation) runs as one
      jitted device program.  ~5x faster at fig3 scale; optional int8
      delta-codec snapshots (``use_delta_codec``).
  host — the original Python control loop over ``OppTransmitter``; kept as
      the reference implementation.  ``tests/test_fused_round.py`` pins the
      two to identical per-round arrived/rescued/dropped trajectories.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import latency as lat
from repro.core.channel import ChannelParams, UAVFleet
from repro.core.fused_round import build_fused_round
from repro.core.metrics import RoundLog, SimLog
from repro.core.schemes import get_scheme
from repro.core.transmission import OppTransmitter
from repro.data.synthetic import Dataset, make_digits
from repro.data.partition import partition
from repro.kernels.delta_codec.ops import codec_ratio, decode_delta, encode_delta
from repro.models import cnn as cnn_mod
from repro.training.loss import accuracy, cross_entropy


@dataclass
class HSFLConfig:
    scheme: str = "opt"            # opt | discard | async
    distribution: str = "noniid"   # iid | noniid | imbalanced
    n_uavs: int = 30
    k_select: int = 10
    rounds: int = 100              # B
    local_epochs: int = 6          # e
    b: int = 2                     # transmission budget
    tau_max: float = 9.0           # seconds
    batch_size: int = 10
    lr: float = 0.01
    steps_per_epoch: int = 4       # fixed-size local epoch (single compile)
    n_train: int = 6000
    n_test: int = 1000
    cut_stage: int = 2             # SL cut: conv stages on the UE
    seed: int = 0
    # nominal payload scale: the paper's CNN transmits ~10 MB class models;
    # ours is ~1.8 MB — latency realism keeps τ_max in the paper's 8–11 s
    # regime via this override (accuracy math is unaffected).
    model_bytes: float = 10e6
    ue_model_fraction: float = 0.25
    compress_ratio: float = 1.0    # <1 when snapshots are compressed
    # int8 delta-codec snapshots (kernels/delta_codec): compress_ratio is
    # then derived from the actual int8+scale byte count of the model, and
    # rescued snapshots carry real quantization noise.  codec_block is the
    # quantization group width (lanes per absmax scale) — sweepable as a
    # group static: smaller blocks mean tighter scales (less noise) but a
    # higher wire-byte overhead (the eq. 15 frontier of arXiv:2405.00681).
    use_delta_codec: bool = False
    codec_block: int = 512
    # delta-codec bit depth: 8 (int8, ~0.252x) or 4 (int4-in-int8 storage,
    # ~0.127x wire bytes) — the sweepable rate point of the eq. 15
    # overhead-vs-delay frontier; 4-bit rescues carry ~16x the noise
    codec_bits: int = 8
    use_fused_round: bool = True   # False -> host OppTransmitter reference
    # CNN hot-path policy (kernels/fused_cnn.ForwardPolicy), device engines
    # only — the host reference loop always runs the autodiff step:
    #   kernel:      xla (custom-VJP fused step, default) | pallas | im2col
    #   precision:   f32 (value-pinned) | bf16 (mixed precision)
    #   block_k:     user-tile size of the blocked kernel grid (0 = the
    #                whole selected cohort in one grid step)
    #   batch_users: False -> legacy vmap-of-per-user-kernels step (the
    #                blocked-vs-vmapped baseline)
    kernel: str = "xla"
    precision: str = "f32"
    block_k: int = 0
    batch_users: bool = True
    schedule_override: tuple = ()  # manual opportunistic schedule (Sec. III-B)
    # UAV on-board compute range (FLOP/s).  Sec. IV doesn't specify device
    # compute; the default straddles the paper's 8-11 s tau_max sweep so the
    # participation cliff (Fig. 3d) is observable.
    flops_range: tuple = (0.8e8, 4e8)
    channel: ChannelParams = field(default_factory=ChannelParams)
    async_alpha: float = 0.4
    async_a: float = 0.5


def model_compress_ratio(cfg: HSFLConfig) -> float:
    """The effective snapshot compression ratio for ``cfg``.

    With ``use_delta_codec`` the knob is *derived* — the actual int8+scale
    byte count of this config's CNN over its float32 bytes
    (``delta_codec.ops.codec_ratio``), computed from abstract shapes so no
    params are materialized; otherwise it is the hand-set
    ``cfg.compress_ratio``.  Shared by ``HSFLSimulation`` (host/fused
    engines) and ``core/sweep`` (device engine) so the eq. 14/15 payload
    accounting cannot drift between them."""
    if not cfg.use_delta_codec:
        return cfg.compress_ratio
    shapes = jax.eval_shape(lambda: cnn_mod.init_cnn(jax.random.PRNGKey(0)))
    n = sum(int(np.prod(l.shape))
            for l in jax.tree_util.tree_leaves(shapes))
    return codec_ratio(n, cfg.codec_block, cfg.codec_bits)


def _heterogeneous_devices(n: int, rng: np.random.Generator,
                           flops_range=(1.5e8, 6e8)) -> List[lat.DeviceProfile]:
    return [lat.DeviceProfile(flops_per_sec=float(rng.uniform(*flops_range)))
            for _ in range(n)]


def build_sim_arrays(cfg: HSFLConfig, pad_len: int | None = None) -> Dict:
    """Per-simulation constant arrays for the on-device engine (core/sweep).

    Drawn with exactly the host simulation's seeding — data/partition from
    ``cfg.seed``, device FLOPS from ``default_rng(cfg.seed)`` in the same
    draw order as ``HSFLSimulation.__init__`` — so a device run and a host
    run with the same config see the same datasets, compute profiles and
    initial params (only the *channel/batch RNG streams* differ; see
    EXPERIMENTS.md).  Client datasets are padded to a common length
    (``pad_len`` lets a sweep pad all sims identically so they stack).
    """
    rng = np.random.default_rng(cfg.seed)
    full = make_digits(cfg.n_train + cfg.n_test, seed=cfg.seed)
    test = Dataset(full.x[cfg.n_train:], full.y[cfg.n_train:])
    train = Dataset(full.x[:cfg.n_train], full.y[:cfg.n_train])
    clients = partition(train, cfg.n_uavs, cfg.distribution, cfg.seed)
    devices = _heterogeneous_devices(cfg.n_uavs, rng, cfg.flops_range)

    m = pad_len or max(len(c) for c in clients)
    xshape = clients[0].x.shape[1:]
    client_x = np.zeros((cfg.n_uavs, m) + xshape, np.float32)
    client_y = np.zeros((cfg.n_uavs, m), clients[0].y.dtype)
    client_len = np.zeros((cfg.n_uavs,), np.int32)
    for i, c in enumerate(clients):
        k = min(len(c), m)
        client_x[i, :k] = c.x[:k]
        client_y[i, :k] = c.y[:k]
        client_len[i] = k
    return {
        "client_x": client_x,
        "client_y": client_y,
        "client_len": client_len,
        "flops": np.array([d.flops_per_sec for d in devices], np.float32),
        "samples": np.array([len(c) for c in clients], np.float32),
        "test_x": test.x.astype(np.float32),
        "test_y": test.y,
    }


def _epoch_indices(n: int, cfg: HSFLConfig, rng: np.random.Generator) -> np.ndarray:
    """Fixed-shape (steps, bs) batch indices for one local epoch."""
    need = cfg.steps_per_epoch * cfg.batch_size
    idx = rng.permutation(n)
    while len(idx) < need:
        idx = np.concatenate([idx, rng.permutation(n)])
    return idx[:need].reshape(cfg.steps_per_epoch, cfg.batch_size)


def _sample_epoch(ds: Dataset, cfg: HSFLConfig, rng: np.random.Generator):
    """Fixed-shape epoch batches (steps, bs, ...) — one jit compile total."""
    idx = _epoch_indices(len(ds), cfg, rng)
    return jnp.asarray(ds.x[idx]), jnp.asarray(ds.y[idx])


def _k_bucket(n_sched: int, k_select: int) -> int:
    """Pad K to a small even bucket so the vmapped round compiles O(1) times."""
    return min(k_select, 2 * ((n_sched + 1) // 2))


class HSFLSimulation:
    """Control plane composing jitted local training (fused or host loop)."""

    def __init__(self, cfg: HSFLConfig):
        self.cfg = cfg
        # the registered transmission policy: probe schedule, selection,
        # final deadline and aggregation all dispatch through it
        self.scheme = get_scheme(cfg.scheme)
        self.rng = np.random.default_rng(cfg.seed)
        full = make_digits(cfg.n_train + cfg.n_test, seed=cfg.seed)
        self.test = Dataset(full.x[cfg.n_train:], full.y[cfg.n_train:])
        train = Dataset(full.x[:cfg.n_train], full.y[:cfg.n_train])
        self.clients = partition(train, cfg.n_uavs, cfg.distribution, cfg.seed)
        self.fleet = UAVFleet(cfg.n_uavs, cfg.channel, seed=cfg.seed + 1)
        self.devices = _heterogeneous_devices(cfg.n_uavs, self.rng,
                                              cfg.flops_range)
        self.workloads = [
            lat.WorkloadProfile(local_epochs=cfg.local_epochs,
                                samples=len(c)) for c in self.clients]
        self.params = cnn_mod.init_cnn(jax.random.PRNGKey(cfg.seed))
        self._test_x = jnp.asarray(self.test.x)
        self._test_y = jnp.asarray(self.test.y)
        # Pallas kernels run in interpret mode off-TPU
        self._interpret = jax.default_backend() != "tpu"
        # the codec makes the compress knob real: actual int8+scale bytes
        # over float32 bytes for this model, not a hand-set scalar
        self.compress_ratio = model_compress_ratio(cfg)
        self._probe_epochs = self._static_schedule()
        self._stack_shard = self._batch_shard = None
        self._shard_ndev = 1
        devs = jax.devices()
        if cfg.use_fused_round and len(devs) > 1 and \
                cfg.k_select % len(devs) == 0:
            from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
            mesh = Mesh(np.array(devs), ("users",))
            self._stack_shard = NamedSharding(mesh, P("users"))
            self._batch_shard = NamedSharding(mesh, P(None, "users"))
            self._shard_ndev = len(devs)
        self._build_jits()

    def _static_schedule(self) -> tuple:
        """The probe schedule is static per config (Alg. 2 line 12 or the
        Sec. III-B manual override) — the scheme's decision."""
        cfg = self.cfg
        return self.scheme.static_schedule(cfg.local_epochs, cfg.b,
                                           cfg.schedule_override)

    # -- jitted kernels ----------------------------------------------------
    def _build_jits(self):
        cfg = self.cfg
        lr = cfg.lr

        def epoch_fn(params, xs, ys):
            def step(p, batch):
                bx, by = batch

                def loss(q):
                    logits = cnn_mod.forward(q, bx)
                    return cross_entropy(logits, by)

                g = jax.grad(loss)(p)
                p = jax.tree_util.tree_map(lambda w, gg: w - lr * gg, p, g)
                return p, ()

            params, _ = jax.lax.scan(step, params, (xs, ys))
            return params

        def eval_fn(params, x, y):
            logits = cnn_mod.forward(params, x)
            return cross_entropy(logits, y), accuracy(logits, y)

        # host path: all selected users advance one epoch at once (K, ...);
        # params are re-read host-side between epochs, so no donation
        self._epoch_all = jax.jit(jax.vmap(epoch_fn))  # analysis: ok=jit-donate
        self._eval = jax.jit(eval_fn)  # analysis: ok=jit-donate
        from repro.kernels.fused_cnn.ops import ForwardPolicy
        self._fused = build_fused_round(
            scheme=self.scheme, local_epochs=cfg.local_epochs,
            steps_per_epoch=cfg.steps_per_epoch, lr=lr, tau_max=cfg.tau_max,
            probe_epochs=self._probe_epochs,
            async_weight=cfg.async_alpha * 2.0 ** (-cfg.async_a),
            use_codec=cfg.use_delta_codec, interpret=self._interpret,
            k_carry=cfg.k_select, codec_block=cfg.codec_block,
            codec_bits=cfg.codec_bits,
            forward=ForwardPolicy(kernel=cfg.kernel,
                                  precision=cfg.precision,
                                  block_k=cfg.block_k,
                                  batch_users=cfg.batch_users).validate(),
            stacked_sharding=self._stack_shard)

    def evaluate(self) -> Tuple[float, float]:
        l, a = self._eval(self.params, self._test_x, self._test_y)
        return float(l), float(a)

    # -- shared per-round control plane -------------------------------------
    def _schedule_round(self):
        cfg = self.cfg
        self.fleet.resample_fading()           # per local-round K (Sec. IV)
        rates0 = self.fleet.rates()
        ue_bytes = cfg.model_bytes * cfg.ue_model_fraction
        # selection budgets the *effective* wire bytes: with the delta
        # codec on, the greedy's eq. 9-13 latency/energy (incl. the final
        # upload) must see the compressed payload — byte parity with the
        # device engine's eff_model_bytes (it used to budget the
        # uncompressed model and under-select)
        sched = self.scheme.selection_policy_host(
            rates0, self.devices, self.workloads,
            cfg.model_bytes * self.compress_ratio,
            ue_bytes * self.compress_ratio, cfg.b, cfg.tau_max, cfg.k_select)
        return sched, ue_bytes

    def run_round(self, t: int, carry_delayed) -> Tuple[RoundLog, object]:
        if self.cfg.use_fused_round:
            return self._run_round_fused(t, carry_delayed)
        return self._run_round_host(t, carry_delayed)

    # -- fused engine --------------------------------------------------------
    def _presample_round(self, sched, K: int):
        """Draw the whole round's channel + batches host-side, consuming the
        fleet/simulation RNG streams in exactly the host-loop order (one
        equivalence contract, tested)."""
        cfg = self.cfg
        e, steps, bs = cfg.local_epochs, cfg.steps_per_epoch, cfg.batch_size
        n_s = len(sched)
        sel = np.array([u.index for u in sched])
        xshape = self.clients[0].x.shape[1:]
        xs = np.zeros((e, K, steps, bs) + xshape, np.float32)
        ys = np.zeros((e, K, steps, bs), self.clients[0].y.dtype)
        rates = np.zeros((e, K), np.float32)
        outs = np.zeros((e, K), bool)
        for e_i in range(e):
            self.fleet.move()                  # path loss varies per epoch
            r = self.fleet.rates()
            o = self.fleet.outages()
            rates[e_i, :n_s] = r[sel]
            outs[e_i, :n_s] = o[sel]
            for j, u in enumerate(sched):
                ds = self.clients[u.index]
                idx = _epoch_indices(len(ds), cfg, self.rng)
                xs[e_i, j] = ds.x[idx]
                ys[e_i, j] = ds.y[idx]
        fr = self.fleet.rates()                # final upload: no extra move
        fo = self.fleet.outages()
        final_rate = np.zeros(K, np.float32)
        final_out = np.zeros(K, bool)
        final_rate[:n_s] = fr[sel]
        final_out[:n_s] = fo[sel]
        return xs, ys, rates, outs, final_rate, final_out

    def _user_consts(self, sched, ue_bytes: float, K: int):
        cfg = self.cfg
        n_s = len(sched)
        payload = np.full(K, cfg.model_bytes, np.float64)
        train_time = np.full(K, 1e9, np.float64)
        for j, u in enumerate(sched):
            payload[j] = cfg.model_bytes if u.mode == "FL" else ue_bytes
            train_time[j] = (
                lat.train_time_fl(self.devices[u.index], self.workloads[u.index])
                if u.mode == "FL" else
                lat.train_time_sl(self.devices[u.index], self.workloads[u.index]))
        payload *= self.compress_ratio
        rate0 = np.array([u.rate0_bps for u in sched] + [1.0] * (K - n_s))
        tau_extra0 = (cfg.b - 1) * payload * 8.0 / np.maximum(rate0, 1e-9)
        valid = np.arange(K) < n_s
        return payload, tau_extra0, train_time, valid

    def _empty_carry(self):
        # built fresh every time: the fused round *donates* the straggler
        # carry buffers, so a cached zero stack would be consumed by its
        # first use
        k = self.cfg.k_select
        stack = jax.tree_util.tree_map(
            lambda a: jax.device_put(np.zeros((k,) + a.shape, a.dtype)),
            self.params)
        return (stack, jax.device_put(np.zeros((k,), bool)))

    def _run_round_fused(self, t: int, carry_delayed):
        cfg = self.cfg
        sched, ue_bytes = self._schedule_round()
        log = RoundLog(round=t, selected=len(sched))
        if isinstance(carry_delayed, (list, tuple)) and not carry_delayed:
            carry_delayed = None

        if not sched:
            # nothing selected: stragglers (async) still merge on the server
            if self.scheme.carries_delayed and carry_delayed is not None:
                stack, mask = carry_delayed
                delayed = [(jax.tree_util.tree_map(lambda a: a[i], stack), 1)
                           for i in range(mask.shape[0]) if bool(mask[i])]
                self.params = self.scheme.aggregate_host(
                    [], delayed, self.params, cfg.async_alpha, cfg.async_a)
            return log, None

        K = _k_bucket(len(sched), cfg.k_select)
        if self._shard_ndev > 1:
            # sharded user axis must stay divisible by the device count
            # (k_select is — the __init__ guard — so this stays ≤ k_select)
            K = -(-K // self._shard_ndev) * self._shard_ndev
        xs, ys, rates, outs, final_rate, final_out = \
            self._presample_round(sched, K)
        payload, tau_extra0, train_time, valid = \
            self._user_consts(sched, ue_bytes, K)

        # dtype conversions happen host-side; a single explicit device_put
        # per input stages the round, so the loop runs clean under
        # jax.transfer_guard_host_to_device("disallow")
        xs = jax.device_put(xs, self._batch_shard)
        ys = jax.device_put(ys, self._batch_shard)
        chan = jax.device_put({
            "rates": np.asarray(rates), "outages": np.asarray(outs),
            "payload_bits": np.asarray(payload * 8.0, np.float32),
            "tau_extra0": np.asarray(tau_extra0, np.float32),
            "final_rate": np.asarray(final_rate),
            "final_outage": np.asarray(final_out),
            "train_time": np.asarray(train_time, np.float32),
            "valid": np.asarray(valid),
        })

        if self.scheme.carries_delayed:
            stack, mask = (carry_delayed if carry_delayed is not None
                           else self._empty_carry())
            self.params, c_stack, c_mask, stats = self._fused(
                self.params, stack, mask, xs, ys, chan)
            new_carry = (c_stack, c_mask)
        else:
            self.params, stats = self._fused(self.params, xs, ys, chan)
            new_carry = None

        arrived = np.asarray(stats.arrived)
        rescued = np.asarray(stats.rescued)
        delayed = np.asarray(stats.delayed)
        dropped = np.asarray(stats.dropped)
        sends = np.asarray(stats.opp_sends)
        log.arrived_final = int(arrived.sum())
        log.used_snapshot = int(rescued.sum())
        log.delayed = int(delayed.sum())
        log.dropped = int(dropped.sum())
        events = sends + arrived.astype(np.int64)
        log.bytes_sent = float(np.sum(payload * events))
        for j, u in enumerate(sched):
            if u.mode == "SL" and events[j] > 0:
                # one-off activation payload m_a rides the SL uplink (eq. 12)
                wl = self.workloads[u.index]
                log.bytes_sent += wl.act_bytes_per_sample * wl.samples
        return log, new_carry

    # -- host reference engine ----------------------------------------------
    def _run_round_host(self, t: int, carry_delayed) -> Tuple[RoundLog, List[tuple]]:
        cfg = self.cfg
        carry_delayed = list(carry_delayed or [])
        sched, ue_bytes = self._schedule_round()

        log = RoundLog(round=t, selected=len(sched))
        if not sched:
            self.params = self.scheme.aggregate_host(
                [], carry_delayed, self.params, cfg.async_alpha, cfg.async_a)
            return log, []
        txs: Dict[int, OppTransmitter] = {}
        for u in sched:
            payload = cfg.model_bytes if u.mode == "FL" else ue_bytes
            txs[u.index] = OppTransmitter(
                payload, cfg.local_epochs, cfg.b, u.rate0_bps,
                compress_ratio=self.compress_ratio,
                schedule_override=cfg.schedule_override)

        # stacked per-user params (K, ...): everyone starts from the global.
        # Pad K to a small bucket so the vmapped epoch compiles O(1) times.
        K = _k_bucket(len(sched), cfg.k_select)
        stacked = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (K,) + a.shape), self.params)

        def user_tree(i: int):
            return jax.tree_util.tree_map(lambda a: a[i], stacked)

        def snapshot_of(i: int):
            if not cfg.use_delta_codec:
                return user_tree(i)
            # quantize-dequantize round trip: the server only ever holds the
            # int8 delta payload, so the stored snapshot carries codec noise
            payload = encode_delta(user_tree(i), self.params,
                                   interpret=self._interpret,
                                   block=cfg.codec_block,
                                   bits=cfg.codec_bits)
            return decode_delta(payload, self.params,
                                interpret=self._interpret)

        # local training: epochs advance in lockstep; channel drifts per epoch
        for e_t in range(1, cfg.local_epochs + 1):
            self.fleet.move()                  # path loss varies per epoch
            rates = self.fleet.rates()
            outages = self.fleet.outages()
            eb = [_sample_epoch(self.clients[u.index], cfg, self.rng)
                  for u in sched]
            while len(eb) < K:                 # pad unused slots (ignored)
                eb.append(eb[0])
            xs = jnp.stack([b[0] for b in eb])
            ys = jnp.stack([b[1] for b in eb])
            stacked = self._epoch_all(stacked, xs, ys)
            if self._probe_epochs:
                for i, u in enumerate(sched):
                    if e_t in txs[u.index].schedule:
                        txs[u.index].maybe_transmit(
                            e_t, float(rates[u.index]),
                            bool(outages[u.index]),
                            lambda i=i: snapshot_of(i))

        # final uploads
        arrived: List[object] = []
        new_delayed: List[tuple] = []
        rates = self.fleet.rates()
        outages = self.fleet.outages()
        for i, u in enumerate(sched):
            tx = txs[u.index]
            dev, wl = self.devices[u.index], self.workloads[u.index]
            tr_time = (lat.train_time_fl(dev, wl) if u.mode == "FL"
                       else lat.train_time_sl(dev, wl))
            # the scheme's deadline: extra seconds charged against τ_max
            # (0 for the paper schemes; eq. 14 allowance for 'deadline',
            # −inf — the server waits — for 'sync')
            slack = float(self.scheme.final_slack(tx.tau_extra0))
            ok = tx.final_upload(float(rates[u.index]), bool(outages[u.index]),
                                 tr_time + slack, cfg.tau_max)
            if ok:
                arrived.append(user_tree(i))
                log.arrived_final += 1
            elif self.scheme.uses_probes and tx.snapshot is not None:
                arrived.append(tx.snapshot)     # the paper's rescue
                log.used_snapshot += 1
            elif self.scheme.carries_delayed:
                new_delayed.append((user_tree(i), 1))      # max delay 1
                log.delayed += 1
            else:
                log.dropped += 1
            log.bytes_sent += tx.bytes_sent
            if u.mode == "SL" and tx.events:
                # one-off activation payload m_a rides the SL uplink (eq. 12)
                log.bytes_sent += self.workloads[u.index].act_bytes_per_sample \
                    * self.workloads[u.index].samples

        self.params = self.scheme.aggregate_host(
            arrived, carry_delayed, self.params,
            cfg.async_alpha, cfg.async_a)
        return log, new_delayed

    # -- full simulation -----------------------------------------------------
    def run(self, eval_every: int = 1, verbose: bool = False) -> SimLog:
        sim = SimLog()
        delayed: object = []
        for t in range(1, self.cfg.rounds + 1):
            log, delayed = self.run_round(t, delayed)
            if t % eval_every == 0 or t == self.cfg.rounds:
                log.test_loss, log.test_acc = self.evaluate()
            sim.add(log)
            if verbose and (t % 10 == 0 or t == 1):
                print(f"[{self.cfg.scheme}/{self.cfg.distribution} b={self.cfg.b}] "
                      f"round {t}: acc={log.test_acc:.4f} loss={log.test_loss:.4f} "
                      f"rescued={log.used_snapshot} dropped={log.dropped}")
        return sim


def run_hsfl(cfg: HSFLConfig, verbose: bool = False) -> SimLog:
    """Deprecated entry point — use ``repro.api.Experiment`` instead::

        Experiment(cfg).run(engine="fused")   # or engine="loop" with
                                              # cfg.use_fused_round=False

    Kept as a thin shim (seeded-equivalent: the facade constructs the same
    ``HSFLSimulation``)."""
    import warnings
    warnings.warn("run_hsfl is deprecated; use repro.api.Experiment(cfg)"
                  ".run(engine='fused'|'loop')", DeprecationWarning,
                  stacklevel=2)
    return HSFLSimulation(cfg).run(verbose=verbose)
