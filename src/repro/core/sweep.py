"""Vectorized sweep engine — an entire Fig. 3 panel as one device program.

The loop engine (``run_hsfl``) simulates one (scheme, seed, config) cell at
a time: per round it presamples the channel host-side, dispatches one fused
device program, and syncs stats back — so a figure grid is a Python loop of
hundreds of host↔device round trips.  This module compiles the whole grid:

  - **rounds** chain under ``lax.scan`` over ``fused_round.build_device_round``
    (channel/mobility/outages realized on-device from a
    ``channel_lib.FleetState`` carry; greedy selection via
    ``selection.select_users_jax``; batches gathered on-device);
  - **configs** (b, τ_max, bandwidth_ratio — anything the round takes as a
    traced scalar) ride an inner ``vmap``;
  - **sims** (seed × distribution, i.e. everything that changes the *data*)
    ride an outer ``vmap``, and that axis is sharded over a 1-D
    ``("sweep",)`` mesh (``launch.mesh.make_sweep_mesh`` +
    ``sharding.rules.shard_sweep_tree``) — simulations are independent, so
    the mesh scales them with zero collectives;
  - **schemes** (and any other static field, e.g. the ``use_delta_codec``
    group static) group into separate compiles of the same program skeleton
    via the ``SweepSpec`` compiler below — except that a b=1 discard group
    is *lowered onto the OPT program* (discard is exactly opt with zero
    probes), so a Fig. 3(b) panel compiles 2 programs instead of 3.

RNG: device runs draw channel/mobility/batch streams from ``jax.random``
(per-sim keys derived from the seed), NOT the host ``np.random`` streams —
a sweep is seeded and reproducible, but not bit-identical to the host
reference engine.  Datasets, partitions, device FLOPS profiles and initial
params ARE identical to the host runs (``hsfl.build_sim_arrays``).
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Tuple

import numpy as np

from repro.core.hsfl import HSFLConfig, build_sim_arrays
from repro.core.metrics import RoundLog, SimLog
from repro.core.schemes import get_scheme

# Fields of HSFLConfig a sweep may vary *per traced config axis* (the inner
# vmap).  Everything else that varies must be a sim axis (data-level: seed,
# distribution) or a group axis (static: scheme, local_epochs, ...).
CFG_AXES = ("b", "tau_max", "bandwidth_ratio")

# HSFLConfig fields a scheme entry may pin as *group statics*: they fork a
# separate compile of the round program instead of riding a traced axis.
# The scheme *identity itself* is the primary group static (each registered
# ``schemes.Scheme`` forks its own program compile).  ``use_delta_codec``
# is the flagship field pin — codec × scheme × budget grids are
# first-class sweeps (``("opt", {"b": 2.0, "use_delta_codec": True})``).
# ``codec_block``/``codec_bits`` sweep the quantization group width and bit
# depth (the eq. 15 overhead-vs-delay frontier), and ``kernel``/
# ``precision``/``block_k``/``batch_users`` fork the CNN hot-path policy
# (kernels/fused_cnn): xla-vs-pallas, f32-vs-bf16, blocked-vs-vmapped and
# user-tile-size groups can sit side by side in one spec.
GROUP_STATICS = ("use_delta_codec", "codec_block", "codec_bits", "kernel",
                 "precision", "block_k", "batch_users")

# Poison value ``compile_spec`` writes into ``group.base.b`` when b rides
# the traced config axis: the real values live in ``group.cfgs`` and
# nothing static may read ``base.b`` (the old behaviour silently pinned it
# to the first config column).
B_SWEPT = -1


@dataclass(frozen=True)
class SweepSpec:
    """A declarative experiment grid (one Fig. 3 panel, typically).

    ``schemes`` entries are registered scheme names (``"opt"``), ``Scheme``
    objects carrying their pins (``get_scheme("opt").with_pins(b=2.0)``),
    or the legacy ``("opt", {"b": 2})`` tuple form — the pins fix
    traced-axis values for that scheme group (Fig. 3(b) compares OPT at
    b=2 against async/discard at b=1).  ``b``/``tau_max``/
    ``bandwidth_ratio`` are swept as a product on the traced config axis;
    ``seeds`` × ``distributions`` form the (sharded) simulation axis.
    """
    base: HSFLConfig = field(default_factory=HSFLConfig)
    seeds: Tuple[int, ...] = (0,)
    schemes: Tuple = ()                  # () -> (base.scheme,)
    distributions: Tuple[str, ...] = ()  # () -> (base.distribution,)
    b: Tuple[float, ...] = ()            # () -> (base.b,)
    tau_max: Tuple[float, ...] = ()      # () -> (base.tau_max,)
    bandwidth_ratio: Tuple[float, ...] = ()   # () -> (1.0,)


@dataclass(frozen=True)
class CompiledGroup:
    """One result slice of a SweepSpec: fixed statics, stacked axes.

    ``program_scheme`` is the scheme whose round program actually executes
    this group — normally ``scheme``, but a discard group pinned at b=1
    lowers onto the OPT program (discard IS opt with zero probes: at b=1
    the probe schedule is empty and the eq. 14 allowance is 0, so no
    snapshot ever exists and the rescue weights vanish identically), which
    lets a Fig. 3(b)-style panel share one compile between opt and discard.
    ``label`` distinguishes groups whose scheme coincides (codec forks)."""
    scheme: str
    base: HSFLConfig                      # statics for this group
    sims: Tuple[Tuple[int, str], ...]     # (seed, distribution) per sim row
    cfgs: Tuple[Dict[str, float], ...]    # traced scalars per config column
    label: str = ""
    program_scheme: str = ""


def compile_spec(spec: SweepSpec,
                 lower_discard: bool = True) -> List[CompiledGroup]:
    """SweepSpec -> stacked-config groups.

    Schemes become groups (static control flow differs); seeds ×
    distributions become the sim rows; the b/τ_max/bandwidth_ratio product
    becomes the traced config columns, with per-scheme pins applied.  Pins
    of ``GROUP_STATICS`` fields fork the group's static config instead
    (codec on/off groups in one spec).  ``lower_discard`` reroutes b=1
    discard groups onto the OPT program so they share its compile
    (``lower_discard=False`` keeps the dedicated discard program — the
    bit-for-bit reference ``tests/test_sweep.py`` compares against).

    ``base.b`` is pinned only when the group's config axis holds a single
    b; when b is genuinely swept it is poisoned to ``B_SWEPT`` (nothing
    static may follow one column — the old code silently pinned the first),
    and a static ``schedule_override`` is rejected outright: the manual
    probe schedule is compiled per group while its budget semantics would
    vary along the traced axis.
    """
    schemes = spec.schemes or (spec.base.scheme,)
    dists = spec.distributions or (spec.base.distribution,)
    sims = tuple(itertools.product(spec.seeds, dists))
    groups = []
    for entry in schemes:
        # entry forms: "opt" | Scheme (pins on the object) | ("opt", {...})
        # — get_scheme raises listing every registered name on an unknown
        # string, BEFORE any engine code runs
        if isinstance(entry, tuple):
            name, tuple_pins = entry
            scheme_obj = get_scheme(name).with_pins(**tuple_pins)
        else:
            scheme_obj = get_scheme(entry)
        scheme, pins = scheme_obj.name, dict(scheme_obj.pins)
        axes = {
            "b": spec.b or (spec.base.b,),
            "tau_max": spec.tau_max or (spec.base.tau_max,),
            "bandwidth_ratio": spec.bandwidth_ratio or (1.0,),
        }
        statics = {}
        for k, v in pins.items():         # pins win, even over swept axes
            if k in GROUP_STATICS:
                statics[k] = v
            elif k in CFG_AXES:
                axes[k] = (v,)
            else:
                raise ValueError(f"scheme pin {k!r} is neither a traced "
                                 f"axis {CFG_AXES} nor a group static "
                                 f"{GROUP_STATICS}")
        cfgs = tuple({"b": float(b), "tau_max": float(t),
                      "bandwidth_ratio": float(w)}
                     for b, t, w in itertools.product(*axes.values()))
        base = replace(spec.base, scheme=scheme, **statics)
        b_vals = sorted({c["b"] for c in cfgs})
        if len(b_vals) == 1:
            base = replace(base, b=int(max(1, round(b_vals[0]))))
        else:
            if spec.base.schedule_override:
                raise ValueError(
                    "schedule_override is a static of the compiled round "
                    "program, but b is swept on the traced config axis "
                    f"({b_vals}); pin b per scheme or drop the override")
            base = replace(base, b=B_SWEPT)
        # program identity is the scheme's own decision: a scheme may lower
        # its group onto another scheme's compile where the two provably
        # coincide (discard @ b=1 IS opt with zero probes)
        program = (scheme_obj.lowered_program(tuple(b_vals))
                   if lower_discard else scheme)
        groups.append(CompiledGroup(
            scheme=scheme, base=base, sims=sims, cfgs=cfgs,
            label=scheme + ("+codec" if base.use_delta_codec else ""),
            program_scheme=program))
    return groups


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

def _stack_sims(group: CompiledGroup) -> Dict[str, np.ndarray]:
    """Build + stack per-sim constant arrays, padded to a common length."""
    per_sim = []
    for seed, dist in group.sims:
        cfg = replace(group.base, seed=seed, distribution=dist)
        per_sim.append(build_sim_arrays(cfg))
    m = max(a["client_x"].shape[1] for a in per_sim)
    for a in per_sim:
        pad = m - a["client_x"].shape[1]
        if pad:
            a["client_x"] = np.pad(
                a["client_x"],
                ((0, 0), (0, pad)) + ((0, 0),) * (a["client_x"].ndim - 2))
            a["client_y"] = np.pad(a["client_y"], ((0, 0), (0, pad)))
    return {k: np.stack([a[k] for a in per_sim]) for k in per_sim[0]}


def _group_build_kwargs(group: CompiledGroup) -> Dict[str, Any]:
    """The static kwargs ``build_device_round`` gets for this group.

    Single source of truth for BOTH the program build (``_build_group_fn``)
    and the program-cache identity (``_program_key``): a static added here
    automatically invalidates cache sharing, so the two cannot drift.
    Deliberately NOT ``base.scheme``/``base.b`` — the program runs
    ``program_scheme`` and b is traced, which is exactly what lets a
    b=1-pinned discard group hash onto the opt program.
    """
    import jax

    from repro.core.hsfl import model_compress_ratio
    from repro.kernels.fused_cnn.ops import ForwardPolicy

    base = group.base
    return dict(
        scheme=group.program_scheme or group.scheme,
        local_epochs=base.local_epochs,
        steps_per_epoch=base.steps_per_epoch, batch_size=base.batch_size,
        lr=base.lr, k_select=base.k_select, channel=base.channel,
        model_bytes=base.model_bytes,
        ue_model_fraction=base.ue_model_fraction,
        compress_ratio=model_compress_ratio(base),
        use_codec=base.use_delta_codec, codec_block=base.codec_block,
        codec_bits=base.codec_bits,
        # Pallas kernels (codec + fused CNN) run in interpret mode off-TPU
        interpret=jax.default_backend() != "tpu",
        forward=ForwardPolicy(kernel=base.kernel,
                              precision=base.precision,
                              block_k=base.block_k,
                              batch_users=base.batch_users).validate(),
        schedule_override=tuple(base.schedule_override),
        async_alpha=base.async_alpha, async_a=base.async_a)


def _program_key(group: CompiledGroup) -> Tuple:
    """Hashable identity of the compiled program a group needs."""
    kw = _group_build_kwargs(group)
    kw["channel"] = repr(kw["channel"])       # mutable dataclass -> repr
    return tuple(sorted(kw.items()))


def _build_group_fn(group: CompiledGroup):
    """jit(vmap_sims(vmap_cfgs(scan_rounds(device_round)))).

    The simulation carry enters with the config axis already materialized
    (leaves ``(S, C, ...)``, see ``_group_inputs``) and the final carry is
    returned next to the metrics — that is what makes ``donate_argnums``
    real: the whole round state (params stack, FleetState, async straggler
    stack, codec state) aliases its output instead of being copied at the
    dispatch boundary, and the scan keeps it in-place between rounds."""
    import jax

    from repro.core.fused_round import build_device_round

    round_fn = build_device_round(**_group_build_kwargs(group))

    def sim_one(carry0, round_keys, sim, cfgv):
        def body(c, k):
            return round_fn(c, k, sim, cfgv)

        carry, metrics = jax.lax.scan(body, carry0, round_keys)
        return carry, metrics                 # (rounds,) per metric field

    over_cfg = jax.vmap(sim_one, in_axes=(0, None, None, 0))
    over_sim = jax.vmap(over_cfg, in_axes=(0, 0, 0, None))
    return jax.jit(over_sim, donate_argnums=(0,))


def _group_inputs(group: CompiledGroup, rounds: int,
                  data: Dict[str, Any] | None = None):
    import jax
    import jax.numpy as jnp

    from repro.core.channel_lib import fleet_init
    from repro.core.fused_round import DeviceSimCarry
    from repro.models import cnn as cnn_mod

    base = group.base
    # this function IS the host->device staging boundary of the sweep
    # engine (seeds, init params, sim constants), so transfers are
    # explicitly opted in here; everything after it — the scanned round
    # programs — runs clean under transfer_guard_host_to_device("disallow")
    with jax.transfer_guard_host_to_device("allow"):
        if data is None:
            data = {k: jax.device_put(np.asarray(v))
                    for k, v in _stack_sims(group).items()}

        params0, fleets, rkeys = [], [], []
        for seed, _ in group.sims:
            params0.append(cnn_mod.init_cnn(jax.random.PRNGKey(seed)))
            fleets.append(jax.random.PRNGKey(seed + 1))
            rkeys.append(jax.random.split(
                jax.random.fold_in(jax.random.PRNGKey(seed), 2), rounds))
        params0 = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *params0)
        fleet0 = jax.vmap(
            lambda k: fleet_init(k, base.n_uavs, base.channel))(
                jnp.stack(fleets))
        round_keys = jnp.stack(rkeys)             # (S, rounds, key)

        k = base.k_select
        zstack = jax.tree_util.tree_map(
            lambda a: jnp.zeros((a.shape[0], k) + a.shape[1:], a.dtype),
            params0)
        carry0 = DeviceSimCarry(
            params=params0, fleet=fleet0, delayed=zstack,
            delayed_mask=jnp.zeros((len(group.sims), k), bool))
        # materialize the config axis on the carry (every config evolves its
        # own state anyway) so the jit can donate it: leaves become
        # (S, C, ...)
        c = len(group.cfgs)
        carry0 = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[:, None], a.shape[:1] + (c,)
                                       + a.shape[1:]), carry0)
        cfg_stack = {key: jax.device_put(
                         np.asarray([cf[key] for cf in group.cfgs],
                                    np.float32))
                     for key in CFG_AXES}
    return carry0, round_keys, data, cfg_stack


@dataclass
class GroupResult:
    scheme: str
    sims: Tuple[Tuple[int, str], ...]
    cfgs: Tuple[Dict[str, float], ...]
    metrics: Dict[str, np.ndarray]        # each (S, C, rounds)
    compile_s: float = 0.0
    run_s: float = 0.0
    label: str = ""                       # scheme (+ "+codec")
    program_id: int = 0                   # groups sharing an id share a jit

    def sim_log(self, sim_i: int, cfg_i: int) -> SimLog:
        """Rebuild the loop engine's SimLog for one (sim, config) cell."""
        log = SimLog()
        m = self.metrics
        for t in range(m["test_acc"].shape[-1]):
            log.add(RoundLog(
                round=t + 1,
                selected=int(m["selected"][sim_i, cfg_i, t]),
                arrived_final=int(m["arrived"][sim_i, cfg_i, t]),
                used_snapshot=int(m["rescued"][sim_i, cfg_i, t]),
                dropped=int(m["dropped"][sim_i, cfg_i, t]),
                delayed=int(m["delayed"][sim_i, cfg_i, t]),
                bytes_sent=float(m["bytes_sent"][sim_i, cfg_i, t]),
                test_loss=float(m["test_loss"][sim_i, cfg_i, t]),
                test_acc=float(m["test_acc"][sim_i, cfg_i, t])))
        return log


@dataclass
class SweepResult:
    groups: List[GroupResult]
    rounds: int
    wall_s: float = 0.0
    n_programs: int = 0                   # distinct jitted round programs
    compile_overlap_s: float = 0.0        # compile time hidden behind runs

    @property
    def n_simulations(self) -> int:
        return sum(len(g.sims) * len(g.cfgs) for g in self.groups)


def _run_sweep(spec: SweepSpec, mesh: Any = "auto", verbose: bool = False,
               timeit: bool = False, lower_discard: bool = True,
               overlap_compile: bool = True) -> SweepResult:
    """Execute a SweepSpec: one compiled program per *distinct* group
    program.  Groups are keyed by ``_program_key`` — a b=1 discard group
    reuses the opt program's jitted fn (``lower_discard``; discard is
    exactly opt with zero probes), so a Fig. 3(b)-style panel compiles 2
    programs instead of 3; ``SweepResult.n_programs`` records the count.

    Programs are AOT-compiled (``lower().compile()``), and with
    ``overlap_compile`` the *next* group's compile runs on a background
    thread while the current group executes (XLA releases the GIL), so a
    multi-scheme panel pays at most one compile on the critical path;
    ``SweepResult.compile_overlap_s`` records how much compile time was
    hidden behind execution.  Each group's ``DeviceSimCarry`` is donated
    to its program (see ``_build_group_fn``).

    ``mesh="auto"`` builds a ``("sweep",)`` mesh over all local devices when
    there is more than one and shards the stacked-simulation axis over it
    (inputs placed via ``sharding.rules.shard_sweep_tree``; XLA propagates
    the sharding through scan/vmap).  Pass ``mesh=None`` to force
    single-device, or an explicit 1-D ``("sweep",)`` mesh.

    ``timeit=True`` executes each group twice (rebuilding the donated
    carry) so ``run_s`` is the steady-state figure the benchmarks record;
    compiles are AOT either way, so ``compile_s`` is always the true
    compile duration rather than a first-minus-second-run residual.
    """
    import threading

    import jax
    import jax.numpy as jnp

    from repro.sharding.rules import shard_sweep_specs, shard_sweep_tree

    if mesh == "auto":
        if len(jax.devices()) > 1:
            from repro.launch.mesh import make_sweep_mesh
            mesh = make_sweep_mesh()
        else:
            mesh = None

    rounds = spec.base.rounds
    t_all = time.time()
    programs: Dict[Tuple, Tuple[Any, int]] = {}
    # nothing a scheme entry can pin (CFG_AXES / GROUP_STATICS) changes the
    # *data*, so the stacked per-sim arrays are built once per sim-row set
    # and shared across groups instead of re-synthesized per scheme
    sims_data: Dict[Tuple, Any] = {}

    def build_inputs(group):
        carry0, round_keys, data, cfg_stack = _group_inputs(
            group, rounds, sims_data[group.sims])
        n_sims = len(group.sims)
        carry0 = shard_sweep_tree(mesh, carry0, n_sims)
        round_keys = shard_sweep_tree(mesh, round_keys, n_sims)
        data = shard_sweep_tree(mesh, data, n_sims)
        return carry0, round_keys, data, cfg_stack

    def input_specs(group):
        """Avals (+ the shardings ``build_inputs`` would apply) without
        materializing the carry: programs lower/compile from these, so a
        background compile never holds a second group's device state —
        only the group being *executed* has its inputs live."""
        carry0, round_keys, data, cfg_stack = jax.eval_shape(
            lambda: _group_inputs(group, rounds, sims_data[group.sims]))
        n_sims = len(group.sims)
        carry0 = shard_sweep_specs(mesh, carry0, n_sims)
        round_keys = shard_sweep_specs(mesh, round_keys, n_sims)
        data = shard_sweep_specs(mesh, data, n_sims)
        return carry0, round_keys, data, cfg_stack

    entries = []
    for group in compile_spec(spec, lower_discard=lower_discard):
        key = _program_key(group)
        if key not in programs:
            programs[key] = (_build_group_fn(group), len(programs))
        fn, pid = programs[key]
        if group.sims not in sims_data:
            sims_data[group.sims] = {k: jax.device_put(np.asarray(v))
                                     for k, v in _stack_sims(group).items()}
        specs = input_specs(group)
        sig = (pid,) + tuple((l.shape, str(l.dtype))
                             for l in jax.tree_util.tree_leaves(specs))
        entries.append((group, fn, pid, specs, sig))

    # -- execute; AOT-compile the next distinct program in the background --
    compiled: Dict[Tuple, Tuple[Any, float, float]] = {}
    threads: Dict[Tuple, threading.Thread] = {}

    def compile_one(sig, fn, specs):
        t0 = time.time()
        ex = fn.lower(*specs).compile()
        compiled[sig] = (ex, t0, time.time())

    out, exec_windows, overlap_s = [], [], 0.0
    overlap_credited, compile_credited = set(), set()
    for i, (group, fn, pid, specs, sig) in enumerate(entries):
        if sig in threads:
            threads.pop(sig).join()
        background = sig in compiled
        if not background:
            compile_one(sig, fn, specs)
        ex, c0, c1 = compiled[sig]
        if background and sig not in overlap_credited:
            overlap_credited.add(sig)
            overlap_s += sum(max(0.0, min(c1, e1) - max(c0, e0))
                             for e0, e1 in exec_windows)
        # the first group using a program pays its compile; cache hits
        # (e.g. discard lowered onto the opt program) report 0
        if sig in compile_credited:
            group_compile_s = 0.0
        else:
            compile_credited.add(sig)
            group_compile_s = c1 - c0
        if overlap_compile:
            for g2, f2, p2, sp2, s2 in entries[i + 1:]:
                if s2 not in compiled and s2 not in threads:
                    th = threading.Thread(target=compile_one,
                                          args=(s2, f2, sp2), daemon=True)
                    th.start()
                    threads[s2] = th
                    break

        args = build_inputs(group)            # lazily: one group at a time
        t0 = time.time()
        _, metrics = ex(*args)
        jax.block_until_ready(metrics)
        t1 = time.time()
        exec_windows.append((t0, t1))
        run_s = t1 - t0
        if timeit:
            args = build_inputs(group)        # the carry was donated
            t2 = time.time()
            _, metrics = ex(*args)
            jax.block_until_ready(metrics)
            t3 = time.time()
            exec_windows.append((t2, t3))
            run_s = t3 - t2
        del args
        out.append(GroupResult(
            scheme=group.scheme, sims=group.sims, cfgs=group.cfgs,
            metrics={k: np.asarray(v)
                     for k, v in metrics._asdict().items()},
            compile_s=round(group_compile_s, 3), run_s=round(run_s, 3),
            label=group.label or group.scheme, program_id=pid))
        if verbose:
            accs = out[-1].metrics["test_acc"][..., -1]
            print(f"[sweep/{out[-1].label}] sims={len(group.sims)} "
                  f"cfgs={len(group.cfgs)} rounds={rounds} "
                  f"run={out[-1].run_s:.2f}s final_acc={accs.mean():.4f}")
    return SweepResult(groups=out, rounds=rounds,
                       wall_s=round(time.time() - t_all, 3),
                       n_programs=len(programs),
                       compile_overlap_s=round(overlap_s, 3))


def run_sweep(spec: SweepSpec, mesh: Any = "auto", verbose: bool = False,
              timeit: bool = False, lower_discard: bool = True,
              overlap_compile: bool = True) -> SweepResult:
    """Deprecated entry point — use ``repro.api.Experiment`` instead::

        Experiment.from_spec(spec).run(engine="sweep", mesh=mesh)

    Kept as a thin shim over the same engine (seeded-equivalent)."""
    import warnings
    warnings.warn("run_sweep is deprecated; use repro.api.Experiment"
                  ".from_spec(spec).run(engine='sweep')",
                  DeprecationWarning, stacklevel=2)
    return _run_sweep(spec, mesh=mesh, verbose=verbose, timeit=timeit,
                      lower_discard=lower_discard,
                      overlap_compile=overlap_compile)


def run_hsfl_on_device(cfg: HSFLConfig, mesh: Any = None) -> SimLog:
    """Deprecated entry point — use ``repro.api.Experiment`` instead::

        Experiment(cfg).run(engine="sweep", mesh=mesh).groups[0].sim_log(0, 0)

    Kept as a thin shim: ``run_hsfl`` with the whole control plane
    on-device (its own RNG stream; see module docstring)."""
    import warnings
    warnings.warn("run_hsfl_on_device is deprecated; use repro.api."
                  "Experiment(cfg).run(engine='sweep')",
                  DeprecationWarning, stacklevel=2)
    spec = SweepSpec(base=cfg, seeds=(cfg.seed,))
    res = _run_sweep(spec, mesh=mesh)
    return res.groups[0].sim_log(0, 0)


# ---------------------------------------------------------------------------
# Fig. 3 panels as SweepSpecs (the grid benchmarks/paper_experiments runs)
# ---------------------------------------------------------------------------

def fig3a_spec(rounds: int = 60, seeds=(0, 1), **base_kw) -> List[SweepSpec]:
    """Fig. 3(a): OPT (b=2) vs discard across iid/non-iid/imbalanced.
    Distributions are a *data* axis, so they stack on the sim axis."""
    base = HSFLConfig(rounds=rounds, **base_kw)
    dists = ("iid", "noniid", "imbalanced")
    return [SweepSpec(base=base, seeds=tuple(seeds), distributions=dists,
                      schemes=(("opt", {"b": 2.0}),
                               ("discard", {"b": 1.0})))]


def fig3b_spec(rounds: int = 60, seeds=(0, 1), **base_kw) -> List[SweepSpec]:
    """Fig. 3(b): OPT-HSFL vs Async-HSFL vs discard on non-iid."""
    base = HSFLConfig(rounds=rounds, **base_kw)
    return [SweepSpec(base=base, seeds=tuple(seeds),
                      schemes=(("opt", {"b": 2.0}),
                               ("async", {"b": 1.0}),
                               ("discard", {"b": 1.0})))]


def fig3c_spec(rounds: int = 60, seeds=(0,), **base_kw) -> List[SweepSpec]:
    """Fig. 3(c): budget sweep — b rides the traced config axis."""
    base = HSFLConfig(rounds=rounds, scheme="opt", **base_kw)
    return [SweepSpec(base=base, seeds=tuple(seeds),
                      b=(1.0, 2.0, 3.0, 4.0, 5.0, 6.0))]


def fig3d_spec(rounds: int = 60, seeds=(0,), **base_kw) -> List[SweepSpec]:
    """Fig. 3(d): τ_max sweep — the latency cliff on the config axis."""
    base = HSFLConfig(rounds=rounds, scheme="opt", b=2, **base_kw)
    return [SweepSpec(base=base, seeds=tuple(seeds),
                      tau_max=(7.0, 8.0, 9.0, 10.0, 11.0))]
