"""Global aggregation schemes — FedAvg + the two comparison baselines.

- ``fedavg``: Alg. 1 line 15 / Alg. 2 line 15 (uniform over received).
- ``fedasync_weight``: the polynomial staleness weight α(t−τ+1)^(−a) from
  Xie et al. [3], as configured in Sec. IV (α=0.4, a=0.5, max delay 1).
- discard is expressed by simply not including a client (b=1 baseline).
"""
from __future__ import annotations

from typing import Any, List, Sequence

import jax
import jax.numpy as jnp

from repro.models import module as m


def fedavg(updates: Sequence[Any], weights: Sequence[float] | None = None) -> Any:
    """Weighted average of parameter pytrees (uniform when weights None).

    Stack-and-contract in a single tree_map: one fused op per leaf instead
    of the old O(K) scale-and-add chain of small dispatches per client.
    """
    assert updates, "fedavg needs at least one update"
    if weights is None:
        ws = jnp.full((len(updates),), 1.0 / len(updates), jnp.float32)
    else:
        ws = jnp.asarray(weights, jnp.float32)
        ws = ws / jnp.sum(ws)
    def avg(*leaves):
        stacked = jnp.stack([jnp.asarray(l) for l in leaves])
        out = jnp.tensordot(ws, stacked.astype(jnp.float32), axes=1)
        return out.astype(stacked.dtype)

    return jax.tree_util.tree_map(avg, *updates)


def fedasync_weight(staleness: int, alpha: float = 0.4, a: float = 0.5) -> float:
    """α(t−τ+1)^(−a): weight for a model update delayed by ``staleness``."""
    return alpha * float(staleness + 1) ** (-a)


def fedasync_merge(global_params: Any, delayed_update: Any, staleness: int,
                   alpha: float = 0.4, a: float = 0.5) -> Any:
    """Server-side async merge: ω ← (1−α_t)·ω + α_t·ω_delayed."""
    w = fedasync_weight(staleness, alpha, a)
    return m.tree_lerp(global_params, delayed_update, w)


def aggregate_round(arrived: List[Any], delayed: List[tuple],
                    global_params: Any, scheme: str,
                    alpha: float = 0.4, a: float = 0.5) -> Any:
    """One round of global aggregation.

    Back-compat wrapper: delegates to the scheme registry, where
    ``get_scheme(scheme).aggregate_host(...)`` holds the single
    per-scheme implementation — new schemes are covered automatically.

    arrived:  fresh updates received this round (final or OPT snapshots).
    delayed:  [(update, staleness), ...] — only used by the 'async' scheme.
    scheme:   'opt' | 'discard' — FedAvg over ``arrived`` (OPT already
              substituted snapshots for missing finals upstream);
              'async' — FedAvg over timely + staleness-weighted delayed
              (weights α(s+1)^(−a) vs 1.0 for timely, Sec. IV).
    """
    # local import: schemes.py imports this module for the primitives
    from repro.core.schemes import get_scheme
    return get_scheme(scheme).aggregate_host(arrived, delayed, global_params,
                                             alpha=alpha, a=a)
