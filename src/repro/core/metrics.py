"""Round-level bookkeeping: comms overhead (MB), staleness, participation."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List


@dataclass
class RoundLog:
    round: int
    selected: int = 0
    arrived_final: int = 0
    used_snapshot: int = 0
    dropped: int = 0
    delayed: int = 0
    bytes_sent: float = 0.0
    test_loss: float = float("nan")
    test_acc: float = float("nan")
    # serving-path counters (serving/fl_server): zero on the batch engines
    duplicates_rejected: int = 0
    stale_rejected: int = 0
    corrupt_rejected: int = 0
    retries: int = 0
    late_accepted: int = 0
    unregistered_skipped: int = 0
    quorum_met: bool = True
    # lossy-wire transport counters (serving path with core.transport):
    # zero when the transport model is disabled
    backoff_s: float = 0.0             # simulated seconds burnt in backoff
    chunks_sent: int = 0               # chunks handed to the wire (1st try)
    chunks_retransmitted: int = 0      # NACKed chunks re-sent
    chunks_corrupt: int = 0            # wire corruptions detected (CRC)
    chunks_recovered: int = 0          # data chunks rebuilt via XOR parity
    transfers_incomplete: int = 0      # uploads lost beyond parity rescue
    parity_bytes: float = 0.0          # FEC overhead on the wire


@dataclass
class SimLog:
    rounds: List[RoundLog] = field(default_factory=list)

    def add(self, r: RoundLog) -> None:
        self.rounds.append(r)

    @property
    def avg_comm_mb(self) -> float:
        """Mean data transmitted to the server per communication round (MB)."""
        if not self.rounds:
            return 0.0
        return sum(r.bytes_sent for r in self.rounds) / len(self.rounds) / 1e6

    @property
    def final_acc(self) -> float:
        tail = [r.test_acc for r in self.rounds[-5:] if r.test_acc == r.test_acc]
        return sum(tail) / len(tail) if tail else float("nan")

    @property
    def acc_curve(self) -> List[float]:
        return [r.test_acc for r in self.rounds]

    @property
    def loss_curve(self) -> List[float]:
        return [r.test_loss for r in self.rounds]

    def summary(self) -> Dict[str, float]:
        n = max(1, len(self.rounds))
        return {
            "rounds": len(self.rounds),
            "final_acc": self.final_acc,
            "avg_comm_mb": self.avg_comm_mb,
            "mean_participation": sum(r.arrived_final + r.used_snapshot
                                      for r in self.rounds) / n,
            "snapshot_rescues": sum(r.used_snapshot for r in self.rounds),
            "drops": sum(r.dropped for r in self.rounds),
            "duplicates_rejected": sum(r.duplicates_rejected
                                       for r in self.rounds),
            "stale_rejected": sum(r.stale_rejected for r in self.rounds),
            "corrupt_rejected": sum(r.corrupt_rejected for r in self.rounds),
            "retries": sum(r.retries for r in self.rounds),
            "chunks_sent": sum(r.chunks_sent for r in self.rounds),
            "chunks_retransmitted": sum(r.chunks_retransmitted
                                        for r in self.rounds),
            "chunks_recovered": sum(r.chunks_recovered for r in self.rounds),
            "transfers_incomplete": sum(r.transfers_incomplete
                                        for r in self.rounds),
        }
