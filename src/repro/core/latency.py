"""One-round latency accounting — Section III-A, eqs. (9)–(16).

FL users:  τ_iF = τ_tr + τ_ul            (eq. 9),  τ_ul = b·m_g / r⁰   (eq. 13)
SL users:  τ_iS = τ_tr + τ_ul + τ_dl     (eq. 10), τ_ul = (b·m_l + m_a)/r⁰
Extra opportunistic allowance: τ_extra = (b−1)·m / r⁰            (eq. 14)
Real-time snapshot delay:      τ^{e_t}  = m / r^{e_t}            (eq. 15)

Training/downlink terms follow [6]'s structure (per-sample FLOPs over device
compute rate); [6]'s exact constants are not in this paper, so they are
explicit dataclass fields here.
"""
from __future__ import annotations

from dataclasses import dataclass



@dataclass
class DeviceProfile:
    """Per-UAV compute/energy profile (heterogeneous fleet)."""
    flops_per_sec: float = 5.0e9          # UAV on-board compute
    server_flops_per_sec: float = 1.0e12  # BS edge server
    power_compute_w: float = 5.0          # UAV compute power draw
    power_tx_w: float = 0.25              # 24 dBm transmit power


@dataclass
class WorkloadProfile:
    """Learning-task constants used by the latency terms."""
    flops_per_sample: float = 2.0e6       # fwd+bwd of the 5-layer CNN
    ue_fraction: float = 0.4              # fraction of FLOPs on UE side (SL)
    local_epochs: int = 6
    samples: int = 200                    # |D_i|
    act_bytes_per_sample: float = 3136.0  # cut-layer activation (m_a / |D_i|)


def train_time_fl(dev: DeviceProfile, wl: WorkloadProfile) -> float:
    """τ_tr for an FL user: all epochs on the UAV."""
    return wl.local_epochs * wl.samples * wl.flops_per_sample / dev.flops_per_sec


def train_time_sl(dev: DeviceProfile, wl: WorkloadProfile) -> float:
    """τ_tr for an SL user: UE front + BS back per epoch."""
    ue = wl.ue_fraction * wl.flops_per_sample / dev.flops_per_sec
    bs = (1 - wl.ue_fraction) * wl.flops_per_sample / dev.server_flops_per_sec
    return wl.local_epochs * wl.samples * (ue + bs)


def uplink_fl(b: int, model_bytes: float, rate_bps: float) -> float:
    """eq. (13) left: b·m_g / r⁰ (seconds)."""
    return b * model_bytes * 8.0 / max(rate_bps, 1e-9)


def uplink_sl(b: int, ue_model_bytes: float, act_bytes: float,
              rate_bps: float) -> float:
    """eq. (13) right: (b·m_l + m_a) / r⁰."""
    return (b * ue_model_bytes + act_bytes) * 8.0 / max(rate_bps, 1e-9)


def downlink_sl(bs_rate_bps: float, ue_model_bytes: float, act_bytes: float) -> float:
    """τ_dl: BS returns the UE-side model + cut-layer gradients."""
    return (ue_model_bytes + act_bytes) * 8.0 / max(bs_rate_bps, 1e-9)


def one_round_latency_fl(dev: DeviceProfile, wl: WorkloadProfile, b: int,
                         model_bytes: float, rate_bps: float) -> float:
    """eq. (9) with relaxed uplink (eq. 13)."""
    return train_time_fl(dev, wl) + uplink_fl(b, model_bytes, rate_bps)


def one_round_latency_sl(dev: DeviceProfile, wl: WorkloadProfile, b: int,
                         ue_model_bytes: float, rate_bps: float,
                         bs_rate_bps: float) -> float:
    """eq. (10) with relaxed uplink (eq. 13)."""
    act = wl.act_bytes_per_sample * wl.samples
    return (train_time_sl(dev, wl)
            + uplink_sl(b, ue_model_bytes, act, rate_bps)
            + downlink_sl(bs_rate_bps, ue_model_bytes, act))


def extra_allowance(b: int, model_bytes: float, rate_bps: float) -> float:
    """eq. (14): τ_extra = (b−1)·m / r⁰."""
    return (b - 1) * model_bytes * 8.0 / max(rate_bps, 1e-9)


def snapshot_delay(model_bytes: float, rate_bps: float) -> float:
    """eq. (15): τ^{e_t} = m / r^{e_t}."""
    return model_bytes * 8.0 / max(rate_bps, 1e-9)


def energy_fl(dev: DeviceProfile, wl: WorkloadProfile, tx_seconds: float) -> float:
    """Joules: compute + transmit (used by the greedy selector's utility)."""
    return (train_time_fl(dev, wl) * dev.power_compute_w
            + tx_seconds * dev.power_tx_w)


def energy_sl(dev: DeviceProfile, wl: WorkloadProfile, tx_seconds: float) -> float:
    ue_t = (wl.local_epochs * wl.samples * wl.ue_fraction
            * wl.flops_per_sample / dev.flops_per_sec)
    return ue_t * dev.power_compute_w + tx_seconds * dev.power_tx_w
