"""Split-learning partition of model parameters (HSFL's SL mode).

Two model shapes are supported:
- the paper CNN: stage-name split (models/cnn.py split_params)
- any scanned transformer: the stacked (L, ...) layer leaves are sliced at a
  cut index — UE side gets embedding + layers [0, cut), BS side gets layers
  [cut, L) + final norm + head.  The cut-layer activation (B, S, d_model) is
  the SL payload; for recurrent families the carried state at the cut layer
  travels with it (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def split_stacked(params: Dict[str, Any], cut: int) -> Tuple[Dict, Dict]:
    """Split a transformer param tree at stacked-layer index ``cut``."""
    layers = params["layers"]
    ue_layers = jax.tree_util.tree_map(lambda a: a[:cut], layers)
    bs_layers = jax.tree_util.tree_map(lambda a: a[cut:], layers)
    ue = {"layers": ue_layers}
    if "embed" in params:
        ue["embed"] = params["embed"]
    bs = {"layers": bs_layers,
          "final_norm": params["final_norm"],
          "head": params["head"]}
    return ue, bs


def merge_stacked(ue: Dict[str, Any], bs: Dict[str, Any]) -> Dict[str, Any]:
    layers = jax.tree_util.tree_map(
        lambda a, b: jnp.concatenate([a, b], axis=0),
        ue["layers"], bs["layers"])
    out = {"layers": layers, "final_norm": bs["final_norm"], "head": bs["head"]}
    if "embed" in ue:
        out["embed"] = ue["embed"]
    return out


def ue_param_bytes(params: Dict[str, Any], cut: int) -> int:
    """m_i^l: size of the UE-side model for eq. (12)/(13)."""
    ue, _ = split_stacked(params, cut) if "layers" in params else (params, None)
    return sum(a.size * a.dtype.itemsize for a in jax.tree_util.tree_leaves(ue))
