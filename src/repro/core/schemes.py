"""Transmission schemes as first-class API objects — the pluggable registry.

The paper's contribution *is* a transmission scheme (opportunistic proactive
upload vs. the sync/async/discard baselines), yet until PR 5 a scheme was a
string branched inside every engine (``HSFLSimulation``, ``build_fused_round``,
``build_device_round``, ``compile_spec``).  This module makes the scheme the
unit of extension: a ``Scheme`` object owns the three decisions every engine
delegates —

  1. **probe schedule** — when Alg. 2 probes the channel for an opportunistic
     snapshot: ``static_schedule`` (host engines, compile-time epochs) and
     ``probe_schedule`` (device engines, a branch-free mask over a *traced*
     budget b);
  2. **selection policy** — which users are scheduled each round:
     ``selection_policy`` wraps ``selection.select_users_jax`` (device) and
     ``selection_policy_host`` wraps ``selection.schedule_users`` (host);
  3. **aggregation** — how the round's contributions merge into the global
     model: ``aggregate`` (stacked (K, ...) device form) and
     ``aggregate_host`` (list-of-pytrees host form);

plus the **final-upload deadline** knob ``final_slack`` (extra seconds charged
against τ_max at the end-of-round upload) and static engine facts
(``uses_probes``, ``carries_delayed``, ``supports_codec``,
``lowered_program``).  Every method an engine traces is jit-compatible, so a
registered scheme runs unchanged on all three engines: the host reference
loop, the fused single-round program and the scanned/vmapped sweep engine.

Registered paper schemes (Sec. III / Fig. 3):

  ``opt``      — OPT-HSFL: scheduled probes under the eq. 14 τ_extra budget,
                 snapshots rescue missed finals (Alg. 2).
  ``sync``     — fully synchronous HSFL: the server *waits* for every
                 scheduled final (no τ_max cutoff; only an upload-time outage
                 loses an update) — the latency-unconstrained envelope.
  ``async``    — Async-HSFL: delayed updates arrive next round, merged with
                 the polynomial staleness weight α(s+1)^(−a) [3].
  ``discard``  — delayed updates dropped (the b=1 / dashed baseline).

Beyond-paper scheme shipped through the same registry (the proof the API
composes):

  ``deadline`` — overhead-aware OPT after arXiv:2405.00681: the eq. 14 probe
                 allowance τ_extra0 = (b−1)·m_i/r_i^0 is *charged against the
                 round deadline*, so a final upload only counts if
                 t_train + τ_extra0 + τ_f ≤ τ_max.  Budgeting more probes
                 tightens the final deadline — the overhead-vs-delay frontier
                 — while snapshots still rescue what the deadline drops.

Extending: subclass ``Scheme``, decorate with ``@register_scheme("name")``,
and the scheme is immediately runnable through ``repro.api.Experiment`` on
every engine, sweepable via ``SweepSpec.schemes`` entries, and selectable
from the benchmark CLIs — no engine edits.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Any, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.aggregation import fedavg, fedasync_merge, fedasync_weight
from repro.core.selection import schedule_users, select_users_jax
from repro.core.transmission import scheduled_epochs


# ---------------------------------------------------------------------------
# stacked-axis aggregation primitives (shared by every scheme + both device
# engines; formerly private to fused_round)
# ---------------------------------------------------------------------------

def kx(flags: jnp.ndarray, leaf: jnp.ndarray) -> jnp.ndarray:
    """Broadcast a (K,) flag vector against a (K, ...) leaf."""
    return flags.reshape(flags.shape + (1,) * (leaf.ndim - 1))


def tree_where_k(flags, a, b):
    return jax.tree_util.tree_map(
        lambda x, y: jnp.where(kx(flags, x), x, y), a, b)


def masked_mean(contrib, weights, fallback):
    """Σ_i w_i·x_i / Σ_i w_i over the K axis; ``fallback`` when Σ w = 0.

    The denominator is the *true* positive sum — clamping it to 1 (the old
    ``jnp.maximum(num, 1.0)``) silently shrinks the mean whenever the
    weights are fractional and sum below 1 (the async staleness weights
    α(s+1)^(−a) ≈ 0.283 do exactly that; same bug class as the fixed
    ``opportunistic_sync.round_sync``)."""
    num = jnp.sum(weights)
    denom = jnp.where(num > 0, num, 1.0)
    return jax.tree_util.tree_map(
        lambda c, p: jnp.where(
            num > 0, jnp.sum(c * kx(weights, c), axis=0) / denom, p),
        contrib, fallback)


def _rank_pos(weights, leaf_ndim: int):
    """Sort-rank helpers shared by the masked robust aggregates: the valid
    count m, and the rank index j broadcast against a sorted (K, ...) leaf.
    Invalid slots are pushed to +inf before the sort, so ranks [0, m) are
    exactly the valid values in ascending order."""
    m = jnp.sum(weights)
    K = weights.shape[0]
    j = jnp.arange(K, dtype=jnp.float32).reshape(
        (K,) + (1,) * (leaf_ndim - 1))
    return m, j


def trimmed_mean(contrib, weights, fallback, trim: float = 0.25):
    """Coordinate-wise α-trimmed mean over the K axis (Byzantine-robust).

    Each coordinate sorts its m = Σw valid entries (invalid slots ride to
    +inf past them), drops g = ⌊trim·m⌋ from each tail — clipped so at
    least one rank survives — and averages ranks [g, m−g).  Selection is
    ``jnp.where`` on position weights, *never* a multiply: the +inf
    padding times a zero weight would be NaN.  Backend-agnostic: the same
    function serves the stacked device engines and (stacked by
    ``_host_stack``) the host server, which is what makes the
    host-vs-fused pins bit-comparable."""
    def one(c, p):
        m, j = _rank_pos(weights, c.ndim)
        s = jnp.sort(jnp.where(kx(weights, c) > 0, c, jnp.inf), axis=0)
        g = jnp.maximum(jnp.minimum(jnp.floor(trim * m),
                                    jnp.floor((m - 1.0) / 2.0)), 0.0)
        keep = (j >= g) & (j < m - g)
        cnt = jnp.maximum(m - 2.0 * g, 1.0)
        val = jnp.sum(jnp.where(keep, s, 0.0), axis=0) / cnt
        return jnp.where(m > 0, val, p)
    return jax.tree_util.tree_map(one, contrib, fallback)


def masked_median(contrib, weights, fallback):
    """Coordinate-wise median over the m = Σw valid slots of the K axis
    (even m averages the two middle ranks).  Rank selection is a one-hot
    ``jnp.where`` sum — branch-free under a traced m."""
    def one(c, p):
        m, j = _rank_pos(weights, c.ndim)
        s = jnp.sort(jnp.where(kx(weights, c) > 0, c, jnp.inf), axis=0)
        lo = jnp.floor((m - 1.0) / 2.0)
        hi = jnp.ceil((m - 1.0) / 2.0)
        med = 0.5 * (jnp.sum(jnp.where(j == lo, s, 0.0), axis=0)
                     + jnp.sum(jnp.where(j == hi, s, 0.0), axis=0))
        return jnp.where(m > 0, med, p)
    return jax.tree_util.tree_map(one, contrib, fallback)


def clipped_mean(contrib, weights, fallback):
    """Masked mean of norm-clipped updates: each slot's delta from the
    global model is scaled down to the masked *median* of the valid delta
    norms (the adaptive clip radius — no tuning knob), then masked-mean.
    A single exploded upload can move the mean by at most the typical
    honest update norm."""
    sq = jax.tree_util.tree_map(
        lambda c, p: jnp.sum((c - p) ** 2,
                             axis=tuple(range(1, c.ndim))),
        contrib, fallback)
    norms = jnp.sqrt(sum(jax.tree_util.tree_leaves(sq)))       # (K,)
    m, j = _rank_pos(weights, 1)
    s = jnp.sort(jnp.where(weights > 0, norms, jnp.inf))
    lo = jnp.floor((m - 1.0) / 2.0)
    hi = jnp.ceil((m - 1.0) / 2.0)
    med = 0.5 * (jnp.sum(jnp.where(j == lo, s, 0.0))
                 + jnp.sum(jnp.where(j == hi, s, 0.0)))
    scale = jnp.minimum(1.0, med / jnp.maximum(norms, 1e-12))  # (K,)
    clipped = jax.tree_util.tree_map(
        lambda c, p: p + kx(scale, c) * (c - p), contrib, fallback)
    return masked_mean(clipped, weights, fallback)


def _host_stack(arrived):
    """List-of-pytrees -> (stacked (n, ...) tree, all-ones weights): the
    adapter that lets ``aggregate_host`` reuse the exact stacked-axis
    robust aggregate the device engines trace."""
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *arrived)
    return stacked, jnp.ones((len(arrived),), jnp.float32)


def async_merge(params, stacked, delayed_stack, delayed_mask, arrived,
                aw: float, k_carry: int):
    """Async aggregation: timely finals at weight 1, prior-round stragglers
    at α(s+1)^(−a); a round with only stragglers falls back to the
    sequential FedAsync server merge (never a full replace)."""
    w_t = arrived.astype(jnp.float32)                      # (K,)
    w_d = delayed_mask.astype(jnp.float32) * aw            # (k_carry,)
    n_arr = jnp.sum(w_t)
    total = n_arr + jnp.sum(w_d)
    mixed = jax.tree_util.tree_map(
        lambda s, d, p: jnp.where(
            total > 0,
            (jnp.sum(s * kx(w_t, s), axis=0)
             + jnp.sum(d * kx(w_d, d), axis=0))
            / jnp.maximum(total, 1e-9), p),
        stacked, delayed_stack, params)

    seq = params
    for i in range(k_carry):          # static unroll; k_carry is small
        seq = jax.tree_util.tree_map(
            lambda acc, d: jnp.where(delayed_mask[i],
                                     (1.0 - aw) * acc + aw * d[i], acc),
            seq, delayed_stack)
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(n_arr > 0, a, b), mixed, seq)


def probe_schedule_mask(e_t: int, local_epochs: int, b) -> jnp.ndarray:
    """``transmission.scheduled_epochs`` membership with a *traced* budget.

    The host schedule is {k·period : 1 ≤ k ≤ b−1, k·period < e} with
    period = max(1, round(e/b)); that set is exactly the e_t with
    e_t ≡ 0 (mod period), e_t < e and e_t ≤ (b−1)·period, which this
    evaluates branch-free so ``b`` can live on a vmapped config axis.
    ``tests/test_sweep.py`` pins the two over an (e, b) grid.
    """
    bf = jnp.asarray(b, jnp.float32)
    period = jnp.clip(jnp.round(local_epochs / jnp.maximum(bf, 1.0)),
                      1.0, float(local_epochs))
    et = jnp.asarray(e_t, jnp.float32)
    return ((jnp.mod(et, period) == 0) & (et < local_epochs)
            & (et <= (bf - 1.0) * period))


# ---------------------------------------------------------------------------
# the Scheme protocol
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Scheme:
    """One transmission policy, decomposed into the decisions every engine
    makes.  Instances are frozen/hashable (they ride static jit arguments
    and program-cache keys); ``pins`` carries per-scheme sweep pins — the
    ``("opt", {"b": 2.0})`` dict of the legacy ``SweepSpec`` entry form —
    onto the object itself (``with_pins``).

    The base class implements the *discard/sync family*: no probes, no
    straggler carry, FedAvg over whatever arrived.  Subclasses override
    only the decisions that differ.
    """
    pins: Tuple[Tuple[str, Any], ...] = ()

    # -- static engine facts (class attributes, NOT dataclass fields: the
    #    registry stamps ``name`` onto the class, and identity/equality is
    #    (class, pins)) --------------------------------------------------
    name = "base"
    uses_probes = False        # compile the Alg. 2 probe/snapshot block
    carries_delayed = False    # the async straggler carry is live
    supports_codec = False     # snapshots exist -> codec state is meaningful

    def with_pins(self, **pins) -> "Scheme":
        """A copy with sweep pins (b/τ_max/group statics) attached."""
        merged = dict(self.pins)
        merged.update(pins)
        return replace(self, pins=tuple(sorted(merged.items())))

    # -- decision 1: probe schedule -----------------------------------------
    def static_schedule(self, local_epochs: int, b: int,
                        override: Sequence[int] = ()) -> Tuple[int, ...]:
        """Compile-time probe epochs for the host/fused engines (Alg. 2
        line 12, or the Sec. III-B manual override)."""
        return ()

    def probe_schedule(self, e_t, local_epochs: int, b,
                       override=None) -> jnp.ndarray:
        """Traced probe mask for the device engine: is local epoch ``e_t``
        a scheduled probe under (possibly traced) budget ``b``?"""
        return jnp.zeros((), bool)

    # -- decision 2: selection policy ---------------------------------------
    def selection_policy(self, rates0, flops, samples, *, b, tau_max,
                         k_select: int, model_bytes: float,
                         ue_model_bytes: float, local_epochs: int,
                         max_sl=None, **lat_kw):
        """Which users train this round (device engines): the greedy
        energy-per-sample selection of Alg. 1 l. 3-5 by default.  Returns
        ``select_users_jax``'s fixed-width slot arrays."""
        return select_users_jax(
            rates0, flops, samples, b=b, tau_max=tau_max, k_select=k_select,
            model_bytes=model_bytes, ue_model_bytes=ue_model_bytes,
            local_epochs=local_epochs, max_sl=max_sl, **lat_kw)

    def selection_policy_host(self, rates0, devices, workloads,
                              model_bytes: float, ue_model_bytes: float,
                              b: int, tau_max: float, k_select: int):
        """Host-engine twin of ``selection_policy`` (Python greedy)."""
        return schedule_users(rates0, devices, workloads, model_bytes,
                              ue_model_bytes, b, tau_max, k_select)

    # -- final-upload deadline ----------------------------------------------
    def final_slack(self, tau_extra0):
        """Extra seconds charged against τ_max at the final upload:
        ``arrived`` requires t_train + final_slack + τ_f ≤ τ_max.

        0 for the paper schemes (shape-preserving so the traced arrival
        predicate is bit-identical to the pre-registry engines); the
        ``deadline`` scheme charges the eq. 14 probe allowance, ``sync``
        returns −inf (the server waits).  Works on host floats and device
        arrays alike."""
        return tau_extra0 * 0.0

    # -- decision 3: aggregation --------------------------------------------
    def aggregate(self, params, contribs, snapshots, has_snap, arrived, *,
                  delayed=None, delayed_mask=None, async_weight: float = 0.0,
                  k_carry: int = 0):
        """Merge the round into the global model (device engines).

        ``contribs`` are the stacked (K, ...) locally-trained params,
        ``snapshots``/``has_snap`` the opportunistic snapshot state,
        ``delayed``/``delayed_mask`` the staleness carry.  Returns
        ``(new_params, rescued)`` with ``rescued`` a (K,) bool mask."""
        rescued = jnp.zeros_like(arrived)
        new = masked_mean(contribs, arrived.astype(jnp.float32), params)
        return new, rescued

    def aggregate_host(self, arrived, delayed, global_params,
                       alpha: float = 0.4, a: float = 0.5):
        """Host-engine twin of ``aggregate``: ``arrived`` is a list of
        pytrees (finals + any rescued snapshots), ``delayed`` a list of
        ``(update, staleness)`` tuples."""
        if not arrived:
            return global_params
        return fedavg(arrived)

    def pod_contribution(self, params, snapshot, have_snap, arrived, *,
                         alpha: float = 0.4, a: float = 0.5):
        """Per-pod twin of ``aggregate`` for the shard_map engine
        (``opportunistic_sync``): this pod's payload and its weight in
        the cross-pod mean.  ``arrived``/``have_snap`` are scalar bools
        local to the pod; returns ``(contrib, valid)`` with ``valid`` a
        scalar f32 weight.  Base: a missed final contributes nothing
        (discard/sync)."""
        del snapshot, have_snap, alpha, a
        return params, arrived.astype(jnp.float32)

    def delayed_out(self, valid, arrived) -> jnp.ndarray:
        """Which users enter next round's staleness carry."""
        return jnp.zeros_like(arrived)

    # -- sweep-engine program identity --------------------------------------
    def lowered_program(self, b_vals: Tuple[float, ...]) -> str:
        """The scheme whose round *program* executes a sweep group pinned to
        budget values ``b_vals`` — normally ``self.name``; a scheme may
        reroute onto another scheme's compile when the two provably
        coincide there (``discard`` @ b=1 is opt with zero probes)."""
        return self.name


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

SCHEMES: Dict[str, Scheme] = {}


def register_scheme(name: str):
    """Class decorator: instantiate and register a Scheme under ``name``.

    The registered instance is the canonical one — ``get_scheme(name)``
    returns it, ``SweepSpec``/``Experiment`` resolve strings through it,
    and the benchmark CLIs list it as a ``--scheme`` choice."""
    def deco(cls):
        if name in SCHEMES:
            raise ValueError(f"scheme {name!r} is already registered "
                             f"({SCHEMES[name].__class__.__name__})")
        taken = next((n for n, s in SCHEMES.items() if s.__class__ is cls),
                     None)
        if taken is not None:
            # stamping a second name onto the same class would retroactively
            # rename the registered singleton (name is a class attribute so
            # that frozen-dataclass replace()/with_pins preserve it)
            raise ValueError(
                f"{cls.__name__} is already registered as {taken!r}; "
                f"subclass it to register an alias")
        cls.name = name
        SCHEMES[name] = cls()
        return cls
    return deco


def registered_schemes() -> Tuple[str, ...]:
    """Sorted names of every registered scheme."""
    return tuple(sorted(SCHEMES))


def get_scheme(scheme) -> Scheme:
    """Resolve a scheme name (or pass a ``Scheme`` instance through).

    Raises a ``ValueError`` naming every registered scheme on an unknown
    string — the error the sweep compiler and every engine surface."""
    if isinstance(scheme, Scheme):
        return scheme
    try:
        return SCHEMES[scheme]
    except KeyError:
        raise ValueError(
            f"unknown transmission scheme {scheme!r}; registered schemes: "
            f"{', '.join(registered_schemes())} "
            f"(add one with @repro.core.schemes.register_scheme)") from None


# ---------------------------------------------------------------------------
# the four paper schemes
# ---------------------------------------------------------------------------

@register_scheme("discard")
class DiscardScheme(Scheme):
    """HSFL with delayed updates dropped (the b=1 / dashed baseline).
    Aggregation/probes are the base class; only the sweep lowering is its
    own: at b=1 the probe schedule is empty and the eq. 14 allowance is 0,
    so no snapshot ever exists and the rescue weights vanish identically —
    discard IS opt there, and the group shares opt's compile."""

    def lowered_program(self, b_vals: Tuple[float, ...]) -> str:
        return "opt" if tuple(b_vals) == (1.0,) else self.name


@register_scheme("sync")
class SyncScheme(Scheme):
    """Fully synchronous HSFL: the server waits for every scheduled final
    upload regardless of τ_max (only an upload-time outage loses one) —
    the latency-unconstrained envelope the deadline-bound schemes trade
    against."""

    def final_slack(self, tau_extra0):
        return tau_extra0 * 0.0 - math.inf     # t + (−inf) ≤ τ_max always


@register_scheme("opt")
class OptScheme(Scheme):
    """OPT-HSFL (this paper): scheduled probes under the eq. 14 τ_extra
    budget; the latest snapshot rescues a missed final (Alg. 2)."""
    uses_probes = True
    supports_codec = True

    def static_schedule(self, local_epochs: int, b: int,
                        override: Sequence[int] = ()) -> Tuple[int, ...]:
        if b <= 1:
            return ()
        sched = (tuple(override) if override
                 else tuple(scheduled_epochs(local_epochs, b)))
        return tuple(e for e in sched if 1 <= e <= local_epochs)

    def probe_schedule(self, e_t, local_epochs: int, b,
                       override=None) -> jnp.ndarray:
        if override is not None:
            return jnp.any(e_t == override)
        return probe_schedule_mask(e_t, local_epochs, b)

    def aggregate(self, params, contribs, snapshots, has_snap, arrived, *,
                  delayed=None, delayed_mask=None, async_weight: float = 0.0,
                  k_carry: int = 0):
        rescued = (~arrived) & has_snap
        contrib = tree_where_k(arrived, contribs, snapshots)
        weights = (arrived | rescued).astype(jnp.float32)
        return masked_mean(contrib, weights, params), rescued

    def pod_contribution(self, params, snapshot, have_snap, arrived, *,
                         alpha: float = 0.4, a: float = 0.5):
        del alpha, a
        contrib = jax.tree_util.tree_map(
            lambda p, s: jnp.where(arrived, p, s), params, snapshot)
        return contrib, (arrived | have_snap).astype(jnp.float32)


@register_scheme("async")
class AsyncScheme(Scheme):
    """Async-HSFL: delayed updates arrive next round and aggregate with the
    polynomial staleness weight α(s+1)^(−a) [3]."""
    carries_delayed = True

    def aggregate(self, params, contribs, snapshots, has_snap, arrived, *,
                  delayed=None, delayed_mask=None, async_weight: float = 0.0,
                  k_carry: int = 0):
        new = async_merge(params, contribs, delayed, delayed_mask, arrived,
                          float(async_weight), k_carry)
        return new, jnp.zeros_like(arrived)

    def aggregate_host(self, arrived, delayed, global_params,
                       alpha: float = 0.4, a: float = 0.5):
        delayed = list(delayed or [])
        if arrived:
            updates = list(arrived)
            weights = [1.0] * len(arrived)
            for upd, staleness in delayed:
                updates.append(upd)
                weights.append(fedasync_weight(staleness, alpha, a))
            return fedavg(updates, weights)
        if delayed:
            # only stragglers: the sequential FedAsync server merge
            # ω ← (1−α_t)·ω + α_t·ω_d — never a full replace
            out = global_params
            for upd, staleness in delayed:
                out = fedasync_merge(out, upd, staleness, alpha, a)
            return out
        return global_params

    def pod_contribution(self, params, snapshot, have_snap, arrived, *,
                         alpha: float = 0.4, a: float = 0.5):
        del snapshot, have_snap
        # the delayed update arrives anyway, one round stale [3]
        w = alpha * 2.0 ** (-a)
        return params, jnp.where(arrived, 1.0, w)

    def delayed_out(self, valid, arrived) -> jnp.ndarray:
        return valid & ~arrived


# ---------------------------------------------------------------------------
# beyond-paper: deadline-aware OPT (arXiv:2405.00681)
# ---------------------------------------------------------------------------

@register_scheme("deadline")
class DeadlineScheme(OptScheme):
    """Overhead-aware OPT: the eq. 14 probe allowance is charged against the
    round deadline, so a final upload only arrives if
    t_train + τ_extra0 + τ_f ≤ τ_max.  A bigger probe budget b buys more
    rescue opportunities but tightens the final deadline — the
    overhead-vs-delay frontier of arXiv:2405.00681.  Snapshots still rescue
    what the deadline drops, so the scheme degrades toward opt's rescue
    path rather than discard's drops.  At b=1 the allowance is exactly 0
    and the scheme coincides with opt (and hence discard)."""

    def final_slack(self, tau_extra0):
        return tau_extra0


# ---------------------------------------------------------------------------
# Byzantine-robust OPT variants (the lossy-wire PR): same probe/rescue
# machinery as opt, but the aggregate survives CRC-clean corruption —
# pre-encode bit flips (the ``flip`` fault) that checksums cannot see.
# Registered like any scheme: zero engine edits, automatically swept by
# the contracts/CI registry iteration.
# ---------------------------------------------------------------------------

@register_scheme("opt_trimmed")
class OptTrimmedScheme(OptScheme):
    """OPT with a coordinate-wise trimmed-mean aggregate: the ⌊trim·m⌋
    largest and smallest entries of every coordinate are dropped before
    averaging, so a minority of exploded uploads cannot move the model.
    ``aggregate_host`` stacks the arrived list and calls the *same*
    ``trimmed_mean`` the device engines trace (host-vs-fused pinned)."""
    trim = 0.25

    def aggregate(self, params, contribs, snapshots, has_snap, arrived, *,
                  delayed=None, delayed_mask=None, async_weight: float = 0.0,
                  k_carry: int = 0):
        rescued = (~arrived) & has_snap
        contrib = tree_where_k(arrived, contribs, snapshots)
        weights = (arrived | rescued).astype(jnp.float32)
        return trimmed_mean(contrib, weights, params, self.trim), rescued

    def aggregate_host(self, arrived, delayed, global_params,
                       alpha: float = 0.4, a: float = 0.5):
        if not arrived:
            return global_params
        stacked, w = _host_stack(arrived)
        return trimmed_mean(stacked, w, global_params, self.trim)


@register_scheme("opt_median")
class OptMedianScheme(OptScheme):
    """OPT with a coordinate-wise median aggregate — the max-breakdown
    member of the robust family (tolerates just under half the uploads
    being arbitrary)."""

    def aggregate(self, params, contribs, snapshots, has_snap, arrived, *,
                  delayed=None, delayed_mask=None, async_weight: float = 0.0,
                  k_carry: int = 0):
        rescued = (~arrived) & has_snap
        contrib = tree_where_k(arrived, contribs, snapshots)
        weights = (arrived | rescued).astype(jnp.float32)
        return masked_median(contrib, weights, params), rescued

    def aggregate_host(self, arrived, delayed, global_params,
                       alpha: float = 0.4, a: float = 0.5):
        if not arrived:
            return global_params
        stacked, w = _host_stack(arrived)
        return masked_median(stacked, w, global_params)


@register_scheme("opt_clip")
class OptClipScheme(OptScheme):
    """OPT with adaptive norm clipping: every update's delta is clipped
    to the median valid delta norm before the masked mean — cheap, and
    keeps honest-majority rounds near the plain mean."""

    def aggregate(self, params, contribs, snapshots, has_snap, arrived, *,
                  delayed=None, delayed_mask=None, async_weight: float = 0.0,
                  k_carry: int = 0):
        rescued = (~arrived) & has_snap
        contrib = tree_where_k(arrived, contribs, snapshots)
        weights = (arrived | rescued).astype(jnp.float32)
        return clipped_mean(contrib, weights, params), rescued

    def aggregate_host(self, arrived, delayed, global_params,
                       alpha: float = 0.4, a: float = 0.5):
        if not arrived:
            return global_params
        stacked, w = _host_stack(arrived)
        return clipped_mean(stacked, w, global_params)
