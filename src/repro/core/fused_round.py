"""Fused HSFL communication round — Algorithms 1 & 2 as one device program.

The host-loop reference (``HSFLSimulation._run_round_host``) pays hundreds of
dispatch round-trips per simulated round: per-epoch batch conversion, per-user
``user_tree(i)`` slicing, per-user Python ``OppTransmitter`` probes and an
O(K) aggregation loop.  This module compiles the whole round into a single
jitted function:

  - the K selected users live on a leading stacked axis (one ``vmap``);
  - the e local epochs run as ``lax.scan`` segments inside one jit, with the
    per-user SGD step lowered through the ``kernels/fused_cnn`` forward
    policy — by default the pool-first fused step with a hand-written VJP
    and a closed-form softmax-CE cotangent (bit-identical forward to
    ``cnn.forward_im2col`` at f32; ``kernel="pallas"`` routes the same
    algorithm through the Pallas kernel suite, ``precision="bf16"`` runs
    bf16 compute against f32 master params, ``kernel="im2col"`` restores
    the PR-1 autodiff step);
  - the OPT scheduler (eqs. 14–16: scheduled probes, outage voids, snapshot
    overwrite, τ_extra bookkeeping) runs on-device and branch-free through
    ``opportunistic_sync.snapshot_decision`` — the same algorithmic core the
    multi-pod OppSync feature uses, so Alg. 2 has one implementation;
  - the round ends with a single masked weighted-mean aggregation over the
    K axis (no per-user tree_map loop);
  - every scheme-specific decision (probe schedule, final deadline,
    aggregation) dispatches through a registered ``schemes.Scheme`` object
    — the engine bodies hold no per-scheme string branches, so registered
    schemes (incl. beyond-paper ones like ``deadline``) compile here
    unchanged;
  - with ``use_codec`` the snapshot state is the int8 delta-codec payload
    (kernels/delta_codec): probes quantize params−base through the Pallas
    kernel and rescues dequantize at aggregation, so the rescued
    contribution carries real quantization noise and the eq. 15 payload
    uses the actual int8+scale byte count.

Two round builders share these pieces:

- ``build_fused_round`` — inputs presampled host-side once per round
  (``hsfl._presample_round``): batch tensors of shape (e, K, steps, bs, ...)
  and per-epoch rate/outage tensors, one host→device transfer per round
  instead of e·K.  The probe *schedule* (Alg. 2 line 12 / the manual
  override of Sec. III-B) is static per configuration, so probes are
  compiled only at scheduled epoch boundaries; everything data-dependent
  (outages, τ budget, arrival, rescue, staleness) stays branch-free
  on-device.  This path replays the host numpy RNG streams bit-for-bit
  (the fused-vs-host equivalence contract).
- ``build_device_round`` — the whole control plane on-device: channel/
  mobility from a ``channel_lib.FleetState`` carry, greedy selection via
  ``selection.select_users_jax``, batches gathered in-program, epochs
  scanned, eval in-program.  This is the round the sweep engine
  (``core/sweep``) chains with ``lax.scan`` and vmaps over seeds/configs.
  ``use_codec`` gives it the same int8 snapshot path: the codec state
  (int8 blocks + scales) rides the epoch scan carry, and the derived
  ``compress_ratio`` feeds selection, τ budgeting and byte metrics.
"""
from __future__ import annotations

from dataclasses import replace as _dc_replace
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.channel_lib import (ChannelParams, FleetState,
                                    fleet_move, fleet_outage_step,
                                    fleet_rates, fleet_resample_fading)
from repro.core.opportunistic_sync import snapshot_decision
from repro.core.schemes import (get_scheme, kx as _kx,
                                masked_mean as _masked_mean,  # noqa: F401
                                probe_schedule_mask,
                                tree_where_k as _tree_where_k)
from repro.kernels.delta_codec.kernel import (BLOCK, dequantize_blocks,
                                              quantize_blocks)
from repro.kernels.delta_codec.ops import stacked_flatten, stacked_unflatten
from repro.kernels.fused_cnn.ops import (ForwardPolicy, make_eval_forward,
                                         make_stacked_epoch_fn,
                                         resolve_train_step)
from repro.training.loss import accuracy, cross_entropy

__all__ = ["RoundStats", "DeviceSimCarry", "DeviceRoundMetrics",
           "build_fused_round", "build_device_round", "probe_schedule_mask"]


class RoundStats(NamedTuple):
    """Per-user round outcome, device-resident until the host reads it."""
    arrived: jnp.ndarray     # (K,) bool — final upload made it (Alg. 2 l. 14)
    rescued: jnp.ndarray     # (K,) bool — snapshot substituted (the rescue)
    delayed: jnp.ndarray     # (K,) bool — carried to next round (async)
    dropped: jnp.ndarray     # (K,) bool — contributed nothing
    opp_sends: jnp.ndarray   # (K,) int32 — opportunistic transmissions sent


def _codec_encode(stacked, params, interpret: bool, block: int = BLOCK,
                  bits: int = 8):
    """Quantize the stacked users' delta vs the round-start global params
    into the int8 codec state ``(q (K, M, block), scales (K, M, 1))``."""
    delta = jax.tree_util.tree_map(lambda s, p: s - p[None], stacked, params)
    flat, _ = stacked_flatten(delta, block=block)
    k, rows, blk = flat.shape
    q, s = quantize_blocks(flat.reshape(k * rows, blk), interpret=interpret,
                           bits=bits)
    return q.reshape(k, rows, blk), s.reshape(k, rows, 1)


def _codec_decode(q, s, stacked_like, params, interpret: bool):
    """Dequantize the codec state back to a stacked params pytree — the
    rescued contribution carries true int8 quantization noise."""
    k, rows, blk = q.shape
    flat = dequantize_blocks(q.reshape(k * rows, blk),
                             s.reshape(k * rows, 1), interpret=interpret)
    delta = stacked_unflatten(flat.reshape(k, rows, blk), stacked_like)
    return jax.tree_util.tree_map(lambda d, p: p[None] + d, delta, params)


def _codec_zero_state(stacked, block: int = BLOCK):
    """All-zero codec state shaped for ``stacked`` (decodes to the global
    params; never aggregated before a probe succeeds — ``has_snap`` gates)."""
    flat, _ = stacked_flatten(stacked, block=block)
    return (jnp.zeros(flat.shape, jnp.int8),
            jnp.zeros(flat.shape[:2] + (1,), jnp.float32))


def _resolve_epoch_fns(forward: Any, lr: float, interpret: bool
                       ) -> Tuple[Callable, Callable]:
    """``(epoch_all, eval_fwd)`` for the round builders.

    Policy forwards (``ForwardPolicy`` or ``None`` → default xla/f32) get
    the *stacked-cohort* epoch (``ops.make_stacked_epoch_fn``): the K-user
    axis lives inside the blocked kernels — one batched ``dot_general``
    (xla) or one ``block_k``-tiled kernel launch (pallas) per layer per
    step — instead of ``jax.vmap`` rewriting each tiny per-user kernel
    into K grid programs.  Legacy bare callables (tests pushing non-CNN
    models through the round) keep the vmapped per-user epoch."""
    if forward is None or isinstance(forward, ForwardPolicy):
        policy = forward if forward is not None else ForwardPolicy()
        policy = _dc_replace(policy,
                             interpret=policy.interpret or interpret)
        policy.validate()
        return (make_stacked_epoch_fn(policy, lr),
                make_eval_forward(policy))
    loss_grad, fwd_eval = resolve_train_step(forward, interpret)
    return jax.vmap(_make_epoch_fn(loss_grad, lr)), fwd_eval


def _make_epoch_fn(loss_grad: Callable, lr: float) -> Callable:
    """One local epoch for one user: scan of SGD steps (Alg. 1 l. 8).

    ``loss_grad`` is the policy-resolved fused training step
    (``kernels/fused_cnn.make_loss_grad``): under the default policy the
    hand-written backward (plus the closed-form softmax-CE cotangent)
    replaces autodiff, and under ``precision="bf16"`` it computes in bf16
    internally while keeping the loss and the returned grads f32 — so the
    master params this scan carries and the SGD update stay f32 regardless
    of the compute precision."""
    def epoch_fn(params, xs, ys):
        def step(p, batch):
            bx, by = batch
            _, g = loss_grad(p, bx, by)
            p = jax.tree_util.tree_map(lambda w, gg: w - lr * gg, p, g)
            return p, ()

        params, _ = jax.lax.scan(step, params, (xs, ys))
        return params

    return epoch_fn


def build_fused_round(*, scheme: Any, local_epochs: int, steps_per_epoch: int,
                      lr: float, tau_max: float, probe_epochs: Tuple[int, ...],
                      async_weight: float = 0.0, use_codec: bool = False,
                      interpret: bool = False, k_carry: int = 0,
                      forward: Any = None, codec_block: int = BLOCK,
                      codec_bits: int = 8,
                      stacked_sharding: Any = None) -> Callable:
    """Compile one HSFL round for a fixed (scheme, e, steps, schedule).

    Returns ``round_fn(params, xs, ys, chan)`` for opt/discard, or
    ``round_fn(params, delayed_stack, delayed_mask, xs, ys, chan)`` for
    async (``delayed_stack`` leaves are (k_carry, ...)).  ``chan`` is a dict
    of device arrays: rates/outages (e, K), payload_bits/tau_extra0/
    final_rate/train_time (K,), final_outage/valid (K,) bool.  The result is
    ``(new_params, stats)`` plus ``new_delayed_stack`` for async.

    ``scheme`` is a registered ``schemes.Scheme`` (or its name): its
    ``final_slack`` shapes the arrival predicate and its ``aggregate``
    merges the round — the engine body holds no per-scheme branches beyond
    the static ``carries_delayed`` signature split.  ``forward`` is a
    ``kernels/fused_cnn.ForwardPolicy`` (or ``None`` for
    the default xla/f32 policy; a bare callable is a legacy hook used by
    tests that push non-CNN models through the round).  The round carries
    are **donated**: the caller's ``params`` (and, for async, the straggler
    ``delayed_stack``/``delayed_mask``) buffers alias the returned ones, so
    chaining rounds the way ``HSFLSimulation`` does stops copying the full
    parameter state every dispatch — do not reuse those arrays after the
    call.  ``codec_block``/``codec_bits`` are the delta-codec quantization
    group width and bit depth (``HSFLConfig.codec_block``/``codec_bits``).
    """
    epoch_all, _ = _resolve_epoch_fns(forward, lr, interpret)
    scheme = get_scheme(scheme)

    if scheme.carries_delayed and k_carry < 1:
        raise ValueError(
            f"{scheme.name} build_fused_round needs k_carry >= 1 (the fixed "
            f"width of the straggler carry), got k_carry={k_carry}")

    def _train_and_probe(params, xs, ys, chan):
        k = chan["valid"].shape[0]
        stacked = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (k,) + a.shape), params)
        if stacked_sharding is not None:
            # spread the user axis over host devices (bench/multi-core runs):
            # without the constraint XLA keeps the broadcast replicated and
            # every device would redo the whole K-stack of work
            stacked = jax.lax.with_sharding_constraint(stacked,
                                                       stacked_sharding)
        tau_extra = chan["tau_extra0"]
        has_snap = jnp.zeros((k,), bool)
        nsent = jnp.zeros((k,), jnp.int32)
        snap = (_codec_zero_state(stacked, codec_block) if use_codec
                else stacked)

        # epochs advance in lockstep; the probe schedule is static, so the
        # OPT transmission logic is only compiled at scheduled boundaries
        for e_t in range(1, local_epochs + 1):
            stacked = epoch_all(stacked, xs[e_t - 1], ys[e_t - 1])
            if e_t in probe_epochs:
                rate = chan["rates"][e_t - 1]
                outage = chan["outages"][e_t - 1]
                tau = chan["payload_bits"] / jnp.maximum(rate, 1e-9)  # eq. 15
                ok, tau_extra = snapshot_decision(chan["valid"], outage,
                                                  tau, tau_extra)
                if use_codec:
                    q_new, s_new = _codec_encode(stacked, params, interpret,
                                                 codec_block, codec_bits)
                    snap = (jnp.where(_kx(ok, q_new), q_new, snap[0]),
                            jnp.where(_kx(ok, s_new), s_new, snap[1]))
                else:
                    snap = _tree_where_k(ok, stacked, snap)
                has_snap = has_snap | ok
                nsent = nsent + ok.astype(jnp.int32)
        return stacked, snap, has_snap, nsent

    def _final_arrival(chan):
        tau_f = chan["payload_bits"] / jnp.maximum(chan["final_rate"], 1e-9)
        fits = chan["train_time"] + scheme.final_slack(chan["tau_extra0"]) \
            + tau_f <= tau_max
        return chan["valid"] & (~chan["final_outage"]) & fits

    def _round_sync(params, stacked, snap, has_snap, arrived):
        """Scheme aggregation: masked mean over finals (+ rescues)."""
        if scheme.uses_probes and use_codec:
            snap = _codec_decode(snap[0], snap[1], stacked, params,
                                 interpret)
        return scheme.aggregate(params, stacked, snap, has_snap, arrived)

    if not scheme.carries_delayed:

        def round_fn(params, xs, ys, chan):
            stacked, snap, has_snap, nsent = _train_and_probe(
                params, xs, ys, chan)
            arrived = _final_arrival(chan)
            new_params, rescued = _round_sync(params, stacked, snap,
                                              has_snap, arrived)
            delayed = scheme.delayed_out(chan["valid"], arrived)
            dropped = chan["valid"] & ~arrived & ~rescued & ~delayed
            return new_params, RoundStats(arrived, rescued, delayed,
                                          dropped, nsent)

        # params -> new_params aliases in place: the round loop stops
        # copying the global model every dispatch
        return jax.jit(round_fn, donate_argnums=(0,))

    # -- staleness-carrying schemes (async): the straggler stack/mask ride
    #    the round signature and the scheme's aggregate merges them --------
    aw = float(async_weight)

    def round_fn(params, delayed_stack, delayed_mask, xs, ys, chan):
        k = chan["valid"].shape[0]
        if k > k_carry:
            raise ValueError(
                f"{scheme.name} round got K={k} stacked users but the "
                f"straggler carry is only k_carry={k_carry} wide; "
                f"build_fused_round needs k_carry >= the padded user bucket "
                f"K (pass k_carry=k_select as HSFLSimulation does)")
        stacked, _, _, nsent = _train_and_probe(params, xs, ys, chan)
        arrived = _final_arrival(chan)
        delayed_new = scheme.delayed_out(chan["valid"], arrived)
        new_params, rescued = scheme.aggregate(
            params, stacked, None, None, arrived, delayed=delayed_stack,
            delayed_mask=delayed_mask, async_weight=aw, k_carry=k_carry)

        # next-round carry, padded to the fixed k_carry width
        pad = k_carry - k
        carry_stack = jax.tree_util.tree_map(
            lambda s: jnp.pad(s, ((0, pad),) + ((0, 0),) * (s.ndim - 1)),
            stacked)
        carry_mask = jnp.pad(delayed_new, (0, pad))
        dropped = chan["valid"] & ~arrived & ~rescued & ~delayed_new
        return (new_params, carry_stack, carry_mask,
                RoundStats(arrived, rescued, delayed_new, dropped, nsent))

    # params + the (k_carry, ...) straggler stack/mask alias their outputs:
    # the async chain stops copying the full per-user parameter stack
    # every round
    return jax.jit(round_fn, donate_argnums=(0, 1, 2))


# ---------------------------------------------------------------------------
# Fully on-device round: FleetState carry, channel realized in-program
# ---------------------------------------------------------------------------

class DeviceSimCarry(NamedTuple):
    """lax.scan carry for a whole simulation (core/sweep.py).

    ``delayed``/``delayed_mask`` are the async straggler carry; for
    opt/discard they ride along untouched (zeros) so every scheme scans with
    one carry structure."""
    params: Any
    fleet: FleetState
    delayed: Any             # stacked (K, ...) params pytree
    delayed_mask: jnp.ndarray   # (K,) bool


class DeviceRoundMetrics(NamedTuple):
    """Per-round scalars, device-resident until the sweep finishes."""
    selected: jnp.ndarray    # int32 — users scheduled this round
    arrived: jnp.ndarray     # int32 — finals that made it (Alg. 2 l. 14)
    rescued: jnp.ndarray     # int32 — snapshot substitutions
    delayed: jnp.ndarray     # int32 — carried to next round (async)
    dropped: jnp.ndarray     # int32 — contributed nothing
    bytes_sent: jnp.ndarray  # float32 — uplink bytes this round
    test_loss: jnp.ndarray   # float32
    test_acc: jnp.ndarray    # float32


def build_device_round(*, scheme: Any, local_epochs: int,
                       steps_per_epoch: int, batch_size: int, lr: float,
                       k_select: int, channel: ChannelParams,
                       model_bytes: float, ue_model_fraction: float,
                       compress_ratio: float = 1.0,
                       use_codec: bool = False, interpret: bool = False,
                       speed_mps: float = 15.0, epoch_seconds: float = 1.0,
                       schedule_override: Tuple[int, ...] = (),
                       async_alpha: float = 0.4, async_a: float = 0.5,
                       max_sl: int | None = None,
                       act_bytes_per_sample: float = 3136.0,
                       codec_block: int = BLOCK, codec_bits: int = 8,
                       forward: Any = None) -> Callable:
    """One HSFL round with the *entire* control plane on-device.

    Unlike ``build_fused_round`` (which consumes host-presampled channel
    tensors so it can replay the numpy reference stream bit-for-bit), this
    round takes a ``channel_lib.FleetState`` in its carry and realizes fleet
    movement, Rician rates and the Gilbert–Elliott outage chain in-program,
    selects users with ``select_users_jax``, and gathers training batches
    from the stacked client datasets by on-device indices — so whole
    simulations chain under ``lax.scan`` and whole sweeps under ``vmap``
    (core/sweep.py) with zero host round trips.

    ``use_codec`` (probing schemes) stores snapshots as the int8/int4
    delta-codec state (``kernels/delta_codec``): scheduled probes quantize
    params − round-start-global through the Pallas kernel into a
    ``(K, M, BLOCK)`` int8 + per-block-scale carry that rides the epoch
    ``lax.scan``, and rescues dequantize at aggregation, so a rescued
    contribution carries true quantization noise.  ``compress_ratio``
    (derive it from ``delta_codec.ops.codec_ratio`` when the codec is on)
    scales the eq. 15 ``payload_bits`` — and, through them, the
    ``select_users_jax`` latency/energy accounting, the eq. 14 τ_extra
    budget, the final-arrival τ and the wire-byte metrics.  Everything is
    a ``where`` over the traced ``b``/``tau_max``/``bandwidth_ratio``
    config axes, so codec grids vmap/shard exactly like uncompressed ones.

    Returns ``round_fn(carry, round_key, sim, cfg) -> (carry, metrics)``:

    - ``carry``: ``DeviceSimCarry`` (global params, fleet, async stragglers);
    - ``round_key``: per-round PRNG key (batch index stream);
    - ``sim``: per-simulation constants — ``client_x`` (N, M, ...),
      ``client_y`` (N, M), ``client_len``/``flops``/``samples`` (N,),
      ``test_x``/``test_y``;
    - ``cfg``: traced scalars ``b``/``tau_max``/``bandwidth_ratio`` — the
      vmappable config axes of a sweep.

    RNG streams (fleet state + batch indices) are jax.random, not the host
    numpy generators: device runs are seeded and self-consistent but not
    bit-identical to the host reference (see EXPERIMENTS.md).

    ``forward`` is a ``kernels/fused_cnn.ForwardPolicy`` (``None`` → the
    default xla/f32 policy): local training runs through its custom-VJP
    training step, in-program eval through its (value-identical) plain
    forward.  ``codec_block`` is the quantization group width of the
    delta-codec snapshot carry.  The returned ``round_fn`` is *unjitted* —
    the sweep engine scans it and donates the whole ``DeviceSimCarry``
    (params, fleet, stragglers) at its own jit boundary.
    """
    epoch_all, fwd_eval = _resolve_epoch_fns(forward, lr, interpret)
    scheme = get_scheme(scheme)
    aw = float(async_alpha) * 2.0 ** (-float(async_a))
    # the codec (or a manual compress_ratio) shrinks every model payload on
    # the wire, so the *effective* bytes drive selection feasibility/energy
    # (eqs. 9–13), the eq. 14/15 τ budgets and the byte metrics alike
    eff_model_bytes = model_bytes * compress_ratio
    eff_ue_bytes = eff_model_bytes * ue_model_fraction
    use_codec = bool(use_codec) and scheme.supports_codec
    K = k_select
    p = channel

    def round_fn(carry: DeviceSimCarry, rkey, sim: Dict[str, Any],
                 cfg: Dict[str, Any]):
        params, fleet = carry.params, carry.fleet
        b, tau_max = cfg["b"], cfg["tau_max"]
        bw = cfg.get("bandwidth_ratio", 1.0)

        # -- schedule (Alg. 1 l. 3-5): fresh fading, greedy selection -------
        fleet = fleet_resample_fading(fleet, p)
        rates0 = fleet_rates(fleet, p, bw)
        sel, mode_sl, valid, n_taken, tt_fl, tt_sl = scheme.selection_policy(
            rates0, sim["flops"], sim["samples"], b=b, tau_max=tau_max,
            k_select=K, model_bytes=eff_model_bytes,
            ue_model_bytes=eff_ue_bytes,
            local_epochs=local_epochs, max_sl=max_sl,
            act_bytes_per_sample=act_bytes_per_sample)
        train_time = jnp.where(mode_sl, tt_sl[sel], tt_fl[sel])
        train_time = jnp.where(valid, train_time, 1e9)
        payload_bits = jnp.where(mode_sl, eff_ue_bytes, eff_model_bytes) \
            * 8.0                                              # eq. (15) m_i
        tau_extra0 = jnp.maximum(b - 1.0, 0.0) * payload_bits \
            / jnp.maximum(rates0[sel], 1e-9)                   # eq. (14)

        # -- local training: epochs in lockstep, channel drifts per epoch.
        # Epochs run as a lax.scan (one compiled epoch body — measurably
        # faster than the unrolled python loop on CPU and ~e× smaller to
        # compile, which matters when a sweep compiles 3 scheme programs).
        # Probes therefore run masked every epoch via probe_schedule_mask
        # (the schedule depends on the *traced* budget b anyway).
        stacked = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (K,) + a.shape), params)
        clen = jnp.maximum(sim["client_len"][sel], 1)
        xshape = sim["client_x"].shape[2:]
        override = (jnp.asarray(schedule_override, jnp.int32)
                    if schedule_override else None)

        def epoch_body(carry_e, e_t):
            fleet, stacked, snap, has_snap, nsent, tau_extra = carry_e
            fleet = fleet_move(fleet, p, speed_mps, epoch_seconds)
            rate_e = fleet_rates(fleet, p, bw)[sel]
            fleet, bad = fleet_outage_step(fleet, p)
            out_e = bad[sel]
            idx = jax.random.randint(
                jax.random.fold_in(rkey, e_t),
                (K, steps_per_epoch * batch_size), 0, clen[:, None])
            # one fused (user, sample) gather — never materializes the
            # (K, M, ...) per-round client slice under a config vmap
            xs = sim["client_x"][sel[:, None], idx].reshape(
                (K, steps_per_epoch, batch_size) + xshape)
            ys = sim["client_y"][sel[:, None], idx].reshape(
                (K, steps_per_epoch, batch_size))
            stacked = epoch_all(stacked, xs, ys)
            if scheme.uses_probes:
                sched = scheme.probe_schedule(e_t, local_epochs, b,
                                              override=override)
                tau = payload_bits / jnp.maximum(rate_e, 1e-9)   # eq. (15)
                ok, tau_extra = snapshot_decision(valid & sched, out_e,
                                                  tau, tau_extra)
                if use_codec:
                    # the snapshot carry is the int8 payload itself, so the
                    # epoch scan carries ~4x fewer snapshot bytes and the
                    # rescue later decodes with true quantization noise
                    q_new, s_new = _codec_encode(stacked, params, interpret,
                                                 codec_block, codec_bits)
                    snap = (jnp.where(_kx(ok, q_new), q_new, snap[0]),
                            jnp.where(_kx(ok, s_new), s_new, snap[1]))
                else:
                    snap = _tree_where_k(ok, stacked, snap)
                has_snap = has_snap | ok
                nsent = nsent + ok.astype(jnp.int32)
            return (fleet, stacked, snap, has_snap, nsent, tau_extra), ()

        snap0 = (_codec_zero_state(stacked, codec_block) if use_codec
                 else stacked)
        carry_e = (fleet, stacked, snap0, jnp.zeros((K,), bool),
                   jnp.zeros((K,), jnp.int32), tau_extra0)
        carry_e, _ = jax.lax.scan(epoch_body, carry_e,
                                  jnp.arange(1, local_epochs + 1))
        fleet, stacked, snap, has_snap, nsent, _ = carry_e

        # -- final upload (Alg. 2 l. 14): no extra move -----------------------
        rate_f = fleet_rates(fleet, p, bw)[sel]
        fleet, bad_f = fleet_outage_step(fleet, p)
        tau_f = payload_bits / jnp.maximum(rate_f, 1e-9)
        fits = train_time + scheme.final_slack(tau_extra0) + tau_f <= tau_max
        arrived = valid & (~bad_f[sel]) & fits

        # -- aggregation (registry dispatch — no scheme branches) -------------
        if use_codec:
            snap = _codec_decode(snap[0], snap[1], stacked, params,
                                 interpret)
        new_params, rescued = scheme.aggregate(
            params, stacked, snap, has_snap, arrived, delayed=carry.delayed,
            delayed_mask=carry.delayed_mask, async_weight=aw, k_carry=K)
        delayed_new = scheme.delayed_out(valid, arrived)
        dropped = valid & ~arrived & ~rescued & ~delayed_new
        if scheme.carries_delayed:
            new_carry = DeviceSimCarry(new_params, fleet, stacked,
                                       delayed_new)
        else:
            new_carry = DeviceSimCarry(new_params, fleet, carry.delayed,
                                       carry.delayed_mask)

        # -- byte accounting + eval ------------------------------------------
        events = nsent + arrived.astype(jnp.int32)
        bytes_sent = jnp.sum(jnp.where(valid,
                                       payload_bits / 8.0 * events, 0.0))
        act = act_bytes_per_sample * sim["samples"][sel]
        bytes_sent = bytes_sent + jnp.sum(
            jnp.where(valid & mode_sl & (events > 0), act, 0.0))
        logits = fwd_eval(new_params, sim["test_x"])
        metrics = DeviceRoundMetrics(
            selected=n_taken,
            arrived=jnp.sum(arrived.astype(jnp.int32)),
            rescued=jnp.sum(rescued.astype(jnp.int32)),
            delayed=jnp.sum(delayed_new.astype(jnp.int32)),
            dropped=jnp.sum(dropped.astype(jnp.int32)),
            bytes_sent=bytes_sent.astype(jnp.float32),
            test_loss=cross_entropy(logits, sim["test_y"]),
            test_acc=accuracy(logits, sim["test_y"]))
        return new_carry, metrics

    return round_fn
