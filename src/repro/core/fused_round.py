"""Fused HSFL communication round — Algorithms 1 & 2 as one device program.

The host-loop reference (``HSFLSimulation._run_round_host``) pays hundreds of
dispatch round-trips per simulated round: per-epoch batch conversion, per-user
``user_tree(i)`` slicing, per-user Python ``OppTransmitter`` probes and an
O(K) aggregation loop.  This module compiles the whole round into a single
jitted function:

  - the K selected users live on a leading stacked axis (one ``vmap``);
  - the e local epochs run as ``lax.scan`` segments inside one jit, with the
    per-user SGD step lowered through ``cnn.forward_im2col`` (matmul
    convolutions — ~4x faster than the vmapped ``conv_general_dilated``
    lowering on CPU);
  - the OPT scheduler (eqs. 14–16: scheduled probes, outage voids, snapshot
    overwrite, τ_extra bookkeeping) runs on-device and branch-free through
    ``opportunistic_sync.snapshot_decision`` — the same algorithmic core the
    multi-pod OppSync feature uses, so Alg. 2 has one implementation;
  - the round ends with a single masked weighted-mean aggregation over the
    K axis (no per-user tree_map loop);
  - with ``use_codec`` the snapshot state is the int8 delta-codec payload
    (kernels/delta_codec): probes quantize params−base through the Pallas
    kernel and rescues dequantize at aggregation, so the rescued
    contribution carries real quantization noise and the eq. 15 payload
    uses the actual int8+scale byte count.

Inputs are presampled host-side once per round (``hsfl._presample_round``):
batch tensors of shape (e, K, steps, bs, ...) and per-epoch rate/outage
tensors — one host→device transfer per round instead of e·K.

The probe *schedule* (Alg. 2 line 12 / the manual override of Sec. III-B) is
static per configuration, so probes are compiled only at scheduled epoch
boundaries; everything data-dependent (outages, τ budget, arrival, rescue,
staleness) stays branch-free on-device.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.opportunistic_sync import snapshot_decision
from repro.kernels.delta_codec.kernel import dequantize_blocks, quantize_blocks
from repro.kernels.delta_codec.ops import stacked_flatten, stacked_unflatten
from repro.models import cnn as cnn_mod
from repro.training.loss import cross_entropy


class RoundStats(NamedTuple):
    """Per-user round outcome, device-resident until the host reads it."""
    arrived: jnp.ndarray     # (K,) bool — final upload made it (Alg. 2 l. 14)
    rescued: jnp.ndarray     # (K,) bool — snapshot substituted (the rescue)
    delayed: jnp.ndarray     # (K,) bool — carried to next round (async)
    dropped: jnp.ndarray     # (K,) bool — contributed nothing
    opp_sends: jnp.ndarray   # (K,) int32 — opportunistic transmissions sent


def _kx(flags: jnp.ndarray, leaf: jnp.ndarray) -> jnp.ndarray:
    """Broadcast a (K,) flag vector against a (K, ...) leaf."""
    return flags.reshape(flags.shape + (1,) * (leaf.ndim - 1))


def _tree_where_k(flags, a, b):
    return jax.tree_util.tree_map(
        lambda x, y: jnp.where(_kx(flags, x), x, y), a, b)


def _masked_mean(contrib, weights, fallback):
    """Σ_i w_i·x_i / Σ_i w_i over the K axis; ``fallback`` when Σ w = 0."""
    num = jnp.sum(weights)
    return jax.tree_util.tree_map(
        lambda c, p: jnp.where(
            num > 0,
            jnp.sum(c * _kx(weights, c), axis=0) / jnp.maximum(num, 1.0), p),
        contrib, fallback)


def build_fused_round(*, scheme: str, local_epochs: int, steps_per_epoch: int,
                      lr: float, tau_max: float, probe_epochs: Tuple[int, ...],
                      async_weight: float = 0.0, use_codec: bool = False,
                      interpret: bool = False, k_carry: int = 0,
                      forward: Callable = None,
                      stacked_sharding: Any = None) -> Callable:
    """Compile one HSFL round for a fixed (scheme, e, steps, schedule).

    Returns ``round_fn(params, xs, ys, chan)`` for opt/discard, or
    ``round_fn(params, delayed_stack, delayed_mask, xs, ys, chan)`` for
    async (``delayed_stack`` leaves are (k_carry, ...)).  ``chan`` is a dict
    of device arrays: rates/outages (e, K), payload_bits/tau_extra0/
    final_rate/train_time (K,), final_outage/valid (K,) bool.  The result is
    ``(new_params, stats)`` plus ``new_delayed_stack`` for async.
    """
    fwd = forward or cnn_mod.forward_im2col
    if scheme not in ("opt", "discard", "async"):
        raise ValueError(scheme)

    def epoch_fn(params, xs, ys):
        """One local epoch for one user: scan of SGD steps (Alg. 1 l. 8)."""
        def step(p, batch):
            bx, by = batch

            def loss(q):
                return cross_entropy(fwd(q, bx), by)

            g = jax.grad(loss)(p)
            p = jax.tree_util.tree_map(lambda w, gg: w - lr * gg, p, g)
            return p, ()

        params, _ = jax.lax.scan(step, params, (xs, ys))
        return params

    epoch_all = jax.vmap(epoch_fn)

    def _encode(stacked, params):
        delta = jax.tree_util.tree_map(lambda s, p: s - p[None],
                                       stacked, params)
        flat, _ = stacked_flatten(delta)
        k, rows, blk = flat.shape
        q, s = quantize_blocks(flat.reshape(k * rows, blk),
                               interpret=interpret)
        return q.reshape(k, rows, blk), s.reshape(k, rows, 1)

    def _decode(q, s, stacked_like, params):
        k, rows, blk = q.shape
        flat = dequantize_blocks(q.reshape(k * rows, blk),
                                 s.reshape(k * rows, 1),
                                 interpret=interpret)
        delta = stacked_unflatten(flat.reshape(k, rows, blk), stacked_like)
        return jax.tree_util.tree_map(lambda d, p: p[None] + d, delta, params)

    def _train_and_probe(params, xs, ys, chan):
        k = chan["valid"].shape[0]
        stacked = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (k,) + a.shape), params)
        if stacked_sharding is not None:
            # spread the user axis over host devices (bench/multi-core runs):
            # without the constraint XLA keeps the broadcast replicated and
            # every device would redo the whole K-stack of work
            stacked = jax.lax.with_sharding_constraint(stacked,
                                                       stacked_sharding)
        tau_extra = chan["tau_extra0"]
        has_snap = jnp.zeros((k,), bool)
        nsent = jnp.zeros((k,), jnp.int32)
        if use_codec:
            flat, _ = stacked_flatten(stacked)
            snap = (jnp.zeros(flat.shape, jnp.int8),
                    jnp.zeros(flat.shape[:2] + (1,), jnp.float32))
        else:
            snap = stacked

        # epochs advance in lockstep; the probe schedule is static, so the
        # OPT transmission logic is only compiled at scheduled boundaries
        for e_t in range(1, local_epochs + 1):
            stacked = epoch_all(stacked, xs[e_t - 1], ys[e_t - 1])
            if e_t in probe_epochs:
                rate = chan["rates"][e_t - 1]
                outage = chan["outages"][e_t - 1]
                tau = chan["payload_bits"] / jnp.maximum(rate, 1e-9)  # eq. 15
                ok, tau_extra = snapshot_decision(chan["valid"], outage,
                                                  tau, tau_extra)
                if use_codec:
                    q_new, s_new = _encode(stacked, params)
                    snap = (jnp.where(_kx(ok, q_new), q_new, snap[0]),
                            jnp.where(_kx(ok, s_new), s_new, snap[1]))
                else:
                    snap = _tree_where_k(ok, stacked, snap)
                has_snap = has_snap | ok
                nsent = nsent + ok.astype(jnp.int32)
        return stacked, snap, has_snap, nsent

    def _final_arrival(chan):
        tau_f = chan["payload_bits"] / jnp.maximum(chan["final_rate"], 1e-9)
        fits = chan["train_time"] + tau_f <= tau_max
        return chan["valid"] & (~chan["final_outage"]) & fits

    def _round_sync(params, stacked, snap, has_snap, arrived, chan):
        """opt/discard aggregation: masked mean over finals (+ rescues)."""
        if scheme == "opt":
            rescued = chan["valid"] & (~arrived) & has_snap
            if use_codec:
                snap_tree = _decode(snap[0], snap[1], stacked, params)
            else:
                snap_tree = snap
            contrib = _tree_where_k(arrived, stacked, snap_tree)
            weights = (arrived | rescued).astype(jnp.float32)
        else:
            rescued = jnp.zeros_like(arrived)
            contrib = stacked
            weights = arrived.astype(jnp.float32)
        return _masked_mean(contrib, weights, params), rescued

    if scheme in ("opt", "discard"):

        def round_fn(params, xs, ys, chan):
            stacked, snap, has_snap, nsent = _train_and_probe(
                params, xs, ys, chan)
            arrived = _final_arrival(chan)
            new_params, rescued = _round_sync(params, stacked, snap,
                                              has_snap, arrived, chan)
            delayed = jnp.zeros_like(arrived)
            dropped = chan["valid"] & ~arrived & ~rescued
            return new_params, RoundStats(arrived, rescued, delayed,
                                          dropped, nsent)

        return jax.jit(round_fn)

    # -- async: timely finals at weight 1, prior-round stragglers at
    #    α(s+1)^(−a); a round with only stragglers falls back to the
    #    sequential FedAsync server merge (never a full replace) ------------
    aw = float(async_weight)

    def round_fn(params, delayed_stack, delayed_mask, xs, ys, chan):
        stacked, _, _, nsent = _train_and_probe(params, xs, ys, chan)
        arrived = _final_arrival(chan)
        delayed_new = chan["valid"] & ~arrived

        w_t = arrived.astype(jnp.float32)                      # (K,)
        w_d = delayed_mask.astype(jnp.float32) * aw            # (k_carry,)
        n_arr = jnp.sum(w_t)
        total = n_arr + jnp.sum(w_d)
        mixed = jax.tree_util.tree_map(
            lambda s, d, p: jnp.where(
                total > 0,
                (jnp.sum(s * _kx(w_t, s), axis=0)
                 + jnp.sum(d * _kx(w_d, d), axis=0))
                / jnp.maximum(total, 1e-9), p),
            stacked, delayed_stack, params)

        seq = params
        for i in range(k_carry):          # static unroll; k_carry is small
            seq = jax.tree_util.tree_map(
                lambda acc, d: jnp.where(delayed_mask[i],
                                         (1.0 - aw) * acc + aw * d[i], acc),
                seq, delayed_stack)
        new_params = jax.tree_util.tree_map(
            lambda a, b: jnp.where(n_arr > 0, a, b), mixed, seq)

        # next-round carry, padded to the fixed k_carry width
        k = chan["valid"].shape[0]
        pad = k_carry - k
        carry_stack = jax.tree_util.tree_map(
            lambda s: jnp.pad(s, ((0, pad),) + ((0, 0),) * (s.ndim - 1)),
            stacked)
        carry_mask = jnp.pad(delayed_new, (0, pad))
        rescued = jnp.zeros_like(arrived)
        dropped = jnp.zeros_like(arrived)
        return (new_params, carry_stack, carry_mask,
                RoundStats(arrived, rescued, delayed_new, dropped, nsent))

    return jax.jit(round_fn)
