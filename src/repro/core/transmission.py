"""Opportunistic-proactive transmission — Algorithm 2's per-client scheduler.

One ``OppTransmitter`` per selected client per round.  It owns the relaxed
budget τ_extra (eq. 14) and decides, at the scheduled local iterations
(e_t % (e/b) == 0), whether the instantaneous channel affords the snapshot
(eqs. 15–16).  A transmission can also be voided by a complete-interruption
outage (Sec. IV: 30%).  The server keeps only the most recent snapshot
("Previous ω_i will be overwritten", Alg. 2 line 14/20).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional

from repro.core import latency as lat


def schedule_period(e: int, b: int) -> int:
    """Probe period e/b of Alg. 2 line 12 — the single source of truth
    shared by the host scheduler, the multi-pod OppSync feature and the
    fused HSFL round."""
    return max(1, round(e / b))


def scheduled_epochs(e: int, b: int) -> List[int]:
    """Local iterations at which Alg. 2 probes the channel: e_t % (e/b) == 0.

    With b transmissions total, (b-1) are intermediate: e_t in
    {e/b, 2e/b, ..., (b-1)e/b} (the final upload at e_t == e is the regular
    end-of-round transmission, not an opportunistic one).
    """
    if b <= 1:
        return []
    period = schedule_period(e, b)
    return [k * period for k in range(1, b) if k * period < e]


@dataclass
class TransmissionEvent:
    epoch: int
    delay_s: float
    payload_bytes: float
    kind: str                       # "opportunistic" | "final"


@dataclass
class OppTransmitter:
    """Per-client, per-round OPT state (Alg. 2, Opportunistic_Transmission)."""
    model_bytes: float
    e: int                          # total local epochs
    b: int                          # transmission budget
    rate0_bps: float                # r_i^0, rate at round start
    compress_ratio: float = 1.0     # <1 when the delta codec shrinks payloads
    schedule_override: tuple = ()   # manual schedule (Sec. III-B: "can be
                                    # manually set by the system")
    tau_extra: float = field(init=False)
    tau_extra0: float = field(init=False)   # initial eq. 14 allowance
    snapshot: Optional[Any] = field(init=False, default=None)
    snapshot_epoch: int = field(init=False, default=-1)
    events: List[TransmissionEvent] = field(init=False, default_factory=list)
    _schedule: tuple = field(init=False)

    def __post_init__(self):
        self.tau_extra = lat.extra_allowance(self.b, self.payload_bytes,
                                             self.rate0_bps)
        # the *budgeted* allowance, kept immutable: deadline-aware schemes
        # charge it against τ_max at the final upload (schemes.final_slack)
        self.tau_extra0 = self.tau_extra
        # cached once: maybe_transmit is called every scheduled epoch and
        # recomputing the schedule there was pure per-call overhead
        self._schedule = (tuple(self.schedule_override) if self.schedule_override
                          else tuple(scheduled_epochs(self.e, self.b)))

    @property
    def payload_bytes(self) -> float:
        return self.model_bytes * self.compress_ratio

    @property
    def schedule(self) -> List[int]:
        return list(self._schedule)

    def maybe_transmit(self, epoch: int, rate_bps: float, outage: bool,
                       params: Any) -> bool:
        """Alg. 2 lines 17–21 at a scheduled epoch.  Returns True if sent.

        ``params`` may be a zero-arg callable, evaluated only once the
        outage/budget checks pass (snapshot materialization — e.g. the
        delta-codec round trip — is not free)."""
        if epoch not in self._schedule:
            return False
        if outage:
            return False
        tau = lat.snapshot_delay(self.payload_bytes, rate_bps)   # eq. (15)
        if tau > self.tau_extra:                                 # cancelled
            return False
        self.tau_extra -= tau                                    # eq. (16)
        self.snapshot = params() if callable(params) else params  # overwrite
        self.snapshot_epoch = epoch
        self.events.append(TransmissionEvent(
            epoch, tau, self.payload_bytes, "opportunistic"))
        return True

    def final_upload(self, rate_bps: float, outage: bool,
                     tau_spent_training: float, tau_max: float) -> bool:
        """End-of-round upload (Alg. 2 line 14).  Fails on outage or if the
        one-round latency including this upload would exceed τ_max."""
        if outage:
            return False
        tau = lat.snapshot_delay(self.payload_bytes, rate_bps)
        if tau_spent_training + tau > tau_max:
            return False
        self.events.append(TransmissionEvent(
            self.e, tau, self.payload_bytes, "final"))
        return True

    @property
    def bytes_sent(self) -> float:
        return sum(ev.payload_bytes for ev in self.events)
