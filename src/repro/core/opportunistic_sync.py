"""OpportunisticSync — the paper's OPT scheme as a multi-pod training feature.

Mapping (DESIGN.md §2): FL clients -> pods running local SGD (DiLoCo-style
local training with round-boundary averaging); the UAV's fluctuating air
interface -> the cross-pod DCN/ICI link, modelled by a per-pod, per-step link
rate trace + outage draws; the BS aggregation -> a masked mean over the
``pod`` mesh axis.

Faithful transliteration of Algorithm 2 onto jax.lax control flow:

  inner step e_t:   if e_t % (e/b) == 0:                 (scheduled probe)
                        τ = payload / rate(e_t)          (eq. 15)
                        if τ <= τ_extra and no outage:
                            snapshot <- params;  τ_extra -= τ   (eq. 16)
  round boundary:   contribution_p = arrived_p ? params_p : snapshot_p
                    ω <- Σ_p valid_p · contribution_p / Σ_p valid_p
                    (pods with neither final nor snapshot are excluded —
                     'discard'; 'async' staleness-weighting is the baseline)

State lives in TrainState's snapshot/snapshot_step/tau_extra slots.  All
per-pod state is stacked on a leading pod axis and the functions run under
``shard_map`` over ``axis``; everything is lax.cond/where — no host round
trips inside a round.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.schemes import get_scheme
from repro.core.transmission import schedule_period as _schedule_period
from repro.models import module as m
from repro.training.train_state import TrainState


@dataclass(frozen=True)
class OppSyncConfig:
    inner_steps: int = 6          # e — local steps per communication round
    budget: int = 2               # b — total transmissions per round
    payload: float = 1.0          # normalized model bytes (m_i)
    rate0: float = 1.0            # budgeting rate r⁰ (eq. 14 denominator)
    outage_prob: float = 0.3
    axis: str = "pod"
    scheme: str = "opt"           # opt | discard | async
    async_alpha: float = 0.4
    async_a: float = 0.5

    @property
    def tau_extra0(self) -> float:
        return (self.budget - 1) * self.payload / self.rate0   # eq. (14)

    def schedule_period(self) -> int:
        return _schedule_period(self.inner_steps, self.budget)


def is_scheduled(cfg: OppSyncConfig, inner_step: jnp.ndarray) -> jnp.ndarray:
    """Alg. 2 line 12: e_t % (e/b) == 0, excluding the final step."""
    if cfg.budget <= 1:
        return jnp.zeros((), bool)
    per = cfg.schedule_period()
    return (inner_step % per == 0) & (inner_step < cfg.inner_steps) \
        & (inner_step > 0)


def snapshot_decision(scheduled: jnp.ndarray, outage: jnp.ndarray,
                      tau: jnp.ndarray, tau_extra: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Alg. 2 lines 17–21 decision core, branch-free and shape-polymorphic.

    Shared single source of truth between the multi-pod OppSync feature
    (scalar per-pod state under shard_map) and the fused HSFL round
    ((K,)-vectors over the stacked-user axis): a scheduled probe succeeds
    iff the channel is up and the instantaneous delay τ (eq. 15) fits the
    remaining allowance; success burns τ from the budget (eq. 16).
    Returns (ok, new_tau_extra).
    """
    ok = scheduled & (~outage) & (tau <= tau_extra)
    return ok, jnp.where(ok, tau_extra - tau, tau_extra)


def maybe_snapshot(cfg: OppSyncConfig, state: TrainState,
                   rate: jnp.ndarray, outage: jnp.ndarray) -> TrainState:
    """Opportunistic_Transmission (Alg. 2 lines 17–21), branch-free."""
    inner = state.step % cfg.inner_steps
    tau = cfg.payload / jnp.maximum(rate, 1e-9)              # eq. (15)
    ok, tau_extra = snapshot_decision(is_scheduled(cfg, inner), outage,
                                      tau, state.tau_extra)
    snapshot = m.tree_where(ok, state.params, state.snapshot)
    return state._replace(
        snapshot=snapshot,
        snapshot_step=jnp.where(ok, state.step, state.snapshot_step),
        tau_extra=tau_extra)


def round_contribution(cfg: OppSyncConfig, state: TrainState,
                       arrived: jnp.ndarray) -> Tuple[Any, jnp.ndarray]:
    """This pod's aggregation payload and validity under the chosen scheme.

    Dispatches through the scheme registry — ``pod_contribution`` is the
    per-pod twin of ``Scheme.aggregate``, so a newly registered scheme is
    picked up here without edits."""
    have_snap = state.snapshot_step >= 0
    return get_scheme(cfg.scheme).pod_contribution(
        state.params, state.snapshot, have_snap, arrived,
        alpha=cfg.async_alpha, a=cfg.async_a)


def round_sync(cfg: OppSyncConfig, state: TrainState,
               arrived: jnp.ndarray) -> TrainState:
    """Round-boundary aggregation across the pod axis (inside shard_map)."""
    contrib, valid = round_contribution(cfg, state, arrived)
    num = jax.lax.psum(valid, cfg.axis)
    summed = jax.tree_util.tree_map(
        lambda x: jax.lax.psum(x * valid, cfg.axis), contrib)
    # divide by the TRUE positive sum: async validity weights are fractional
    # (α(s+1)^(−a) ≈ 0.283), so an all-delayed round has 0 < Σvalid < 1 and
    # clamping the denominator to 1 would silently shrink the aggregate
    # toward zero.  num > 0 still guards the empty round.
    denom = jnp.where(num > 0, num, 1.0)
    new_params = jax.tree_util.tree_map(
        lambda s, p: jnp.where(num > 0, s / denom, p),
        summed, state.params)
    return state._replace(
        params=new_params,
        snapshot=new_params,
        snapshot_step=jnp.asarray(-1, jnp.int32),
        tau_extra=jnp.asarray(cfg.tau_extra0, jnp.float32))


def channel_trace(cfg: OppSyncConfig, key: jax.Array, n_pods: int,
                  rounds: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Simulated per-pod link condition: log-normal rates around rate0 +
    Bernoulli outages, shape (rounds, inner_steps+1, n_pods).  The final slot
    of each round drives the 'arrived' draw for the round-end upload."""
    k1, k2 = jax.random.split(key)
    shape = (rounds, cfg.inner_steps + 1, n_pods)
    rates = cfg.rate0 * jnp.exp(
        0.5 * jax.random.normal(k1, shape, jnp.float32))
    outages = jax.random.uniform(k2, shape) < cfg.outage_prob
    arrived = ~outages[:, -1, :]
    return rates, outages, arrived


def make_opp_sync_round(cfg: OppSyncConfig, train_step: Callable,
                        mesh, state_spec, batch_spec) -> Callable:
    """Build a jitted one-round function under shard_map over the pod axis.

    All TrainState leaves carry a leading pod dim sharded P(axis); batches
    carry (pod, e, local_batch...).  rates/outages: (e+1, n_pods) slices.
    """
    from jax.experimental.shard_map import shard_map

    def one_round(state, batches, rates, outages, arrived):
        # inside shard_map: leading pod dim is local (size 1) — squeeze it
        sq = lambda t: jax.tree_util.tree_map(lambda a: a[0], t)
        ex = lambda t: jax.tree_util.tree_map(lambda a: a[None], t)
        st = sq(state)
        bt = sq(batches)
        rt, ot = rates[:, 0], outages[:, 0]
        arr = arrived[0]

        def inner(st, xs):
            batch, rate, outage = xs
            st, metrics = train_step(st, batch)
            st = maybe_snapshot(cfg, st, rate, outage)
            return st, metrics["loss"]

        st, losses = jax.lax.scan(
            inner, st, (bt, rt[:cfg.inner_steps], ot[:cfg.inner_steps]))
        st = round_sync(cfg, st, arr)
        return ex(st), ex(losses)

    ax = cfg.axis
    smapped = shard_map(
        one_round, mesh=mesh,
        in_specs=(state_spec, batch_spec, P(None, ax), P(None, ax), P(ax)),
        out_specs=(state_spec, P(ax, None)),
        check_rep=False)
    # both callers rebind `state, losses = one_round(state, ...)`, so the
    # old sharded state is safely donated to the new one
    return jax.jit(smapped, donate_argnums=(0,))
