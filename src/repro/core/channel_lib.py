"""Backend-agnostic wireless channel core — Section II-A, eqs. (1)–(7).

One implementation of the Rician/LOS channel math serves two control planes:

- the **host reference** (``core/channel.py``): thin numpy wrappers around
  these functions plus the stateful ``UAVFleet``; semantics (and the RNG
  stream ``tests/test_fused_round.py`` pins) are unchanged.
- the **device path** (``FleetState`` below): the same equations in pure
  ``jnp`` with fleet mobility, per-round Rician-K resampling and the
  Gilbert–Elliott outage chain expressed as a ``lax.scan``-able carry keyed
  on ``jax.random`` — this is what lets a whole simulation (rounds × seeds ×
  configs) compile to one program (``core/sweep.py``).

Every equation function takes ``xp`` (numpy or jax.numpy); unit
interpretations are documented in ``core/channel.py`` / DESIGN.md §2 and are
identical in both backends.  The Gilbert–Elliott transition probabilities
live here as a pure float function (``outage_transitions``) so the numpy
chain and the jax chain cannot drift apart.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Tuple

import numpy as np

C_LIGHT = 299_792_458.0


@dataclass
class ChannelParams:
    """Table I."""
    p_uav_dbm: float = 24.0
    noise_dbm_per_hz: float = -174.0
    k_db_range: Tuple[float, float] = (1.8, 5.0)
    carrier_hz: float = 2.0e9
    bandwidth_uav_hz: float = 10.0e6
    a0: float = 5.0188           # urban environment parameters
    b0: float = 0.3511
    eta_los_db: float = 21.0     # additional path loss LOS   (η_l)
    eta_nlos_db: float = 1.0     # additional path loss NLOS  (η_n)
    outage_prob: float = 0.30    # complete-interruption probability (Sec. IV)
    outage_persistence: float = 0.70   # Gilbert-Elliott stay-bad per epoch
    cell_radius_m: float = 500.0
    bs_height_m: float = 20.0
    uav_z_range: Tuple[float, float] = (20.0, 80.0)


def dbm_to_watt(dbm: float) -> float:
    return 10.0 ** (dbm / 10.0) * 1e-3


def outage_transitions(outage_prob: float,
                       persistence: float) -> Tuple[float, float]:
    """Gilbert–Elliott (go_bad, stay_bad) for a target stationary marginal.

    ``stay_bad`` is the persistence knob; ``go_bad`` is solved from the
    stationary balance π_bad·(1−stay_bad) = (1−π_bad)·go_bad with
    π_bad = outage_prob.  As ``outage_prob → 1`` the solved go_bad exceeds 1
    (the target marginal is unreachable for the given persistence); it is
    clamped to [0, 1] so the chain saturates at its true reachable marginal
    instead of silently comparing uniforms against a probability > 1.
    Shared single source of truth between the numpy ``UAVFleet`` chain and
    the jax ``fleet_outage_step`` chain.
    """
    stay_bad = min(max(float(persistence), 0.0), 1.0)
    go_bad = float(outage_prob) * (1.0 - stay_bad) \
        / max(1.0 - float(outage_prob), 1e-9)
    return min(max(go_bad, 0.0), 1.0), stay_bad


# ---------------------------------------------------------------------------
# eqs. (1)–(7), generic over the array backend (numpy / jax.numpy)
# ---------------------------------------------------------------------------

def distance(pos, bs_height: float, xp=np):
    """eq. (1).  pos: (..., 3) UAV coordinates; BS at (0, 0, z0)."""
    dz = pos[..., 2] - bs_height
    return xp.sqrt(pos[..., 0] ** 2 + pos[..., 1] ** 2 + dz ** 2)


def elevation_deg(pos, bs_height: float, xp=np):
    """eq. (2), degrees in [0, 90)."""
    d = xp.maximum(distance(pos, bs_height, xp), 1e-6)
    return xp.degrees(xp.arcsin(xp.abs(pos[..., 2] - bs_height) / d))


def p_los(theta_deg, p: ChannelParams, xp=np):
    """eq. (3)."""
    return 1.0 / (1.0 + p.a0 * xp.exp(-p.b0 * (theta_deg - p.a0)))


def path_loss_db(pos, p: ChannelParams, xp=np):
    """eq. (4) (negative dB = attenuation): standard Friis FSPL plus the
    P_LOS-weighted Holis–Pechac expected additional loss (calibration
    recorded in DESIGN.md §2 / core/channel.py)."""
    d = xp.maximum(distance(pos, p.bs_height_m, xp), 1.0)
    plos = p_los(elevation_deg(pos, p.bs_height_m, xp), p, xp)
    fspl = 20.0 * xp.log10(4.0 * np.pi * d * p.carrier_hz / C_LIGHT)
    eta_los = min(p.eta_los_db, p.eta_nlos_db)       # LOS suffers less
    eta_nlos = max(p.eta_los_db, p.eta_nlos_db)
    extra = plos * eta_los + (1.0 - plos) * eta_nlos
    return -fspl - extra


def channel_gain(pos, k_db, p: ChannelParams, xp=np):
    """eqs. (5)–(6): linear power gain x expected Rician amplitude (v+s)."""
    k_lin = 10.0 ** (xp.asarray(k_db) / 10.0)
    v = xp.sqrt(k_lin / (k_lin + 1.0))
    s = xp.sqrt(1.0 / (2.0 * (k_lin + 1.0)))
    return 10.0 ** (path_loss_db(pos, p, xp) / 10.0) * (v + s)


def rate_bps(pos, k_db, p: ChannelParams, bandwidth_ratio=1.0, xp=np):
    """eq. (7): Shannon rate in bits/s for allocated bandwidth n_i·B_uav.

    ``bandwidth_ratio`` may be a traced scalar under jax (a sweep axis)."""
    bw = bandwidth_ratio * p.bandwidth_uav_hz
    noise_w = dbm_to_watt(p.noise_dbm_per_hz + 10.0 * xp.log10(bw))
    snr = channel_gain(pos, k_db, p, xp) * dbm_to_watt(p.p_uav_dbm) / noise_w
    return bw * xp.log2(1.0 + snr)


# ---------------------------------------------------------------------------
# Device-side fleet: mobility + fading + outage chain as a scan-able carry
# ---------------------------------------------------------------------------

class FleetState(NamedTuple):
    """On-device UAV fleet state (Section IV dynamics).

    All leaves are device arrays, so a whole simulation can carry the fleet
    through ``lax.scan`` without host round trips; ``key`` is the fleet's
    private ``jax.random`` stream (split-and-consume per transition).  Note
    this stream is *not* the numpy ``UAVFleet`` stream — seeded device runs
    are self-consistent but not bit-identical to the host reference
    (EXPERIMENTS.md, "on-device RNG").
    """
    pos: "jnp.ndarray"      # (N, 3) UAV coordinates
    k_db: "jnp.ndarray"     # (N,) Rician factor, dB
    bad: "jnp.ndarray"      # (N,) bool Gilbert–Elliott outage state
    key: "jnp.ndarray"      # fleet PRNG key


def _jnp():
    import jax.numpy as jnp
    return jnp


def fleet_init(key, n: int, p: ChannelParams) -> FleetState:
    """Mirror of ``UAVFleet.__post_init__``: uniform-in-disk xy, uniform z,
    uniform K, outage state seeded at the stationary marginal."""
    import jax
    jnp = _jnp()
    kr, ka, kz, kk, kb, key = jax.random.split(key, 6)
    r = p.cell_radius_m * jnp.sqrt(jax.random.uniform(kr, (n,)))
    ang = jax.random.uniform(ka, (n,)) * 2.0 * np.pi
    z = jax.random.uniform(kz, (n,), minval=p.uav_z_range[0],
                           maxval=p.uav_z_range[1])
    pos = jnp.stack([r * jnp.cos(ang), r * jnp.sin(ang), z], axis=-1)
    k_db = jax.random.uniform(kk, (n,), minval=p.k_db_range[0],
                              maxval=p.k_db_range[1])
    bad = jax.random.uniform(kb, (n,)) < p.outage_prob
    return FleetState(pos=pos, k_db=k_db, bad=bad, key=key)


def fleet_resample_fading(state: FleetState, p: ChannelParams) -> FleetState:
    """New Rician K per local training round (Sec. IV)."""
    import jax
    kk, key = jax.random.split(state.key)
    k_db = jax.random.uniform(kk, state.k_db.shape, minval=p.k_db_range[0],
                              maxval=p.k_db_range[1])
    return state._replace(k_db=k_db, key=key)


def fleet_move(state: FleetState, p: ChannelParams, speed_mps: float,
               dt: float) -> FleetState:
    """Random-direction step, reflected into the cell (per local epoch)."""
    import jax
    jnp = _jnp()
    ks, key = jax.random.split(state.key)
    step = jax.random.normal(ks, state.pos.shape)
    step = step / jnp.maximum(
        jnp.linalg.norm(step, axis=-1, keepdims=True), 1e-9)
    pos = state.pos + step * speed_mps * dt
    rad = jnp.maximum(jnp.linalg.norm(pos[:, :2], axis=-1), 1e-9)
    scale = jnp.where(rad > p.cell_radius_m, p.cell_radius_m / rad, 1.0)
    pos = pos.at[:, :2].multiply(scale[:, None])
    pos = pos.at[:, 2].set(jnp.clip(pos[:, 2], *p.uav_z_range))
    return state._replace(pos=pos, key=key)


def fleet_outage_step(state: FleetState, p: ChannelParams):
    """Advance the Gilbert–Elliott chain one epoch; returns (state, bad)."""
    import jax
    jnp = _jnp()
    go_bad, stay_bad = outage_transitions(p.outage_prob, p.outage_persistence)
    ku, key = jax.random.split(state.key)
    u = jax.random.uniform(ku, state.bad.shape)
    bad = jnp.where(state.bad, u < stay_bad, u < go_bad)
    return state._replace(bad=bad, key=key), bad


def fleet_rates(state: FleetState, p: ChannelParams,
                bandwidth_ratio=1.0):
    """Current per-UAV uplink rate, bits/s (eq. 7)."""
    return rate_bps(state.pos, state.k_db, p, bandwidth_ratio, xp=_jnp())
