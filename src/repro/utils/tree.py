"""Pytree inspection helpers."""
from __future__ import annotations

from typing import Any

import jax
import numpy as np


def tree_bytes(tree: Any) -> int:
    return sum(np.size(x) * np.dtype(getattr(x, "dtype", np.float32)).itemsize
               if str(getattr(x, "dtype", "")) != "bfloat16"
               else np.size(x) * 2
               for x in jax.tree_util.tree_leaves(tree))


def tree_size(tree: Any) -> int:
    return sum(int(np.size(x)) for x in jax.tree_util.tree_leaves(tree))


def tree_describe(tree: Any, max_leaves: int = 20) -> str:
    lines = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0][:max_leaves]:
        keys = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        lines.append(f"{keys}: {getattr(leaf, 'shape', ())} "
                     f"{getattr(leaf, 'dtype', '')}")
    return "\n".join(lines)
