"""HLO text analysis for the roofline: collective bytes + remat duplication.

``collective_bytes`` parses lowered/compiled HLO text and sums operand sizes
of all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops.  cost_analysis() does not report these, so the §Roofline collective term
comes from here (see the brief's ROOFLINE ANALYSIS).
"""
from __future__ import annotations

import re
from collections import Counter
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  f32[16,128]{1,0}  or  bf16[2,4096,1024]
_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64)"
                       r"\[([0-9,]*)\]")

# line-based: "%name = <type(s)> <collective>(operands...)"; the type may be
# a tuple spanning /*index=N*/ comments, so match everything up to the op
# token rather than excluding characters.
_OP_RE = re.compile(
    r"=\s*(.*?)\s(" + "|".join(_COLLECTIVES) + r")(-start|-done)?\(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective-kind {count, bytes} from HLO text (output shapes).

    '-done' ops are skipped so async pairs aren't double counted."""
    stats: Dict[str, Dict[str, float]] = {
        k: {"count": 0, "bytes": 0.0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        type_str, kind, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":
            continue
        stats[kind]["count"] += 1
        stats[kind]["bytes"] += _shape_bytes(type_str)
    return stats


def collective_bytes(hlo_text: str) -> float:
    return sum(v["bytes"] for v in collective_stats(hlo_text).values())


def duplicate_op_counts(hlo_text: str, top: int = 10) -> Counter:
    """Fusion-name histogram — a quick remat/recompute smell test."""
    names = re.findall(r"%([a-zA-Z0-9_.\-]+?)(?:\.\d+)?\s*=", hlo_text)
    return Counter(names).most_common(top)
