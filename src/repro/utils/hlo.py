"""HLO text analysis: collective bytes, remat duplication, buffer aliasing.

``collective_bytes`` parses lowered/compiled HLO text and sums operand sizes
of all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops.  cost_analysis() does not report these, so the §Roofline collective term
comes from here (see the brief's ROOFLINE ANALYSIS).

``input_output_aliases`` parses the ``input_output_alias={...}`` annotation
off the compiled module header — the ground truth of which donated argument
buffers XLA actually reuses (``analysis/ir/alias_audit`` compares it against
the donation the source claims).  ``compiled_memory_stats`` normalizes
``Compiled.memory_analysis()`` into a plain dict (shared by
``launch/dryrun.py`` and the IR auditor).
"""
from __future__ import annotations

import re
from collections import Counter
from typing import Any, Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  f32[16,128]{1,0}  or  bf16[2,4096,1024]
_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64)"
                       r"\[([0-9,]*)\]")

# line-based: "%name = <type(s)> <collective>(operands...)"; the type may be
# a tuple spanning /*index=N*/ comments, so match everything up to the op
# token rather than excluding characters.
_OP_RE = re.compile(
    r"=\s*(.*?)\s(" + "|".join(_COLLECTIVES) + r")(-start|-done)?\(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective-kind {count, bytes} from HLO text (output shapes).

    '-done' ops are skipped so async pairs aren't double counted."""
    stats: Dict[str, Dict[str, float]] = {
        k: {"count": 0, "bytes": 0.0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        type_str, kind, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":
            continue
        stats[kind]["count"] += 1
        stats[kind]["bytes"] += _shape_bytes(type_str)
    return stats


def collective_bytes(hlo_text: str) -> float:
    return sum(v["bytes"] for v in collective_stats(hlo_text).values())


def duplicate_op_counts(hlo_text: str, top: int = 10) -> Counter:
    """Fusion-name histogram — a quick remat/recompute smell test."""
    names = re.findall(r"%([a-zA-Z0-9_.\-]+?)(?:\.\d+)?\s*=", hlo_text)
    return Counter(names).most_common(top)


# ---------------------------------------------------------------------------
# buffer aliasing + compiled memory stats (IR auditor / dryrun plumbing)
# ---------------------------------------------------------------------------

# module-header annotation, e.g.
#   input_output_alias={ {0}: (0, {}, may-alias), {1}: (2, {}, must-alias) }
_ALIAS_BLOCK_RE = re.compile(r"input_output_alias=\{(.*?)\}\s*(?:,|$)")
_ALIAS_ENTRY_RE = re.compile(
    r"\{\s*([0-9, ]*)\s*\}\s*:\s*\(\s*(\d+)\s*,\s*\{[0-9, ]*\}\s*,\s*"
    r"(may-alias|must-alias)\s*\)")


def input_output_aliases(hlo_text: str) -> List[Dict[str, Any]]:
    """Parsed ``input_output_alias`` entries from a compiled module header.

    Each entry is ``{"output_index": (..) , "parameter": int, "kind": str}``;
    an empty list means XLA aliases nothing — every donated buffer was
    silently dropped."""
    out: List[Dict[str, Any]] = []
    for line in hlo_text.splitlines():
        if "input_output_alias=" not in line:
            continue
        block = _ALIAS_BLOCK_RE.search(line)
        if not block:
            continue
        for oidx, param, kind in _ALIAS_ENTRY_RE.findall(line):
            out.append({
                "output_index": tuple(int(x) for x in oidx.split(",")
                                      if x.strip()),
                "parameter": int(param),
                "kind": kind,
            })
        break                     # the annotation appears once, on the header
    return out


def aliased_parameters(hlo_text: str) -> Tuple[int, ...]:
    """Sorted parameter numbers that alias some output buffer."""
    return tuple(sorted({e["parameter"]
                         for e in input_output_aliases(hlo_text)}))


_MEMORY_FIELDS = ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes", "peak_memory_in_bytes")


def compiled_memory_stats(compiled: Any) -> Dict[str, int]:
    """``Compiled.memory_analysis()`` as a plain dict (absent fields -> 0).

    Some backends return None (no memory analysis); that maps to all-zero
    so callers can always do arithmetic on the result."""
    mem = compiled.memory_analysis()
    return {k: int(getattr(mem, k, 0) or 0) for k in _MEMORY_FIELDS}
