from repro.utils.hlo import collective_bytes, collective_stats
from repro.utils.tree import tree_bytes, tree_describe, tree_size

__all__ = ["collective_bytes", "collective_stats", "tree_bytes",
           "tree_describe", "tree_size"]
