"""``repro.api`` — one ``Experiment`` facade over all three HSFL engines.

Before PR 5 the repo had three divergent entry points — ``run_hsfl`` (the
per-round host-driven loop), ``run_hsfl_on_device`` (single sim on the
device engine) and ``run_sweep`` (whole grids as one program) — each with
its own way of saying *which transmission scheme* to run.  ``Experiment``
is the one front door: a config, a chain of registered schemes (the
``repro.core.schemes`` registry), the grid axes, and an engine choice::

    from repro.api import Experiment

    # one scheme, one seed, the fused single-round program -> SimLog
    log = Experiment(rounds=30).with_scheme("opt", b=2.0).run(engine="fused")

    # a Fig. 3(b)-style panel on the vectorized sweep engine -> SweepResult
    res = (Experiment(rounds=60, distribution="noniid")
           .with_scheme("opt", b=2.0)
           .with_scheme("async", b=1.0)
           .with_scheme("discard", b=1.0)
           .with_seeds(0, 1)
           .run(engine="sweep"))

    # a beyond-paper scheme, same API, any engine
    log = Experiment(rounds=30).with_scheme("deadline", b=3.0).run("fused")

Engines:

  ``loop``   — the host reference control loop (``HSFLSimulation`` with
               ``use_fused_round=False``): Python ``OppTransmitter`` per
               user, numpy RNG streams — the bit-exact reference.
  ``fused``  — the same per-round driver dispatching the single-jit fused
               round program (``core/fused_round``).  Seeded-identical
               count/byte trajectories to ``loop``.
  ``sweep``  — the vectorized device engine (``core/sweep``): rounds
               scanned, configs/seeds vmapped, sim axis mesh-sharded.  Own
               ``jax.random`` streams (seeded, not bit-identical to the
               host engines — see EXPERIMENTS.md).
  ``auto``   — ``sweep`` (the scalable default).

``loop``/``fused`` return a ``SimLog`` (or a list of them for several
seeds); ``sweep`` returns a ``SweepResult`` whose groups rebuild per-cell
``SimLog``s via ``GroupResult.sim_log``.

Beyond the batch engines, ``Experiment.serve`` stands up the long-lived
fault-tolerant aggregation service (``serving/fl_server.FLServer``):
client registry, idempotent upload inbox, seeded fault injection and
per-round checkpoint/resume — fault-free it reproduces ``engine="loop"``
bit-for-bit::

    server = Experiment(rounds=20).with_scheme("opt", b=2).serve(
        ckpt_dir="/tmp/fl_ckpt", faults="dup@r2:c*; crash@r3:close")
"""
from __future__ import annotations

from dataclasses import replace
from typing import Any, Dict, List, Tuple, Union

from repro.core.hsfl import HSFLConfig, HSFLSimulation
from repro.core.metrics import SimLog
from repro.core.schemes import (Scheme, get_scheme, register_scheme,
                                registered_schemes)
from repro.core.sweep import (CFG_AXES, GROUP_STATICS, SweepResult,
                              SweepSpec, _run_sweep)

__all__ = ["ENGINES", "Experiment", "Scheme", "get_scheme",
           "register_scheme", "registered_schemes"]

ENGINES = ("auto", "loop", "fused", "sweep")

# HSFLConfig fields that are int-typed but ride float-valued sweep pins
_INT_PINS = ("b",)


class Experiment:
    """Declarative experiment builder; every ``with_*`` returns a copy."""

    def __init__(self, cfg: HSFLConfig | None = None, **overrides):
        if cfg is None:
            cfg = HSFLConfig()
        if overrides:
            cfg = replace(cfg, **overrides)
        self.cfg = cfg
        self._schemes: List[Scheme] = []
        self._seeds: Tuple[int, ...] = (cfg.seed,)
        self._dists: Tuple[str, ...] = ()
        self._axes: Dict[str, Tuple[float, ...]] = {}
        self._spec_override: SweepSpec | None = None

    @classmethod
    def from_spec(cls, spec: SweepSpec) -> "Experiment":
        """Wrap an existing ``SweepSpec`` (panel helpers like
        ``sweep.fig3b_spec`` build these)."""
        ex = cls(spec.base)
        ex._spec_override = spec
        return ex

    def _clone(self) -> "Experiment":
        if self._spec_override is not None:
            # a from_spec experiment is a frozen wrapper: silently merging
            # builder calls into a ready-made SweepSpec would drop them
            raise ValueError(
                "this Experiment wraps a ready-made SweepSpec "
                "(Experiment.from_spec); builder methods would be ignored "
                "— edit the spec, or start from Experiment(cfg)")
        ex = Experiment(self.cfg)
        ex._schemes = list(self._schemes)
        ex._seeds = self._seeds
        ex._dists = self._dists
        ex._axes = dict(self._axes)
        return ex

    # -- builders -----------------------------------------------------------
    def with_scheme(self, scheme: Union[str, Scheme],
                    **pins) -> "Experiment":
        """Append a registered scheme (by name or instance); ``pins`` fix
        traced-axis values (b, tau_max, bandwidth_ratio) or group statics
        (use_delta_codec, codec_block, codec_bits, kernel, precision) for
        that scheme's group."""
        ex = self._clone()
        ex._schemes.append(get_scheme(scheme).with_pins(**pins))
        return ex

    def with_seeds(self, *seeds: int) -> "Experiment":
        ex = self._clone()
        ex._seeds = tuple(int(s) for s in seeds)
        return ex

    def with_distributions(self, *dists: str) -> "Experiment":
        ex = self._clone()
        ex._dists = tuple(dists)
        return ex

    def with_axes(self, **axes) -> "Experiment":
        """Sweep values on the traced config axes, e.g.
        ``with_axes(b=(1.0, 2.0, 3.0))`` (sweep engine only)."""
        bad = sorted(set(axes) - set(CFG_AXES))
        if bad:
            raise ValueError(f"{bad} are not traced config axes {CFG_AXES}; "
                             f"pin group statics {GROUP_STATICS} per scheme "
                             f"via with_scheme(..., **pins)")
        ex = self._clone()
        for k, v in axes.items():
            ex._axes[k] = tuple(float(x) for x in v)
        return ex

    # -- spec / config materialization --------------------------------------
    def to_spec(self) -> SweepSpec:
        """The ``SweepSpec`` this experiment compiles to on the sweep
        engine."""
        if self._spec_override is not None:
            return self._spec_override
        return SweepSpec(
            base=self.cfg, seeds=self._seeds,
            schemes=tuple(self._schemes),
            distributions=self._dists,
            b=self._axes.get("b", ()),
            tau_max=self._axes.get("tau_max", ()),
            bandwidth_ratio=self._axes.get("bandwidth_ratio", ()))

    def _loop_cfgs(self, engine: str) -> List[HSFLConfig]:
        """Materialize per-simulation configs for the host-driven engines
        (every pin folded into the HSFLConfig)."""
        if self._spec_override is not None:
            raise ValueError("from_spec experiments run on the sweep "
                             "engine; loop/fused take builder-style "
                             "Experiments")
        if len(self._schemes) > 1:
            raise ValueError(
                f"engine={engine!r} runs one scheme per simulation; got "
                f"{[s.name for s in self._schemes]} — use engine='sweep' "
                f"for multi-scheme panels")
        if len(self._dists) > 1:
            raise ValueError(f"engine={engine!r} runs one distribution; "
                             f"use engine='sweep'")
        cfg = self.cfg
        if self._dists:
            cfg = replace(cfg, distribution=self._dists[0])
        for k, vals in self._axes.items():
            if len(vals) != 1:
                raise ValueError(
                    f"engine={engine!r} cannot sweep {k}={vals}; swept "
                    f"axes need engine='sweep'")
        pins = {k: vals[0] for k, vals in self._axes.items()}
        if self._schemes:
            scheme = self._schemes[0]
            cfg = replace(cfg, scheme=scheme.name)
            pins.update(dict(scheme.pins))
        for k, v in pins.items():
            if k == "bandwidth_ratio":
                if float(v) != 1.0:
                    raise ValueError("bandwidth_ratio is a sweep-engine "
                                     "axis; the host engines run at 1.0")
                continue
            if k in _INT_PINS:
                if float(v) != int(float(v)):
                    raise ValueError(
                        f"{k}={v!r} is fractional: the host engines take "
                        f"integer budgets (the sweep engine traces floats) "
                        f"— pin an integral value or use engine='sweep'")
                v = int(float(v))
            if k in CFG_AXES or k in GROUP_STATICS:
                cfg = replace(cfg, **{k: v})
            else:
                raise ValueError(f"scheme pin {k!r} is neither a traced "
                                 f"axis {CFG_AXES} nor a group static "
                                 f"{GROUP_STATICS}")
        cfg = replace(cfg, use_fused_round=(engine == "fused"))
        return [replace(cfg, seed=sd) for sd in self._seeds]

    # -- execution ----------------------------------------------------------
    def run(self, engine: str = "auto", mesh: Any = "auto",
            verbose: bool = False, **engine_kw
            ) -> Union[SimLog, List[SimLog], SweepResult]:
        """Execute on the chosen engine.

        ``engine_kw`` passes through to the sweep engine (``timeit``,
        ``lower_discard``, ``overlap_compile``).  ``mesh`` only applies to
        the sweep engine."""
        if engine == "auto":
            engine = "sweep"
        if engine == "sweep":
            return _run_sweep(self.to_spec(), mesh=mesh, verbose=verbose,
                              **engine_kw)
        if engine in ("loop", "fused"):
            if engine_kw:
                raise ValueError(f"{sorted(engine_kw)} only apply to the "
                                 f"sweep engine")
            logs = [HSFLSimulation(cfg).run(verbose=verbose)
                    for cfg in self._loop_cfgs(engine)]
            return logs[0] if len(logs) == 1 else logs
        raise ValueError(f"unknown engine {engine!r}; choose from {ENGINES}")

    def to_config(self) -> HSFLConfig:
        """The single-simulation ``HSFLConfig`` this experiment denotes
        (one scheme, one seed; every pin folded in) — what ``serve()``
        and the crash supervisor ``serving.fl_server.run_with_restarts``
        consume."""
        cfgs = self._loop_cfgs("loop")
        if len(cfgs) != 1:
            raise ValueError(f"to_config() denotes one simulation; got "
                             f"{len(cfgs)} seeds — pick one with "
                             f"with_seeds(s)")
        return cfgs[0]

    def serve(self, *, ckpt_dir: str | None = None, faults=None,
              quorum: float = 0.0, **server_kw):
        """Build the long-lived aggregation service for this experiment
        (one scheme, one seed — the host reference semantics).

        Returns an un-started ``serving.fl_server.FLServer``; drive it
        with ``.serve()``/``.step()``, or hand the same config to
        ``serving.fl_server.run_with_restarts`` for crash supervision.
        ``faults`` is a ``FaultPlan`` or plan-grammar string.  Pass
        ``transport=core.transport.TransportConfig(...)`` (rides
        ``**server_kw``) to opt into the chunked lossy-wire model:
        resumable uploads, Gilbert-Elliott burst errors, XOR-parity
        erasure rescue."""
        from repro.serving.fl_server import FLServer
        return FLServer(self.to_config(), ckpt_dir=ckpt_dir,
                        fault_plan=faults, quorum=quorum, **server_kw)
