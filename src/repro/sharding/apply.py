"""Activation sharding constraints threaded through the model code.

GSPMD propagation alone replicates the batch through the attention-head
reshape whenever head counts don't divide the model axis (hymba 25H,
qwen2-vl 12H, granite 24H, llama4 40H — and every GQA arch's KV=8 < 16), so
the model bodies call ``constrain`` at the canonical points (post-embed,
post-projection, per-layer output).  ``act`` is None outside the dry-run /
launcher (single-device smoke tests), making everything a no-op.

act = {"batch": ("data",) | ("pod","data"), "model": "model", "model_size": 16}
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import PartitionSpec as P


def constrain(x, act: Optional[dict], *entries):
    """with_sharding_constraint under the ambient mesh; no-op when act=None.

    entries use the placeholders 'B' (batch axes), 'M' (model axis), None."""
    if act is None:
        return x
    spec = []
    for e in entries:
        if e == "B":
            spec.append(act["batch"])
        elif e == "M":
            spec.append(act["model"])
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))


def heads_shardable(act: Optional[dict], num_heads: int) -> bool:
    return act is not None and num_heads % act.get("model_size", 16) == 0


def batch_shardable(act: Optional[dict], batch: int) -> bool:
    if act is None:
        return False
    n = act.get("batch_size", 16)
    return batch % n == 0 and batch > 1
