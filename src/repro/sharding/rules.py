"""PartitionSpec rule tables for every family (DESIGN.md §4/§6).

Scheme: 2D param sharding — FSDP along ``data`` on the input/feature dim +
tensor-parallel along ``model`` on the flattened heads·head_dim / ffn dim
(head-count axes are never sharded directly: hymba 25H, qwen2-vl 12H and
granite 24H don't divide the 16-way model axis, but their flattened feature
dims do — recorded in DESIGN.md §4).  Params are replicated over ``pod``;
cross-pod traffic belongs to OpportunisticSync.

MoE placement: llama4 (128e) experts are expert-parallel on ``model``
(128/16 = 8 per shard); granite (40e ∤ 16) replicates experts and shards
*inside* each expert (moe_d_ff 512/16 = 32).

Decode caches shard the cache-position axis over ``model`` (batch over
data): KV head counts (8, 5, 2) don't divide 16, cache positions always do.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig

DATA, MODEL, POD = "data", "model", "pod"


def batch_axes(multi_pod: bool) -> Tuple[str, ...]:
    return (POD, DATA) if multi_pod else (DATA,)


def _divisible(dim: int, mesh_axis_size: int) -> bool:
    return dim % mesh_axis_size == 0


def _key_path_str(path) -> str:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
    return "/".join(out)


def _param_rule(cfg: ModelConfig, path: str, ndim: int) -> P:
    """Rule for one parameter leaf.  Stacked layer leaves carry a leading L
    dim (never sharded); we match on the trailing dims."""
    stacked = path.startswith("layers/")
    lead = (None,) if stacked else ()
    name = path.split("/")[-1]
    parent = path.split("/")[-2] if "/" in path else ""

    def spec(*trail):
        full = lead + trail
        assert len(full) == ndim, (path, ndim, full)
        return P(*full)

    # --- embeddings / head --------------------------------------------------
    if path == "embed/table":
        return P(MODEL, DATA)               # vocab x d
    if path == "head/w":
        return P(DATA, MODEL)               # d x vocab
    # --- norms / small vectors ---------------------------------------------
    if name in ("scale", "mu", "decay_w0", "bonus_u", "ln_scale", "D", "b"):
        return P(*([None] * ndim))
    # --- MoE ----------------------------------------------------------------
    if parent == "experts" or "experts" in path:
        expert_parallel = _divisible(cfg.num_experts, 16)
        if name in ("w_gate", "w_up"):       # (L, E, d, ff)
            return spec(MODEL, DATA, None) if expert_parallel \
                else spec(None, DATA, MODEL)
        if name == "w_down":                 # (L, E, ff, d)
            return spec(MODEL, None, DATA) if expert_parallel \
                else spec(None, MODEL, DATA)
    if name == "router":                     # (L, d, E)
        return spec(DATA, None)
    # --- attention / generic matmuls ----------------------------------------
    if name in ("wq", "wk", "wv", "w_gate", "w_up", "w_in", "w_k", "w_r",
                "w_v_up"):
        return spec(DATA, MODEL)             # (L, d, out)
    if name in ("wo", "w_down", "w_out"):
        return spec(MODEL, DATA)             # (L, out, d)
    if name in ("bq", "bk", "bv"):
        return spec(MODEL)
    # --- rwkv6 --------------------------------------------------------------
    if name == "w_v" and parent == "time":   # d x d value proj
        return spec(DATA, MODEL)
    if name == "w_g":
        return spec(DATA, MODEL)
    if name == "w_o":
        return spec(MODEL, DATA)
    if name in ("decay_a",):                 # (L, d, rank): rank tiny
        return spec(DATA, None)
    if name in ("decay_b",):                 # (L, rank, d)
        return spec(None, MODEL)
    # --- mamba --------------------------------------------------------------
    if name == "conv_w":                     # (L, K, di)
        return spec(None, MODEL)
    if name == "w_xproj":                    # (L, di, R+2N)
        return spec(MODEL, None)
    if name == "w_dt":                       # (L, R, di)
        return spec(None, MODEL)
    if name == "log_A":                      # (L, di, N)
        return spec(MODEL, None)
    # --- cnn / fallback ------------------------------------------------------
    return P(*([None] * ndim))


def param_specs(cfg: ModelConfig, params: Any) -> Any:
    """PartitionSpec tree matching a params pytree (works on shapes too)."""
    def rule(path, leaf):
        return _param_rule(cfg, _key_path_str(path), np.ndim(leaf) or len(leaf.shape))
    return jax.tree_util.tree_map_with_path(rule, params)


def opt_state_specs(cfg: ModelConfig, params: Any) -> Dict[str, Any]:
    """AdamW moments mirror the param sharding; step is replicated."""
    ps = param_specs(cfg, params)
    return {"step": P(), "m": ps, "v": ps}


def train_state_specs(cfg: ModelConfig, params: Any):
    from repro.training.train_state import TrainState
    return TrainState(params=param_specs(cfg, params),
                      opt_state=opt_state_specs(cfg, params),
                      step=P())


# ---------------------------------------------------------------------------
# activations / inputs / decode state
# ---------------------------------------------------------------------------

def input_sharding_specs(cfg: ModelConfig, shape: InputShape,
                         multi_pod: bool) -> Any:
    """Spec tree matching models.inputs.input_specs structure."""
    b_ax = batch_axes(multi_pod)
    n = (2 if multi_pod else 1) * 16
    b = b_ax if (shape.global_batch > 1 and shape.global_batch % n == 0) else None

    specs: Dict[str, P] = {}
    if shape.kind in ("train", "prefill"):
        if cfg.family == "audio":
            specs["embeds"] = P(b, None, None)
            if shape.kind == "train":
                specs["labels"] = P(b, None)
                specs["mask"] = P(b, None)
        else:
            specs["tokens"] = P(b, None)
            if shape.kind == "train":
                specs["labels"] = P(b, None)
            if cfg.family == "vlm":
                specs["patch_embeds"] = P(b, None, None)
                specs["positions"] = P(b, None, None)
        return specs
    specs["token"] = P(b, None)
    specs["position"] = P(b)
    return specs


def decode_state_specs(cfg: ModelConfig, batch: int, multi_pod: bool) -> Any:
    """Spec tree matching transformer.init_decode_state structure."""
    b_ax = batch_axes(multi_pod)
    n_batch_shards = (2 if multi_pod else 1) * 16
    # the batch dim is ONE PartitionSpec entry (possibly a tuple of axes)
    bspec = (b_ax,) if batch % n_batch_shards == 0 and batch > 1 else (None,)
    cache_ax = MODEL if batch > 1 else (DATA, MODEL)
    # when batch is unsharded (long_500k), spread the cache over data+model
    st: Dict[str, Any] = {}
    if cfg.family == "ssm":
        st["rwkv"] = {
            "shift_t": P(None, *bspec, MODEL),
            "shift_c": P(None, *bspec, MODEL),
            "wkv": P(None, *bspec, None, None, None) if batch > 1
                   else P(None, None, MODEL, None, None),
        }
        return st
    st["kv"] = {
        "k": P(None, *bspec, cache_ax, None, None),
        "v": P(None, *bspec, cache_ax, None, None),
    }
    if cfg.family == "hybrid":
        st["mamba"] = {
            "conv": P(None, *bspec, None, MODEL),
            "ssm": P(None, *bspec, MODEL, None),
        }
    return st


def logits_spec(multi_pod: bool, batch: int) -> P:
    b_ax = batch_axes(multi_pod)
    n = (2 if multi_pod else 1) * 16
    if batch % n == 0 and batch > 1:
        return P(b_ax, None, MODEL)
    return P(None, None, MODEL)


# ---------------------------------------------------------------------------
# sweep engine (core/sweep.py): stacked-simulation axis over a 1-D mesh
# ---------------------------------------------------------------------------

SWEEP = "sweep"


def sweep_leading_spec(ndim: int) -> P:
    """Shard the leading (simulation) axis over ``sweep``; replicate rest."""
    return P(SWEEP, *([None] * (ndim - 1)))


def shard_sweep_tree(mesh, tree: Any, n_sims: int) -> Any:
    """Place every leaf of a stacked-simulation pytree on ``mesh``.

    Leaves whose leading dim is the simulation axis get
    ``P("sweep", None, ...)``; when ``n_sims`` doesn't divide the mesh (or
    ``mesh`` is None) the tree is returned as-is (replicated), so callers
    never have to special-case single-device runs.
    """
    if mesh is None or n_sims % mesh.shape[SWEEP] != 0:
        return tree
    from jax.sharding import NamedSharding

    def put(leaf):
        nd = np.ndim(leaf)
        if nd == 0:
            return leaf
        return jax.device_put(leaf, NamedSharding(mesh,
                                                  sweep_leading_spec(nd)))

    return jax.tree_util.tree_map(put, tree)


def shard_sweep_specs(mesh, tree: Any, n_sims: int) -> Any:
    """Abstract twin of ``shard_sweep_tree``: annotate a
    ``jax.ShapeDtypeStruct`` pytree with the shardings ``device_put`` would
    apply, so the sweep engine can AOT-lower a group's program from avals
    alone — without materializing its (large, donated) input carry."""
    if mesh is None or n_sims % mesh.shape[SWEEP] != 0:
        return tree
    from jax.sharding import NamedSharding

    def ann(leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return leaf
        return jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype,
            sharding=NamedSharding(mesh, sweep_leading_spec(nd)))

    return jax.tree_util.tree_map(ann, tree)
