"""Batched autoregressive serving loop on top of decode_step."""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import module as m
from repro.models.registry import Model


def prefill(model: Model, params, tokens: jnp.ndarray,
            context_len: int, opts: Optional[dict] = None
            ) -> Tuple[jnp.ndarray, Any, jnp.ndarray]:
    """Feed a prompt token-by-token through decode_step (cache-exact path).

    Returns (last_logits, state, positions).  Production prefill uses the
    full-sequence forward; this loop is the reference used by tests to prove
    decode == full forward."""
    B, S = tokens.shape
    dtype = m.dtype_of(model.cfg.dtype)
    state = model.init_decode_state(B, context_len, dtype)
    logits = None

    def body(carry, t):
        state, _ = carry
        tok = jax.lax.dynamic_slice_in_dim(tokens, t, 1, axis=1)
        pos = jnp.full((B,), t, jnp.int32)
        lg, state = model.decode(params, tok, state, pos, opts)
        return (state, lg), None

    lg0 = jnp.zeros((B, 1, model.cfg.vocab_padded), dtype)
    (state, logits), _ = jax.lax.scan(body, (state, lg0), jnp.arange(S))
    return logits, state, jnp.full((B,), S, jnp.int32)


def generate(model: Model, params, prompt: jnp.ndarray, max_new: int,
             context_len: int, temperature: float = 0.0,
             key: Optional[jax.Array] = None,
             opts: Optional[dict] = None) -> jnp.ndarray:
    """Greedy / sampled generation.  prompt: (B, S) -> (B, max_new)."""
    B = prompt.shape[0]
    logits, state, pos = prefill(model, params, prompt, context_len, opts)
    key = key if key is not None else jax.random.PRNGKey(0)

    def pick(lg, k):
        lg = lg[:, -1].astype(jnp.float32)
        if temperature <= 0.0:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        return jax.random.categorical(k, lg / temperature, axis=-1).astype(jnp.int32)

    def body(carry, _):
        state, pos, last_tok, key = carry
        key, sub = jax.random.split(key)
        lg, state = model.decode(params, last_tok[:, None], state, pos, opts)
        nxt = pick(lg, sub)
        return (state, pos + 1, nxt, key), nxt

    first = pick(logits, key)
    (state, pos, _, _), toks = jax.lax.scan(
        body, (state, pos, first, key), None, length=max_new - 1)
    return jnp.concatenate([first[:, None], toks.T], axis=1)
