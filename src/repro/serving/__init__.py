from repro.serving.decode import generate, prefill

__all__ = ["generate", "prefill"]
