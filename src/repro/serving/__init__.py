from repro.serving.decode import generate, prefill

__all__ = ["FLServer", "ClientRegistry", "generate", "prefill",
           "run_with_restarts"]


def __getattr__(name):
    # fl_server pulls in the whole HSFL stack; load it lazily so the
    # decode-only serving path stays light
    if name in ("FLServer", "ClientRegistry", "run_with_restarts"):
        from repro.serving import fl_server
        return getattr(fl_server, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
