"""Fault-tolerant FL aggregation service — the long-lived serving path.

Every engine in this repo ran as a crash-fragile batch script; this module
turns the host reference loop into a *service* that survives the paper's
whole premise (unreliable clients) plus its own death:

  - **client registry** — clients register/join/drop mid-training;
    selection is intersected with the registry, round ids are monotonic,
    and per-client staleness (rounds since last accepted upload) is
    tracked (the server/registry pattern of arXiv:2210.10970's
    UAV-coordinated FL).
  - **idempotent inbox** — every upload (final or opportunistic snapshot)
    is a CRC-checked message keyed by ``(round, client, kind)``; duplicate
    deliveries are rejected without touching aggregation (bit-identical
    output with and without duplicates), stale round ids are refused, and
    corrupt payloads are NACKed so the client re-sends under
    ``core.faults.retry_call`` exponential backoff.
  - **quorum-or-deadline close** — a round closes when every scheduled
    upload resolves; if fewer than ``quorum``·selected finals arrived the
    server holds the round open for late (fault-delayed) uploads before
    degrading to the registered Scheme's rescue/delayed path
    (staleness-adaptive async semantics after arXiv:2403.06653).
  - **checkpoint/resume** — after each round the full resume state
    (params, straggler carry, fleet state, every RNG bit-generator state,
    registry, metrics) commits through ``checkpoint/msgpack_ckpt``'s
    COMMIT-marker atomicity; a killed server restarts from
    ``latest_step`` and replays the interrupted round *bit-compatibly*
    (the final model equals an uninterrupted run on the same seed).
  - **fault injection** — a seeded ``core.faults.FaultPlan`` perturbs the
    transport (drop/dup/corrupt/delay) and the server itself (crash at
    train/close/checkpoint phases); ``run_with_restarts`` is the
    supervisor that eats crashes and resumes.

The trajectory contract: with an empty (or fully *recoverable*) fault
plan, ``FLServer`` reproduces ``Experiment(cfg).run(engine="loop")``
bit-for-bit — same per-round arrivals/rescues/bytes, same final params.
``tests/test_fl_server.py`` pins it.
"""
from __future__ import annotations

import json
import math
import os
import zlib
from dataclasses import asdict, dataclass, replace
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.msgpack_ckpt import (_decode_leaf, _encode_leaf,
                                           latest_step, restore_aux,
                                           restore_checkpoint,
                                           save_checkpoint)
from repro.core import latency as lat
from repro.core.faults import (BackoffPolicy, CorruptPayload,
                               RetriesExhausted, ServerCrash, UploadTimeout,
                               as_fault_plan, client_rng, retry_call)
from repro.core.hsfl import (HSFLConfig, HSFLSimulation, _k_bucket,
                             _sample_epoch)
from repro.core.metrics import RoundLog, SimLog
from repro.core.transmission import OppTransmitter
from repro.core.transport import (ChunkedUploader, LossyWire, TransferLedger,
                                  TransportConfig, make_chunks)
from repro.kernels.delta_codec.ops import decode_delta, encode_delta

import msgpack

__all__ = ["ClientRegistry", "FLServer", "METRICS_SCHEMA", "UploadMsg",
           "run_with_restarts"]

# metrics.jsonl record schema: bump when the per-round row shape changes
# (2 = lossy-wire transport counters + this version field)
METRICS_SCHEMA = 2


# ---------------------------------------------------------------------------
# wire format: msgpack-encoded pytrees with a CRC32 trailer
# ---------------------------------------------------------------------------

def encode_tree(tree: Any) -> bytes:
    """Serialize a parameter pytree to wire bytes (checkpoint leaf codec)."""
    leaves = jax.tree_util.tree_leaves(tree)
    return msgpack.packb([_encode_leaf(x) for x in leaves],
                         use_bin_type=True)


def decode_tree(payload: bytes, like: Any) -> Any:
    """Inverse of ``encode_tree`` into the structure of ``like``."""
    enc = msgpack.unpackb(payload, raw=False)
    leaves, treedef = jax.tree_util.tree_flatten(like)
    if len(enc) != len(leaves):
        raise ValueError(f"upload has {len(enc)} leaves, expected "
                         f"{len(leaves)}")
    return jax.tree_util.tree_unflatten(
        treedef, [jnp.asarray(_decode_leaf(d)) for d in enc])


@dataclass
class UploadMsg:
    """One client→server delivery attempt."""
    client_id: int
    round_id: int
    kind: str                      # "final" | "snapshot"
    seq: int                       # client-side attempt nonce
    payload: bytes
    crc: int
    wire_bytes: float              # the *accounted* channel payload (eq. 13)

    @classmethod
    def build(cls, client_id: int, round_id: int, kind: str, seq: int,
              tree: Any, wire_bytes: float) -> "UploadMsg":
        """``tree`` may be a pytree or pre-encoded wire bytes (the chunked
        transport reassembles payloads without re-decoding them)."""
        payload = tree if isinstance(tree, bytes) else encode_tree(tree)
        return cls(client_id, round_id, kind, seq, payload,
                   zlib.crc32(payload), wire_bytes)

    def corrupted(self) -> "UploadMsg":
        """A copy with one payload byte flipped (CRC now mismatches)."""
        i = len(self.payload) // 2
        bad = self.payload[:i] + bytes([self.payload[i] ^ 0xFF]) \
            + self.payload[i + 1:]
        return replace(self, payload=bad)


# ---------------------------------------------------------------------------
# client registry
# ---------------------------------------------------------------------------

@dataclass
class ClientRecord:
    client_id: int
    joined_round: int = 1          # first round the client is schedulable
    dropped_round: Optional[int] = None   # drop takes effect *during* this
    last_upload: Optional[int] = None     # last round an upload was accepted
    uploads: int = 0


class ClientRegistry:
    """Who is in the fleet, since when, and how stale they are.

    Round ids are monotonic; joins take effect next round (a client
    registering *during* round t first becomes schedulable at t+1) and so
    do drops (the client leaves the candidate set from ``dropped_round``
    on).  A client vanishing *inside* a round — trained but never
    delivered — is the transport-level ``drop`` fault of
    ``core.faults.FaultPlan``.
    """

    def __init__(self, client_ids=()):
        self._rec: Dict[int, ClientRecord] = {
            int(c): ClientRecord(int(c)) for c in client_ids}

    def register(self, client_id: int, current_round: int = 0) -> ClientRecord:
        """Join (or re-join) the fleet, schedulable from the next round."""
        cid = int(client_id)
        rec = self._rec.get(cid)
        if rec is None or rec.dropped_round is not None:
            rec = ClientRecord(cid, joined_round=current_round + 1)
            self._rec[cid] = rec
        return rec

    def drop(self, client_id: int, at_round: int) -> None:
        """Leave the fleet: not schedulable from ``at_round`` onwards."""
        rec = self._rec.get(int(client_id))
        if rec is not None and rec.dropped_round is None:
            rec.dropped_round = int(at_round)

    def schedulable(self, client_id: int, round_id: int) -> bool:
        rec = self._rec.get(int(client_id))
        return (rec is not None and rec.joined_round <= round_id
                and (rec.dropped_round is None
                     or rec.dropped_round > round_id))

    def is_dropped(self, client_id: int, round_id: int) -> bool:
        rec = self._rec.get(int(client_id))
        return rec is not None and rec.dropped_round is not None \
            and rec.dropped_round <= round_id

    def record_upload(self, client_id: int, round_id: int) -> None:
        rec = self._rec.get(int(client_id))
        if rec is not None:
            rec.last_upload = round_id
            rec.uploads += 1

    def staleness(self, client_id: int, round_id: int) -> Optional[int]:
        """Rounds since the last accepted upload (None = never uploaded)."""
        rec = self._rec.get(int(client_id))
        if rec is None or rec.last_upload is None:
            return None
        return round_id - rec.last_upload

    def records(self) -> List[ClientRecord]:
        return [self._rec[c] for c in sorted(self._rec)]

    # -- checkpoint round trip ----------------------------------------------
    def to_json(self) -> Dict[str, Any]:
        return {str(c): asdict(r) for c, r in sorted(self._rec.items())}

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "ClientRegistry":
        reg = cls()
        for c, r in d.items():
            reg._rec[int(c)] = ClientRecord(**r)
        return reg


# ---------------------------------------------------------------------------
# the round inbox
# ---------------------------------------------------------------------------

class RoundInbox:
    """Per-round upload store: first valid delivery per (client, kind)
    wins; everything else is classified and counted, never aggregated."""

    def __init__(self, round_id: int):
        self.round_id = round_id
        self.accepted: Dict[Tuple[int, str], UploadMsg] = {}
        self.duplicates = 0
        self.stale = 0
        self.corrupt = 0

    def offer(self, msg: UploadMsg) -> str:
        """Classify a delivery: 'accepted' | 'duplicate' | 'stale' |
        'corrupt'.  Raises ``CorruptPayload`` on CRC mismatch (the NACK
        the client's retry loop consumes)."""
        if msg.round_id != self.round_id:
            self.stale += 1
            return "stale"
        if zlib.crc32(msg.payload) != msg.crc:
            self.corrupt += 1
            raise CorruptPayload(
                f"round {self.round_id} client {msg.client_id} "
                f"{msg.kind} seq {msg.seq}: CRC mismatch")
        key = (msg.client_id, msg.kind)
        prev = self.accepted.get(key)
        if prev is not None:
            if msg.kind == "final" or msg.seq == prev.seq:
                # re-delivery of an already-accepted upload: idempotent
                self.duplicates += 1
                return "duplicate"
            # a *newer* snapshot overwrites the previous one (Alg. 2
            # line 14/20: "Previous ω_i will be overwritten")
        self.accepted[key] = msg
        return "accepted"

    def get(self, client_id: int, kind: str) -> Optional[UploadMsg]:
        return self.accepted.get((client_id, kind))


# ---------------------------------------------------------------------------
# the server
# ---------------------------------------------------------------------------

class FLServer:
    """Long-lived HSFL aggregation service over the host reference engine.

    Construct directly from an ``HSFLConfig`` (or through
    ``repro.api.Experiment.serve``), then drive with ``step()`` /
    ``serve()``.  With ``ckpt_dir`` set, every completed round commits a
    resume checkpoint; constructing with ``resume=True`` (the default)
    picks up ``latest_step`` and continues bit-compatibly.
    """

    def __init__(self, cfg: HSFLConfig, *, ckpt_dir: Optional[str] = None,
                 fault_plan=None, quorum: float = 0.0,
                 backoff: Optional[BackoffPolicy] = None,
                 eval_every: int = 1, resume: bool = True,
                 metrics_path: Optional[str] = None,
                 initial_clients=None, skip_crashes=frozenset(),
                 transport: Optional[TransportConfig] = None):
        if not (0.0 <= quorum <= 1.0):
            raise ValueError(f"quorum must lie in [0, 1], got {quorum}")
        # opt-in lossy wire (core.transport): chunked resumable uploads,
        # Gilbert–Elliott burst errors, XOR-parity erasure rescue.  None
        # keeps the legacy atomic-blob wire (and the bit-identical
        # host-loop trajectory contract).
        self.transport = transport.validate() if transport else None
        self._ledger = TransferLedger()
        # the service wraps the host reference path: per-client transmitters
        # and list-form aggregation are what an inbox can mediate
        self.cfg = replace(cfg, use_fused_round=False)
        self.sim = HSFLSimulation(self.cfg)
        self.faults = as_fault_plan(fault_plan)
        self.quorum = float(quorum)
        self.backoff = (backoff or BackoffPolicy()).validate()
        self.eval_every = int(eval_every)
        self.ckpt_dir = ckpt_dir
        self.metrics_path = metrics_path or (
            os.path.join(ckpt_dir, "metrics.jsonl") if ckpt_dir else None)
        self.skip_crashes = frozenset(skip_crashes)
        ids = (range(cfg.n_uavs) if initial_clients is None
               else initial_clients)
        self.registry = ClientRegistry(ids)
        self.round = 0                       # last *completed* round id
        self.log = SimLog()
        self._delayed: List[Tuple[Any, int]] = []   # async straggler carry
        if resume and ckpt_dir is not None:
            step = latest_step(ckpt_dir)
            if step is not None:
                self._restore(step)

    # -- public API ---------------------------------------------------------
    def register_client(self, client_id: int) -> ClientRecord:
        """Join mid-training: schedulable from the next round."""
        return self.registry.register(client_id, self.round)

    def drop_client(self, client_id: int, at_round: Optional[int] = None):
        """Leave mid-training: the client stops being scheduled from the
        next round (transport-level mid-round loss is the ``drop`` fault)."""
        self.registry.drop(client_id, self.round + 1 if at_round is None
                           else at_round)

    @property
    def params(self):
        return self.sim.params

    def step(self) -> RoundLog:
        """Run exactly one round (may raise ``ServerCrash`` under an
        injected crash; state is only committed on completion)."""
        t = self.round + 1
        rlog = self._run_round(t)
        self.round = t
        self.log.add(rlog)
        self._checkpoint(t)
        self._emit_metrics(rlog)
        return rlog

    def serve(self, rounds: Optional[int] = None, verbose: bool = False
              ) -> SimLog:
        """Run until round ``rounds`` (default ``cfg.rounds``)."""
        end = self.cfg.rounds if rounds is None else int(rounds)
        while self.round < end:
            rlog = self.step()
            if verbose and (rlog.round % 10 == 0 or rlog.round == 1):
                print(f"[serve/{self.cfg.scheme}] round {rlog.round}: "
                      f"acc={rlog.test_acc:.4f} "
                      f"arrived={rlog.arrived_final} "
                      f"rescued={rlog.used_snapshot} "
                      f"dup={rlog.duplicates_rejected} "
                      f"retries={rlog.retries}")
        return self.log

    # -- fault hooks --------------------------------------------------------
    def _crash_maybe(self, t: int, phase: str):
        if self.faults.crash_phase(t) == phase \
                and (t, phase) not in self.skip_crashes:
            if phase == "checkpoint":
                # die mid-save: step dir + payload written, COMMIT absent —
                # exactly the half-written save latest_step must skip
                self._write_half_checkpoint(t)
            raise ServerCrash(t, phase)

    # -- transport ----------------------------------------------------------
    def _fault_state(self, t: int, client_id: int,
                     fault_state: Dict[int, Dict[str, int]]
                     ) -> Dict[str, int]:
        return fault_state.setdefault(client_id, {
            "corrupt_left": self.faults.count("corrupt", t, client_id),
            "dropped": self.faults.count("drop", t, client_id),
            "partial": self.faults.count("partial", t, client_id),
            "seq": 0,
        })

    def _maybe_flip(self, t: int, client_id: int, tree: Any) -> Any:
        """The ``flip`` fault: seeded *pre-encode* bit flips in the upload
        copy.  The wire CRC is computed afterwards, so the corruption is
        CRC-clean — only a robust aggregate can absorb it.  Flipping the
        top exponent bit (30) turns any sub-unit weight into a huge
        (~1e37) outlier; if the result lands on exponent 255 (inf/NaN)
        the exponent LSB is flipped too, keeping the outlier *finite* —
        a NaN would poison even robust sorts at small cohort sizes."""
        n = self.faults.count("flip", t, client_id)
        if not n:
            return tree
        rng = np.random.default_rng(np.random.SeedSequence(
            (int(self.cfg.seed), int(t), int(client_id), 0xF11D)))
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        out = [np.array(x) for x in leaves]
        elig = [i for i, x in enumerate(out) if x.dtype == np.float32]
        total = sum(out[i].size for i in elig)
        for pos in rng.integers(0, total, size=n):
            for i in elig:
                if pos < out[i].size:
                    flat = out[i].reshape(-1)
                    bits = flat.view(np.int32)
                    bits[pos] ^= np.int32(1 << 30)
                    if not np.isfinite(flat[pos]):
                        bits[pos] ^= np.int32(1 << 23)
                    break
                pos -= out[i].size
        return jax.tree_util.tree_unflatten(
            treedef, [jnp.asarray(x) for x in out])

    def _send(self, t: int, client_id: int, kind: str, tree: Any,
              wire_bytes: float, inbox: RoundInbox, rlog: RoundLog,
              fault_state: Dict[int, Dict[str, int]]) -> str:
        """One upload through the faulty transport with client-side
        retry/backoff.  ``tree`` may be pre-encoded wire bytes (the
        chunked transport's reassembled payload — flip/partial already
        applied at the chunk layer).  Returns 'accepted' | 'lost' |
        'deferred'."""
        fs = self._fault_state(t, client_id, fault_state)
        if not isinstance(tree, bytes):
            tree = self._maybe_flip(t, client_id, tree)
        if kind == "final" and self.faults.count("delay", t, client_id):
            # misses the deadline: parked for the quorum policy at close
            fs["seq"] += 1
            msg = UploadMsg.build(client_id, t, kind, fs["seq"], tree,
                                  wire_bytes)
            self._late.append(msg)
            return "deferred"

    # NB: bytes accounting — the *first* attempt's payload is already
    # counted by the OppTransmitter event log (host-loop parity); only
    # retries and duplicate deliveries add wire bytes on top.
        rng = client_rng(self.cfg.seed, t, client_id)
        attempt_no = {"n": 0}

        def attempt():
            attempt_no["n"] += 1
            if attempt_no["n"] > 1:
                rlog.bytes_sent += wire_bytes
            if kind == "final" and fs["dropped"]:
                raise UploadTimeout(f"client {client_id} round {t}: "
                                    f"black-holed")
            fs["seq"] += 1
            msg = UploadMsg.build(client_id, t, kind, fs["seq"], tree,
                                  wire_bytes)
            if fs["partial"] and kind == "final" \
                    and self.transport is None:
                # truncated blob on the legacy atomic wire: fails CRC on
                # *every* attempt — unrecoverable without chunking+parity
                try:
                    inbox.offer(replace(
                        msg, payload=msg.payload[:len(msg.payload) // 2]))
                finally:
                    rlog.corrupt_rejected += 1
                return None           # unreachable: offer raised
            if fs["corrupt_left"] > 0:
                fs["corrupt_left"] -= 1
                try:
                    inbox.offer(msg.corrupted())
                finally:
                    rlog.corrupt_rejected += 1
                return None           # unreachable: offer raised
            return inbox.offer(msg), msg

        try:
            res = retry_call(attempt, self.backoff, rng)
        except RetriesExhausted:
            rlog.retries += self.backoff.max_attempts - 1
            return "lost"
        rlog.retries += res.retries
        rlog.backoff_s += res.backoff_s
        status, msg = res.value
        if status != "accepted":
            return "lost"
        for _ in range(self.faults.count("dup", t, client_id)
                       if kind == "final" else 0):
            # duplicate deliveries: the inbox must reject them all
            if inbox.offer(msg) == "duplicate":
                rlog.duplicates_rejected += 1
                rlog.bytes_sent += wire_bytes
        return "accepted"

    # -- chunked lossy-wire transport (core.transport) ----------------------
    def _wire_for(self, t: int, client_id: int,
                  wires: Dict[int, LossyWire]) -> LossyWire:
        """The per-(round, client) Gilbert–Elliott burst-error wire; its
        RNG stream is independent of both the simulation RNG and the
        backoff jitter stream (fault handling never perturbs training)."""
        if client_id not in wires:
            wires[client_id] = LossyWire(
                self.transport, np.random.default_rng(np.random.SeedSequence(
                    (int(self.cfg.seed), int(t), int(client_id), 0x317E))))
        return wires[client_id]

    def _deliver_chunks(self, t: int, client_id: int, chunks,
                        wire: LossyWire, asm, rlog: RoundLog) -> None:
        """Push chunks over the lossy wire into the server-side assembler.
        A wire-corrupted chunk fails its CRC, is NACKed, and retransmits
        under the backoff policy; a chunk that exhausts its retries stays
        missing — the XOR parity group may still rebuild it."""
        rng = client_rng(self.cfg.seed, t, client_id)
        for ch in chunks:
            attempt_no = {"n": 0}

            def attempt(ch=ch):
                attempt_no["n"] += 1
                if attempt_no["n"] > 1:
                    rlog.chunks_retransmitted += 1
                    rlog.bytes_sent += len(ch.data)
                st = asm.add(wire.transmit(ch))
                if st == "corrupt":
                    rlog.chunks_corrupt += 1
                    raise CorruptPayload(
                        f"round {t} client {client_id}: chunk "
                        f"{ch.kind}[{ch.index}] of transfer "
                        f"{ch.transfer_id:#010x} corrupted on the wire")
                return st

            try:
                res = retry_call(attempt, self.backoff, rng)
            except RetriesExhausted:
                rlog.retries += self.backoff.max_attempts - 1
                continue                  # lost chunk; parity may rescue
            rlog.retries += res.retries
            rlog.backoff_s += res.backoff_s

    def _pump_snapshot(self, t: int, client_id: int, up: ChunkedUploader,
                       rate: float, inbox: RoundInbox, rlog: RoundLog,
                       fault_state, wires: Dict[int, LossyWire]) -> None:
        """One probe epoch of a chunked snapshot upload: send what the
        eq. 14 budget share affords, and hand the transfer off to the
        inbox once every chunk has been on the wire."""
        chunks = up.take_epoch(rate)
        if chunks:
            asm = self._ledger.assembler(client_id, chunks[0],
                                         self.transport)
            send = [c for c in chunks if c.key not in asm.have()]
            par = sum(len(c.data) for c in send if c.kind == "parity")
            rlog.chunks_sent += len(send)
            rlog.bytes_sent += sum(len(c.data) for c in send)
            rlog.parity_bytes += par
            self._deliver_chunks(t, client_id, send,
                                 self._wire_for(t, client_id, wires),
                                 asm, rlog)
        if up.idle and up.chunks:
            # every chunk had its chance on the wire: close the transfer
            self._finish_transfer(t, client_id, up, inbox, rlog,
                                  fault_state)

    def _finish_transfer(self, t: int, client_id: int, up: ChunkedUploader,
                         inbox: RoundInbox, rlog: RoundLog,
                         fault_state) -> str:
        """Close out an in-flight snapshot transfer: XOR-reconstruct what
        parity can, offer the reassembled payload to the inbox, or count
        the upload as lost.  Also the round-close rescue path for
        transfers whose budget ran out mid-upload."""
        asm = self._ledger.get(client_id, up.transfer_id) \
            if up.transfer_id is not None else None
        up.finish()
        if asm is None:
            rlog.transfers_incomplete += 1
            return "lost"
        rlog.chunks_recovered += asm.try_reconstruct()
        if not asm.complete():
            rlog.transfers_incomplete += 1
            return "lost"                 # assembler stays in the ledger:
        payload = asm.payload()           # a re-offer resumes from it
        self._ledger.pop(client_id, asm.transfer_id)
        return self._send(t, client_id, "snapshot", payload,
                          float(len(payload)), inbox, rlog, fault_state)

    def _send_final_transport(self, t: int, client_id: int, tree: Any,
                              wire_bytes: float, inbox: RoundInbox,
                              rlog: RoundLog, fault_state,
                              wires: Dict[int, LossyWire]) -> str:
        """The final upload over the chunked lossy wire.  ``partial``
        truncates the tail of the chunk sequence before it leaves the
        client; parity can rebuild at most one missing data chunk per
        group.  Data airtime is already accounted by the transmitter's
        final-upload event — only parity overhead adds wire bytes here."""
        fs = self._fault_state(t, client_id, fault_state)
        tree = self._maybe_flip(t, client_id, tree)
        payload = encode_tree(tree)
        if fs["dropped"]:
            # black-holed before the first chunk: legacy retry accounting
            rlog.retries += self.backoff.max_attempts - 1
            return "lost"
        if self.faults.count("delay", t, client_id):
            fs["seq"] += 1
            self._late.append(UploadMsg.build(
                client_id, t, "final", fs["seq"], payload, wire_bytes))
            return "deferred"
        chunks = make_chunks(payload, self.transport)
        if fs["partial"]:
            chunks = chunks[:max(0, len(chunks) - fs["partial"])]
        if not chunks:
            return "lost"
        asm = self._ledger.assembler(client_id, chunks[0], self.transport)
        send = [c for c in chunks if c.key not in asm.have()]
        par = sum(len(c.data) for c in send if c.kind == "parity")
        rlog.chunks_sent += len(send)
        rlog.bytes_sent += par
        rlog.parity_bytes += par
        self._deliver_chunks(t, client_id, send,
                             self._wire_for(t, client_id, wires), asm, rlog)
        rlog.chunks_recovered += asm.try_reconstruct()
        if not asm.complete():
            rlog.transfers_incomplete += 1
            return "lost"
        reassembled = asm.payload()
        self._ledger.pop(client_id, asm.transfer_id)
        return self._send(t, client_id, "final", reassembled, wire_bytes,
                          inbox, rlog, fault_state)

    # -- one round ----------------------------------------------------------
    def _run_round(self, t: int) -> RoundLog:
        cfg, sim = self.cfg, self.sim
        scheme = sim.scheme
        carry = list(self._delayed)
        self._late: List[UploadMsg] = []
        inbox = RoundInbox(t)

        sched, ue_bytes = sim._schedule_round()
        rlog = RoundLog(round=t, selected=len(sched))
        live = [u for u in sched if self.registry.schedulable(u.index, t)]
        rlog.unregistered_skipped = len(sched) - len(live)
        sched = live
        rlog.selected = len(sched)
        if not sched:
            # injected server crashes do not care whether anyone was
            # scheduled — fire the phase hooks even on an empty round
            self._crash_maybe(t, "train")
            self._crash_maybe(t, "close")
            self.sim.params = scheme.aggregate_host(
                [], carry, sim.params, cfg.async_alpha, cfg.async_a)
            self._delayed = []
            self._eval_round(rlog)
            return rlog

        txs: Dict[int, OppTransmitter] = {}
        for u in sched:
            payload = cfg.model_bytes if u.mode == "FL" else ue_bytes
            txs[u.index] = OppTransmitter(
                payload, cfg.local_epochs, cfg.b, u.rate0_bps,
                compress_ratio=sim.compress_ratio,
                schedule_override=cfg.schedule_override)

        K = _k_bucket(len(sched), cfg.k_select)
        stacked = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (K,) + a.shape), sim.params)

        def user_tree(i: int):
            return jax.tree_util.tree_map(lambda a: a[i], stacked)

        def snapshot_of(i: int):
            if not cfg.use_delta_codec:
                return user_tree(i)
            payload = encode_delta(user_tree(i), sim.params,
                                   interpret=sim._interpret,
                                   block=cfg.codec_block,
                                   bits=cfg.codec_bits)
            return decode_delta(payload, sim.params,
                                interpret=sim._interpret)

        fault_state: Dict[int, Dict[str, int]] = {}
        wires: Dict[int, LossyWire] = {}
        uploaders: Dict[int, ChunkedUploader] = {}
        if self.transport is not None:
            for u in sched:
                tx = txs[u.index]
                uploaders[u.index] = ChunkedUploader(
                    self.transport, tx.tau_extra0, len(tx.schedule))
        # local training in lockstep; probe uploads ride the faulty
        # transport into the inbox (the server, not the transmitter, is
        # the durable holder of the latest snapshot)
        for e_t in range(1, cfg.local_epochs + 1):
            sim.fleet.move()
            rates = sim.fleet.rates()
            outages = sim.fleet.outages()
            eb = [_sample_epoch(sim.clients[u.index], cfg, sim.rng)
                  for u in sched]
            while len(eb) < K:
                eb.append(eb[0])
            xs = jnp.stack([b[0] for b in eb])
            ys = jnp.stack([b[1] for b in eb])
            stacked = sim._epoch_all(stacked, xs, ys)
            if sim._probe_epochs:
                for i, u in enumerate(sched):
                    tx = txs[u.index]
                    if e_t not in tx.schedule:
                        continue
                    if self.transport is not None:
                        # chunked resumable upload: an outage skips the
                        # epoch (the in-flight transfer survives it); an
                        # idle uploader starts shipping a fresh snapshot
                        if bool(outages[u.index]):
                            continue
                        up = uploaders[u.index]
                        if up.idle:
                            up.begin(encode_tree(self._maybe_flip(
                                t, u.index, snapshot_of(i))))
                        self._pump_snapshot(t, u.index, up,
                                            float(rates[u.index]), inbox,
                                            rlog, fault_state, wires)
                    else:
                        sent = tx.maybe_transmit(
                            e_t, float(rates[u.index]),
                            bool(outages[u.index]),
                            lambda i=i: snapshot_of(i))
                        if sent:
                            self._send(t, u.index, "snapshot", tx.snapshot,
                                       tx.payload_bytes, inbox, rlog,
                                       fault_state)
            if e_t == 1:
                self._crash_maybe(t, "train")

        # round-close rescue: transfers whose budget ran out mid-upload
        # get one XOR-parity reconstruction attempt before aggregation
        for u in sched:
            up = uploaders.get(u.index)
            if up is not None and up.chunks:
                self._finish_transfer(t, u.index, up, inbox, rlog,
                                      fault_state)

        # final uploads through the transport
        rates = sim.fleet.rates()
        outages = sim.fleet.outages()
        outcome: Dict[int, str] = {}
        for i, u in enumerate(sched):
            tx = txs[u.index]
            tr_time = (lat.train_time_fl(sim.devices[u.index],
                                         sim.workloads[u.index])
                       if u.mode == "FL" else
                       lat.train_time_sl(sim.devices[u.index],
                                         sim.workloads[u.index]))
            slack = float(scheme.final_slack(tx.tau_extra0))
            ok = tx.final_upload(float(rates[u.index]),
                                 bool(outages[u.index]),
                                 tr_time + slack, cfg.tau_max)
            if ok and self.registry.is_dropped(u.index, t):
                outcome[u.index] = "lost"       # left mid-round
            elif ok and self.transport is not None:
                outcome[u.index] = self._send_final_transport(
                    t, u.index, user_tree(i), tx.payload_bytes,
                    inbox, rlog, fault_state, wires)
            elif ok:
                outcome[u.index] = self._send(
                    t, u.index, "final", user_tree(i), tx.payload_bytes,
                    inbox, rlog, fault_state)
            else:
                outcome[u.index] = "missed"     # channel/deadline, no send
            rlog.bytes_sent += tx.bytes_sent
            if u.mode == "SL" and tx.events:
                wl = sim.workloads[u.index]
                rlog.bytes_sent += wl.act_bytes_per_sample * wl.samples

        self._crash_maybe(t, "close")

        # quorum-or-deadline close: too few timely finals -> hold the round
        # open and admit late uploads before degrading to the scheme path
        arrived_n = sum(1 for s in outcome.values() if s == "accepted")
        need = math.ceil(self.quorum * len(sched))
        rlog.quorum_met = arrived_n >= need
        for msg in self._late:
            if arrived_n < need and inbox.offer(msg) == "accepted":
                outcome[msg.client_id] = "accepted"
                rlog.late_accepted += 1
                arrived_n += 1
            else:
                inbox.stale += 1
                rlog.stale_rejected += 1
        self._late = []

        # close the round in schedule order (aggregation must not depend on
        # arrival order — that is what makes duplicates/permutations moot)
        arrived: List[Any] = []
        new_delayed: List[Tuple[Any, int]] = []
        for i, u in enumerate(sched):
            if outcome[u.index] == "accepted":
                msg = inbox.get(u.index, "final")
                arrived.append(decode_tree(msg.payload, sim.params))
                self.registry.record_upload(u.index, t)
                rlog.arrived_final += 1
            elif scheme.uses_probes \
                    and inbox.get(u.index, "snapshot") is not None:
                snap = inbox.get(u.index, "snapshot")
                arrived.append(decode_tree(snap.payload, sim.params))
                self.registry.record_upload(u.index, t)
                rlog.used_snapshot += 1
            elif scheme.carries_delayed \
                    and not self.registry.is_dropped(u.index, t):
                new_delayed.append((user_tree(i), 1))
                rlog.delayed += 1
            else:
                rlog.dropped += 1

        self.sim.params = scheme.aggregate_host(
            arrived, carry, sim.params, cfg.async_alpha, cfg.async_a)
        self._delayed = new_delayed
        self._eval_round(rlog)
        return rlog

    def _eval_round(self, rlog: RoundLog):
        if rlog.round % self.eval_every == 0 \
                or rlog.round == self.cfg.rounds:
            rlog.test_loss, rlog.test_acc = self.sim.evaluate()

    # -- checkpoint / resume -------------------------------------------------
    def _ckpt_tree(self) -> Any:
        fleet = self.sim.fleet
        return {
            "params": self.sim.params,
            "delayed": [tr for tr, _ in self._delayed],
            "fleet_pos": np.asarray(fleet.pos),
            "fleet_kdb": np.asarray(fleet.k_db),
            "fleet_bad": np.asarray(fleet._bad),
        }

    def _ckpt_aux(self, t: int) -> Dict[str, Any]:
        return {
            "round": t,
            "scheme": self.cfg.scheme,
            "seed": self.cfg.seed,
            "delayed_staleness": [int(s) for _, s in self._delayed],
            "sim_rng": self.sim.rng.bit_generator.state,
            "fleet_rng": self.sim.fleet.rng.bit_generator.state,
            "registry": self.registry.to_json(),
            "rounds_log": [asdict(r) for r in self.log.rounds],
        }

    def _checkpoint(self, t: int):
        if self.ckpt_dir is None:
            return
        self._crash_maybe(t, "checkpoint")
        save_checkpoint(self.ckpt_dir, t, self._ckpt_tree(),
                        aux=self._ckpt_aux(t))

    def _write_half_checkpoint(self, t: int):
        """A crashed writer: payload on disk, COMMIT never lands."""
        path = save_checkpoint(self.ckpt_dir, t, self._ckpt_tree(),
                               aux=self._ckpt_aux(t))
        os.remove(os.path.join(path, "COMMIT"))

    def _restore(self, step: int):
        aux = restore_aux(self.ckpt_dir, step)
        if aux is None:
            raise ValueError(
                f"checkpoint step {step} in {self.ckpt_dir} has no aux.json "
                f"resume state (not an FLServer checkpoint?)")
        n_delayed = len(aux["delayed_staleness"])
        like = {
            "params": self.sim.params,
            "delayed": [self.sim.params] * n_delayed,
            "fleet_pos": np.asarray(self.sim.fleet.pos),
            "fleet_kdb": np.asarray(self.sim.fleet.k_db),
            "fleet_bad": np.asarray(self.sim.fleet._bad),
        }
        tree = restore_checkpoint(self.ckpt_dir, step, like)
        self.sim.params = tree["params"]
        self._delayed = list(zip(tree["delayed"],
                                 aux["delayed_staleness"]))
        fleet = self.sim.fleet
        fleet.pos = np.asarray(tree["fleet_pos"])
        fleet.k_db = np.asarray(tree["fleet_kdb"])
        fleet._bad = np.asarray(tree["fleet_bad"])
        self.sim.rng.bit_generator.state = aux["sim_rng"]
        fleet.rng.bit_generator.state = aux["fleet_rng"]
        self.registry = ClientRegistry.from_json(aux["registry"])
        self.round = int(aux["round"])
        self.log = SimLog()
        for r in aux["rounds_log"]:
            self.log.add(RoundLog(**r))

    # -- metrics log ---------------------------------------------------------
    def _emit_metrics(self, rlog: RoundLog):
        if self.metrics_path is None:
            return
        stal = [self.registry.staleness(r.client_id, rlog.round)
                for r in self.registry.records()]
        stal = [s for s in stal if s is not None]
        row = dict(asdict(rlog), schema=METRICS_SCHEMA,
                   scheme=self.cfg.scheme,
                   seed=self.cfg.seed,
                   registered=len(self.registry.records()),
                   mean_staleness=(float(np.mean(stal)) if stal else None))
        os.makedirs(os.path.dirname(os.path.abspath(self.metrics_path)),
                    exist_ok=True)
        with open(self.metrics_path, "a") as f:
            f.write(json.dumps(row) + "\n")


# ---------------------------------------------------------------------------
# the supervisor
# ---------------------------------------------------------------------------

def run_with_restarts(cfg: HSFLConfig, *, ckpt_dir: str, fault_plan=None,
                      rounds: Optional[int] = None, max_restarts: int = 10,
                      verbose: bool = False, **server_kw
                      ) -> Tuple[FLServer, int]:
    """Run a server to completion, eating injected crashes: each
    ``ServerCrash`` is marked consumed and a *fresh* server resumes from
    the latest committed checkpoint.  Returns (server, n_restarts)."""
    plan = as_fault_plan(fault_plan)
    consumed: set = set()
    restarts = 0
    while True:
        server = FLServer(cfg, ckpt_dir=ckpt_dir, fault_plan=plan,
                          skip_crashes=frozenset(consumed), **server_kw)
        try:
            server.serve(rounds=rounds, verbose=verbose)
            return server, restarts
        except ServerCrash as e:
            consumed.add((e.round_id, e.phase))
            restarts += 1
            if restarts > max_restarts:
                raise RuntimeError(
                    f"server crashed {restarts} times; giving up") from e
            if verbose:
                print(f"[supervisor] crash at round {e.round_id} "
                      f"({e.phase}); restarting from "
                      f"step {latest_step(ckpt_dir)}")
