"""Batching/iteration over host datasets, with epoch shuffling."""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np

from repro.data.synthetic import Dataset


def batches(ds: Dataset, batch_size: int, seed: int = 0,
            drop_remainder: bool = True) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """One epoch of shuffled minibatches."""
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(ds))
    stop = (len(ds) // batch_size) * batch_size if drop_remainder else len(ds)
    if stop == 0 and len(ds) > 0:               # tiny client: one short batch
        yield ds.x[idx], ds.y[idx]
        return
    for s in range(0, stop, batch_size):
        take = idx[s:s + batch_size]
        yield ds.x[take], ds.y[take]


def epoch_count_steps(ds: Dataset, batch_size: int) -> int:
    return max(1, len(ds) // batch_size)
