from repro.data.partition import partition
from repro.data.pipeline import batches
from repro.data.synthetic import Dataset, make_digits, make_token_stream

__all__ = ["Dataset", "batches", "make_digits", "make_token_stream", "partition"]
