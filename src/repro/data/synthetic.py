"""Synthetic datasets (offline container: no MNIST download).

``make_digits`` builds an MNIST-shaped 10-class image problem whose classes
are deterministic smoothed prototype blobs + per-sample jitter/noise — a
5-layer CNN separates it well but not trivially (accuracy climbs over tens of
FL rounds, which is what the paper's figures need).  ``make_token_stream``
builds LM token data with Zipfian unigrams + Markov bigram structure for the
framework examples.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class Dataset:
    x: np.ndarray     # images (N, 28, 28, 1) float32 or tokens (N, S) int32
    y: np.ndarray     # labels (N,) or next-token targets (N, S)

    def __len__(self) -> int:
        return len(self.x)


def _smooth(img: np.ndarray, iters: int = 2) -> np.ndarray:
    for _ in range(iters):
        img = (img
               + np.roll(img, 1, 0) + np.roll(img, -1, 0)
               + np.roll(img, 1, 1) + np.roll(img, -1, 1)) / 5.0
    return img


def make_digits(n: int, seed: int = 0, side: int = 28,
                num_classes: int = 10, noise: float = 0.8) -> Dataset:
    rng = np.random.default_rng(seed)
    protos = []
    proto_rng = np.random.default_rng(1234)      # class shapes fixed across sims
    for _ in range(num_classes):
        base = (proto_rng.random((side, side)) < 0.18).astype(np.float32)
        protos.append(_smooth(base, 4) * 3.0)
    protos = np.stack(protos)                    # (C, side, side)

    y = rng.integers(0, num_classes, n)
    shifts = rng.integers(-3, 4, (n, 2))
    xs = np.empty((n, side, side, 1), np.float32)
    for i in range(n):
        img = np.roll(protos[y[i]], tuple(shifts[i]), (0, 1))
        img = img + rng.standard_normal((side, side)).astype(np.float32) * noise
        xs[i, :, :, 0] = img
    mean, std = xs.mean(), xs.std() + 1e-6
    return Dataset(((xs - mean) / std).astype(np.float32), y.astype(np.int32))


def make_token_stream(n_seqs: int, seq_len: int, vocab: int,
                      seed: int = 0) -> Dataset:
    """Zipf unigram + noisy-successor bigram LM data."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1)
    probs = 1.0 / ranks
    probs /= probs.sum()
    succ = rng.permutation(vocab)                # deterministic bigram skeleton
    toks = np.empty((n_seqs, seq_len + 1), np.int64)
    toks[:, 0] = rng.choice(vocab, n_seqs, p=probs)
    for t in range(seq_len):
        follow = rng.random(n_seqs) < 0.7
        toks[:, t + 1] = np.where(follow, succ[toks[:, t]],
                                  rng.choice(vocab, n_seqs, p=probs))
    return Dataset(toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32))
