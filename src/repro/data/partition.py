"""Federated data partitioning — the three distributions of Section IV.

- iid: uniform random split (McMahan et al. [9]).
- non-iid: label-sorted shards, 2 classes per client ([9]'s pathological
  non-iid: "each user only accesses the samples from two classes").
- imbalanced: Hsu et al. [12] — class skew from Dirichlet(α_d) and dataset
  size imbalance from a power-law with exponent tied to α_imd
  (paper setting: α_d = 0.01, α_imd = 2).
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.data.synthetic import Dataset


def _subset(ds: Dataset, idx: np.ndarray) -> Dataset:
    return Dataset(ds.x[idx], ds.y[idx])


def partition_iid(ds: Dataset, n_clients: int, seed: int = 0) -> List[Dataset]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(ds))
    return [_subset(ds, part) for part in np.array_split(idx, n_clients)]


def partition_noniid(ds: Dataset, n_clients: int, classes_per_client: int = 2,
                     seed: int = 0) -> List[Dataset]:
    """Each client sees exactly ``classes_per_client`` classes ([9]'s
    pathological non-iid: 'each user only accesses samples from two
    classes').  Class pools are sliced round-robin so shards never straddle
    a class boundary."""
    rng = np.random.default_rng(seed)
    classes = np.unique(ds.y)
    pools = {c: rng.permutation(np.where(ds.y == c)[0]) for c in classes}
    # round-robin class pairs, shuffled for variety
    picks = []
    for i in range(n_clients):
        start = (i * classes_per_client) % len(classes)
        picks.append([classes[(start + j) % len(classes)]
                      for j in range(classes_per_client)])
    rng.shuffle(picks)
    uses = {c: sum(c in row for row in picks) for c in classes}
    cursor = {c: 0 for c in classes}
    out = []
    for row in picks:
        idx = []
        for c in row:
            share = len(pools[c]) // max(uses[c], 1)
            s = cursor[c]
            idx.append(pools[c][s:s + share])
            cursor[c] += share
        out.append(_subset(ds, np.concatenate(idx)))
    return out


def partition_imbalanced(ds: Dataset, n_clients: int, alpha_d: float = 0.01,
                         alpha_imd: float = 2.0, seed: int = 0) -> List[Dataset]:
    """Dirichlet class skew + power-law size imbalance [12]."""
    rng = np.random.default_rng(seed)
    classes = np.unique(ds.y)
    by_class = {c: rng.permutation(np.where(ds.y == c)[0]) for c in classes}
    used = {c: 0 for c in classes}
    # sizes: power law, smaller alpha_imd => more imbalanced
    raw = rng.pareto(alpha_imd, n_clients) + 1.0
    sizes = np.maximum((raw / raw.sum() * len(ds)).astype(int), 8)
    out = []
    for i in range(n_clients):
        pvec = rng.dirichlet(np.full(len(classes), alpha_d))
        counts = rng.multinomial(sizes[i], pvec)
        take = []
        for c, k in zip(classes, counts):
            pool = by_class[c]
            start = used[c]
            grab = pool[start:start + k]
            used[c] = min(start + k, len(pool))
            take.append(grab)
        idx = np.concatenate(take) if take else np.empty(0, int)
        if len(idx) == 0:                    # guarantee non-empty clients
            idx = rng.integers(0, len(ds), 8)
        out.append(_subset(ds, idx))
    return out


def partition(ds: Dataset, n_clients: int, dist: str, seed: int = 0) -> List[Dataset]:
    if dist == "iid":
        return partition_iid(ds, n_clients, seed)
    if dist == "noniid":
        return partition_noniid(ds, n_clients, seed=seed)
    if dist == "imbalanced":
        return partition_imbalanced(ds, n_clients, seed=seed)
    raise ValueError(f"unknown distribution {dist!r}")
