from repro.checkpoint.msgpack_ckpt import (latest_step, restore_aux,
                                           restore_checkpoint,
                                           save_checkpoint)

__all__ = ["latest_step", "restore_aux", "restore_checkpoint",
           "save_checkpoint"]
