"""Checkpointing: msgpack-serialized pytrees with dtype/shape manifest.

Layout: ``<dir>/<step>/checkpoint.msgpack + MANIFEST.json [+ aux.json]``;
``latest_step`` resolves the newest *complete* save — a COMMIT marker
finalizes a save, so a crashed writer (directory present, marker absent)
is silently skipped rather than ever yielding a half-read checkpoint.

``MANIFEST.json`` records per-leaf dtype/shape; ``restore_checkpoint``
validates the decoded leaves against it (and against the ``like`` tree)
with a clear error instead of a silent mismatch.  ``aux`` carries small
JSON-able sidecar state (RNG bit-generator states, registries, counters)
that rides the same COMMIT atomicity as the tensor payload — the FL
serving path checkpoints its whole resume state through it.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _encode_leaf(x) -> Dict[str, Any]:
    arr = np.asarray(x)
    if arr.dtype == jnp.bfloat16:
        return {"dtype": "bfloat16", "shape": list(arr.shape),
                "data": arr.view(np.uint16).tobytes()}
    return {"dtype": str(arr.dtype), "shape": list(arr.shape),
            "data": arr.tobytes()}


def _decode_leaf(d: Dict[str, Any]) -> np.ndarray:
    # np.frombuffer views the (immutable) msgpack bytes, so the raw array is
    # read-only; copy so restored pytrees are writable like any fresh array
    if d["dtype"] == "bfloat16":
        raw = np.frombuffer(d["data"], np.uint16).reshape(d["shape"]).copy()
        return raw.view(jnp.bfloat16)
    return np.frombuffer(d["data"], np.dtype(d["dtype"])) \
        .reshape(d["shape"]).copy()


def _leaf_spec(d: Dict[str, Any]) -> Dict[str, Any]:
    return {"dtype": d["dtype"], "shape": list(d["shape"])}


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    aux: Optional[Dict[str, Any]] = None) -> str:
    """Write step ``step``; only the final COMMIT marker makes it visible.

    ``aux`` is an optional JSON-serializable sidecar (restored by
    ``restore_aux``) committed atomically with the tensor payload.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    path = os.path.join(ckpt_dir, str(step))
    os.makedirs(path, exist_ok=True)
    enc = [_encode_leaf(x) for x in leaves]
    payload = msgpack.packb(enc, use_bin_type=True)
    tmp = os.path.join(path, "checkpoint.msgpack.tmp")
    with open(tmp, "wb") as f:
        f.write(payload)
    os.replace(tmp, os.path.join(path, "checkpoint.msgpack"))
    with open(os.path.join(path, "MANIFEST.json"), "w") as f:
        json.dump({"step": step, "num_leaves": len(leaves),
                   "treedef": str(treedef),
                   "leaves": [_leaf_spec(d) for d in enc]}, f)
    if aux is not None:
        with open(os.path.join(path, "aux.json"), "w") as f:
            json.dump(aux, f)
    open(os.path.join(path, "COMMIT"), "w").close()
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest committed step, or None.  Half-written saves — a step
    directory without its COMMIT marker (crashed writer), or a stray
    non-directory entry — are skipped, never an error."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d) for d in os.listdir(ckpt_dir)
             if d.isdigit() and os.path.isdir(os.path.join(ckpt_dir, d))
             and os.path.exists(os.path.join(ckpt_dir, d, "COMMIT"))]
    return max(steps) if steps else None


def _load_manifest(path: str) -> Optional[Dict[str, Any]]:
    mpath = os.path.join(path, "MANIFEST.json")
    if not os.path.exists(mpath):
        return None
    with open(mpath) as f:
        return json.load(f)


def restore_checkpoint(ckpt_dir: str, step: int, like: Any) -> Any:
    """Restore into the structure of ``like``.

    Decoded leaves are validated twice: against the save-time
    ``MANIFEST.json`` specs (corruption / partial write shows up as a
    manifest mismatch naming the leaf) and against ``like`` (a changed
    model shows up as a shape/dtype mismatch naming both sides).
    """
    path = os.path.join(ckpt_dir, str(step))
    with open(os.path.join(path, "checkpoint.msgpack"), "rb") as f:
        enc = msgpack.unpackb(f.read(), raw=False)
    manifest = _load_manifest(path)
    leaves, treedef = jax.tree_util.tree_flatten(like)
    if len(enc) != len(leaves):
        raise ValueError(f"checkpoint has {len(enc)} leaves, expected "
                         f"{len(leaves)}")
    specs: List[Optional[Dict[str, Any]]] = [None] * len(enc)
    if manifest is not None and "leaves" in manifest:
        if len(manifest["leaves"]) != len(enc):
            raise ValueError(
                f"MANIFEST.json records {len(manifest['leaves'])} leaves "
                f"but the payload holds {len(enc)} — the save is "
                f"inconsistent (corrupt or mixed-version)")
        specs = list(manifest["leaves"])
    decoded = []
    for i, (d, ref, spec) in enumerate(zip(enc, leaves, specs)):
        arr = _decode_leaf(d)
        if spec is not None and (
                list(arr.shape) != list(spec["shape"])
                or d["dtype"] != spec["dtype"]):
            raise ValueError(
                f"leaf {i}: decoded {d['dtype']}{tuple(arr.shape)} does not "
                f"match MANIFEST.json {spec['dtype']}{tuple(spec['shape'])} "
                f"— the checkpoint payload is corrupt")
        if tuple(arr.shape) != tuple(np.shape(ref)):
            raise ValueError(f"leaf {i}: shape mismatch {arr.shape} vs "
                             f"{np.shape(ref)}")
        decoded.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, decoded)


def restore_aux(ckpt_dir: str, step: int) -> Optional[Dict[str, Any]]:
    """The JSON sidecar saved alongside step ``step`` (None if absent)."""
    path = os.path.join(ckpt_dir, str(step), "aux.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)
