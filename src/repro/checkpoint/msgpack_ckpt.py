"""Checkpointing: msgpack-serialized pytrees with dtype/shape manifest.

Layout: <dir>/<step>/checkpoint.msgpack + MANIFEST.json; ``latest_step``
resolves the newest complete save (a COMMIT marker finalizes a save, so a
crashed writer never yields a half-read checkpoint).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _encode_leaf(x) -> Dict[str, Any]:
    arr = np.asarray(x)
    if arr.dtype == jnp.bfloat16:
        return {"dtype": "bfloat16", "shape": list(arr.shape),
                "data": arr.view(np.uint16).tobytes()}
    return {"dtype": str(arr.dtype), "shape": list(arr.shape),
            "data": arr.tobytes()}


def _decode_leaf(d: Dict[str, Any]) -> np.ndarray:
    if d["dtype"] == "bfloat16":
        raw = np.frombuffer(d["data"], np.uint16).reshape(d["shape"])
        return raw.view(jnp.bfloat16)
    return np.frombuffer(d["data"], np.dtype(d["dtype"])).reshape(d["shape"])


def save_checkpoint(ckpt_dir: str, step: int, tree: Any) -> str:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    path = os.path.join(ckpt_dir, str(step))
    os.makedirs(path, exist_ok=True)
    payload = msgpack.packb([_encode_leaf(x) for x in leaves], use_bin_type=True)
    with open(os.path.join(path, "checkpoint.msgpack"), "wb") as f:
        f.write(payload)
    with open(os.path.join(path, "MANIFEST.json"), "w") as f:
        json.dump({"step": step, "num_leaves": len(leaves),
                   "treedef": str(treedef)}, f)
    open(os.path.join(path, "COMMIT"), "w").close()
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d) for d in os.listdir(ckpt_dir)
             if d.isdigit() and os.path.exists(os.path.join(ckpt_dir, d, "COMMIT"))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype validated)."""
    path = os.path.join(ckpt_dir, str(step), "checkpoint.msgpack")
    with open(path, "rb") as f:
        enc = msgpack.unpackb(f.read(), raw=False)
    leaves, treedef = jax.tree_util.tree_flatten(like)
    if len(enc) != len(leaves):
        raise ValueError(f"checkpoint has {len(enc)} leaves, expected {len(leaves)}")
    decoded = []
    for d, ref in zip(enc, leaves):
        arr = _decode_leaf(d)
        if tuple(arr.shape) != tuple(np.shape(ref)):
            raise ValueError(f"shape mismatch {arr.shape} vs {np.shape(ref)}")
        decoded.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, decoded)
