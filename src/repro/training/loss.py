"""Loss functions: cross-entropy over (padded) vocab, with masking."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean CE.  logits (..., V) — padded slots already masked to -1e9;
    labels (...) int; mask (...) optional bool/float weighting."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        w = mask.astype(jnp.float32)
        return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)
    return jnp.mean(nll)


def fused_head_cross_entropy(head_params, embed_params, cfg, hidden: jnp.ndarray,
                             labels: jnp.ndarray,
                             mask: Optional[jnp.ndarray] = None,
                             chunk: int = 512) -> jnp.ndarray:
    """CE without materializing the full (B, S, V) logits tensor.

    §Perf memory lever: at vocab 128k x 1M train tokens the logits tensor is
    ~0.5 TB of HBM traffic; computing head-projection + logsumexp per
    sequence chunk (recomputed in the backward via jax.checkpoint) keeps the
    live logits at (B, chunk, V).
    """
    from repro.models.layers import lm_logits

    B, S, _ = hidden.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n = S // chunk

    @jax.checkpoint
    def chunk_loss(args):
        h, y, w = args
        logits = lm_logits(head_params, embed_params, cfg, h).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        return jnp.sum((logz - gold) * w), jnp.sum(w)

    def body(carry, i):
        tot, cnt = carry
        h = jax.lax.dynamic_slice_in_dim(hidden, i * chunk, chunk, axis=1)
        y = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, axis=1)
        w = (jax.lax.dynamic_slice_in_dim(mask, i * chunk, chunk, axis=1)
             .astype(jnp.float32) if mask is not None
             else jnp.ones((B, chunk), jnp.float32))
        s, c = chunk_loss((h, y, w))
        return (tot + s, cnt + c), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 jnp.arange(n))
    return tot / jnp.maximum(cnt, 1.0)


def accuracy(logits: jnp.ndarray, labels: jnp.ndarray,
             mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    pred = jnp.argmax(logits, axis=-1)
    hit = (pred == labels).astype(jnp.float32)
    if mask is not None:
        w = mask.astype(jnp.float32)
        return jnp.sum(hit * w) / jnp.maximum(jnp.sum(w), 1.0)
    return jnp.mean(hit)
