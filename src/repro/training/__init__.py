from repro.training.loss import accuracy, cross_entropy
from repro.training.step import (loss_fn, make_decode_step, make_prefill_step,
                                 make_train_step)
from repro.training.train_state import TrainState, create_train_state

__all__ = ["TrainState", "accuracy", "create_train_state", "cross_entropy",
           "loss_fn", "make_decode_step", "make_prefill_step", "make_train_step"]
