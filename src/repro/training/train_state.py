"""TrainState pytree: params + optimizer state + step counter.

Also carries the OpportunisticSync snapshot slots when the pod-axis OPT
feature is enabled (core/opportunistic_sync.py)."""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax.numpy as jnp


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray
    # OpportunisticSync slots (None when the feature is off)
    snapshot: Optional[Any] = None
    snapshot_step: Optional[jnp.ndarray] = None
    tau_extra: Optional[jnp.ndarray] = None


def create_train_state(params: Any, optimizer, with_opt_sync: bool = False,
                       tau_extra0: float = 0.0) -> TrainState:
    import jax
    opt_state = optimizer.init(params)
    if with_opt_sync:
        return TrainState(
            params=params, opt_state=opt_state, step=jnp.zeros((), jnp.int32),
            snapshot=jax.tree_util.tree_map(jnp.copy, params),
            snapshot_step=jnp.asarray(-1, jnp.int32),
            tau_extra=jnp.asarray(tau_extra0, jnp.float32))
    return TrainState(params=params, opt_state=opt_state,
                      step=jnp.zeros((), jnp.int32))
