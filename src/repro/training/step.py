"""train_step / prefill_step factories shared by smoke tests, examples, the
FL simulation, and the multi-pod dry-run."""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.registry import Model
from repro.optim.sgd import Optimizer, apply_updates, clip_by_global_norm
from repro.training.loss import cross_entropy
from repro.training.train_state import TrainState


def loss_fn(model: Model, params, batch: Dict[str, Any],
            opts: Optional[dict] = None) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    mask = batch.get("mask")
    if opts and opts.get("fused_head"):
        from repro.training.loss import fused_head_cross_entropy
        hidden, aux = model.forward(params, batch,
                                    {**opts, "return_hidden": True})
        ce = fused_head_cross_entropy(params.get("head"), params.get("embed"),
                                      model.cfg, hidden, batch["labels"], mask)
    else:
        logits, aux = model.forward(params, batch, opts)
        ce = cross_entropy(logits, batch["labels"], mask)
    total = ce + model.cfg.router_aux_coef * aux if model.cfg.num_experts else ce
    return total, {"ce": ce, "aux": aux}


def make_train_step(model: Model, optimizer: Optimizer,
                    opts: Optional[dict] = None,
                    grad_clip: float = 0.0) -> Callable:
    """Returns step(state, batch) -> (state, metrics).  Pure, jit-able."""

    def step(state: TrainState, batch: Dict[str, Any]):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(model, p, batch, opts), has_aux=True)(state.params)
        if grad_clip:
            grads = clip_by_global_norm(grads, grad_clip)
        updates, opt_state = optimizer.update(grads, state.opt_state, state.params)
        params = apply_updates(state.params, updates)
        new_state = state._replace(params=params, opt_state=opt_state,
                                   step=state.step + 1)
        return new_state, {"loss": loss, **parts}

    return step


def make_prefill_step(model: Model, opts: Optional[dict] = None) -> Callable:
    """Forward-only step (inference prefill / encoder encode)."""

    def step(params, batch: Dict[str, Any]):
        logits, _ = model.forward(params, batch, opts)
        return logits

    return step


def make_decode_step(model: Model, opts: Optional[dict] = None) -> Callable:
    """One-token serve step: (params, token, state, position) -> (logits, state)."""
    assert model.decode is not None, f"{model.cfg.name} has no decode step"

    def step(params, token, state, position):
        return model.decode(params, token, state, position, opts)

    return step
