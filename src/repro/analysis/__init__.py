"""repro.analysis — repo-specific static analysis for the JAX engines.

Three halves (see ANALYSIS.md for the rule list and rationale):

- ``lint``: AST rules over the repo's own invariants (scheme-registry
  dispatch, host-sync-free traced bodies, RNG discipline, donated jits,
  dtype-policy threading, numpy-free hot modules);
- ``contracts``: shape/dtype contracts for the public entry points via
  ``jax.eval_shape`` — no execution;
- ``guards``: runtime context managers (compile budgets, transfer guards,
  leak checks) the guarded test/CI smokes run under.

CLI: ``python -m repro.analysis`` — file:line findings, exit 1 on any
non-baselined violation.
"""
from repro.analysis.findings import Baseline, Finding
from repro.analysis.guards import (CompileBudgetExceeded, CompileCounter,
                                   compile_budget, engine_guard, leak_check,
                                   no_implicit_transfers)
from repro.analysis.lint import all_rules, lint_paths, lint_source

__all__ = [
    "Baseline", "Finding", "CompileBudgetExceeded", "CompileCounter",
    "compile_budget", "engine_guard", "leak_check", "no_implicit_transfers",
    "all_rules", "lint_paths", "lint_source",
]
