"""except-swallow: retry paths may not silently eat broad exceptions.

Motivation (PR 6/PR 9): the serving and transport layers are built on
deliberate fault injection — dropped chunks, timed-out uploads, crashed
rounds — and their correctness story is that every fault is either
retried, logged, or surfaced.  A ``except Exception: pass`` (or
``continue``) in those paths converts an injected fault into silent data
loss: the aggregation round proceeds with a missing update and the test
suite can't tell.  Any handler for bare ``Exception``/``BaseException``
(or an untyped ``except:``) whose entire body is ``pass``/``continue``
under ``serving/``, ``core/transport.py`` or ``core/faults.py`` is a
finding.  Deliberate swallow sites (e.g. best-effort cleanup) are
annotated inline with ``# analysis: ok=except-swallow``.
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.findings import Finding
from repro.analysis.lint import ModuleContext, Rule, dotted_name, \
    register_rule

_BROAD = ("Exception", "BaseException")
_SCOPE_PREFIXES = ("src/repro/serving/",)
_SCOPE_FILES = ("src/repro/core/transport.py", "src/repro/core/faults.py")


def _is_broad(type_node) -> bool:
    if type_node is None:            # untyped `except:`
        return True
    d = dotted_name(type_node)
    return d is not None and d.split(".")[-1] in _BROAD


@register_rule
class ExceptSwallowRule(Rule):
    name = "except-swallow"
    description = ("'except Exception: pass/continue' in serving/transport "
                   "retry paths swallows injected faults")

    def applies(self, relpath: str) -> bool:
        return (relpath in _SCOPE_FILES
                or any(relpath.startswith(p) for p in _SCOPE_PREFIXES))

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if not _is_broad(node.type):
                continue
            if len(node.body) == 1 and \
                    isinstance(node.body[0], (ast.Pass, ast.Continue)):
                kind = ("pass" if isinstance(node.body[0], ast.Pass)
                        else "continue")
                caught = (dotted_name(node.type)
                          if node.type is not None else "everything")
                yield ctx.finding(
                    node, self.name,
                    f"handler catches {caught} and only does '{kind}' — "
                    f"in a fault-injected retry path this turns faults "
                    f"into silent data loss; re-raise, log, or record the "
                    f"failure ('# analysis: ok=except-swallow' for "
                    f"deliberate best-effort sites)")
