"""scheme-branch: no scheme-string branching outside ``core/schemes.py``.

Motivation (PR 5): before the registry, every engine branched on
``scheme == "opt"``-style strings and new schemes meant editing all of
them; PR 5 made ``repro.core.schemes`` the single dispatch point.  This
rule keeps it that way: any comparison between a ``*scheme*``-named value
and a string literal (or literal collection) inside ``src/repro`` is a
finding.  Presentation code *outside* ``src/repro`` (benchmarks filtering
result groups by ``g.scheme``) is out of scope — the invariant is about
engine logic, not labels.
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.findings import Finding
from repro.analysis.lint import ModuleContext, Rule, register_rule

_EXEMPT = ("src/repro/core/schemes.py", "src/repro/analysis/")


def _mentions_scheme(node: ast.AST) -> bool:
    if isinstance(node, ast.Name):
        return node.id.lower().endswith("scheme")
    if isinstance(node, ast.Attribute):
        return node.attr.lower().endswith("scheme")
    return False


def _is_str_literal(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant):
        return isinstance(node.value, str)
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        return bool(node.elts) and all(_is_str_literal(e)
                                       for e in node.elts)
    return False


@register_rule
class SchemeBranchRule(Rule):
    name = "scheme-branch"
    description = ("no scheme ==/in string branching outside "
                   "core/schemes.py — dispatch through the registry")

    def applies(self, relpath: str) -> bool:
        return relpath.startswith("src/repro/") \
            and not relpath.startswith(_EXEMPT[1]) \
            and relpath != _EXEMPT[0]

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Compare):
                continue
            if not all(isinstance(op, (ast.Eq, ast.NotEq, ast.In, ast.NotIn))
                       for op in node.ops):
                continue
            operands = [node.left] + list(node.comparators)
            if any(_mentions_scheme(o) for o in operands) \
                    and any(_is_str_literal(o) for o in operands):
                yield ctx.finding(
                    node, self.name,
                    "scheme-string branch outside core/schemes.py; "
                    "dispatch through get_scheme(...)/a Scheme method")
