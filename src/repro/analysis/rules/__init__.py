"""Lint rules — importing this package registers every rule."""
from repro.analysis.rules import (dtype_policy, except_swallow, host_sync,
                                  jit_donate, numpy_hot, rng_discipline,
                                  scheme_strings)  # noqa: F401
