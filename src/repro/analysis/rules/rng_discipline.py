"""rng-reuse: a PRNG key is consumed at most once per path.

Motivation (channel calibration + the device engines): feeding the same
key to two ``jax.random`` draws silently correlates them — in this repo
that means fading and outage streams that move in lockstep, which skews
the eq. 1-7 channel statistics without failing any shape check.  The rule
does a per-function, statement-ordered walk:

- a ``jax.random`` *distribution* call (normal, uniform, randint, ...)
  and ``jax.random.split`` **consume** their key argument;
- ``fold_in`` / ``PRNGKey`` / ``key`` / ``clone`` do not (repeated
  ``fold_in(key, e)`` with distinct data is the idiomatic stream split);
- rebinding a name resets it; branches of an ``if`` are analyzed
  independently (two exclusive arms may each consume the same key);
- consuming a key inside a loop whose binding lives outside the loop is
  a reuse (the same key every iteration).

Only first-argument *names* are tracked — composite expressions like
``normal(fold_in(k, i), ...)`` derive fresh keys by construction.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional

from repro.analysis.findings import Finding
from repro.analysis.lint import ModuleContext, Rule, dotted_name, \
    register_rule

CONSUMING = frozenset({
    "normal", "uniform", "randint", "bernoulli", "permutation",
    "categorical", "choice", "gumbel", "exponential", "laplace", "logistic",
    "truncated_normal", "bits", "poisson", "dirichlet", "beta", "gamma",
    "cauchy", "rademacher", "maxwell", "orthogonal", "ball", "split",
})
_RANDOM_BASES = ("jax.random.", "jrandom.", "random.")


def _consuming_key(call: ast.Call) -> Optional[str]:
    """Name of the key consumed by ``call``, if any."""
    d = dotted_name(call.func)
    if d is None:
        return None
    base, _, fn = d.rpartition(".")
    if fn not in CONSUMING or not (base + ".").startswith(_RANDOM_BASES) \
            and not d.startswith(_RANDOM_BASES):
        return None
    if call.args and isinstance(call.args[0], ast.Name):
        return call.args[0].id
    return None


class _Walker:
    """Statement-ordered abstract walk of one function body."""

    def __init__(self, ctx: ModuleContext, rule: str):
        self.ctx = ctx
        self.rule = rule
        self.findings: List[Finding] = []

    def run(self, body) -> None:
        self._block(body, bindings={}, consumed={}, depth=0)

    # state: bindings name->loop depth of binding; consumed name->node
    def _block(self, stmts, bindings: Dict[str, int],
               consumed: Dict[str, ast.AST], depth: int) -> None:
        for stmt in stmts:
            self._stmt(stmt, bindings, consumed, depth)

    def _stmt(self, stmt, bindings, consumed, depth) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            a = stmt.args
            params = {p.arg: 0 for p in (a.posonlyargs + a.args
                                         + a.kwonlyargs)}
            self._block(stmt.body, params, {}, 0)
            return
        if isinstance(stmt, ast.ClassDef):
            self._block(stmt.body, {}, {}, 0)
            return
        if isinstance(stmt, (ast.If,)):
            self._exprs(stmt.test, bindings, consumed, depth)
            b1, c1 = dict(bindings), dict(consumed)
            b2, c2 = dict(bindings), dict(consumed)
            self._block(stmt.body, b1, c1, depth)
            self._block(stmt.orelse, b2, c2, depth)
            consumed.clear()
            consumed.update(c1)
            consumed.update(c2)
            bindings.update(b1)
            bindings.update(b2)
            return
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._exprs(stmt.iter, bindings, consumed, depth)
            self._bind_target(stmt.target, bindings, consumed, depth + 1)
            self._block(stmt.body, bindings, consumed, depth + 1)
            self._block(stmt.orelse, bindings, consumed, depth)
            return
        if isinstance(stmt, ast.While):
            self._exprs(stmt.test, bindings, consumed, depth + 1)
            self._block(stmt.body, bindings, consumed, depth + 1)
            self._block(stmt.orelse, bindings, consumed, depth)
            return
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._exprs(item.context_expr, bindings, consumed, depth)
            self._block(stmt.body, bindings, consumed, depth)
            return
        if isinstance(stmt, ast.Try):
            self._block(stmt.body, bindings, consumed, depth)
            for h in stmt.handlers:
                self._block(h.body, dict(bindings), dict(consumed), depth)
            self._block(stmt.orelse, bindings, consumed, depth)
            self._block(stmt.finalbody, bindings, consumed, depth)
            return
        if isinstance(stmt, ast.Assign):
            # `sub, key = split(key)` chaining: the statement rebinds the
            # key it consumes — exempt from the loop-reuse check
            rebound = set()
            for t in stmt.targets:
                self._target_names(t, rebound)
            self._exprs(stmt.value, bindings, consumed, depth,
                        rebinding=rebound)
            for t in stmt.targets:
                self._bind_target(t, bindings, consumed, depth)
            return
        if isinstance(stmt, ast.AugAssign):
            self._exprs(stmt.value, bindings, consumed, depth)
            self._bind_target(stmt.target, bindings, consumed, depth)
            return
        if isinstance(stmt, ast.AnnAssign):
            if stmt.value is not None:
                self._exprs(stmt.value, bindings, consumed, depth)
            self._bind_target(stmt.target, bindings, consumed, depth)
            return
        if isinstance(stmt, ast.Return) and stmt.value is not None:
            self._exprs(stmt.value, bindings, consumed, depth)
            return
        if isinstance(stmt, ast.Expr):
            self._exprs(stmt.value, bindings, consumed, depth)
            return
        # anything else: scan its expressions conservatively
        for child in ast.iter_child_nodes(stmt):
            if isinstance(child, ast.expr):
                self._exprs(child, bindings, consumed, depth)

    def _target_names(self, target, out: set) -> None:
        if isinstance(target, ast.Name):
            out.add(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self._target_names(el, out)
        elif isinstance(target, ast.Starred):
            self._target_names(target.value, out)

    def _bind_target(self, target, bindings, consumed, depth) -> None:
        if isinstance(target, ast.Name):
            bindings[target.id] = depth
            consumed.pop(target.id, None)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self._bind_target(el, bindings, consumed, depth)
        elif isinstance(target, ast.Starred):
            self._bind_target(target.value, bindings, consumed, depth)

    def _exprs(self, expr, bindings, consumed, depth,
               rebinding: set = frozenset()) -> None:
        for node in ast.walk(expr):
            if isinstance(node, (ast.Lambda, ast.ListComp, ast.SetComp,
                                 ast.DictComp, ast.GeneratorExp)):
                continue  # handled below / out of scope for the linear walk
            if not isinstance(node, ast.Call):
                continue
            key = _consuming_key(node)
            if key is None:
                continue
            if key in consumed:
                self.findings.append(self.ctx.finding(
                    node, self.rule,
                    f"PRNG key {key!r} already consumed at line "
                    f"{consumed[key].lineno}; split (or fold_in) before "
                    f"reusing it"))
            elif key in bindings and bindings[key] < depth \
                    and key not in rebinding:
                self.findings.append(self.ctx.finding(
                    node, self.rule,
                    f"PRNG key {key!r} bound outside this loop is "
                    f"consumed every iteration; derive a per-iteration "
                    f"key (fold_in/split)"))
            else:
                consumed[key] = node


@register_rule
class RngReuseRule(Rule):
    name = "rng-reuse"
    description = ("no jax.random key consumed twice (or loop-consumed) "
                   "without an intervening split/fold_in")

    def applies(self, relpath: str) -> bool:
        return not relpath.startswith("src/repro/analysis/")

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        w = _Walker(ctx, self.name)
        w.run(ctx.tree.body)
        return w.findings
