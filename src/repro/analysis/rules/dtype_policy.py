"""dtype-thread: dtype-policy parameters must be threaded, not shadowed.

Motivation (PR 7): the ``ForwardPolicy.precision`` plumbing works only if
every function that *accepts* a compute-dtype parameter actually honors
it — a kernel that takes ``compute_dtype`` and then hard-codes
``astype(jnp.float32)`` silently pins the path to f32 and the bf16 sweep
rows measure nothing.  For functions in ``kernels/`` and ``models/``
declaring a dtype-like parameter (``compute_dtype``/``dtype``/
``out_dtype``/...), this rule flags

- a parameter the body never references, and
- ``.astype(jnp.float32 | jnp.bfloat16 | jnp.float16)`` with a hard-coded
  dtype — deliberate f32-accumulation contracts are allowlisted inline
  where they occur (the pragma doubles as documentation).
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.findings import Finding
from repro.analysis.lint import ModuleContext, Rule, dotted_name, \
    register_rule

DTYPE_PARAMS = frozenset({"compute_dtype", "dtype", "out_dtype",
                          "param_dtype", "acc_dtype"})
_HARD_DTYPES = frozenset({"jnp.float32", "jnp.bfloat16", "jnp.float16",
                          "np.float32"})


@register_rule
class DtypeThreadRule(Rule):
    name = "dtype-thread"
    description = ("functions taking a compute_dtype/dtype policy must "
                   "thread it instead of hard-coding jnp.float32")

    def applies(self, relpath: str) -> bool:
        return relpath.startswith(("src/repro/kernels/", "src/repro/models/"))

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            args = fn.args
            names = [a.arg for a in (args.posonlyargs + args.args
                                     + args.kwonlyargs)]
            dtype_args = [n for n in names if n in DTYPE_PARAMS]
            if not dtype_args:
                continue
            used = {n.id for sub in fn.body for n in ast.walk(sub)
                    if isinstance(n, ast.Name)}
            for missing in (a for a in dtype_args if a not in used):
                yield ctx.finding(
                    fn, self.name,
                    f"dtype parameter {missing!r} of {fn.name}() is never "
                    f"threaded into the body")
            for sub in ast.walk(fn):
                if isinstance(sub, ast.Call) \
                        and isinstance(sub.func, ast.Attribute) \
                        and sub.func.attr == "astype" and sub.args:
                    d = dotted_name(sub.args[0])
                    if d in _HARD_DTYPES:
                        yield ctx.finding(
                            sub, self.name,
                            f"{fn.name}() takes {dtype_args[0]!r} but "
                            f"hard-codes astype({d}); thread the policy "
                            f"dtype (pragma if this is a deliberate "
                            f"accumulation contract)")
