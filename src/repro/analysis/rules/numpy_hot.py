"""np-hot: no host numpy in the device-resident hot modules.

Motivation (PR 1/PR 7): the fused round, the schemes' traced methods and
every kernel package are device code end to end — a ``np.`` call there
either breaks under jit or forces an eager host round-trip.  Host
*constants* (``np.pi``, dtype objects) are fine; everything else in the
hot-module list below must be ``jnp``.  Host orchestration modules
(``sweep.py``'s AOT driver, ``selection.py``'s greedy schedule,
``hsfl.py``'s host engine) legitimately use numpy and are not listed.
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.findings import Finding
from repro.analysis.lint import ModuleContext, Rule, register_rule

HOT_MODULES = (
    "src/repro/core/fused_round.py",
    "src/repro/core/schemes.py",
    "src/repro/core/channel_lib.py",
    "src/repro/core/opportunistic_sync.py",
    "src/repro/core/transmission.py",
    "src/repro/kernels/",
)

# host constants and dtype objects are jit-safe trace-time values
ALLOWED_ATTRS = frozenset({
    "pi", "e", "inf", "nan", "euler_gamma", "newaxis",
    "float32", "float64", "float16", "int32", "int64", "int16", "int8",
    "uint8", "uint32", "bool_", "ndarray", "dtype", "generic",
})


@register_rule
class NumpyHotRule(Rule):
    name = "np-hot"
    description = ("no np.* (beyond constants/dtypes) in core//kernels/ "
                   "hot modules — device code is jnp end to end")

    def applies(self, relpath: str) -> bool:
        return relpath.startswith(HOT_MODULES)

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Attribute):
                continue
            if not isinstance(node.value, ast.Name) \
                    or node.value.id not in ("np", "numpy"):
                continue
            if node.attr in ALLOWED_ATTRS:
                continue
            # np.random.<x> chains surface as Attribute(np, 'random')
            yield ctx.finding(
                node, self.name,
                f"host numpy ({node.value.id}.{node.attr}) in a hot "
                f"module; use jnp (np constants/dtypes are exempt)")
