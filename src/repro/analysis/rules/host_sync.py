"""host-sync: no host-synchronizing primitives inside traced code.

Motivation (PR 1/PR 2): the fused round and the sweep engine exist to
eliminate host round-trips; one stray ``.item()``/``float()``/``np.*`` on
a traced value either crashes under jit (TracerConversionError) or —
worse — silently forces a device sync per step when the surrounding code
happens to run eagerly.  Inside traced scopes (see ``lint.ModuleContext``)
in ``core/`` and ``kernels/`` this rule flags:

- ``.item()`` / ``.tolist()`` / ``.block_until_ready()``
- any ``np.*`` call (host numpy cannot consume tracers)
- ``time.time()``-family wall clocks (trace-time constants, a classic
  silent bug in scanned bodies)
- ``jax.device_get``
- ``float()/int()/bool()`` on non-static values (shape/ndim/len
  expressions and literals are trace-time constants and stay legal)
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.findings import Finding
from repro.analysis.lint import ModuleContext, Rule, dotted_name, \
    register_rule

_SYNC_METHODS = frozenset({"item", "tolist", "block_until_ready"})
_CLOCKS = frozenset({"time.time", "time.perf_counter", "time.monotonic",
                     "time.process_time"})
_STATIC_ATTRS = frozenset({"shape", "ndim", "size", "dtype"})


def _is_static(expr: ast.AST) -> bool:
    """Conservatively: is ``expr`` a trace-time constant?"""
    if isinstance(expr, ast.Constant):
        return True
    if isinstance(expr, ast.Attribute):
        return expr.attr in _STATIC_ATTRS or _is_static(expr.value)
    if isinstance(expr, ast.Subscript):
        return _is_static(expr.value)
    if isinstance(expr, ast.BinOp):
        return _is_static(expr.left) and _is_static(expr.right)
    if isinstance(expr, ast.UnaryOp):
        return _is_static(expr.operand)
    if isinstance(expr, ast.Call):
        d = dotted_name(expr.func)
        if d == "len":
            return True
        if d in ("int", "float", "bool"):
            return all(_is_static(a) for a in expr.args)
        return False
    return False


@register_rule
class HostSyncRule(Rule):
    name = "host-sync"
    description = ("no .item()/float()/np.*/time.time() on traced values "
                   "inside jitted or scanned bodies in core/ and kernels/")

    def applies(self, relpath: str) -> bool:
        return relpath.startswith(("src/repro/core/", "src/repro/kernels/"))

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call) \
                    or not ctx.in_traced_scope(node):
                continue
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _SYNC_METHODS:
                yield ctx.finding(
                    node, self.name,
                    f".{node.func.attr}() forces a host sync inside a "
                    f"traced body")
                continue
            d = dotted_name(node.func)
            if d is None:
                continue
            if d.startswith("np.") or d.startswith("numpy."):
                yield ctx.finding(
                    node, self.name,
                    f"host numpy call {d}() inside a traced body "
                    f"(use jnp)")
            elif d in _CLOCKS:
                yield ctx.finding(
                    node, self.name,
                    f"{d}() in a traced body is a trace-time constant, "
                    f"not a clock")
            elif d == "jax.device_get":
                yield ctx.finding(
                    node, self.name,
                    "jax.device_get inside a traced body forces a host "
                    "sync")
            elif d in ("float", "int", "bool") and node.args \
                    and not _is_static(node.args[0]):
                yield ctx.finding(
                    node, self.name,
                    f"{d}() on a possibly-traced value inside a traced "
                    f"body (hoist to the builder, or use jnp casts)")
