"""jit-donate: ``jax.jit`` in ``core/`` must declare donated arguments.

Motivation (PR 7): the engines chain full model/carry state through their
jits every round; forgetting ``donate_argnums`` silently doubles the
parameter-state footprint and copies it every dispatch (the exact
regression PR 7's donated carries removed).  Any ``jax.jit(...)`` — or
``partial(jax.jit, ...)`` decorator form — under ``src/repro/core/``
without ``donate_argnums``/``donate_argnames`` is a finding.  Jits whose
inputs are genuinely reused by the caller (eval params, shared batches)
are allowlisted inline with ``# analysis: ok=jit-donate`` or via the
baseline, with the justification recorded.
"""
from __future__ import annotations

import ast
from typing import Iterable, Optional

from repro.analysis.findings import Finding
from repro.analysis.lint import ModuleContext, Rule, dotted_name, \
    register_rule

_DONATE_KW = ("donate_argnums", "donate_argnames")


def _jit_call(node: ast.Call) -> Optional[ast.Call]:
    """The call whose keywords carry jit options, if ``node`` is a jit."""
    d = dotted_name(node.func)
    if d in ("jax.jit", "jit"):
        return node
    if d is not None and d.split(".")[-1] == "partial" and node.args:
        inner = dotted_name(node.args[0])
        if inner in ("jax.jit", "jit"):
            return node
    return None


@register_rule
class JitDonateRule(Rule):
    name = "jit-donate"
    description = ("jax.jit in core/ must declare donate_argnums/"
                   "donate_argnames (or be allowlisted)")

    def applies(self, relpath: str) -> bool:
        return relpath.startswith("src/repro/core/")

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            jit = _jit_call(node)
            if jit is None:
                continue
            if any(kw.arg in _DONATE_KW for kw in jit.keywords):
                continue
            yield ctx.finding(
                node, self.name,
                "jax.jit without donate_argnums/donate_argnames: chained "
                "round state gets copied every dispatch (allowlist with "
                "'# analysis: ok=jit-donate' if the caller reuses the "
                "inputs)")
