"""AST lint engine — repo-specific JAX invariants as pluggable visitors.

Each rule is a ``Rule`` subclass registered with ``@register_rule``; the
engine parses every file once, computes the *traced-scope* map (which
function bodies end up inside ``jit``/``scan``/``vmap``/``pallas_call``
traces) and hands each rule a ``ModuleContext`` with the tree, the scope
map and dotted-name helpers.  Rules yield ``Finding``s; pragma/baseline
suppression happens downstream (``findings.filter_findings``).

Traced-scope heuristic (shared by the host-sync rule and anyone else who
cares whether code runs under a tracer):

- a function (or lambda) passed by name to ``jax.jit`` / ``jax.vmap`` /
  ``jax.pmap`` / ``jax.grad`` / ``jax.lax.scan`` / ``cond`` /
  ``while_loop`` / ``fori_loop`` / ``switch`` / ``pl.pallas_call`` /
  ``shard_map`` / ``checkpoint`` / ``defvjp`` is traced — as an argument
  or as a decorator (``@jax.jit``, ``@partial(jax.jit, ...)``);
- every function nested (at any depth) inside a ``build_*``/``make_*``
  builder in ``core/``/``kernels/`` is traced — the repo's engines close
  round/epoch/step functions over builder arguments and hand them to jit,
  so the builder *body* is host code but its nested defs are device code;
- nesting inside a traced function is traced.

This is a heuristic, not an escape analysis: it is tuned to this repo's
idioms and errs toward silence (a function the engine cannot resolve is
host code).  The fixture suite in ``tests/test_analysis.py`` pins both
directions for every rule.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Tuple, Type

from repro.analysis.findings import Finding

# call names whose function-valued arguments end up traced
TRACING_CALL_NAMES = frozenset({
    "jit", "vmap", "pmap", "grad", "value_and_grad", "scan", "cond",
    "while_loop", "fori_loop", "switch", "pallas_call", "shard_map",
    "checkpoint", "remat", "custom_vjp", "custom_jvp", "defvjp", "eval_shape",
})

BUILDER_RE = re.compile(r"^_{0,2}(build|make)_")

_FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


def dotted_name(node: ast.AST) -> Optional[str]:
    """``jax.lax.scan`` for the matching Attribute/Name chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class ModuleContext:
    """One parsed file + everything rules share (scopes, parents, lines)."""

    def __init__(self, relpath: str, source: str, tree: ast.Module):
        self.relpath = relpath
        self.source = source
        self.lines = source.splitlines()
        self.tree = tree
        self.parents: Dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(tree):
            for child in ast.iter_child_nodes(parent):
                self.parents[child] = parent
        self.traced: set = self._compute_traced()

    # -- scope machinery ----------------------------------------------------

    def enclosing_function(self, node: ast.AST) -> Optional[ast.AST]:
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, _FUNC_NODES):
                return cur
            cur = self.parents.get(cur)
        return None

    def in_traced_scope(self, node: ast.AST) -> bool:
        fn = self.enclosing_function(node)
        return fn is not None and id(fn) in self.traced

    def _compute_traced(self) -> set:
        by_name: Dict[Tuple[int, str], ast.AST] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                owner = self.enclosing_function(node)
                by_name[(id(owner), node.name)] = node

        traced: set = set()

        def resolve(arg: ast.AST, scope_fn) -> Optional[ast.AST]:
            # fn, functools.partial(fn, ...), or a lambda literal
            if isinstance(arg, ast.Lambda):
                return arg
            if isinstance(arg, ast.Call):
                d = dotted_name(arg.func)
                if d and d.split(".")[-1] == "partial" and arg.args:
                    return resolve(arg.args[0], scope_fn)
                return None
            if isinstance(arg, ast.Name):
                # look the name up through the enclosing function chain
                cur = scope_fn
                while True:
                    hit = by_name.get((id(cur), arg.id))
                    if hit is not None:
                        return hit
                    if cur is None:
                        return None
                    cur = self.enclosing_function(cur)
            return None

        def is_tracing_name(node: ast.AST) -> bool:
            d = dotted_name(node)
            return d is not None and d.split(".")[-1] in TRACING_CALL_NAMES

        # decorator forms: @jax.jit / @jit(...) / @partial(jax.jit, ...)
        for node in ast.walk(self.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for dec in node.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                if is_tracing_name(target):
                    traced.add(id(node))
                elif isinstance(dec, ast.Call) and dec.args:
                    d = dotted_name(dec.func)
                    if d and d.split(".")[-1] == "partial" \
                            and is_tracing_name(dec.args[0]):
                        traced.add(id(node))

        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            d = dotted_name(node.func)
            if d is None or d.split(".")[-1] not in TRACING_CALL_NAMES:
                continue
            scope_fn = self.enclosing_function(node)
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                target = resolve(arg, scope_fn)
                if target is not None:
                    traced.add(id(target))

        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and BUILDER_RE.match(node.name):
                for sub in ast.walk(node):
                    if sub is not node and isinstance(sub, _FUNC_NODES):
                        traced.add(id(sub))

        # closure: nesting inside a traced function is traced
        changed = True
        while changed:
            changed = False
            for node in ast.walk(self.tree):
                if isinstance(node, _FUNC_NODES) and id(node) in traced:
                    for sub in ast.walk(node):
                        if sub is not node and isinstance(sub, _FUNC_NODES) \
                                and id(sub) not in traced:
                            traced.add(id(sub))
                            changed = True
        return traced

    # -- finding helper ------------------------------------------------------

    def finding(self, node: ast.AST, rule: str, message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        snippet = self.lines[line - 1].strip() if line <= len(self.lines) \
            else ""
        return Finding(self.relpath, line, col, rule, message, snippet)


class Rule:
    """One invariant.  ``applies`` gates by repo-relative path; ``check``
    yields findings for a parsed module."""
    name = "base"
    description = ""

    def applies(self, relpath: str) -> bool:
        raise NotImplementedError

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        raise NotImplementedError


RULES: Dict[str, Type[Rule]] = {}


def register_rule(cls: Type[Rule]) -> Type[Rule]:
    if cls.name in RULES:
        raise ValueError(f"lint rule {cls.name!r} already registered")
    RULES[cls.name] = cls
    return cls


def all_rules() -> List[Rule]:
    # rule modules self-register on import
    from repro.analysis import rules as _rules  # noqa: F401
    return [cls() for _, cls in sorted(RULES.items())]


def lint_source(source: str, relpath: str,
                rules: Optional[List[Rule]] = None) -> List[Finding]:
    """Lint one in-memory module (the test-fixture entry point)."""
    rules = rules if rules is not None else all_rules()
    try:
        tree = ast.parse(source)
    except SyntaxError as exc:
        return [Finding(relpath, exc.lineno or 1, exc.offset or 0,
                        "syntax", f"could not parse: {exc.msg}")]
    ctx = ModuleContext(relpath, source, tree)
    out: List[Finding] = []
    for rule in rules:
        if rule.applies(relpath):
            out.extend(rule.check(ctx))
    return out


def iter_py_files(root: Path, paths: Iterable[str]) -> Iterator[Path]:
    for p in paths:
        full = root / p
        if full.is_file() and full.suffix == ".py":
            yield full
        elif full.is_dir():
            yield from sorted(f for f in full.rglob("*.py")
                              if "__pycache__" not in f.parts)


def lint_paths(root: Path, paths: Iterable[str],
               rules: Optional[List[Rule]] = None
               ) -> Tuple[List[Finding], Dict[str, List[str]]]:
    """Lint every .py under ``paths`` (relative to ``root``).

    Returns ``(findings, sources)`` with ``sources`` the per-file line
    lists the pragma filter needs."""
    rules = rules if rules is not None else all_rules()
    findings: List[Finding] = []
    sources: Dict[str, List[str]] = {}
    for f in iter_py_files(root, paths):
        rel = f.relative_to(root).as_posix()
        source = f.read_text()
        sources[rel] = source.splitlines()
        findings.extend(lint_source(source, rel, rules))
    return findings, sources
