"""Finding records, inline pragmas and the reviewed baseline file.

Shared by the AST lint engine (``analysis/lint.py``), the abstract-
interpretation contract checker (``analysis/contracts.py``) and the CLI
(``python -m repro.analysis``).

Suppression has two layers, both reviewed in-tree:

- an **inline pragma** ``# analysis: ok=<rule>[,<rule>]`` on the offending
  line accepts that one site (``# analysis: ok`` with no rule list accepts
  every rule on the line) — use it where the exception is a documented
  contract of the surrounding code;
- the **baseline file** (``analysis_baseline.txt`` at the repo root)
  accepts findings by ``(path, rule, source-line)`` with a mandatory
  one-line justification — use it for exceptions that belong to review
  history rather than to the code itself.

Baseline entries key on the *stripped source text* of the offending line,
not its line number, so ordinary edits elsewhere in a file never stale the
baseline; editing the offending line itself re-surfaces the finding for
re-review, which is the point.
"""
from __future__ import annotations

import dataclasses
import json
import re
from pathlib import Path
from typing import Dict, Iterable, List, Sequence, Tuple

PRAGMA_RE = re.compile(r"#\s*analysis:\s*ok(?:=(?P<rules>[\w,-]+))?")
_SEP = " :: "


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violation at a source location (``path`` is repo-relative)."""
    path: str
    line: int
    col: int
    rule: str
    message: str
    snippet: str = ""    # stripped source of the offending line

    def key(self) -> Tuple[str, str, str]:
        return (self.path, self.rule, self.snippet)

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] " \
               f"{self.message}"


def pragma_rules(source_line: str):
    """Rules accepted by an inline pragma on ``source_line``.

    Returns ``None`` when there is no pragma, an empty frozenset for the
    blanket ``# analysis: ok``, else the frozenset of named rules."""
    mt = PRAGMA_RE.search(source_line)
    if mt is None:
        return None
    names = mt.group("rules")
    if not names:
        return frozenset()
    return frozenset(r.strip() for r in names.split(",") if r.strip())


def suppressed_by_pragma(finding: Finding, lines: Sequence[str]) -> bool:
    if not 1 <= finding.line <= len(lines):
        return False
    rules = pragma_rules(lines[finding.line - 1])
    if rules is None:
        return False
    return not rules or finding.rule in rules


class Baseline:
    """The reviewed exception list: ``path :: rule :: snippet :: why``."""

    def __init__(self, entries: Dict[Tuple[str, str, str], str] | None = None):
        self.entries = dict(entries or {})
        self.hits: set = set()

    @classmethod
    def load(cls, path: Path) -> "Baseline":
        entries: Dict[Tuple[str, str, str], str] = {}
        if not path.exists():
            return cls(entries)
        for ln, raw in enumerate(path.read_text().splitlines(), 1):
            line = raw.strip()
            if not line or line.startswith("#"):
                continue
            parts = line.split(_SEP, 3)
            if len(parts) != 4:
                raise ValueError(
                    f"{path}:{ln}: baseline entries are "
                    f"'path :: rule :: snippet :: justification', "
                    f"got {raw!r}")
            fpath, rule, snippet, why = (p.strip() for p in parts)
            if not why:
                raise ValueError(
                    f"{path}:{ln}: baseline entry for {fpath} [{rule}] "
                    f"needs a one-line justification")
            entries[(fpath, rule, snippet)] = why
        return cls(entries)

    def covers(self, finding: Finding) -> bool:
        key = finding.key()
        if key in self.entries:
            self.hits.add(key)
            return True
        return False

    def stale(self) -> List[Tuple[str, str, str]]:
        """Entries that matched nothing this run (candidates for removal)."""
        return sorted(k for k in self.entries if k not in self.hits)

    @staticmethod
    def render(findings: Iterable[Finding],
               why: str = "TODO: one-line justification") -> str:
        lines = ["# repro.analysis baseline — reviewed exceptions.",
                 "# Format: path :: rule :: offending source line "
                 ":: justification."]
        for f in sorted(set(findings), key=lambda f: f.key()):
            lines.append(_SEP.join((f.path, f.rule, f.snippet, why)))
        return "\n".join(lines) + "\n"


def render_text(findings: Sequence[Finding]) -> str:
    return "\n".join(f.format() for f in findings)


def render_github(findings: Sequence[Finding]) -> str:
    """GitHub Actions workflow-command annotations (one per finding).

    Annotation messages are single-line by protocol; newlines are
    escaped the way Actions expects (%0A)."""
    out = []
    for f in findings:
        msg = f"[{f.rule}] {f.message}".replace("%", "%25") \
            .replace("\r", "%0D").replace("\n", "%0A")
        out.append(f"::error file={f.path},line={max(1, f.line)},"
                   f"col={max(1, f.col)}::{msg}")
    return "\n".join(out)


def render_sarif(findings: Sequence[Finding],
                 rule_descriptions: Dict[str, str] | None = None) -> str:
    """Minimal SARIF 2.1.0 document for code-scanning upload."""
    descriptions = rule_descriptions or {}
    rule_ids = sorted({f.rule for f in findings})
    doc = {
        "$schema": "https://json.schemastore.org/sarif-2.1.0.json",
        "version": "2.1.0",
        "runs": [{
            "tool": {"driver": {
                "name": "repro.analysis",
                "informationUri":
                    "https://example.invalid/repro-analysis",
                "rules": [
                    {"id": rid,
                     "shortDescription": {
                         "text": descriptions.get(rid, rid)}}
                    for rid in rule_ids],
            }},
            "results": [{
                "ruleId": f.rule,
                "level": "error",
                "message": {"text": f.message},
                "locations": [{"physicalLocation": {
                    "artifactLocation": {"uri": f.path},
                    "region": {"startLine": max(1, f.line),
                               "startColumn": max(1, f.col)},
                }}],
            } for f in findings],
        }],
    }
    return json.dumps(doc, indent=2, sort_keys=True)


RENDERERS = {
    "text": render_text,
    "github": render_github,
    "sarif": render_sarif,
}


def filter_findings(findings: Iterable[Finding], baseline: Baseline,
                    sources: Dict[str, Sequence[str]]) -> List[Finding]:
    """Drop pragma- and baseline-suppressed findings.

    ``sources`` maps repo-relative paths to their source lines (for pragma
    lookup); contract findings have no source entry and only the baseline
    applies to them."""
    out = []
    for f in findings:
        lines = sources.get(f.path, ())
        if lines and suppressed_by_pragma(f, lines):
            continue
        if baseline.covers(f):
            continue
        out.append(f)
    return sorted(out, key=lambda f: (f.path, f.line, f.col, f.rule))
