"""CLI: ``python -m repro.analysis [paths...]``.

Runs the AST lint rules and the eval_shape contract sweep over the repo
tree — and, with ``--ir``, the IR-level auditors (jaxpr liveness walk,
donation/alias verification, K-scaling gate).  Prints
``path:line:col: [rule] message`` findings (``--format`` switches to
GitHub annotations or SARIF) and exits non-zero if any finding is
neither pragma'd (``# analysis: ok=<rule>``) nor listed in the baseline
file (``analysis_baseline.txt``) with a justification.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis.findings import RENDERERS, Baseline, filter_findings
from repro.analysis.lint import all_rules, lint_paths

DEFAULT_PATHS = ("src/repro", "benchmarks", "examples")
DEFAULT_BASELINE = "analysis_baseline.txt"
DEFAULT_SCALING = "analysis_scaling.json"

# program-level IR rules (no AST Rule object to describe them)
IR_RULE_DESCRIPTIONS = {
    "ir-trace": "engine program failed to trace to a jaxpr",
    "ir-dtype": "f32 tensor minted from bf16 operands in a bf16 program",
    "ir-alias": "declared donation silently dropped by XLA",
    "ir-scaling": "buffer scales past its declared O(K) budget",
}


def find_repo_root(start: Path) -> Path:
    for cand in [start] + list(start.parents):
        if (cand / "src" / "repro").is_dir():
            return cand
    return start


def run_ir(root: Path, scaling_file: str):
    """The IR sweep: walker + alias audit + scaling gate. Lazy imports —
    this pulls in jax and every engine."""
    from repro.analysis.ir import (run_alias_audit, run_jaxpr_audit,
                                   run_scaling_gate)
    findings, _audits = run_jaxpr_audit()
    alias_findings, _records = run_alias_audit()
    findings.extend(alias_findings)
    scaling_findings, _report = run_scaling_gate(
        committed=root / scaling_file)
    findings.extend(scaling_findings)
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific static analysis (lint + contracts "
                    "+ IR audit)")
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"files/dirs to lint (default: {DEFAULT_PATHS})")
    ap.add_argument("--root", type=Path, default=None,
                    help="repo root (default: auto-detected)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline file, relative to the root")
    ap.add_argument("--scaling-file", default=DEFAULT_SCALING,
                    help="committed scaling record, relative to the root")
    ap.add_argument("--no-contracts", action="store_true",
                    help="skip the eval_shape contract sweep (lint only)")
    ap.add_argument("--no-lint", action="store_true",
                    help="skip the AST lint rules (contracts only)")
    ap.add_argument("--ir", action="store_true",
                    help="run the IR auditors (jaxpr walk, donation "
                         "verification, K-scaling gate)")
    ap.add_argument("--format", choices=sorted(RENDERERS), default="text",
                    help="finding output format (default: text)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="print a baseline covering the current findings")
    ap.add_argument("--prune-baseline", action="store_true",
                    help="rewrite the baseline file dropping entries that "
                         "matched nothing this run")
    ap.add_argument("--strict-baseline", action="store_true",
                    help="fail (exit 1) on stale baseline entries")
    ap.add_argument("--write-scaling", action="store_true",
                    help="regenerate the committed scaling record and exit")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    root = args.root or find_repo_root(Path.cwd())
    paths = args.paths or list(DEFAULT_PATHS)

    if args.list_rules:
        for rule in all_rules():
            print(f"{rule.name:15s} {rule.description}")
        for name, desc in sorted(IR_RULE_DESCRIPTIONS.items()):
            print(f"{name:15s} {desc} (--ir)")
        return 0

    if args.write_scaling:
        from repro.analysis.ir import scaling_report, write_scaling_json
        out = root / args.scaling_file
        write_scaling_json(out, scaling_report())
        print(f"wrote {out}")
        return 0

    findings, sources = [], {}
    if not args.no_lint:
        findings, sources = lint_paths(root, paths)
    if not args.no_contracts:
        # imported lazily: the contract sweep imports every engine
        from repro.analysis.contracts import run_contracts
        findings.extend(run_contracts(repo_root=root))
    if args.ir:
        findings.extend(run_ir(root, args.scaling_file))
        # IR findings carry real source sites; load those files so the
        # inline-pragma layer applies to them like any lint finding
        for f in findings:
            fpath = root / f.path
            if f.path not in sources and fpath.is_file():
                sources[f.path] = fpath.read_text().splitlines()

    baseline_path = root / args.baseline
    baseline = Baseline.load(baseline_path)
    live = filter_findings(findings, baseline, sources)

    if args.write_baseline:
        sys.stdout.write(Baseline.render(live))
        return 0

    stale = baseline.stale()
    if args.prune_baseline and stale:
        kept = [f for key, why in baseline.entries.items()
                if key in baseline.hits
                for f in [_entry_line(key, why)]]
        header = ["# repro.analysis baseline — reviewed exceptions.",
                  "# Format: path :: rule :: offending source line "
                  ":: justification."]
        baseline_path.write_text("\n".join(header + kept) + "\n")
        print(f"pruned {len(stale)} stale baseline entr"
              f"{'y' if len(stale) == 1 else 'ies'} from {args.baseline}",
              file=sys.stderr)
        stale = []

    rendered = RENDERERS[args.format](live)
    if rendered:
        print(rendered)
    for key in stale:
        print(f"note: stale baseline entry (matched nothing): "
              f"{' :: '.join(key)}", file=sys.stderr)
    if live:
        print(f"\n{len(live)} finding(s). Fix, pragma "
              f"(# analysis: ok=<rule>) or baseline with a justification "
              f"in {args.baseline}.", file=sys.stderr)
        return 1
    if stale and args.strict_baseline:
        print(f"{len(stale)} stale baseline entr"
              f"{'y' if len(stale) == 1 else 'ies'} — remove them or run "
              f"--prune-baseline.", file=sys.stderr)
        return 1
    if args.format == "text":
        parts = [] if args.no_lint else ["lint"]
        if not args.no_contracts:
            parts.append("contracts")
        if args.ir:
            parts.append("ir")
        print(f"repro.analysis: clean ({' + '.join(parts)})"
              if parts else "repro.analysis: clean")
    return 0


def _entry_line(key, why) -> str:
    return " :: ".join((*key, why))


if __name__ == "__main__":
    sys.exit(main())
