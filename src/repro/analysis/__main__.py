"""CLI: ``python -m repro.analysis [paths...]``.

Runs the AST lint rules and the eval_shape contract sweep over the repo
tree, prints ``path:line:col: [rule] message`` findings and exits non-zero
if any finding is neither pragma'd (``# analysis: ok=<rule>``) nor listed
in the baseline file (``analysis_baseline.txt``) with a justification.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis.findings import Baseline, filter_findings
from repro.analysis.lint import all_rules, lint_paths

DEFAULT_PATHS = ("src/repro", "benchmarks", "examples")
DEFAULT_BASELINE = "analysis_baseline.txt"


def find_repo_root(start: Path) -> Path:
    for cand in [start] + list(start.parents):
        if (cand / "src" / "repro").is_dir():
            return cand
    return start


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific static analysis (lint + contracts)")
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"files/dirs to lint (default: {DEFAULT_PATHS})")
    ap.add_argument("--root", type=Path, default=None,
                    help="repo root (default: auto-detected)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline file, relative to the root")
    ap.add_argument("--no-contracts", action="store_true",
                    help="skip the eval_shape contract sweep (lint only)")
    ap.add_argument("--no-lint", action="store_true",
                    help="skip the AST lint rules (contracts only)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="print a baseline covering the current findings")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    root = args.root or find_repo_root(Path.cwd())
    paths = args.paths or list(DEFAULT_PATHS)

    if args.list_rules:
        for rule in all_rules():
            print(f"{rule.name:15s} {rule.description}")
        return 0

    findings, sources = [], {}
    if not args.no_lint:
        findings, sources = lint_paths(root, paths)
    if not args.no_contracts:
        # imported lazily: the contract sweep imports every engine
        from repro.analysis.contracts import run_contracts
        findings.extend(run_contracts(repo_root=root))

    baseline = Baseline.load(root / args.baseline)
    live = filter_findings(findings, baseline, sources)

    if args.write_baseline:
        sys.stdout.write(Baseline.render(live))
        return 0

    for f in live:
        print(f.format())
    for key in baseline.stale():
        print(f"note: stale baseline entry (matched nothing): "
              f"{' :: '.join(key)}", file=sys.stderr)
    if live:
        print(f"\n{len(live)} finding(s). Fix, pragma "
              f"(# analysis: ok=<rule>) or baseline with a justification "
              f"in {args.baseline}.", file=sys.stderr)
        return 1
    suffix = "" if args.no_contracts else " (lint + contracts)"
    print(f"repro.analysis: clean{suffix}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
