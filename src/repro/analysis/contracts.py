"""Abstract-interpretation contract checker — shapes/dtypes via eval_shape.

Everything here runs through ``jax.eval_shape``: the round programs and
kernels are *traced*, never executed, so the whole sweep below finishes in
seconds on any backend and proves the declared signatures statically.

Checked contracts:

1. **Device round carry stability** — for every registered scheme,
   ``build_device_round``'s round function must return a
   ``DeviceSimCarry`` abstractly identical to its input (the sweep engine
   chains it under ``lax.scan``; any aval drift is a scan type error at
   best and a silent recompile per round at worst), and
   ``DeviceRoundMetrics`` fields must keep their declared dtypes.
2. **Fused round params preservation** — for every registered scheme,
   ``build_fused_round`` must return ``new_params`` with exactly the input
   params avals (the host engine chains rounds through donated buffers —
   aval drift breaks donation), ``RoundStats`` stays ``(K,)``
   bool/int32, and the async straggler carry keeps its fixed width.
3. **Kernel twin equivalence** — every ``kernels/*`` package with a
   ``ref.py``/``kernel.py`` pair must appear in the twin registry below,
   and each twin pair must produce identical abstract signatures on
   representative inputs (the runtime bit-level pins live in the tier-1
   suite; this is the execution-free half of that contract).
4. **Scheme program identity** — ``lowered_program`` of every scheme
   resolves to a registered scheme for representative budget pins.
"""
from __future__ import annotations

from pathlib import Path
from typing import Any, Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.findings import Finding

_SCHEMES_PATH = "src/repro/core/schemes.py"
_FUSED_PATH = "src/repro/core/fused_round.py"

# tiny-but-representative example scale (shapes only; nothing executes)
_N, _K, _E, _STEPS, _BS = 8, 4, 2, 1, 4


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _avalize(tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda l: _sds(jnp.shape(l), jnp.result_type(l)), tree)


def _sig(tree: Any) -> List[str]:
    """Canonical printable signature of a pytree of avals."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    out = [f"treedef={treedef}"]
    out += [f"{i}: {tuple(l.shape)} {jnp.result_type(l)}"
            for i, l in enumerate(leaves)]
    return out


def diff_signatures(a: Any, b: Any) -> List[str]:
    """Human-readable differences between two aval trees ([] if equal)."""
    sa, sb = _sig(a), _sig(b)
    return [f"{x} != {y}" for x, y in zip(sa, sb) if x != y] \
        + [f"arity {len(sa)} != {len(sb)}"] * (len(sa) != len(sb))


# ---------------------------------------------------------------------------
# scheme round contracts
# ---------------------------------------------------------------------------

def _example_params():
    from repro.models.cnn import init_cnn
    return jax.eval_shape(lambda: init_cnn(jax.random.PRNGKey(0)))


def _key_aval():
    k = jax.random.PRNGKey(0)
    return _sds(k.shape, k.dtype)


def check_device_round(schemes=None) -> List[Finding]:
    """Contract 1: per-scheme scan-carry stability of build_device_round."""
    from repro.core.channel_lib import ChannelParams, fleet_init
    from repro.core.fused_round import (DeviceRoundMetrics, DeviceSimCarry,
                                        build_device_round)
    from repro.core.schemes import registered_schemes
    from repro.kernels.fused_cnn.ops import ForwardPolicy

    findings: List[Finding] = []
    params = _example_params()
    chan = ChannelParams()
    fleet = jax.eval_shape(
        lambda k: fleet_init(k, _N, chan), jax.random.PRNGKey(0))
    stacked = jax.tree_util.tree_map(
        lambda l: _sds((_K,) + tuple(l.shape), l.dtype), params)
    carry = DeviceSimCarry(params=params, fleet=fleet, delayed=stacked,
                           delayed_mask=_sds((_K,), jnp.bool_))
    xdim = (28, 28, 1)
    sim = {
        "client_x": _sds((_N, 32) + xdim, jnp.float32),
        "client_y": _sds((_N, 32), jnp.int32),
        "client_len": _sds((_N,), jnp.int32),
        "flops": _sds((_N,), jnp.float32),
        "samples": _sds((_N,), jnp.float32),
        "test_x": _sds((16,) + xdim, jnp.float32),
        "test_y": _sds((16,), jnp.int32),
    }
    cfg = {"b": _sds((), jnp.float32), "tau_max": _sds((), jnp.float32),
           "bandwidth_ratio": _sds((), jnp.float32)}
    metric_dtypes = DeviceRoundMetrics(
        selected=jnp.int32, arrived=jnp.int32, rescued=jnp.int32,
        delayed=jnp.int32, dropped=jnp.int32, bytes_sent=jnp.float32,
        test_loss=jnp.float32, test_acc=jnp.float32)

    variants: List[Tuple[str, Dict[str, Any]]] = []
    for name in (schemes or registered_schemes()):
        variants.append((name, {}))
    variants.append(("opt", {"use_codec": True, "compress_ratio": 0.252}))
    variants.append(("opt", {"forward": ForwardPolicy(kernel="pallas",
                                                      interpret=True)}))

    for name, extra in variants:
        label = name + ("" if not extra else f"+{sorted(extra)}")
        try:
            round_fn = build_device_round(
                scheme=name, local_epochs=_E, steps_per_epoch=_STEPS,
                batch_size=_BS, lr=0.01, k_select=_K, channel=chan,
                model_bytes=1e6, ue_model_fraction=0.25, interpret=True,
                **extra)
            out_carry, metrics = jax.eval_shape(
                round_fn, carry, _key_aval(), sim, cfg)
        except Exception as exc:  # a broken build IS the finding
            findings.append(Finding(
                _FUSED_PATH, 1, 0, "contract-device-round",
                f"build_device_round({label}) failed abstract "
                f"evaluation: {type(exc).__name__}: {exc}"))
            continue
        for d in diff_signatures(_avalize(carry), _avalize(out_carry)):
            findings.append(Finding(
                _FUSED_PATH, 1, 0, "contract-device-round",
                f"scheme {label!r}: DeviceSimCarry is not scan-stable "
                f"(in != out): {d}"))
        for field, want in metric_dtypes._asdict().items():
            got = getattr(metrics, field)
            if tuple(got.shape) != () or jnp.result_type(got) != want:
                findings.append(Finding(
                    _FUSED_PATH, 1, 0, "contract-device-round",
                    f"scheme {label!r}: metrics.{field} is "
                    f"{tuple(got.shape)} {jnp.result_type(got)}, declared "
                    f"() {jnp.dtype(want)}"))
    return findings


def check_fused_round(schemes=None) -> List[Finding]:
    """Contract 2: build_fused_round preserves params avals per scheme."""
    from repro.core.fused_round import build_fused_round
    from repro.core.schemes import get_scheme, registered_schemes

    findings: List[Finding] = []
    params = _example_params()
    xdim = (28, 28, 1)
    xs = _sds((_E, _K, _STEPS, _BS) + xdim, jnp.float32)
    ys = _sds((_E, _K, _STEPS, _BS), jnp.int32)
    chan = {
        "rates": _sds((_E, _K), jnp.float32),
        "outages": _sds((_E, _K), jnp.bool_),
        "payload_bits": _sds((_K,), jnp.float32),
        "tau_extra0": _sds((_K,), jnp.float32),
        "final_rate": _sds((_K,), jnp.float32),
        "train_time": _sds((_K,), jnp.float32),
        "final_outage": _sds((_K,), jnp.bool_),
        "valid": _sds((_K,), jnp.bool_),
    }
    stats_dtypes = {"arrived": jnp.bool_, "rescued": jnp.bool_,
                    "delayed": jnp.bool_, "dropped": jnp.bool_,
                    "opp_sends": jnp.int32}

    for name in (schemes or registered_schemes()):
        scheme = get_scheme(name)
        probe = scheme.static_schedule(_E, 2)
        kw: Dict[str, Any] = dict(
            scheme=name, local_epochs=_E, steps_per_epoch=_STEPS, lr=0.01,
            tau_max=9.0, probe_epochs=probe, interpret=True)
        try:
            if scheme.carries_delayed:
                fn = build_fused_round(k_carry=_K, async_weight=0.283, **kw)
                stacked = jax.tree_util.tree_map(
                    lambda l: _sds((_K,) + tuple(l.shape), l.dtype), params)
                mask = _sds((_K,), jnp.bool_)
                new_params, new_stack, new_mask, stats = jax.eval_shape(
                    fn, params, stacked, mask, xs, ys, chan)
                carry_pairs = [("delayed_stack", stacked, new_stack),
                               ("delayed_mask", mask, new_mask)]
            else:
                fn = build_fused_round(**kw)
                new_params, stats = jax.eval_shape(fn, params, xs, ys, chan)
                carry_pairs = []
        except Exception as exc:
            findings.append(Finding(
                _FUSED_PATH, 1, 0, "contract-fused-round",
                f"build_fused_round({name!r}) failed abstract "
                f"evaluation: {type(exc).__name__}: {exc}"))
            continue
        for d in diff_signatures(_avalize(params), _avalize(new_params)):
            findings.append(Finding(
                _FUSED_PATH, 1, 0, "contract-fused-round",
                f"scheme {name!r}: new_params drifts from params "
                f"(breaks donation/chaining): {d}"))
        for label, want, got in carry_pairs:
            for d in diff_signatures(_avalize(want), _avalize(got)):
                findings.append(Finding(
                    _FUSED_PATH, 1, 0, "contract-fused-round",
                    f"scheme {name!r}: {label} is not round-stable: {d}"))
        for field, want in stats_dtypes.items():
            got = getattr(stats, field)
            if tuple(got.shape) != (_K,) or jnp.result_type(got) != want:
                findings.append(Finding(
                    _FUSED_PATH, 1, 0, "contract-fused-round",
                    f"scheme {name!r}: RoundStats.{field} is "
                    f"{tuple(got.shape)} {jnp.result_type(got)}, declared "
                    f"({_K},) {jnp.dtype(want)}"))
    return findings


def check_scheme_programs() -> List[Finding]:
    """Contract 4: lowered_program resolves inside the registry."""
    from repro.core.schemes import get_scheme, registered_schemes
    findings: List[Finding] = []
    names = registered_schemes()
    for name in names:
        scheme = get_scheme(name)
        for pins in ((1.0,), (2.0,), (1.0, 2.0, 4.0)):
            prog = scheme.lowered_program(pins)
            if prog not in names:
                findings.append(Finding(
                    _SCHEMES_PATH, 1, 0, "contract-scheme-program",
                    f"scheme {name!r}: lowered_program({pins}) -> "
                    f"{prog!r}, which is not a registered scheme"))
    return findings


# ---------------------------------------------------------------------------
# kernel twins
# ---------------------------------------------------------------------------

def compare_twin(name: str, path: str, ref_thunk: Callable[[], Any],
                 kernel_thunk: Callable[[], Any]) -> List[Finding]:
    """Findings if two abstract evaluations disagree (or either fails)."""
    outs = {}
    for side, thunk in (("ref", ref_thunk), ("kernel", kernel_thunk)):
        try:
            outs[side] = thunk()
        except Exception as exc:
            return [Finding(path, 1, 0, "contract-kernel-twin",
                            f"{name}: {side} side failed abstract "
                            f"evaluation: {type(exc).__name__}: {exc}")]
    return [Finding(path, 1, 0, "contract-kernel-twin",
                    f"{name}: ref/kernel abstract signatures differ: {d}")
            for d in diff_signatures(outs["ref"], outs["kernel"])]


def twin_registry() -> List[Tuple[str, str, Callable, Callable]]:
    """Every kernels/* ref/kernel twin pair as (name, path, ref, kernel).

    The thunks return aval trees via eval_shape — adapters fold layout
    differences (wkv6's (B,S,H,D) vs (BH,S,D)) so "identical signature"
    means identical *user-facing* outputs."""
    import repro.kernels.delta_codec.kernel as dck
    import repro.kernels.delta_codec.ref as dcr
    import repro.kernels.flash_attention.kernel as fak
    import repro.kernels.flash_attention.ref as far
    import repro.kernels.fused_cnn.ops as cnn_ops
    import repro.kernels.fused_cnn.ref as cnn_ref
    import repro.kernels.wkv6.ops as wko
    import repro.kernels.wkv6.ref as wkr
    from repro.kernels.fused_cnn.ops import ForwardPolicy

    ev = jax.eval_shape
    pairs: List[Tuple[str, str, Callable, Callable]] = []

    # -- delta_codec ------------------------------------------------------
    x = _sds((256, 512), jnp.float32)
    q, s = _sds((256, 512), jnp.int8), _sds((256, 1), jnp.float32)
    for bits in (8, 4):
        pairs.append((
            f"delta_codec.quantize[bits={bits}]",
            "src/repro/kernels/delta_codec/kernel.py",
            lambda bits=bits: ev(lambda a: dcr.quantize_ref(a, bits=bits), x),
            lambda bits=bits: ev(lambda a: dck.quantize_blocks(
                a, interpret=True, bits=bits), x)))
    pairs.append((
        "delta_codec.dequantize", "src/repro/kernels/delta_codec/kernel.py",
        lambda: ev(dcr.dequantize_ref, q, s),
        lambda: ev(lambda a, b: dck.dequantize_blocks(
            a, b, interpret=True), q, s)))

    # -- flash_attention --------------------------------------------------
    qa = _sds((4, 256, 64), jnp.float32)
    for label, kw in (("causal", dict(causal=True)),
                      ("window", dict(causal=True, window=128))):
        pairs.append((
            f"flash_attention.{label}",
            "src/repro/kernels/flash_attention/kernel.py",
            lambda kw=kw: ev(lambda a, b, c: far.attention_ref(
                a, b, c, **kw), qa, qa, qa),
            lambda kw=kw: ev(lambda a, b, c: fak.flash_attention_bh(
                a, b, c, interpret=True, **kw), qa, qa, qa)))

    # -- wkv6 -------------------------------------------------------------
    B, S, H, D = 2, 256, 2, 64
    r = _sds((B, S, H, D), jnp.float32)
    u = _sds((H, D), jnp.float32)
    s0 = _sds((B, H, D, D), jnp.float32)
    pairs.append((
        "wkv6.recurrence", "src/repro/kernels/wkv6/kernel.py",
        lambda: ev(wkr.wkv6_ref, r, r, r, r, u, s0),
        lambda: ev(lambda *a: wko.wkv6(*a, interpret=True), r, r, r, r, u)))

    # -- fused_cnn --------------------------------------------------------
    params = _example_params()
    img = _sds((_BS, 28, 28, 1), jnp.float32)
    base = ForwardPolicy(interpret=True)
    for kernel in ("pallas", "im2col"):
        pol = ForwardPolicy(kernel=kernel, interpret=True)
        pairs.append((
            f"fused_cnn.forward[{kernel} vs xla]",
            "src/repro/kernels/fused_cnn/kernel.py",
            lambda: ev(cnn_ops.make_forward(base), params, img),
            lambda pol=pol: ev(cnn_ops.make_forward(pol), params, img)))
    # the hand-written VJP twin against the pure-jnp reference fwd
    pairs.append((
        "fused_cnn.forward[ref oracle]",
        "src/repro/kernels/fused_cnn/ref.py",
        lambda: ev(cnn_ref.forward_ref, params, img),
        lambda: ev(cnn_ops.make_forward(base), params, img)))
    # stacked-cohort twins: blocked kernels vs the vmapped composition
    stacked = jax.tree_util.tree_map(
        lambda l: _sds((_K,) + tuple(l.shape), l.dtype), params)
    bx = _sds((_K, _BS, 28, 28, 1), jnp.float32)
    by = _sds((_K, _BS), jnp.int32)
    vm = ForwardPolicy(interpret=True, batch_users=False)
    for label, pol in (("xla", base),
                       ("pallas", ForwardPolicy(kernel="pallas",
                                                interpret=True)),
                       ("block_k", ForwardPolicy(interpret=True, block_k=2)),
                       ("bf16", ForwardPolicy(precision="bf16",
                                              interpret=True))):
        pairs.append((
            f"fused_cnn.stacked_loss_grad[{label} vs vmapped]",
            "src/repro/kernels/fused_cnn/kernel.py",
            lambda: ev(cnn_ops.make_stacked_loss_grad(vm), stacked, bx, by),
            lambda pol=pol: ev(cnn_ops.make_stacked_loss_grad(pol),
                               stacked, bx, by)))
    return pairs


def covered_twin_packages() -> set:
    return {name.split(".")[0] for name, _, _, _ in twin_registry()}


def kernel_twin_packages(repo_root: Path) -> set:
    """kernels/* packages shipping a ref.py/kernel.py twin pair."""
    kdir = repo_root / "src" / "repro" / "kernels"
    return {d.name for d in kdir.iterdir()
            if d.is_dir() and (d / "ref.py").exists()
            and (d / "kernel.py").exists()}


def check_kernel_twins(repo_root: Path | None = None) -> List[Finding]:
    """Contract 3: twin signatures agree + every twin package is covered."""
    findings: List[Finding] = []
    for name, path, ref_thunk, kernel_thunk in twin_registry():
        findings.extend(compare_twin(name, path, ref_thunk, kernel_thunk))
    if repo_root is not None:
        missing = kernel_twin_packages(repo_root) - covered_twin_packages()
        for pkg in sorted(missing):
            findings.append(Finding(
                f"src/repro/kernels/{pkg}/kernel.py", 1, 0,
                "contract-kernel-twin",
                f"kernels/{pkg} ships a ref.py/kernel.py twin pair but "
                f"has no entry in analysis.contracts.twin_registry()"))
    return findings


def run_contracts(repo_root: Path | None = None) -> List[Finding]:
    """The full contract sweep (every registered scheme, every twin)."""
    findings: List[Finding] = []
    findings.extend(check_scheme_programs())
    findings.extend(check_device_round())
    findings.extend(check_fused_round())
    findings.extend(check_kernel_twins(repo_root))
    return findings
