"""Runtime guards: recompile budgets, transfer guards, tracer-leak checks.

The static halves (lint + contracts) prove structure; these context
managers prove the *dispatch-time* invariants the engines advertise:

- ``CompileCounter``/``compile_budget`` — counts actual XLA compiles by
  listening to jax's compile logging (``jax.log_compiles``): the sweep
  engine claims ``SweepResult.n_programs`` distinct round programs per
  run, the fused engine claims O(1) compiles per configuration, and a
  budget overrun is exactly the silent-recompile-per-round regression
  class PR 2/PR 7 fought.
- ``no_implicit_transfers`` — ``jax.transfer_guard_*("disallow")`` around
  engine execution: after the engines stage inputs with explicit
  ``jax.device_put``, any remaining implicit host→device transfer inside
  the round loop is a bug.  Device→host reads of *results* are the
  intended sync boundary, so the default guards only host→device.
- ``leak_check`` — ``jax.checking_leaks()``: no tracer escapes a traced
  scope (the runtime twin of the lint host-sync rule).
- ``memory_budget`` — caps the per-program compiled memory footprint
  (arguments + outputs + temps − aliased) of every program compiled in
  the block, the runtime twin of the IR walker's liveness estimate: the
  static walk bounds what the program *asks for*, this checks what XLA
  actually *reserved*.
"""
from __future__ import annotations

import contextlib
import logging
import re
import threading
from typing import Iterator, List, Optional

import jax

# The dispatch logger emits "Finished XLA compilation of jit(<name>) in
# ..." exactly once per real XLA compile on BOTH dispatch paths — eager
# jit calls and AOT ``lower().compile()`` (which the sweep engine uses
# from a background thread).  Cache hits are silent.  The pxla
# "Compiling <name> with global shapes" message is eager-only, so it is
# not used: matching both would double-count eager compiles.
_COMPILE_LOGGERS = ("jax._src.dispatch",)
_COMPILE_RE = re.compile(
    r"Finished XLA compilation of (?:jit\()?([\w<>\[\]()., -]+?)\)? in ")


class CompileBudgetExceeded(AssertionError):
    pass


class CompileCounter(logging.Handler):
    """Context manager counting XLA compiles (by compiled-program name).

    >>> with CompileCounter() as cc:
    ...     run_things()
    >>> cc.count(), cc.count(match="over_sim")
    """

    def __init__(self) -> None:
        super().__init__(level=logging.DEBUG)
        self.names: List[str] = []
        self._lock_names = threading.Lock()
        self._prev: Optional[bool] = None

    def emit(self, record: logging.LogRecord) -> None:
        mt = _COMPILE_RE.search(record.getMessage())
        if mt:
            with self._lock_names:
                self.names.append(mt.group(1))

    def count(self, match: Optional[str] = None) -> int:
        with self._lock_names:
            if match is None:
                return len(self.names)
            return sum(1 for n in self.names if re.search(match, n))

    def __enter__(self) -> "CompileCounter":
        # the *global* flag, not the jax.log_compiles context manager: the
        # CM's setting is thread-local, and the sweep engine AOT-compiles
        # its next program in a background thread
        self._prev = jax.config.jax_log_compiles
        jax.config.update("jax_log_compiles", True)
        for name in _COMPILE_LOGGERS:
            logging.getLogger(name).addHandler(self)
        return self

    def __exit__(self, *exc) -> None:
        for name in _COMPILE_LOGGERS:
            logging.getLogger(name).removeHandler(self)
        jax.config.update("jax_log_compiles", bool(self._prev))
        self._prev = None


@contextlib.contextmanager
def compile_budget(budget: int, match: Optional[str] = None
                   ) -> Iterator[CompileCounter]:
    """Fail if the enclosed block compiles more than ``budget`` programs
    (optionally only those whose name matches ``match``)."""
    with CompileCounter() as cc:
        yield cc
        n = cc.count(match)
        if n > budget:
            what = f"programs matching {match!r}" if match else "programs"
            raise CompileBudgetExceeded(
                f"compiled {n} {what}, budget is {budget}; names: "
                f"{[x for x in cc.names if match is None or re.search(match, x)]}")


@contextlib.contextmanager
def no_implicit_transfers(direction: str = "host_to_device"
                          ) -> Iterator[None]:
    """Disallow implicit transfers inside the block.

    ``direction``: ``"host_to_device"`` (default — result reads stay
    legal; the engines' documented sync boundary), ``"device_to_host"``,
    or ``"all"``."""
    if direction == "host_to_device":
        cm = jax.transfer_guard_host_to_device("disallow")
    elif direction == "device_to_host":
        cm = jax.transfer_guard_device_to_host("disallow")
    elif direction == "all":
        cm = jax.transfer_guard("disallow")
    else:
        raise ValueError(f"unknown transfer-guard direction {direction!r}")
    with cm:
        yield


@contextlib.contextmanager
def leak_check() -> Iterator[None]:
    """Raise if a tracer leaks out of any traced scope in the block."""
    with jax.checking_leaks():
        yield


class MemoryBudgetExceeded(AssertionError):
    pass


@contextlib.contextmanager
def memory_budget(limit_bytes: int, match: Optional[str] = None
                  ) -> Iterator[List]:
    """Fail if any program compiled in the block reserves more than
    ``limit_bytes`` (optionally only programs whose name matches
    ``match``).

    Hooks ``pxla.MeshComputation.compile`` — the single chokepoint both
    dispatch paths go through (eager jit calls and AOT
    ``lower().compile()``, including the sweep engine's background-thread
    compiles) — and reads the executable's compiled memory stats.  The
    measured footprint is ``argument + output + temp − alias`` bytes: what
    one dispatch of the program actually reserves, with donation credited.
    Programs whose backend reports no stats are skipped, not failed.

    Violations are raised together on block exit (background-thread
    compiles can't raise usefully into the caller mid-block); yields the
    live ``[(name, bytes)]`` record list for inspection."""
    from jax._src.interpreters import pxla

    records: List = []
    violations: List = []
    lock = threading.Lock()
    orig = pxla.MeshComputation.compile

    def patched(self, *a, **kw):
        ex = orig(self, *a, **kw)
        name = str(getattr(self, "_name", "<unnamed>"))
        if match is not None and not re.search(match, name):
            return ex
        try:
            stats = ex.xla_executable.get_compiled_memory_stats()
            used = (stats.argument_size_in_bytes
                    + stats.output_size_in_bytes
                    + stats.temp_size_in_bytes
                    - stats.alias_size_in_bytes)
        except Exception:
            return ex
        with lock:
            records.append((name, used))
            if used > limit_bytes:
                violations.append((name, used))
        return ex

    pxla.MeshComputation.compile = patched
    try:
        yield records
    finally:
        pxla.MeshComputation.compile = orig
    if violations:
        detail = ", ".join(f"{n}: {b / 1e6:.2f} MB" for n, b in violations)
        raise MemoryBudgetExceeded(
            f"{len(violations)} program(s) over the "
            f"{limit_bytes / 1e6:.2f} MB memory budget"
            f"{f' (match={match!r})' if match else ''}: {detail}")


@contextlib.contextmanager
def engine_guard(budget: Optional[int] = None, match: Optional[str] = None
                 ) -> Iterator[CompileCounter]:
    """The combined harness the guarded CI smokes run under: no implicit
    host→device transfers + an optional compile budget."""
    with contextlib.ExitStack() as stack:
        cc = stack.enter_context(CompileCounter())
        stack.enter_context(no_implicit_transfers())
        yield cc
        if budget is not None:
            n = cc.count(match)
            if n > budget:
                raise CompileBudgetExceeded(
                    f"compiled {n} programs (match={match!r}), budget "
                    f"{budget}; names: {cc.names}")
