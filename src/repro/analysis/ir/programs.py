"""The K-parameterized engine-program registry the IR auditors sweep.

One place answers "what programs does this repo actually ship?" so the
jaxpr walker, the donation verifier and the K-scaling gate all audit the
same list — and the coverage tests can assert that every registered
scheme appears through *both* round builders and that every ``kernels/*``
ref/kernel twin package has an IR entry (the eval_shape contract sweep in
``analysis/contracts.py`` makes the same promise for signatures).

Every entry is an ``EngineProgram`` whose ``build(K)`` returns
``(fn, args)`` with all array arguments as ``ShapeDtypeStruct``s: nothing
here allocates or executes — ``jax.make_jaxpr`` traces and
``fn.lower(*args)`` lowers straight off the avals.  ``K`` scales the
user/cohort axis (and only that axis), which is what lets the scaling
gate fit per-buffer exponents in K.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

# tiny-but-representative non-K dims (match analysis/contracts.py)
_E, _STEPS, _BS = 2, 1, 4
_XDIM = (28, 28, 1)
_M = 32          # samples per client (device-round gather source)
_NTEST = 16

FUSED_PATH = "src/repro/core/fused_round.py"


@dataclasses.dataclass(frozen=True)
class EngineProgram:
    """One auditable program.  ``build(K) -> (fn, args)``.

    ``family`` groups findings ("fused_round" / "device_round" /
    "kernel"); ``path`` anchors program-level findings that have no
    better source site; ``compute_dtype`` declares the compute policy the
    dtype audit enforces ("bf16" programs may not mint f32 tensors from
    bf16 operands outside the allowlisted accumulator primitives);
    ``donate_argnums`` is the donation the *source* claims — the alias
    audit verifies XLA kept it.  ``scheme``/``twin`` tag coverage."""
    name: str
    family: str
    path: str
    build: Callable[[int], Tuple[Callable, Tuple[Any, ...]]]
    compute_dtype: str = "f32"
    donate_argnums: Tuple[int, ...] = ()
    scheme: str = ""
    twin: str = ""


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _params_aval():
    from repro.models.cnn import init_cnn
    return jax.eval_shape(lambda: init_cnn(jax.random.PRNGKey(0)))


def _stack(tree, k: int):
    return jax.tree_util.tree_map(
        lambda l: _sds((k,) + tuple(l.shape), l.dtype), tree)


def _key_aval():
    k = jax.random.PRNGKey(0)
    return _sds(k.shape, k.dtype)


# ---------------------------------------------------------------------------
# round builders
# ---------------------------------------------------------------------------

def _fused_args(k: int, carries_delayed: bool):
    params = _params_aval()
    xs = _sds((_E, k, _STEPS, _BS) + _XDIM, jnp.float32)
    ys = _sds((_E, k, _STEPS, _BS), jnp.int32)
    chan = {
        "rates": _sds((_E, k), jnp.float32),
        "outages": _sds((_E, k), jnp.bool_),
        "payload_bits": _sds((k,), jnp.float32),
        "tau_extra0": _sds((k,), jnp.float32),
        "final_rate": _sds((k,), jnp.float32),
        "train_time": _sds((k,), jnp.float32),
        "final_outage": _sds((k,), jnp.bool_),
        "valid": _sds((k,), jnp.bool_),
    }
    if carries_delayed:
        return (params, _stack(params, k), _sds((k,), jnp.bool_), xs, ys,
                chan)
    return (params, xs, ys, chan)


def _build_fused(scheme_name: str, forward=None):
    from repro.core.fused_round import build_fused_round
    from repro.core.schemes import get_scheme

    def build(k: int):
        scheme = get_scheme(scheme_name)
        probe = scheme.static_schedule(_E, 2)
        kw: Dict[str, Any] = dict(
            scheme=scheme_name, local_epochs=_E, steps_per_epoch=_STEPS,
            lr=0.01, tau_max=9.0, probe_epochs=probe, interpret=True,
            forward=forward)
        if scheme.carries_delayed:
            fn = build_fused_round(k_carry=k, async_weight=0.283, **kw)
        else:
            fn = build_fused_round(**kw)
        return fn, _fused_args(k, scheme.carries_delayed)

    return build


def _device_args(k: int):
    from repro.core.channel_lib import ChannelParams, fleet_init
    from repro.core.fused_round import DeviceSimCarry

    params = _params_aval()
    chan = ChannelParams()
    fleet = jax.eval_shape(
        lambda key: fleet_init(key, k, chan), jax.random.PRNGKey(0))
    carry = DeviceSimCarry(params=params, fleet=fleet,
                           delayed=_stack(params, k),
                           delayed_mask=_sds((k,), jnp.bool_))
    sim = {
        "client_x": _sds((k, _M) + _XDIM, jnp.float32),
        "client_y": _sds((k, _M), jnp.int32),
        "client_len": _sds((k,), jnp.int32),
        "flops": _sds((k,), jnp.float32),
        "samples": _sds((k,), jnp.float32),
        "test_x": _sds((_NTEST,) + _XDIM, jnp.float32),
        "test_y": _sds((_NTEST,), jnp.int32),
    }
    cfg = {"b": _sds((), jnp.float32), "tau_max": _sds((), jnp.float32),
           "bandwidth_ratio": _sds((), jnp.float32)}
    return carry, _key_aval(), sim, cfg


def _build_device(scheme_name: str, forward=None, use_codec: bool = False):
    from repro.core.channel_lib import ChannelParams
    from repro.core.fused_round import build_device_round

    def build(k: int):
        # N = K (every UAV selected): buffers on the fleet axis and on the
        # selected-cohort axis scale together, which is the fleet-scale
        # regime the ROADMAP's sub-linear-memory item cares about
        round_fn = build_device_round(
            scheme=scheme_name, local_epochs=_E, steps_per_epoch=_STEPS,
            batch_size=_BS, lr=0.01, k_select=k, channel=ChannelParams(),
            model_bytes=1e6, ue_model_fraction=0.25, interpret=True,
            use_codec=use_codec,
            compress_ratio=0.252 if use_codec else 1.0, forward=forward)
        # the sweep engine donates the whole DeviceSimCarry at its jit
        # boundary (core/sweep._build_group_fn) — audit that same claim at
        # the round level
        fn = jax.jit(round_fn, donate_argnums=(0,))
        return fn, _device_args(k)

    return build


# ---------------------------------------------------------------------------
# kernel twins (K scales the stacked-cohort / batch axis)
# ---------------------------------------------------------------------------

def _build_kernel(pkg: str, variant: str = ""):
    def build(k: int):
        if pkg == "fused_cnn":
            from repro.kernels.fused_cnn.ops import (ForwardPolicy,
                                                     make_stacked_loss_grad)
            pol = ForwardPolicy(interpret=True,
                                precision="bf16" if variant == "bf16"
                                else "f32")
            params = _stack(_params_aval(), k)
            bx = _sds((k, _BS) + _XDIM, jnp.float32)
            by = _sds((k, _BS), jnp.int32)
            return make_stacked_loss_grad(pol), (params, bx, by)
        if pkg == "delta_codec":
            from repro.kernels.delta_codec.kernel import quantize_blocks
            x = _sds((k * 8, 512), jnp.float32)
            return (lambda a: quantize_blocks(a, interpret=True), (x,))
        if pkg == "flash_attention":
            from repro.kernels.flash_attention.kernel import \
                flash_attention_bh
            q = _sds((k, 128, 64), jnp.float32)
            return (lambda a, b, c: flash_attention_bh(
                a, b, c, causal=True, interpret=True), (q, q, q))
        if pkg == "wkv6":
            from repro.kernels.wkv6.ops import wkv6
            r = _sds((k, 64, 2, 64), jnp.float32)
            u = _sds((2, 64), jnp.float32)
            return (lambda *a: wkv6(*a, interpret=True), (r, r, r, r, u))
        raise ValueError(f"no IR program for kernels/{pkg}")

    return build


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------

def engine_programs() -> List[EngineProgram]:
    """Every program the IR sweep audits.

    The scheme list comes from the live registry, so a newly registered
    scheme enters the IR sweep automatically (coverage-asserted in
    ``tests/test_analysis_ir.py``); the kernel list is asserted against
    the ``kernels/*`` twin packages on disk the same way."""
    from repro.core.schemes import registered_schemes
    from repro.kernels.fused_cnn.ops import ForwardPolicy

    progs: List[EngineProgram] = []
    for name in registered_schemes():
        from repro.core.schemes import get_scheme
        donate = (0, 1, 2) if get_scheme(name).carries_delayed else (0,)
        progs.append(EngineProgram(
            name=f"fused_round[{name}]", family="fused_round",
            path=FUSED_PATH, build=_build_fused(name),
            donate_argnums=donate, scheme=name))
        progs.append(EngineProgram(
            name=f"device_round[{name}]", family="device_round",
            path=FUSED_PATH, build=_build_device(name),
            donate_argnums=(0,), scheme=name))
    bf16 = ForwardPolicy(precision="bf16", interpret=True)
    progs.append(EngineProgram(
        name="fused_round[opt+bf16]", family="fused_round", path=FUSED_PATH,
        build=_build_fused("opt", forward=bf16), compute_dtype="bf16",
        donate_argnums=(0,), scheme="opt"))
    progs.append(EngineProgram(
        name="device_round[opt+codec]", family="device_round",
        path=FUSED_PATH, build=_build_device("opt", use_codec=True),
        donate_argnums=(0,), scheme="opt"))
    for pkg in ("fused_cnn", "delta_codec", "flash_attention", "wkv6"):
        progs.append(EngineProgram(
            name=f"kernel[{pkg}]", family="kernel",
            path=f"src/repro/kernels/{pkg}/kernel.py",
            build=_build_kernel(pkg), twin=pkg))
    progs.append(EngineProgram(
        name="kernel[fused_cnn+bf16]", family="kernel",
        path="src/repro/kernels/fused_cnn/kernel.py",
        build=_build_kernel("fused_cnn", "bf16"), compute_dtype="bf16",
        twin="fused_cnn"))
    return progs


def program_names() -> List[str]:
    return [p.name for p in engine_programs()]


def covered_schemes() -> Dict[str, set]:
    """family -> set of scheme names with an IR entry (coverage asserts)."""
    out: Dict[str, set] = {"fused_round": set(), "device_round": set()}
    for p in engine_programs():
        if p.scheme and p.family in out:
            out[p.family].add(p.scheme)
    return out


def covered_kernel_twins() -> set:
    return {p.twin for p in engine_programs() if p.twin}
