"""IR-level program auditor — the jaxpr/HLO half of ``repro.analysis``.

The PR-8 layers see source text (AST lint) and output signatures
(eval_shape contracts).  This subpackage operates on the traced program
itself:

- ``ir.programs``   — the K-parameterized registry of engine programs
  (every registered scheme through both round builders, every kernel
  twin), so the walkers below sweep exactly what the repo ships;
- ``ir.jaxpr_audit`` — liveness-based peak-memory estimation with
  per-buffer provenance, plus the bf16→f32 silent-promotion audit;
- ``ir.alias_audit`` — lower+compile each jitted entry point and verify
  the donation the source claims against the compiled
  ``input_output_alias`` map (a dropped donation is a 2x memory surprise);
- ``ir.scaling``     — trace each program at K ∈ {4, 16, 64, 256}, fit
  per-buffer and total-peak scaling exponents in K, and gate any buffer
  that scales past its declared budget (``analysis_scaling.json``).

Everything funnels into the standard ``Finding`` stream, so the CLI's
pragma + baseline machinery applies unchanged.
"""
from repro.analysis.ir.alias_audit import audit_donation, run_alias_audit
from repro.analysis.ir.jaxpr_audit import (ProgramAudit, audit_program,
                                           run_jaxpr_audit)
from repro.analysis.ir.programs import EngineProgram, engine_programs
from repro.analysis.ir.scaling import (K_VALUES, run_scaling_gate,
                                       scaling_report, write_scaling_json)

__all__ = [
    "EngineProgram", "engine_programs", "ProgramAudit", "audit_program",
    "run_jaxpr_audit", "audit_donation", "run_alias_audit", "K_VALUES",
    "scaling_report", "run_scaling_gate", "write_scaling_json",
]
