"""K-scaling gate: fit per-buffer memory exponents in K and gate them.

The engines are *designed* to be O(K) in memory on the user axis: stacked
user batches, codec snapshots, per-UAV channel traces — one row per user.
Anything super-linear (a K×K gram matrix from a badly-ordered einsum, a
broadcast that materializes) is exactly the class of bug that is
invisible at the test sizes (K=4) and fatal at fleet scale (K=256+).

``scaling_report`` traces every registry program at K ∈ ``K_VALUES``,
reuses the jaxpr walker's per-site ``site_max_bytes``, and fits a
log-log least-squares exponent per source site plus one for the total
liveness peak.  ``run_scaling_gate`` then applies the declared budgets:

- sites in engine/kernel modules (and program arguments) are *declared*
  O(K) — the data model says one row per user;
- undeclared sites get a strict O(1) cap, so an undeclared buffer that
  grows with K at all is a finding, with the same ``path:line``
  provenance the walker gives every buffer.

The fitted report is committed as ``analysis_scaling.json``
(``--write-scaling`` regenerates it); the gate also flags drift — a
program whose total-peak exponent moved materially from the committed
record — so a regression shows up as a diff *and* a finding.
"""
from __future__ import annotations

import json
import math
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.analysis.findings import Finding
from repro.analysis.ir.jaxpr_audit import audit_program, trace_program

K_VALUES: Tuple[int, ...] = (4, 16, 64, 256)

# path-prefix -> declared exponent budget (first match wins).  The engine
# data model is one-row-per-user, so engine/kernel modules and the program
# arguments are declared O(K); jax-internal frames inherit the same budget
# (they are minted on behalf of engine code).
DECLARED_BUDGETS: Tuple[Tuple[str, float], ...] = (
    ("<argument>", 1.0),
    ("<jax-internal>", 1.0),
    ("src/repro/core/", 1.0),
    ("src/repro/kernels/", 1.0),
    ("src/repro/models/", 1.0),
    ("src/repro/", 1.0),
    ("site-packages/", 1.0),
    ("/jax/", 1.0),
)
DEFAULT_CAP = 0.0        # undeclared sites: O(1) or it's a finding
TOLERANCE = 0.35         # fit slack: cap is budget + TOLERANCE
TOTAL_PEAK_CAP = 1.0     # the whole program must stay linear in K
DRIFT_TOLERANCE = 0.25   # vs the committed analysis_scaling.json
_REPORT_SITES = 12       # top sites recorded per program (violators always)


def declared_budget(path: str) -> Optional[float]:
    """The exponent budget for a source path, or None if undeclared."""
    for prefix, cap in DECLARED_BUDGETS:
        if path.startswith(prefix) or prefix in path:
            return cap
    return None


def fit_exponent(ks: Sequence[int], byts: Sequence[int]) -> Optional[float]:
    """Least-squares slope of log(bytes) against log(K).

    Returns None when the series can't be fit (a zero-byte point)."""
    pts = [(math.log(k), math.log(b)) for k, b in zip(ks, byts) if b > 0]
    if len(pts) < 2:
        return None
    xbar = sum(x for x, _ in pts) / len(pts)
    ybar = sum(y for _, y in pts) / len(pts)
    den = sum((x - xbar) ** 2 for x, _ in pts)
    if den == 0:
        return None
    return sum((x - xbar) * (y - ybar) for x, y in pts) / den


def _fit_program(prog, k_values: Sequence[int]) -> Dict[str, Any]:
    """Trace one program across K and fit every site + the total peak."""
    per_k: Dict[int, Any] = {}
    for k in k_values:
        per_k[k] = audit_program(prog, k, closed=trace_program(prog, k))
    sites = sorted({s for a in per_k.values() for s in a.site_max_bytes},
                   key=lambda s: (s.path, s.line, s.primitive))
    site_rows: List[Dict[str, Any]] = []
    for site in sites:
        byts = [per_k[k].site_max_bytes.get(site, 0) for k in k_values]
        exp = fit_exponent(k_values, byts)
        budget = declared_budget(site.path)
        site_rows.append({
            "site": site.label(),
            "path": site.path,
            "line": site.line,
            "bytes": {str(k): b for k, b in zip(k_values, byts)},
            "exponent": None if exp is None else round(exp, 3),
            "budget": budget,
            "declared": budget is not None,
        })
    totals = [per_k[k].peak_bytes for k in k_values]
    return {
        "path": prog.path,
        "family": prog.family,
        "peak_bytes": {str(k): b for k, b in zip(k_values, totals)},
        "total_exponent": (lambda e: None if e is None else round(e, 3))(
            fit_exponent(k_values, totals)),
        "sites": site_rows,
    }


def _site_violations(row: Dict[str, Any]) -> Optional[str]:
    exp = row["exponent"]
    if exp is None:
        return None
    cap = (row["budget"] if row["declared"] else DEFAULT_CAP) + TOLERANCE
    if exp <= cap:
        return None
    biggest = max(int(b) for b in row["bytes"].values())
    if row["declared"]:
        return (f"buffer scales ~O(K^{exp:.2f}) but its module is declared "
                f"O(K^{row['budget']:.0f}) (cap {cap:.2f}; "
                f"largest {biggest / 1e6:.2f} MB)")
    return (f"undeclared buffer scales ~O(K^{exp:.2f}) in the user count "
            f"(cap {cap:.2f}; largest {biggest / 1e6:.2f} MB) — declare a "
            f"budget in analysis/ir/scaling.py or fix the allocation")


def scaling_report(programs=None,
                   k_values: Sequence[int] = K_VALUES) -> Dict[str, Any]:
    """Fit exponents for every registry program; JSON-able.

    Per program the committed record keeps the total-peak series plus the
    top ``_REPORT_SITES`` sites by size and every violating site; the
    gate itself evaluates *all* sites before truncation."""
    from repro.analysis.ir.programs import engine_programs
    report: Dict[str, Any] = {
        "k_values": list(k_values),
        "tolerance": TOLERANCE,
        "default_cap": DEFAULT_CAP,
        "programs": {},
    }
    for prog in (programs if programs is not None else engine_programs()):
        try:
            fitted = _fit_program(prog, k_values)
        except Exception as exc:
            report["programs"][prog.name] = {
                "path": prog.path, "family": prog.family,
                "error": f"{type(exc).__name__}: {exc}"}
            continue
        for row in fitted["sites"]:
            msg = _site_violations(row)
            if msg:
                row["violation"] = msg
        keep = [r for r in fitted["sites"] if "violation" in r]
        rest = sorted((r for r in fitted["sites"] if "violation" not in r),
                      key=lambda r: -max(int(b) for b in r["bytes"].values()))
        dropped = max(0, len(rest) - _REPORT_SITES)
        fitted["sites"] = keep + rest[:_REPORT_SITES]
        fitted["sites_omitted"] = dropped
        report["programs"][prog.name] = fitted
    return report


def run_scaling_gate(programs=None, k_values: Sequence[int] = K_VALUES,
                     committed: Optional[Path] = None,
                     report: Optional[Dict[str, Any]] = None
                     ) -> Tuple[List[Finding], Dict[str, Any]]:
    """Apply the budgets (and drift vs the committed record) as findings."""
    if report is None:
        report = scaling_report(programs, k_values)
    findings: List[Finding] = []
    for name, rec in report["programs"].items():
        if "error" in rec:
            findings.append(Finding(
                rec["path"], 1, 0, "ir-scaling",
                f"{name}: scaling sweep failed: {rec['error']}"))
            continue
        for row in rec["sites"]:
            if "violation" in row:
                findings.append(Finding(
                    row["path"] if row["line"] else rec["path"],
                    row["line"] or 1, 0, "ir-scaling",
                    f"{name}: {row['site']}: {row['violation']}"))
        texp = rec["total_exponent"]
        if texp is not None and texp > TOTAL_PEAK_CAP + TOLERANCE:
            findings.append(Finding(
                rec["path"], 1, 0, "ir-scaling",
                f"{name}: total liveness peak scales ~O(K^{texp:.2f}) "
                f"(cap {TOTAL_PEAK_CAP + TOLERANCE:.2f}) — the program is "
                f"super-linear in the user count"))
    if committed is not None:
        findings.extend(_drift_findings(report, committed))
    return findings, report


def _drift_findings(report: Dict[str, Any],
                    committed_path: Path) -> List[Finding]:
    try:
        committed = json.loads(Path(committed_path).read_text())
    except FileNotFoundError:
        return [Finding(
            str(committed_path), 1, 0, "ir-scaling",
            "committed scaling record missing — run "
            "`python -m repro.analysis --write-scaling` and commit it")]
    except Exception as exc:
        return [Finding(str(committed_path), 1, 0, "ir-scaling",
                        f"committed scaling record unreadable: {exc}")]
    out: List[Finding] = []
    old = committed.get("programs", {})
    for name, rec in report["programs"].items():
        texp, prev = rec.get("total_exponent"), old.get(name, {})
        pexp = prev.get("total_exponent")
        if texp is None or pexp is None:
            continue
        if abs(texp - pexp) > DRIFT_TOLERANCE:
            out.append(Finding(
                rec["path"], 1, 0, "ir-scaling",
                f"{name}: total-peak exponent drifted "
                f"{pexp:.2f} -> {texp:.2f} vs committed "
                f"analysis_scaling.json (tolerance {DRIFT_TOLERANCE}) — "
                f"regenerate with --write-scaling if intentional"))
    return out


def write_scaling_json(path: Path, report: Dict[str, Any]) -> None:
    Path(path).write_text(
        json.dumps(report, indent=2, sort_keys=True) + "\n")
