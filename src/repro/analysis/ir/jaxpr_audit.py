"""Jaxpr walker: liveness peak-memory estimate + bf16→f32 promotion audit.

``audit_program`` traces one registry entry with ``jax.make_jaxpr`` and
walks the resulting IR:

- **liveness** — a linear scan over the equations: each output buffer is
  born at its equation and dies after its last use (program outputs live
  to the end), so the running live-set total is a peak-memory estimate
  with *per-buffer provenance* — which primitive and which source line
  created each buffer (``source_info_util.user_frame``).  Call-like
  primitives (``pjit``/``scan``/``cond``/``while``/``pallas_call``/custom
  VJPs) are handled by recursion: an inner jaxpr contributes its own peak
  minus its input bytes (those are views of outer buffers) as transient
  overhead at the call site.  This is an estimate of what the program
  *asks for*, not what XLA schedules after fusion — it upper-bounds real
  allocation and, crucially for the scaling gate, it scales in K exactly
  like the real thing.
- **dtype promotion** — inside a ``compute_dtype="bf16"`` program, an
  f32 tensor born from bf16 operands is a silent upcast (the PR-7
  regression class: one stray promotion drags the whole epoch back to
  f32).  jnp implements implicit promotion *via*
  ``convert_element_type``, so the audit flags bf16→f32 converts whose
  source line shows no cast of its own (a visible ``astype``/``float32``
  on the line is deliberate and owned by the AST ``dtype-thread`` rule)
  plus any other primitive minting f32 straight from bf16 operands — a
  ``dot_general``/``conv`` with an explicit f32
  ``preferred_element_type`` excepted (the documented accumulator
  idiom).

Findings carry real ``path:line`` sites, so the CLI's pragma + baseline
machinery applies to them unchanged.
"""
from __future__ import annotations

import dataclasses
import functools
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax._src import core as jcore
from jax._src import source_info_util as _siu

from repro.analysis.findings import Finding
from repro.analysis.ir.programs import EngineProgram

TOP_N = 8            # live buffers reported at the peak program point

# f32-accumulating contractions are policy, not leaks
_ACCUM_PRIMS = frozenset({"dot_general", "conv_general_dilated"})
# source-line tokens that make an upcast *visible* (deliberate casts are
# the AST dtype-thread rule's jurisdiction, not the IR audit's)
_CAST_MARKERS = ("astype", "float32", "f32", "convert", "promote")


@dataclasses.dataclass(frozen=True)
class BufferSite:
    """Where a buffer was born: repo-relative source line + primitive."""
    path: str
    line: int
    primitive: str

    def label(self) -> str:
        return f"{self.path}:{self.line} ({self.primitive})"


@dataclasses.dataclass
class BufferInfo:
    site: BufferSite
    nbytes: int
    shape: Tuple[int, ...]
    dtype: str


@dataclasses.dataclass
class ProgramAudit:
    """One program's walk: the peak estimate and everything it's made of."""
    name: str
    peak_bytes: int
    peak_live: List[BufferInfo]              # live set at the peak point
    site_max_bytes: Dict[BufferSite, int]    # per-site max buffer bytes
    n_eqns: int

    def top_buffers(self, n: int = TOP_N) -> List[BufferInfo]:
        return sorted(self.peak_live, key=lambda b: -b.nbytes)[:n]


def _repo_relative(filename: str) -> str:
    """``/abs/.../src/repro/x.py`` -> ``src/repro/x.py`` (best effort)."""
    norm = filename.replace("\\", "/")
    for anchor in ("src/repro/", "benchmarks/", "examples/", "tests/"):
        idx = norm.find(anchor)
        if idx >= 0:
            return norm[idx:]
    return norm


def _site(eqn: jcore.JaxprEqn) -> BufferSite:
    frame = None
    try:
        frame = _siu.user_frame(eqn.source_info)
    except Exception:
        pass
    if frame is None:
        return BufferSite("<jax-internal>", 0, eqn.primitive.name)
    return BufferSite(_repo_relative(frame.file_name), frame.start_line,
                      eqn.primitive.name)


def _aval_bytes(aval: Any) -> int:
    try:
        return int(aval.size) * jnp.dtype(aval.dtype).itemsize
    except Exception:     # tokens, refs without layouts, abstract units
        return 0


def _aval_info(aval: Any, site: BufferSite) -> BufferInfo:
    shape = tuple(getattr(aval, "shape", ()))
    dtype = str(getattr(aval, "dtype", "-"))
    return BufferInfo(site, _aval_bytes(aval), shape, dtype)


def _sub_jaxprs(eqn: jcore.JaxprEqn) -> List[jcore.Jaxpr]:
    """Inner jaxprs of a call-like equation (scan/pjit/cond/while/...)."""
    out: List[jcore.Jaxpr] = []
    for val in eqn.params.values():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for x in vals:
            if isinstance(x, jcore.ClosedJaxpr):
                out.append(x.jaxpr)
            elif isinstance(x, jcore.Jaxpr):
                out.append(x)
    return out


def _walk(jaxpr: jcore.Jaxpr, in_bufs: Dict[Any, BufferInfo],
          site_max: Dict[BufferSite, int],
          depth: int = 0) -> Tuple[int, List[BufferInfo]]:
    """Linear-scan liveness over one jaxpr.

    Returns ``(peak_bytes, live_set_at_peak)``; ``site_max`` accumulates
    the largest single buffer each source site ever created (recursively
    — the scaling gate fits per-site exponents from it)."""
    live: Dict[Any, BufferInfo] = dict(in_bufs)
    last_use: Dict[Any, int] = {}
    for i, eqn in enumerate(jaxpr.eqns):
        for v in eqn.invars:
            if isinstance(v, jcore.Var):
                last_use[v] = i
    for v in jaxpr.outvars:
        if isinstance(v, jcore.Var):
            last_use[v] = len(jaxpr.eqns)

    peak = sum(b.nbytes for b in live.values())
    peak_live = list(live.values())
    for i, eqn in enumerate(jaxpr.eqns):
        site = _site(eqn)
        for v in eqn.outvars:
            if isinstance(v, jcore.Var):
                info = _aval_info(v.aval, site)
                live[v] = info
                if info.nbytes > site_max.get(site, 0):
                    site_max[site] = info.nbytes
        inner_extra, inner_live = 0, []
        if depth < 12:
            for sub in _sub_jaxprs(eqn):
                sub_in = {
                    v: _aval_info(v.aval, site)
                    for v in list(sub.invars) + list(sub.constvars)
                    if isinstance(v, jcore.Var)}
                sub_peak, sub_live = _walk(sub, sub_in, site_max, depth + 1)
                # inner inputs are views of outer buffers already counted
                sub_in_bytes = sum(b.nbytes for b in sub_in.values())
                extra = max(0, sub_peak - sub_in_bytes)
                if extra > inner_extra:
                    inner_extra, inner_live = extra, [
                        b for b in sub_live
                        if b.nbytes > 0 and b.site.path != "<jax-internal>"]
        cur = sum(b.nbytes for b in live.values()) + inner_extra
        if cur > peak:
            peak = cur
            peak_live = list(live.values()) + inner_live
        for v in list(live):
            if last_use.get(v, -1) <= i:
                del live[v]
    return peak, peak_live


def trace_program(prog: EngineProgram, k: int) -> jcore.ClosedJaxpr:
    fn, args = prog.build(k)
    return jax.make_jaxpr(fn)(*args)


def audit_program(prog: EngineProgram, k: int = 4,
                  closed: Optional[jcore.ClosedJaxpr] = None) -> ProgramAudit:
    """Trace (or reuse ``closed``) and walk one program at user count K."""
    if closed is None:
        closed = trace_program(prog, k)
    jaxpr = closed.jaxpr
    arg_site = BufferSite("<argument>", 0, "argument")
    in_bufs = {v: _aval_info(v.aval, arg_site)
               for v in list(jaxpr.invars) + list(jaxpr.constvars)
               if isinstance(v, jcore.Var)}
    site_max: Dict[BufferSite, int] = {
        arg_site: max((b.nbytes for b in in_bufs.values()), default=0)}
    n_eqns = sum(1 for _ in _iter_eqns(jaxpr))
    peak, peak_live = _walk(jaxpr, in_bufs, site_max)
    return ProgramAudit(name=prog.name, peak_bytes=peak,
                        peak_live=peak_live, site_max_bytes=site_max,
                        n_eqns=n_eqns)


def _iter_eqns(jaxpr: jcore.Jaxpr, depth: int = 0
               ) -> Iterable[jcore.JaxprEqn]:
    for eqn in jaxpr.eqns:
        yield eqn
        if depth < 12:
            for sub in _sub_jaxprs(eqn):
                yield from _iter_eqns(sub, depth + 1)


# ---------------------------------------------------------------------------
# dtype-promotion audit (bf16 programs only)
# ---------------------------------------------------------------------------

def _float_dtypes(vars_: Iterable[Any]) -> set:
    out = set()
    for v in vars_:
        dt = getattr(getattr(v, "aval", None), "dtype", None)
        if dt is not None and jnp.issubdtype(dt, jnp.floating):
            out.add(jnp.dtype(dt))
    return out


def _repo_root() -> Path:
    # src/repro/analysis/ir/jaxpr_audit.py -> four levels up
    return Path(__file__).resolve().parents[4]


@functools.lru_cache(maxsize=4096)
def _source_line(path: str, line: int) -> Optional[str]:
    try:
        lines = (_repo_root() / path).read_text().splitlines()
        return lines[line - 1] if 1 <= line <= len(lines) else None
    except OSError:
        return None


def _visible_cast(site: BufferSite) -> bool:
    """True when the offending source line shows the cast itself.

    Unreadable sites (jax internals, generated code) count as visible —
    the audit only claims *silent* when it can read the line and see
    nothing."""
    text = _source_line(site.path, site.line)
    if text is None:
        return True
    low = text.lower()
    return any(m in low for m in _CAST_MARKERS)


def dtype_promotions(prog: EngineProgram,
                     closed: Optional[jcore.ClosedJaxpr] = None,
                     k: int = 4) -> List[Finding]:
    """f32 tensors born from bf16 operands inside a bf16-policy program."""
    if prog.compute_dtype != "bf16":
        return []
    if closed is None:
        closed = trace_program(prog, k)
    bf16, f32 = jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float32)
    findings: List[Finding] = []
    seen = set()
    for eqn in _iter_eqns(closed.jaxpr):
        name = eqn.primitive.name
        if _sub_jaxprs(eqn):
            continue           # calls are audited through their bodies
        if name in _ACCUM_PRIMS and \
                eqn.params.get("preferred_element_type") == jnp.float32:
            continue           # documented f32-accumulator idiom
        if bf16 not in _float_dtypes(eqn.invars):
            continue
        outs = [v for v in eqn.outvars
                if getattr(getattr(v, "aval", None), "dtype", None) == f32]
        if not outs:
            continue
        site = _site(eqn)
        if name == "convert_element_type" and _visible_cast(site):
            continue           # deliberate cast: dtype-thread's business
        key = (site.path, site.line, name)
        if key in seen:
            continue
        seen.add(key)
        shape = tuple(getattr(outs[0].aval, "shape", ()))
        how = ("implicit promotion (jnp inserted the upcast)"
               if name == "convert_element_type"
               else f"{name} mints f32 from bf16 operands")
        findings.append(Finding(
            site.path, site.line, 0, "ir-dtype",
            f"{prog.name}: {how} -> f32{list(shape)} inside a "
            f"compute_dtype=bf16 program — a silent upcast; cast "
            f"explicitly (a visible astype/float32 on the line is "
            f"exempt) or keep the op in bf16"))
    return findings


def run_jaxpr_audit(programs=None, k: int = 4
                    ) -> Tuple[List[Finding], List[ProgramAudit]]:
    """Walk every registry program once: dtype findings + memory audits.

    A program that fails to trace is itself a finding (same convention as
    the eval_shape contract sweep)."""
    from repro.analysis.ir.programs import engine_programs
    findings: List[Finding] = []
    audits: List[ProgramAudit] = []
    for prog in (programs if programs is not None else engine_programs()):
        try:
            closed = trace_program(prog, k)
        except Exception as exc:      # a broken trace IS the finding
            findings.append(Finding(
                prog.path, 1, 0, "ir-trace",
                f"{prog.name}: jaxpr trace failed at K={k}: "
                f"{type(exc).__name__}: {exc}"))
            continue
        audits.append(audit_program(prog, k, closed=closed))
        findings.extend(dtype_promotions(prog, closed=closed, k=k))
    return findings, audits
