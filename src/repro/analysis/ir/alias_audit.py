"""Donation/aliasing verifier: does XLA keep the donation the source claims?

``jax.jit(..., donate_argnums=...)`` is a *request*: XLA only aliases a
donated input buffer onto an output with matching shape/dtype/layout, and
silently drops the rest (jax emits a UserWarning, nobody reads it in CI).
A dropped donation is a 2x memory surprise on exactly the buffers the
engines chained donation for — the full params stack, the async straggler
carry, the whole ``DeviceSimCarry``.

``audit_donation`` lowers + compiles one registry program off its avals
and cross-checks three sources:

1. the ``input_output_alias`` map parsed off the compiled module header
   (``utils/hlo.input_output_aliases`` — the ground truth);
2. the flattened donation claim (``donate_argnums`` → flat parameter
   numbers, via the arguments' tree structure);
3. jax's "Some donated buffers were not usable" warning, captured for the
   offending avals so the finding names the exact leaves.

Compiled memory stats ride along through the same
``utils/hlo.compiled_memory_stats`` plumbing the dry-run uses, so the
audit record shows what the aliasing is actually worth in bytes.
"""
from __future__ import annotations

import warnings
from typing import Any, Dict, List, Tuple

import jax

from repro.analysis.findings import Finding
from repro.analysis.ir.programs import EngineProgram
from repro.utils.hlo import aliased_parameters, compiled_memory_stats

_DROP_WARNING = "donated buffers were not usable"


def donated_flat_indices(args: Tuple[Any, ...],
                         donate_argnums: Tuple[int, ...]) -> List[int]:
    """Flat parameter numbers the donation claim covers.

    jit flattens its arguments depth-first in order, so top-level argument
    ``i``'s leaves occupy a contiguous run of parameter numbers."""
    idx, out = 0, []
    for i, arg in enumerate(args):
        leaves = jax.tree_util.tree_leaves(arg)
        if i in donate_argnums:
            out.extend(range(idx, idx + len(leaves)))
        idx += len(leaves)
    return out


def audit_donation(prog: EngineProgram, k: int = 4
                   ) -> Tuple[List[Finding], Dict[str, Any]]:
    """Compile one program and verify its donation claim end to end.

    Returns ``(findings, record)``; the record carries the compiled
    memory stats and the alias coverage for reporting."""
    fn, args = prog.build(k)
    record: Dict[str, Any] = {"program": prog.name, "k": k}
    if not prog.donate_argnums or not hasattr(fn, "lower"):
        record["skipped"] = "no donation claim / not a jitted entry point"
        return [], record
    dropped_msgs: List[str] = []
    try:
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            compiled = fn.lower(*args).compile()
        dropped_msgs = [str(w.message) for w in caught
                        if _DROP_WARNING in str(w.message)]
    except Exception as exc:          # a broken compile IS the finding
        return [Finding(
            prog.path, 1, 0, "ir-alias",
            f"{prog.name}: lower+compile failed: "
            f"{type(exc).__name__}: {exc}")], record

    hlo = compiled.as_text()
    aliased = set(aliased_parameters(hlo))
    claimed = donated_flat_indices(args, prog.donate_argnums)
    missing = sorted(set(claimed) - aliased)
    record.update(
        memory=compiled_memory_stats(compiled),
        claimed_donated=len(claimed), aliased=sorted(aliased),
        missing=missing)

    findings: List[Finding] = []
    if missing:
        detail = ("; jax: " + "; ".join(m.splitlines()[0]
                                        for m in dropped_msgs)
                  if dropped_msgs else "")
        lost = sum(_flat_leaf_bytes(args)[i] for i in missing)
        findings.append(Finding(
            prog.path, 1, 0, "ir-alias",
            f"{prog.name}: donate_argnums={prog.donate_argnums} claims "
            f"{len(claimed)} donated buffers but the compiled "
            f"input_output_alias map only covers "
            f"{len(aliased & set(claimed))} — XLA silently dropped flat "
            f"parameter(s) {missing} (~{lost / 1e6:.2f} MB double-"
            f"buffered every dispatch){detail}"))
    elif dropped_msgs:
        # belt and braces: the warning fired but the alias map looks
        # complete — surface it rather than second-guess the parse
        findings.append(Finding(
            prog.path, 1, 0, "ir-alias",
            f"{prog.name}: jax reported dropped donations "
            f"({dropped_msgs[0].splitlines()[0]}) not visible in the "
            f"input_output_alias map"))
    return findings, record


def _flat_leaf_bytes(args: Tuple[Any, ...]) -> List[int]:
    out = []
    for arg in args:
        for leaf in jax.tree_util.tree_leaves(arg):
            try:
                out.append(int(leaf.size)
                           * jax.numpy.dtype(leaf.dtype).itemsize)
            except Exception:
                out.append(0)
    return out


def run_alias_audit(programs=None, k: int = 4, families=("fused_round",)
                    ) -> Tuple[List[Finding], List[Dict[str, Any]]]:
    """Verify donation for every jitted entry point in the registry.

    Compiling is the expensive half of the IR sweep, so the default only
    compiles the ``fused_round`` family — the entry points whose donation
    the host engine chains round over round (``families=None`` audits
    everything, which the scheduled CI job uses for the device rounds
    too)."""
    from repro.analysis.ir.programs import engine_programs
    findings: List[Finding] = []
    records: List[Dict[str, Any]] = []
    for prog in (programs if programs is not None else engine_programs()):
        if families is not None and prog.family not in families:
            continue
        f, rec = audit_donation(prog, k)
        findings.extend(f)
        records.append(rec)
    return findings, records


def audit_callable(name: str, fn: Any, args: Tuple[Any, ...],
                   donate_argnums: Tuple[int, ...],
                   path: str = "<fixture>") -> List[Finding]:
    """Ad-hoc entry point (tests / notebooks): audit any jitted callable."""
    prog = EngineProgram(name=name, family="fixture", path=path,
                         build=lambda k: (fn, args),
                         donate_argnums=donate_argnums)
    return audit_donation(prog)[0]
