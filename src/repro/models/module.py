"""Minimal pure-JAX module substrate (no flax).

Params are plain nested dicts of jnp arrays.  Every layer is a pair of
functions: ``init_*(key, cfg) -> params`` and ``apply_*(params, x, ...)``.
Stacked decoder layers are initialised with ``jax.vmap`` over per-layer keys,
giving every leaf a leading ``(num_layers, ...)`` axis that ``lax.scan``
consumes — compile time is O(1) in depth.
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# initialisers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32,
               scale: float | None = None):
    """Fan-in scaled truncated-normal (LeCun) weight (in_dim, out_dim)."""
    std = scale if scale is not None else in_dim ** -0.5
    w = jax.random.truncated_normal(key, -2.0, 2.0, (in_dim, out_dim)) * std
    return w.astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


def zeros(shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


def ones(shape, dtype=jnp.float32):
    return jnp.ones(shape, dtype)


def stack_layers(init_fn: Callable[[jax.Array], Params], key,
                 num_layers: int) -> Params:
    """vmap a single-layer init over per-layer keys -> stacked leaves (L, ...)."""
    keys = jax.random.split(key, num_layers)
    return jax.vmap(init_fn)(keys)


# ---------------------------------------------------------------------------
# pytree helpers
# ---------------------------------------------------------------------------

def param_count(params: Params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


def param_bytes(params: Params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(params))


def tree_cast(params: Params, dtype) -> Params:
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        params)


def tree_zeros_like(params: Params) -> Params:
    return jax.tree_util.tree_map(jnp.zeros_like, params)


def tree_add(a: Params, b: Params) -> Params:
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_sub(a: Params, b: Params) -> Params:
    return jax.tree_util.tree_map(jnp.subtract, a, b)


def tree_scale(a: Params, s) -> Params:
    return jax.tree_util.tree_map(lambda x: x * s, a)


def tree_lerp(a: Params, b: Params, w) -> Params:
    """(1-w)*a + w*b, leafwise; w may be a scalar tracer."""
    return jax.tree_util.tree_map(lambda x, y: (1.0 - w) * x + w * y, a, b)


def tree_where(pred, a: Params, b: Params) -> Params:
    """Select whole trees by a scalar predicate (used by opportunistic sync)."""
    return jax.tree_util.tree_map(lambda x, y: jnp.where(pred, x, y), a, b)


def global_norm(params: Params) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(params)]
    return jnp.sqrt(sum(leaves))
