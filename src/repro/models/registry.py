"""Model registry: resolve an arch id to a uniform model API."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax

from repro.configs.base import ModelConfig, get_config
from repro.models import cnn as cnn_mod
from repro.models import module as m
from repro.models import transformer as tf


@dataclass(frozen=True)
class Model:
    """Uniform handle: pure init/apply callables bound to one config."""
    cfg: ModelConfig
    init: Callable[[jax.Array], Dict[str, Any]]
    forward: Callable[..., Any]            # (params, inputs, opts) -> (logits, aux)
    decode: Optional[Callable[..., Any]]   # (params, token, state, position, opts)
    init_decode_state: Optional[Callable[..., Any]]

    def param_count(self, params) -> int:
        return m.param_count(params)


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "cnn":
        return Model(
            cfg=cfg,
            init=lambda key: cnn_mod.init_cnn(key, cfg.vocab_size, cfg.d_model),
            forward=lambda p, inputs, opts=None: (
                cnn_mod.forward(p, inputs["images"]), 0.0),
            decode=None,
            init_decode_state=None,
        )
    has_decode = not cfg.is_encoder_only
    return Model(
        cfg=cfg,
        init=lambda key: tf.init_model(key, cfg),
        forward=lambda p, inputs, opts=None: tf.forward_full(p, cfg, inputs, opts),
        decode=(lambda p, token, state, position, opts=None:
                tf.decode_step(p, cfg, token, state, position, opts)
                ) if has_decode else None,
        init_decode_state=(lambda batch, context_len, dtype:
                           tf.init_decode_state(cfg, batch, context_len, dtype)
                           ) if has_decode else None,
    )


def get_model(arch_id: str) -> Model:
    return build_model(get_config(arch_id))


def get_reduced_model(arch_id: str) -> Model:
    return build_model(get_config(arch_id).reduced())
