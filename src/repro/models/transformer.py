"""Universal scanned-transformer spine for every assigned family.

One block definition covers:
  dense / vlm / audio : norm -> GQA attention -> res ; norm -> SwiGLU -> res
  moe                 : ... ; norm -> top-k MoE FFN -> res (aux accumulated)
  ssm (rwkv6)         : norm -> WKV6 time-mix -> res ; norm -> channel-mix -> res
  hybrid (hymba)      : norm -> (attention || mamba) branch-normed mean -> res ;
                        norm -> SwiGLU -> res

Per-layer weights are stacked on a leading (L, ...) axis and consumed by
``lax.scan`` — compile time is O(1) in depth (essential for llama3-405b's
126 layers under the dry-run).  ``opts``:
  impl          'xla' | 'flash'       (attention path)
  wkv_impl      'xla' | 'wkv6_kernel' (rwkv6 path)
  moe_dispatch  'scatter' | 'dense'
  remat         'none' | 'full' | 'dots'
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import mamba as mb
from repro.models import module as m
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rk
from repro.models.rope import text_positions

DEFAULT_OPTS = {"impl": "xla", "wkv_impl": "xla",
                "moe_dispatch": "scatter", "remat": "none",
                # activation sharding map (sharding/apply.py); None = no-op
                "act_sharding": None,
                # unroll the layer scan (dry-run FLOPs calibration only)
                "unroll_layers": False}


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {
        "norm1": L.init_rmsnorm(cfg.d_model),
        "norm2": L.init_rmsnorm(cfg.d_model),
    }
    if cfg.family == "ssm":
        p["time"] = rk.init_time_mix(ks[0], cfg)
        p["channel"] = rk.init_channel_mix(ks[1], cfg)
        return p
    p["attn"] = attn.init_attention(ks[0], cfg)
    if cfg.family == "hybrid":
        p["mamba"] = mb.init_mamba(ks[1], cfg)
        p["bnorm_attn"] = L.init_rmsnorm(cfg.d_model)
        p["bnorm_mamba"] = L.init_rmsnorm(cfg.d_model)
    if cfg.num_experts:
        p["moe"] = moe_mod.init_moe(ks[2], cfg)
    else:
        p["mlp"] = L.init_mlp(ks[2], cfg)
    return p


def init_model(key, cfg: ModelConfig) -> Dict[str, Any]:
    k_emb, k_layers, k_head, k_fin = jax.random.split(key, 4)
    params: Dict[str, Any] = {}
    if not (cfg.family == "audio" and cfg.frontend_stub):
        params["embed"] = L.init_embedding(k_emb, cfg)
    params["layers"] = m.stack_layers(
        lambda k: _init_layer(k, cfg), k_layers, cfg.num_layers)
    params["final_norm"] = L.init_rmsnorm(cfg.d_model)
    params["head"] = L.init_lm_head(k_head, cfg)
    return params


# ---------------------------------------------------------------------------
# block bodies
# ---------------------------------------------------------------------------

def _mixer_full(p, cfg: ModelConfig, h: jnp.ndarray, positions, opts) -> jnp.ndarray:
    act = opts["act_sharding"]
    if cfg.family == "ssm":
        return rk.time_mix_full(p["time"], cfg, h, impl=opts["wkv_impl"])
    if cfg.family == "hybrid":
        a = attn.attend_full(p["attn"], cfg, h, positions, impl=opts["impl"],
                             act=act)
        s = mb.mamba_full(p["mamba"], cfg, h)
        return 0.5 * (L.rmsnorm(p["bnorm_attn"], a, cfg.norm_eps)
                      + L.rmsnorm(p["bnorm_mamba"], s, cfg.norm_eps))
    return attn.attend_full(p["attn"], cfg, h, positions, impl=opts["impl"],
                            act=act)


def _ffn_full(p, cfg: ModelConfig, h: jnp.ndarray,
              opts) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if cfg.family == "ssm":
        return rk.channel_mix_full(p["channel"], cfg, h), jnp.zeros((), jnp.float32)
    if cfg.num_experts:
        return moe_mod.moe_ffn(p["moe"], cfg, h, dispatch=opts["moe_dispatch"],
                               act=opts["act_sharding"])
    return L.mlp(p["mlp"], h), jnp.zeros((), jnp.float32)


def _layer_full(p, cfg: ModelConfig, x: jnp.ndarray, positions, opts):
    from repro.sharding.apply import constrain

    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    x = x + _mixer_full(p, cfg, h, positions, opts)
    h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
    y, aux = _ffn_full(p, cfg, h, opts)
    out = constrain(x + y, opts["act_sharding"], "B", None, None)
    return out, aux


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def embed_inputs(params, cfg: ModelConfig, inputs: Dict[str, jnp.ndarray],
                 dtype) -> jnp.ndarray:
    """Resolve the input embedding for every modality (stub carve-out)."""
    if cfg.family == "audio" and cfg.frontend_stub:
        return inputs["embeds"].astype(dtype)          # precomputed frames
    x = L.embed(params["embed"], inputs["tokens"], dtype)
    if cfg.family == "vlm" and "patch_embeds" in inputs:
        pe = inputs["patch_embeds"].astype(dtype)      # (B, P, d) early fusion
        P = pe.shape[1]
        x = jax.lax.dynamic_update_slice(x, pe, (0, 0, 0)) if P == x.shape[1] \
            else x.at[:, :P].set(pe)
    return x


def forward_full(params, cfg: ModelConfig, inputs: Dict[str, jnp.ndarray],
                 opts: Optional[dict] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits (B, S, vocab_padded), moe_aux scalar)."""
    from repro.sharding.apply import constrain

    opts = {**DEFAULT_OPTS, **(opts or {})}
    dtype = m.dtype_of(cfg.dtype)
    x = embed_inputs(params, cfg, inputs, dtype)
    x = constrain(x, opts["act_sharding"], "B", None, None)
    B, S = x.shape[:2]
    positions = inputs.get("positions")
    if positions is None:
        positions = text_positions(B, S, mrope=bool(cfg.mrope_sections))

    def body(x, layer_p):
        return _layer_full(layer_p, cfg, x, positions, opts)

    if opts["remat"] == "full":
        body = jax.checkpoint(body)
    elif opts["remat"] == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    if opts["unroll_layers"]:
        auxs = []
        for i in range(cfg.num_layers):
            layer_p = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            x, aux = body(x, layer_p)
            auxs.append(aux)
        auxs = jnp.stack(auxs)
    else:
        x, auxs = jax.lax.scan(body, x, params["layers"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if opts.get("return_hidden"):
        return x, jnp.sum(auxs)          # fused-head loss path (§Perf)
    logits = L.lm_logits(params["head"], params.get("embed"), cfg, x)
    return logits, jnp.sum(auxs)


# ---------------------------------------------------------------------------
# decode (single new token against carried per-layer state)
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, context_len: int,
                      dtype) -> Dict[str, Any]:
    """Stacked (L, ...) per-layer state pytree for lax.scan consumption."""
    Lr = cfg.num_layers

    def rep(tree):
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (Lr,) + a.shape), tree)

    st: Dict[str, Any] = {}
    if cfg.family == "ssm":
        st["rwkv"] = rep(rk.init_rwkv_state(cfg, batch, dtype))
        return st
    st["kv"] = rep(attn.init_cache(cfg, batch, context_len, dtype))
    if cfg.family == "hybrid":
        st["mamba"] = rep(mb.init_mamba_state(cfg, batch, dtype))
    return st


def _layer_decode(p, cfg: ModelConfig, x, state, position, opts):
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    new_state = dict(state)
    if cfg.family == "ssm":
        y, rst = rk.time_mix_decode(p["time"], cfg, h, state["rwkv"])
        new_state["rwkv"] = rst
        x = x + y
        h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        y, rst = rk.channel_mix_decode(p["channel"], cfg, h, new_state["rwkv"])
        new_state["rwkv"] = rst
        return x + y, new_state
    if cfg.family == "hybrid":
        a, kv = attn.attend_decode(p["attn"], cfg, h, state["kv"], position)
        s, mst = mb.mamba_decode(p["mamba"], cfg, h, state["mamba"])
        new_state["kv"], new_state["mamba"] = kv, mst
        y = 0.5 * (L.rmsnorm(p["bnorm_attn"], a, cfg.norm_eps)
                   + L.rmsnorm(p["bnorm_mamba"], s, cfg.norm_eps))
    else:
        y, kv = attn.attend_decode(p["attn"], cfg, h, state["kv"], position)
        new_state["kv"] = kv
    x = x + y
    h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
    if cfg.num_experts:
        y, _ = moe_mod.moe_ffn(p["moe"], cfg, h, dispatch=opts["moe_dispatch"])
    else:
        y = L.mlp(p["mlp"], h)
    return x + y, new_state


def decode_step(params, cfg: ModelConfig, token: jnp.ndarray,
                state: Dict[str, Any], position: jnp.ndarray,
                opts: Optional[dict] = None) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """token: (B, 1) int32; position: (B,) absolute index of the new token.
    Returns (logits (B, 1, vocab_padded), new_state)."""
    from repro.sharding.apply import constrain

    opts = {**DEFAULT_OPTS, **(opts or {})}
    dtype = m.dtype_of(cfg.dtype)
    x = L.embed(params["embed"], token, dtype)
    x = constrain(x, opts["act_sharding"], "B", None, None)

    def body(x, xs):
        layer_p, st = xs
        x, new_st = _layer_decode(layer_p, cfg, x, st, position, opts)
        x = constrain(x, opts["act_sharding"], "B", None, None)
        return x, new_st

    if opts["unroll_layers"]:
        new_states = []
        for i in range(cfg.num_layers):
            xs_i = jax.tree_util.tree_map(lambda a: a[i],
                                          (params["layers"], state))
            x, st_i = body(x, xs_i)
            new_states.append(st_i)
        new_state = jax.tree_util.tree_map(
            lambda *leaves: jnp.stack(leaves), *new_states)
    else:
        x, new_state = jax.lax.scan(body, x, (params["layers"], state))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.lm_logits(params["head"], params.get("embed"), cfg, x)
    return logits, new_state
