"""RWKV6 "Finch" time-mix + channel-mix [arXiv:2404.05892].

Headline Finch feature implemented faithfully: **data-dependent decay**
w_t = exp(-exp(w0 + tanh(x W_a) W_b)) per key channel, per step.  Token-shift
interpolation uses static per-channel mixes (the full ddlerp low-rank mix is a
recorded simplification; decay *is* data-dependent).  The WKV recurrence per
head (state S in R^{DxD}):

    y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

Full-sequence mode is a lax.scan over time (the Pallas `wkv6` kernel is the
TPU hot-path; kernels/wkv6/ref.py wraps the same math).  Decode carries
(shift_t, shift_c, S).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import module as m

DECAY_RANK = 64


def init_time_mix(key, cfg: ModelConfig):
    pdt = m.dtype_of(cfg.param_dtype)
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    return {
        "mu": (jax.random.uniform(ks[0], (5, d)) * 0.5 + 0.25).astype(jnp.float32),
        "w_r": m.dense_init(ks[1], d, d, pdt),
        "w_k": m.dense_init(ks[2], d, d, pdt),
        "w_v": m.dense_init(ks[3], d, d, pdt),
        "w_g": m.dense_init(ks[4], d, d, pdt),
        "w_o": m.dense_init(ks[5], d, d, pdt),
        "decay_a": m.dense_init(ks[6], d, DECAY_RANK, pdt, scale=0.01),
        "decay_b": m.dense_init(ks[7], DECAY_RANK, d, pdt, scale=0.01),
        "decay_w0": (jnp.linspace(-6.0, -1.0, d)).astype(jnp.float32),
        "bonus_u": (jnp.zeros((d,))).astype(jnp.float32),
        "ln_scale": m.ones((d,), jnp.float32),      # per-head groupnorm scale
    }


def _mix(x, xx, mu):
    return x + (xx - x) * mu.astype(x.dtype)


def _decay(params, xw: jnp.ndarray) -> jnp.ndarray:
    """Data-dependent decay in (0,1).  xw: (..., d) mixed input."""
    dt = xw.dtype
    lo = jnp.tanh(xw @ params["decay_a"].astype(dt)) @ params["decay_b"].astype(dt)
    return jnp.exp(-jnp.exp(params["decay_w0"] + lo.astype(jnp.float32)))


def _group_norm(y: jnp.ndarray, scale: jnp.ndarray, H: int, eps: float = 64e-5):
    """Per-head groupnorm over head_dim.  y: (..., d)."""
    shp = y.shape
    yh = y.reshape(*shp[:-1], H, shp[-1] // H).astype(jnp.float32)
    mean = yh.mean(axis=-1, keepdims=True)
    var = yh.var(axis=-1, keepdims=True)
    yh = (yh - mean) * jax.lax.rsqrt(var + eps)
    return (yh.reshape(shp) * scale).astype(y.dtype)


def _wkv_inputs(params, cfg: ModelConfig, x: jnp.ndarray, xx: jnp.ndarray):
    """Project mixed inputs to per-head r,k,v,w,g.  x, xx: (B, S, d)."""
    dt = x.dtype
    mu = params["mu"]
    r = _mix(x, xx, mu[0]) @ params["w_r"].astype(dt)
    k = _mix(x, xx, mu[1]) @ params["w_k"].astype(dt)
    v = _mix(x, xx, mu[2]) @ params["w_v"].astype(dt)
    g = _mix(x, xx, mu[3]) @ params["w_g"].astype(dt)
    w = _decay(params, _mix(x, xx, mu[4]))
    return r, k, v, w, g


def wkv_scan(r, k, v, w, u, S0):
    """Reference WKV recurrence.  r,k,v,w: (B, S, H, D); u: (H, D);
    S0: (B, H, D, D).  Returns (y (B,S,H,D), S_final)."""
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))

    def step(S, t):
        r_t, k_t, v_t, w_t = t                                # (B, H, D)
        kv = k_t[..., :, None] * v_t[..., None, :]            # (B,H,D,D)
        y = jnp.einsum("bhi,bhij->bhj", r_t, S + u[..., :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, wf))
    S, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1), S


def time_mix_full(params, cfg: ModelConfig, x: jnp.ndarray,
                  impl: str = "xla") -> jnp.ndarray:
    """Full-sequence time-mix.  x: (B, S, d)."""
    B, S, d = x.shape
    D = cfg.head_dim
    H = d // D
    xx = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]         # token shift
    r, k, v, w, g = _wkv_inputs(params, cfg, x, xx)
    rh, kh, vh, wh = (a.reshape(B, S, H, D) for a in (r, k, v, w))
    u = params["bonus_u"].reshape(H, D)
    S0 = jnp.zeros((B, H, D, D), jnp.float32)
    if impl == "wkv6_kernel":
        from repro.kernels.wkv6 import ops as wkv_ops
        y, _ = wkv_ops.wkv6(rh, kh, vh, wh, u, S0)
    else:
        y, _ = wkv_scan(rh, kh, vh, wh, u, S0)
    y = y.reshape(B, S, d).astype(x.dtype)
    y = _group_norm(y, params["ln_scale"], H)
    return (y * jax.nn.silu(g)) @ params["w_o"].astype(x.dtype)


def init_channel_mix(key, cfg: ModelConfig):
    pdt = m.dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    return {
        "mu": (jax.random.uniform(ks[2], (2, cfg.d_model))
               * 0.5 + 0.25).astype(jnp.float32),
        "w_k": m.dense_init(ks[0], cfg.d_model, cfg.d_ff, pdt),
        "w_v": m.dense_init(ks[1], cfg.d_ff, cfg.d_model, pdt),
        "w_r": m.dense_init(jax.random.fold_in(ks[0], 1), cfg.d_model,
                            cfg.d_model, pdt),
    }


def channel_mix_full(params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    dt = x.dtype
    xx = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    k = _mix(x, xx, params["mu"][0]) @ params["w_k"].astype(dt)
    r = _mix(x, xx, params["mu"][1]) @ params["w_r"].astype(dt)
    v = jnp.square(jax.nn.relu(k)) @ params["w_v"].astype(dt)
    return jax.nn.sigmoid(r) * v


# ---------------------------------------------------------------------------
# decode (single token, carried state)
# ---------------------------------------------------------------------------

def init_rwkv_state(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jnp.ndarray]:
    D = cfg.head_dim
    H = cfg.d_model // D
    return {
        "shift_t": jnp.zeros((batch, cfg.d_model), dtype),
        "shift_c": jnp.zeros((batch, cfg.d_model), dtype),
        "wkv": jnp.zeros((batch, H, D, D), jnp.float32),
    }


def time_mix_decode(params, cfg: ModelConfig, x: jnp.ndarray,
                    state: Dict[str, jnp.ndarray]) -> Tuple[jnp.ndarray, Dict]:
    """x: (B, 1, d)."""
    B, _, d = x.shape
    D = cfg.head_dim
    H = d // D
    x1 = x[:, 0]
    xx = state["shift_t"]
    r, k, v, w, g = _wkv_inputs(params, cfg, x1, xx)
    rh, kh, vh, wh = (a.reshape(B, H, D).astype(jnp.float32)
                      for a in (r, k, v, w))
    u = params["bonus_u"].reshape(H, D)
    kv = kh[..., :, None] * vh[..., None, :]
    y = jnp.einsum("bhi,bhij->bhj", rh, state["wkv"] + u[..., :, None] * kv)
    S = wh[..., :, None] * state["wkv"] + kv
    y = _group_norm(y.reshape(B, d).astype(x.dtype), params["ln_scale"], H)
    out = (y * jax.nn.silu(g)) @ params["w_o"].astype(x.dtype)
    new_state = dict(state, shift_t=x1, wkv=S)
    return out[:, None], new_state


def channel_mix_decode(params, cfg: ModelConfig, x: jnp.ndarray,
                       state: Dict[str, jnp.ndarray]) -> Tuple[jnp.ndarray, Dict]:
    dt = x.dtype
    x1 = x[:, 0]
    xx = state["shift_c"]
    k = _mix(x1, xx, params["mu"][0]) @ params["w_k"].astype(dt)
    r = _mix(x1, xx, params["mu"][1]) @ params["w_r"].astype(dt)
    v = jnp.square(jax.nn.relu(k)) @ params["w_v"].astype(dt)
    out = jax.nn.sigmoid(r) * v
    return out[:, None], dict(state, shift_c=x1)
