"""Mixture-of-Experts FFN: top-k router + capacity-based scatter dispatch.

Two dispatch strategies, both static-shape and GSPMD-partitionable:

- ``scatter`` (default): tokens are scattered into a per-expert capacity
  buffer (E, C, d) via computed slot indices, experts run as a vmapped SwiGLU
  over the expert axis, outputs gather back.  FLOPs ~= useful FLOPs; the
  buffer is the all-to-all payload when experts are expert-parallel.
- ``dense``: every expert processes every token and the router combine is an
  einsum.  FLOPs inflate by E/k but there is no dispatch traffic — profitable
  for fine-grained small experts (granite) at small token counts; kept as a
  first-class option for the §Perf comparison.

Router aux loss is the Switch load-balance term  E * sum_e f_e * P_e.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import module as m

CAPACITY_FACTOR = 1.25


def init_moe(key, cfg: ModelConfig):
    pdt = m.dtype_of(cfg.param_dtype)
    kr, kg, ku, kd = jax.random.split(key, 4)
    E, d, ff = cfg.num_experts, cfg.d_model, (cfg.moe_d_ff or cfg.d_ff)

    def one_expert(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "w_gate": m.dense_init(k1, d, ff, pdt),
            "w_up": m.dense_init(k2, d, ff, pdt),
            "w_down": m.dense_init(k3, ff, d, pdt),
        }

    return {
        "router": m.dense_init(kr, d, E, pdt, scale=0.02),
        "experts": m.stack_layers(one_expert, jax.random.fold_in(kg, 7), E),
    }


def _expert_ffn(wp, x):
    """x: (..., d) with stacked expert weights already selected/vmapped."""
    dt = x.dtype
    gate = x @ wp["w_gate"].astype(dt)
    up = x @ wp["w_up"].astype(dt)
    return (jax.nn.silu(gate) * up) @ wp["w_down"].astype(dt)


def _route(params, cfg: ModelConfig, x2d: jnp.ndarray):
    """Router top-k.  x2d: (T, d) -> (weights (T,k), experts (T,k), aux)."""
    logits = (x2d @ params["router"].astype(x2d.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                   # (T, E)
    k = cfg.experts_per_token
    top_w, top_e = jax.lax.top_k(probs, k)                    # (T, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    # Switch load-balance aux: fraction routed vs mean prob, per expert
    T = x2d.shape[0]
    onehot = jax.nn.one_hot(top_e[:, 0], cfg.num_experts)     # primary route
    f = jnp.mean(onehot, axis=0)
    P = jnp.mean(probs, axis=0)
    aux = cfg.num_experts * jnp.sum(f * P)
    return top_w.astype(x2d.dtype), top_e, aux


def moe_dense(params, cfg: ModelConfig,
              x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """All-experts einsum path.  x: (B, S, d) -> (y, aux).

    The router combine is folded INTO the down-projection contraction
    (§Perf granite iteration 3): contracting e and f in one einsum makes the
    tensor-parallel partial-sum all-reduce carry (T, d) instead of (T, E, d)
    — an E x collective-bytes reduction (40x for granite)."""
    B, S, d = x.shape
    x2d = x.reshape(B * S, d)
    top_w, top_e, aux = _route(params, cfg, x2d)
    dt = x.dtype
    ex = params["experts"]
    gate = jnp.einsum("td,edf->tef", x2d, ex["w_gate"].astype(dt))
    up = jnp.einsum("td,edf->tef", x2d, ex["w_up"].astype(dt))
    combine = jnp.zeros((B * S, cfg.num_experts), dt)
    combine = jax.vmap(lambda c, e, w: c.at[e].add(w))(combine, top_e, top_w)
    hidden = (jax.nn.silu(gate) * up) * combine[..., None]    # (T, E, F)
    y = jnp.einsum("tef,efd->td", hidden, ex["w_down"].astype(dt))
    return y.reshape(B, S, d), aux


def moe_scatter(params, cfg: ModelConfig, x: jnp.ndarray,
                act=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Capacity scatter/gather path.  x: (B, S, d) -> (y, aux)."""
    from repro.sharding.apply import constrain

    B, S, d = x.shape
    T = B * S
    E, k = cfg.num_experts, cfg.experts_per_token
    C = max(8, int(CAPACITY_FACTOR * T * k / E + 0.5))
    x2d = x.reshape(T, d)
    top_w, top_e, aux = _route(params, cfg, x2d)

    flat_e = top_e.reshape(T * k)                             # (T*k,)
    flat_w = top_w.reshape(T * k)
    # position of each routed token within its expert, via cumsum of one-hots
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)       # (T*k, E)
    pos_in_e = (jnp.cumsum(onehot, axis=0) - onehot)          # exclusive
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = pos < C                                            # capacity drop
    slot = jnp.where(keep, flat_e * C + pos, E * C)           # E*C = waste slot

    buf = jnp.zeros((E * C + 1, d), x.dtype)
    src = jnp.repeat(x2d, k, axis=0) if k > 1 else x2d
    buf = buf.at[slot].set(src, mode="drop")
    expert_in = buf[: E * C].reshape(E, C, d)
    # expert-parallel over the model axis when E divides it (llama4);
    # otherwise experts are replicated and sharded inside (granite)
    e_ax = "M" if (act is not None and E % act.get("model_size", 16) == 0) else None
    expert_in = constrain(expert_in, act, e_ax, None, None)
    expert_out = jax.vmap(_expert_ffn)(params["experts"], expert_in)
    expert_out = constrain(expert_out, act, e_ax, None, None)

    flat_out = jnp.concatenate(
        [expert_out.reshape(E * C, d), jnp.zeros((1, d), x.dtype)], axis=0)
    y_tok = flat_out[slot] * (flat_w * keep.astype(flat_w.dtype))[:, None]
    y = y_tok.reshape(T, k, d).sum(axis=1) if k > 1 else y_tok
    return y.reshape(B, S, d), aux


def moe_ffn(params, cfg: ModelConfig, x: jnp.ndarray,
            dispatch: str = "scatter", act=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if dispatch == "dense":
        return moe_dense(params, cfg, x)
    return moe_scatter(params, cfg, x, act=act)
