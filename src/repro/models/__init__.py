from repro.models.registry import Model, build_model, get_model, get_reduced_model

__all__ = ["Model", "build_model", "get_model", "get_reduced_model"]
