"""Input pytrees per (architecture × input shape), in two renderings:

- ``input_specs``: jax.ShapeDtypeStruct stand-ins (weak-type-correct, no
  allocation) — what the multi-pod dry-run lowers against.
- ``materialize``: small real arrays with the same structure — what smoke
  tests and examples feed.

This is also where the modality-frontend STUB carve-out lives: audio gets
precomputed frame embeddings (B, S, d); VLM gets patch embeddings
(B, P, d) + M-RoPE (B, 3, S) positions.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape, ModelConfig
from repro.models import module as m


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_specs(cfg: ModelConfig, B: int, S: int) -> Dict[str, Any]:
    dt = m.dtype_of(cfg.dtype)
    if cfg.family == "audio":
        return {
            "embeds": _sds((B, S, cfg.d_model), dt),
            "labels": _sds((B, S), jnp.int32),
            "mask": _sds((B, S), jnp.bool_),
        }
    spec = {
        "tokens": _sds((B, S), jnp.int32),
        "labels": _sds((B, S), jnp.int32),
    }
    if cfg.family == "vlm":
        spec["patch_embeds"] = _sds((B, cfg.num_patches, cfg.d_model), dt)
        spec["positions"] = _sds((B, 3, S), jnp.int32)
    return spec


def prefill_specs(cfg: ModelConfig, B: int, S: int) -> Dict[str, Any]:
    spec = train_specs(cfg, B, S)
    spec.pop("labels", None)
    spec.pop("mask", None)
    return spec


def decode_specs(cfg: ModelConfig, B: int, S: int) -> Dict[str, Any]:
    assert not cfg.is_encoder_only, f"{cfg.name} is encoder-only: no decode"
    return {
        "token": _sds((B, 1), jnp.int32),
        "position": _sds((B,), jnp.int32),
    }


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return train_specs(cfg, B, S)
    if shape.kind == "prefill":
        return prefill_specs(cfg, B, S)
    return decode_specs(cfg, B, S)


# ---------------------------------------------------------------------------
# real arrays with the same structure (smoke tests / examples)
# ---------------------------------------------------------------------------

def materialize(spec: Dict[str, Any], cfg: ModelConfig,
                seed: int = 0) -> Dict[str, Any]:
    rng = np.random.default_rng(seed)
    out: Dict[str, Any] = {}
    for name, s in spec.items():
        if name in ("tokens", "labels", "token"):
            out[name] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, s.shape), jnp.int32)
        elif name == "position":
            out[name] = jnp.zeros(s.shape, jnp.int32)
        elif name == "positions":
            B, _, S = s.shape
            pos = np.broadcast_to(np.arange(S, dtype=np.int32), (B, S))
            out[name] = jnp.asarray(np.broadcast_to(pos[:, None], (B, 3, S)))
        elif name == "mask":
            out[name] = jnp.asarray(rng.random(s.shape) < 0.3)
        else:  # embeds / patch_embeds
            out[name] = jnp.asarray(
                rng.standard_normal(s.shape) * 0.02, s.dtype)
    return out
