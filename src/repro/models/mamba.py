"""Mamba selective-SSM branch (used by the Hymba hybrid block).

Selective scan (Mamba-1 style):  h_t = exp(dt_t * A) h_{t-1} + dt_t B_t x_t,
y_t = C_t . h_t + D x_t,  with input-dependent (dt, B, C) and a causal
depthwise conv front.  Full-sequence mode uses ``lax.scan`` over time (O(1)
compile in seq len); decode carries ``(conv_state, ssm_state)``.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import module as m


def dt_rank(cfg: ModelConfig) -> int:
    return max(1, cfg.d_model // 16)


def init_mamba(key, cfg: ModelConfig):
    pdt = m.dtype_of(cfg.param_dtype)
    di, N, R = cfg.d_inner, cfg.ssm_state, dt_rank(cfg)
    ks = jax.random.split(key, 6)
    A = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))
    return {
        "w_in": m.dense_init(ks[0], cfg.d_model, 2 * di, pdt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, di)) * 0.1).astype(pdt),
        "w_xproj": m.dense_init(ks[2], di, R + 2 * N, pdt),
        "w_dt": m.dense_init(ks[3], R, di, pdt),
        "log_A": jnp.log(A),                       # keeps A negative: -exp(log_A)
        "D": m.ones((di,), jnp.float32),
        "w_out": m.dense_init(ks[4], di, cfg.d_model, pdt),
    }


def _split_proj(params, cfg: ModelConfig, xc: jnp.ndarray):
    """xc: (..., di) post-conv activations -> (dt (..,di), B (..,N), C (..,N))."""
    N, R = cfg.ssm_state, dt_rank(cfg)
    proj = xc @ params["w_xproj"].astype(xc.dtype)
    dtr, Bm, Cm = proj[..., :R], proj[..., R:R + N], proj[..., R + N:]
    dt = jax.nn.softplus(dtr @ params["w_dt"].astype(xc.dtype)).astype(jnp.float32)
    return dt, Bm.astype(jnp.float32), Cm.astype(jnp.float32)


def _causal_conv(params, x: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv.  x: (B, S, di)."""
    K = params["conv_w"].shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    w = params["conv_w"].astype(x.dtype)
    out = sum(pad[:, i:i + x.shape[1]] * w[i] for i in range(K))
    return jax.nn.silu(out)


def mamba_full(params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Full-sequence selective scan.  x: (B, S, d) -> (B, S, d)."""
    dt_ = x.dtype
    B_, S, _ = x.shape
    di, N = cfg.d_inner, cfg.ssm_state
    xz = x @ params["w_in"].astype(dt_)
    xs, z = xz[..., :di], xz[..., di:]
    xc = _causal_conv(params, xs)
    dt, Bm, Cm = _split_proj(params, cfg, xc)                 # (B,S,di) (B,S,N)
    A = -jnp.exp(params["log_A"])                             # (di, N)
    xf = xc.astype(jnp.float32)

    def step(h, t):
        dt_t, B_t, C_t, x_t = t                    # (B,di) (B,N) (B,N) (B,di)
        decay = jnp.exp(dt_t[..., None] * A)                  # (B, di, N)
        h = decay * h + (dt_t * x_t)[..., None] * B_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    h0 = jnp.zeros((B_, di, N), jnp.float32)
    xs_t = (jnp.moveaxis(dt, 1, 0), jnp.moveaxis(Bm, 1, 0),
            jnp.moveaxis(Cm, 1, 0), jnp.moveaxis(xf, 1, 0))
    _, ys = jax.lax.scan(step, h0, xs_t)
    y = jnp.moveaxis(ys, 0, 1) + xf * params["D"]             # (B,S,di)
    y = (y.astype(dt_) * jax.nn.silu(z))
    return y @ params["w_out"].astype(dt_)


def init_mamba_state(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jnp.ndarray]:
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }


def mamba_decode(params, cfg: ModelConfig, x: jnp.ndarray,
                 state: Dict[str, jnp.ndarray]) -> Tuple[jnp.ndarray, Dict]:
    """One-token step.  x: (B, 1, d)."""
    dt_ = x.dtype
    di = cfg.d_inner
    xz = x[:, 0] @ params["w_in"].astype(dt_)
    xs, z = xz[..., :di], xz[..., di:]
    window = jnp.concatenate([state["conv"], xs[:, None]], axis=1)  # (B,K,di)
    w = params["conv_w"].astype(dt_)
    xc = jax.nn.silu(jnp.einsum("bkd,kd->bd", window, w))
    dt, Bm, Cm = _split_proj(params, cfg, xc)
    A = -jnp.exp(params["log_A"])
    decay = jnp.exp(dt[..., None] * A)
    h = decay * state["ssm"] + (dt * xc.astype(jnp.float32))[..., None] * Bm[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, Cm) + xc.astype(jnp.float32) * params["D"]
    y = (y.astype(dt_) * jax.nn.silu(z)) @ params["w_out"].astype(dt_)
    return y[:, None], {"conv": window[:, 1:], "ssm": h}
