"""GQA attention: full-sequence (train/prefill) and single-token decode.

Supports: grouped KV heads, optional QKV bias (qwen2), causal or bidirectional
masking, sliding-window masking (dense long-context variant), RoPE/M-RoPE
applied at write time (the KV cache stores rotated keys), and a ring-buffer
cache for windowed decode.

``impl='xla'`` is the GSPMD-partitionable einsum path used by the dry-run;
``impl='flash'`` dispatches to the Pallas flash-attention kernel (TPU target,
interpret-validated on CPU — see repro.kernels.flash_attention).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import module as m
from repro.models.rope import apply_rope, rope_angles

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def init_attention(key, cfg: ModelConfig):
    pdt = m.dtype_of(cfg.param_dtype)
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": m.dense_init(kq, cfg.d_model, cfg.q_dim, pdt),
        "wk": m.dense_init(kk, cfg.d_model, cfg.kv_dim, pdt),
        "wv": m.dense_init(kv, cfg.d_model, cfg.kv_dim, pdt),
        "wo": m.dense_init(ko, cfg.q_dim, cfg.d_model, pdt),
    }
    if cfg.qkv_bias:
        p["bq"] = m.zeros((cfg.q_dim,), pdt)
        p["bk"] = m.zeros((cfg.kv_dim,), pdt)
        p["bv"] = m.zeros((cfg.kv_dim,), pdt)
    return p


def _project_qkv(params, cfg: ModelConfig, x: jnp.ndarray):
    dt = x.dtype
    B, S, _ = x.shape
    q = x @ params["wq"].astype(dt)
    k = x @ params["wk"].astype(dt)
    v = x @ params["wv"].astype(dt)
    if "bq" in params:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    return q, k, v


def _sdpa(cfg: ModelConfig, q, k, v, mask) -> jnp.ndarray:
    """Grouped scaled-dot-product attention.

    q: (B, Sq, H, D); k, v: (B, Sk, KV, D); mask: broadcastable to
    (B, KV, G, Sq, Sk) with True = attend.
    """
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, D)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    scores = scores * (D ** -0.5)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Sq, H * D)


def full_mask(cfg: ModelConfig, seq: int) -> jnp.ndarray:
    """(1, 1, 1, S, S) boolean mask for full-sequence attention."""
    qpos = jnp.arange(seq)[:, None]
    kpos = jnp.arange(seq)[None, :]
    mask = jnp.ones((seq, seq), bool)
    if cfg.causal:
        mask &= kpos <= qpos
    if cfg.sliding_window:
        mask &= (qpos - kpos) < cfg.sliding_window
    return mask[None, None, None]


CHUNK_THRESHOLD = 1024     # beyond this, use the q-chunked flash-style path
Q_CHUNK = 256


def _sdpa_chunked(cfg: ModelConfig, q, k, v, act=None) -> jnp.ndarray:
    """Memory-efficient attention: scan over query chunks, KV repeated to
    (B, Sk, H, D), chunk body rematerialized (flash-style linear memory).

    This is the XLA/GSPMD production path for long sequences — the full
    (S, S) score tensor is never materialized (e.g. qwen2-72b prefill_32k
    would otherwise allocate ~0.5 TB of scores per device)."""
    from repro.sharding.apply import constrain, heads_shardable

    B, Sq, H, D = q.shape
    KV = k.shape[2]
    if KV != H:
        k = jnp.repeat(k, H // KV, axis=2)
        v = jnp.repeat(v, H // KV, axis=2)
    # canonical layout: batch over data(+pod); heads over model when they
    # divide it, else replicated (DESIGN.md §4 — hymba/qwen2-vl/granite/llama4)
    h_ax = "M" if heads_shardable(act, H) else None
    q = constrain(q, act, "B", None, h_ax, None)
    k = constrain(k, act, "B", None, h_ax, None)
    v = constrain(v, act, "B", None, h_ax, None)
    nq = Sq // Q_CHUNK
    assert Sq % Q_CHUNK == 0, (Sq, Q_CHUNK)
    scale = D ** -0.5
    kpos = jnp.arange(k.shape[1])[None, :]

    @jax.checkpoint
    def chunk_body(carry, qc_idx):
        qc = jax.lax.dynamic_slice_in_dim(q, qc_idx * Q_CHUNK, Q_CHUNK, axis=1)
        s = jnp.einsum("bqhd,bkhd->bhqk", qc, k).astype(jnp.float32) * scale
        qpos = qc_idx * Q_CHUNK + jnp.arange(Q_CHUNK)[:, None]
        mask = jnp.ones((Q_CHUNK, k.shape[1]), bool)
        if cfg.causal:
            mask &= kpos <= qpos
        if cfg.sliding_window:
            mask &= (qpos - kpos) < cfg.sliding_window
        s = jnp.where(mask[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", p, v)
        return carry, o

    _, outs = jax.lax.scan(chunk_body, (), jnp.arange(nq))
    # outs: (nq, B, Q_CHUNK, H, D) -> (B, Sq, H*D)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H * D)
    return out


def attend_full(params, cfg: ModelConfig, x: jnp.ndarray,
                positions: jnp.ndarray, impl: str = "xla",
                act=None) -> jnp.ndarray:
    """Full-sequence attention for train/prefill.  x: (B, S, d)."""
    from repro.sharding.apply import constrain

    q, k, v = _project_qkv(params, cfg, x)
    angles = rope_angles(positions, cfg.head_dim, cfg.rope_theta,
                         cfg.mrope_sections)
    q = apply_rope(q, angles)
    k = apply_rope(k, angles)
    S = x.shape[1]
    if impl == "flash":
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(
            q, k, v, causal=cfg.causal, window=cfg.sliding_window or 0)
        out = out.reshape(*x.shape[:2], cfg.q_dim)
    elif S > CHUNK_THRESHOLD and S % Q_CHUNK == 0:
        out = _sdpa_chunked(cfg, q, k, v, act)
    else:
        out = _sdpa(cfg, q, k, v, full_mask(cfg, S))
    out = constrain(out, act, "B", None, None)
    return out @ params["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# decode with KV cache
# ---------------------------------------------------------------------------

def cache_len(cfg: ModelConfig, context_len: int) -> int:
    """Physical cache length: window ring buffer if windowed, else context."""
    if cfg.sliding_window and cfg.sliding_window < context_len:
        return cfg.sliding_window
    return context_len


def init_cache(cfg: ModelConfig, batch: int, context_len: int,
               dtype) -> Dict[str, jnp.ndarray]:
    C = cache_len(cfg, context_len)
    shape = (batch, C, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attend_decode(params, cfg: ModelConfig, x: jnp.ndarray,
                  cache: Dict[str, jnp.ndarray], position: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token decode.  x: (B, 1, d); position: (B,) absolute positions of
    the new token; cache stores rotated keys.  Returns (out (B,1,d), cache')."""
    B = x.shape[0]
    q, k, v = _project_qkv(params, cfg, x)                    # S == 1
    pos = position[:, None]                                   # (B, 1)
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(pos[:, None], (B, 3, 1))
    angles = rope_angles(pos, cfg.head_dim, cfg.rope_theta, cfg.mrope_sections)
    q = apply_rope(q, angles)
    k = apply_rope(k, angles)

    C = cache["k"].shape[1]
    slot = (position % C).astype(jnp.int32)                   # ring index (B,)
    onehot = jax.nn.one_hot(slot, C, dtype=cache["k"].dtype)  # (B, C)
    new_k = cache["k"] * (1 - onehot)[:, :, None, None] + onehot[:, :, None, None] * k
    new_v = cache["v"] * (1 - onehot)[:, :, None, None] + onehot[:, :, None, None] * v

    # validity: entries written so far; windowed cache recycles all slots
    idx = jnp.arange(C)[None, :]                              # (1, C)
    n_valid = jnp.minimum(position + 1, C)[:, None]           # (B, 1)
    valid = idx < n_valid                                     # (B, C)
    mask = valid[:, None, None, None, :]                      # (B,1,1,1,C)
    out = _sdpa(cfg, q, new_k, new_v, mask)
    out = out @ params["wo"].astype(x.dtype)
    return out, {"k": new_k, "v": new_v}
