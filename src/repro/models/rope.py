"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

M-RoPE [arXiv:2409.12191]: the head_dim/2 rotary frequencies are split into
(t, h, w) sections; each section reads its position id from the matching row
of a (B, 3, S) position tensor.  For pure text, t == h == w == arange(S).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """(head_dim/2,) inverse frequencies."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float,
                mrope_sections: Tuple[int, ...] = ()) -> jnp.ndarray:
    """Angles (..., S, head_dim/2) from positions.

    positions: (B, S) int32 for standard RoPE, or (B, 3, S) for M-RoPE.
    """
    inv = rope_freqs(head_dim, theta)                        # (half,)
    if not mrope_sections:
        if positions.ndim == 3:                              # tolerate (B,3,S)
            positions = positions[:, 0]
        return positions[..., None].astype(jnp.float32) * inv
    assert positions.ndim == 3 and positions.shape[1] == 3, (
        "M-RoPE needs (B, 3, S) positions")
    half = head_dim // 2
    assert sum(mrope_sections) == half, (mrope_sections, half)
    # angle per (section row, freq): pick t/h/w position per frequency band
    sec_id = jnp.repeat(jnp.arange(3), jnp.asarray(mrope_sections),
                        total_repeat_length=half)            # (half,)
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),                       # (B, 3, S)
        jnp.broadcast_to(
            sec_id[None, :, None],
            (positions.shape[0], half, positions.shape[2])).astype(jnp.int32),
        axis=1)                                              # (B, half, S)
    return jnp.swapaxes(pos, 1, 2) * inv                     # (B, S, half)


def apply_rope(x: jnp.ndarray, angles: jnp.ndarray) -> jnp.ndarray:
    """Rotate x (..., S, H, D) by angles (..., S, D/2) (broadcast over heads)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = jnp.cos(angles)[..., None, :].astype(x.dtype)      # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def text_positions(batch: int, seq: int, mrope: bool = False,
                   offset: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Default positions; offset (B,) shifts (decode).  Returns (B,S) or (B,3,S)."""
    pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None], (batch, seq))
    if offset is not None:
        pos = pos + offset[:, None].astype(jnp.int32)
    if mrope:
        pos = jnp.broadcast_to(pos[:, None], (batch, 3, seq))
    return pos
