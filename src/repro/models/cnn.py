"""The paper's model: 5-layer MNIST CNN (2 conv + 3 fc), Section IV.

Exposed as an ordered stage list so HSFL split learning (DESIGN.md §2) can
cut at any stage boundary: stages [conv1, conv2, fc1, fc2, fc3]; the
activation at the cut is the SL payload (its byte size feeds eq. 12's m_a).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import module as m

STAGES = ("conv1", "conv2", "fc1", "fc2", "fc3")
NUM_STAGES = len(STAGES)


def init_cnn(key, num_classes: int = 10, image_side: int = 28):
    ks = jax.random.split(key, 5)
    side = image_side // 4                        # two 2x2 pools
    flat = side * side * 16
    return {
        "conv1": {"w": (jax.random.normal(ks[0], (3, 3, 1, 8))
                        * (9 ** -0.5)).astype(jnp.float32),
                  "b": m.zeros((8,))},
        "conv2": {"w": (jax.random.normal(ks[1], (3, 3, 8, 16))
                        * (72 ** -0.5)).astype(jnp.float32),
                  "b": m.zeros((16,))},
        "fc1": {"w": m.dense_init(ks[2], flat, 128), "b": m.zeros((128,))},
        "fc2": {"w": m.dense_init(ks[3], 128, 64), "b": m.zeros((64,))},
        "fc3": {"w": m.dense_init(ks[4], 64, num_classes),
                "b": m.zeros((num_classes,))},
    }


def _conv(p, x):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    y = jax.nn.relu(y + p["b"])
    return jax.lax.reduce_window(
        y, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def _fc(p, x, act=True):
    y = x @ p["w"] + p["b"]
    return jax.nn.relu(y) if act else y


def apply_stage(params, stage: str, x: jnp.ndarray) -> jnp.ndarray:
    if stage == "conv1":
        return _conv(params["conv1"], x)
    if stage == "conv2":
        y = _conv(params["conv2"], x)
        return y.reshape(y.shape[0], -1)
    if stage == "fc1":
        return _fc(params["fc1"], x)
    if stage == "fc2":
        return _fc(params["fc2"], x)
    return _fc(params["fc3"], x, act=False)


def forward(params, images: jnp.ndarray, start: int = 0,
            stop: int = NUM_STAGES) -> jnp.ndarray:
    """images: (B, 28, 28, 1) (or the cut activation when start > 0)."""
    x = images
    for stage in STAGES[start:stop]:
        x = apply_stage(params, stage, x)
    return x


# ---------------------------------------------------------------------------
# im2col fast path — value-identical to ``forward``, lowered to matmuls
# ---------------------------------------------------------------------------

def _patches3x3(x: jnp.ndarray) -> jnp.ndarray:
    """(B, H, W, C) -> (B, H, W, 9·C) SAME-padded 3x3 patch view.

    The shifted-slice concat keeps the per-pixel 9-term contraction order
    identical to ``conv_general_dilated``'s, so the forward values match the
    reference conv bit-for-bit on CPU; only the (cheaper) backward differs
    in reassociation.
    """
    b, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    cols = [xp[:, i:i + h, j:j + w, :] for i in range(3) for j in range(3)]
    return jnp.concatenate(cols, axis=-1)


def _pool2(x: jnp.ndarray) -> jnp.ndarray:
    """2x2 max pool via reshape — equal values, no reduce_window lowering."""
    b, h, w, c = x.shape
    return x.reshape(b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


def _conv_im2col(p, x):
    b, h, w, cin = x.shape
    cout = p["w"].shape[-1]
    y = _patches3x3(x).reshape(b * h * w, 9 * cin)
    y = y @ p["w"].reshape(9 * cin, cout)
    y = jax.nn.relu(y.reshape(b, h, w, cout) + p["b"])
    return _pool2(y)


def forward_im2col(params, images: jnp.ndarray,
                   compute_dtype=None) -> jnp.ndarray:
    """Full-model forward, same values as ``forward`` but ~4x faster to
    train on CPU: convolutions become (B·H·W, 9·Cin)x(9·Cin, Cout) matmuls
    and pooling a reshape-max, both of which XLA lowers far better than the
    vmapped ``conv_general_dilated``/``reduce_window`` pair.

    ``compute_dtype`` threads the mixed-precision policy
    (``kernels/fused_cnn.ForwardPolicy``): params and activations are cast
    to it (bf16 in practice) and the logits come back float32, so losses
    accumulate at full precision against f32 master params.  ``None``
    keeps everything in the params' own dtype (the f32 value-equivalence
    contract).  This is the PR-1 training step kept as the autodiff
    baseline; the fused round's default path is the custom-VJP pool-first
    step in ``kernels/fused_cnn`` (bit-identical forward at f32)."""
    if compute_dtype is not None:
        params = jax.tree_util.tree_map(
            lambda l: l.astype(compute_dtype), params)
        images = images.astype(compute_dtype)
    y = _conv_im2col(params["conv1"], images)
    y = _conv_im2col(params["conv2"], y)
    y = y.reshape(y.shape[0], -1)
    y = _fc(params["fc1"], y)
    y = _fc(params["fc2"], y)
    y = _fc(params["fc3"], y, act=False)
    # f32-logits contract: losses always reduce in f32 whatever the
    # compute dtype, so the cast target is deliberately not threaded
    return (y.astype(jnp.float32)  # analysis: ok=dtype-thread
            if compute_dtype is not None else y)


def forward_im2col_k(params, images: jnp.ndarray,
                     compute_dtype=None) -> jnp.ndarray:
    """Stacked-cohort forward: params leaves ``(K, ...)``, images
    ``(K, B, H, W, C)`` — exactly ``vmap(forward_im2col)`` (and pinned to
    it bit-for-bit in the tier-1 suite).

    This is the autodiff oracle the *blocked* kernels
    (``kernels/fused_cnn``'s ``*_k`` twins, which fold the user axis into
    one batched ``dot_general`` / one grid-tiled kernel launch per layer)
    are bit-pinned against at f32 for K ∈ {1, 3, 10}."""
    return jax.vmap(
        lambda p, x: forward_im2col(p, x, compute_dtype=compute_dtype)
    )(params, images)


def split_params(params, cut: int) -> Tuple[Dict, Dict]:
    """UE-side stages [0, cut), BS-side stages [cut, 5)."""
    ue = {s: params[s] for s in STAGES[:cut]}
    bs = {s: params[s] for s in STAGES[cut:]}
    return ue, bs


def merge_params(ue: Dict, bs: Dict) -> Dict:
    return {**ue, **bs}
