"""The paper's model: 5-layer MNIST CNN (2 conv + 3 fc), Section IV.

Exposed as an ordered stage list so HSFL split learning (DESIGN.md §2) can
cut at any stage boundary: stages [conv1, conv2, fc1, fc2, fc3]; the
activation at the cut is the SL payload (its byte size feeds eq. 12's m_a).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import module as m

STAGES = ("conv1", "conv2", "fc1", "fc2", "fc3")
NUM_STAGES = len(STAGES)


def init_cnn(key, num_classes: int = 10, image_side: int = 28):
    ks = jax.random.split(key, 5)
    side = image_side // 4                        # two 2x2 pools
    flat = side * side * 16
    return {
        "conv1": {"w": (jax.random.normal(ks[0], (3, 3, 1, 8)) * (9 ** -0.5)).astype(jnp.float32),
                  "b": m.zeros((8,))},
        "conv2": {"w": (jax.random.normal(ks[1], (3, 3, 8, 16)) * (72 ** -0.5)).astype(jnp.float32),
                  "b": m.zeros((16,))},
        "fc1": {"w": m.dense_init(ks[2], flat, 128), "b": m.zeros((128,))},
        "fc2": {"w": m.dense_init(ks[3], 128, 64), "b": m.zeros((64,))},
        "fc3": {"w": m.dense_init(ks[4], 64, num_classes), "b": m.zeros((num_classes,))},
    }


def _conv(p, x):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    y = jax.nn.relu(y + p["b"])
    return jax.lax.reduce_window(
        y, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def _fc(p, x, act=True):
    y = x @ p["w"] + p["b"]
    return jax.nn.relu(y) if act else y


def apply_stage(params, stage: str, x: jnp.ndarray) -> jnp.ndarray:
    if stage == "conv1":
        return _conv(params["conv1"], x)
    if stage == "conv2":
        y = _conv(params["conv2"], x)
        return y.reshape(y.shape[0], -1)
    if stage == "fc1":
        return _fc(params["fc1"], x)
    if stage == "fc2":
        return _fc(params["fc2"], x)
    return _fc(params["fc3"], x, act=False)


def forward(params, images: jnp.ndarray, start: int = 0, stop: int = NUM_STAGES) -> jnp.ndarray:
    """images: (B, 28, 28, 1) (or the cut activation when start > 0)."""
    x = images
    for stage in STAGES[start:stop]:
        x = apply_stage(params, stage, x)
    return x


def split_params(params, cut: int) -> Tuple[Dict, Dict]:
    """UE-side stages [0, cut), BS-side stages [cut, 5)."""
    ue = {s: params[s] for s in STAGES[:cut]}
    bs = {s: params[s] for s in STAGES[cut:]}
    return ue, bs


def merge_params(ue: Dict, bs: Dict) -> Dict:
    return {**ue, **bs}
