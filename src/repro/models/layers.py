"""Shared layers: RMSNorm, SwiGLU MLP, padded embeddings / LM head."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import module as m


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def init_rmsnorm(dim: int, dtype=jnp.float32):
    return {"scale": m.ones((dim,), dtype)}


def rmsnorm(params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None):
    d_ff = d_ff or cfg.d_ff
    pdt = m.dtype_of(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": m.dense_init(k1, cfg.d_model, d_ff, pdt),
        "w_up": m.dense_init(k2, cfg.d_model, d_ff, pdt),
        "w_down": m.dense_init(k3, d_ff, cfg.d_model, pdt),
    }


def mlp(params, x: jnp.ndarray) -> jnp.ndarray:
    dt = x.dtype
    gate = x @ params["w_gate"].astype(dt)
    up = x @ params["w_up"].astype(dt)
    return (jax.nn.silu(gate) * up) @ params["w_down"].astype(dt)


# ---------------------------------------------------------------------------
# Embeddings (vocab padded to shardable width; see DESIGN.md §4)
# ---------------------------------------------------------------------------

def init_embedding(key, cfg: ModelConfig):
    pdt = m.dtype_of(cfg.param_dtype)
    return {"table": m.embed_init(key, cfg.vocab_padded, cfg.d_model, pdt)}


def embed(params, tokens: jnp.ndarray, dtype) -> jnp.ndarray:
    return params["table"].astype(dtype)[tokens]


def init_lm_head(key, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return {}
    pdt = m.dtype_of(cfg.param_dtype)
    return {"w": m.dense_init(key, cfg.d_model, cfg.vocab_padded, pdt)}


def lm_logits(head_params, embed_params, cfg: ModelConfig,
              x: jnp.ndarray) -> jnp.ndarray:
    """Logits over the padded vocab; padded slots masked to a large negative."""
    if cfg.tie_embeddings:
        logits = x @ embed_params["table"].astype(x.dtype).T
    else:
        logits = x @ head_params["w"].astype(x.dtype)
    if cfg.vocab_padded != cfg.vocab_size:
        mask = jnp.arange(cfg.vocab_padded) < cfg.vocab_size
        logits = jnp.where(mask, logits, jnp.asarray(-1e9, logits.dtype))
    return logits
