"""RWKV6-7B "Finch" [arXiv:2404.05892] — attention-free, data-dependent decay.
32L d_model=4096 d_ff=14336 vocab=65536.  head_dim=64 -> 64 WKV heads.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    citation="arXiv:2404.05892",
    num_layers=32,
    d_model=4096,
    num_heads=64,          # wkv heads = d_model / head_dim
    num_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    attn_free=True,
)
