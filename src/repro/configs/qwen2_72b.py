"""Qwen2-72B [arXiv:2407.10671] — dense, GQA, QKV bias.
80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    citation="arXiv:2407.10671",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
)
