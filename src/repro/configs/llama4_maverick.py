"""Llama-4 Maverick 400B-A17B [hf:meta-llama/Llama-4-Scout-17B-16E family].
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128 experts top-1.

Simplification recorded in DESIGN.md: Llama-4 interleaves dense and MoE FFNs;
we keep every layer MoE (top-1, 128 experts) so the layer scan stays uniform —
the assigned config specifies "MoE 128e top-1" for the stack.  Early fusion is
handled as an interleaved token stream (no vision tower; text path exercised).
Experts are expert-parallel over the 16-way model axis (8 experts/shard).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    citation="hf:meta-llama/Llama-4-Scout-17B-16E",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    moe_d_ff=8192,
    vocab_size=202048,
    num_experts=128,
    experts_per_token=1,
)
