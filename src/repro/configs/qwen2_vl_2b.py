"""Qwen2-VL-2B [arXiv:2409.12191] — VLM backbone with M-RoPE.
28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.

The ViT vision encoder + projector is the allowed STUB: ``input_specs()``
provides precomputed patch embeddings (B, num_patches, d_model) that the
backbone scatters into the token stream at image-placeholder positions.
M-RoPE splits each head_dim/2 rotary block into (t, h, w) sections [16,24,24].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    citation="arXiv:2409.12191",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    mrope_sections=(16, 24, 24),
    num_patches=256,
    frontend_stub=True,
)
