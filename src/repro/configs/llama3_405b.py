"""Llama-3-405B [arXiv:2407.21783] — dense frontier scale.
126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    citation="arXiv:2407.21783",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab_size=128256,
)
