"""The paper's own model: 5-layer CNN for MNIST (2 conv + 3 fc), Section IV.

Not part of the assigned-architecture pool — this is the faithful-repro model
used by the HSFL/OPT simulation (benchmarks fig3a-fig3d).  The ModelConfig
fields are reused loosely; models/cnn.py reads only name/vocab_size (classes).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paper-cnn",
    family="cnn",
    citation="Li, Liu, Mahmoodi 2023 (this paper), Sec. IV",
    num_layers=5,
    d_model=28,            # image side
    vocab_size=10,         # classes
    dtype="float32",
    param_dtype="float32",
)
