"""HuBERT-XLarge [arXiv:2106.07447] — encoder-only audio transformer.
48L d_model=1280 16H (kv=16 = full MHA) d_ff=5120 vocab=504 (cluster units).

The mel-spectrogram + conv feature extractor frontend is the allowed STUB:
``input_specs()`` provides precomputed frame embeddings (B, S, d_model).
Encoder-only => bidirectional attention, no decode shapes (DESIGN.md §5).
Training objective: masked-unit prediction over 504 classes (padded to 512).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    citation="arXiv:2106.07447",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    frontend_stub=True,
)
