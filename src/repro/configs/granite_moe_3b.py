"""Granite-MoE-3B-A800M [hf:ibm-granite/granite-3.0-1b-a400m-base family].
32L d_model=1536 24H (GQA kv=8) d_ff=512/expert vocab=49155, MoE 40e top-8.

40 experts % 16-way model axis != 0, so experts are replicated and sharded
tensor-parallel *inside* each expert (moe_d_ff 512 / 16 = 32 lanes/shard) —
see DESIGN.md §4.  Vocab padded 49155->49408.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    moe_d_ff=512,
    vocab_size=49155,
    num_experts=40,
    experts_per_token=8,
)
