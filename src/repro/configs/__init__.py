from repro.configs.base import (
    ARCH_IDS,
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    get_config,
    pad_vocab,
)

__all__ = [
    "ARCH_IDS",
    "INPUT_SHAPES",
    "InputShape",
    "ModelConfig",
    "get_config",
    "pad_vocab",
]
