"""Config system: one frozen dataclass describes every supported architecture.

Every assigned architecture gets a module in this package exporting CONFIG;
``repro.configs.get_config(arch_id)`` resolves it.  ``reduced()`` produces the
CPU-smoke variant (2 layers, d_model<=512, <=4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass
from typing import Tuple

_VOCAB_PAD_MULTIPLE = 256


def pad_vocab(v: int, multiple: int = _VOCAB_PAD_MULTIPLE) -> int:
    """Megatron-style vocab padding so the table shards over the model axis."""
    return ((v + multiple - 1) // multiple) * multiple


@dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    citation: str = ""
    # trunk
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 512
    vocab_size: int = 1024
    # attention
    attn_free: bool = False        # rwkv6: no attention at all
    causal: bool = True            # False for encoder-only (hubert)
    qkv_bias: bool = False         # qwen2
    sliding_window: int = 0        # >0 enables windowed attention (long ctx)
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE [t, h, w] halves
    # moe
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0              # per-expert FFN width (granite: 512)
    router_aux_coef: float = 0.01  # load-balance loss coefficient
    # ssm / hybrid
    ssm_state: int = 0             # mamba state size N (hymba: 16)
    ssm_expand: int = 2            # mamba inner expansion
    ssm_conv: int = 4              # mamba depthwise conv width
    # modality frontends (stub carve-out)
    num_patches: int = 0           # vlm: patch-embedding stand-ins per sample
    frontend_stub: bool = False    # audio/vlm: input_specs provides embeddings
    # numerics / misc
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"        # activation/compute dtype
    param_dtype: str = "float32"   # parameter storage dtype

    # ---- derived ----------------------------------------------------------
    @property
    def vocab_padded(self) -> int:
        return pad_vocab(self.vocab_size)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def is_subquadratic(self) -> bool:
        """True if decode with 500k context needs no quadratic attention."""
        return (self.attn_free or self.family in ("ssm", "hybrid")
                or self.sliding_window > 0)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def with_sliding_window(self, window: int = 8192) -> "ModelConfig":
        """First-class long-context variant for dense archs (DESIGN.md §5)."""
        return self.replace(sliding_window=window)

    # ---- parameter counting (used for roofline MODEL_FLOPS = 6·N·D) ------
    def param_count(self, active_only: bool = False) -> int:
        d, L = self.d_model, self.num_layers
        n = self.vocab_padded * d                      # embeddings
        if not self.tie_embeddings:
            n += self.vocab_padded * d                 # lm head
        per_layer = 0
        if not self.attn_free:
            per_layer += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.qkv_bias:
                per_layer += self.q_dim + 2 * self.kv_dim
        if self.family == "ssm":                       # rwkv6 mixer
            H = d // self.head_dim
            per_layer += 4 * d * d + d * d             # r,k,v,g,o
            per_layer += H * self.head_dim             # decay params (approx)
        if self.family == "hybrid" and self.ssm_state:
            di = self.d_inner
            per_layer += d * 2 * di + di * d           # in/out proj
            per_layer += di * (2 * self.ssm_state + 1) # B,C,dt projections
        if self.num_experts:
            e = self.experts_per_token if active_only else self.num_experts
            ff = self.moe_d_ff or self.d_ff
            per_layer += e * (3 * d * ff)
            per_layer += d * self.num_experts          # router
        else:
            per_layer += 3 * d * self.d_ff             # swiglu
        per_layer += 2 * d                             # norms
        n += L * per_layer + d                         # final norm
        return n

    def reduced(self) -> "ModelConfig":
        """CPU smoke variant: same family/code path, tiny dims."""
        d = min(self.d_model, 256)
        hd = 32
        sections = self.mrope_sections
        if sections:
            # rescale (t,h,w) sections to the reduced head_dim/2
            half = hd // 2
            t = max(1, half - 2 * (half * sections[1] // sum(sections)))
            hw = (half - t) // 2
            sections = (half - 2 * hw, hw, hw)
        heads = max(2, min(4, self.num_heads))
        kv = max(1, min(heads, self.num_kv_heads
                        if self.num_kv_heads < self.num_heads else heads))
        return self.replace(
            num_layers=2,
            d_model=d,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            experts_per_token=min(self.experts_per_token, 2) if self.num_experts else 0,
            moe_d_ff=min(self.moe_d_ff, 128) if self.moe_d_ff else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            mrope_sections=sections,
            num_patches=min(self.num_patches, 16) if self.num_patches else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            dtype="float32",
            param_dtype="float32",
        )


ARCH_IDS = (
    "hymba-1.5b",
    "deepseek-67b",
    "rwkv6-7b",
    "qwen2-72b",
    "qwen2-vl-2b",
    "llama4-maverick-400b-a17b",
    "llama3.2-1b",
    "llama3-405b",
    "granite-moe-3b-a800m",
    "hubert-xlarge",
)

_MODULE_FOR = {
    "hymba-1.5b": "hymba_1_5b",
    "deepseek-67b": "deepseek_67b",
    "rwkv6-7b": "rwkv6_7b",
    "qwen2-72b": "qwen2_72b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "llama3.2-1b": "llama3_2_1b",
    "llama3-405b": "llama3_405b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "hubert-xlarge": "hubert_xlarge",
    "paper-cnn": "paper_cnn",
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULE_FOR:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULE_FOR)}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[arch_id]}")
    return mod.CONFIG


def tuned_opts(cfg: ModelConfig, shape_kind: str) -> dict:
    """Per-arch production defaults distilled from the §Perf hillclimbs
    (EXPERIMENTS.md): MoE dispatch strategy is per-arch, and training runs
    dots-remat with bf16 AdamW moments (fits llama3-405b in v5e HBM with a
    −12% memory / −26% compute term vs full remat)."""
    opts: dict = {}
    if cfg.num_experts:
        # fine-grained small experts (granite: 512-wide, top-8) win with the
        # dense all-expert einsum + fused combine (124x collective cut);
        # large top-1 expert pools (llama4: 128e) need capacity scatter
        # (dense measured 100x worse there).
        ff = cfg.moe_d_ff or cfg.d_ff
        opts["moe_dispatch"] = "dense" if (ff <= 1024 and
                                           cfg.experts_per_token >= 4) else "scatter"
    if shape_kind == "train":
        opts["remat"] = "dots"
        opts["adam_bf16_moments"] = True
    return opts


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
