"""Hymba-1.5B [arXiv:2411.13676] — hybrid-head: parallel attention + Mamba
heads inside every block.  32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16.  Vocab padded 32001->32256 for model-axis sharding.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    citation="arXiv:2411.13676",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_expand=2,
)
