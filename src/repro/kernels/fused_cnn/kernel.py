"""Pallas TPU kernels for the fused CNN training step.

One kernel per block of the 5-layer CNN, each fusing everything between
the HBM boundaries of that block so intermediates (im2col patches, pre-
pool conv outputs, selection masks) live and die in VMEM:

  ``conv_pool_fwd``  — 3x3 patch gather + matmul + pool + bias + ReLU
                       (pool-first, bit-equal to the im2col order — see
                       ``ref.py``), emitting the argmax/ReLU masks the
                       backward consumes instead of recomputing them.
  ``conv_pool_bwd``  — mask algebra + the two transposed matmuls
                       (dW = patᵀ·dz, dpatches = dz·Wᵀ) + the fold-back
                       scatter-add, all in one VMEM-resident program.
  ``fc_chain_fwd``   — fc1+ReLU → fc2+ReLU → fc3 as a single kernel.
  ``fc_chain_bwd``   — the three transposed matmuls + ReLU masking.

Each kernel is a single program (no grid): the paper-scale per-user batch
(10 x 28 x 28 images, ≤72-lane contractions) fits a 28-image block in well
under 2 MB of VMEM, and the user axis arrives via ``jax.vmap`` inside the
fused round — Pallas's batching rule turns that into the kernel grid, so
the same kernels serve ``build_fused_round``, ``build_device_round`` and
the sweep engine's nested sim/config vmaps unchanged.  Full-test-set eval
(B=1000) would exceed a sane VMEM block, so the forward *policy* routes
eval through the value-identical XLA path (``ops.make_eval_forward``).

Off-TPU the kernels run with ``interpret=True`` (same convention as
``kernels/delta_codec``): value-pinned against ``ref.py`` and
``cnn.forward_im2col`` in the tier-1 suite, compiled only on TPU.
Matmuls always accumulate f32 (``preferred_element_type``); the compute
dtype follows the inputs (f32, or bf16 under the mixed-precision policy).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dot(a, b):
    return jax.lax.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def _dot32(a, b):
    return jax.lax.dot(a, b, preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# conv block: patches + matmul + pool + bias + relu
# ---------------------------------------------------------------------------

def _conv_pool_fwd_kernel(xp_ref, w_ref, b_ref, a_ref, pat_ref, eq_ref,
                          m_ref, *, bs, h, wd, c, o):
    xp = xp_ref[...]                               # (B, H+2, W+2, C)
    cols = [xp[:, i:i + h, j:j + wd, :] for i in range(3) for j in range(3)]
    pat = jnp.concatenate(cols, axis=-1).reshape(bs * h * wd, 9 * c)
    pat_ref[...] = pat
    z = _dot(pat, w_ref[...]).reshape(bs, h, wd, o)
    zw = z.reshape(bs, h // 2, 2, wd // 2, 2, o)
    pz = zw.max(axis=(2, 4))
    eqw = (zw == pz[:, :, None, :, None, :])
    cnt = eqw.sum(axis=(2, 4), keepdims=True)
    eq_ref[...] = jnp.where(eqw, 1.0 / cnt, 0.0).astype(z.dtype).reshape(
        bs, h, wd, o)
    a = jnp.maximum(pz + b_ref[...].reshape(o), 0.0)
    m_ref[...] = (a > 0).astype(z.dtype)
    a_ref[...] = a


def conv_pool_fwd(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                  interpret: bool = False) -> Tuple[jnp.ndarray, Tuple]:
    """Pallas twin of ``ref.conv_pool_fwd`` (same signature + residuals)."""
    bs, h, wd, c = x.shape
    o = w.shape[-1]
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    dt = x.dtype
    a, pat, eq, relu_m = pl.pallas_call(
        functools.partial(_conv_pool_fwd_kernel, bs=bs, h=h, wd=wd, c=c, o=o),
        out_shape=[jax.ShapeDtypeStruct((bs, h // 2, wd // 2, o), dt),
                   jax.ShapeDtypeStruct((bs * h * wd, 9 * c), dt),
                   jax.ShapeDtypeStruct((bs, h, wd, o), dt),
                   jax.ShapeDtypeStruct((bs, h // 2, wd // 2, o), dt)],
        interpret=interpret,
    )(xp, w.reshape(9 * c, o), b.reshape(1, o))
    return a, (pat, eq, relu_m)


def _conv_pool_bwd_kernel(pat_ref, eq_ref, m_ref, w_ref, da_ref,
                          dw_ref, db_ref, *maybe_dx_ref, bs, h, wd, c, o):
    dp = da_ref[...] * m_ref[...]                  # (B, H/2, W/2, O)
    db_ref[...] = dp.astype(jnp.float32).sum(axis=(0, 1, 2)).reshape(1, o)
    dz = (eq_ref[...].reshape(bs, h // 2, 2, wd // 2, 2, o)
          * dp[:, :, None, :, None, :]).reshape(bs * h * wd, o)
    pat = pat_ref[...]
    dw_ref[...] = _dot32(pat.T, dz)
    if maybe_dx_ref:
        dx_ref, = maybe_dx_ref
        dpat = _dot(dz, w_ref[...].T).reshape(bs, h, wd, 9 * c)
        dx_ref[...] = jnp.zeros(dx_ref.shape, dx_ref.dtype)
        for idx in range(9):
            i, j = divmod(idx, 3)
            dx_ref[:, i:i + h, j:j + wd, :] += dpat[..., idx * c:(idx + 1) * c]


def conv_pool_bwd(res: Tuple, w: jnp.ndarray, da: jnp.ndarray,
                  need_dx: bool, interpret: bool = False) -> Tuple:
    """Pallas twin of ``ref.conv_pool_bwd``: (dw, db, dx-or-None).

    ``dx`` is accumulated on the padded (H+2, W+2) canvas in VMEM (the
    fold-back scatter-add) and sliced to (H, W) on the way out."""
    pat, eq, relu_m = res
    bs, h, wd, o = eq.shape
    c = pat.shape[-1] // 9
    dt = pat.dtype
    out_shape = [jax.ShapeDtypeStruct((9 * c, o), jnp.float32),
                 jax.ShapeDtypeStruct((1, o), jnp.float32)]
    if need_dx:
        out_shape.append(jax.ShapeDtypeStruct((bs, h + 2, wd + 2, c), dt))
    out = pl.pallas_call(
        functools.partial(_conv_pool_bwd_kernel, bs=bs, h=h, wd=wd, c=c, o=o),
        out_shape=out_shape,
        interpret=interpret,
    )(pat, eq, relu_m, w.reshape(9 * c, o), da)
    dw, db = out[0], out[1]
    dx = out[2][:, 1:1 + h, 1:1 + wd, :] if need_dx else None
    return dw.reshape(3, 3, c, o), db.reshape(o), dx


# ---------------------------------------------------------------------------
# fc chain: fc1 + relu -> fc2 + relu -> fc3
# ---------------------------------------------------------------------------

def _fc_chain_fwd_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref,
                         b3_ref, out_ref, h1_ref, h2_ref):
    h1 = jnp.maximum(_dot(x_ref[...], w1_ref[...]) + b1_ref[...], 0.0)
    h1_ref[...] = h1
    h2 = jnp.maximum(_dot(h1, w2_ref[...]) + b2_ref[...], 0.0)
    h2_ref[...] = h2
    out_ref[...] = _dot(h2, w3_ref[...]) + b3_ref[...]


def fc_chain_fwd(flat: jnp.ndarray, params: dict,
                 interpret: bool = False) -> Tuple[jnp.ndarray, Tuple]:
    bs = flat.shape[0]
    p1, p2, p3 = params["fc1"], params["fc2"], params["fc3"]
    d1, d2, d3 = p1["w"].shape[1], p2["w"].shape[1], p3["w"].shape[1]
    dt = flat.dtype
    logits, h1, h2 = pl.pallas_call(
        _fc_chain_fwd_kernel,
        out_shape=[jax.ShapeDtypeStruct((bs, d3), dt),
                   jax.ShapeDtypeStruct((bs, d1), dt),
                   jax.ShapeDtypeStruct((bs, d2), dt)],
        interpret=interpret,
    )(flat, p1["w"], p1["b"].reshape(1, d1), p2["w"], p2["b"].reshape(1, d2),
      p3["w"], p3["b"].reshape(1, d3))
    return logits, (h1, h2)


def _fc_chain_bwd_kernel(x_ref, h1_ref, h2_ref, w1_ref, w2_ref, w3_ref,
                         g_ref, dw1_ref, db1_ref, dw2_ref, db2_ref,
                         dw3_ref, db3_ref, dx_ref):
    g = g_ref[...]
    h1, h2 = h1_ref[...], h2_ref[...]
    dw3_ref[...] = _dot32(h2.T, g)
    db3_ref[...] = g.astype(jnp.float32).sum(axis=0, keepdims=True)
    dh2 = _dot(g, w3_ref[...].T) * (h2 > 0)
    dw2_ref[...] = _dot32(h1.T, dh2)
    db2_ref[...] = dh2.astype(jnp.float32).sum(axis=0, keepdims=True)
    dh1 = _dot(dh2, w2_ref[...].T) * (h1 > 0)
    dw1_ref[...] = _dot32(x_ref[...].T, dh1)
    db1_ref[...] = dh1.astype(jnp.float32).sum(axis=0, keepdims=True)
    dx_ref[...] = _dot(dh1, w1_ref[...].T)


def fc_chain_bwd(flat: jnp.ndarray, res: Tuple, params: dict,
                 dlogits: jnp.ndarray,
                 interpret: bool = False) -> Tuple[dict, jnp.ndarray]:
    h1, h2 = res
    bs, f = flat.shape
    p1, p2, p3 = params["fc1"], params["fc2"], params["fc3"]
    d1, d2, d3 = p1["w"].shape[1], p2["w"].shape[1], p3["w"].shape[1]
    dt = flat.dtype
    f32 = jnp.float32
    dw1, db1, dw2, db2, dw3, db3, dflat = pl.pallas_call(
        _fc_chain_bwd_kernel,
        out_shape=[jax.ShapeDtypeStruct((f, d1), f32),
                   jax.ShapeDtypeStruct((1, d1), f32),
                   jax.ShapeDtypeStruct((d1, d2), f32),
                   jax.ShapeDtypeStruct((1, d2), f32),
                   jax.ShapeDtypeStruct((d2, d3), f32),
                   jax.ShapeDtypeStruct((1, d3), f32),
                   jax.ShapeDtypeStruct((bs, f), dt)],
        interpret=interpret,
    )(flat, h1, h2, p1["w"], p2["w"], p3["w"], dlogits)
    grads = {"fc1": {"w": dw1, "b": db1.reshape(d1)},
             "fc2": {"w": dw2, "b": db2.reshape(d2)},
             "fc3": {"w": dw3, "b": db3.reshape(d3)}}
    return grads, dflat
