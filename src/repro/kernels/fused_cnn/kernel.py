"""Pallas TPU kernels for the fused CNN training step.

One kernel per block of the 5-layer CNN, each fusing everything between
the HBM boundaries of that block so intermediates (im2col patches, pre-
pool conv outputs, selection masks) live and die in VMEM:

  ``conv_pool_fwd``  — 3x3 patch gather + matmul + pool + bias + ReLU
                       (pool-first, bit-equal to the im2col order — see
                       ``ref.py``), emitting the argmax/ReLU masks the
                       backward consumes instead of recomputing them.
  ``conv_pool_bwd``  — mask algebra + the two transposed matmuls
                       (dW = patᵀ·dz, dpatches = dz·Wᵀ) + the fold-back
                       scatter-add, all in one VMEM-resident program.
  ``fc_chain_fwd``   — fc1+ReLU → fc2+ReLU → fc3 as a single kernel.
  ``fc_chain_bwd``   — the three transposed matmuls + ReLU masking.

Two generations of each kernel live here.  The PR-4 originals are single
programs (no grid) batched over the K selected users by ``jax.vmap``'s
batching rule — kept as the ``batch_users=False`` baseline the microbench
compares against.  The ``*_k`` blocked twins (bottom of the file) are the
production path: they take the stacked ``(K, ...)`` weights directly and
tile their grid over *user tiles* of ``ForwardPolicy.block_k`` users, so
one kernel launch covers the whole cohort's layer instead of K tiny-GEMM
launches (and, in interpret mode, one Python-evaluated program instead of
K per step per layer — the source of the 23x Pallas gap this PR closes).
Full-test-set eval (B=1000) would exceed a sane VMEM block, so the forward
*policy* routes eval through the value-identical XLA path
(``ops.make_eval_forward``).

Off-TPU the kernels run with ``interpret=True`` (same convention as
``kernels/delta_codec``): value-pinned against ``ref.py`` and
``cnn.forward_im2col`` in the tier-1 suite, compiled only on TPU.
Matmuls always accumulate f32 (``preferred_element_type``); the compute
dtype follows the inputs (f32, or bf16 under the mixed-precision policy).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dot(a, b):
    return jax.lax.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def _dot32(a, b):
    return jax.lax.dot(a, b, preferred_element_type=jnp.float32)


_BDN = (((2,), (1,)), ((0,), (0,)))       # (bk,M,P) x (bk,P,N) -> (bk,M,N)


def _bdot(a, b):
    """In-kernel batched matmul over the user tile (see ``ref._bdot``:
    native bf16 GEMM, f32 accumulation contract at f32)."""
    if a.dtype == jnp.bfloat16:
        return jax.lax.dot_general(a, b, _BDN)
    return jax.lax.dot_general(
        a, b, _BDN, preferred_element_type=jnp.float32).astype(a.dtype)


def _bdot32(a, b):
    if a.dtype == jnp.bfloat16:
        return jax.lax.dot_general(a, b, _BDN).astype(jnp.float32)
    return jax.lax.dot_general(a, b, _BDN,
                               preferred_element_type=jnp.float32)


def _bT(t):
    return jnp.swapaxes(t, 1, 2)


def resolve_block_k(k: int, block_k: int) -> int:
    """User-tile size for the blocked kernels: ``block_k <= 0`` (or
    ``>= K``) means the whole cohort in one grid step.  Callers pad the
    user axis to a multiple first (``ops._pad_users``)."""
    bk = k if block_k <= 0 or block_k >= k else int(block_k)
    if k % bk:
        raise ValueError(f"user axis K={k} not a multiple of block_k={bk}; "
                         "pad the cohort before calling the blocked kernels")
    return bk


# ---------------------------------------------------------------------------
# conv block: patches + matmul + pool + bias + relu
# ---------------------------------------------------------------------------

def _conv_pool_fwd_kernel(xp_ref, w_ref, b_ref, a_ref, pat_ref, eq_ref,
                          m_ref, *, bs, h, wd, c, o):
    xp = xp_ref[...]                               # (B, H+2, W+2, C)
    cols = [xp[:, i:i + h, j:j + wd, :] for i in range(3) for j in range(3)]
    pat = jnp.concatenate(cols, axis=-1).reshape(bs * h * wd, 9 * c)
    pat_ref[...] = pat
    z = _dot(pat, w_ref[...]).reshape(bs, h, wd, o)
    zw = z.reshape(bs, h // 2, 2, wd // 2, 2, o)
    pz = zw.max(axis=(2, 4))
    eqw = (zw == pz[:, :, None, :, None, :])
    cnt = eqw.sum(axis=(2, 4), keepdims=True)
    eq_ref[...] = jnp.where(eqw, 1.0 / cnt, 0.0).astype(z.dtype).reshape(
        bs, h, wd, o)
    a = jnp.maximum(pz + b_ref[...].reshape(o), 0.0)
    m_ref[...] = (a > 0).astype(z.dtype)
    a_ref[...] = a


def conv_pool_fwd(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                  interpret: bool = False) -> Tuple[jnp.ndarray, Tuple]:
    """Pallas twin of ``ref.conv_pool_fwd`` (same signature + residuals)."""
    bs, h, wd, c = x.shape
    o = w.shape[-1]
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    dt = x.dtype
    a, pat, eq, relu_m = pl.pallas_call(
        functools.partial(_conv_pool_fwd_kernel, bs=bs, h=h, wd=wd, c=c, o=o),
        out_shape=[jax.ShapeDtypeStruct((bs, h // 2, wd // 2, o), dt),
                   jax.ShapeDtypeStruct((bs * h * wd, 9 * c), dt),
                   jax.ShapeDtypeStruct((bs, h, wd, o), dt),
                   jax.ShapeDtypeStruct((bs, h // 2, wd // 2, o), dt)],
        interpret=interpret,
    )(xp, w.reshape(9 * c, o), b.reshape(1, o))
    return a, (pat, eq, relu_m)


def _conv_pool_bwd_kernel(pat_ref, eq_ref, m_ref, w_ref, da_ref,
                          dw_ref, db_ref, *maybe_dx_ref, bs, h, wd, c, o):
    dp = da_ref[...] * m_ref[...]                  # (B, H/2, W/2, O)
    db_ref[...] = dp.astype(jnp.float32).sum(axis=(0, 1, 2)).reshape(1, o)
    dz = (eq_ref[...].reshape(bs, h // 2, 2, wd // 2, 2, o)
          * dp[:, :, None, :, None, :]).reshape(bs * h * wd, o)
    pat = pat_ref[...]
    dw_ref[...] = _dot32(pat.T, dz)
    if maybe_dx_ref:
        dx_ref, = maybe_dx_ref
        dpat = _dot(dz, w_ref[...].T).reshape(bs, h, wd, 9 * c)
        dx_ref[...] = jnp.zeros(dx_ref.shape, dx_ref.dtype)
        for idx in range(9):
            i, j = divmod(idx, 3)
            dx_ref[:, i:i + h, j:j + wd, :] += dpat[..., idx * c:(idx + 1) * c]


def conv_pool_bwd(res: Tuple, w: jnp.ndarray, da: jnp.ndarray,
                  need_dx: bool, interpret: bool = False) -> Tuple:
    """Pallas twin of ``ref.conv_pool_bwd``: (dw, db, dx-or-None).

    ``dx`` is accumulated on the padded (H+2, W+2) canvas in VMEM (the
    fold-back scatter-add) and sliced to (H, W) on the way out."""
    pat, eq, relu_m = res
    bs, h, wd, o = eq.shape
    c = pat.shape[-1] // 9
    dt = pat.dtype
    out_shape = [jax.ShapeDtypeStruct((9 * c, o), jnp.float32),
                 jax.ShapeDtypeStruct((1, o), jnp.float32)]
    if need_dx:
        out_shape.append(jax.ShapeDtypeStruct((bs, h + 2, wd + 2, c), dt))
    out = pl.pallas_call(
        functools.partial(_conv_pool_bwd_kernel, bs=bs, h=h, wd=wd, c=c, o=o),
        out_shape=out_shape,
        interpret=interpret,
    )(pat, eq, relu_m, w.reshape(9 * c, o), da)
    dw, db = out[0], out[1]
    dx = out[2][:, 1:1 + h, 1:1 + wd, :] if need_dx else None
    return dw.reshape(3, 3, c, o), db.reshape(o), dx


# ---------------------------------------------------------------------------
# fc chain: fc1 + relu -> fc2 + relu -> fc3
# ---------------------------------------------------------------------------

def _fc_chain_fwd_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref,
                         b3_ref, out_ref, h1_ref, h2_ref):
    h1 = jnp.maximum(_dot(x_ref[...], w1_ref[...]) + b1_ref[...], 0.0)
    h1_ref[...] = h1
    h2 = jnp.maximum(_dot(h1, w2_ref[...]) + b2_ref[...], 0.0)
    h2_ref[...] = h2
    out_ref[...] = _dot(h2, w3_ref[...]) + b3_ref[...]


def fc_chain_fwd(flat: jnp.ndarray, params: dict,
                 interpret: bool = False) -> Tuple[jnp.ndarray, Tuple]:
    bs = flat.shape[0]
    p1, p2, p3 = params["fc1"], params["fc2"], params["fc3"]
    d1, d2, d3 = p1["w"].shape[1], p2["w"].shape[1], p3["w"].shape[1]
    dt = flat.dtype
    logits, h1, h2 = pl.pallas_call(
        _fc_chain_fwd_kernel,
        out_shape=[jax.ShapeDtypeStruct((bs, d3), dt),
                   jax.ShapeDtypeStruct((bs, d1), dt),
                   jax.ShapeDtypeStruct((bs, d2), dt)],
        interpret=interpret,
    )(flat, p1["w"], p1["b"].reshape(1, d1), p2["w"], p2["b"].reshape(1, d2),
      p3["w"], p3["b"].reshape(1, d3))
    return logits, (h1, h2)


def _fc_chain_bwd_kernel(x_ref, h1_ref, h2_ref, w1_ref, w2_ref, w3_ref,
                         g_ref, dw1_ref, db1_ref, dw2_ref, db2_ref,
                         dw3_ref, db3_ref, dx_ref):
    g = g_ref[...]
    h1, h2 = h1_ref[...], h2_ref[...]
    dw3_ref[...] = _dot32(h2.T, g)
    db3_ref[...] = g.astype(jnp.float32).sum(axis=0, keepdims=True)
    dh2 = _dot(g, w3_ref[...].T) * (h2 > 0)
    dw2_ref[...] = _dot32(h1.T, dh2)
    db2_ref[...] = dh2.astype(jnp.float32).sum(axis=0, keepdims=True)
    dh1 = _dot(dh2, w2_ref[...].T) * (h1 > 0)
    dw1_ref[...] = _dot32(x_ref[...].T, dh1)
    db1_ref[...] = dh1.astype(jnp.float32).sum(axis=0, keepdims=True)
    dx_ref[...] = _dot(dh1, w1_ref[...].T)


def fc_chain_bwd(flat: jnp.ndarray, res: Tuple, params: dict,
                 dlogits: jnp.ndarray,
                 interpret: bool = False) -> Tuple[dict, jnp.ndarray]:
    h1, h2 = res
    bs, f = flat.shape
    p1, p2, p3 = params["fc1"], params["fc2"], params["fc3"]
    d1, d2, d3 = p1["w"].shape[1], p2["w"].shape[1], p3["w"].shape[1]
    dt = flat.dtype
    f32 = jnp.float32
    dw1, db1, dw2, db2, dw3, db3, dflat = pl.pallas_call(
        _fc_chain_bwd_kernel,
        out_shape=[jax.ShapeDtypeStruct((f, d1), f32),
                   jax.ShapeDtypeStruct((1, d1), f32),
                   jax.ShapeDtypeStruct((d1, d2), f32),
                   jax.ShapeDtypeStruct((1, d2), f32),
                   jax.ShapeDtypeStruct((d2, d3), f32),
                   jax.ShapeDtypeStruct((1, d3), f32),
                   jax.ShapeDtypeStruct((bs, f), dt)],
        interpret=interpret,
    )(flat, h1, h2, p1["w"], p2["w"], p3["w"], dlogits)
    grads = {"fc1": {"w": dw1, "b": db1.reshape(d1)},
             "fc2": {"w": dw2, "b": db2.reshape(d2)},
             "fc3": {"w": dw3, "b": db3.reshape(d3)}}
    return grads, dflat


# ---------------------------------------------------------------------------
# blocked twins: the user axis IS the kernel grid
# ---------------------------------------------------------------------------
#
# The single-program kernels above batch the K selected users via vmap's
# batching rule — which rewrites each tiny kernel into K grid programs.
# Compiled on TPU that is merely suboptimal (K launches of ≤72-lane GEMMs
# that never fill the MXU); in interpret mode it is catastrophic, because
# every one of those K programs is a separate Python-interpreted kernel
# evaluation *per step per layer* (the 23x Pallas gap in BENCH_hsfl.json).
#
# The ``*_k`` twins below take the stacked ``(K, ...)`` weights directly
# and tile the grid over *user tiles* of ``block_k`` users: each grid step
# gathers im2col patches for its whole tile (merged ``bk·B`` leading axis)
# and runs one batched ``dot_general`` per layer, so a single kernel launch
# covers the entire cohort's layer — ``block_k=0`` (the default) is one
# grid step for all K users.  ``block_k`` trades VMEM residency against
# launch count on real hardware; in interpret mode it is the number of
# Python iterations, so whole-cohort blocks are the fast setting there.


def _conv_pool_fwd_k_kernel(xp_ref, w_ref, b_ref, a_ref, pat_ref, eq_ref,
                            m_ref, *, bk, bs, h, wd, c, o):
    xp = xp_ref[...]                               # (bk, B, H+2, W+2, C)
    cols = [xp[:, :, i:i + h, j:j + wd, :]
            for i in range(3) for j in range(3)]
    pat = jnp.concatenate(cols, axis=-1).reshape(bk, bs * h * wd, 9 * c)
    pat_ref[...] = pat
    z = _bdot(pat, w_ref[...]).reshape(bk, bs, h, wd, o)
    zw = z.reshape(bk, bs, h // 2, 2, wd // 2, 2, o)
    pz = zw.max(axis=(3, 5))
    eqw = (zw == pz[:, :, :, None, :, None, :])
    cnt = eqw.sum(axis=(3, 5), keepdims=True)
    eq_ref[...] = jnp.where(eqw, 1.0 / cnt, 0.0).astype(z.dtype).reshape(
        bk, bs, h, wd, o)
    a = jnp.maximum(pz + b_ref[...].reshape(bk, 1, 1, 1, o), 0.0)
    m_ref[...] = (a > 0).astype(z.dtype)
    a_ref[...] = a


def conv_pool_fwd_k(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                    block_k: int = 0,
                    interpret: bool = False) -> Tuple[jnp.ndarray, Tuple]:
    """Blocked Pallas twin of ``ref.conv_pool_fwd_k``: x (K,B,H,W,C),
    stacked w (K,3,3,C,O) / b (K,O); grid = (K // block_k,) user tiles."""
    k, bs, h, wd, c = x.shape
    o = w.shape[-1]
    bk = resolve_block_k(k, block_k)
    xp = jnp.pad(x, ((0, 0), (0, 0), (1, 1), (1, 1), (0, 0)))
    dt = x.dtype
    a, pat, eq, relu_m = pl.pallas_call(
        functools.partial(_conv_pool_fwd_k_kernel, bk=bk, bs=bs, h=h,
                          wd=wd, c=c, o=o),
        grid=(k // bk,),
        in_specs=[
            pl.BlockSpec((bk, bs, h + 2, wd + 2, c),
                         lambda i: (i, 0, 0, 0, 0)),
            pl.BlockSpec((bk, 9 * c, o), lambda i: (i, 0, 0)),
            pl.BlockSpec((bk, 1, o), lambda i: (i, 0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((k, bs, h // 2, wd // 2, o), dt),
                   jax.ShapeDtypeStruct((k, bs * h * wd, 9 * c), dt),
                   jax.ShapeDtypeStruct((k, bs, h, wd, o), dt),
                   jax.ShapeDtypeStruct((k, bs, h // 2, wd // 2, o), dt)],
        out_specs=[
            pl.BlockSpec((bk, bs, h // 2, wd // 2, o),
                         lambda i: (i, 0, 0, 0, 0)),
            pl.BlockSpec((bk, bs * h * wd, 9 * c), lambda i: (i, 0, 0)),
            pl.BlockSpec((bk, bs, h, wd, o), lambda i: (i, 0, 0, 0, 0)),
            pl.BlockSpec((bk, bs, h // 2, wd // 2, o),
                         lambda i: (i, 0, 0, 0, 0)),
        ],
        interpret=interpret,
    )(xp, w.reshape(k, 9 * c, o), b.reshape(k, 1, o))
    return a, (pat, eq, relu_m)


def _conv_pool_bwd_k_kernel(pat_ref, eq_ref, m_ref, w_ref, da_ref,
                            dw_ref, db_ref, *maybe_dx_ref,
                            bk, bs, h, wd, c, o):
    dp = da_ref[...] * m_ref[...]                  # (bk, B, H/2, W/2, O)
    db_ref[...] = dp.astype(jnp.float32).sum(axis=(1, 2, 3)).reshape(bk, 1, o)
    dz = (eq_ref[...].reshape(bk, bs, h // 2, 2, wd // 2, 2, o)
          * dp[:, :, :, None, :, None, :]).reshape(bk, bs * h * wd, o)
    pat = pat_ref[...]
    dw_ref[...] = _bdot32(_bT(pat), dz)
    if maybe_dx_ref:
        dx_ref, = maybe_dx_ref
        dpat = _bdot(dz, _bT(w_ref[...])).reshape(bk, bs, h, wd, 9 * c)
        dx_ref[...] = jnp.zeros(dx_ref.shape, dx_ref.dtype)
        for idx in range(9):
            i, j = divmod(idx, 3)
            dx_ref[:, :, i:i + h, j:j + wd, :] += (
                dpat[..., idx * c:(idx + 1) * c])


def conv_pool_bwd_k(res: Tuple, w: jnp.ndarray, da: jnp.ndarray,
                    need_dx: bool, block_k: int = 0,
                    interpret: bool = False) -> Tuple:
    """Blocked Pallas twin of ``ref.conv_pool_bwd_k``: stacked (K, ...)
    residuals/weights in, per-user (dw f32, db f32, dx-or-None) out."""
    pat, eq, relu_m = res
    k, bs, h, wd, o = eq.shape
    c = pat.shape[-1] // 9
    bk = resolve_block_k(k, block_k)
    dt = pat.dtype
    f32 = jnp.float32
    out_shape = [jax.ShapeDtypeStruct((k, 9 * c, o), f32),
                 jax.ShapeDtypeStruct((k, 1, o), f32)]
    out_specs = [pl.BlockSpec((bk, 9 * c, o), lambda i: (i, 0, 0)),
                 pl.BlockSpec((bk, 1, o), lambda i: (i, 0, 0))]
    if need_dx:
        out_shape.append(
            jax.ShapeDtypeStruct((k, bs, h + 2, wd + 2, c), dt))
        out_specs.append(pl.BlockSpec((bk, bs, h + 2, wd + 2, c),
                                      lambda i: (i, 0, 0, 0, 0)))
    out = pl.pallas_call(
        functools.partial(_conv_pool_bwd_k_kernel, bk=bk, bs=bs, h=h,
                          wd=wd, c=c, o=o),
        grid=(k // bk,),
        in_specs=[
            pl.BlockSpec((bk, bs * h * wd, 9 * c), lambda i: (i, 0, 0)),
            pl.BlockSpec((bk, bs, h, wd, o), lambda i: (i, 0, 0, 0, 0)),
            pl.BlockSpec((bk, bs, h // 2, wd // 2, o),
                         lambda i: (i, 0, 0, 0, 0)),
            pl.BlockSpec((bk, 9 * c, o), lambda i: (i, 0, 0)),
            pl.BlockSpec((bk, bs, h // 2, wd // 2, o),
                         lambda i: (i, 0, 0, 0, 0)),
        ],
        out_shape=out_shape,
        out_specs=out_specs,
        interpret=interpret,
    )(pat, eq, relu_m, w.reshape(k, 9 * c, o), da)
    dw, db = out[0], out[1]
    dx = out[2][:, :, 1:1 + h, 1:1 + wd, :] if need_dx else None
    return dw.reshape(k, 3, 3, c, o), db.reshape(k, o), dx


def _fc_chain_fwd_k_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref,
                           b3_ref, out_ref, h1_ref, h2_ref):
    h1 = jnp.maximum(_bdot(x_ref[...], w1_ref[...]) + b1_ref[...], 0.0)
    h1_ref[...] = h1
    h2 = jnp.maximum(_bdot(h1, w2_ref[...]) + b2_ref[...], 0.0)
    h2_ref[...] = h2
    out_ref[...] = _bdot(h2, w3_ref[...]) + b3_ref[...]


def fc_chain_fwd_k(flat: jnp.ndarray, params: dict, block_k: int = 0,
                   interpret: bool = False) -> Tuple[jnp.ndarray, Tuple]:
    """Blocked Pallas twin of ``ref.fc_chain_fwd_k``: flat (K,B,F),
    stacked fc params (K, ...)."""
    k, bs, f = flat.shape
    bk = resolve_block_k(k, block_k)
    p1, p2, p3 = params["fc1"], params["fc2"], params["fc3"]
    d1, d2, d3 = p1["w"].shape[-1], p2["w"].shape[-1], p3["w"].shape[-1]
    dt = flat.dtype
    mat = lambda m, n: pl.BlockSpec((bk, m, n), lambda i: (i, 0, 0))
    logits, h1, h2 = pl.pallas_call(
        _fc_chain_fwd_k_kernel,
        grid=(k // bk,),
        in_specs=[mat(bs, f), mat(f, d1), mat(1, d1), mat(d1, d2),
                  mat(1, d2), mat(d2, d3), mat(1, d3)],
        out_shape=[jax.ShapeDtypeStruct((k, bs, d3), dt),
                   jax.ShapeDtypeStruct((k, bs, d1), dt),
                   jax.ShapeDtypeStruct((k, bs, d2), dt)],
        out_specs=[mat(bs, d3), mat(bs, d1), mat(bs, d2)],
        interpret=interpret,
    )(flat, p1["w"], p1["b"].reshape(k, 1, d1), p2["w"],
      p2["b"].reshape(k, 1, d2), p3["w"], p3["b"].reshape(k, 1, d3))
    return logits, (h1, h2)


def _fc_chain_bwd_k_kernel(x_ref, h1_ref, h2_ref, w1_ref, w2_ref, w3_ref,
                           g_ref, dw1_ref, db1_ref, dw2_ref, db2_ref,
                           dw3_ref, db3_ref, dx_ref):
    g = g_ref[...]
    h1, h2 = h1_ref[...], h2_ref[...]
    dw3_ref[...] = _bdot32(_bT(h2), g)
    db3_ref[...] = g.astype(jnp.float32).sum(axis=1, keepdims=True)
    dh2 = _bdot(g, _bT(w3_ref[...])) * (h2 > 0)
    dw2_ref[...] = _bdot32(_bT(h1), dh2)
    db2_ref[...] = dh2.astype(jnp.float32).sum(axis=1, keepdims=True)
    dh1 = _bdot(dh2, _bT(w2_ref[...])) * (h1 > 0)
    dw1_ref[...] = _bdot32(_bT(x_ref[...]), dh1)
    db1_ref[...] = dh1.astype(jnp.float32).sum(axis=1, keepdims=True)
    dx_ref[...] = _bdot(dh1, _bT(w1_ref[...]))


def fc_chain_bwd_k(flat: jnp.ndarray, res: Tuple, params: dict,
                   dlogits: jnp.ndarray, block_k: int = 0,
                   interpret: bool = False) -> Tuple[dict, jnp.ndarray]:
    """Blocked Pallas twin of ``ref.fc_chain_bwd_k``."""
    h1, h2 = res
    k, bs, f = flat.shape
    bk = resolve_block_k(k, block_k)
    p1, p2, p3 = params["fc1"], params["fc2"], params["fc3"]
    d1, d2, d3 = p1["w"].shape[-1], p2["w"].shape[-1], p3["w"].shape[-1]
    dt = flat.dtype
    f32 = jnp.float32
    mat = lambda m, n: pl.BlockSpec((bk, m, n), lambda i: (i, 0, 0))
    dw1, db1, dw2, db2, dw3, db3, dflat = pl.pallas_call(
        _fc_chain_bwd_k_kernel,
        grid=(k // bk,),
        in_specs=[mat(bs, f), mat(bs, d1), mat(bs, d2), mat(f, d1),
                  mat(d1, d2), mat(d2, d3), mat(bs, d3)],
        out_shape=[jax.ShapeDtypeStruct((k, f, d1), f32),
                   jax.ShapeDtypeStruct((k, 1, d1), f32),
                   jax.ShapeDtypeStruct((k, d1, d2), f32),
                   jax.ShapeDtypeStruct((k, 1, d2), f32),
                   jax.ShapeDtypeStruct((k, d2, d3), f32),
                   jax.ShapeDtypeStruct((k, 1, d3), f32),
                   jax.ShapeDtypeStruct((k, bs, f), dt)],
        out_specs=[mat(f, d1), mat(1, d1), mat(d1, d2), mat(1, d2),
                   mat(d2, d3), mat(1, d3), mat(bs, f)],
        interpret=interpret,
    )(flat, h1, h2, p1["w"], p2["w"], p3["w"], dlogits)
    grads = {"fc1": {"w": dw1, "b": db1.reshape(k, d1)},
             "fc2": {"w": dw2, "b": db2.reshape(k, d2)},
             "fc3": {"w": dw3, "b": db3.reshape(k, d3)}}
    return grads, dflat
