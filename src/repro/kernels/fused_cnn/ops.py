"""Forward-policy layer: one flag flips the CNN hot path everywhere.

``ForwardPolicy`` selects how the 5-layer CNN computes inside the fused
HSFL round (``core/fused_round``), the sweep engine (``core/sweep``), the
benchmarks and the examples:

  kernel    "xla"    — pool-first fused step with the hand-written VJP
                       (``ref.py``) — the default; breaks the PR-3 compute
                       floor on CPU and lowers cleanly everywhere.
            "pallas" — the same algorithm through the Pallas kernel suite
                       (``kernel.py``); ``interpret=True`` off-TPU, same
                       convention as ``kernels/delta_codec``.
            "im2col" — the PR-1 reference: ``cnn.forward_im2col`` +
                       ``jax.grad`` autodiff (kept as the baseline the
                       fast paths are value-pinned against).
  precision "f32"    — value-equivalence pinned: bit-identical forward to
                       ``cnn.forward_im2col``.
            "bf16"   — mixed precision: bf16 compute, f32 master params,
                       f32 matmul accumulation (xla/pallas paths; the
                       im2col baseline keeps its legacy compute-dtype
                       accumulation) and f32 loss; grads come back f32 so
                       the SGD update never touches bf16 state.  (Paper-
                       comparable accuracy is pinned by the loss-tolerance
                       test, not bit equality.)

``make_forward`` wires the chosen implementation into ``jax.custom_vjp``
so ``jax.grad`` of any loss through it uses the hand-written backward —
the epoch fn in ``fused_round._make_epoch_fn`` needs no other change.
The custom backward returns the true image cotangent too; it is dead code
under ``jax.grad(loss)(params)`` and XLA DCEs it on the "xla" path.

``make_eval_forward`` returns the plain (non-custom-vjp, non-Pallas)
forward at the same precision: full-test-set eval batches would blow the
single-program VMEM budget of the Pallas kernels, and the ref path is
value-identical anyway (pinned).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.fused_cnn import kernel as knl
from repro.kernels.fused_cnn import ref

KERNELS = ("xla", "pallas", "im2col")
PRECISIONS = ("f32", "bf16")


@dataclass(frozen=True)
class ForwardPolicy:
    """How the CNN hot path computes.  Hashable → usable as a jit static
    and inside ``core/sweep``'s program-cache key.

    ``block_k`` sizes the user tile of the blocked kernels' grid: 0 (the
    default) is one grid step for the whole selected cohort, ``n`` runs
    ``ceil(K/n)`` grid steps of ``n`` users each (the cohort is padded to
    a multiple; pad users are sliced off the grads).  ``batch_users=False``
    keeps the PR-4 vmap-of-per-user-kernels step — the baseline the
    ``blocked-vs-vmapped`` microbench and CI perf-guard compare against."""
    kernel: str = "xla"
    precision: str = "f32"
    interpret: bool = False
    block_k: int = 0
    batch_users: bool = True

    def validate(self) -> "ForwardPolicy":
        if self.kernel not in KERNELS:
            raise ValueError(f"ForwardPolicy.kernel={self.kernel!r}; "
                             f"choose from {KERNELS}")
        if self.precision not in PRECISIONS:
            raise ValueError(f"ForwardPolicy.precision={self.precision!r}; "
                             f"choose from {PRECISIONS}")
        if not isinstance(self.block_k, int) or self.block_k < 0:
            raise ValueError(f"ForwardPolicy.block_k={self.block_k!r}; "
                             "expected an int >= 0 (0 = whole cohort)")
        return self


def _cast_tree(tree: Any, dtype) -> Any:
    return jax.tree_util.tree_map(lambda l: l.astype(dtype), tree)


def _impl(policy: ForwardPolicy):
    """(forward_with_residuals, backward) pair for the policy's kernel."""
    if policy.kernel == "xla":
        return ref.forward_fwd_ref, ref.backward_ref

    it = policy.interpret

    def fwd_res(p, x):
        a1, r1 = knl.conv_pool_fwd(x, p["conv1"]["w"], p["conv1"]["b"],
                                   interpret=it)
        a2, r2 = knl.conv_pool_fwd(a1, p["conv2"]["w"], p["conv2"]["b"],
                                   interpret=it)
        flat = a2.reshape(a2.shape[0], -1)
        logits, rfc = knl.fc_chain_fwd(flat, p, interpret=it)
        return logits, (r1, r2, flat, rfc)

    def bwd(p, res, g, need_dx=True):
        # need_dx threads down to the conv1 kernel: a pallas_call's outputs
        # are opaque to XLA's DCE, so the unused image gradient must be
        # skipped at kernel-build time, not relied on to be eliminated
        r1, r2, flat, rfc = res
        gfc, dflat = knl.fc_chain_bwd(flat, rfc, p, g, interpret=it)
        bs, h, wd, o = r2[1].shape
        da2 = dflat.reshape(bs, h // 2, wd // 2, o)
        dw2, db2, da1 = knl.conv_pool_bwd(r2, p["conv2"]["w"], da2, True,
                                          interpret=it)
        dw1, db1, dx = knl.conv_pool_bwd(r1, p["conv1"]["w"], da1, need_dx,
                                         interpret=it)
        grads = {"conv1": {"w": dw1, "b": db1},
                 "conv2": {"w": dw2, "b": db2}, **gfc}
        return grads, dx

    return fwd_res, bwd


def make_forward(policy: ForwardPolicy) -> Callable:
    """``forward(params, images) -> logits`` with the policy's compute
    path and the hand-written VJP attached (except "im2col" = autodiff)."""
    policy.validate()
    cd = jnp.bfloat16 if policy.precision == "bf16" else None
    if policy.kernel == "im2col":
        # legacy baseline, kept bit-for-bit: note its bf16 variant
        # accumulates matmuls in the compute dtype (plain ``@``), unlike
        # the xla/pallas paths which force f32 accumulation — compare
        # bf16 numerics across kernels with that in mind
        from repro.models import cnn as cnn_mod
        if cd is None:
            return cnn_mod.forward_im2col
        return lambda p, x: cnn_mod.forward_im2col(p, x, compute_dtype=cd)

    fwd_res, bwd_impl = _impl(policy)

    @jax.custom_vjp
    def forward(params, images):
        p = _cast_tree(params, cd) if cd else params
        x = images.astype(cd) if cd else images
        logits, _ = fwd_res(p, x)
        return logits.astype(jnp.float32) if cd else logits

    def forward_fwd(params, images):
        p = _cast_tree(params, cd) if cd else params
        x = images.astype(cd) if cd else images
        logits, res = fwd_res(p, x)
        out = logits.astype(jnp.float32) if cd else logits
        return out, (p, res)

    def forward_bwd(saved, g):
        p, res = saved
        gc = g.astype(cd) if cd else g
        grads, dx = bwd_impl(p, res, gc)
        if cd is None:
            # match the caller's (master) param dtypes exactly
            grads = jax.tree_util.tree_map(
                lambda gg, pp: gg.astype(pp.dtype), grads, p)
        # bf16 policy: grads already carry f32 accumulation — the master
        # params and the SGD update stay f32
        return grads, dx.astype(jnp.float32) if dx is not None else None

    forward.defvjp(forward_fwd, forward_bwd)
    return forward


def make_loss_grad(policy: ForwardPolicy) -> Callable:
    """``(params, bx, by) -> (loss, grads)`` with softmax cross-entropy
    fused onto the hand-written backward.

    ``jax.grad`` of ``cross_entropy(forward(...))`` pays a
    ``take_along_axis`` scatter in the loss backward; here the closed-form
    ``(softmax − onehot)/B`` cotangent feeds the custom backward directly.
    Loss and logits math run in f32 whatever the compute precision (the
    policy's "f32 loss accumulation" contract); grads come back f32 (or
    the master dtype at f32 policy).  This is the training step
    ``fused_round._make_epoch_fn`` runs for policy-selected forwards —
    value-equal to the autodiff composition up to summation order."""
    policy.validate()
    if policy.kernel == "im2col":
        # legacy baseline: plain autodiff through forward_im2col
        return _autodiff_loss_grad(make_forward(policy))

    cd = jnp.bfloat16 if policy.precision == "bf16" else None
    fwd_res, bwd_impl = _impl(policy)

    def loss_grad(params, bx, by):
        p = _cast_tree(params, cd) if cd else params
        x = bx.astype(cd) if cd else bx
        logits, res = fwd_res(p, x)
        lf = logits.astype(jnp.float32)
        zm = lf - lf.max(axis=-1, keepdims=True)
        logz = jnp.log(jnp.sum(jnp.exp(zm), axis=-1, keepdims=True))
        logp = zm - logz
        onehot = jax.nn.one_hot(by, lf.shape[-1], dtype=jnp.float32)
        loss = -jnp.mean(jnp.sum(onehot * logp, axis=-1))
        dlogits = (jnp.exp(logp) - onehot) / lf.shape[0]
        grads, _ = bwd_impl(p, res, dlogits.astype(cd) if cd else dlogits,
                            need_dx=False)
        if cd is None:
            grads = jax.tree_util.tree_map(
                lambda gg, pp: gg.astype(pp.dtype), grads, p)
        return loss, grads

    return loss_grad


def _autodiff_loss_grad(fwd: Callable) -> Callable:
    from repro.training.loss import cross_entropy

    def loss_grad(params, bx, by):
        return jax.value_and_grad(
            lambda q: cross_entropy(fwd(q, bx), by))(params)

    return loss_grad


def make_eval_forward(policy: ForwardPolicy) -> Callable:
    """Plain forward at the policy's precision (ref path, no custom VJP):
    for in-program eval over full test batches."""
    policy.validate()
    if policy.kernel == "im2col":
        return make_forward(policy)
    if policy.precision == "f32":
        return ref.forward_ref

    def eval_fwd(params, images):
        p = _cast_tree(params, jnp.bfloat16)
        return ref.forward_ref(p, images.astype(jnp.bfloat16)).astype(
            jnp.float32)

    return eval_fwd


# ---------------------------------------------------------------------------
# stacked-cohort step: the K-user axis handled by the kernels, not vmap
# ---------------------------------------------------------------------------

def _impl_stacked(policy: ForwardPolicy):
    """(fwd_res_k, bwd_k) over stacked ``(K, ...)`` params for the blocked
    kernels (xla = batched ``dot_general`` ref twins, pallas = grid-tiled
    blocked kernels)."""
    if policy.kernel == "xla":
        return ref.forward_fwd_ref_k, ref.backward_ref_k

    it = policy.interpret
    bk = policy.block_k

    def fwd_res(p, x):
        a1, r1 = knl.conv_pool_fwd_k(x, p["conv1"]["w"], p["conv1"]["b"],
                                     block_k=bk, interpret=it)
        a2, r2 = knl.conv_pool_fwd_k(a1, p["conv2"]["w"], p["conv2"]["b"],
                                     block_k=bk, interpret=it)
        flat = a2.reshape(a2.shape[0], a2.shape[1], -1)
        logits, rfc = knl.fc_chain_fwd_k(flat, p, block_k=bk, interpret=it)
        return logits, (r1, r2, flat, rfc)

    def bwd(p, res, g, need_dx=True):
        r1, r2, flat, rfc = res
        gfc, dflat = knl.fc_chain_bwd_k(flat, rfc, p, g, block_k=bk,
                                        interpret=it)
        k, bs, h, wd, o = r2[1].shape
        da2 = dflat.reshape(k, bs, h // 2, wd // 2, o)
        dw2, db2, da1 = knl.conv_pool_bwd_k(r2, p["conv2"]["w"], da2, True,
                                            block_k=bk, interpret=it)
        dw1, db1, dx = knl.conv_pool_bwd_k(r1, p["conv1"]["w"], da1,
                                           need_dx, block_k=bk,
                                           interpret=it)
        grads = {"conv1": {"w": dw1, "b": db1},
                 "conv2": {"w": dw2, "b": db2}, **gfc}
        return grads, dx

    return fwd_res, bwd


def _pad_users(policy: ForwardPolicy, loss_grad_k: Callable) -> Callable:
    """Pad the user axis to a multiple of ``block_k`` around a stacked
    loss-grad (the blocked Pallas grid needs an exact tiling; pad users
    are zero-weight phantoms whose grads are sliced off)."""

    def wrapped(params, bx, by):
        k = by.shape[0]
        bk = k if policy.block_k <= 0 or policy.block_k >= k \
            else policy.block_k
        pad = (-k) % bk
        if not pad:
            return loss_grad_k(params, bx, by)
        pw = lambda t: jnp.pad(t, ((0, pad),) + ((0, 0),) * (t.ndim - 1))
        loss, grads = loss_grad_k(jax.tree_util.tree_map(pw, params),
                                  pw(bx), pw(by))
        return loss[:k], jax.tree_util.tree_map(lambda t: t[:k], grads)

    return wrapped


def make_stacked_loss_grad(policy: ForwardPolicy) -> Callable:
    """``(stacked_params, bx, by) -> (loss (K,), grads)`` over the whole
    selected cohort: params leaves stacked ``(K, ...)``, bx ``(K, B, ...)``,
    by ``(K, B)``.

    This is ``make_loss_grad`` with the user axis moved *into* the kernels:
    one batched ``dot_general`` (xla) or one grid-tiled kernel launch
    (pallas) per layer instead of K vmapped tiny-GEMM programs.  The
    "im2col" baseline and ``batch_users=False`` keep the vmap composition
    (bit-identical to PR 4) so the blocked path has an in-tree twin to be
    pinned and benchmarked against."""
    policy.validate()
    if policy.kernel == "im2col" or not policy.batch_users:
        return jax.vmap(make_loss_grad(policy))

    cd = jnp.bfloat16 if policy.precision == "bf16" else None
    fwd_res_k, bwd_k = _impl_stacked(policy)

    def loss_grad_k(params, bx, by):
        p = _cast_tree(params, cd) if cd else params
        x = bx.astype(cd) if cd else bx
        logits, res = fwd_res_k(p, x)
        lf = logits.astype(jnp.float32)            # (K, B, classes)
        zm = lf - lf.max(axis=-1, keepdims=True)
        logz = jnp.log(jnp.sum(jnp.exp(zm), axis=-1, keepdims=True))
        logp = zm - logz
        onehot = jax.nn.one_hot(by, lf.shape[-1], dtype=jnp.float32)
        loss = -jnp.mean(jnp.sum(onehot * logp, axis=-1), axis=-1)
        dlogits = (jnp.exp(logp) - onehot) / lf.shape[1]
        grads, _ = bwd_k(p, res, dlogits.astype(cd) if cd else dlogits,
                         need_dx=False)
        if cd is None:
            grads = jax.tree_util.tree_map(
                lambda gg, pp: gg.astype(pp.dtype), grads, p)
        return loss, grads

    if policy.kernel == "pallas":
        return _pad_users(policy, loss_grad_k)
    return loss_grad_k


def make_stacked_epoch_fn(policy: ForwardPolicy, lr: float) -> Callable:
    """``epoch_all(stacked, xs, ys) -> stacked``: one local epoch of SGD
    for the whole cohort — xs ``(K, steps, B, ...)``, ys ``(K, steps, B)``,
    params leaves stacked ``(K, ...)`` (f32 master).

    The step axis is scanned with the *user axis inside the kernels*
    (``make_stacked_loss_grad``), replacing ``vmap(per-user epoch)``.

    bf16 policy (xla/pallas): the master-param round-trip is hoisted to
    the epoch boundary — images and params cast to bf16 ONCE per epoch,
    the step scan carries the bf16 trajectory plus an f32 gradient
    accumulator, and the f32 master updates once at the end with the full
    f32 gradient sum (``master - lr·Σg``).  The old per-step
    master→bf16→f32 round-trip both paid 2·|params| casts per step and
    quantized every SGD update to bf16 resolution against the master;
    here per-step bf16 drift is confined inside one epoch and the master
    integrates exact f32 gradients (quality pinned by the loss-tolerance
    regression test)."""
    policy.validate()
    loss_grad_k = make_stacked_loss_grad(policy)
    bf16_fast = policy.precision == "bf16" and policy.kernel != "im2col"
    tmap = jax.tree_util.tree_map

    def epoch_all(stacked, xs, ys):
        sx = jnp.swapaxes(xs, 0, 1)                # (steps, K, B, ...)
        sy = jnp.swapaxes(ys, 0, 1)
        if not bf16_fast:
            def step(p, batch):
                bx, by = batch
                _, g = loss_grad_k(p, bx, by)
                return tmap(lambda w, gg: w - lr * gg, p, g), ()

            out, _ = jax.lax.scan(step, stacked, (sx, sy))
            return out

        sx = sx.astype(jnp.bfloat16)               # cast ONCE per epoch
        p0 = _cast_tree(stacked, jnp.bfloat16)
        acc0 = tmap(jnp.zeros_like, stacked)       # f32 accumulator

        def step(carry, batch):
            p, acc = carry
            bx, by = batch
            _, g = loss_grad_k(p, bx, by)          # grads come back f32
            acc = tmap(jnp.add, acc, g)
            p = tmap(lambda w, gg: w - lr * gg.astype(jnp.bfloat16), p, g)
            return (p, acc), ()

        (_, acc), _ = jax.lax.scan(step, (p0, acc0), (sx, sy))
        return tmap(lambda w, a: w - lr * a, stacked, acc)

    return epoch_all


def resolve_train_step(forward: Any, interpret: bool = False
                       ) -> Tuple[Callable, Callable]:
    """Normalize ``build_fused_round``/``build_device_round``'s ``forward=``
    argument into ``(loss_grad, eval_fwd)``: the fused
    ``(params, bx, by) -> (loss, grads)`` training step
    (``make_loss_grad``) the epoch scan runs, and the plain eval forward.

    - ``None`` → the default ``ForwardPolicy()`` (xla kernel, f32);
    - a ``ForwardPolicy`` → its compute path (``interpret`` is OR-ed with
      the round builder's flag, the delta-codec convention);
    - any other callable → autodiff around it, and used verbatim for eval
      (legacy hook, used by tests that train tiny non-CNN models through
      the round).
    """
    if forward is None:
        forward = ForwardPolicy()
    if isinstance(forward, ForwardPolicy):
        policy = replace(forward, interpret=forward.interpret or interpret)
        return make_loss_grad(policy), make_eval_forward(policy)
    return _autodiff_loss_grad(forward), forward
