from repro.kernels.fused_cnn.ops import (ForwardPolicy, make_eval_forward,
                                         make_forward, make_loss_grad,
                                         make_stacked_epoch_fn,
                                         make_stacked_loss_grad,
                                         resolve_train_step)

__all__ = ["ForwardPolicy", "make_forward", "make_eval_forward",
           "make_loss_grad", "make_stacked_loss_grad",
           "make_stacked_epoch_fn", "resolve_train_step"]
