"""Pure-jnp oracle for the fused CNN training step (pool-first layout).

The paper's hot loop is K users x e epochs x S steps of the 5-layer MNIST
CNN — stock XLA autodiff of ``cnn.forward_im2col`` pays full-resolution
bias/ReLU passes and re-derives the pool/ReLU selection masks in the
backward.  This module is the algorithmic reference the Pallas kernels
(``kernel.py``) and the XLA fast path (``ops.py``) are pinned against:

- **pool-first conv block**: ``pool(relu(z + b)) == relu(pool(z) + b)``
  *bit-for-bit* (max commutes with the monotone per-channel bias add, and
  relu is monotone), so the bias add and ReLU run at pooled resolution —
  4x fewer elements than the ``forward_im2col`` order.  Forward values
  are identical to ``cnn.forward_im2col`` at f32.
- **hand-written backward**: the forward saves the im2col patch matrix,
  the pool argmax mask ``eq = (z == pooled_z)`` (with JAX's tie-splitting
  1/count semantics, so grads match ``jax.grad`` of the reference
  exactly) and the ReLU mask — the backward is pure mask algebra plus the
  two transposed matmuls, never re-deriving activations.
- conv1's ``dx`` (the fold back to the input image) is exposed but unused
  by the training step — images carry no gradient, XLA DCEs it.

``D`` below is the compute dtype (f32, or bf16 under the mixed-precision
policy); matmul accumulation is always f32 (``preferred_element_type``).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _dot(a, b):
    """Matmul with f32 accumulation, result in the compute dtype."""
    return jax.lax.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def patches3x3(x: jnp.ndarray) -> jnp.ndarray:
    """(B, H, W, C) -> (B, H, W, 9C) SAME-padded 3x3 patch view, in the
    per-pixel contraction order of ``conv_general_dilated``.

    Delegates to ``cnn._patches3x3`` — one copy of the patch-ordering
    contract the bit-equivalence pin against ``forward_im2col`` rests on
    (``kernel.py`` necessarily re-states it inside the Pallas program)."""
    from repro.models.cnn import _patches3x3
    return _patches3x3(x)


def fold3x3(dpatches: jnp.ndarray) -> jnp.ndarray:
    """Transpose of ``patches3x3``: scatter-add (B,H,W,9C) -> (B,H,W,C)."""
    b, h, w, c9 = dpatches.shape
    c = c9 // 9
    dxp = jnp.zeros((b, h + 2, w + 2, c), dpatches.dtype)
    for idx in range(9):
        i, j = divmod(idx, 3)
        dxp = dxp.at[:, i:i + h, j:j + w, :].add(
            dpatches[..., idx * c:(idx + 1) * c])
    return dxp[:, 1:1 + h, 1:1 + w, :]


def conv_pool_fwd(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, Tuple]:
    """Fused im2col conv + bias + ReLU + 2x2 maxpool, pool-first.

    x (B,H,W,C); w (3,3,C,O); b (O,).  Returns the block activation
    ``a (B,H/2,W/2,O)`` and residuals ``(pat, eq, relu_m)``:

      pat    (B·H·W, 9C)   — im2col patches (reused for dW)
      eq     (B,H,W,O)     — pool argmax mask, 1/count-weighted at ties
                             (exactly ``jax.grad``'s reduce-max rule)
      relu_m (B,H/2,W/2,O) — ReLU mask at pooled resolution

    The forward value equals ``cnn._conv_im2col`` bit-for-bit at f32:
    ``pool(relu(z+b)) == relu(pool(z)+b)`` because the per-channel bias
    add is monotone (the same window element wins the max) and relu is
    monotone.
    """
    bs, h, wd, c = x.shape
    o = w.shape[-1]
    pat = patches3x3(x).reshape(bs * h * wd, 9 * c)
    z = _dot(pat, w.reshape(9 * c, o)).reshape(bs, h, wd, o)
    zw = z.reshape(bs, h // 2, 2, wd // 2, 2, o)
    pz = zw.max(axis=(2, 4))
    a = jnp.maximum(pz + b, 0.0)
    eqw = (zw == pz[:, :, None, :, None, :])
    cnt = eqw.sum(axis=(2, 4), keepdims=True)
    eq = jnp.where(eqw, 1.0 / cnt, 0.0).astype(x.dtype).reshape(bs, h, wd, o)
    relu_m = (pz + b > 0).astype(x.dtype)
    return a, (pat, eq, relu_m)


def conv_pool_bwd(res: Tuple, w: jnp.ndarray, da: jnp.ndarray,
                  need_dx: bool) -> Tuple:
    """Backward of ``conv_pool_fwd`` from the saved masks.

    da (B,H/2,W/2,O) -> (dw (3,3,C,O), db (O,), dx (B,H,W,C) or None).
    ``db`` is summed at pooled resolution (4x cheaper than the im2col
    order, identical value: the bias reaches the loss only through the
    pool winners)."""
    pat, eq, relu_m = res
    bs, h, wd, o = eq.shape
    c = pat.shape[-1] // 9
    dp = da * relu_m                               # (B,H/2,W/2,O)
    db = dp.astype(jnp.float32).sum(axis=(0, 1, 2))
    dz = (eq.reshape(bs, h // 2, 2, wd // 2, 2, o)
          * dp[:, :, None, :, None, :]).reshape(bs * h * wd, o)
    dw = jax.lax.dot(pat.T, dz, preferred_element_type=jnp.float32)
    dw = dw.reshape(3, 3, c, o)
    dx = None
    if need_dx:
        dpat = _dot(dz, w.reshape(9 * c, o).T).reshape(bs, h, wd, 9 * c)
        dx = fold3x3(dpat)
    return dw, db, dx


def fc_chain_fwd(flat: jnp.ndarray, params: dict) -> Tuple[jnp.ndarray, Tuple]:
    """fc1+ReLU -> fc2+ReLU -> fc3 logits in one pass.

    flat (B, F).  Returns logits (B, num_classes) and residuals
    (h1, h2) — the ReLU masks are recovered as ``h > 0`` (free)."""
    h1 = jnp.maximum(_dot(flat, params["fc1"]["w"]) + params["fc1"]["b"], 0.0)
    h2 = jnp.maximum(_dot(h1, params["fc2"]["w"]) + params["fc2"]["b"], 0.0)
    logits = _dot(h2, params["fc3"]["w"]) + params["fc3"]["b"]
    return logits, (h1, h2)


def fc_chain_bwd(flat: jnp.ndarray, res: Tuple, params: dict,
                 dlogits: jnp.ndarray) -> Tuple[dict, jnp.ndarray]:
    """Backward of ``fc_chain_fwd``: grads for fc1..fc3 plus dflat."""
    h1, h2 = res

    def dot32(a, b):
        return jax.lax.dot(a, b, preferred_element_type=jnp.float32)

    g3 = {"w": dot32(h2.T, dlogits), "b": dlogits.astype(jnp.float32).sum(0)}
    dh2 = _dot(dlogits, params["fc3"]["w"].T) * (h2 > 0)
    g2 = {"w": dot32(h1.T, dh2), "b": dh2.astype(jnp.float32).sum(0)}
    dh1 = _dot(dh2, params["fc2"]["w"].T) * (h1 > 0)
    g1 = {"w": dot32(flat.T, dh1), "b": dh1.astype(jnp.float32).sum(0)}
    dflat = _dot(dh1, params["fc1"]["w"].T)
    return {"fc1": g1, "fc2": g2, "fc3": g3}, dflat


# ---------------------------------------------------------------------------
# batched-over-users twins: the K-user cohort as ONE GEMM per layer
# ---------------------------------------------------------------------------
#
# The PR-4 step ran per user and relied on ``jax.vmap`` to batch the K axis.
# These twins take the stacked ``(K, ...)`` weights directly: the patch /
# pool / mask stages run on the merged ``K·B`` leading axis (one elementwise
# program for the whole cohort) and every matmul is a single batched
# ``dot_general`` whose M dimension is the per-user ``B·P`` block — the
# "blocked" layout the Pallas kernels (``kernel.py``) tile over their grid.

_BDN = (((2,), (1,)), ((0,), (0,)))       # (K,M,P) x (K,P,N) -> (K,M,N)


def _bdot(a, b):
    """Batched-over-users matmul in the compute dtype.

    f32 inputs keep the f32-accumulation contract of ``_dot``.  bf16 inputs
    run the backend's *native* bf16 GEMM (no forced-f32 output): on
    AMX/AVX512-BF16 CPUs and TPU MXUs the accumulator is f32 *inside* the
    GEMM microkernel and only the stored result rounds to bf16 — forcing an
    f32 output element type pushes CPU XLA off the native path entirely
    (measured ~2x slower than f32 instead of ~6x faster on the bench
    container, see ``launch/env.py``)."""
    if a.dtype == jnp.bfloat16:
        return jax.lax.dot_general(a, b, _BDN)
    return jax.lax.dot_general(
        a, b, _BDN, preferred_element_type=jnp.float32).astype(a.dtype)


def _bdot32(a, b):
    """Batched grad matmul: f32 result whatever the compute dtype (the
    master-param SGD update never sees a bf16 gradient leaf)."""
    if a.dtype == jnp.bfloat16:
        return jax.lax.dot_general(a, b, _BDN).astype(jnp.float32)
    return jax.lax.dot_general(a, b, _BDN,
                               preferred_element_type=jnp.float32)


def _bT(t: jnp.ndarray) -> jnp.ndarray:
    """Transpose the per-user matrix of a (K, M, N) stack."""
    return jnp.swapaxes(t, 1, 2)


def conv_pool_fwd_k(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, Tuple]:
    """Batched twin of ``conv_pool_fwd``: x (K,B,H,W,C); w (K,3,3,C,O);
    b (K,O) -> a (K,B,H/2,W/2,O) + residuals (pat, eq, relu_m) with a
    leading K.  Values are bit-equal to ``vmap(conv_pool_fwd)`` at f32 —
    the per-user GEMM is the same contraction, just stacked on the batch
    dimension of one ``dot_general``."""
    k, bs, h, wd, c = x.shape
    o = w.shape[-1]
    pat = patches3x3(x.reshape(k * bs, h, wd, c)).reshape(
        k, bs * h * wd, 9 * c)
    z = _bdot(pat, w.reshape(k, 9 * c, o)).reshape(k, bs, h, wd, o)
    zw = z.reshape(k, bs, h // 2, 2, wd // 2, 2, o)
    pz = zw.max(axis=(3, 5))
    bb = b.reshape(k, 1, 1, 1, o).astype(x.dtype)
    a = jnp.maximum(pz + bb, 0.0)
    eqw = (zw == pz[:, :, :, None, :, None, :])
    cnt = eqw.sum(axis=(3, 5), keepdims=True)
    eq = jnp.where(eqw, 1.0 / cnt, 0.0).astype(x.dtype).reshape(
        k, bs, h, wd, o)
    relu_m = (pz + bb > 0).astype(x.dtype)
    return a, (pat, eq, relu_m)


def conv_pool_bwd_k(res: Tuple, w: jnp.ndarray, da: jnp.ndarray,
                    need_dx: bool) -> Tuple:
    """Batched twin of ``conv_pool_bwd``: da (K,B,H/2,W/2,O) ->
    (dw (K,3,3,C,O) f32, db (K,O) f32, dx (K,B,H,W,C) or None)."""
    pat, eq, relu_m = res
    k, bs, h, wd, o = eq.shape
    c = pat.shape[-1] // 9
    dp = da * relu_m                               # (K,B,H/2,W/2,O)
    db = dp.astype(jnp.float32).sum(axis=(1, 2, 3))
    dz = (eq.reshape(k, bs, h // 2, 2, wd // 2, 2, o)
          * dp[:, :, :, None, :, None, :]).reshape(k, bs * h * wd, o)
    dw = _bdot32(_bT(pat), dz).reshape(k, 3, 3, c, o)
    dx = None
    if need_dx:
        dpat = _bdot(dz, _bT(w.reshape(k, 9 * c, o)))
        dx = fold3x3(dpat.reshape(k * bs, h, wd, 9 * c)).reshape(
            k, bs, h, wd, c)
    return dw, db, dx


def fc_chain_fwd_k(flat: jnp.ndarray, params: dict
                   ) -> Tuple[jnp.ndarray, Tuple]:
    """Batched twin of ``fc_chain_fwd``: flat (K,B,F), params leaves
    stacked (K, ...) -> logits (K,B,classes) + (h1, h2)."""
    b1 = params["fc1"]["b"][:, None, :]
    b2 = params["fc2"]["b"][:, None, :]
    b3 = params["fc3"]["b"][:, None, :]
    h1 = jnp.maximum(_bdot(flat, params["fc1"]["w"]) + b1, 0.0)
    h2 = jnp.maximum(_bdot(h1, params["fc2"]["w"]) + b2, 0.0)
    logits = _bdot(h2, params["fc3"]["w"]) + b3
    return logits, (h1, h2)


def fc_chain_bwd_k(flat: jnp.ndarray, res: Tuple, params: dict,
                   dlogits: jnp.ndarray) -> Tuple[dict, jnp.ndarray]:
    """Batched twin of ``fc_chain_bwd``: per-user fc grads (f32) + dflat."""
    h1, h2 = res
    g3 = {"w": _bdot32(_bT(h2), dlogits),
          "b": dlogits.astype(jnp.float32).sum(axis=1)}
    dh2 = _bdot(dlogits, _bT(params["fc3"]["w"])) * (h2 > 0)
    g2 = {"w": _bdot32(_bT(h1), dh2),
          "b": dh2.astype(jnp.float32).sum(axis=1)}
    dh1 = _bdot(dh2, _bT(params["fc2"]["w"])) * (h1 > 0)
    g1 = {"w": _bdot32(_bT(flat), dh1),
          "b": dh1.astype(jnp.float32).sum(axis=1)}
    dflat = _bdot(dh1, _bT(params["fc1"]["w"]))
    return {"fc1": g1, "fc2": g2, "fc3": g3}, dflat


def forward_fwd_ref_k(params: dict, images: jnp.ndarray):
    """Stacked-cohort forward + residuals: params leaves (K, ...),
    images (K,B,H,W,C)."""
    a1, r1 = conv_pool_fwd_k(images, params["conv1"]["w"],
                             params["conv1"]["b"])
    a2, r2 = conv_pool_fwd_k(a1, params["conv2"]["w"], params["conv2"]["b"])
    flat = a2.reshape(a2.shape[0], a2.shape[1], -1)
    logits, rfc = fc_chain_fwd_k(flat, params)
    return logits, (r1, r2, flat, rfc)


def backward_ref_k(params: dict, residuals, dlogits: jnp.ndarray,
                   need_dx: bool = False):
    """Stacked-cohort hand-written VJP: dlogits (K,B,classes) -> per-user
    grads (conv grads f32 via ``_bdot32``)."""
    r1, r2, flat, rfc = residuals
    gfc, dflat = fc_chain_bwd_k(flat, rfc, params, dlogits)
    k, bs, h2_, w2_, o2 = r2[1].shape
    da2 = dflat.reshape(k, bs, h2_ // 2, w2_ // 2, o2)
    dw2, db2, da1 = conv_pool_bwd_k(r2, params["conv2"]["w"], da2, True)
    dw1, db1, dx = conv_pool_bwd_k(r1, params["conv1"]["w"], da1, need_dx)
    grads = {"conv1": {"w": dw1, "b": db1}, "conv2": {"w": dw2, "b": db2},
             **gfc}
    return grads, dx


def forward_ref(params: dict, images: jnp.ndarray) -> jnp.ndarray:
    """Full-model forward, bit-identical to ``cnn.forward_im2col`` at f32
    (pool-first reassociation only — see ``conv_pool_fwd``)."""
    a1, _ = conv_pool_fwd(images, params["conv1"]["w"], params["conv1"]["b"])
    a2, _ = conv_pool_fwd(a1, params["conv2"]["w"], params["conv2"]["b"])
    logits, _ = fc_chain_fwd(a2.reshape(a2.shape[0], -1), params)
    return logits


def forward_fwd_ref(params: dict, images: jnp.ndarray):
    """Forward + all residuals (the ``custom_vjp`` fwd rule)."""
    a1, r1 = conv_pool_fwd(images, params["conv1"]["w"], params["conv1"]["b"])
    a2, r2 = conv_pool_fwd(a1, params["conv2"]["w"], params["conv2"]["b"])
    flat = a2.reshape(a2.shape[0], -1)
    logits, rfc = fc_chain_fwd(flat, params)
    return logits, (r1, r2, flat, rfc)


def backward_ref(params: dict, residuals, dlogits: jnp.ndarray,
                 need_dx: bool = True):
    """Hand-written VJP: dlogits -> dparams (+ dimages when ``need_dx``).

    The training step (``ops.make_loss_grad``) passes ``need_dx=False`` —
    images carry no gradient there; the ``custom_vjp`` wrapper keeps the
    image cotangent for correctness (XLA DCEs it on this jnp path when
    unused, but the Pallas twin cannot rely on DCE inside a kernel)."""
    r1, r2, flat, rfc = residuals
    gfc, dflat = fc_chain_bwd(flat, rfc, params, dlogits)
    b2, h2, w2, o2 = r2[1].shape
    da2 = dflat.reshape(b2, h2 // 2, w2 // 2, o2)
    dw2, db2, da1 = conv_pool_bwd(r2, params["conv2"]["w"], da2, True)
    dw1, db1, dx = conv_pool_bwd(r1, params["conv1"]["w"], da1, need_dx)
    grads = {"conv1": {"w": dw1, "b": db1}, "conv2": {"w": dw2, "b": db2},
             **gfc}
    return grads, dx
