"""Jit-friendly wrapper: (B, S, H, D) GQA layout -> kernel layout."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bh


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (B, S, H, D); k, v: (B, S, KV, D).  Returns (B, S, H, D).

    Heads are folded into the batch grid dim; GQA group mapping happens in
    the kernel's k/v index_map (no repeated K/V materialization).
    """
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    # (B, S, H, D) -> (B*H, S, D) with h-major so b*H + h // G == b*KV + h//G
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, k.shape[1], D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, v.shape[1], D)
    out = flash_attention_bh(qf, kf, vf, group_size=G, causal=causal,
                             window=window, block_q=block_q, block_k=block_k,
                             interpret=interpret)
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
