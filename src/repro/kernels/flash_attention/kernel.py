"""Flash attention Pallas TPU kernel — online-softmax, VMEM-tiled.

Grid: (BH, num_q_blocks, num_k_blocks); the k dimension is innermost, so the
(acc, m, l) running state lives in VMEM scratch across k steps (TPU grids
execute minor-most sequentially).  Block sizes default to 128/256 — MXU-
aligned multiples of 128.  GQA is handled without materializing repeated
K/V: the k/v index_map folds the q-head onto its kv-head (b // group_size).

Masking covers causal and sliding-window attention; fully-masked k blocks
are skipped via @pl.when on the block index bound (the causal/window wavefront),
so the kernel does O(S·W) work for windowed attention, not O(S²).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale: float, causal: bool, window: int,
                 block_q: int, block_k: int, num_k_blocks: int, seq_q: int,
                 seq_k: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # absolute positions; q/k ends aligned (supports Sq < Sk decode windows)
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) \
        + (seq_k - seq_q)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # wavefront test: is any (q, k) pair in this block pair live?
    block_live = jnp.asarray(True)
    if causal:
        block_live &= (kj * block_k) <= (qi * block_q + block_q - 1 + (seq_k - seq_q))
    if window > 0:
        block_live &= ((qi * block_q + (seq_k - seq_q))
                       - (kj * block_k + block_k - 1) < window)

    @pl.when(block_live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        mask = jnp.ones((block_q, block_k), bool)
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
        m_ref[...] = m_cur

    @pl.when(kj == num_k_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-20)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_bh(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                       group_size: int = 1, causal: bool = True,
                       window: int = 0, block_q: int = 128,
                       block_k: int = 128, interpret: bool = False
                       ) -> jnp.ndarray:
    """q: (BH, Sq, D); k, v: (BKV, Sk, D) with BH = BKV * group_size."""
    BH, Sq, D = q.shape
    BKV, Sk, _ = k.shape
    assert BH == BKV * group_size, (BH, BKV, group_size)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    nq = math.ceil(Sq / block_q)
    nk = math.ceil(Sk / block_k)
    assert Sq % block_q == 0 and Sk % block_k == 0, "pad seq to block multiple"

    kernel = functools.partial(
        _attn_kernel, scale=D ** -0.5, causal=causal, window=window,
        block_q=block_q, block_k=block_k, num_k_blocks=nk,
        seq_q=Sq, seq_k=Sk)

    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda b, i, j, g=group_size: (b // g, j, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda b, i, j, g=group_size: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            # VMEM scratch: running accumulator / max / normalizer
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
