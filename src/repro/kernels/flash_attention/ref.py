"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True, window: int = 0) -> jnp.ndarray:
    """q: (BH, Sq, D); k, v: (BH, Sk, D) (kv heads already aligned).

    Returns (BH, Sq, D).  window > 0 additionally masks keys further than
    ``window`` positions behind the query (sliding-window attention).
    """
    Sq, Sk = q.shape[1], k.shape[1]
    scores = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32)
    scores = scores * (q.shape[-1] ** -0.5)
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)     # align ends (decode-style)
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= (qpos - kpos) < window
    scores = jnp.where(mask[None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bqk,bkd->bqd", probs, v)
