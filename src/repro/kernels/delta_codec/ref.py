"""Pure-jnp oracle for the int8 delta codec."""
from __future__ import annotations

import jax.numpy as jnp


def quantize_ref(x: jnp.ndarray, bits: int = 8):
    """x: (M, block) float -> (q int8 (M, block), scale f32 (M, 1))."""
    qmax = float(2 ** (bits - 1) - 1)
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / qmax
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def dequantize_ref(q: jnp.ndarray, scale: jnp.ndarray, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)
