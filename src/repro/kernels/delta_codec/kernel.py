"""int8 delta codec Pallas TPU kernel — blockwise absmax quantization.

Paper-adjacent hot spot: the OPT scheme transmits model snapshots (m_i in
eqs. 14–15); quantizing the *delta* vs the last-distributed global model to
int8 shrinks the payload ~3.6x (int8 + f32 scale per 512 lanes), which
directly scales down τ^{e_t} and makes more opportunistic windows affordable.

Grid: (num_tiles,) over rows of a (M, block) view; each tile quantizes
(tile_rows, block) in VMEM: absmax per row -> scale -> round/clip to int8.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 512          # default lanes per quantization group
TILE_ROWS = 256      # rows per grid step


def validate_block(block: int) -> int:
    """A quantization group width must be a positive lane-aligned multiple
    of 128 (the TPU lane count) — the sweepable ``HSFLConfig.codec_block``
    is validated through here before it reaches a kernel grid."""
    if block <= 0 or block % 128:
        raise ValueError(
            f"codec block width must be a positive multiple of 128 "
            f"(TPU lane alignment), got {block}")
    return block


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, x_ref, *, dtype):
    x_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[...]).astype(dtype)


def quantize_blocks(x: jnp.ndarray, interpret: bool = False):
    """x: (M, block) -> (q int8 (M, block), scales f32 (M, 1)).

    The group width is the trailing dimension of ``x`` (``BLOCK`` by
    default; any ``validate_block``-accepted width sweeps)."""
    M, B = x.shape
    validate_block(B)
    rows = min(TILE_ROWS, M)
    assert M % rows == 0
    return pl.pallas_call(
        _quant_kernel,
        grid=(M // rows,),
        in_specs=[pl.BlockSpec((rows, B), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((rows, B), lambda i: (i, 0)),
                   pl.BlockSpec((rows, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((M, B), jnp.int8),
                   jax.ShapeDtypeStruct((M, 1), jnp.float32)],
        interpret=interpret,
    )(x)


def dequantize_blocks(q: jnp.ndarray, scales: jnp.ndarray,
                      dtype=jnp.float32, interpret: bool = False):
    M, B = q.shape
    rows = min(TILE_ROWS, M)
    assert M % rows == 0
    return pl.pallas_call(
        functools.partial(_dequant_kernel, dtype=dtype),
        grid=(M // rows,),
        in_specs=[pl.BlockSpec((rows, B), lambda i: (i, 0)),
                  pl.BlockSpec((rows, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, B), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, B), dtype),
        interpret=interpret,
    )(q, scales)
