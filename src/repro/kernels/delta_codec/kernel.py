"""int8/int4 delta codec Pallas TPU kernel — blockwise absmax quantization.

Paper-adjacent hot spot: the OPT scheme transmits model snapshots (m_i in
eqs. 14–15); quantizing the *delta* vs the last-distributed global model to
int8 shrinks the payload ~3.6x (int8 + f32 scale per 512 lanes), which
directly scales down τ^{e_t} and makes more opportunistic windows affordable.
``bits=4`` halves the wire bytes again (values clip to ±7; storage stays
int8 — the byte accounting in ``ops.codec_ratio``/``payload_bytes`` counts
the packed 4-bit width) at ~16x the quantization noise: the sweepable rate
point of the eq. 15 overhead-vs-delay frontier (arXiv:2405.00681).

Grid: (num_tiles,) over rows of a (M, block) view; each tile quantizes
(tile_rows, block) in VMEM: absmax per row -> scale -> round/clip.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 512          # default lanes per quantization group
TILE_ROWS = 256      # rows per grid step
BITS = (4, 8)        # supported quantization bit depths


def validate_block(block: int) -> int:
    """A quantization group width must be a positive lane-aligned multiple
    of 128 (the TPU lane count) — the sweepable ``HSFLConfig.codec_block``
    is validated through here before it reaches a kernel grid."""
    if block <= 0 or block % 128:
        raise ValueError(
            f"codec block width must be a positive multiple of 128 "
            f"(TPU lane alignment), got {block}")
    return block


def validate_bits(bits: int) -> int:
    """The sweepable ``HSFLConfig.codec_bits`` must be a supported depth."""
    if bits not in BITS:
        raise ValueError(f"codec bit depth must be one of {BITS}, "
                         f"got {bits}")
    return bits


def _quant_kernel(x_ref, q_ref, s_ref, *, qmax):
    x = x_ref[...].astype(jnp.float32)
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / qmax
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, x_ref, *, dtype):
    # int8 q always widens through f32 (the scales' dtype) before the
    # *threaded* output cast — the f32 step is the accumulator, not policy
    x_ref[...] = (q_ref[...].astype(jnp.float32)  # analysis: ok=dtype-thread
                  * s_ref[...]).astype(dtype)


def quantize_blocks(x: jnp.ndarray, interpret: bool = False, bits: int = 8):
    """x: (M, block) -> (q int8 (M, block), scales f32 (M, 1)).

    The group width is the trailing dimension of ``x`` (``BLOCK`` by
    default; any ``validate_block``-accepted width sweeps).  ``bits``
    selects the quantization depth: 8 clips to ±127, 4 to ±7 (stored in
    the same int8 lanes; the wire-byte accounting lives in ``ops``)."""
    M, B = x.shape
    validate_block(B)
    qmax = float(2 ** (validate_bits(bits) - 1) - 1)
    rows = min(TILE_ROWS, M)
    assert M % rows == 0
    return pl.pallas_call(
        functools.partial(_quant_kernel, qmax=qmax),
        grid=(M // rows,),
        in_specs=[pl.BlockSpec((rows, B), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((rows, B), lambda i: (i, 0)),
                   pl.BlockSpec((rows, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((M, B), jnp.int8),
                   jax.ShapeDtypeStruct((M, 1), jnp.float32)],
        interpret=interpret,
    )(x)


def dequantize_blocks(q: jnp.ndarray, scales: jnp.ndarray,
                      dtype=jnp.float32, interpret: bool = False):
    M, B = q.shape
    rows = min(TILE_ROWS, M)
    assert M % rows == 0
    return pl.pallas_call(
        functools.partial(_dequant_kernel, dtype=dtype),
        grid=(M // rows,),
        in_specs=[pl.BlockSpec((rows, B), lambda i: (i, 0)),
                  pl.BlockSpec((rows, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, B), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, B), dtype),
        interpret=interpret,
    )(q, scales)
