"""Pytree-level delta codec: encode/decode parameter snapshots as int8 deltas.

``encode_delta(params, base)`` returns a compact payload; ``decode_delta``
reconstructs base + dequantized delta.  ``COMPRESS_RATIO`` is the asymptotic
byte ratio vs float32 (int8 + one f32 scale per 512 lanes = 0.2520);
``codec_ratio(n)`` is the exact ratio for an n-parameter payload including
the final partial block — this is what the HSFL sim's ``compress_ratio``
knob and the eq. (15) payload use when the codec is enabled.

The flatten helpers pad to the kernel's full contract: lane padding to
``BLOCK`` columns *and* row padding to a multiple of ``TILE_ROWS`` (needed
whenever the flat view exceeds one tile), so arbitrary pytrees — and stacked
``(K, ...)`` user pytrees in the fused HSFL round — can ride the Pallas
kernel.  Padding rows quantize to zero blocks and are sliced off on decode;
``payload_bytes``/``codec_ratio`` count only the ceil(n/BLOCK) real blocks.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.delta_codec.kernel import (BLOCK, TILE_ROWS,
                                              dequantize_blocks,
                                              quantize_blocks,
                                              validate_bits,
                                              validate_block)
from repro.models import module as m

COMPRESS_RATIO = (1.0 + 4.0 / BLOCK) / 4.0     # ≈ 0.2520 of f32 bytes (int8)


def _padded_rows(n: int, block: int = BLOCK) -> int:
    """Rows of the (M, block) view for n values, honouring the row tiling."""
    rows = max(1, math.ceil(n / block))
    if rows > TILE_ROWS:
        rows = math.ceil(rows / TILE_ROWS) * TILE_ROWS
    return rows


def _flatten(tree: Any, block: int = BLOCK) -> Tuple[jnp.ndarray, Any, int]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    flat = jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves])
    n = flat.size
    rows = _padded_rows(n, block)
    flat = jnp.pad(flat, (0, rows * block - n))
    return flat.reshape(rows, block), treedef, n


def _unflatten(flat: jnp.ndarray, like: Any) -> Any:
    leaves, treedef = jax.tree_util.tree_flatten(like)
    out, off = [], 0
    flat = flat.reshape(-1)
    for l in leaves:
        out.append(flat[off:off + l.size].reshape(l.shape).astype(l.dtype))
        off += l.size
    return jax.tree_util.tree_unflatten(treedef, out)


def stacked_flatten(stacked: Any, block: int = BLOCK
                    ) -> Tuple[jnp.ndarray, int]:
    """Stacked user pytree (leaves ``(K, ...)``) -> ``(K, M, block)`` + n.

    M is padded to a multiple of TILE_ROWS so the collapsed ``(K·M, block)``
    view always meets the kernel's grid contract regardless of K.
    """
    validate_block(block)
    leaves = jax.tree_util.tree_leaves(stacked)
    k = leaves[0].shape[0]
    flat = jnp.concatenate(
        [l.reshape(k, -1).astype(jnp.float32) for l in leaves], axis=1)
    n = flat.shape[1]
    rows = math.ceil(max(1, math.ceil(n / block)) / TILE_ROWS) * TILE_ROWS
    flat = jnp.pad(flat, ((0, 0), (0, rows * block - n)))
    return flat.reshape(k, rows, block), n


def stacked_unflatten(flat: jnp.ndarray, like_stacked: Any) -> Any:
    """Inverse of ``stacked_flatten`` (drops padding)."""
    leaves, treedef = jax.tree_util.tree_flatten(like_stacked)
    k = flat.shape[0]
    flat = flat.reshape(k, -1)
    out, off = [], 0
    for l in leaves:
        size = l.size // k
        out.append(flat[:, off:off + size].reshape(l.shape).astype(l.dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, out)


@partial(jax.jit, static_argnames=("interpret", "block", "bits"))
def encode_delta(params: Any, base: Any, interpret: bool = False,
                 block: int = BLOCK, bits: int = 8) -> Dict[str, jnp.ndarray]:
    delta = m.tree_sub(params, base)
    flat, _, n = _flatten(delta, block)
    q, s = quantize_blocks(flat, interpret=interpret, bits=bits)
    return {"q": q, "scales": s, "n": jnp.asarray(n, jnp.int32),
            "bits": jnp.asarray(bits, jnp.int32)}


@partial(jax.jit, static_argnames=("interpret",))
def decode_delta(payload: Dict[str, jnp.ndarray], base: Any,
                 interpret: bool = False) -> Any:
    flat = dequantize_blocks(payload["q"], payload["scales"],
                             interpret=interpret)
    delta = _unflatten(flat, base)
    return m.tree_add(base, delta)


def payload_bytes(payload: Dict[str, jnp.ndarray]) -> int:
    """True wire bytes: quantized lanes (packed to the codec bit depth) +
    f32 scale for the real blocks only (row padding added for the kernel
    tiling is not transmitted).  The group width and bit depth are read
    off the payload itself (pre-``bits`` payloads count as int8)."""
    block = payload["q"].shape[-1]
    bits = int(payload.get("bits", 8))
    blocks = math.ceil(int(payload["n"]) / block)
    return blocks * block * bits // 8 + blocks * 4


def codec_ratio(n: int, block: int = BLOCK, bits: int = 8) -> float:
    """Exact compressed/uncompressed byte ratio for an n-value payload:
    ceil(n/block) quantized blocks + one f32 scale each, over n float32
    bytes.

    ``block`` is the sweepable quantization group width
    (``HSFLConfig.codec_block``): smaller groups track the delta
    distribution tighter (less quantization noise) at a higher scale
    overhead.  ``bits`` (``HSFLConfig.codec_bits``) is the sweepable rate
    point: int4 halves the lane bytes again at ~16x the noise — together
    the eq. 15 overhead-vs-delay frontier of arXiv:2405.00681."""
    blocks = math.ceil(n / validate_block(block))
    return (blocks * block * validate_bits(bits) / 8.0 + blocks * 4) \
        / (4.0 * n)
