"""Pytree-level delta codec: encode/decode parameter snapshots as int8 deltas.

``encode_delta(params, base)`` returns a compact payload; ``decode_delta``
reconstructs base + dequantized delta.  ``COMPRESS_RATIO`` is the byte ratio
vs float32 (int8 + one f32 scale per 512 lanes = 0.2578) — this is what the
HSFL sim's ``compress_ratio`` knob and the eq. (15) payload use.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.delta_codec.kernel import (BLOCK, dequantize_blocks,
                                              quantize_blocks)
from repro.models import module as m

COMPRESS_RATIO = (1.0 + 4.0 / BLOCK) / 4.0     # ≈ 0.2520 of f32 bytes


def _flatten(tree: Any) -> Tuple[jnp.ndarray, Any, int]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    flat = jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves])
    n = flat.size
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, BLOCK), treedef, n


def _unflatten(flat: jnp.ndarray, like: Any) -> Any:
    leaves, treedef = jax.tree_util.tree_flatten(like)
    out, off = [], 0
    flat = flat.reshape(-1)
    for l in leaves:
        out.append(flat[off:off + l.size].reshape(l.shape).astype(l.dtype))
        off += l.size
    return jax.tree_util.tree_unflatten(treedef, out)


@partial(jax.jit, static_argnames=("interpret",))
def encode_delta(params: Any, base: Any, interpret: bool = False
                 ) -> Dict[str, jnp.ndarray]:
    delta = m.tree_sub(params, base)
    flat, _, n = _flatten(delta)
    q, s = quantize_blocks(flat, interpret=interpret)
    return {"q": q, "scales": s, "n": jnp.asarray(n, jnp.int32)}


@partial(jax.jit, static_argnames=("interpret",))
def decode_delta(payload: Dict[str, jnp.ndarray], base: Any,
                 interpret: bool = False) -> Any:
    flat = dequantize_blocks(payload["q"], payload["scales"],
                             interpret=interpret)
    delta = _unflatten(flat, base)
    return m.tree_add(base, delta)


def payload_bytes(payload: Dict[str, jnp.ndarray]) -> int:
    return int(payload["q"].size + payload["scales"].size * 4)
