from repro.kernels.delta_codec.ops import (COMPRESS_RATIO, codec_ratio,
                                           decode_delta, encode_delta,
                                           payload_bytes, stacked_flatten,
                                           stacked_unflatten)
from repro.kernels.delta_codec.ref import dequantize_ref, quantize_ref

__all__ = ["COMPRESS_RATIO", "codec_ratio", "decode_delta", "dequantize_ref",
           "encode_delta", "payload_bytes", "quantize_ref", "stacked_flatten",
           "stacked_unflatten"]
