from repro.kernels.delta_codec.ops import (COMPRESS_RATIO, decode_delta,
                                           encode_delta, payload_bytes)
from repro.kernels.delta_codec.ref import dequantize_ref, quantize_ref

__all__ = ["COMPRESS_RATIO", "decode_delta", "dequantize_ref", "encode_delta",
           "payload_bytes", "quantize_ref"]
