"""Pure-jnp oracle for the WKV6 recurrence (same math as models/rwkv6)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w, u, S0):
    """r,k,v,w: (B, S, H, D); u: (H, D); S0: (B, H, D, D) f32.

        y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
        S_t = diag(w_t) S_{t-1} + k_t v_t^T

    Returns (y (B,S,H,D) in r.dtype, S_final (B,H,D,D) f32)."""
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))

    def step(S, t):
        r_t, k_t, v_t, w_t = t
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhi,bhij->bhj", r_t, S + u[..., :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, wf))
    S, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), S
