"""WKV6 recurrence Pallas TPU kernel — chunked over the sequence.

Grid: (B*H, num_chunks); the chunk dim is minor, so the (D, D) state persists
in VMEM scratch across chunks.  Within a chunk the recurrence runs as a
fori_loop over timesteps — each step is VPU work on (D, D) = (64, 64) tiles,
with all chunk inputs already resident in VMEM (the whole point vs the XLA
scan, which round-trips the state through HBM each step).

The naive scan moves S (D² f32) HBM->VMEM->HBM per token: 2·4·D²·S bytes per
(b,h).  This kernel moves each input chunk once: 4·chunk·D·2 bytes — a
~2·D/4 = 32x memory-traffic reduction at D=64 (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, sfin_ref, s_ref, *,
                chunk: int, num_chunks: int):
    cj = pl.program_id(1)

    @pl.when(cj == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    u = u_ref[0].astype(jnp.float32)               # (D,)

    def step(t, _):
        r_t = r_ref[0, t].astype(jnp.float32)      # (D,)
        k_t = k_ref[0, t].astype(jnp.float32)
        v_t = v_ref[0, t].astype(jnp.float32)
        w_t = w_ref[0, t].astype(jnp.float32)
        S = s_ref[...]
        kv = k_t[:, None] * v_t[None, :]           # (D, D)
        y = jnp.sum(r_t[:, None] * (S + u[:, None] * kv), axis=0)
        y_ref[0, t] = y.astype(y_ref.dtype)
        s_ref[...] = w_t[:, None] * S + kv
        return ()

    jax.lax.fori_loop(0, chunk, step, ())

    @pl.when(cj == num_chunks - 1)
    def _emit_state():
        sfin_ref[0] = s_ref[...]


def wkv6_bh(r, k, v, w, u, *, chunk: int = 256, interpret: bool = False):
    """r,k,v,w: (BH, S, D); u: (BH, D).  Returns (y (BH,S,D), S (BH,D,D))."""
    BH, S, D = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, "pad sequence to chunk multiple"
    nc = S // chunk

    kernel = functools.partial(_wkv_kernel, chunk=chunk, num_chunks=nc)
    io_spec = pl.BlockSpec((1, chunk, D), lambda b, c: (b, c, 0))
    y, sfin = pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[io_spec, io_spec, io_spec, io_spec,
                  pl.BlockSpec((1, D), lambda b, c: (b, 0))],
        out_specs=[io_spec, pl.BlockSpec((1, D, D), lambda b, c: (b, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((BH, S, D), r.dtype),
                   jax.ShapeDtypeStruct((BH, D, D), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return y, sfin
