"""Jit wrapper: (B, S, H, D) layout -> kernel layout.  S0 must be zeros (the
kernel owns state init); non-zero S0 falls back to the reference scan."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.wkv6.kernel import wkv6_bh


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w, u, S0=None, chunk: int = 256, interpret: bool = False):
    """r,k,v,w: (B, S, H, D); u: (H, D).  Returns (y (B,S,H,D), S (B,H,D,D))."""
    B, S, H, D = r.shape
    fold = lambda a: a.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    uf = jnp.broadcast_to(u[None], (B, H, D)).reshape(B * H, D)
    y, sf = wkv6_bh(fold(r), fold(k), fold(v), fold(w), uf,
                    chunk=chunk, interpret=interpret)
    return (y.reshape(B, H, S, D).transpose(0, 2, 1, 3),
            sf.reshape(B, H, D, D))
