"""Channel model unit tests (Section II-A, eqs. 1-7)."""
import numpy as np
import pytest

from repro.core.channel import (ChannelParams, UAVFleet, channel_gain,
                                distance, elevation_deg, p_los, path_loss_db,
                                rate_bps)

P = ChannelParams()


def test_distance_eq1():
    pos = np.array([3.0, 4.0, 20.0 + 12.0])
    assert distance(pos, 20.0) == pytest.approx(13.0)


def test_elevation_bounds():
    pos = np.array([[100.0, 0.0, 80.0], [0.0, 0.0, 80.0]])
    th = elevation_deg(pos, 20.0)
    assert 0.0 <= th[0] < 90.0
    assert th[1] == pytest.approx(90.0)  # directly overhead


def test_plos_monotonic_in_elevation():
    th = np.linspace(1.0, 89.0, 50)
    pl = p_los(th, P)
    assert np.all(np.diff(pl) > 0)
    assert 0.0 < pl[0] < pl[-1] <= 1.0


def test_rate_decreases_with_distance():
    z = 50.0
    xs = np.linspace(50, 480, 20)
    pos = np.stack([xs, np.zeros_like(xs), np.full_like(xs, z)], axis=-1)
    r = rate_bps(pos, np.full(20, 3.0), P)
    assert np.all(r > 0)
    assert r[0] > r[-1]


def test_channel_gain_below_unity():
    pos = np.array([[200.0, 0.0, 60.0]])
    g = channel_gain(pos, np.array([3.0]), P)
    assert 0.0 < g[0] < 1.0


def test_path_loss_is_attenuation():
    pos = np.array([[100.0, 100.0, 40.0]])
    assert path_loss_db(pos, P)[0] < -60.0


def test_outage_chain_stationary():
    fleet = UAVFleet(2000, P, seed=3)
    draws = np.stack([fleet.outages() for _ in range(300)])
    marginal = draws.mean()
    assert abs(marginal - P.outage_prob) < 0.03
    # burstiness: P(bad_t | bad_{t-1}) should match the persistence knob
    prev, cur = draws[:-1].ravel(), draws[1:].ravel()
    stay = cur[prev].mean()
    assert abs(stay - P.outage_persistence) < 0.05


def test_fleet_stays_in_cell():
    fleet = UAVFleet(100, P, seed=0)
    for _ in range(50):
        fleet.move()
    rad = np.linalg.norm(fleet.pos[:, :2], axis=-1)
    assert np.all(rad <= P.cell_radius_m + 1e-6)
    assert np.all((fleet.pos[:, 2] >= P.uav_z_range[0])
                  & (fleet.pos[:, 2] <= P.uav_z_range[1]))
