"""Hypothesis property tests on system invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; property tests skipped")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import channel_lib as cl
from repro.core.aggregation import fedavg, fedasync_weight
from repro.core.latency import extra_allowance, snapshot_delay
from repro.core.transmission import OppTransmitter, scheduled_epochs
from repro.core.channel import ChannelParams, rate_bps
from repro.kernels.delta_codec.ref import dequantize_ref, quantize_ref

SETTINGS = dict(max_examples=40, deadline=None)


@given(e=st.integers(2, 64), b=st.integers(1, 16))
@settings(**SETTINGS)
def test_schedule_has_at_most_b_minus_1_intermediates(e, b):
    sch = scheduled_epochs(e, b)
    assert len(sch) <= max(0, b - 1)
    assert all(0 < s < e for s in sch)
    assert sch == sorted(set(sch))


@given(b=st.integers(1, 8),
       m=st.floats(1e4, 1e9),
       r=st.floats(1e3, 1e9))
@settings(**SETTINGS)
def test_budget_conservation(b, m, r):
    """Total opportunistic spend never exceeds the eq.-14 allowance."""
    tx = OppTransmitter(m, e=16, b=b, rate0_bps=r)
    budget0 = extra_allowance(b, m, r)
    rng = np.random.default_rng(0)
    for e_t in range(1, 16):
        tx.maybe_transmit(e_t, float(rng.uniform(r / 10, r * 10)), False, e_t)
    spent = sum(ev.delay_s for ev in tx.events if ev.kind == "opportunistic")
    assert spent <= budget0 + 1e-9
    assert tx.tau_extra >= -1e-9


@given(vals=st.lists(st.floats(-100, 100), min_size=1, max_size=6))
@settings(**SETTINGS)
def test_fedavg_convexity(vals):
    """FedAvg output lies within the per-leaf min/max of its inputs."""
    trees = [{"w": jnp.full((2,), v, jnp.float32)} for v in vals]
    out = fedavg(trees)
    assert float(out["w"][0]) <= max(vals) + 1e-4
    assert float(out["w"][0]) >= min(vals) - 1e-4


@given(s=st.integers(0, 50))
@settings(**SETTINGS)
def test_fedasync_weight_decreasing(s):
    assert fedasync_weight(s + 1) < fedasync_weight(s) <= 0.4


@given(x=st.floats(10, 500), y=st.floats(10, 500), z=st.floats(20, 80),
       k_db=st.floats(1.8, 5.0))
@settings(**SETTINGS)
def test_rate_nonnegative_finite(x, y, z, k_db):
    pos = np.array([[x, y, z]])
    r = rate_bps(pos, np.array([k_db]), ChannelParams())
    assert np.isfinite(r[0]) and r[0] >= 0


@given(scale=st.floats(1e-6, 1e3),
       seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_codec_error_bounded_by_half_scale(scale, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((4, 512)) * scale, jnp.float32)
    q, s = quantize_ref(x)
    xd = dequantize_ref(q, s)
    assert float(jnp.max(jnp.abs(xd - x))) <= float(jnp.max(s)) * 0.5 + 1e-9


@given(m=st.floats(1e3, 1e9), r1=st.floats(1e3, 1e9), r2=st.floats(1e3, 1e9))
@settings(**SETTINGS)
def test_snapshot_delay_monotone_in_rate(m, r1, r2):
    lo, hi = min(r1, r2), max(r1, r2)
    assert snapshot_delay(m, hi) <= snapshot_delay(m, lo)


@given(x=st.floats(-500, 500), y=st.floats(-500, 500), z=st.floats(20, 80),
       k_db=st.floats(1.8, 5.0))
@settings(**SETTINGS)
def test_numpy_jax_channel_core_agree(x, y, z, k_db):
    """The jax binding of channel_lib (the sweep engine's channel) matches
    the numpy host reference pointwise over the cell's position/K ranges."""
    pos = np.array([[x, y, z]])
    k = np.array([k_db])
    host = rate_bps(pos, k, ChannelParams())
    dev = np.asarray(cl.rate_bps(jnp.asarray(pos, jnp.float32),
                                 jnp.asarray(k, jnp.float32),
                                 ChannelParams(), xp=jnp))
    assert np.isfinite(dev[0]) and dev[0] >= 0
    np.testing.assert_allclose(dev, host, rtol=5e-4)


@given(prob=st.floats(0.0, 1.0), pers=st.floats(0.0, 1.0))
@settings(**SETTINGS)
def test_outage_transitions_are_probabilities(prob, pers):
    go, stay = cl.outage_transitions(prob, pers)
    assert 0.0 <= go <= 1.0 and 0.0 <= stay <= 1.0
