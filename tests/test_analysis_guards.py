"""Runtime-guard tests: compile budgets, transfer guards, leak checks.

The headline invariant: the sweep engine's ``SweepResult.n_programs``
accounting must equal the number of XLA programs actually compiled — a
silent recompile-per-round (the PR 2/PR 7 regression class) shows up here
as a budget overrun, not as a mysteriously slow CI run.  Both engines
must also run clean under ``jax.transfer_guard_host_to_device("disallow")``
after their explicit ``device_put`` staging.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis.guards import (CompileBudgetExceeded, CompileCounter,
                                   MemoryBudgetExceeded, compile_budget,
                                   engine_guard, leak_check, memory_budget,
                                   no_implicit_transfers)
from repro.core.hsfl import HSFLConfig, HSFLSimulation
from repro.core.sweep import SweepSpec, run_sweep


def tiny_base(**kw):
    base = dict(rounds=2, n_uavs=6, k_select=3, n_train=400, n_test=100,
                steps_per_epoch=2, local_epochs=2)
    base.update(kw)
    return HSFLConfig(**base)


# ---------------------------------------------------------------------------
# CompileCounter / compile_budget
# ---------------------------------------------------------------------------

def test_counter_sees_fresh_compile_not_cache_hit():
    def fresh_fn_alpha(x):
        return x * 3.0 + 1.0

    f = jax.jit(fresh_fn_alpha)
    x = jax.device_put(np.ones((8,), np.float32))
    with CompileCounter() as cc:
        f(x)
        f(x)                       # cache hit — must not count
    assert cc.count(match="fresh_fn_alpha") == 1
    with CompileCounter() as cc2:
        f(x)                       # still cached
    assert cc2.count(match="fresh_fn_alpha") == 0


def test_counter_sees_aot_compile():
    def fresh_fn_beta(x):
        return x - 2.0

    lowered = jax.jit(fresh_fn_beta).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32))
    with CompileCounter() as cc:
        lowered.compile()
    assert cc.count(match="fresh_fn_beta") == 1


def test_compile_budget_raises_on_overrun():
    def fresh_fn_gamma(x):
        return x + 5.0

    x = jax.device_put(np.ones((8,), np.float32))
    with pytest.raises(CompileBudgetExceeded):
        with compile_budget(0, match="fresh_fn_gamma"):
            jax.jit(fresh_fn_gamma)(x)


def test_compile_budget_passes_within_budget():
    def fresh_fn_delta(x):
        return x * 0.5

    x = jax.device_put(np.ones((8,), np.float32))
    with compile_budget(1, match="fresh_fn_delta") as cc:
        jax.jit(fresh_fn_delta)(x)
    assert cc.count(match="fresh_fn_delta") == 1


# ---------------------------------------------------------------------------
# transfer guard / leak check
# ---------------------------------------------------------------------------

def test_transfer_guard_blocks_implicit_h2d():
    f = jax.jit(lambda a: a + 1.0)
    host = np.ones((4,), np.float32)
    with no_implicit_transfers():
        with pytest.raises(Exception, match="[Dd]isallowed"):
            f(host)                          # implicit numpy->device
        out = f(jax.device_put(host))        # explicit staging is fine
    assert np.allclose(np.asarray(out), 2.0)


def test_transfer_guard_allows_result_reads():
    f = jax.jit(lambda a: a + 1.0)
    x = jax.device_put(np.ones((4,), np.float32))
    with no_implicit_transfers():             # h2d only: d2h is the
        val = np.asarray(f(x))                # documented sync boundary
    assert np.allclose(val, 2.0)


def test_leak_check_catches_escaped_tracer():
    leaked = []

    @jax.jit
    def bad(x):
        leaked.append(x)
        return x * 2.0

    with pytest.raises(Exception):
        with leak_check():
            bad(jax.device_put(np.float32(1.0)))


# ---------------------------------------------------------------------------
# engine-level guarantees
# ---------------------------------------------------------------------------

def test_sweep_compiles_exactly_n_programs_under_guard():
    """run_sweep under the combined guard: no implicit h2d transfers and
    exactly SweepResult.n_programs XLA round programs (name ``sim_one`` —
    the innermost scanned/vmapped body each group jit compiles)."""
    spec = SweepSpec(base=tiny_base(), seeds=(0, 1),
                     schemes=("opt", "async"), b=(1.0, 2.0))
    with engine_guard() as cc:
        res = run_sweep(spec)
    assert res.n_programs == 2                 # opt and async programs
    assert cc.count(match="sim_one") == res.n_programs


def test_sweep_recompile_budget_overrun_fails():
    """If a sweep compiles more round programs than its result claims,
    the budget context raises — the recompile-regression tripwire."""
    spec = SweepSpec(base=tiny_base(), seeds=(0,),
                     schemes=("opt", "async"))
    probe = run_sweep(spec)                    # how many programs it needs
    assert probe.n_programs == 2
    with pytest.raises(CompileBudgetExceeded):
        # fresh run_sweep rebuilds its closures -> recompiles every program
        with compile_budget(probe.n_programs - 1, match="sim_one"):
            run_sweep(spec)


def test_fused_engine_clean_under_guard():
    sim = HSFLSimulation(tiny_base())
    delayed = None
    with no_implicit_transfers():
        for t in (1, 2):
            log, delayed = sim.run_round(t, delayed)
    assert log.selected == 3


def test_fused_async_carry_clean_under_guard():
    sim = HSFLSimulation(tiny_base(scheme="async"))
    delayed = None
    with no_implicit_transfers():
        for t in (1, 2):
            log, delayed = sim.run_round(t, delayed)
    assert log.selected == 3


# ---------------------------------------------------------------------------
# memory_budget — the compiled-footprint cap
# ---------------------------------------------------------------------------

def _mm(x):
    return x @ x.T


def test_memory_budget_under_limit_passes():
    with memory_budget(64 * 2**20) as records:
        jax.jit(_mm)(jnp.ones((64, 64))).block_until_ready()
    assert any("_mm" in name for name, _ in records)


def test_memory_budget_overrun_raises_with_name():
    with pytest.raises(MemoryBudgetExceeded, match="_mm"):
        with memory_budget(1024, match="_mm"):
            jax.jit(_mm)(jnp.ones((128, 128))).block_until_ready()


def test_memory_budget_match_filters_programs():
    with memory_budget(1024, match="no_such_program") as records:
        jax.jit(_mm)(jnp.ones((128, 128))).block_until_ready()
    assert records == []


def test_memory_budget_credits_donation():
    """A donated in-place update reserves ~one buffer, not two."""
    n = 256 * 256          # 256 kB per f32 buffer
    fn = jax.jit(lambda x: x * 2.0, donate_argnums=(0,))
    # budget fits arg+out with aliasing credited, not without
    with memory_budget(int(n * 4 * 1.5), match="lambda"):
        fn(jnp.ones((n,))).block_until_ready()


def test_memory_budget_restores_compile_path():
    from jax._src.interpreters import pxla
    before = pxla.MeshComputation.compile
    with memory_budget(2**30):
        pass
    assert pxla.MeshComputation.compile is before


def test_fused_engine_round_fits_memory_budget():
    """The fused round at test scale stays under a generous cap — the
    runtime twin of the IR walker's liveness estimate."""
    sim = HSFLSimulation(tiny_base())
    with memory_budget(512 * 2**20):
        log, _ = sim.run_round(1, None)
    assert log.selected == 3
