"""Scheme registry + Experiment facade tests (PR 5).

Three contracts:

1. **Registry round-trip** — every registered scheme resolves to itself,
   unknown names fail loudly *listing the registry* (in ``get_scheme`` and
   through ``sweep.compile_spec``), and a scheme registered at test time is
   immediately runnable on the sweep engine with zero engine edits.
2. **Seeded equivalence** — the facade (``repro.api.Experiment``) is
   bit-equivalent to the deprecated entry points it shims, and the new
   ``sync``/``deadline`` schemes reproduce the host reference loop exactly
   on the fused engine (the same contract the paper schemes carry in
   ``tests/test_fused_round.py``).
3. **Scheme semantics** — under common random numbers on the sweep engine,
   ``sync`` arrivals dominate ``opt`` arrivals dominate ``deadline``
   arrivals (the deadline charges the eq. 14 overhead; sync waives τ_max).
"""
from dataclasses import replace

import numpy as np
import pytest

from repro.api import Experiment
from repro.core.hsfl import HSFLConfig, HSFLSimulation
from repro.core.schemes import (SCHEMES, Scheme, get_scheme,
                                register_scheme, registered_schemes)
from repro.core.sweep import SweepSpec, compile_spec
from repro.core.transmission import scheduled_epochs

PAPER_SCHEMES = ("opt", "sync", "async", "discard")


def tiny(**kw):
    base = dict(rounds=2, n_uavs=8, k_select=4, n_train=400, n_test=100,
                steps_per_epoch=2, local_epochs=4)
    base.update(kw)
    return HSFLConfig(**base)


# -- registry round-trip ------------------------------------------------------

def test_registry_roundtrip():
    names = registered_schemes()
    for want in PAPER_SCHEMES + ("deadline",):
        assert want in names
    for name in names:
        s = get_scheme(name)
        assert s.name == name
        assert get_scheme(s) is s               # instances pass through
        assert SCHEMES[name] is s               # canonical singleton


def test_get_scheme_unknown_lists_registry():
    with pytest.raises(ValueError) as ei:
        get_scheme("bogus")
    for name in registered_schemes():
        assert name in str(ei.value)


def test_compile_spec_unknown_scheme_lists_registry():
    """Satellite: an unknown scheme entry must fail at spec compilation
    with the registered names — not fall through to an engine branch."""
    spec = SweepSpec(base=tiny(), schemes=("bogus",))
    with pytest.raises(ValueError, match="registered schemes"):
        compile_spec(spec)
    spec2 = SweepSpec(base=tiny(), schemes=(("bogus", {"b": 2.0}),))
    with pytest.raises(ValueError, match="registered schemes"):
        compile_spec(spec2)


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        register_scheme("opt")(type("Dup", (Scheme,), {}))
    # aliasing an already-registered CLASS under a new name would
    # retroactively rename the registered singleton — must be rejected
    with pytest.raises(ValueError, match="subclass"):
        register_scheme("opt2")(get_scheme("opt").__class__)
    assert get_scheme("opt").name == "opt"
    assert "opt2" not in registered_schemes()


def test_with_pins_merges_and_preserves_identity():
    s = get_scheme("opt").with_pins(b=2.0)
    s2 = s.with_pins(tau_max=9.0, b=3.0)
    assert dict(s.pins) == {"b": 2.0}
    assert dict(s2.pins) == {"b": 3.0, "tau_max": 9.0}
    assert s2.name == "opt" and s2.uses_probes
    # pins ride the object into compile_spec (Scheme entries, no tuples)
    g = compile_spec(SweepSpec(base=tiny(), schemes=(s,)))[0]
    assert {c["b"] for c in g.cfgs} == {2.0}


def test_static_schedule_matches_legacy_rules():
    """OptScheme.static_schedule == the pre-registry HSFLSimulation logic:
    empty for b<=1, scheduled_epochs otherwise, override filtered to
    [1, e]; non-probing schemes never schedule (even with an override)."""
    opt = get_scheme("opt")
    for e in (2, 4, 6, 12):
        for b in (1, 2, 3, 6):
            want = tuple(scheduled_epochs(e, b)) if b > 1 else ()
            assert opt.static_schedule(e, b) == want, (e, b)
    assert opt.static_schedule(6, 2, override=(1, 5, 99)) == (1, 5)
    assert opt.static_schedule(6, 1, override=(1, 5)) == ()
    for name in ("discard", "async", "sync"):
        assert get_scheme(name).static_schedule(6, 3, override=(2,)) == ()
    assert get_scheme("deadline").static_schedule(6, 2) == \
        opt.static_schedule(6, 2)


def test_scheme_flags_and_slack():
    assert get_scheme("opt").supports_codec
    assert get_scheme("deadline").supports_codec
    assert not get_scheme("async").supports_codec
    assert get_scheme("async").carries_delayed
    assert get_scheme("sync").final_slack(3.5) == -np.inf
    assert get_scheme("deadline").final_slack(3.5) == 3.5
    for name in ("opt", "discard", "async"):
        assert get_scheme(name).final_slack(3.5) == 0.0


def test_register_custom_scheme_runs_on_sweep_engine():
    """The extension contract: a scheme registered *here* runs through the
    sweep engine (and the facade) without touching any engine code."""
    name = "_test_half_deadline"

    try:
        @register_scheme(name)
        class HalfDeadline(get_scheme("deadline").__class__):
            """Deadline variant charging half the eq. 14 allowance."""
            def final_slack(self, tau_extra0):
                return 0.5 * tau_extra0

        res = (Experiment(tiny(rounds=1)).with_scheme(name, b=2.0)
               .run(engine="sweep", mesh=None))
        m = res.groups[0].metrics
        assert res.groups[0].scheme == name
        assert np.all(np.isfinite(m["test_loss"]))
    finally:
        SCHEMES.pop(name, None)
    with pytest.raises(ValueError):
        get_scheme(name)


# -- new schemes: host-reference equivalence on the fused engine --------------

def _traj(cfg):
    sim = HSFLSimulation(cfg)
    delayed, logs = [], []
    for t in range(1, cfg.rounds + 1):
        log, delayed = sim.run_round(t, delayed)
        logs.append((log.selected, log.arrived_final, log.used_snapshot,
                     log.dropped, log.delayed, round(log.bytes_sent, 3)))
    return logs


@pytest.mark.parametrize("scheme,b", [("sync", 1), ("deadline", 2),
                                      ("deadline", 3)])
def test_new_schemes_fused_matches_host(scheme, b):
    cfg = tiny(rounds=3, local_epochs=6, scheme=scheme, b=b, seed=1)
    host = _traj(replace(cfg, use_fused_round=False))
    fused = _traj(replace(cfg, use_fused_round=True))
    assert host == fused, (scheme, host, fused)


def _one_round_inputs(K=2, e=2, dim=4, ncls=3):
    """Synthetic single-round inputs for build_fused_round with a linear
    model — the pattern of tests/test_fused_round's async tests."""
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.normal(size=(e, K, 1, 2, dim)), np.float32)
    ys = jnp.asarray(rng.integers(0, ncls, (e, K, 1, 2)))
    params = {"w": jnp.asarray(rng.normal(size=(dim, ncls)), np.float32)}
    chan = {
        "rates": jnp.full((e, K), 1e7, jnp.float32),
        "outages": jnp.zeros((e, K), bool),
        "payload_bits": jnp.full((K,), 8e6, jnp.float32),
        # the eq. 14 allowance: the quantity 'deadline' charges vs τ_max
        "tau_extra0": jnp.full((K,), 7.0, jnp.float32),
        "final_rate": jnp.full((K,), 4e6, jnp.float32),   # τ_f = 2 s
        "final_outage": jnp.zeros((K,), bool),
        "train_time": jnp.full((K,), 1.0, jnp.float32),
        "valid": jnp.ones((K,), bool),
    }
    return params, xs, ys, chan


def _linear_forward(params, x):
    return x @ params["w"]


@pytest.mark.parametrize("scheme,want_arrived,want_rescued", [
    # τ_max=9: train 1 + τ_f 2 fits for opt; deadline charges the 7 s
    # eq. 14 allowance (1+7+2 > 9) -> final dropped, snapshot rescues
    ("opt", True, False),
    ("deadline", False, True),
])
def test_deadline_final_arrival_semantics(scheme, want_arrived, want_rescued):
    from repro.core.fused_round import build_fused_round
    fn = build_fused_round(scheme=scheme, local_epochs=2, steps_per_epoch=1,
                           lr=0.1, tau_max=9.0, probe_epochs=(1,),
                           forward=_linear_forward)
    params, xs, ys, chan = _one_round_inputs()
    _, stats = fn(params, xs, ys, chan)
    assert bool(np.all(np.asarray(stats.arrived) == want_arrived)), scheme
    assert bool(np.all(np.asarray(stats.rescued) == want_rescued)), scheme
    # the probe at epoch 1 succeeded either way (τ ≈ 0.8 ≤ 7)
    assert np.asarray(stats.opp_sends).sum() == 2


def test_sync_waives_tau_max_but_not_outages():
    import jax.numpy as jnp
    from repro.core.fused_round import build_fused_round
    fn = build_fused_round(scheme="sync", local_epochs=2, steps_per_epoch=1,
                           lr=0.1, tau_max=9.0, probe_epochs=(),
                           forward=_linear_forward)
    params, xs, ys, chan = _one_round_inputs()
    # user 0: train_time alone blows τ_max; user 1: outage at the final
    chan["train_time"] = jnp.asarray([1e9, 1.0], jnp.float32)
    chan["final_outage"] = jnp.asarray([False, True])
    _, stats = fn(params, xs, ys, chan)
    assert list(np.asarray(stats.arrived)) == [True, False]
    assert list(np.asarray(stats.dropped)) == [False, True]


# -- scheme semantics under common random numbers (sweep engine) --------------

@pytest.fixture(scope="module")
def five_scheme_panel():
    ex = Experiment(tiny(rounds=3, local_epochs=6)).with_seeds(0, 1)
    for s in ("opt", "deadline", "sync", "discard", "async"):
        ex = ex.with_scheme(s, b=3.0)
    return ex.run(engine="sweep", mesh=None)


def test_all_registered_schemes_one_panel(five_scheme_panel):
    res = five_scheme_panel
    assert [g.scheme for g in res.groups] == \
        ["opt", "deadline", "sync", "discard", "async"]
    for g in res.groups:
        m = g.metrics
        assert np.all(np.isfinite(m["test_loss"]))
        assert np.all((m["test_acc"] >= 0) & (m["test_acc"] <= 1))
        assert np.all(m["arrived"] + m["dropped"] + m["delayed"]
                      + m["rescued"] <= m["selected"])


def test_arrival_dominance_sync_opt_deadline(five_scheme_panel):
    """Same channel/data streams across groups (common random numbers):
    waiving the deadline (sync) can only add arrivals over opt, charging
    the eq. 14 overhead (deadline) can only remove them."""
    by = {g.scheme: g.metrics["arrived"] for g in five_scheme_panel.groups}
    assert np.all(by["sync"] >= by["opt"])
    assert np.all(by["deadline"] <= by["opt"])
    # at b=3 the probes exist for opt/deadline only
    rescues = {g.scheme: g.metrics["rescued"].sum()
               for g in five_scheme_panel.groups}
    assert rescues["sync"] == rescues["discard"] == rescues["async"] == 0


# -- facade vs deprecated shims: seeded equivalence ---------------------------

def test_facade_fused_matches_run_hsfl_shim():
    for scheme, b in (("opt", 2.0), ("async", 1.0)):
        cfg = tiny(scheme=scheme, b=int(b))
        with pytest.warns(DeprecationWarning):
            from repro.core.hsfl import run_hsfl
            want = run_hsfl(cfg)
        got = Experiment(tiny()).with_scheme(scheme, b=b).run(engine="fused")
        assert [r.test_acc for r in got.rounds] == \
            [r.test_acc for r in want.rounds]
        assert [r.bytes_sent for r in got.rounds] == \
            [r.bytes_sent for r in want.rounds]


def test_facade_sweep_matches_run_sweep_shim():
    spec = SweepSpec(base=tiny(), seeds=(0,),
                     schemes=(("opt", {"b": 2.0}),
                              ("deadline", {"b": 2.0})))
    with pytest.warns(DeprecationWarning):
        from repro.core.sweep import run_sweep
        want = run_sweep(spec, mesh=None)
    got = Experiment.from_spec(spec).run(engine="sweep", mesh=None)
    for g1, g2 in zip(got.groups, want.groups):
        assert g1.scheme == g2.scheme
        for key in g1.metrics:
            np.testing.assert_array_equal(g1.metrics[key], g2.metrics[key],
                                          err_msg=key)
    # the builder form compiles to the same spec as the tuple form
    built = (Experiment(tiny()).with_scheme("opt", b=2.0)
             .with_scheme("deadline", b=2.0).to_spec())
    assert compile_spec(built)[0].cfgs == compile_spec(spec)[0].cfgs


def test_facade_on_device_matches_run_hsfl_on_device_shim():
    cfg = tiny(scheme="discard", b=1)
    with pytest.warns(DeprecationWarning):
        from repro.core.sweep import run_hsfl_on_device
        want = run_hsfl_on_device(cfg)
    got = Experiment(cfg).run(engine="sweep", mesh=None) \
        .groups[0].sim_log(0, 0)
    assert [r.test_acc for r in got.rounds] == \
        [r.test_acc for r in want.rounds]


def test_facade_loop_engine_is_host_reference():
    """engine='loop' must run the host OppTransmitter path (bit-identical
    to use_fused_round=False), not the fused program."""
    cfg = tiny(scheme="opt", b=2, seed=1)
    want = _traj(replace(cfg, use_fused_round=False))
    log = Experiment(cfg).with_scheme("opt", b=2.0).run(engine="loop")
    got = [(r.selected, r.arrived_final, r.used_snapshot, r.dropped,
            r.delayed, round(r.bytes_sent, 3)) for r in log.rounds]
    assert got == want


def test_facade_rejects_bad_requests():
    ex = Experiment(tiny())
    with pytest.raises(ValueError, match="engine"):
        ex.run(engine="warp")
    with pytest.raises(ValueError, match="sweep"):
        ex.with_scheme("opt").with_scheme("async").run(engine="fused")
    with pytest.raises(ValueError, match="sweep"):
        ex.with_axes(b=(1.0, 2.0)).run(engine="fused")
    with pytest.raises(ValueError, match="traced config axes"):
        ex.with_axes(rounds=(3,))
    # a fractional budget cannot silently round on the host engines
    with pytest.raises(ValueError, match="fractional"):
        ex.with_scheme("opt", b=2.5).run(engine="fused")
    # a from_spec experiment is frozen: builder calls would be dropped
    frozen = Experiment.from_spec(SweepSpec(base=tiny()))
    with pytest.raises(ValueError, match="from_spec"):
        frozen.with_scheme("deadline", b=2.0)
    with pytest.raises(ValueError, match="from_spec"):
        frozen.with_seeds(0, 1)


# -- Byzantine-robust aggregates (PR 9) ---------------------------------------

def test_robust_schemes_registered_as_opt_variants():
    for name in ("opt_trimmed", "opt_median", "opt_clip"):
        s = get_scheme(name)
        assert s.name == name
        # Alg. 2 semantics ride along: probes/rescue stay live
        assert s.uses_probes and not s.carries_delayed


def test_robust_primitives_hand_computed():
    import jax.numpy as jnp

    from repro.core.schemes import clipped_mean, masked_median, trimmed_mean

    # 4 slots, 3 valid; values chosen so every statistic is exact
    contrib = {"w": jnp.asarray([[1.0], [3.0], [2.0], [99.0]])}
    weights = jnp.asarray([1.0, 1.0, 1.0, 0.0])     # slot 3 invalid
    fb = {"w": jnp.asarray([-7.0])}
    # m=3, trim 0.25 -> g=0: trimmed mean == masked mean (no trimming)
    assert float(trimmed_mean(contrib, weights, fb)["w"][0]) == \
        pytest.approx(2.0)
    assert float(masked_median(contrib, weights, fb)["w"][0]) == 2.0
    # m=4 even: median averages the two middle ranks
    w4 = jnp.ones(4)
    assert float(masked_median(contrib, w4, fb)["w"][0]) == \
        pytest.approx(2.5)
    # m=4, g=1: the 99.0 outlier and the 1.0 low end are trimmed
    assert float(trimmed_mean(contrib, w4, fb)["w"][0]) == \
        pytest.approx(2.5)
    # m=0 falls back (never divides by zero)
    z = jnp.zeros(4)
    for fn in (trimmed_mean, masked_median, clipped_mean):
        assert float(fn(contrib, z, fb)["w"][0]) == -7.0


def test_robust_primitives_reject_huge_outlier():
    import jax.numpy as jnp

    from repro.core.schemes import clipped_mean, masked_median, trimmed_mean

    # a flip-style 1e37 outlier in 1 of 5 slots must not leak through
    contrib = {"w": jnp.asarray([[0.1], [0.2], [0.3], [1e37], [0.2]])}
    weights = jnp.ones(5)
    fb = {"w": jnp.zeros(1)}
    for fn in (trimmed_mean, masked_median, clipped_mean):
        out = float(fn(contrib, weights, fb)["w"][0])
        assert np.isfinite(out) and abs(out) < 1.0, fn.__name__
    # masked mean (the non-robust baseline) does leak it
    from repro.core.schemes import masked_mean
    assert float(masked_mean(contrib, weights, fb)["w"][0]) > 1e35


def test_robust_scheme_runs_on_sweep_engine_zero_edits():
    """The registry contract: a robust aggregate is just another Scheme —
    the sweep engine runs it with no engine edits, and its arrivals match
    opt's under common random numbers (selection/transport identical;
    only the aggregation rule differs)."""
    ex = Experiment(tiny()).with_seeds(0)
    for s in ("opt", "opt_trimmed", "opt_median"):
        ex = ex.with_scheme(s, b=2.0)
    res = ex.run(engine="sweep", mesh=None)
    by = {g.scheme: g.metrics for g in res.groups}
    assert np.array_equal(by["opt"]["arrived"], by["opt_trimmed"]["arrived"])
    assert np.array_equal(by["opt"]["arrived"], by["opt_median"]["arrived"])
    for name in ("opt_trimmed", "opt_median"):
        assert np.all(np.isfinite(by[name]["test_loss"]))
