"""Data partitioning + optimizer substrate tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import batches, make_digits, make_token_stream, partition
from repro.optim import adamw, apply_updates, clip_by_global_norm, cosine, sgd


def test_partition_iid_covers_all():
    ds = make_digits(600, seed=0)
    parts = partition(ds, 10, "iid")
    assert sum(len(p) for p in parts) == 600


def test_partition_noniid_two_classes():
    ds = make_digits(2000, seed=0)
    parts = partition(ds, 10, "noniid")
    for p in parts:
        assert len(np.unique(p.y)) <= 2     # [9]'s pathological split


def test_partition_imbalanced_skewed():
    ds = make_digits(3000, seed=0)
    parts = partition(ds, 10, "imbalanced")
    sizes = np.array([len(p) for p in parts])
    assert sizes.max() > 2 * sizes.min()    # size imbalance
    assert all(len(p) > 0 for p in parts)


def test_batches_shapes():
    ds = make_digits(105, seed=1)
    got = list(batches(ds, 10, seed=0))
    assert len(got) == 10
    assert got[0][0].shape == (10, 28, 28, 1)


def test_token_stream_next_token_alignment():
    ds = make_token_stream(4, 32, vocab=100, seed=0)
    assert ds.x.shape == (4, 32) and ds.y.shape == (4, 32)
    assert np.all(ds.x[:, 1:] == ds.y[:, :-1])


def test_sgd_step():
    opt = sgd(0.1)
    params = {"w": jnp.ones(3)}
    state = opt.init(params)
    grads = {"w": jnp.full(3, 2.0)}
    upd, state = opt.update(grads, state)
    new = apply_updates(params, upd)
    np.testing.assert_allclose(new["w"], 0.8, rtol=1e-6)


def test_adamw_converges_quadratic():
    opt = adamw(0.1)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        upd, state = opt.update(grads, state, params)
        params = apply_updates(params, upd)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_clip_by_global_norm():
    grads = {"w": jnp.full(4, 10.0)}
    clipped = clip_by_global_norm(grads, 1.0)
    norm = float(jnp.linalg.norm(clipped["w"]))
    assert norm == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule():
    fn = cosine(1.0, warmup=10, total=100)
    assert float(fn(0)) == 0.0
    assert float(fn(10)) == pytest.approx(1.0, abs=1e-5)
    assert float(fn(100)) == pytest.approx(0.0, abs=1e-3)
