"""utils/hlo.py text-analysis tests: collectives, dup ops, aliasing, stats.

The collective fixtures use the tuple-typed async form (``-start`` whose
result is a ``(operand, result)`` tuple consumed by ``-done``) that real
compiled HLO emits for overlapped collectives — the parser must count
each async pair once, off the ``-start`` line.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.utils.hlo import (aliased_parameters, collective_bytes,
                             collective_stats, compiled_memory_stats,
                             duplicate_op_counts, input_output_aliases)

ASYNC_HLO = """\
HloModule jit_round

ENTRY %main (p0: f32[16,128], p1: f32[128]) -> f32[64,128] {
  %p0 = f32[16,128]{1,0} parameter(0)
  %p1 = f32[128]{0} parameter(1)
  %ag-start = (f32[16,128]{1,0}, f32[64,128]{1,0}) all-gather-start(f32[16,128]{1,0} %p0), dimensions={0}
  %ag-done = f32[64,128]{1,0} all-gather-done((f32[16,128]{1,0}, f32[64,128]{1,0}) %ag-start)
  %ar-start = (f32[128]{0}, f32[128]{0}) all-reduce-start(f32[128]{0} %p1), to_apply=%add
  %ar-done = f32[128]{0} all-reduce-done((f32[128]{0}, f32[128]{0}) %ar-start)
  %rs = bf16[32,64]{1,0} reduce-scatter(bf16[128,64]{1,0} %x), dimensions={0}
}
"""


def test_collective_stats_counts_async_pairs_once():
    stats = collective_stats(ASYNC_HLO)
    assert stats["all-gather"]["count"] == 1
    assert stats["all-reduce"]["count"] == 1
    assert stats["reduce-scatter"]["count"] == 1
    assert stats["all-to-all"]["count"] == 0


def test_collective_bytes_tuple_types():
    stats = collective_stats(ASYNC_HLO)
    # the -start result type is the (operand, result) tuple: both shapes
    assert stats["all-gather"]["bytes"] == (16 * 128 + 64 * 128) * 4
    assert stats["all-reduce"]["bytes"] == 2 * 128 * 4
    assert stats["reduce-scatter"]["bytes"] == 32 * 64 * 2  # bf16 output
    assert collective_bytes(ASYNC_HLO) == sum(
        v["bytes"] for v in stats.values())


def test_collective_stats_empty_on_pure_compute():
    hlo = "ENTRY %main {\n  %d = f32[8,8]{1,0} dot(%a, %b)\n}\n"
    assert collective_bytes(hlo) == 0.0


def test_duplicate_op_counts_folds_ssa_suffixes():
    hlo = ("%fusion = f32[8]{0} fusion(%a)\n"
           "%fusion.1 = f32[8]{0} fusion(%b)\n"
           "%fusion.2 = f32[8]{0} fusion(%c)\n"
           "%dot.3 = f32[8]{0} dot(%d, %e)\n")
    top = dict(duplicate_op_counts(hlo))
    assert top["fusion"] == 3
    assert top["dot"] == 1


# ---------------------------------------------------------------------------
# input_output_alias header parsing
# ---------------------------------------------------------------------------

ALIAS_HEADER = ("HloModule jit_step, "
                "input_output_alias={ {0}: (0, {}, may-alias), "
                "{1}: (2, {}, must-alias) }, "
                "entry_computation_layout={(f32[4]{0})->f32[4]{0}}")


def test_input_output_aliases_parses_header():
    entries = input_output_aliases(ALIAS_HEADER)
    assert entries == [
        {"output_index": (0,), "parameter": 0, "kind": "may-alias"},
        {"output_index": (1,), "parameter": 2, "kind": "must-alias"},
    ]
    assert aliased_parameters(ALIAS_HEADER) == (0, 2)


def test_input_output_aliases_absent_means_all_dropped():
    assert input_output_aliases("HloModule jit_step\nENTRY %main {}") == []
    assert aliased_parameters("HloModule jit_step") == ()


def test_input_output_aliases_nested_output_index():
    hdr = "HloModule m, input_output_alias={ {1, 0}: (3, {}, may-alias) }"
    entries = input_output_aliases(hdr)
    assert entries == [
        {"output_index": (1, 0), "parameter": 3, "kind": "may-alias"}]


def test_aliases_round_trip_through_real_compile():
    fn = jax.jit(lambda x: x + 1.0, donate_argnums=(0,))
    compiled = fn.lower(jnp.ones((32,))).compile()
    assert aliased_parameters(compiled.as_text()) == (0,)


# ---------------------------------------------------------------------------
# compiled_memory_stats normalization
# ---------------------------------------------------------------------------

def test_compiled_memory_stats_real_compile():
    compiled = jax.jit(lambda x: x * 2.0).lower(jnp.ones((64,))).compile()
    mem = compiled_memory_stats(compiled)
    assert mem["argument_size_in_bytes"] >= 64 * 4
    assert mem["output_size_in_bytes"] >= 64 * 4
    assert all(isinstance(v, int) for v in mem.values())
    # absent fields (e.g. peak on CPU) normalize to 0, not AttributeError
    assert mem["peak_memory_in_bytes"] >= 0


def test_compiled_memory_stats_handles_none():
    class NoAnalysis:
        def memory_analysis(self):
            return None

    mem = compiled_memory_stats(NoAnalysis())
    assert set(mem.values()) == {0}


def test_compiled_memory_stats_partial_fields():
    class Partial:
        def memory_analysis(self):
            class S:
                argument_size_in_bytes = 128
                temp_size_in_bytes = 7
            return S()

    mem = compiled_memory_stats(Partial())
    assert mem["argument_size_in_bytes"] == 128
    assert mem["temp_size_in_bytes"] == 7
    assert mem["output_size_in_bytes"] == 0


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
