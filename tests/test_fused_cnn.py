"""Fused CNN training-step kernels (kernels/fused_cnn) — the PR-4 suite.

Pins, per the acceptance criteria:
- f32 value equivalence of the fused forward (xla custom-VJP path AND the
  Pallas kernels in interpret mode) against ``cnn.forward_im2col`` at the
  bit level;
- the hand-written VJP against ``jax.grad`` of the reference, including
  the pool tie-splitting semantics on real digits data (constant-zero
  backgrounds produce 4-way pool ties);
- the bf16 mixed-precision policy: f32 master params/grads and a loss
  curve within tolerance of the f32 run;
- donation: the fused round's params (and async straggler stack) buffers
  alias their outputs instead of being copied every round;
- the sweepable delta-codec block width.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hsfl import HSFLConfig, HSFLSimulation, model_compress_ratio
from repro.data.synthetic import make_digits
from repro.kernels.fused_cnn.ops import (ForwardPolicy, make_eval_forward,
                                         make_forward, make_loss_grad,
                                         make_stacked_epoch_fn,
                                         make_stacked_loss_grad)
from repro.models import cnn as cnn_mod
from repro.training.loss import cross_entropy

POLICIES = [ForwardPolicy(),                                  # xla / f32
            ForwardPolicy(kernel="pallas", interpret=True)]   # pallas / f32


@pytest.fixture(scope="module")
def fixture_data():
    params = cnn_mod.init_cnn(jax.random.PRNGKey(3))
    ds = make_digits(64, seed=0)
    # real digits: constant-zero backgrounds exercise the pool-tie and
    # dead-ReLU branches of the hand-written backward
    x = jnp.asarray(ds.x[:32])
    y = jnp.asarray(ds.y[:32])
    return params, x, y


@pytest.mark.parametrize("policy", POLICIES,
                         ids=lambda p: p.kernel)
def test_forward_bit_equivalence_f32(policy, fixture_data):
    params, x, _ = fixture_data
    want = cnn_mod.forward_im2col(params, x)
    got = make_forward(policy)(params, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    got_eval = make_eval_forward(policy)(params, x)
    np.testing.assert_array_equal(np.asarray(got_eval), np.asarray(want))


@pytest.mark.parametrize("policy", POLICIES,
                         ids=lambda p: p.kernel)
def test_custom_vjp_matches_autodiff(policy, fixture_data):
    params, x, y = fixture_data
    gref = jax.grad(
        lambda q: cross_entropy(cnn_mod.forward_im2col(q, x), y))(params)
    fwd = make_forward(policy)
    got = jax.grad(lambda q: cross_entropy(fwd(q, x), y))(params)
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_leaves_with_path(gref),
            jax.tree_util.tree_leaves_with_path(got)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   atol=2e-7, rtol=1e-5,
                                   err_msg=jax.tree_util.keystr(pa))


@pytest.mark.parametrize("policy", POLICIES + [ForwardPolicy(kernel="im2col")],
                         ids=lambda p: p.kernel)
def test_fused_loss_grad_matches_autodiff(policy, fixture_data):
    """make_loss_grad (the epoch-scan training step: closed-form softmax-CE
    cotangent + hand-written backward) vs jax.grad of the reference."""
    from repro.kernels.fused_cnn.ops import make_loss_grad
    params, x, y = fixture_data
    lref, gref = jax.value_and_grad(
        lambda q: cross_entropy(cnn_mod.forward_im2col(q, x), y))(params)
    loss, g = make_loss_grad(policy)(params, x, y)
    np.testing.assert_allclose(float(loss), float(lref), rtol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(gref),
                    jax.tree_util.tree_leaves(g)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   atol=2e-7, rtol=1e-5)


def test_pool_first_tie_gradients_match_on_synthetic_ties():
    """Windows with exact positive ties must split the pool gradient by
    1/count, like jax's reduce-max rule — pinned on a crafted input."""
    params = cnn_mod.init_cnn(jax.random.PRNGKey(0))
    x = jnp.ones((2, 28, 28, 1))                 # maximal tie pressure
    y = jnp.asarray([1, 7])
    gref = jax.grad(
        lambda q: cross_entropy(cnn_mod.forward_im2col(q, x), y))(params)
    fwd = make_forward(ForwardPolicy())
    got = jax.grad(lambda q: cross_entropy(fwd(q, x), y))(params)
    for a, b in zip(jax.tree_util.tree_leaves(gref),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   atol=2e-7, rtol=1e-5)


def test_policy_validation():
    with pytest.raises(ValueError, match="kernel"):
        make_forward(ForwardPolicy(kernel="cuda"))
    with pytest.raises(ValueError, match="precision"):
        make_forward(ForwardPolicy(precision="fp8"))
    with pytest.raises(ValueError, match="kernel"):
        HSFLSimulation(HSFLConfig(rounds=1, n_uavs=4, k_select=2,
                                  n_train=100, n_test=50, kernel="nope"))


# -- bf16 mixed precision -----------------------------------------------------

def _train(fwd, params, x, y, steps=150, lr=0.1, bs=32):
    def step(p, i):
        bx = jax.lax.dynamic_slice_in_dim(x, (i * bs) % (x.shape[0] - bs),
                                          bs)
        by = jax.lax.dynamic_slice_in_dim(y, (i * bs) % (x.shape[0] - bs),
                                          bs)
        g = jax.grad(lambda q: cross_entropy(fwd(q, bx), by))(p)
        p = jax.tree_util.tree_map(lambda w, gg: w - lr * gg, p, g)
        return p, cross_entropy(fwd(p, bx), by)

    params, losses = jax.lax.scan(step, params, jnp.arange(steps))
    return params, np.asarray(losses)


def test_bf16_policy_loss_curve_tracks_f32():
    """The mixed-precision step must train: master params/grads stay f32,
    and the loss curve stays within tolerance of the f32 run (the
    'paper-comparable accuracy' pin — bf16 is a compute dtype, not a
    different algorithm)."""
    params = cnn_mod.init_cnn(jax.random.PRNGKey(1))
    ds = make_digits(400, seed=2)
    x, y = jnp.asarray(ds.x), jnp.asarray(ds.y)
    f32 = make_forward(ForwardPolicy())
    bf16 = make_forward(ForwardPolicy(precision="bf16"))
    p32, l32 = _train(f32, params, x, y)
    pbf, lbf = _train(bf16, params, x, y)
    assert all(l.dtype == jnp.float32
               for l in jax.tree_util.tree_leaves(pbf))
    # both learn…
    assert l32[-5:].mean() < 0.2 * l32[0]
    assert lbf[-5:].mean() < 0.2 * lbf[0]
    # …and the bf16 curve tracks f32 within a small absolute band
    assert abs(float(lbf[-5:].mean() - l32[-5:].mean())) < 0.15, (
        lbf[-5:], l32[-5:])


def test_bf16_grads_are_f32_accumulated():
    params = cnn_mod.init_cnn(jax.random.PRNGKey(1))
    x = jnp.asarray(make_digits(16, seed=0).x)
    y = jnp.asarray(make_digits(16, seed=0).y)
    fwd = make_forward(ForwardPolicy(precision="bf16"))
    g = jax.grad(lambda q: cross_entropy(fwd(q, x), y))(params)
    assert all(l.dtype == jnp.float32 for l in jax.tree_util.tree_leaves(g))


# -- donation: no spurious copies of the round carries ------------------------

def test_fused_round_donates_params():
    """The opt round must consume its params buffer and alias it to the
    output (buffer-identity check, CPU donation is real in this jax)."""
    from repro.core.fused_round import build_fused_round
    fn = build_fused_round(scheme="opt", local_epochs=2, steps_per_epoch=1,
                           lr=0.01, tau_max=30.0, probe_epochs=(),
                           forward=ForwardPolicy())
    params = cnn_mod.init_cnn(jax.random.PRNGKey(0))
    ptr0 = params["fc1"]["w"].unsafe_buffer_pointer()
    K, e, steps, bs = 2, 2, 1, 4
    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.normal(size=(e, K, steps, bs, 28, 28, 1)),
                     jnp.float32)
    ys = jnp.asarray(rng.integers(0, 10, (e, K, steps, bs)))
    chan = {"rates": jnp.full((e, K), 1e6, jnp.float32),
            "outages": jnp.zeros((e, K), bool),
            "payload_bits": jnp.full((K,), 8e6, jnp.float32),
            "tau_extra0": jnp.zeros((K,), jnp.float32),
            "final_rate": jnp.full((K,), 1e6, jnp.float32),
            "final_outage": jnp.zeros((K,), bool),
            "train_time": jnp.full((K,), 1.0, jnp.float32),
            "valid": jnp.ones((K,), bool)}
    new_params, stats = fn(params, xs, ys, chan)
    jax.block_until_ready(new_params)
    assert params["fc1"]["w"].is_deleted(), \
        "params were not donated — the round copies the model every dispatch"
    assert new_params["fc1"]["w"].unsafe_buffer_pointer() == ptr0, \
        "donated params buffer was not aliased to the output"


def test_sweep_group_fn_donates_carry():
    """The sweep program must consume the DeviceSimCarry (params stack,
    fleet, stragglers) rather than copying it at the dispatch boundary."""
    from repro.core.sweep import (SweepSpec, _build_group_fn,
                                  _group_inputs, compile_spec)
    spec = SweepSpec(base=HSFLConfig(rounds=2, n_uavs=6, k_select=2,
                                     n_train=200, n_test=50,
                                     steps_per_epoch=1, local_epochs=2),
                     seeds=(0,), schemes=(("opt", {"b": 2.0}),))
    group = compile_spec(spec)[0]
    fn = _build_group_fn(group)
    carry0, round_keys, data, cfg_stack = _group_inputs(group, 2)
    leaf = carry0.params["fc1"]["w"]
    carry_out, metrics = fn(carry0, round_keys, data, cfg_stack)
    jax.block_until_ready(metrics)
    assert leaf.is_deleted(), "DeviceSimCarry was not donated"
    assert carry_out.params["fc1"]["w"].shape == leaf.shape


# -- the pallas policy end to end through a (tiny) fused round ----------------

def test_pallas_round_matches_xla_round():
    """kernel='pallas' must reproduce the default path through a real
    fused round: identical count trajectories, params within float noise
    (both backwards are the same mask algebra, modulo reassociation)."""
    def run(kernel):
        cfg = HSFLConfig(rounds=2, n_uavs=8, k_select=4, n_train=400,
                         n_test=100, steps_per_epoch=2, local_epochs=3,
                         scheme="opt", b=2, seed=0, kernel=kernel)
        sim = HSFLSimulation(cfg)
        delayed, logs = [], []
        for t in range(1, cfg.rounds + 1):
            log, delayed = sim.run_round(t, delayed)
            logs.append((log.selected, log.arrived_final, log.used_snapshot,
                         log.dropped, round(log.bytes_sent, 3)))
        return logs, sim.params

    logs_x, p_x = run("xla")
    logs_p, p_p = run("pallas")
    assert logs_x == logs_p
    diff = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
               for a, b in zip(jax.tree_util.tree_leaves(p_x),
                               jax.tree_util.tree_leaves(p_p)))
    assert diff < 1e-6, diff


# -- sweepable codec block width ----------------------------------------------

def test_codec_block_ratio_frontier():
    """Smaller quantization groups cost more scale overhead per wire byte:
    the overhead-vs-delay frontier of arXiv:2405.00681."""
    from repro.kernels.delta_codec.ops import codec_ratio
    n = 123_456
    r = [codec_ratio(n, b) for b in (128, 256, 512, 1024)]
    assert r == sorted(r, reverse=True)
    assert r[2] == codec_ratio(n)                  # default block is 512
    with pytest.raises(ValueError, match="128"):
        codec_ratio(n, 100)


def test_codec_block_quantize_roundtrip():
    from repro.kernels.delta_codec.kernel import (dequantize_blocks,
                                                  quantize_blocks)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    q, s = quantize_blocks(x, interpret=True)      # block from the shape
    xd = dequantize_blocks(q, s, interpret=True)
    assert q.shape == (256, 256) and s.shape == (256, 1)
    assert float(jnp.max(jnp.abs(xd - x))) <= float(jnp.max(s)) / 2 + 1e-7


def test_codec_block_is_group_static_and_threads_through():
    """codec_block forks a sweep group (program static) and changes the
    derived compress ratio end to end."""
    from repro.core.sweep import SweepSpec, compile_spec, run_sweep
    base = HSFLConfig(rounds=2, n_uavs=6, k_select=2, n_train=200,
                      n_test=50, steps_per_epoch=1, local_epochs=4,
                      use_delta_codec=True)
    r256 = model_compress_ratio(HSFLConfig(use_delta_codec=True,
                                           codec_block=256))
    r512 = model_compress_ratio(HSFLConfig(use_delta_codec=True))
    assert r256 > r512
    spec = SweepSpec(base=base, seeds=(0,),
                     schemes=(("opt", {"b": 2.0}),
                              ("opt", {"b": 2.0, "codec_block": 256})))
    groups = compile_spec(spec)
    assert [g.base.codec_block for g in groups] == [512, 256]
    res = run_sweep(spec, mesh=None)
    assert res.n_programs == 2                     # block width is a static
    for g in res.groups:
        assert np.all(np.isfinite(g.metrics["test_loss"]))


# -- PR 7: blocked stacked-cohort kernels (user axis inside the grid) ---------

STACKED_POLICIES = [ForwardPolicy(),                                # xla
                    ForwardPolicy(kernel="pallas", interpret=True)]


def _stack_fixture(k, bs=8, seed=0):
    """Stacked ``(K, ...)`` params + per-user digit shards (real digits:
    zero backgrounds exercise pool-tie and dead-ReLU mask branches)."""
    keys = jax.random.split(jax.random.PRNGKey(seed), k)
    params = jax.vmap(lambda kk: cnn_mod.init_cnn(kk))(keys)
    ds = make_digits(k * bs, seed=seed + 1)
    x = jnp.asarray(ds.x).reshape(k, bs, 28, 28, 1)
    y = jnp.asarray(ds.y).reshape(k, bs)
    return params, x, y


def _vmapped_autodiff_loss_grad(params, x, y):
    def one(p, bx, by):
        return jax.value_and_grad(
            lambda q: cross_entropy(cnn_mod.forward_im2col(q, bx), by))(p)

    return jax.vmap(one)(params, x, y)


@pytest.mark.parametrize("k", [1, 3, 10])
@pytest.mark.parametrize("policy", STACKED_POLICIES, ids=lambda p: p.kernel)
def test_stacked_forward_bit_equivalence_f32(policy, k):
    """Blocked forward (xla batched dot_general AND the grid-tiled Pallas
    kernels in interpret mode) is bit-equal to vmap(forward_im2col) at f32
    for cohort sizes 1, 3, and the paper's K=10."""
    from repro.kernels.fused_cnn.ops import _impl_stacked
    params, x, _ = _stack_fixture(k)
    want = cnn_mod.forward_im2col_k(params, x)
    fwd_res_k, _ = _impl_stacked(policy)
    got, _ = fwd_res_k(params, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("k", [1, 3, 10])
@pytest.mark.parametrize("policy", STACKED_POLICIES, ids=lambda p: p.kernel)
def test_stacked_loss_grad_matches_vmapped_autodiff(policy, k):
    params, x, y = _stack_fixture(k)
    lref, gref = _vmapped_autodiff_loss_grad(params, x, y)
    loss, g = make_stacked_loss_grad(policy)(params, x, y)
    assert loss.shape == (k,)
    np.testing.assert_allclose(np.asarray(loss), np.asarray(lref),
                               rtol=1e-5)
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_leaves_with_path(gref),
            jax.tree_util.tree_leaves_with_path(g)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   atol=2e-7, rtol=1e-5,
                                   err_msg=jax.tree_util.keystr(pa))


@pytest.mark.parametrize("policy", STACKED_POLICIES, ids=lambda p: p.kernel)
def test_stacked_pool_tie_and_dead_relu_gradients(policy):
    """Constant-ones images put every 2x2 pool window in a 4-way positive
    tie, and random conv2 signs leave dead-ReLU lanes: the blocked
    backward must split/zero exactly like jax's reduce-max rule."""
    k, bs = 3, 2
    params = jax.vmap(lambda kk: cnn_mod.init_cnn(kk))(
        jax.random.split(jax.random.PRNGKey(0), k))
    x = jnp.ones((k, bs, 28, 28, 1))
    y = jnp.tile(jnp.asarray([1, 7]), (k, 1))
    lref, gref = _vmapped_autodiff_loss_grad(params, x, y)
    loss, g = make_stacked_loss_grad(policy)(params, x, y)
    np.testing.assert_allclose(np.asarray(loss), np.asarray(lref),
                               rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(gref),
                    jax.tree_util.tree_leaves(g)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   atol=2e-7, rtol=1e-5)


def test_block_k_tiling_and_padding_match_full_cohort():
    """block_k tiles the grid (divisor) or pads the cohort (non-divisor:
    K=10 @ block_k=4 pads 2 phantom users) without changing the result;
    on the xla path the knob is an accepted no-op."""
    params, x, y = _stack_fixture(10, bs=4)
    want_l, want_g = make_stacked_loss_grad(ForwardPolicy())(params, x, y)
    for policy in (
            ForwardPolicy(kernel="pallas", interpret=True, block_k=5),
            ForwardPolicy(kernel="pallas", interpret=True, block_k=4),
            ForwardPolicy(block_k=5)):
        loss, g = make_stacked_loss_grad(policy)(params, x, y)
        np.testing.assert_allclose(np.asarray(loss), np.asarray(want_l),
                                   rtol=1e-5)
        for a, b in zip(jax.tree_util.tree_leaves(want_g),
                        jax.tree_util.tree_leaves(g)):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                       atol=2e-7, rtol=1e-5)
    with pytest.raises(ValueError, match="block_k"):
        make_stacked_loss_grad(ForwardPolicy(block_k=-1))


def test_batch_users_false_is_the_vmapped_step():
    """batch_users=False must be *bit-identical* to vmap(make_loss_grad):
    it IS the PR-4 composition, kept as the blocked path's in-tree twin."""
    params, x, y = _stack_fixture(4)
    loss_v, g_v = make_stacked_loss_grad(
        ForwardPolicy(batch_users=False))(params, x, y)
    loss_m, g_m = jax.vmap(make_loss_grad(ForwardPolicy()))(params, x, y)
    np.testing.assert_array_equal(np.asarray(loss_v), np.asarray(loss_m))
    for a, b in zip(jax.tree_util.tree_leaves(g_m),
                    jax.tree_util.tree_leaves(g_v)):
        np.testing.assert_array_equal(np.asarray(b), np.asarray(a))


def _epoch_fixture(k=3, steps=4, bs=10, seed=0):
    params, _, _ = _stack_fixture(k, bs=1, seed=seed)
    ds = make_digits(k * steps * bs, seed=seed + 2)
    xs = jnp.asarray(ds.x).reshape(k, steps, bs, 28, 28, 1)
    ys = jnp.asarray(ds.y).reshape(k, steps, bs)
    return params, xs, ys


def test_stacked_epoch_blocked_matches_vmapped_bitwise():
    """At f32 the blocked epoch (user axis in the kernel grid) and the
    vmapped epoch produce bit-identical parameter trajectories: the
    batched dot_generals keep f32 accumulation and contraction order."""
    params, xs, ys = _epoch_fixture()
    blocked = make_stacked_epoch_fn(ForwardPolicy(), 0.05)
    vmapped = make_stacked_epoch_fn(ForwardPolicy(batch_users=False), 0.05)
    pb, pv = blocked(params, xs, ys), vmapped(params, xs, ys)
    for a, b in zip(jax.tree_util.tree_leaves(pv),
                    jax.tree_util.tree_leaves(pb)):
        np.testing.assert_array_equal(np.asarray(b), np.asarray(a))


def test_bf16_stacked_epoch_master_roundtrip_and_loss():
    """The epoch-boundary bf16 scheme (cast once per epoch, f32 gradient
    accumulator, master - lr·Σg) must keep an f32 master and land within
    a small loss band of the f32 trajectory after several epochs — the
    regression pin for the master-param round-trip fix."""
    params, xs, ys = _epoch_fixture()
    x_eval = xs.reshape(xs.shape[0], -1, 28, 28, 1)
    y_eval = ys.reshape(ys.shape[0], -1)

    def cohort_loss(p):
        logits = cnn_mod.forward_im2col_k(p, x_eval)
        return float(jnp.mean(jax.vmap(cross_entropy)(logits, y_eval)))

    f32_fn = jax.jit(make_stacked_epoch_fn(ForwardPolicy(), 0.02))
    bf_fn = jax.jit(make_stacked_epoch_fn(
        ForwardPolicy(precision="bf16"), 0.02))
    p32 = pbf = params
    for _ in range(10):
        p32, pbf = f32_fn(p32, xs, ys), bf_fn(pbf, xs, ys)
    assert all(l.dtype == jnp.float32
               for l in jax.tree_util.tree_leaves(pbf))
    loss0, l32, lbf = cohort_loss(params), cohort_loss(p32), cohort_loss(pbf)
    assert l32 < 0.8 * loss0 and lbf < 0.8 * loss0, (loss0, l32, lbf)
    assert abs(lbf - l32) < 0.15, (l32, lbf)


def test_stacked_epoch_donates_stacked_carry():
    """The stacked ``(K, ...)`` parameter carry must donate through the
    blocked epoch: same buffer in and out, no per-epoch model copy."""
    params, xs, ys = _epoch_fixture(k=4, steps=2, bs=5)
    fn = jax.jit(make_stacked_epoch_fn(ForwardPolicy(), 0.01),
                 donate_argnums=(0,))
    leaf = params["fc1"]["w"]
    ptr0 = leaf.unsafe_buffer_pointer()
    out = fn(params, xs, ys)
    jax.block_until_ready(out)
    assert leaf.is_deleted(), "stacked carry was not donated"
    assert out["fc1"]["w"].unsafe_buffer_pointer() == ptr0, \
        "donated stacked buffer was not aliased to the output"
    assert out["fc1"]["w"].shape == (4,) + tuple(cnn_mod.init_cnn(
        jax.random.PRNGKey(0))["fc1"]["w"].shape)
