"""Fused CNN training-step kernels (kernels/fused_cnn) — the PR-4 suite.

Pins, per the acceptance criteria:
- f32 value equivalence of the fused forward (xla custom-VJP path AND the
  Pallas kernels in interpret mode) against ``cnn.forward_im2col`` at the
  bit level;
- the hand-written VJP against ``jax.grad`` of the reference, including
  the pool tie-splitting semantics on real digits data (constant-zero
  backgrounds produce 4-way pool ties);
- the bf16 mixed-precision policy: f32 master params/grads and a loss
  curve within tolerance of the f32 run;
- donation: the fused round's params (and async straggler stack) buffers
  alias their outputs instead of being copied every round;
- the sweepable delta-codec block width.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hsfl import HSFLConfig, HSFLSimulation, model_compress_ratio
from repro.data.synthetic import make_digits
from repro.kernels.fused_cnn import ref
from repro.kernels.fused_cnn.ops import (ForwardPolicy, make_eval_forward,
                                         make_forward)
from repro.models import cnn as cnn_mod
from repro.training.loss import cross_entropy

POLICIES = [ForwardPolicy(),                                  # xla / f32
            ForwardPolicy(kernel="pallas", interpret=True)]   # pallas / f32


@pytest.fixture(scope="module")
def fixture_data():
    params = cnn_mod.init_cnn(jax.random.PRNGKey(3))
    ds = make_digits(64, seed=0)
    # real digits: constant-zero backgrounds exercise the pool-tie and
    # dead-ReLU branches of the hand-written backward
    x = jnp.asarray(ds.x[:32])
    y = jnp.asarray(ds.y[:32])
    return params, x, y


@pytest.mark.parametrize("policy", POLICIES,
                         ids=lambda p: p.kernel)
def test_forward_bit_equivalence_f32(policy, fixture_data):
    params, x, _ = fixture_data
    want = cnn_mod.forward_im2col(params, x)
    got = make_forward(policy)(params, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    got_eval = make_eval_forward(policy)(params, x)
    np.testing.assert_array_equal(np.asarray(got_eval), np.asarray(want))


@pytest.mark.parametrize("policy", POLICIES,
                         ids=lambda p: p.kernel)
def test_custom_vjp_matches_autodiff(policy, fixture_data):
    params, x, y = fixture_data
    gref = jax.grad(
        lambda q: cross_entropy(cnn_mod.forward_im2col(q, x), y))(params)
    fwd = make_forward(policy)
    got = jax.grad(lambda q: cross_entropy(fwd(q, x), y))(params)
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_leaves_with_path(gref),
            jax.tree_util.tree_leaves_with_path(got)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   atol=2e-7, rtol=1e-5,
                                   err_msg=jax.tree_util.keystr(pa))


@pytest.mark.parametrize("policy", POLICIES + [ForwardPolicy(kernel="im2col")],
                         ids=lambda p: p.kernel)
def test_fused_loss_grad_matches_autodiff(policy, fixture_data):
    """make_loss_grad (the epoch-scan training step: closed-form softmax-CE
    cotangent + hand-written backward) vs jax.grad of the reference."""
    from repro.kernels.fused_cnn.ops import make_loss_grad
    params, x, y = fixture_data
    lref, gref = jax.value_and_grad(
        lambda q: cross_entropy(cnn_mod.forward_im2col(q, x), y))(params)
    loss, g = make_loss_grad(policy)(params, x, y)
    np.testing.assert_allclose(float(loss), float(lref), rtol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(gref),
                    jax.tree_util.tree_leaves(g)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   atol=2e-7, rtol=1e-5)


def test_pool_first_tie_gradients_match_on_synthetic_ties():
    """Windows with exact positive ties must split the pool gradient by
    1/count, like jax's reduce-max rule — pinned on a crafted input."""
    params = cnn_mod.init_cnn(jax.random.PRNGKey(0))
    x = jnp.ones((2, 28, 28, 1))                 # maximal tie pressure
    y = jnp.asarray([1, 7])
    gref = jax.grad(
        lambda q: cross_entropy(cnn_mod.forward_im2col(q, x), y))(params)
    fwd = make_forward(ForwardPolicy())
    got = jax.grad(lambda q: cross_entropy(fwd(q, x), y))(params)
    for a, b in zip(jax.tree_util.tree_leaves(gref),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   atol=2e-7, rtol=1e-5)


def test_policy_validation():
    with pytest.raises(ValueError, match="kernel"):
        make_forward(ForwardPolicy(kernel="cuda"))
    with pytest.raises(ValueError, match="precision"):
        make_forward(ForwardPolicy(precision="fp8"))
    with pytest.raises(ValueError, match="kernel"):
        HSFLSimulation(HSFLConfig(rounds=1, n_uavs=4, k_select=2,
                                  n_train=100, n_test=50, kernel="nope"))


# -- bf16 mixed precision -----------------------------------------------------

def _train(fwd, params, x, y, steps=150, lr=0.1, bs=32):
    def step(p, i):
        bx = jax.lax.dynamic_slice_in_dim(x, (i * bs) % (x.shape[0] - bs),
                                          bs)
        by = jax.lax.dynamic_slice_in_dim(y, (i * bs) % (x.shape[0] - bs),
                                          bs)
        g = jax.grad(lambda q: cross_entropy(fwd(q, bx), by))(p)
        p = jax.tree_util.tree_map(lambda w, gg: w - lr * gg, p, g)
        return p, cross_entropy(fwd(p, bx), by)

    params, losses = jax.lax.scan(step, params, jnp.arange(steps))
    return params, np.asarray(losses)


def test_bf16_policy_loss_curve_tracks_f32():
    """The mixed-precision step must train: master params/grads stay f32,
    and the loss curve stays within tolerance of the f32 run (the
    'paper-comparable accuracy' pin — bf16 is a compute dtype, not a
    different algorithm)."""
    params = cnn_mod.init_cnn(jax.random.PRNGKey(1))
    ds = make_digits(400, seed=2)
    x, y = jnp.asarray(ds.x), jnp.asarray(ds.y)
    f32 = make_forward(ForwardPolicy())
    bf16 = make_forward(ForwardPolicy(precision="bf16"))
    p32, l32 = _train(f32, params, x, y)
    pbf, lbf = _train(bf16, params, x, y)
    assert all(l.dtype == jnp.float32
               for l in jax.tree_util.tree_leaves(pbf))
    # both learn…
    assert l32[-5:].mean() < 0.2 * l32[0]
    assert lbf[-5:].mean() < 0.2 * lbf[0]
    # …and the bf16 curve tracks f32 within a small absolute band
    assert abs(float(lbf[-5:].mean() - l32[-5:].mean())) < 0.15, (
        lbf[-5:], l32[-5:])


def test_bf16_grads_are_f32_accumulated():
    params = cnn_mod.init_cnn(jax.random.PRNGKey(1))
    x = jnp.asarray(make_digits(16, seed=0).x)
    y = jnp.asarray(make_digits(16, seed=0).y)
    fwd = make_forward(ForwardPolicy(precision="bf16"))
    g = jax.grad(lambda q: cross_entropy(fwd(q, x), y))(params)
    assert all(l.dtype == jnp.float32 for l in jax.tree_util.tree_leaves(g))


# -- donation: no spurious copies of the round carries ------------------------

def test_fused_round_donates_params():
    """The opt round must consume its params buffer and alias it to the
    output (buffer-identity check, CPU donation is real in this jax)."""
    from repro.core.fused_round import build_fused_round
    fn = build_fused_round(scheme="opt", local_epochs=2, steps_per_epoch=1,
                           lr=0.01, tau_max=30.0, probe_epochs=(),
                           forward=ForwardPolicy())
    params = cnn_mod.init_cnn(jax.random.PRNGKey(0))
    ptr0 = params["fc1"]["w"].unsafe_buffer_pointer()
    K, e, steps, bs = 2, 2, 1, 4
    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.normal(size=(e, K, steps, bs, 28, 28, 1)),
                     jnp.float32)
    ys = jnp.asarray(rng.integers(0, 10, (e, K, steps, bs)))
    chan = {"rates": jnp.full((e, K), 1e6, jnp.float32),
            "outages": jnp.zeros((e, K), bool),
            "payload_bits": jnp.full((K,), 8e6, jnp.float32),
            "tau_extra0": jnp.zeros((K,), jnp.float32),
            "final_rate": jnp.full((K,), 1e6, jnp.float32),
            "final_outage": jnp.zeros((K,), bool),
            "train_time": jnp.full((K,), 1.0, jnp.float32),
            "valid": jnp.ones((K,), bool)}
    new_params, stats = fn(params, xs, ys, chan)
    jax.block_until_ready(new_params)
    assert params["fc1"]["w"].is_deleted(), \
        "params were not donated — the round copies the model every dispatch"
    assert new_params["fc1"]["w"].unsafe_buffer_pointer() == ptr0, \
        "donated params buffer was not aliased to the output"


def test_sweep_group_fn_donates_carry():
    """The sweep program must consume the DeviceSimCarry (params stack,
    fleet, stragglers) rather than copying it at the dispatch boundary."""
    from repro.core.sweep import (SweepSpec, _build_group_fn,
                                  _group_inputs, compile_spec)
    spec = SweepSpec(base=HSFLConfig(rounds=2, n_uavs=6, k_select=2,
                                     n_train=200, n_test=50,
                                     steps_per_epoch=1, local_epochs=2),
                     seeds=(0,), schemes=(("opt", {"b": 2.0}),))
    group = compile_spec(spec)[0]
    fn = _build_group_fn(group)
    carry0, round_keys, data, cfg_stack = _group_inputs(group, 2)
    leaf = carry0.params["fc1"]["w"]
    carry_out, metrics = fn(carry0, round_keys, data, cfg_stack)
    jax.block_until_ready(metrics)
    assert leaf.is_deleted(), "DeviceSimCarry was not donated"
    assert carry_out.params["fc1"]["w"].shape == leaf.shape


# -- the pallas policy end to end through a (tiny) fused round ----------------

def test_pallas_round_matches_xla_round():
    """kernel='pallas' must reproduce the default path through a real
    fused round: identical count trajectories, params within float noise
    (both backwards are the same mask algebra, modulo reassociation)."""
    def run(kernel):
        cfg = HSFLConfig(rounds=2, n_uavs=8, k_select=4, n_train=400,
                         n_test=100, steps_per_epoch=2, local_epochs=3,
                         scheme="opt", b=2, seed=0, kernel=kernel)
        sim = HSFLSimulation(cfg)
        delayed, logs = [], []
        for t in range(1, cfg.rounds + 1):
            log, delayed = sim.run_round(t, delayed)
            logs.append((log.selected, log.arrived_final, log.used_snapshot,
                         log.dropped, round(log.bytes_sent, 3)))
        return logs, sim.params

    logs_x, p_x = run("xla")
    logs_p, p_p = run("pallas")
    assert logs_x == logs_p
    diff = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
               for a, b in zip(jax.tree_util.tree_leaves(p_x),
                               jax.tree_util.tree_leaves(p_p)))
    assert diff < 1e-6, diff


# -- sweepable codec block width ----------------------------------------------

def test_codec_block_ratio_frontier():
    """Smaller quantization groups cost more scale overhead per wire byte:
    the overhead-vs-delay frontier of arXiv:2405.00681."""
    from repro.kernels.delta_codec.ops import codec_ratio
    n = 123_456
    r = [codec_ratio(n, b) for b in (128, 256, 512, 1024)]
    assert r == sorted(r, reverse=True)
    assert r[2] == codec_ratio(n)                  # default block is 512
    with pytest.raises(ValueError, match="128"):
        codec_ratio(n, 100)


def test_codec_block_quantize_roundtrip():
    from repro.kernels.delta_codec.kernel import (dequantize_blocks,
                                                  quantize_blocks)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    q, s = quantize_blocks(x, interpret=True)      # block from the shape
    xd = dequantize_blocks(q, s, interpret=True)
    assert q.shape == (256, 256) and s.shape == (256, 1)
    assert float(jnp.max(jnp.abs(xd - x))) <= float(jnp.max(s)) / 2 + 1e-7


def test_codec_block_is_group_static_and_threads_through():
    """codec_block forks a sweep group (program static) and changes the
    derived compress ratio end to end."""
    from repro.core.sweep import SweepSpec, compile_spec, run_sweep
    base = HSFLConfig(rounds=2, n_uavs=6, k_select=2, n_train=200,
                      n_test=50, steps_per_epoch=1, local_epochs=4,
                      use_delta_codec=True)
    r256 = model_compress_ratio(HSFLConfig(use_delta_codec=True,
                                           codec_block=256))
    r512 = model_compress_ratio(HSFLConfig(use_delta_codec=True))
    assert r256 > r512
    spec = SweepSpec(base=base, seeds=(0,),
                     schemes=(("opt", {"b": 2.0}),
                              ("opt", {"b": 2.0, "codec_block": 256})))
    groups = compile_spec(spec)
    assert [g.base.codec_block for g in groups] == [512, 256]
    res = run_sweep(spec, mesh=None)
    assert res.n_programs == 2                     # block width is a static
    for g in res.groups:
        assert np.all(np.isfinite(g.metrics["test_loss"]))
