"""Fault-tolerant FL aggregation service (serving/fl_server).

The two contracts PR 6 pins:

  1. *Trajectory*: fault-free (and recoverable-fault) serving reproduces
     the host reference loop bit-for-bit — same per-round
     arrivals/rescues/bytes, same final global model.
  2. *Durability*: a server killed at any round phase resumes from the
     latest committed msgpack checkpoint and finishes with the same
     global model as an uninterrupted run on the same seed.
"""
import json
import os

import jax
import numpy as np
import pytest

from repro.api import Experiment
from repro.core.faults import (BackoffPolicy, FaultPlan, RetriesExhausted,
                               UploadTimeout, retry_call)
from repro.core.hsfl import HSFLConfig, HSFLSimulation
from repro.serving.fl_server import (ClientRegistry, FLServer, RoundInbox,
                                     UploadMsg, run_with_restarts)


def small_cfg(**kw):
    base = dict(scheme="opt", b=2, rounds=3, n_uavs=8, k_select=4,
                n_train=400, n_test=100, steps_per_epoch=2, local_epochs=4,
                use_fused_round=False, seed=0)
    base.update(kw)
    return HSFLConfig(**base)


def assert_trees_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.fixture(scope="module")
def clean_opt():
    """The uninterrupted fault-free serve on the opt scheme."""
    server = FLServer(small_cfg())
    log = server.serve()
    return server, log


# ---------------------------------------------------------------------------
# contract 1: trajectory parity with the loop engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme,b", [("opt", 2), ("async", 1),
                                      ("discard", 1)])
def test_fault_free_serving_matches_loop_engine(scheme, b):
    cfg = small_cfg(scheme=scheme, b=b, rounds=2)
    ref = HSFLSimulation(cfg)
    ref_log = ref.run()
    server = FLServer(cfg)
    log = server.serve()
    for a, s in zip(ref_log.rounds, log.rounds):
        assert (a.selected, a.arrived_final, a.used_snapshot,
                a.delayed, a.dropped) == \
               (s.selected, s.arrived_final, s.used_snapshot,
                s.delayed, s.dropped)
        assert a.bytes_sent == pytest.approx(s.bytes_sent)
        assert a.test_acc == s.test_acc
    assert_trees_equal(ref.params, server.params)


def test_serve_matches_experiment_loop_engine(clean_opt):
    _, log = clean_opt
    ref_log = Experiment(small_cfg()).with_scheme("opt", b=2) \
        .run(engine="loop")
    for a, s in zip(ref_log.rounds, log.rounds):
        assert (a.arrived_final, a.used_snapshot, a.dropped) == \
               (s.arrived_final, s.used_snapshot, s.dropped)
        assert a.test_acc == s.test_acc


def test_experiment_serve_facade(clean_opt):
    clean_server, _ = clean_opt
    server = Experiment(small_cfg()).with_scheme("opt", b=2).serve()
    log = server.serve()
    assert len(log.rounds) == 3
    assert_trees_equal(clean_server.params, server.params)


# ---------------------------------------------------------------------------
# duplicates / corruption are provably recoverable
# ---------------------------------------------------------------------------

def test_duplicate_uploads_are_idempotent(clean_opt):
    clean_server, _ = clean_opt
    server = FLServer(small_cfg(),
                      fault_plan="dup@r1:c*x2; dup@r2:c*; dup@r3:c*")
    log = server.serve()
    assert sum(r.duplicates_rejected for r in log.rounds) > 0
    # aggregation output is identical with and without the duplicates
    assert_trees_equal(clean_server.params, server.params)
    for a, s in zip(clean_server.log.rounds, log.rounds):
        assert a.test_acc == s.test_acc
        assert (a.arrived_final, a.used_snapshot) == \
               (s.arrived_final, s.used_snapshot)


def test_corrupt_payloads_refused_and_retried(clean_opt):
    clean_server, _ = clean_opt
    server = FLServer(small_cfg(), fault_plan="corrupt@r1:c*; corrupt@r2:c*")
    log = server.serve()
    assert sum(r.corrupt_rejected for r in log.rounds) > 0
    assert sum(r.retries for r in log.rounds) > 0
    assert_trees_equal(clean_server.params, server.params)


# ---------------------------------------------------------------------------
# contract 2: kill-and-restart chaos
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("phase", ["train", "close", "checkpoint"])
def test_server_killed_midround_resumes_bit_compatibly(tmp_path, clean_opt,
                                                       phase):
    clean_server, clean_log = clean_opt
    server, restarts = run_with_restarts(
        small_cfg(), ckpt_dir=str(tmp_path / phase),
        fault_plan=f"crash@r2:{phase}")
    assert restarts == 1
    assert len(server.log.rounds) == 3
    assert_trees_equal(clean_server.params, server.params)
    for a, s in zip(clean_log.rounds, server.log.rounds):
        assert a.test_acc == s.test_acc


def test_crash_during_checkpoint_leaves_no_committed_garbage(tmp_path,
                                                             clean_opt):
    """A 'checkpoint' crash writes step dir + payload but no COMMIT; the
    resumed server must fall back to the previous committed step."""
    from repro.checkpoint import latest_step
    d = str(tmp_path / "ck")
    plan = FaultPlan.parse("crash@r2:checkpoint")
    first = FLServer(small_cfg(), ckpt_dir=d, fault_plan=plan)
    from repro.core.faults import ServerCrash
    with pytest.raises(ServerCrash):
        first.serve()
    # the half-written step 2 exists on disk but is invisible
    assert os.path.isdir(os.path.join(d, "2"))
    assert not os.path.exists(os.path.join(d, "2", "COMMIT"))
    assert latest_step(d) == 1
    server = FLServer(small_cfg(), ckpt_dir=d, fault_plan=plan,
                      skip_crashes={(2, "checkpoint")})
    assert server.round == 1          # resumed from the committed step
    server.serve()
    clean_server, _ = clean_opt
    assert_trees_equal(clean_server.params, server.params)


def test_resume_after_completion_is_a_noop(tmp_path, clean_opt):
    d = str(tmp_path / "done")
    FLServer(small_cfg(), ckpt_dir=d).serve()
    server = FLServer(small_cfg(), ckpt_dir=d)
    assert server.round == 3
    log = server.serve()              # already complete
    assert len(log.rounds) == 3
    clean_server, _ = clean_opt
    assert_trees_equal(clean_server.params, server.params)


# ---------------------------------------------------------------------------
# degradation to the scheme's rescue/delayed path
# ---------------------------------------------------------------------------

def test_drop_fault_degrades_to_scheme_path():
    cfg = small_cfg(rounds=2)
    server = FLServer(cfg, fault_plan="drop@r1:c*; drop@r2:c*")
    log = server.serve()
    # black-holed finals exhaust their retries ...
    assert sum(r.retries for r in log.rounds) > 0
    for r in log.rounds:
        assert r.arrived_final == 0
        # ... and every scheduled client resolves through the scheme path
        assert r.used_snapshot + r.dropped + r.delayed == r.selected


def test_delayed_upload_rejected_as_stale_then_rescued():
    cfg = small_cfg(rounds=2)
    clean = FLServer(cfg)
    clean_log = clean.serve()
    server = FLServer(cfg, fault_plan="delay@r1:c*; delay@r2:c*")
    log = server.serve()
    lost = sum(r.arrived_final for r in clean_log.rounds) \
        - sum(r.arrived_final for r in log.rounds)
    assert lost > 0
    assert sum(r.stale_rejected for r in log.rounds) == lost
    # opt degrades gracefully: snapshots rescue what the delay lost
    assert sum(r.used_snapshot for r in log.rounds) >= \
        sum(r.used_snapshot for r in clean_log.rounds)


def test_quorum_holds_round_open_for_late_uploads():
    cfg = small_cfg(rounds=2)
    clean = FLServer(cfg)
    clean.serve()
    server = FLServer(cfg, fault_plan="delay@r1:c*; delay@r2:c*",
                      quorum=1.0)
    log = server.serve()
    assert sum(r.late_accepted for r in log.rounds) > 0
    assert not all(r.quorum_met for r in log.rounds)
    # with every late upload admitted the trajectory is fault-free again
    assert_trees_equal(clean.params, server.params)


# ---------------------------------------------------------------------------
# registry: join/drop mid-training, staleness
# ---------------------------------------------------------------------------

def test_registry_join_and_drop_mid_training(tmp_path):
    d = str(tmp_path / "reg")
    cfg = small_cfg()
    server = FLServer(cfg, ckpt_dir=d, initial_clients=range(4))
    r1 = server.step()
    assert r1.selected + r1.unregistered_skipped >= r1.selected
    server.register_client(6)
    server.drop_client(0)
    assert server.registry.schedulable(6, 2)
    assert not server.registry.schedulable(0, 2)
    server.step()
    server.step()
    # registry state survives checkpoint/resume
    resumed = FLServer(cfg, ckpt_dir=d, initial_clients=range(4))
    assert resumed.round == 3
    assert resumed.registry.schedulable(6, 4)
    assert not resumed.registry.schedulable(0, 4)


def test_registry_staleness_tracking():
    reg = ClientRegistry(range(3))
    assert reg.staleness(0, 5) is None
    reg.record_upload(0, 2)
    assert reg.staleness(0, 5) == 3
    rec = reg.register(7, current_round=4)
    assert rec.joined_round == 5
    assert not reg.schedulable(7, 4) and reg.schedulable(7, 5)


def test_metrics_jsonl(tmp_path):
    from repro.serving.fl_server import METRICS_SCHEMA
    d = str(tmp_path / "m")
    FLServer(small_cfg(rounds=2), ckpt_dir=d,
             fault_plan="dup@r1:c*").serve()
    rows = [json.loads(l) for l in open(os.path.join(d, "metrics.jsonl"))]
    assert [r["round"] for r in rows] == [1, 2]
    for key in ("arrived_final", "used_snapshot", "duplicates_rejected",
                "stale_rejected", "corrupt_rejected", "retries",
                "bytes_sent", "test_acc", "scheme", "registered",
                "backoff_s", "chunks_sent", "chunks_retransmitted",
                "chunks_recovered", "transfers_incomplete", "parity_bytes"):
        assert key in rows[0], key
    assert all(r["schema"] == METRICS_SCHEMA for r in rows)
    # transport disabled: the chunk counters stay zero
    assert all(r["chunks_sent"] == 0 for r in rows)


def test_transport_metrics_and_summary(tmp_path):
    from repro.core.transport import TransportConfig
    d = str(tmp_path / "mt")
    server = FLServer(small_cfg(rounds=2), ckpt_dir=d,
                      transport=TransportConfig(chunk_bytes=2048))
    server.serve()
    rows = [json.loads(l) for l in open(os.path.join(d, "metrics.jsonl"))]
    assert sum(r["chunks_sent"] for r in rows) > 0
    assert sum(r["parity_bytes"] for r in rows) > 0
    s = server.log.summary()
    for key in ("chunks_sent", "chunks_retransmitted", "chunks_recovered",
                "transfers_incomplete"):
        assert key in s, key
    assert s["chunks_sent"] == sum(r["chunks_sent"] for r in rows)


def test_transport_crash_resume_round_trips_roundlog(tmp_path):
    """The lossy-wire counters ride the checkpoint aux round-trip: a
    crashed transport-enabled server must restore its RoundLog history
    (new fields included) and finish the run."""
    from repro.core.transport import TransportConfig
    d = str(tmp_path / "tc")
    server, restarts = run_with_restarts(
        small_cfg(rounds=3), ckpt_dir=d, fault_plan="crash@r2:close",
        transport=TransportConfig(chunk_bytes=2048))
    assert restarts == 1
    assert len(server.log.rounds) == 3
    assert sum(r.chunks_sent for r in server.log.rounds) > 0


# ---------------------------------------------------------------------------
# inbox + wire-format units
# ---------------------------------------------------------------------------

def test_inbox_classification_and_snapshot_overwrite():
    tree = {"w": np.arange(4, dtype=np.float32)}
    inbox = RoundInbox(round_id=3)
    final = UploadMsg.build(1, 3, "final", 1, tree, 64.0)
    assert inbox.offer(final) == "accepted"
    assert inbox.offer(final) == "duplicate"
    assert inbox.duplicates == 1
    stale = UploadMsg.build(1, 2, "final", 2, tree, 64.0)
    assert inbox.offer(stale) == "stale"
    # snapshots: re-delivery of the same seq is a duplicate, a newer seq
    # overwrites (Alg. 2: previous snapshot is overwritten)
    s1 = UploadMsg.build(2, 3, "snapshot", 1, {"w": np.zeros(4, np.float32)},
                         64.0)
    s2 = UploadMsg.build(2, 3, "snapshot", 2, tree, 64.0)
    assert inbox.offer(s1) == "accepted"
    assert inbox.offer(s1) == "duplicate"
    assert inbox.offer(s2) == "accepted"
    got = inbox.get(2, "snapshot")
    assert got.seq == 2


def test_corrupt_payload_crc_refused():
    from repro.core.faults import CorruptPayload
    inbox = RoundInbox(round_id=1)
    msg = UploadMsg.build(0, 1, "final", 1,
                          {"w": np.ones(8, np.float32)}, 64.0)
    with pytest.raises(CorruptPayload):
        inbox.offer(msg.corrupted())
    assert inbox.corrupt == 1
    assert inbox.get(0, "final") is None


# ---------------------------------------------------------------------------
# faults module units
# ---------------------------------------------------------------------------

def test_fault_plan_grammar_roundtrip():
    text = "dup@r2:c1;corrupt@r1:c*x2;drop@r4:c0;delay@r3:c2;crash@r5:checkpoint"
    plan = FaultPlan.parse(text)
    assert str(plan) == text
    assert plan.count("dup", 2, 1) == 1
    assert plan.count("dup", 2, 0) == 0
    assert plan.count("corrupt", 1, 9) == 2      # c* hits every client
    assert plan.crash_phase(5) == "checkpoint"
    assert plan.crash_phase(4) is None
    assert not plan.recoverable                  # drop/delay move the model
    assert FaultPlan.parse("dup@r1:c*; crash@r2:close").recoverable
    assert not FaultPlan.parse("")
    with pytest.raises(ValueError):
        FaultPlan.parse("explode@r1")
    with pytest.raises(ValueError):
        FaultPlan.parse("crash@r1:sideways")


def test_flip_partial_grammar_and_recoverability():
    text = "flip@r2:c*x3;partial@r1:c0"
    plan = FaultPlan.parse(text)
    assert str(plan) == text
    assert plan.count("flip", 2, 5) == 3
    assert plan.count("partial", 1, 0) == 1
    # flip perturbs the aggregate; partial x1 loses bytes on the legacy
    # wire: neither is bitwise-recoverable there
    assert not plan.recoverable
    # ...but under chunked transport, partial x1 only costs the newest
    # group's parity chunk — parity reassembles bit-identically
    assert FaultPlan.parse("partial@r1:c0").parity_recoverable
    assert not FaultPlan.parse("partial@r1:c0x2").parity_recoverable
    assert not FaultPlan.parse("flip@r1:c0").parity_recoverable
    assert FaultPlan.parse("dup@r1:c*; corrupt@r2:c0").parity_recoverable


def test_fault_plan_random_is_seeded():
    a = FaultPlan.random(7, 5, range(8), p_dup=0.2, p_corrupt=0.1,
                         crash_rounds=(3,))
    b = FaultPlan.random(7, 5, range(8), p_dup=0.2, p_corrupt=0.1,
                         crash_rounds=(3,))
    assert str(a) == str(b)
    assert a.crash_phase(3) is not None


def test_backoff_policy_and_retry_call():
    pol = BackoffPolicy(max_attempts=3, base_s=0.1, factor=2.0,
                        max_delay_s=10.0, jitter=0.5)
    rng = np.random.default_rng(0)
    d0, d1 = pol.delay_s(0, rng), pol.delay_s(1, rng)
    assert 0.05 <= d0 <= 0.1 and 0.1 <= d1 <= 0.2
    # deterministic under the same seed
    rng2 = np.random.default_rng(0)
    assert pol.delay_s(0, rng2) == d0

    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise UploadTimeout("not yet")
        return "ok"

    res = retry_call(flaky, pol, np.random.default_rng(0))
    assert res.value == "ok" and res.retries == 2 and res.backoff_s > 0

    def dead():
        raise UploadTimeout("never")

    with pytest.raises(RetriesExhausted):
        retry_call(dead, pol, np.random.default_rng(0))
