"""CI-scale exercise of the multi-pod dry-run path (subprocess: the 512
forced host devices must not leak into other tests)."""
import os
import subprocess
import sys
import textwrap

_SCRIPT = textwrap.dedent("""
    from repro.launch.dryrun import run_one
    rec = run_one("llama3.2-1b", "decode_32k", multi_pod=False,
                  calibrate=False, verbose=False)
    assert rec["status"] == "ok", rec
    assert rec["memory"]["argument_size_in_bytes"] > 0
    rec_mp = run_one("llama3.2-1b", "decode_32k", multi_pod=True,
                     calibrate=False, verbose=False)
    assert rec_mp["status"] == "ok", rec_mp
    assert rec_mp["n_chips"] == 512
    skip = run_one("hubert-xlarge", "long_500k", multi_pod=False,
                   verbose=False)
    assert skip["status"] == "skip_documented"
    print("DRYRUN_CI_OK")
""")


def test_dryrun_lowers_on_both_meshes():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert "DRYRUN_CI_OK" in out.stdout, out.stdout + "\n" + out.stderr
