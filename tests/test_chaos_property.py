"""Seeded chaos property test (hypothesis-gated like test_property.py).

The recoverability contract of the serving path: for ANY fault plan made
only of *recoverable* faults — duplicate deliveries, corrupt payloads
(refused + retried), injected server crashes (resumed from checkpoint) —
the served global model and per-round trajectory are bit-identical to the
fault-free ``Experiment.run(engine="loop")`` run on the same seed.

PR 9 extends the contract to the lossy-wire transport and the Byzantine
``flip``/``partial`` faults, with BOTH sides pinned:

  - ``partial x1`` under chunked transport loses only a group's *parity*
    chunk, so reassembly stays **bitwise** identical — while the same
    plan on the legacy atomic wire fails CRC every retry and loses the
    upload.
  - ``flip`` (CRC-clean pre-encode corruption) stays tolerance-bounded
    under a robust registered aggregate — while plain masked-mean on the
    same fault stream measurably degrades (params/loss blow up).
  - a forced-bad burst-error wire is survivable with XOR parity on and
    loses every transfer with parity off.
"""
from dataclasses import replace

import jax
import numpy as np
import pytest

# hypothesis gates only the @given properties — the deterministic
# both-sides pins below must run even where hypothesis is absent
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:
    class _NoStrategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _NoStrategies()

    def given(**kw):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed; property test skipped")(f)

    def settings(**kw):
        return lambda f: f

from repro.api import Experiment
from repro.core.faults import BackoffPolicy, FaultPlan
from repro.core.hsfl import HSFLConfig
from repro.core.transport import TransportConfig
from repro.serving.fl_server import FLServer, run_with_restarts

CFG = HSFLConfig(scheme="opt", b=2, rounds=2, n_uavs=8, k_select=4,
                 n_train=400, n_test=100, steps_per_epoch=2, local_epochs=4,
                 use_fused_round=False, seed=0)
_REF = {}


def reference():
    """The fault-free loop-engine trajectory + final model (computed once)."""
    if not _REF:
        log = Experiment(CFG).with_scheme("opt", b=2).run(engine="loop")
        server = FLServer(CFG)
        server.serve()
        _REF["log"] = log
        _REF["params"] = server.params
    return _REF


def assert_matches_reference(server):
    ref = reference()
    for a, s in zip(ref["log"].rounds, server.log.rounds):
        assert (a.selected, a.arrived_final, a.used_snapshot,
                a.dropped) == (s.selected, s.arrived_final,
                               s.used_snapshot, s.dropped)
        assert a.test_acc == s.test_acc
    for x, y in zip(jax.tree_util.tree_leaves(ref["params"]),
                    jax.tree_util.tree_leaves(server.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@given(seed=st.integers(0, 2**31 - 1),
       p_dup=st.floats(0.0, 0.6),
       p_corrupt=st.floats(0.0, 0.4))
@settings(max_examples=6, deadline=None)
def test_recoverable_chaos_preserves_the_trajectory(seed, p_dup, p_corrupt):
    plan = FaultPlan.random(seed, CFG.rounds, range(CFG.n_uavs),
                            p_dup=p_dup, p_corrupt=p_corrupt)
    assert plan.recoverable
    server = FLServer(CFG, fault_plan=plan)
    server.serve()
    assert_matches_reference(server)


@given(seed=st.integers(0, 2**31 - 1),
       crash_round=st.integers(1, 2))
@settings(max_examples=4, deadline=None)
def test_chaos_with_crash_and_restart_preserves_the_trajectory(
        tmp_path_factory, seed, crash_round):
    plan = FaultPlan.random(seed, CFG.rounds, range(CFG.n_uavs),
                            p_dup=0.3, p_corrupt=0.2,
                            crash_rounds=(crash_round,))
    assert plan.recoverable
    d = tmp_path_factory.mktemp("chaos")
    server, restarts = run_with_restarts(CFG, ckpt_dir=str(d),
                                         fault_plan=plan)
    assert restarts == 1
    assert_matches_reference(server)


# ---------------------------------------------------------------------------
# lossy-wire transport: partial uploads, erasure rescue, flip robustness
# ---------------------------------------------------------------------------

TP = TransportConfig(chunk_bytes=2048, parity_k=4)   # perfect wire, chunked
_TREF = {}


def transport_reference():
    """The fault-free *chunked-transport* trajectory (computed once).
    Chunked snapshots accumulate across probe epochs, so this trajectory
    legitimately differs from the unchunked eq. 15 gate's — the bitwise
    contract is against the same transport config, not across configs."""
    if not _TREF:
        server = FLServer(CFG, transport=TP)
        server.serve()
        _TREF["log"] = server.log
        _TREF["params"] = server.params
    return _TREF


@given(seed=st.integers(0, 2**31 - 1), p_partial=st.floats(0.1, 0.8))
@settings(max_examples=4, deadline=None)
def test_partial_uploads_rescued_bitwise_under_parity(seed, p_partial):
    """``partial x1`` truncates the *last* chunk of each faulted final —
    under systematic interleaved parity that is always the newest group's
    parity chunk, so every data chunk still lands and reassembly is
    bit-identical: the whole trajectory matches the fault-free transport
    run exactly."""
    plan = FaultPlan.random(seed, CFG.rounds, range(CFG.n_uavs),
                            p_partial=p_partial)
    assert plan.parity_recoverable
    server = FLServer(CFG, fault_plan=plan, transport=TP)
    server.serve()
    ref = transport_reference()
    for a, s in zip(ref["log"].rounds, server.log.rounds):
        assert (a.selected, a.arrived_final, a.used_snapshot,
                a.dropped) == (s.selected, s.arrived_final,
                               s.used_snapshot, s.dropped)
        assert a.test_acc == s.test_acc
    for x, y in zip(jax.tree_util.tree_leaves(ref["params"]),
                    jax.tree_util.tree_leaves(server.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_partial_without_transport_loses_the_upload():
    """BOTH sides of the pin: the same truncation on the legacy atomic
    wire fails CRC on every retry — the finals are lost, not rescued."""
    ref = reference()
    server = FLServer(CFG, fault_plan="partial@r1:c*x1")
    server.serve()
    ref_arrived = sum(r.arrived_final for r in ref["log"].rounds)
    got_arrived = sum(r.arrived_final for r in server.log.rounds)
    assert got_arrived < ref_arrived
    assert sum(r.corrupt_rejected for r in server.log.rounds) > 0


# the Byzantine pin needs >=3 voices per round (a cohort of 2 has no
# honest majority for ANY aggregate); 12 UAVs / k=6 keeps m in 4..5
RCFG = HSFLConfig(scheme="opt_trimmed", b=2, rounds=2, n_uavs=12,
                  k_select=6, n_train=400, n_test=100, steps_per_epoch=2,
                  local_epochs=4, use_fused_round=False, seed=0)
FLIPS = "flip@r1:c*x3; flip@r2:c*x3"
_RREF = {}


def _amax(params):
    return max(float(np.max(np.abs(np.asarray(x))))
               for x in jax.tree_util.tree_leaves(params))


def _leaf_diff(a, b):
    return max(float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


def _robust_run(scheme, plan=None):
    key = (scheme, plan)
    if key not in _RREF:
        server = FLServer(replace(RCFG, scheme=scheme), fault_plan=plan)
        server.serve()
        _RREF[key] = (server.params, server.log.rounds[-1])
    return _RREF[key]


@pytest.mark.parametrize("scheme", ["opt_trimmed", "opt_median"])
def test_flip_bounded_under_robust_aggregate(scheme):
    """CRC-clean bit flips (~1e37 outliers in every upload) stay
    tolerance-bounded under the registered robust aggregates: the flipped
    coordinates are trimmed/outvoted, everything else aggregates
    identically."""
    p_ref, r_ref = _robust_run(scheme)
    p_flip, r_flip = _robust_run(scheme, FLIPS)
    assert np.isfinite(_amax(p_flip))
    assert _leaf_diff(p_ref, p_flip) < 0.05
    assert abs(r_flip.test_loss - r_ref.test_loss) < 0.1
    assert abs(r_flip.test_acc - r_ref.test_acc) <= 0.1


def test_flip_degrades_plain_mean():
    """BOTH sides of the pin: the same flip stream through plain
    masked-mean blows the global model up (the 1e37 outliers average in;
    training then spreads them) — params explode and the loss diverges.
    NaN-safe assertion form: ``not (x <= bound)`` is True for NaN."""
    _, r_ref = _robust_run("opt")
    p_flip, r_flip = _robust_run("opt", FLIPS)
    assert not (_amax(p_flip) <= 1e6)
    assert not (r_flip.test_loss <= r_ref.test_loss + 1.0)


def test_lossy_wire_plus_flips_survive_with_full_subsystem():
    """The headline acceptance pin, all at once: a wire pinned to the
    Gilbert–Elliott bad state (forced BER, single send attempt) carrying
    chunked+parity transport, with CRC-clean ``flip`` chaos on top, under
    the trimmed-mean aggregate — the run finishes within a stated
    accuracy tolerance of the fault-free run.  The degraded side (same
    flip stream, no transport, plain masked-mean) is pinned right below
    via the memoized ``_robust_run``: params explode, loss diverges."""
    tp = TransportConfig(chunk_bytes=2048, parity_k=4, ber_bad=1e-6,
                         wire_outage_prob=1.0, wire_persistence=1.0)
    server = FLServer(RCFG, transport=tp, fault_plan=FLIPS,
                      backoff=BackoffPolicy(max_attempts=1))
    server.serve()
    rounds = server.log.rounds
    assert sum(r.chunks_corrupt for r in rounds) > 0    # the wire really bit
    assert sum(r.chunks_recovered for r in rounds) > 0  # parity engaged
    assert np.isfinite(_amax(server.params))
    p_ref, r_ref = _robust_run("opt_trimmed")
    assert abs(rounds[-1].test_acc - r_ref.test_acc) <= 0.1
    # degraded side: identical flip stream, subsystem off (legacy wire,
    # plain mean) — non-finite params / divergent loss
    p_flip, r_flip = _robust_run("opt", FLIPS)
    assert not (_amax(p_flip) <= 1e6)
    assert not (r_flip.test_loss <= r_ref.test_loss + 1.0)


def test_parity_rescues_forced_bad_wire():
    """Acceptance pin for the erasure code: a single-attempt (no
    retransmit) wire stuck in the bad state corrupts ~1%% of chunks.
    With XOR parity every transfer reconstructs; with parity off the
    same wire loses every transfer."""
    outcomes = {}
    for parity_k in (4, 0):
        tp = TransportConfig(chunk_bytes=2048, parity_k=parity_k,
                             ber_bad=1e-6, wire_outage_prob=1.0,
                             wire_persistence=1.0)
        server = FLServer(CFG, transport=tp,
                          backoff=BackoffPolicy(max_attempts=1))
        server.serve()
        outcomes[parity_k] = (
            sum(r.arrived_final + r.used_snapshot for r in server.log.rounds),
            sum(r.chunks_recovered for r in server.log.rounds),
            sum(r.transfers_incomplete for r in server.log.rounds))
    part_on, rec_on, inc_on = outcomes[4]
    part_off, rec_off, inc_off = outcomes[0]
    assert rec_on > 0 and inc_on == 0       # every loss reconstructed
    assert part_on > part_off               # participation rescued
    assert rec_off == 0 and inc_off > 0     # no parity -> transfers lost
