"""Seeded chaos property test (hypothesis-gated like test_property.py).

The recoverability contract of the serving path: for ANY fault plan made
only of *recoverable* faults — duplicate deliveries, corrupt payloads
(refused + retried), injected server crashes (resumed from checkpoint) —
the served global model and per-round trajectory are bit-identical to the
fault-free ``Experiment.run(engine="loop")`` run on the same seed.
"""
import jax
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; property tests skipped")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import Experiment
from repro.core.faults import FaultPlan
from repro.core.hsfl import HSFLConfig
from repro.serving.fl_server import FLServer, run_with_restarts

CFG = HSFLConfig(scheme="opt", b=2, rounds=2, n_uavs=8, k_select=4,
                 n_train=400, n_test=100, steps_per_epoch=2, local_epochs=4,
                 use_fused_round=False, seed=0)
_REF = {}


def reference():
    """The fault-free loop-engine trajectory + final model (computed once)."""
    if not _REF:
        log = Experiment(CFG).with_scheme("opt", b=2).run(engine="loop")
        server = FLServer(CFG)
        server.serve()
        _REF["log"] = log
        _REF["params"] = server.params
    return _REF


def assert_matches_reference(server):
    ref = reference()
    for a, s in zip(ref["log"].rounds, server.log.rounds):
        assert (a.selected, a.arrived_final, a.used_snapshot,
                a.dropped) == (s.selected, s.arrived_final,
                               s.used_snapshot, s.dropped)
        assert a.test_acc == s.test_acc
    for x, y in zip(jax.tree_util.tree_leaves(ref["params"]),
                    jax.tree_util.tree_leaves(server.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@given(seed=st.integers(0, 2**31 - 1),
       p_dup=st.floats(0.0, 0.6),
       p_corrupt=st.floats(0.0, 0.4))
@settings(max_examples=6, deadline=None)
def test_recoverable_chaos_preserves_the_trajectory(seed, p_dup, p_corrupt):
    plan = FaultPlan.random(seed, CFG.rounds, range(CFG.n_uavs),
                            p_dup=p_dup, p_corrupt=p_corrupt)
    assert plan.recoverable
    server = FLServer(CFG, fault_plan=plan)
    server.serve()
    assert_matches_reference(server)


@given(seed=st.integers(0, 2**31 - 1),
       crash_round=st.integers(1, 2))
@settings(max_examples=4, deadline=None)
def test_chaos_with_crash_and_restart_preserves_the_trajectory(
        tmp_path_factory, seed, crash_round):
    plan = FaultPlan.random(seed, CFG.rounds, range(CFG.n_uavs),
                            p_dup=0.3, p_corrupt=0.2,
                            crash_rounds=(crash_round,))
    assert plan.recoverable
    d = tmp_path_factory.mktemp("chaos")
    server, restarts = run_with_restarts(CFG, ckpt_dir=str(d),
                                         fault_plan=plan)
    assert restarts == 1
    assert_matches_reference(server)
