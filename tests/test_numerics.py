"""Numerical-equivalence tests for the §Perf optimization paths.

Every beyond-baseline fast path must match its reference semantics — these
are the guards that kept the hillclimb honest.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import attention as attn_mod
from repro.models import build_model
from repro.models import moe as moe_mod
from repro.models.inputs import materialize, train_specs
from repro.training.step import loss_fn

RNG = np.random.default_rng(0)


def test_chunked_attention_matches_dense_path(monkeypatch):
    """The flash-style q-chunked path == the einsum path (same S)."""
    cfg = get_config("llama3.2-1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S = 512
    inputs = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, S)),
                                    jnp.int32)}
    ref_logits, _ = model.forward(params, inputs)          # S<=1024: einsum
    monkeypatch.setattr(attn_mod, "CHUNK_THRESHOLD", 256)  # force chunked
    chunked_logits, _ = model.forward(params, inputs)
    np.testing.assert_allclose(np.asarray(chunked_logits, np.float32),
                               np.asarray(ref_logits, np.float32),
                               atol=2e-3, rtol=2e-3)


def test_chunked_attention_sliding_window(monkeypatch):
    cfg = get_config("llama3.2-1b").reduced().with_sliding_window(64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    inputs = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (1, 512)),
                                    jnp.int32)}
    ref_logits, _ = model.forward(params, inputs)
    monkeypatch.setattr(attn_mod, "CHUNK_THRESHOLD", 256)
    win_logits, _ = model.forward(params, inputs)
    np.testing.assert_allclose(np.asarray(win_logits, np.float32),
                               np.asarray(ref_logits, np.float32),
                               atol=2e-3, rtol=2e-3)


def test_fused_head_loss_matches_standard():
    cfg = get_config("llama3.2-1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    spec = train_specs(cfg, 2, 16)
    batch = materialize(spec, cfg, seed=3)
    std, _ = loss_fn(model, params, batch, None)
    fused, _ = loss_fn(model, params, batch, {"fused_head": True})
    np.testing.assert_allclose(float(std), float(fused), rtol=1e-5)


def test_fused_head_grads_match():
    cfg = get_config("llama3.2-1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = materialize(train_specs(cfg, 2, 16), cfg, seed=4)
    g_std = jax.grad(lambda p: loss_fn(model, p, batch, None)[0])(params)
    g_fused = jax.grad(
        lambda p: loss_fn(model, p, batch, {"fused_head": True})[0])(params)
    for a, b in zip(jax.tree_util.tree_leaves(g_std),
                    jax.tree_util.tree_leaves(g_fused)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-4)


def test_moe_dense_matches_scatter_when_capacity_ample():
    """With capacity >> demand nothing is dropped, so both dispatches agree
    (the fused-combine rewrite must preserve the math)."""
    cfg = get_config("granite-moe-3b-a800m").reduced()
    key = jax.random.PRNGKey(1)
    params = moe_mod.init_moe(key, cfg)
    x = jnp.asarray(RNG.standard_normal((2, 8, cfg.d_model)) * 0.5,
                    jnp.float32)
    y_dense, aux_d = moe_mod.moe_dense(params, cfg, x)
    y_scatter, aux_s = moe_mod.moe_scatter(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_scatter),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(float(aux_d), float(aux_s), rtol=1e-6)


def test_adamw_bf16_moments_track_f32():
    from repro.optim import adamw, apply_updates
    params = {"w": jnp.asarray([1.0, -2.0, 0.5])}
    for dt, tol in ((jnp.float32, 0.0), (jnp.bfloat16, 5e-2)):
        opt = adamw(0.1, moment_dtype=dt)
        p, st = params, opt.init(params)
        for _ in range(20):
            g = jax.grad(lambda q: jnp.sum(q["w"] ** 2))(p)
            upd, st = opt.update(g, st, p)
            p = apply_updates(p, upd)
        if dt == jnp.float32:
            ref = p
        else:
            np.testing.assert_allclose(np.asarray(p["w"]),
                                       np.asarray(ref["w"]), atol=tol)


def test_sliding_window_decode_ring_buffer():
    """Windowed decode == full-cache decode while position < window."""
    from repro.serving import prefill
    cfg_full = get_config("llama3.2-1b").reduced()
    cfg_win = cfg_full.with_sliding_window(64)
    tokens = jnp.asarray(RNG.integers(0, cfg_full.vocab_size, (1, 12)),
                         jnp.int32)
    m_full, m_win = build_model(cfg_full), build_model(cfg_win)
    params = m_full.init(jax.random.PRNGKey(0))
    lg_full, _, _ = prefill(m_full, params, tokens, context_len=32)
    lg_win, _, _ = prefill(m_win, params, tokens, context_len=128)
    np.testing.assert_allclose(np.asarray(lg_full, np.float32),
                               np.asarray(lg_win, np.float32),
                               atol=2e-3, rtol=2e-3)
