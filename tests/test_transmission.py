"""OPT scheduler unit tests (Algorithm 2)."""
import pytest

from repro.core.transmission import OppTransmitter, scheduled_epochs


def test_scheduled_epochs_paper_setting():
    # e=6, b=2 -> one intermediate transmission at e_t=3
    assert scheduled_epochs(6, 2) == [3]
    # b=3 -> period 2 -> epochs 2, 4
    assert scheduled_epochs(6, 3) == [2, 4]
    # b=1 -> no intermediates (the discard baseline)
    assert scheduled_epochs(6, 1) == []
    # b=6 -> every epoch except the final
    assert scheduled_epochs(6, 6) == [1, 2, 3, 4, 5]


def test_budget_decrement_eq16():
    tx = OppTransmitter(model_bytes=10e6, e=6, b=3, rate0_bps=80e6)
    assert tx.tau_extra == pytest.approx(2.0)       # (b-1)*m/r0
    ok = tx.maybe_transmit(2, 80e6, outage=False, params={"w": 1})
    assert ok and tx.tau_extra == pytest.approx(1.0)
    ok = tx.maybe_transmit(4, 80e6, outage=False, params={"w": 2})
    assert ok and tx.tau_extra == pytest.approx(0.0)


def test_overwrite_semantics():
    tx = OppTransmitter(10e6, e=6, b=3, rate0_bps=80e6)
    tx.maybe_transmit(2, 80e6, False, "first")
    tx.maybe_transmit(4, 80e6, False, "second")
    assert tx.snapshot == "second"                  # Alg. 2: overwritten
    assert tx.snapshot_epoch == 4


def test_outage_blocks_transmission():
    tx = OppTransmitter(10e6, e=6, b=2, rate0_bps=80e6)
    assert not tx.maybe_transmit(3, 80e6, outage=True, params="x")
    assert tx.snapshot is None
    assert tx.tau_extra == pytest.approx(1.0)       # budget untouched


def test_cancel_when_channel_too_slow():
    tx = OppTransmitter(10e6, e=6, b=2, rate0_bps=80e6)
    # rate collapsed 4x -> tau = 4 > tau_extra = 1 -> cancelled (Sec. III-B)
    assert not tx.maybe_transmit(3, 20e6, outage=False, params="x")
    assert tx.snapshot is None


def test_unscheduled_epoch_ignored():
    tx = OppTransmitter(10e6, e=6, b=2, rate0_bps=80e6)
    assert not tx.maybe_transmit(2, 1e9, False, "x")


def test_final_upload_latency_gate():
    tx = OppTransmitter(10e6, e=6, b=2, rate0_bps=80e6)
    assert tx.final_upload(80e6, outage=False, tau_spent_training=5.0,
                           tau_max=9.0)
    tx2 = OppTransmitter(10e6, e=6, b=2, rate0_bps=80e6)
    assert not tx2.final_upload(8e6, outage=False, tau_spent_training=5.0,
                                tau_max=9.0)        # 10s upload > budget


def test_bytes_accounting_with_compression():
    tx = OppTransmitter(10e6, e=6, b=2, rate0_bps=80e6, compress_ratio=0.25)
    tx.maybe_transmit(3, 80e6, False, "x")
    tx.final_upload(80e6, False, 1.0, 9.0)
    assert tx.bytes_sent == pytest.approx(2 * 2.5e6)
