"""Pallas kernel allclose sweeps vs pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.delta_codec.kernel import dequantize_blocks, quantize_blocks
from repro.kernels.delta_codec.ops import (COMPRESS_RATIO, decode_delta,
                                           encode_delta)
from repro.kernels.delta_codec.ref import dequantize_ref, quantize_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.wkv6.ops import wkv6
from repro.kernels.wkv6.ref import wkv6_ref

RNG = np.random.default_rng(0)


def _gqa_ref(q, k, v, causal, window):
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qr = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kr = jnp.repeat(k, G, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, D)
    vr = jnp.repeat(v, G, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, D)
    out = attention_ref(qr, kr, vr, causal=causal, window=window)
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("B,S,H,KV,D", [
    (1, 128, 2, 2, 64),
    (2, 256, 4, 2, 64),
    (1, 256, 8, 1, 128),
    (2, 384, 6, 2, 32),        # non-pow2 head count / small head dim
])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, KV, D, causal, window, dtype):
    q = jnp.asarray(RNG.standard_normal((B, S, H, D)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, S, KV, D)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, S, KV, D)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True)
    ref = _gqa_ref(q, k, v, causal, window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("B,S,H,D,chunk", [
    (1, 128, 2, 64, 64),
    (2, 256, 3, 64, 128),
    (1, 256, 1, 32, 256),      # single chunk == full sequence
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_sweep(B, S, H, D, chunk, dtype):
    r, k, v = (jnp.asarray(RNG.standard_normal((B, S, H, D)) * 0.5, dtype)
               for _ in range(3))
    w = jnp.asarray(RNG.random((B, S, H, D)) * 0.4 + 0.55, dtype)
    u = jnp.asarray(RNG.standard_normal((H, D)) * 0.1, jnp.float32)
    S0 = jnp.zeros((B, H, D, D), jnp.float32)
    y, sf = wkv6(r, k, v, w, u, chunk=chunk, interpret=True)
    yr, sr = wkv6_ref(r, k, v, w, u, S0)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(sf, sr, atol=tol, rtol=tol)


def test_wkv6_state_identity_property():
    """With w == 1 and u == 0, y_t = r_t . sum_{s<t} k_s v_s^T (prefix sums)."""
    B, S, H, D = 1, 64, 1, 32
    r = jnp.asarray(RNG.standard_normal((B, S, H, D)) * 0.3, jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, H, D)) * 0.3, jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, H, D)) * 0.3, jnp.float32)
    w = jnp.ones((B, S, H, D), jnp.float32)
    u = jnp.zeros((H, D), jnp.float32)
    y, _ = wkv6(r, k, v, w, u, chunk=32, interpret=True)
    kv = jnp.einsum("bshi,bshj->bshij", k, v)
    prefix = jnp.cumsum(kv, axis=1) - kv          # strictly-previous sum
    expect = jnp.einsum("bshi,bshij->bshj", r, prefix)
    np.testing.assert_allclose(y, expect, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("M,scale", [(256, 1.0), (512, 1e-3), (1024, 50.0)])
def test_codec_matches_ref(M, scale):
    x = jnp.asarray(RNG.standard_normal((M, 512)) * scale, jnp.float32)
    q, s = quantize_blocks(x, interpret=True)
    qr, sr = quantize_ref(x)
    assert bool(jnp.all(q == qr))
    np.testing.assert_allclose(s, sr, rtol=1e-6)
    xd = dequantize_blocks(q, s, interpret=True)
    np.testing.assert_allclose(xd, dequantize_ref(qr, sr), rtol=1e-6)


def test_codec_roundtrip_error_bound():
    x = jnp.asarray(RNG.standard_normal((512, 512)), jnp.float32)
    q, s = quantize_blocks(x, interpret=True)
    xd = dequantize_blocks(q, s, interpret=True)
    assert float(jnp.max(jnp.abs(xd - x))) <= float(jnp.max(s)) / 2 + 1e-7


@pytest.mark.parametrize("bits", [4, 8])
def test_codec_int4_matches_ref_and_bounds(bits):
    x = jnp.asarray(RNG.standard_normal((256, 512)), jnp.float32)
    q, s = quantize_blocks(x, interpret=True, bits=bits)
    qr, sr = quantize_ref(x, bits=bits)
    assert bool(jnp.all(q == qr))
    np.testing.assert_allclose(s, sr, rtol=1e-6)
    qmax = 2 ** (bits - 1) - 1
    assert int(jnp.max(jnp.abs(q.astype(jnp.int32)))) <= qmax
    xd = dequantize_blocks(q, s, interpret=True)
    # error bounded by half an int step of the per-block scale
    assert float(jnp.max(jnp.abs(xd - x))) <= float(jnp.max(s)) / 2 + 1e-7


def test_codec_bits_validated():
    from repro.kernels.delta_codec.kernel import validate_bits
    from repro.kernels.delta_codec.ops import codec_ratio
    with pytest.raises(ValueError, match="bit depth"):
        validate_bits(5)
    with pytest.raises(ValueError, match="bit depth"):
        codec_ratio(1000, bits=16)


def test_codec_ratio_bits_frontier():
    """int4 halves the lane bytes: ratio(bits=4) sits between half the
    int8 ratio and the int8 ratio, for any block width."""
    from repro.kernels.delta_codec.ops import codec_ratio, payload_bytes
    for n in (1000, 451_850):
        for block in (128, 512):
            r8 = codec_ratio(n, block, bits=8)
            r4 = codec_ratio(n, block, bits=4)
            assert r4 < r8
            assert r4 > r8 / 2          # the f32 scale overhead stays
    # payload_bytes agrees with the ratio accounting
    base = {"w": jnp.zeros((700,))}
    params = {"w": jnp.ones((700,)) * 0.01}
    p4 = encode_delta(params, base, interpret=True, bits=4)
    p8 = encode_delta(params, base, interpret=True, bits=8)
    blocks = -(-700 // 512)
    assert payload_bytes(p8) == blocks * 512 + blocks * 4
    assert payload_bytes(p4) == blocks * 512 // 2 + blocks * 4
    # int4 payload decodes within its coarser error bound
    out = decode_delta(p4, base, interpret=True)
    np.testing.assert_allclose(np.asarray(out["w"]), 0.01, atol=1e-3)


def test_delta_codec_tree_roundtrip():
    params = {"a": jnp.asarray(RNG.standard_normal((33, 7)), jnp.float32),
              "b": {"c": jnp.asarray(RNG.standard_normal(501), jnp.float32)}}
    base = jax.tree_util.tree_map(jnp.zeros_like, params)
    payload = encode_delta(params, base, interpret=True)
    rec = decode_delta(payload, base, interpret=True)
    for pth in ("a",):
        err = float(jnp.max(jnp.abs(rec[pth] - params[pth])))
        assert err < 2e-2
    assert 0.2 < COMPRESS_RATIO < 0.3
