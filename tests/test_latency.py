"""Latency relaxation unit tests (Section III-A, eqs. 9-16)."""
import pytest

from repro.core import latency as lat

DEV = lat.DeviceProfile(flops_per_sec=1e9)
WL = lat.WorkloadProfile(local_epochs=6, samples=200)


def test_uplink_eq13_fl():
    # b * m * 8 / r
    assert lat.uplink_fl(2, 10e6, 80e6) == pytest.approx(2.0)
    assert lat.uplink_fl(1, 10e6, 80e6) == pytest.approx(1.0)


def test_uplink_eq13_sl():
    # (b*m_l + m_a) * 8 / r
    got = lat.uplink_sl(3, 2e6, 1e6, 56e6)
    assert got == pytest.approx((3 * 2e6 + 1e6) * 8 / 56e6)


def test_extra_allowance_eq14():
    assert lat.extra_allowance(1, 10e6, 80e6) == 0.0
    assert lat.extra_allowance(2, 10e6, 80e6) == pytest.approx(1.0)
    assert lat.extra_allowance(4, 10e6, 80e6) == pytest.approx(3.0)


def test_snapshot_delay_eq15():
    assert lat.snapshot_delay(10e6, 80e6) == pytest.approx(1.0)
    # worse channel -> longer delay
    assert lat.snapshot_delay(10e6, 40e6) > lat.snapshot_delay(10e6, 80e6)


def test_one_round_latency_monotonic_in_b():
    l1 = lat.one_round_latency_fl(DEV, WL, 1, 10e6, 80e6)
    l2 = lat.one_round_latency_fl(DEV, WL, 2, 10e6, 80e6)
    assert l2 > l1
    assert l2 - l1 == pytest.approx(1.0)


def test_sl_faster_training_for_slow_device():
    slow = lat.DeviceProfile(flops_per_sec=1e8)
    assert lat.train_time_sl(slow, WL) < lat.train_time_fl(slow, WL)


def test_energy_positive():
    assert lat.energy_fl(DEV, WL, 1.0) > 0
    assert lat.energy_sl(DEV, WL, 1.0) > 0
    # SL compute energy is cheaper on the UAV (offloaded share)
    assert lat.energy_sl(DEV, WL, 0.0) < lat.energy_fl(DEV, WL, 0.0)
