"""repro.analysis lint + contract tests.

Every rule gets a positive fixture (the violation fires at the expected
line) and a negative fixture (the compliant twin stays silent) — the
fixtures are the repo's own bug taxonomy: each one reproduces, in
miniature, a defect class an earlier PR actually fixed.  The contract
half is checked both ways: the real tree must be clean, and a planted
mismatch must be caught.
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis.findings import Baseline, Finding, filter_findings, \
    pragma_rules, suppressed_by_pragma
from repro.analysis.lint import all_rules, lint_source

REPO = Path(__file__).resolve().parents[1]

CORE = "src/repro/core/somemod.py"
KERN = "src/repro/kernels/somepkg/kernel.py"


def findings_for(src, relpath, rule=None):
    out = [f for f in lint_source(textwrap.dedent(src), relpath)]
    if rule is not None:
        out = [f for f in out if f.rule == rule]
    return out


def test_rule_registry_nonempty():
    names = {r.name for r in all_rules()}
    assert {"scheme-branch", "host-sync", "rng-reuse", "jit-donate",
            "dtype-thread", "np-hot", "except-swallow"} <= names


# ---------------------------------------------------------------------------
# scheme-branch
# ---------------------------------------------------------------------------

SCHEME_BRANCH = """
def agg(scheme, x):
    if scheme == "opt":
        return x
    if scheme in ("async", "discard"):
        return -x
"""


def test_scheme_branch_fires_outside_registry():
    got = findings_for(SCHEME_BRANCH, CORE, "scheme-branch")
    assert len(got) == 2
    assert {f.line for f in got} == {3, 5}


def test_scheme_branch_allowed_in_schemes_py():
    assert not findings_for(SCHEME_BRANCH, "src/repro/core/schemes.py",
                            "scheme-branch")


def test_scheme_branch_ignores_other_strings():
    src = """
    def f(mode, x):
        if mode == "fast":
            return x
    """
    assert not findings_for(src, CORE, "scheme-branch")


# ---------------------------------------------------------------------------
# host-sync
# ---------------------------------------------------------------------------

def test_host_sync_item_in_scanned_body():
    src = """
    import jax

    def build_round():
        def body(c, x):
            return c + x.item(), None
        return jax.lax.scan(body, 0.0, xs)
    """
    got = findings_for(src, CORE, "host-sync")
    assert len(got) == 1 and got[0].line == 6


def test_host_sync_clock_in_jitted_fn():
    src = """
    import time
    import jax

    @jax.jit
    def step(x):
        t0 = time.time()
        return x * t0
    """
    got = findings_for(src, CORE, "host-sync")
    assert len(got) == 1 and "time.time" in got[0].snippet


def test_host_sync_float_of_traced_value():
    src = """
    import jax

    def make_step():
        def step(x):
            return float(x) * 2.0
        return jax.jit(step, donate_argnums=(0,))
    """
    assert findings_for(src, CORE, "host-sync")


def test_host_sync_allows_static_shape_math():
    src = """
    import jax

    def make_step():
        def step(x):
            return x / float(x.shape[0])
        return jax.jit(step, donate_argnums=(0,))
    """
    assert not findings_for(src, CORE, "host-sync")


def test_host_sync_host_code_untouched():
    src = """
    import time

    def report(x):
        t0 = time.time()
        return x.item(), t0
    """
    assert not findings_for(src, CORE, "host-sync")


def test_host_sync_only_core_and_kernels():
    src = """
    import jax

    @jax.jit
    def step(x):
        return float(x)
    """
    assert not findings_for(src, "src/repro/serving/server.py", "host-sync")


# ---------------------------------------------------------------------------
# rng-reuse
# ---------------------------------------------------------------------------

def test_rng_double_consume():
    src = """
    import jax

    def sample(key):
        a = jax.random.normal(key, (3,))
        b = jax.random.uniform(key, (3,))
        return a + b
    """
    got = findings_for(src, CORE, "rng-reuse")
    assert len(got) == 1 and got[0].line == 6


def test_rng_loop_reuse():
    src = """
    import jax

    def sample(key):
        out = []
        for i in range(4):
            out.append(jax.random.normal(key, (3,)))
        return out
    """
    got = findings_for(src, CORE, "rng-reuse")
    assert len(got) == 1 and "loop" in got[0].message


def test_rng_split_chain_ok():
    src = """
    import jax

    def sample(key):
        out = []
        for i in range(4):
            sub, key = jax.random.split(key)
            out.append(jax.random.normal(sub, (3,)))
        return out
    """
    assert not findings_for(src, CORE, "rng-reuse")


def test_rng_fold_in_ok():
    src = """
    import jax

    def sample(key):
        return [jax.random.normal(jax.random.fold_in(key, i), (3,))
                for i in range(4)]
    """
    assert not findings_for(src, CORE, "rng-reuse")


def test_rng_exclusive_branches_ok():
    src = """
    import jax

    def sample(key, flag):
        if flag:
            return jax.random.normal(key, (3,))
        else:
            return jax.random.uniform(key, (3,))
    """
    assert not findings_for(src, CORE, "rng-reuse")


# ---------------------------------------------------------------------------
# jit-donate
# ---------------------------------------------------------------------------

def test_jit_donate_fires_in_core():
    src = """
    import jax

    def build(f):
        return jax.jit(f)
    """
    assert findings_for(src, CORE, "jit-donate")


def test_jit_donate_satisfied():
    src = """
    import jax

    def build(f):
        return jax.jit(f, donate_argnums=(0,))
    """
    assert not findings_for(src, CORE, "jit-donate")


def test_jit_donate_scope_is_core_only():
    src = """
    import jax

    def build(f):
        return jax.jit(f)
    """
    assert not findings_for(src, KERN, "jit-donate")


def test_jit_donate_pragma_suppression():
    src = """
    import jax

    def build(f):
        return jax.jit(f)  # analysis: ok=jit-donate
    """
    findings = findings_for(src, CORE, "jit-donate")
    assert len(findings) == 1  # raw lint still reports it ...
    lines = textwrap.dedent(src).splitlines()
    assert suppressed_by_pragma(findings[0], lines)  # ... filter drops it


def test_pragma_wrong_rule_does_not_suppress():
    line = "return jax.jit(f)  # analysis: ok=np-hot"
    f = Finding(path=CORE, line=1, col=0, rule="jit-donate", message="m",
                snippet=line.strip())
    assert not suppressed_by_pragma(f, [line])
    assert pragma_rules(line) == frozenset({"np-hot"})


# ---------------------------------------------------------------------------
# dtype-thread
# ---------------------------------------------------------------------------

def test_dtype_thread_unused_param():
    src = """
    import jax.numpy as jnp

    def forward(params, x, compute_dtype=None):
        return x @ params
    """
    got = findings_for(src, KERN, "dtype-thread")
    assert len(got) == 1 and "compute_dtype" in got[0].message


def test_dtype_thread_hardcoded_cast():
    src = """
    import jax.numpy as jnp

    def forward(params, x, compute_dtype=jnp.float32):
        y = x.astype(compute_dtype) @ params
        return y.astype(jnp.float32)
    """
    got = findings_for(src, KERN, "dtype-thread")
    assert len(got) == 1 and "astype" in got[0].snippet


def test_dtype_thread_threaded_ok():
    src = """
    import jax.numpy as jnp

    def forward(params, x, compute_dtype=jnp.float32):
        return (x @ params).astype(compute_dtype)
    """
    assert not findings_for(src, KERN, "dtype-thread")


# ---------------------------------------------------------------------------
# np-hot
# ---------------------------------------------------------------------------

def test_np_hot_fires_in_hot_module():
    src = """
    import numpy as np

    def agg(x):
        return np.mean(x)
    """
    got = findings_for(src, "src/repro/core/fused_round.py", "np-hot")
    assert len(got) == 1


def test_np_hot_constants_allowed():
    src = """
    import numpy as np

    def agg(x):
        return x * np.pi + np.float32(0)
    """
    assert not findings_for(src, "src/repro/core/fused_round.py", "np-hot")


def test_np_hot_cold_modules_exempt():
    src = """
    import numpy as np

    def agg(x):
        return np.mean(x)
    """
    assert not findings_for(src, "src/repro/core/metrics.py", "np-hot")


# ---------------------------------------------------------------------------
# baseline round-trip + syntax errors
# ---------------------------------------------------------------------------

def test_baseline_round_trip(tmp_path):
    f = Finding(path=CORE, line=7, col=4, rule="jit-donate",
                message="msg", snippet="return jax.jit(f)")
    text = Baseline.render([f])
    p = tmp_path / "baseline.txt"
    p.write_text(text.replace("TODO: one-line justification", "reviewed"))
    bl = Baseline.load(p)
    assert bl.covers(f)
    # line drift must not invalidate the entry (keyed on source text)
    drifted = Finding(path=f.path, line=99, col=0, rule=f.rule,
                      message=f.message, snippet=f.snippet)
    assert bl.covers(drifted)
    assert not bl.stale()
    other = Finding(path=f.path, line=7, col=4, rule="np-hot",
                    message="msg", snippet="np.mean(x)")
    assert not bl.covers(other)


def test_filter_findings_applies_baseline_and_pragma(tmp_path):
    src = "import jax\ndef build(f):\n    return jax.jit(f)\n"
    live = lint_source(src, CORE)
    assert live
    p = tmp_path / "baseline.txt"
    p.write_text(Baseline.render(live))
    bl = Baseline.load(p)
    kept = filter_findings(live, bl, {CORE: src.splitlines()})
    assert kept == []
    assert not bl.stale()


def test_syntax_error_is_a_finding():
    got = lint_source("def broken(:\n", CORE)
    assert len(got) == 1 and got[0].rule == "syntax"


# ---------------------------------------------------------------------------
# CLI end-to-end on a temp tree
# ---------------------------------------------------------------------------

VIOLATION = """import jax

def build(f):
    return jax.jit(f)
"""


def _run_cli(root, *extra):
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--root", str(root),
         "--no-contracts", *extra],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/tmp"})


@pytest.fixture
def tmp_tree(tmp_path):
    mod = tmp_path / "src" / "repro" / "core"
    mod.mkdir(parents=True)
    (mod / "bad.py").write_text(VIOLATION)
    return tmp_path


def test_cli_exits_nonzero_on_violation(tmp_tree):
    res = _run_cli(tmp_tree, "src/repro")
    assert res.returncode == 1
    assert "[jit-donate]" in res.stdout
    assert "src/repro/core/bad.py:4" in res.stdout


def test_cli_baseline_silences(tmp_tree):
    res = _run_cli(tmp_tree, "--write-baseline", "src/repro")
    assert res.returncode == 0
    (tmp_tree / "analysis_baseline.txt").write_text(
        res.stdout.replace("TODO: one-line justification", "reviewed"))
    res2 = _run_cli(tmp_tree, "src/repro")
    assert res2.returncode == 0, res2.stdout + res2.stderr


def test_cli_clean_tree_exits_zero(tmp_tree):
    (tmp_tree / "src" / "repro" / "core" / "bad.py").write_text(
        "import jax\n\ndef build(f):\n"
        "    return jax.jit(f, donate_argnums=(0,))\n")
    res = _run_cli(tmp_tree, "src/repro")
    assert res.returncode == 0
    assert "clean" in res.stdout


def test_repo_tree_is_clean():
    """The repo's own lint findings are all fixed, pragma'd or baselined."""
    res = _run_cli(REPO)
    assert res.returncode == 0, res.stdout + res.stderr


# ---------------------------------------------------------------------------
# contracts
# ---------------------------------------------------------------------------

def test_compare_twin_catches_mismatch():
    import jax
    import jax.numpy as jnp

    from repro.analysis.contracts import _sds, compare_twin

    bad = compare_twin(
        "demo", "src/repro/kernels/demo",
        lambda: jax.eval_shape(lambda x: x.astype(jnp.float32),
                               _sds((4,), jnp.int8)),
        lambda: jax.eval_shape(lambda x: x.astype(jnp.bfloat16),
                               _sds((4,), jnp.int8)))
    assert len(bad) == 1 and bad[0].rule == "contract-kernel-twin"

    good = compare_twin(
        "demo", "src/repro/kernels/demo",
        lambda: jax.eval_shape(lambda x: x + 1, _sds((4,), jnp.float32)),
        lambda: jax.eval_shape(lambda x: x * 2, _sds((4,), jnp.float32)))
    assert good == []


def test_compare_twin_catches_build_failure():
    from repro.analysis.contracts import compare_twin

    def boom():
        raise ValueError("kernel build exploded")

    bad = compare_twin("demo", "src/repro/kernels/demo",
                       lambda: {"ok": 1}, boom)
    assert len(bad) == 1 and "exploded" in bad[0].message


def test_twin_coverage_matches_filesystem():
    """Every kernels/* package with a ref.py/kernel.py pair is in the
    twin registry — adding a kernel without contract coverage fails."""
    from repro.analysis.contracts import (covered_twin_packages,
                                          kernel_twin_packages)

    on_disk = kernel_twin_packages(REPO)
    assert on_disk, "expected ref/kernel twin packages under src/repro/kernels"
    assert set(on_disk) <= covered_twin_packages()


def test_scheme_contract_sweep_covers_registry():
    from repro.analysis.contracts import check_scheme_programs
    from repro.core.schemes import SCHEMES

    assert set(SCHEMES) >= {"opt", "discard", "async", "sync", "deadline"}
    assert check_scheme_programs() == []


def test_full_contract_sweep_clean():
    from repro.analysis.contracts import run_contracts

    assert run_contracts(repo_root=REPO) == []


# ---------------------------------------------------------------------------
# except-swallow
# ---------------------------------------------------------------------------

SWALLOW = """
def recv(sock):
    for _ in range(3):
        try:
            return sock.read()
        except Exception:
            continue
    try:
        sock.close()
    except:
        pass
"""

SERVE = "src/repro/serving/fl_server.py"


def test_except_swallow_fires_in_serving():
    got = findings_for(SWALLOW, SERVE, "except-swallow")
    assert len(got) == 2
    assert {f.line for f in got} == {6, 10}


def test_except_swallow_fires_in_transport_and_faults():
    for path in ("src/repro/core/transport.py", "src/repro/core/faults.py"):
        assert findings_for(SWALLOW, path, "except-swallow")


def test_except_swallow_silent_outside_scope():
    assert not findings_for(SWALLOW, CORE, "except-swallow")
    assert not findings_for(SWALLOW, KERN, "except-swallow")


def test_except_swallow_allows_handlers_that_act():
    src = """
    def recv(sock, log):
        try:
            return sock.read()
        except TimeoutError:
            pass                      # narrow type: deliberate retry
        except Exception as exc:
            log.warning("recv failed: %s", exc)
    """
    assert not findings_for(src, SERVE, "except-swallow")


def test_except_swallow_pragma_suppresses():
    src = ("def close(s):\n"
           "    try:\n"
           "        s.close()\n"
           "    except Exception:  # analysis: ok=except-swallow\n"
           "        pass\n")
    live = findings_for(src, SERVE, "except-swallow")
    assert live
    kept = filter_findings(live, Baseline(), {SERVE: src.splitlines()})
    assert kept == []


# ---------------------------------------------------------------------------
# output formats (github / sarif)
# ---------------------------------------------------------------------------

def test_render_github_annotations():
    from repro.analysis.findings import render_github
    fs = [Finding("src/a.py", 3, 1, "np-hot", "first\nsecond % line")]
    out = render_github(fs)
    assert out.startswith("::error file=src/a.py,line=3,col=1::")
    assert "%0A" in out and "%25" in out and "\n" not in out.strip()


def test_render_sarif_structure():
    import json

    from repro.analysis.findings import render_sarif
    fs = [Finding("src/a.py", 3, 1, "np-hot", "msg"),
          Finding("src/b.py", 0, 0, "ir-alias", "dropped")]
    doc = json.loads(render_sarif(fs, {"np-hot": "numpy in hot path"}))
    assert doc["version"] == "2.1.0"
    run = doc["runs"][0]
    assert {r["id"] for r in run["tool"]["driver"]["rules"]} == \
        {"np-hot", "ir-alias"}
    res = run["results"]
    assert res[0]["ruleId"] == "np-hot"
    loc = res[1]["locations"][0]["physicalLocation"]
    assert loc["region"]["startLine"] == 1       # clamped from 0


def test_cli_format_github(tmp_tree):
    res = _run_cli(tmp_tree, "--format", "github", "src/repro")
    assert res.returncode == 1
    assert "::error file=src/repro/core/bad.py,line=4" in res.stdout


def test_cli_format_sarif(tmp_tree):
    import json

    res = _run_cli(tmp_tree, "--format", "sarif", "src/repro")
    assert res.returncode == 1
    doc = json.loads(res.stdout)
    assert doc["runs"][0]["results"][0]["ruleId"] == "jit-donate"


# ---------------------------------------------------------------------------
# stale-baseline lifecycle: --strict-baseline and --prune-baseline
# ---------------------------------------------------------------------------

STALE_ENTRY = ("src/repro/core/gone.py :: jit-donate :: return jax.jit(f) "
               ":: was reviewed, file since deleted\n")


def test_cli_strict_baseline_fails_on_stale(tmp_tree):
    (tmp_tree / "src" / "repro" / "core" / "bad.py").write_text(
        "import jax\n\ndef build(f):\n"
        "    return jax.jit(f, donate_argnums=(0,))\n")
    (tmp_tree / "analysis_baseline.txt").write_text(STALE_ENTRY)
    res = _run_cli(tmp_tree, "src/repro")
    assert res.returncode == 0               # stale is a note by default
    assert "stale baseline entry" in res.stderr
    res = _run_cli(tmp_tree, "--strict-baseline", "src/repro")
    assert res.returncode == 1
    assert "stale" in res.stderr


def test_cli_prune_baseline_rewrites_file(tmp_tree):
    res = _run_cli(tmp_tree, "--write-baseline", "src/repro")
    live_entries = res.stdout.replace("TODO: one-line justification",
                                      "reviewed")
    (tmp_tree / "analysis_baseline.txt").write_text(
        live_entries + STALE_ENTRY)
    res = _run_cli(tmp_tree, "--prune-baseline", "--strict-baseline",
                   "src/repro")
    assert res.returncode == 0, res.stdout + res.stderr
    assert "pruned 1 stale" in res.stderr
    kept = (tmp_tree / "analysis_baseline.txt").read_text()
    assert "gone.py" not in kept
    assert "bad.py" in kept                  # live entry survives the prune
    res = _run_cli(tmp_tree, "--strict-baseline", "src/repro")
    assert res.returncode == 0, res.stdout + res.stderr
