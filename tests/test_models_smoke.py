"""Per-architecture smoke tests (required deliverable f).

Each assigned arch instantiates its REDUCED family variant (2 layers,
d_model<=256, <=4 experts) and runs one forward + one train step + (for
decoder archs) one decode step on CPU, asserting output shapes and no NaNs.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.models.inputs import decode_specs, materialize, train_specs
from repro.optim import sgd
from repro.training import create_train_state, make_train_step

B, S = 2, 16


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = get_config(request.param).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    inputs = materialize(train_specs(cfg, B, S), cfg, seed=1)
    return request.param, cfg, model, params, inputs


def test_reduced_config_limits(arch_setup):
    _, cfg, *_ = arch_setup
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4


def test_forward_shapes_no_nan(arch_setup):
    arch, cfg, model, params, inputs = arch_setup
    logits, aux = jax.jit(lambda p, i: model.forward(p, i))(params, inputs)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert not bool(jnp.isnan(logits).any()), arch
    assert not bool(jnp.isnan(aux)), arch


def test_one_train_step(arch_setup):
    arch, cfg, model, params, inputs = arch_setup
    opt = sgd(1e-2)
    state = create_train_state(params, opt)
    step = jax.jit(make_train_step(model, opt))
    new_state, metrics = step(state, inputs)
    assert float(metrics["loss"]) > 0
    assert not bool(jnp.isnan(metrics["loss"])), arch
    assert int(new_state.step) == 1
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: bool(jnp.any(a != b)), state.params, new_state.params)
    assert any(jax.tree_util.tree_leaves(moved)), arch


def test_decode_step(arch_setup):
    arch, cfg, model, params, inputs = arch_setup
    if model.decode is None:
        assert cfg.is_encoder_only          # hubert: documented skip
        pytest.skip("encoder-only arch has no decode")
    st = model.init_decode_state(B, 32, jnp.float32)
    dins = materialize(decode_specs(cfg, B, 32), cfg, seed=2)
    logits, st2 = jax.jit(lambda p, t, s, pos: model.decode(p, t, s, pos))(
        params, dins["token"], st, dins["position"])
    assert logits.shape == (B, 1, cfg.vocab_padded)
    assert not bool(jnp.isnan(logits).any()), arch
    # state structure preserved
    assert (jax.tree_util.tree_structure(st)
            == jax.tree_util.tree_structure(st2))


def test_loss_decreases_two_steps(arch_setup):
    arch, cfg, model, params, inputs = arch_setup
    opt = sgd(5e-2)
    state = create_train_state(params, opt)
    step = jax.jit(make_train_step(model, opt))
    losses = []
    for _ in range(3):
        state, metrics = step(state, inputs)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], (arch, losses)
