"""numpy ↔ jax channel-core equivalence (Section II-A, eqs. 1–7).

``core/channel_lib`` is one implementation bound to two backends; these
tests pin the jax ``FleetState`` path to the numpy host reference: same
positions/K → same rates, P_LOS and path loss, and the on-device
Gilbert–Elliott chain reproduces the host chain's stationary marginal and
shared transition probabilities (including the go_bad clamp as
``outage_prob → 1``)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import channel_lib as cl
from repro.core.channel import UAVFleet

P = cl.ChannelParams()


def _positions(n=64, seed=0):
    rng = np.random.default_rng(seed)
    r = P.cell_radius_m * np.sqrt(rng.random(n))
    ang = rng.random(n) * 2 * np.pi
    z = rng.uniform(*P.uav_z_range, n)
    return np.stack([r * np.cos(ang), r * np.sin(ang), z], axis=-1)


def test_numpy_jax_equivalence_eqs_1_to_7():
    pos = _positions()
    k_db = np.random.default_rng(1).uniform(*P.k_db_range, len(pos))
    jpos, jk = jnp.asarray(pos, jnp.float32), jnp.asarray(k_db, jnp.float32)

    for host, dev in [
        (cl.distance(pos, P.bs_height_m),
         cl.distance(jpos, P.bs_height_m, xp=jnp)),
        (cl.p_los(cl.elevation_deg(pos, P.bs_height_m), P),
         cl.p_los(cl.elevation_deg(jpos, P.bs_height_m, xp=jnp), P, xp=jnp)),
        (cl.path_loss_db(pos, P), cl.path_loss_db(jpos, P, xp=jnp)),
        (cl.rate_bps(pos, k_db, P), cl.rate_bps(jpos, jk, P, xp=jnp)),
    ]:
        np.testing.assert_allclose(np.asarray(dev), host, rtol=2e-4)


def test_rate_bandwidth_ratio_traced():
    """bandwidth_ratio may ride a vmapped config axis."""
    pos = jnp.asarray(_positions(8), jnp.float32)
    k = jnp.full((8,), 3.0)
    rates = jax.vmap(lambda w: cl.rate_bps(pos, k, P, w, xp=jnp))(
        jnp.asarray([0.5, 1.0]))
    assert rates.shape == (2, 8)
    # more bandwidth -> more rate (eq. 7 is monotone in n_i·B for these SNRs)
    assert bool(jnp.all(rates[1] > rates[0]))


def test_outage_transitions_clamped():
    """go_bad solved from the stationary balance exceeds 1 as
    outage_prob → 1; the shared helper clamps it to a probability."""
    for prob in (0.0, 0.1, 0.3, 0.6, 0.9, 0.99, 0.999, 1.0):
        for pers in (0.0, 0.5, 0.7, 0.99):
            go, stay = cl.outage_transitions(prob, pers)
            assert 0.0 <= go <= 1.0
            assert 0.0 <= stay <= 1.0
    # the unclamped region still solves the stationary equation exactly
    go, stay = cl.outage_transitions(0.3, 0.7)
    pi = go / (go + (1.0 - stay))
    assert pi == pytest.approx(0.3)


def test_host_chain_uses_clamped_transitions():
    """Pre-fix, outage_prob=0.95/persistence=0.7 compared uniforms against
    go_bad=5.7; the chain must behave as a (clamped) probability."""
    p = cl.ChannelParams(outage_prob=0.95, outage_persistence=0.7)
    fleet = UAVFleet(500, p, seed=0)
    draws = np.stack([fleet.outages() for _ in range(200)])
    go, stay = cl.outage_transitions(0.95, 0.7)
    assert go == 1.0
    # with go_bad=1 every good state flips bad; stationary = 1/(2-stay)
    expect = 1.0 / (1.0 + (1.0 - stay))
    assert abs(draws[50:].mean() - expect) < 0.03


def test_fleet_outage_chain_stationary():
    """Device chain hits the host chain's stationary marginal (eq. is the
    shared outage_transitions)."""
    state = cl.fleet_init(jax.random.PRNGKey(3), 1500, P)

    def step(s, _):
        s, bad = cl.fleet_outage_step(s, P)
        return s, bad

    _, draws = jax.lax.scan(step, state, None, length=250)
    draws = np.asarray(draws)
    assert abs(draws[20:].mean() - P.outage_prob) < 0.03
    prev, cur = draws[:-1].ravel(), draws[1:].ravel()
    assert abs(cur[prev].mean() - P.outage_persistence) < 0.05


def test_fleet_moves_stay_in_cell():
    state = cl.fleet_init(jax.random.PRNGKey(0), 100, P)

    def step(s, _):
        return cl.fleet_move(s, P, 15.0, 1.0), ()

    state, _ = jax.lax.scan(step, state, None, length=50)
    pos = np.asarray(state.pos)
    assert np.all(np.linalg.norm(pos[:, :2], axis=-1)
                  <= P.cell_radius_m + 1e-3)
    assert np.all((pos[:, 2] >= P.uav_z_range[0])
                  & (pos[:, 2] <= P.uav_z_range[1]))


def test_fleet_init_and_fading_ranges():
    state = cl.fleet_init(jax.random.PRNGKey(7), 400, P)
    assert np.all(np.linalg.norm(np.asarray(state.pos)[:, :2], axis=-1)
                  <= P.cell_radius_m + 1e-3)
    k0 = np.asarray(state.k_db)
    assert np.all((k0 >= P.k_db_range[0]) & (k0 <= P.k_db_range[1]))
    state2 = cl.fleet_resample_fading(state, P)
    k1 = np.asarray(state2.k_db)
    assert np.all((k1 >= P.k_db_range[0]) & (k1 <= P.k_db_range[1]))
    assert not np.allclose(k0, k1)
    # seeding at the stationary marginal
    assert abs(np.asarray(state.bad).mean() - P.outage_prob) < 0.08


# The hypothesis property tests over positions/K ranges live in
# tests/test_property.py, behind its existing importorskip gate (a
# module-level importorskip here would skip this whole file).
