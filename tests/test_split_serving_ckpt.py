"""Split learning, serving consistency, checkpoint roundtrip."""
import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (latest_step, restore_aux, restore_checkpoint,
                              save_checkpoint)
from repro.checkpoint.msgpack_ckpt import _decode_leaf, _encode_leaf
from repro.configs import get_config
from repro.core.split import merge_stacked, split_stacked
from repro.models import build_model
from repro.models import cnn as cnn_mod
from repro.serving import generate, prefill


def test_cnn_split_merge_roundtrip():
    params = cnn_mod.init_cnn(jax.random.PRNGKey(0))
    ue, bs = cnn_mod.split_params(params, 2)
    assert set(ue) == {"conv1", "conv2"} and set(bs) == {"fc1", "fc2", "fc3"}
    x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 28, 28, 1)),
                    jnp.float32)
    full = cnn_mod.forward(params, x)
    cut_act = cnn_mod.forward(ue, x, start=0, stop=2)      # UE side
    composed = cnn_mod.forward(bs, cut_act, start=2)       # BS side
    np.testing.assert_allclose(full, composed, rtol=1e-6)


def test_transformer_split_merge_identity():
    cfg = get_config("llama3.2-1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ue, bs = split_stacked(params, 1)
    merged = merge_stacked(ue, bs)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(merged)):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "rwkv6-7b", "hymba-1.5b"])
def test_decode_matches_full_forward(arch):
    """Token-by-token decode reproduces the full-sequence forward logits."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S = 12
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (2, S)), jnp.int32)
    full_logits, _ = model.forward(params, {"tokens": tokens})
    last_logits, _, _ = prefill(model, params, tokens, context_len=S)
    np.testing.assert_allclose(
        np.asarray(last_logits[:, 0], np.float32),
        np.asarray(full_logits[:, -1], np.float32), atol=2e-3, rtol=2e-3)


def test_generate_shapes_and_determinism():
    cfg = get_config("llama3.2-1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    out1 = generate(model, params, prompt, max_new=6, context_len=16)
    out2 = generate(model, params, prompt, max_new=6, context_len=16)
    assert out1.shape == (1, 6)
    np.testing.assert_array_equal(out1, out2)       # greedy is deterministic
    assert int(out1.max()) < cfg.vocab_padded


def test_checkpoint_roundtrip_with_bf16():
    tree = {"a": jnp.asarray([[1.5, -2.0]], jnp.bfloat16),
            "b": {"step": jnp.asarray(7, jnp.int32),
                  "w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)}}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, tree)
        save_checkpoint(d, 10, tree)
        assert latest_step(d) == 10
        got = restore_checkpoint(d, 10, tree)
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(got)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_checkpoint_rejects_shape_mismatch():
    tree = {"w": jnp.zeros((2, 2))}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 0, tree)
        with pytest.raises(ValueError):
            restore_checkpoint(d, 0, {"w": jnp.zeros((3,))})


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16, np.int32])
def test_decoded_leaves_are_writable(dtype):
    """np.frombuffer over msgpack bytes is read-only; _decode_leaf must
    copy so restored pytrees behave like fresh arrays (the FL server
    mutates restored fleet state in place)."""
    src = jnp.arange(6, dtype=dtype).reshape(2, 3)
    arr = _decode_leaf(_encode_leaf(src))
    assert arr.flags.writeable
    arr[0, 0] = arr[0, 1]  # must not raise "assignment destination read-only"
    restored = np.asarray(_decode_leaf(_encode_leaf(src)), np.float32)
    np.testing.assert_array_equal(restored, np.asarray(src, np.float32))


def test_restore_validates_against_manifest():
    """Payload/manifest disagreement is reported as corruption naming the
    leaf, not a silent mis-shaped restore."""
    tree = {"w": jnp.ones((2, 3)), "b": jnp.zeros((4,))}
    with tempfile.TemporaryDirectory() as d:
        path = save_checkpoint(d, 0, tree)
        mpath = os.path.join(path, "MANIFEST.json")
        with open(mpath) as f:
            manifest = json.load(f)
        manifest["leaves"][1]["shape"] = [7, 7]
        with open(mpath, "w") as f:
            json.dump(manifest, f)
        with pytest.raises(ValueError, match="leaf 1.*corrupt"):
            restore_checkpoint(d, 0, tree)
        # leaf-count disagreement is also inconsistency, not an index error
        manifest["leaves"] = manifest["leaves"][:1]
        with open(mpath, "w") as f:
            json.dump(manifest, f)
        with pytest.raises(ValueError, match="inconsistent"):
            restore_checkpoint(d, 0, tree)


def test_latest_step_skips_crashed_writer():
    """A step directory without its COMMIT marker (writer died mid-save)
    must be invisible: resume from the last committed step, no error."""
    tree = {"w": jnp.zeros((2, 2))}
    with tempfile.TemporaryDirectory() as d:
        assert latest_step(d) is None
        save_checkpoint(d, 1, tree)
        # simulate a crash during the step-5 save: payload written, no COMMIT
        half = save_checkpoint(d, 5, tree)
        os.remove(os.path.join(half, "COMMIT"))
        # and a stray digit-named file that is not a step directory at all
        open(os.path.join(d, "9"), "w").close()
        assert latest_step(d) == 1
        got = restore_checkpoint(d, 1, tree)
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.asarray(tree["w"]))


def test_aux_sidecar_roundtrip():
    tree = {"w": jnp.zeros((2,))}
    aux = {"round": 3, "rng": {"state": [1, 2, 3]}, "note": "hi"}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, tree, aux=aux)
        assert restore_aux(d, 3) == aux
        save_checkpoint(d, 4, tree)  # no aux saved
        assert restore_aux(d, 4) is None
