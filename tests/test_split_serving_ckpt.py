"""Split learning, serving consistency, checkpoint roundtrip."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.core.split import merge_stacked, split_stacked
from repro.models import build_model
from repro.models import cnn as cnn_mod
from repro.models.inputs import materialize, prefill_specs
from repro.serving import generate, prefill


def test_cnn_split_merge_roundtrip():
    params = cnn_mod.init_cnn(jax.random.PRNGKey(0))
    ue, bs = cnn_mod.split_params(params, 2)
    assert set(ue) == {"conv1", "conv2"} and set(bs) == {"fc1", "fc2", "fc3"}
    x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 28, 28, 1)),
                    jnp.float32)
    full = cnn_mod.forward(params, x)
    cut_act = cnn_mod.forward(ue, x, start=0, stop=2)      # UE side
    composed = cnn_mod.forward(bs, cut_act, start=2)       # BS side
    np.testing.assert_allclose(full, composed, rtol=1e-6)


def test_transformer_split_merge_identity():
    cfg = get_config("llama3.2-1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ue, bs = split_stacked(params, 1)
    merged = merge_stacked(ue, bs)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(merged)):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "rwkv6-7b", "hymba-1.5b"])
def test_decode_matches_full_forward(arch):
    """Token-by-token decode reproduces the full-sequence forward logits."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S = 12
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (2, S)), jnp.int32)
    full_logits, _ = model.forward(params, {"tokens": tokens})
    last_logits, _, _ = prefill(model, params, tokens, context_len=S)
    np.testing.assert_allclose(
        np.asarray(last_logits[:, 0], np.float32),
        np.asarray(full_logits[:, -1], np.float32), atol=2e-3, rtol=2e-3)


def test_generate_shapes_and_determinism():
    cfg = get_config("llama3.2-1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    out1 = generate(model, params, prompt, max_new=6, context_len=16)
    out2 = generate(model, params, prompt, max_new=6, context_len=16)
    assert out1.shape == (1, 6)
    np.testing.assert_array_equal(out1, out2)       # greedy is deterministic
    assert int(out1.max()) < cfg.vocab_padded


def test_checkpoint_roundtrip_with_bf16():
    tree = {"a": jnp.asarray([[1.5, -2.0]], jnp.bfloat16),
            "b": {"step": jnp.asarray(7, jnp.int32),
                  "w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)}}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, tree)
        save_checkpoint(d, 10, tree)
        assert latest_step(d) == 10
        got = restore_checkpoint(d, 10, tree)
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(got)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_checkpoint_rejects_shape_mismatch():
    tree = {"w": jnp.zeros((2, 2))}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 0, tree)
        with pytest.raises(ValueError):
            restore_checkpoint(d, 0, {"w": jnp.zeros((3,))})
