"""Sweep engine tests (core/sweep + the on-device round's control plane).

Device runs use their own jax.random streams, so trajectories are not
bit-compared against the host reference; instead the *deterministic* parts
of the control plane are pinned exactly (greedy selection port, probe
schedule mask) and the stochastic engine is checked for invariants,
reproducibility and mesh-sharding consistency.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import latency as lat
from repro.core.fused_round import probe_schedule_mask
from repro.core.hsfl import HSFLConfig, build_sim_arrays
from repro.core.selection import schedule_users, select_users_jax
from repro.core.sweep import (SweepSpec, compile_spec, run_hsfl_on_device,
                              run_sweep)
from repro.core.transmission import scheduled_epochs


def tiny_base(**kw):
    base = dict(rounds=2, n_uavs=8, k_select=4, n_train=400, n_test=100,
                steps_per_epoch=2, local_epochs=4)
    base.update(kw)
    return HSFLConfig(**base)


# -- deterministic control-plane ports pinned to the host reference ----------

def test_probe_schedule_mask_matches_scheduled_epochs():
    for e in (2, 3, 4, 6, 8, 12):
        for b in range(1, 9):
            want = set(scheduled_epochs(e, b))
            got = {e_t for e_t in range(1, e + 1)
                   if bool(probe_schedule_mask(e_t, e, float(b)))}
            assert got == want, (e, b, got, want)


def test_select_users_jax_matches_host_greedy():
    rng = np.random.default_rng(0)
    for trial in range(12):
        n = int(rng.integers(3, 25))
        k = int(rng.integers(2, 9))
        b = int(rng.integers(1, 5))
        tau = float(rng.uniform(6, 12))
        rates0 = rng.uniform(1e6, 1e8, n)
        flops = rng.uniform(0.8e8, 4e8, n)
        samples = rng.integers(50, 400, n)
        devices = [lat.DeviceProfile(flops_per_sec=float(f)) for f in flops]
        wls = [lat.WorkloadProfile(local_epochs=6, samples=int(s))
               for s in samples]
        host = schedule_users(rates0, devices, wls, 10e6, 2.5e6, b, tau, k)
        sel, mode_sl, valid, n_taken, _, _ = select_users_jax(
            jnp.asarray(rates0, jnp.float32), jnp.asarray(flops, jnp.float32),
            jnp.asarray(samples, jnp.float32), b=jnp.float32(b),
            tau_max=jnp.float32(tau), k_select=k, model_bytes=10e6,
            ue_model_bytes=2.5e6, local_epochs=6)
        got = [(int(sel[j]), "SL" if bool(mode_sl[j]) else "FL")
               for j in range(k) if bool(valid[j])]
        assert got == [(u.index, u.mode) for u in host], trial
        assert int(n_taken) == len(host)


# -- SweepSpec compiler -------------------------------------------------------

def test_compile_spec_groups_and_axes():
    spec = SweepSpec(base=tiny_base(), seeds=(0, 1),
                     distributions=("iid", "noniid"),
                     schemes=(("opt", {"b": 2.0}), ("discard", {"b": 1.0})),
                     tau_max=(8.0, 9.0))
    groups = compile_spec(spec)
    assert [g.scheme for g in groups] == ["opt", "discard"]
    for g in groups:
        assert len(g.sims) == 4               # 2 seeds x 2 distributions
        assert len(g.cfgs) == 2               # tau axis
    assert {c["b"] for c in groups[0].cfgs} == {2.0}
    assert {c["b"] for c in groups[1].cfgs} == {1.0}
    assert {c["tau_max"] for c in groups[0].cfgs} == {8.0, 9.0}


def test_compile_spec_rejects_static_pin():
    spec = SweepSpec(base=tiny_base(), schemes=(("opt", {"rounds": 3}),))
    with pytest.raises(ValueError):
        compile_spec(spec)


def test_build_sim_arrays_shapes_and_padding():
    cfg = tiny_base()
    sim = build_sim_arrays(cfg)
    n = cfg.n_uavs
    assert sim["client_x"].shape[0] == n
    assert sim["client_len"].max() == sim["client_x"].shape[1]
    assert sim["flops"].shape == (n,) and np.all(sim["flops"] > 0)
    assert sim["test_x"].shape[0] == cfg.n_test
    padded = build_sim_arrays(cfg, pad_len=sim["client_x"].shape[1] + 7)
    assert padded["client_x"].shape[1] == sim["client_x"].shape[1] + 7
    np.testing.assert_array_equal(padded["client_len"], sim["client_len"])


# -- engine smoke: invariants, reproducibility, sharding ----------------------

@pytest.fixture(scope="module")
def small_sweep():
    spec = SweepSpec(base=tiny_base(), seeds=(0, 1),
                     schemes=(("opt", {"b": 2.0}), ("async", {"b": 1.0})))
    return spec, run_sweep(spec, mesh=None)


def test_sweep_shapes_and_invariants(small_sweep):
    spec, res = small_sweep
    assert res.n_simulations == 4
    k = spec.base.k_select
    for g in res.groups:
        m = g.metrics
        assert m["test_acc"].shape == (2, 1, spec.base.rounds)
        assert np.all((m["selected"] >= 0) & (m["selected"] <= k))
        assert np.all(m["arrived"] + m["dropped"] + m["delayed"]
                      + m["rescued"] <= m["selected"])
        assert np.all((m["test_acc"] >= 0) & (m["test_acc"] <= 1))
        assert np.all(np.isfinite(m["test_loss"]))
        assert np.all(m["bytes_sent"] >= 0)
    opt, asy = res.groups
    assert np.all(opt.metrics["delayed"] == 0)      # opt never delays
    assert np.all(asy.metrics["rescued"] == 0)      # async never rescues


def test_sweep_is_deterministic(small_sweep):
    spec, res = small_sweep
    res2 = run_sweep(spec, mesh=None)
    for g1, g2 in zip(res.groups, res2.groups):
        for key in g1.metrics:
            np.testing.assert_array_equal(g1.metrics[key], g2.metrics[key])


def test_sweep_sim_log_roundtrip(small_sweep):
    spec, res = small_sweep
    log = res.groups[0].sim_log(1, 0)
    assert len(log.rounds) == spec.base.rounds
    s = log.summary()
    assert 0.0 <= s["final_acc"] <= 1.0
    assert s["rounds"] == spec.base.rounds


def test_sweep_config_axis_orders_budget():
    """More budget -> never fewer opportunistic rescues (same channel/data
    stream across the vmapped config axis: common random numbers)."""
    spec = SweepSpec(base=tiny_base(rounds=3, local_epochs=6), seeds=(1,),
                     b=(1.0, 3.0))
    res = run_sweep(spec, mesh=None)
    resc = res.groups[0].metrics["rescued"].sum(axis=-1)[0]   # (C,)
    sends = res.groups[0].metrics["bytes_sent"].sum(axis=-1)[0]
    assert resc[0] == 0                       # b=1: no snapshots exist
    assert sends[1] >= sends[0]               # budget can only add uplink


def test_sweep_on_mesh_matches_unsharded(small_sweep):
    """The mesh path (1 device in the tier-1 run; 2+ forced host devices in
    the CI sweep-smoke job) must not change results."""
    from repro.launch.mesh import make_sweep_mesh
    spec, res = small_sweep
    res_mesh = run_sweep(spec, mesh=make_sweep_mesh())
    for g1, g2 in zip(res.groups, res_mesh.groups):
        for key in g1.metrics:
            np.testing.assert_allclose(g1.metrics[key], g2.metrics[key],
                                       rtol=1e-5, atol=1e-6)


def test_run_hsfl_on_device_single_sim():
    log = run_hsfl_on_device(tiny_base(scheme="discard", b=1))
    assert len(log.rounds) == 2
    assert all(r.selected <= 4 for r in log.rounds)
