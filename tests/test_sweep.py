"""Sweep engine tests (core/sweep + the on-device round's control plane).

Device runs use their own jax.random streams, so trajectories are not
bit-compared against the host reference; instead the *deterministic* parts
of the control plane are pinned exactly (greedy selection port, probe
schedule mask) and the stochastic engine is checked for invariants,
reproducibility and mesh-sharding consistency.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import latency as lat
from repro.core.fused_round import probe_schedule_mask
from repro.core.hsfl import (HSFLConfig, build_sim_arrays,
                             model_compress_ratio)
from repro.core.selection import schedule_users, select_users_jax
from repro.core.sweep import (B_SWEPT, SweepSpec, compile_spec, fig3c_spec,
                              run_hsfl_on_device, run_sweep)
from repro.core.transmission import scheduled_epochs


def tiny_base(**kw):
    base = dict(rounds=2, n_uavs=8, k_select=4, n_train=400, n_test=100,
                steps_per_epoch=2, local_epochs=4)
    base.update(kw)
    return HSFLConfig(**base)


# -- deterministic control-plane ports pinned to the host reference ----------

def test_probe_schedule_mask_matches_scheduled_epochs():
    for e in (2, 3, 4, 6, 8, 12):
        for b in range(1, 9):
            want = set(scheduled_epochs(e, b))
            got = {e_t for e_t in range(1, e + 1)
                   if bool(probe_schedule_mask(e_t, e, float(b)))}
            assert got == want, (e, b, got, want)


def test_select_users_jax_matches_host_greedy():
    rng = np.random.default_rng(0)
    for trial in range(12):
        n = int(rng.integers(3, 25))
        k = int(rng.integers(2, 9))
        b = int(rng.integers(1, 5))
        tau = float(rng.uniform(6, 12))
        rates0 = rng.uniform(1e6, 1e8, n)
        flops = rng.uniform(0.8e8, 4e8, n)
        samples = rng.integers(50, 400, n)
        devices = [lat.DeviceProfile(flops_per_sec=float(f)) for f in flops]
        wls = [lat.WorkloadProfile(local_epochs=6, samples=int(s))
               for s in samples]
        host = schedule_users(rates0, devices, wls, 10e6, 2.5e6, b, tau, k)
        sel, mode_sl, valid, n_taken, _, _ = select_users_jax(
            jnp.asarray(rates0, jnp.float32), jnp.asarray(flops, jnp.float32),
            jnp.asarray(samples, jnp.float32), b=jnp.float32(b),
            tau_max=jnp.float32(tau), k_select=k, model_bytes=10e6,
            ue_model_bytes=2.5e6, local_epochs=6)
        got = [(int(sel[j]), "SL" if bool(mode_sl[j]) else "FL")
               for j in range(k) if bool(valid[j])]
        assert got == [(u.index, u.mode) for u in host], trial
        assert int(n_taken) == len(host)


# -- SweepSpec compiler -------------------------------------------------------

def test_compile_spec_groups_and_axes():
    spec = SweepSpec(base=tiny_base(), seeds=(0, 1),
                     distributions=("iid", "noniid"),
                     schemes=(("opt", {"b": 2.0}), ("discard", {"b": 1.0})),
                     tau_max=(8.0, 9.0))
    groups = compile_spec(spec)
    assert [g.scheme for g in groups] == ["opt", "discard"]
    for g in groups:
        assert len(g.sims) == 4               # 2 seeds x 2 distributions
        assert len(g.cfgs) == 2               # tau axis
    assert {c["b"] for c in groups[0].cfgs} == {2.0}
    assert {c["b"] for c in groups[1].cfgs} == {1.0}
    assert {c["tau_max"] for c in groups[0].cfgs} == {8.0, 9.0}


def test_compile_spec_rejects_static_pin():
    spec = SweepSpec(base=tiny_base(), schemes=(("opt", {"rounds": 3}),))
    with pytest.raises(ValueError):
        compile_spec(spec)


def test_compile_spec_swept_b_is_poisoned():
    """When b rides the traced config axis, ``base.b`` must NOT silently pin
    to the first column (the old behaviour): it is poisoned to B_SWEPT so
    any static consumer fails loudly, and a static ``schedule_override``
    (the one genuinely b-coupled static) is rejected outright."""
    spec = SweepSpec(base=tiny_base(), seeds=(0,), b=(1.0, 2.0, 3.0))
    g = compile_spec(spec)[0]
    assert g.base.b == B_SWEPT
    assert [c["b"] for c in g.cfgs] == [1.0, 2.0, 3.0]
    # the real Fig. 3(c) panel spec sweeps b the same way
    for g3 in compile_spec(fig3c_spec(rounds=2)[0]):
        assert g3.base.b == B_SWEPT
    # a single-valued b axis still pins base.b for static consumers
    assert compile_spec(SweepSpec(base=tiny_base(), b=(4.0,)))[0].base.b == 4
    bad = SweepSpec(base=tiny_base(schedule_override=(1,)), b=(1.0, 2.0))
    with pytest.raises(ValueError, match="schedule_override"):
        compile_spec(bad)


def test_compile_spec_group_statics_labels_and_lowering():
    """``use_delta_codec`` pins as a *group static* (codec on/off groups in
    one spec), labels tell same-scheme groups apart, and a b=1 discard
    group lowers onto the OPT program (discard is opt with zero probes)."""
    spec = SweepSpec(base=tiny_base(), seeds=(0,),
                     schemes=(("opt", {"b": 2.0}),
                              ("opt", {"b": 2.0, "use_delta_codec": True}),
                              ("discard", {"b": 1.0})))
    gs = compile_spec(spec)
    assert [g.label for g in gs] == ["opt", "opt+codec", "discard"]
    assert gs[1].base.use_delta_codec and not gs[0].base.use_delta_codec
    assert gs[2].program_scheme == "opt"
    assert compile_spec(spec, lower_discard=False)[2].program_scheme \
        == "discard"
    # discard at b != 1 is NOT opt (the budget still shapes selection):
    # it must keep its dedicated program
    spec2 = SweepSpec(base=tiny_base(), schemes=(("discard", {"b": 2.0}),))
    assert compile_spec(spec2)[0].program_scheme == "discard"


def test_build_sim_arrays_shapes_and_padding():
    cfg = tiny_base()
    sim = build_sim_arrays(cfg)
    n = cfg.n_uavs
    assert sim["client_x"].shape[0] == n
    assert sim["client_len"].max() == sim["client_x"].shape[1]
    assert sim["flops"].shape == (n,) and np.all(sim["flops"] > 0)
    assert sim["test_x"].shape[0] == cfg.n_test
    padded = build_sim_arrays(cfg, pad_len=sim["client_x"].shape[1] + 7)
    assert padded["client_x"].shape[1] == sim["client_x"].shape[1] + 7
    np.testing.assert_array_equal(padded["client_len"], sim["client_len"])


# -- engine smoke: invariants, reproducibility, sharding ----------------------

@pytest.fixture(scope="module")
def small_sweep():
    spec = SweepSpec(base=tiny_base(), seeds=(0, 1),
                     schemes=(("opt", {"b": 2.0}), ("async", {"b": 1.0})))
    return spec, run_sweep(spec, mesh=None)


def test_sweep_shapes_and_invariants(small_sweep):
    spec, res = small_sweep
    assert res.n_simulations == 4
    k = spec.base.k_select
    for g in res.groups:
        m = g.metrics
        assert m["test_acc"].shape == (2, 1, spec.base.rounds)
        assert np.all((m["selected"] >= 0) & (m["selected"] <= k))
        assert np.all(m["arrived"] + m["dropped"] + m["delayed"]
                      + m["rescued"] <= m["selected"])
        assert np.all((m["test_acc"] >= 0) & (m["test_acc"] <= 1))
        assert np.all(np.isfinite(m["test_loss"]))
        assert np.all(m["bytes_sent"] >= 0)
    opt, asy = res.groups
    assert np.all(opt.metrics["delayed"] == 0)      # opt never delays
    assert np.all(asy.metrics["rescued"] == 0)      # async never rescues


def test_sweep_is_deterministic(small_sweep):
    spec, res = small_sweep
    res2 = run_sweep(spec, mesh=None)
    for g1, g2 in zip(res.groups, res2.groups):
        for key in g1.metrics:
            np.testing.assert_array_equal(g1.metrics[key], g2.metrics[key])


def test_sweep_sim_log_roundtrip(small_sweep):
    spec, res = small_sweep
    log = res.groups[0].sim_log(1, 0)
    assert len(log.rounds) == spec.base.rounds
    s = log.summary()
    assert 0.0 <= s["final_acc"] <= 1.0
    assert s["rounds"] == spec.base.rounds


def test_sweep_config_axis_orders_budget():
    """More budget -> never fewer opportunistic rescues (same channel/data
    stream across the vmapped config axis: common random numbers)."""
    spec = SweepSpec(base=tiny_base(rounds=3, local_epochs=6), seeds=(1,),
                     b=(1.0, 3.0))
    res = run_sweep(spec, mesh=None)
    resc = res.groups[0].metrics["rescued"].sum(axis=-1)[0]   # (C,)
    sends = res.groups[0].metrics["bytes_sent"].sum(axis=-1)[0]
    assert resc[0] == 0                       # b=1: no snapshots exist
    assert sends[1] >= sends[0]               # budget can only add uplink


def test_sweep_on_mesh_matches_unsharded(small_sweep):
    """The mesh path (1 device in the tier-1 run; 2+ forced host devices in
    the CI sweep-smoke job) must not change results."""
    from repro.launch.mesh import make_sweep_mesh
    spec, res = small_sweep
    res_mesh = run_sweep(spec, mesh=make_sweep_mesh())
    for g1, g2 in zip(res.groups, res_mesh.groups):
        for key in g1.metrics:
            np.testing.assert_allclose(g1.metrics[key], g2.metrics[key],
                                       rtol=1e-5, atol=1e-6)


def test_run_hsfl_on_device_single_sim():
    log = run_hsfl_on_device(tiny_base(scheme="discard", b=1))
    assert len(log.rounds) == 2
    assert all(r.selected <= 4 for r in log.rounds)


# -- int8 delta-codec snapshots on the device round / sweep engine ------------

@pytest.fixture(scope="module")
def codec_panel():
    """A Fig. 3(b)-shaped panel with codec snapshots: opt(b=2) vs async vs
    discard, all on the delta codec."""
    spec = SweepSpec(base=tiny_base(rounds=3, local_epochs=6,
                                    use_delta_codec=True),
                     seeds=(0,),
                     schemes=(("opt", {"b": 2.0}), ("async", {"b": 1.0}),
                              ("discard", {"b": 1.0})))
    return spec, run_sweep(spec, mesh=None)


def test_codec_panel_compiles_two_programs(codec_panel):
    """Acceptance: a fig3b-style codec panel is at most 2 compiled programs
    — opt-codec + async; discard rides the opt program pinned at b=1."""
    spec, res = codec_panel
    assert res.n_programs == 2
    assert [g.program_id for g in res.groups] == [0, 1, 0]
    assert [g.label for g in res.groups] == ["opt+codec", "async+codec",
                                             "discard+codec"]
    for g in res.groups:
        m = g.metrics
        assert np.all((m["test_acc"] >= 0) & (m["test_acc"] <= 1))
        assert np.all(np.isfinite(m["test_loss"]))
        # codec payload accounting: every wire byte is ≤ codec_ratio of the
        # uncompressed model payload (plus the small SL activation rider)
        cap = (0.26 * spec.base.model_bytes * spec.base.k_select
               * max(spec.base.b, 2) + 1e6)
        assert np.all(m["bytes_sent"] <= cap)


def test_codec_discard_lowering_bitforbit(codec_panel):
    """The lowered discard group (opt program @ b=1) must reproduce the
    dedicated discard program exactly, metric for metric."""
    spec, res = codec_panel
    ref = run_sweep(spec, mesh=None, lower_discard=False)
    assert ref.n_programs == 3
    got = next(g for g in res.groups if g.scheme == "discard")
    want = next(g for g in ref.groups if g.scheme == "discard")
    for key in want.metrics:
        np.testing.assert_array_equal(got.metrics[key], want.metrics[key],
                                      err_msg=key)


def test_codec_sweep_sharded_smoke():
    """Tiny codec sweep on the ("sweep",) mesh (1 device under tier-1; the
    CI sweep-smoke job forces 2 host devices): opt + lowered discard share
    one program and the sharded run stays deterministic."""
    from repro.launch.mesh import make_sweep_mesh
    spec = SweepSpec(base=tiny_base(use_delta_codec=True), seeds=(0, 1),
                     schemes=(("opt", {"b": 2.0}), ("discard", {"b": 1.0})))
    res = run_sweep(spec, mesh=make_sweep_mesh())
    assert res.n_programs == 1                  # discard reuses opt-codec
    for g in res.groups:
        assert g.metrics["test_acc"].shape == (2, 1, spec.base.rounds)
        assert np.all(np.isfinite(g.metrics["test_loss"]))
    assert np.all(res.groups[1].metrics["rescued"] == 0)


def test_codec_bits_group_static_forks_programs():
    """codec_bits is a GROUP_STATICS entry: int8 and int4 codec groups sit
    side by side in one spec as two compiled programs (the bit depth is
    baked into the round program), and the int4 group's payload accounting
    flows from codec_ratio(bits=4)."""
    from repro.core.hsfl import model_compress_ratio
    from repro.core.sweep import GROUP_STATICS, _group_build_kwargs
    assert "codec_bits" in GROUP_STATICS
    spec = SweepSpec(base=tiny_base(rounds=2, local_epochs=4),
                     seeds=(0,),
                     schemes=(("opt", {"b": 2.0, "use_delta_codec": True}),
                              ("opt", {"b": 2.0, "use_delta_codec": True,
                                       "codec_bits": 4})))
    g8, g4 = compile_spec(spec)
    assert g8.base.codec_bits == 8 and g4.base.codec_bits == 4
    assert _group_build_kwargs(g4)["codec_bits"] == 4
    assert _group_build_kwargs(g4)["compress_ratio"] \
        == model_compress_ratio(g4.base) < _group_build_kwargs(g8)["compress_ratio"]
    res = run_sweep(spec, mesh=None)
    assert res.n_programs == 2
    for g in res.groups:
        assert np.all(np.isfinite(g.metrics["test_loss"]))


def test_device_round_codec_matches_matched_channels():
    """Seeded equivalence of device-round codec rescues: against an
    uncompressed device run with ``compress_ratio`` pinned to the same
    ``codec_ratio`` value, the RNG streams, selection, τ budgets and
    probe/arrival decisions are identical — so the per-round count/byte
    trajectories must match EXACTLY, and the aggregated params may differ
    only by the int8 quantization noise that rescued contributions carry
    (the test_fused_round tolerance policy, scaled for compounding over
    rounds).  This is the device-engine analogue of
    ``test_fused_matches_host_with_delta_codec`` — the host-vs-device RNG
    streams themselves are intentionally different (EXPERIMENTS.md), so
    the matched realization is constructed on the device side."""
    import jax
    import jax.numpy as jnp

    from repro.core.channel_lib import fleet_init
    from repro.core.fused_round import DeviceSimCarry, build_device_round
    from repro.models import cnn as cnn_mod

    base = dict(rounds=4, n_uavs=8, k_select=4, n_train=400, n_test=100,
                steps_per_epoch=2, local_epochs=6, scheme="opt", b=3,
                seed=1)
    ratio = model_compress_ratio(HSFLConfig(use_delta_codec=True, **base))

    def run_dev(cfg):
        sim = {k: jnp.asarray(v)
               for k, v in build_sim_arrays(cfg).items()}
        params0 = cnn_mod.init_cnn(jax.random.PRNGKey(cfg.seed))
        fleet0 = fleet_init(jax.random.PRNGKey(cfg.seed + 1), cfg.n_uavs,
                            cfg.channel)
        rkeys = jax.random.split(
            jax.random.fold_in(jax.random.PRNGKey(cfg.seed), 2), cfg.rounds)
        k = cfg.k_select
        zstack = jax.tree_util.tree_map(
            lambda a: jnp.zeros((k,) + a.shape, a.dtype), params0)
        carry = DeviceSimCarry(params0, fleet0, zstack,
                               jnp.zeros((k,), bool))
        rf = jax.jit(build_device_round(
            scheme="opt", local_epochs=cfg.local_epochs,
            steps_per_epoch=cfg.steps_per_epoch, batch_size=cfg.batch_size,
            lr=cfg.lr, k_select=k, channel=cfg.channel,
            model_bytes=cfg.model_bytes,
            ue_model_fraction=cfg.ue_model_fraction,
            compress_ratio=model_compress_ratio(cfg),
            use_codec=cfg.use_delta_codec,
            interpret=jax.default_backend() != "tpu"))
        cfgv = {"b": jnp.float32(cfg.b), "tau_max": jnp.float32(cfg.tau_max),
                "bandwidth_ratio": jnp.float32(1.0)}
        traj = []
        for t in range(cfg.rounds):
            carry, m = rf(carry, rkeys[t], sim, cfgv)
            traj.append((int(m.selected), int(m.arrived), int(m.rescued),
                         int(m.dropped), float(m.bytes_sent)))
        return traj, carry.params

    traj_c, p_c = run_dev(HSFLConfig(use_delta_codec=True, **base))
    traj_p, p_p = run_dev(HSFLConfig(compress_ratio=ratio, **base))
    assert sum(t[2] for t in traj_c) > 0, "fixture no longer rescues"
    assert traj_c == traj_p, (traj_c, traj_p)
    diff = max(float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
               for x, y in zip(jax.tree_util.tree_leaves(p_c),
                               jax.tree_util.tree_leaves(p_p)))
    assert diff < 5e-3, diff
