"""Fused-round equivalence vs the host OppTransmitter reference path.

The fused engine (core/fused_round) must reproduce the host control loop
exactly: same seeds -> identical per-round selected/arrived/rescued/dropped/
delayed counts and byte accounting, and aggregated params within tolerance
(the fused train step lowers convolutions via im2col, which reassociates the
backward — values drift at the 1e-7/round level, amplified to ~1e-5 through
the int8 codec's rounding boundaries).

Known boundary: the host reference compares the eq. 14-16 τ budgets in
Python float64 while the device program uses float32, so a probe whose τ
lands within ~1e-7 *relative* of the remaining allowance could in principle
decide differently between engines.  Both sides are deterministic IEEE
scalar math, so the pinned seeds here are stable; if a future fixture change
flips a count by ±1, suspect this boundary before suspecting the logic.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hsfl import HSFLConfig, HSFLSimulation
from repro.kernels.delta_codec.ops import (codec_ratio, decode_delta,
                                           encode_delta, payload_bytes,
                                           stacked_flatten, stacked_unflatten)


def small_cfg(**kw):
    base = dict(rounds=4, n_uavs=12, k_select=4, n_train=800, n_test=200,
                steps_per_epoch=2, local_epochs=6, seed=0)
    base.update(kw)
    return HSFLConfig(**base)


def run_traj(cfg):
    sim = HSFLSimulation(cfg)
    delayed, logs = [], []
    for t in range(1, cfg.rounds + 1):
        log, delayed = sim.run_round(t, delayed)
        logs.append((log.selected, log.arrived_final, log.used_snapshot,
                     log.dropped, log.delayed, round(log.bytes_sent, 3)))
    return logs, sim.params


def max_leaf_diff(a, b):
    return max(float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


@pytest.mark.parametrize("scheme,b", [("opt", 2), ("discard", 1), ("async", 1)])
def test_fused_matches_host_trajectory(scheme, b):
    host, p_host = run_traj(small_cfg(scheme=scheme, b=b,
                                      use_fused_round=False))
    fused, p_fused = run_traj(small_cfg(scheme=scheme, b=b,
                                        use_fused_round=True))
    assert host == fused, f"count/byte trajectories diverge:\n{host}\n{fused}"
    assert max_leaf_diff(p_host, p_fused) < 1e-5


def test_fused_matches_host_with_rescue():
    # seed 1 produces a snapshot rescue within 5 rounds (exercises the
    # snapshot-overwrite + rescue aggregation path end to end)
    cfg = dict(scheme="opt", b=2, rounds=5, seed=1)
    host, p_host = run_traj(small_cfg(use_fused_round=False, **cfg))
    fused, p_fused = run_traj(small_cfg(use_fused_round=True, **cfg))
    assert sum(r[2] for r in host) > 0, "fixture no longer rescues"
    assert host == fused
    assert max_leaf_diff(p_host, p_fused) < 1e-5


def test_fused_matches_host_with_delta_codec():
    cfg = dict(scheme="opt", b=2, rounds=5, seed=1, use_delta_codec=True)
    host, p_host = run_traj(small_cfg(use_fused_round=False, **cfg))
    fused, p_fused = run_traj(small_cfg(use_fused_round=True, **cfg))
    assert host == fused
    # int8 rounding boundaries amplify the im2col backward drift
    assert max_leaf_diff(p_host, p_fused) < 3e-5


def test_codec_compress_ratio_is_derived():
    sim = HSFLSimulation(small_cfg(rounds=1, use_delta_codec=True))
    n = sum(x.size for x in jax.tree_util.tree_leaves(sim.params))
    assert sim.compress_ratio == pytest.approx(codec_ratio(n))
    assert 0.2 < sim.compress_ratio < 0.3
    # bytes on the wire shrink accordingly
    log, _ = sim.run_round(1, [])
    assert log.bytes_sent < 0.3 * log.selected * 2 * sim.cfg.model_bytes


def test_fused_schedule_override():
    cfg = dict(scheme="opt", b=2, rounds=4, schedule_override=(1, 5))
    host, p_host = run_traj(small_cfg(use_fused_round=False, **cfg))
    fused, p_fused = run_traj(small_cfg(use_fused_round=True, **cfg))
    assert host == fused
    assert max_leaf_diff(p_host, p_fused) < 1e-5


def test_forward_im2col_matches_reference():
    from repro.models import cnn as cnn_mod
    params = cnn_mod.init_cnn(jax.random.PRNGKey(3))
    x = jax.random.normal(jax.random.PRNGKey(4), (7, 28, 28, 1))
    ref = cnn_mod.forward(params, x)
    fast = cnn_mod.forward_im2col(params, x)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


# -- delta codec flatten/pad contract ---------------------------------------

def _odd_tree(key):
    """Leaf sizes deliberately NOT multiples of 512 (773 + 3*5*7 + 11)."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {"a": jax.random.normal(k1, (773,)),
            "b": {"c": jax.random.normal(k2, (3, 5, 7)),
                  "d": jax.random.normal(k3, (11,))}}


def test_delta_codec_roundtrip_odd_sizes():
    base = _odd_tree(jax.random.PRNGKey(0))
    params = jax.tree_util.tree_map(
        lambda x: x + 0.01 * jnp.sin(
            jnp.arange(x.size, dtype=jnp.float32)).reshape(x.shape), base)
    payload = encode_delta(params, base, interpret=True)
    out = decode_delta(payload, base, interpret=True)
    # error bounded by half an int8 step of the per-block scale
    for got, want in zip(jax.tree_util.tree_leaves(out),
                         jax.tree_util.tree_leaves(params)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-4)
    n = 773 + 3 * 5 * 7 + 11
    assert int(payload["n"]) == n
    blocks = -(-n // 512)
    assert payload_bytes(payload) == blocks * 512 + blocks * 4


def test_stacked_flatten_roundtrip_odd_sizes():
    tree = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (3,) + x.shape),
        _odd_tree(jax.random.PRNGKey(2)))
    flat, n = stacked_flatten(tree)
    assert flat.shape[0] == 3 and flat.shape[2] == 512
    assert flat.shape[1] % 256 == 0          # kernel row-tiling contract
    assert n == 773 + 3 * 5 * 7 + 11
    back = stacked_unflatten(flat, tree)
    for got, want in zip(jax.tree_util.tree_leaves(back),
                         jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_large_tree_meets_row_tiling():
    """>256 blocks forces row padding to a TILE_ROWS multiple (the old
    _flatten asserted out here)."""
    base = {"w": jnp.zeros((300, 512))}          # 300 rows > TILE_ROWS
    params = {"w": jnp.ones((300, 512)) * 0.01}
    payload = encode_delta(params, base, interpret=True)
    assert payload["q"].shape[0] % 256 == 0
    out = decode_delta(payload, base, interpret=True)
    np.testing.assert_allclose(np.asarray(out["w"]), 0.01, atol=1e-4)
