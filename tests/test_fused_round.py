"""Fused-round equivalence vs the host OppTransmitter reference path.

The fused engine (core/fused_round) must reproduce the host control loop
exactly: same seeds -> identical per-round selected/arrived/rescued/dropped/
delayed counts and byte accounting, and aggregated params within tolerance
(the fused train step lowers convolutions via im2col, which reassociates the
backward — values drift at the 1e-7/round level, amplified to ~1e-5 through
the int8 codec's rounding boundaries).

Known boundary: the host reference compares the eq. 14-16 τ budgets in
Python float64 while the device program uses float32, so a probe whose τ
lands within ~1e-7 *relative* of the remaining allowance could in principle
decide differently between engines.  Both sides are deterministic IEEE
scalar math, so the pinned seeds here are stable; if a future fixture change
flips a count by ±1, suspect this boundary before suspecting the logic.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hsfl import HSFLConfig, HSFLSimulation
from repro.kernels.delta_codec.ops import (codec_ratio, decode_delta,
                                           encode_delta, payload_bytes,
                                           stacked_flatten, stacked_unflatten)


def small_cfg(**kw):
    base = dict(rounds=4, n_uavs=12, k_select=4, n_train=800, n_test=200,
                steps_per_epoch=2, local_epochs=6, seed=0)
    base.update(kw)
    return HSFLConfig(**base)


def run_traj(cfg):
    sim = HSFLSimulation(cfg)
    delayed, logs = [], []
    for t in range(1, cfg.rounds + 1):
        log, delayed = sim.run_round(t, delayed)
        logs.append((log.selected, log.arrived_final, log.used_snapshot,
                     log.dropped, log.delayed, round(log.bytes_sent, 3)))
    return logs, sim.params


def max_leaf_diff(a, b):
    return max(float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


@pytest.mark.parametrize("scheme,b,tol", [
    ("opt", 2, 1e-5), ("discard", 1, 1e-5), ("async", 1, 1e-5),
    ("sync", 1, 1e-5), ("deadline", 2, 1e-5),
    # Byzantine-robust aggregates: the host list path and the fused masked
    # sort must agree on the same rounds.  opt_clip's global L2 norms
    # reduce in a different order on K-slot vs stacked-list inputs, so its
    # envelope matches the other reduction-order pins (~int4 codec class).
    ("opt_trimmed", 2, 1e-5), ("opt_median", 2, 1e-5),
    ("opt_clip", 2, 5e-4)])
def test_fused_matches_host_trajectory(scheme, b, tol):
    host, p_host = run_traj(small_cfg(scheme=scheme, b=b,
                                      use_fused_round=False))
    fused, p_fused = run_traj(small_cfg(scheme=scheme, b=b,
                                        use_fused_round=True))
    assert host == fused, f"count/byte trajectories diverge:\n{host}\n{fused}"
    assert max_leaf_diff(p_host, p_fused) < tol


def test_fused_matches_host_with_rescue():
    # seed 1 produces a snapshot rescue within 5 rounds (exercises the
    # snapshot-overwrite + rescue aggregation path end to end)
    cfg = dict(scheme="opt", b=2, rounds=5, seed=1)
    host, p_host = run_traj(small_cfg(use_fused_round=False, **cfg))
    fused, p_fused = run_traj(small_cfg(use_fused_round=True, **cfg))
    assert sum(r[2] for r in host) > 0, "fixture no longer rescues"
    assert host == fused
    assert max_leaf_diff(p_host, p_fused) < 1e-5


def test_fused_matches_host_with_delta_codec():
    cfg = dict(scheme="opt", b=2, rounds=5, seed=1, use_delta_codec=True)
    host, p_host = run_traj(small_cfg(use_fused_round=False, **cfg))
    fused, p_fused = run_traj(small_cfg(use_fused_round=True, **cfg))
    assert host == fused
    # int8 rounding boundaries amplify the im2col backward drift
    assert max_leaf_diff(p_host, p_fused) < 3e-5


def test_fused_matches_host_with_int4_codec():
    """codec_bits=4: the host and fused engines must still agree on the
    count/byte trajectories (both budget the same int4 payload bytes) and
    on params within the larger int4 rescue-noise envelope (~16x int8)."""
    cfg = dict(scheme="opt", b=2, rounds=4, seed=1, use_delta_codec=True,
               codec_bits=4)
    host, p_host = run_traj(small_cfg(use_fused_round=False, **cfg))
    fused, p_fused = run_traj(small_cfg(use_fused_round=True, **cfg))
    assert host == fused
    assert max_leaf_diff(p_host, p_fused) < 5e-4
    # the derived payload knob is the int4 ratio (~0.127 of f32)
    from repro.core.hsfl import model_compress_ratio
    assert 0.12 < model_compress_ratio(small_cfg(**cfg)) < 0.14


def test_host_selection_budgets_compressed_bytes():
    """PR-3 follow-up: host greedy selection used to budget the
    *uncompressed* model for the final upload under ``use_delta_codec`` —
    it must see the same effective bytes the device engine's
    ``eff_model_bytes`` does (host/device byte parity).

    A UAV whose uplink only fits τ_max at the compressed payload proves
    the budgeting: infeasible at full bytes, selected with the codec."""
    from repro.core import latency as lat
    from repro.core.hsfl import model_compress_ratio
    from repro.core.selection import schedule_users, select_users_jax

    cfg = HSFLConfig(use_delta_codec=True)
    ratio = model_compress_ratio(cfg)
    model_b, b, tau = 10e6, 2, 9.0
    ue_b = model_b * cfg.ue_model_fraction
    # rate 1.8e7: FL uplink = 2·10e6·8/1.8e7 ≈ 8.9 s -> infeasible
    # uncompressed (8.9 + 0.6 training > τ_max), but ·ratio ≈ 2.2 s fits.
    # SL stays infeasible (the activation payload doesn't compress).
    rates = np.array([1.8e7, 1e6])
    devices = [lat.DeviceProfile(flops_per_sec=4e9) for _ in rates]
    wls = [lat.WorkloadProfile(local_epochs=6, samples=200,
                               act_bytes_per_sample=1e6) for _ in rates]
    full = schedule_users(rates, devices, wls, model_b, ue_b, b, tau, 2)
    eff = schedule_users(rates, devices, wls, model_b * ratio,
                         ue_b * ratio, b, tau, 2)
    assert [u.index for u in full] == []
    assert [u.index for u in eff] == [0]

    # the device greedy port sees the identical effective bytes
    sel, mode_sl, valid, n_taken, _, _ = select_users_jax(
        jnp.asarray(rates, jnp.float32),
        jnp.asarray([d.flops_per_sec for d in devices], jnp.float32),
        jnp.asarray([w.samples for w in wls], jnp.float32),
        b=jnp.float32(b), tau_max=jnp.float32(tau), k_select=2,
        model_bytes=model_b * ratio, ue_model_bytes=ue_b * ratio,
        local_epochs=6, act_bytes_per_sample=1e6)
    assert int(n_taken) == 1 and int(sel[0]) == 0

    # end to end: HSFLSimulation._schedule_round passes exactly
    # (model_bytes·ratio, ue_bytes·ratio) to the greedy
    sim = HSFLSimulation(small_cfg(rounds=1, use_delta_codec=True))
    from repro.core.channel import UAVFleet
    twin = UAVFleet(sim.cfg.n_uavs, sim.cfg.channel, seed=sim.cfg.seed + 1)
    twin.resample_fading()
    want = schedule_users(
        twin.rates(), sim.devices, sim.workloads,
        sim.cfg.model_bytes * sim.compress_ratio,
        sim.cfg.model_bytes * sim.cfg.ue_model_fraction * sim.compress_ratio,
        sim.cfg.b, sim.cfg.tau_max, sim.cfg.k_select)
    got, _ = sim._schedule_round()
    assert [(u.index, u.mode) for u in got] == \
        [(u.index, u.mode) for u in want]


def test_codec_compress_ratio_is_derived():
    sim = HSFLSimulation(small_cfg(rounds=1, use_delta_codec=True))
    n = sum(x.size for x in jax.tree_util.tree_leaves(sim.params))
    assert sim.compress_ratio == pytest.approx(codec_ratio(n))
    assert 0.2 < sim.compress_ratio < 0.3
    # bytes on the wire shrink accordingly
    log, _ = sim.run_round(1, [])
    assert log.bytes_sent < 0.3 * log.selected * 2 * sim.cfg.model_bytes


def test_fused_schedule_override():
    cfg = dict(scheme="opt", b=2, rounds=4, schedule_override=(1, 5))
    host, p_host = run_traj(small_cfg(use_fused_round=False, **cfg))
    fused, p_fused = run_traj(small_cfg(use_fused_round=True, **cfg))
    assert host == fused
    assert max_leaf_diff(p_host, p_fused) < 1e-5


def test_forward_im2col_matches_reference():
    from repro.models import cnn as cnn_mod
    params = cnn_mod.init_cnn(jax.random.PRNGKey(3))
    x = jax.random.normal(jax.random.PRNGKey(4), (7, 28, 28, 1))
    ref = cnn_mod.forward(params, x)
    fast = cnn_mod.forward_im2col(params, x)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


# -- async carry width + fractional-weight aggregation ------------------------

def _linear_forward(params, x):
    return x @ params["w"]


def _async_round_inputs(K, e=2, steps=1, bs=2, dim=4, ncls=3):
    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.normal(size=(e, K, steps, bs, dim)), jnp.float32)
    ys = jnp.asarray(rng.integers(0, ncls, (e, K, steps, bs)))
    chan = {
        "rates": jnp.full((e, K), 1e6, jnp.float32),
        "outages": jnp.zeros((e, K), bool),
        "payload_bits": jnp.full((K,), 8e6, jnp.float32),
        "tau_extra0": jnp.zeros((K,), jnp.float32),
        "final_rate": jnp.full((K,), 1e6, jnp.float32),
        "final_outage": jnp.zeros((K,), bool),
        "train_time": jnp.full((K,), 1.0, jnp.float32),
        "valid": jnp.ones((K,), bool),
    }
    params = {"w": jnp.asarray(rng.normal(size=(dim, ncls)), jnp.float32)}
    return params, xs, ys, chan


def test_async_k_carry_too_small_raises_clearly():
    """K > k_carry used to hit jnp.pad with a negative width — a cryptic
    error deep inside the jit.  It must be a clear ValueError instead."""
    from repro.core.fused_round import build_fused_round
    K, k_carry = 4, 2
    fn = build_fused_round(scheme="async", local_epochs=2, steps_per_epoch=1,
                           lr=0.1, tau_max=30.0, probe_epochs=(),
                           async_weight=0.3, k_carry=k_carry,
                           forward=_linear_forward)
    params, xs, ys, chan = _async_round_inputs(K)
    dstack = {"w": jnp.zeros((k_carry,) + params["w"].shape)}
    dmask = jnp.zeros((k_carry,), bool)
    with pytest.raises(ValueError, match="k_carry"):
        fn(params, dstack, dmask, xs, ys, chan)


def test_async_k_carry_zero_rejected_at_build():
    from repro.core.fused_round import build_fused_round
    with pytest.raises(ValueError, match="k_carry"):
        build_fused_round(scheme="async", local_epochs=2, steps_per_epoch=1,
                          lr=0.1, tau_max=30.0, probe_epochs=(),
                          k_carry=0, forward=_linear_forward)


def test_async_k_carry_equals_K_boundary():
    """k_carry == K is valid (zero pad) and must round-trip the carry."""
    from repro.core.fused_round import build_fused_round
    K = 2
    fn = build_fused_round(scheme="async", local_epochs=2, steps_per_epoch=1,
                           lr=0.1, tau_max=30.0, probe_epochs=(),
                           async_weight=0.3, k_carry=K,
                           forward=_linear_forward)
    params, xs, ys, chan = _async_round_inputs(K)
    dstack = {"w": jnp.zeros((K,) + params["w"].shape)}
    dmask = jnp.zeros((K,), bool)
    new_params, c_stack, c_mask, stats = fn(params, dstack, dmask,
                                            xs, ys, chan)
    assert c_mask.shape == (K,)
    assert c_stack["w"].shape == (K,) + params["w"].shape
    assert np.asarray(stats.arrived).shape == (K,)


def test_masked_mean_fractional_weights():
    """Audit companion to the round_sync fix: Σw < 1 must divide by Σw,
    not by the old ``maximum(Σw, 1)`` clamp."""
    from repro.core.fused_round import _masked_mean
    contrib = {"w": jnp.asarray([[2.0], [10.0]])}
    weights = jnp.asarray([0.3, 0.3])
    fallback = {"w": jnp.asarray([-1.0])}
    out = _masked_mean(contrib, weights, fallback)
    np.testing.assert_allclose(np.asarray(out["w"]), [6.0], rtol=1e-6)
    empty = _masked_mean(contrib, jnp.zeros(2), fallback)
    np.testing.assert_allclose(np.asarray(empty["w"]), [-1.0])


# -- delta codec flatten/pad contract ---------------------------------------

def _odd_tree(key):
    """Leaf sizes deliberately NOT multiples of 512 (773 + 3*5*7 + 11)."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {"a": jax.random.normal(k1, (773,)),
            "b": {"c": jax.random.normal(k2, (3, 5, 7)),
                  "d": jax.random.normal(k3, (11,))}}


def test_delta_codec_roundtrip_odd_sizes():
    base = _odd_tree(jax.random.PRNGKey(0))
    params = jax.tree_util.tree_map(
        lambda x: x + 0.01 * jnp.sin(
            jnp.arange(x.size, dtype=jnp.float32)).reshape(x.shape), base)
    payload = encode_delta(params, base, interpret=True)
    out = decode_delta(payload, base, interpret=True)
    # error bounded by half an int8 step of the per-block scale
    for got, want in zip(jax.tree_util.tree_leaves(out),
                         jax.tree_util.tree_leaves(params)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-4)
    n = 773 + 3 * 5 * 7 + 11
    assert int(payload["n"]) == n
    blocks = -(-n // 512)
    assert payload_bytes(payload) == blocks * 512 + blocks * 4


def test_stacked_flatten_roundtrip_odd_sizes():
    tree = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (3,) + x.shape),
        _odd_tree(jax.random.PRNGKey(2)))
    flat, n = stacked_flatten(tree)
    assert flat.shape[0] == 3 and flat.shape[2] == 512
    assert flat.shape[1] % 256 == 0          # kernel row-tiling contract
    assert n == 773 + 3 * 5 * 7 + 11
    back = stacked_unflatten(flat, tree)
    for got, want in zip(jax.tree_util.tree_leaves(back),
                         jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_large_tree_meets_row_tiling():
    """>256 blocks forces row padding to a TILE_ROWS multiple (the old
    _flatten asserted out here)."""
    base = {"w": jnp.zeros((300, 512))}          # 300 rows > TILE_ROWS
    params = {"w": jnp.ones((300, 512)) * 0.01}
    payload = encode_delta(params, base, interpret=True)
    assert payload["q"].shape[0] % 256 == 0
    out = decode_delta(payload, base, interpret=True)
    np.testing.assert_allclose(np.asarray(out["w"]), 0.01, atol=1e-4)
