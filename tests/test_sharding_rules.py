"""Sharding rule tables: structural match + divisibility on the 16x16 mesh.

Pure host-side checks (no devices needed): every sharded dim of every param
of every FULL assigned config must divide the mesh axis it is mapped to —
this is exactly what the multi-pod dry-run would trip over.
"""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.models import build_model
from repro.models import transformer as tf
from repro.sharding import rules

AXIS_SIZE = {"data": 16, "model": 16, "pod": 2}


def _shape_tree(cfg):
    model = build_model(cfg)
    return jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_divisible(arch):
    cfg = get_config(arch)
    shapes = _shape_tree(cfg)
    specs = rules.param_specs(cfg, shapes)
    flat_s = jax.tree_util.tree_leaves_with_path(shapes)
    flat_p = jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p)
    for (path, shp), spec in zip(flat_s, flat_p):
        assert isinstance(spec, P)
        assert len(spec) <= len(shp.shape)
        for dim, ax in zip(shp.shape, spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = int(np.prod([AXIS_SIZE[a] for a in axes]))
            assert dim % total == 0, (arch, path, shp.shape, spec)


@pytest.mark.parametrize("arch", ["llama3-405b", "rwkv6-7b", "hymba-1.5b",
                                  "granite-moe-3b-a800m"])
@pytest.mark.parametrize("multi_pod", [False, True])
def test_decode_state_specs_divisible(arch, multi_pod):
    cfg = get_config(arch)
    if cfg.is_encoder_only:
        pytest.skip("no decode")
    for shape_name in ("decode_32k", "long_500k"):
        shp = INPUT_SHAPES[shape_name]
        ccfg = cfg if cfg.is_subquadratic or shape_name != "long_500k" \
            else cfg.with_sliding_window()
        state = jax.eval_shape(
            lambda: tf.init_decode_state(ccfg, shp.global_batch, shp.seq_len,
                                         jax.numpy.bfloat16))
        specs = rules.decode_state_specs(ccfg, shp.global_batch, multi_pod)
        flat_s = jax.tree_util.tree_leaves_with_path(state)
        flat_p = jax.tree_util.tree_leaves(specs,
                                           is_leaf=lambda x: isinstance(x, P))
        assert len(flat_s) == len(flat_p)
        for (path, s), spec in zip(flat_s, flat_p):
            for dim, ax in zip(s.shape, spec):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                total = int(np.prod([AXIS_SIZE[a] for a in axes]))
                assert dim % total == 0, (arch, shape_name, path, s.shape, spec)


def test_vocab_padding_divisible():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        assert cfg.vocab_padded % 256 == 0
        assert cfg.vocab_padded >= cfg.vocab_size
        assert cfg.vocab_padded - cfg.vocab_size < 256


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_plausible(arch):
    """Config-level param_count tracks the real init within 25%."""
    cfg = get_config(arch)
    shapes = _shape_tree(cfg)
    real = sum(int(np.prod(s.shape)) for s in jax.tree_util.tree_leaves(shapes))
    est = cfg.param_count()
    assert abs(est - real) / real < 0.25, (arch, est, real)
