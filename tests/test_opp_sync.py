"""OpportunisticSync (pod-axis OPT) tests.

Multi-device behaviour needs >1 host device, and XLA device count is locked
at first jax init — so the shard_map tests run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=4 (the dry-run does the
same with 512; smoke tests keep seeing 1 device, per the brief).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.opportunistic_sync import (OppSyncConfig, is_scheduled,
                                           round_sync)
from repro.training.train_state import TrainState


def test_schedule_matches_alg2():
    cfg = OppSyncConfig(inner_steps=6, budget=2)
    sched = [bool(is_scheduled(cfg, jnp.asarray(i))) for i in range(6)]
    assert sched == [False, False, False, True, False, False]
    cfg3 = OppSyncConfig(inner_steps=6, budget=3)
    sched3 = [bool(is_scheduled(cfg3, jnp.asarray(i))) for i in range(6)]
    assert sched3 == [False, False, True, False, True, False]


def test_budget1_never_schedules():
    cfg = OppSyncConfig(inner_steps=8, budget=1)
    assert not any(bool(is_scheduled(cfg, jnp.asarray(i))) for i in range(8))


def test_tau_extra0_eq14():
    cfg = OppSyncConfig(budget=4, payload=2.0, rate0=0.5)
    assert cfg.tau_extra0 == pytest.approx(3 * 2.0 / 0.5)


def _pod_state(p):
    return TrainState(params={"w": p}, opt_state=(),
                      step=jnp.asarray(4, jnp.int32),
                      snapshot={"w": jnp.zeros_like(p)},
                      snapshot_step=jnp.asarray(-1, jnp.int32),
                      tau_extra=jnp.asarray(0.0, jnp.float32))


def test_round_sync_all_delayed_fractional_weights():
    """Regression: the async scheme's validity weights are fractional
    (α(s+1)^(−a) ≈ 0.283), so a round where EVERY pod is delayed has
    0 < Σvalid < 1.  The old denominator clamp ``maximum(num, 1.0)``
    silently divided the weighted sum by 1 instead of Σvalid, shrinking the
    aggregated params toward zero; the aggregate must be the true weighted
    mean (= plain mean here, since all weights are equal)."""
    cfg = OppSyncConfig(scheme="async", axis="pod")
    pods = jnp.asarray([[1.0, 2.0], [3.0, 4.0]])       # 2 pods, Σvalid ≈ 0.57

    def one(p, arrived):
        return round_sync(cfg, _pod_state(p), arrived).params["w"]

    out = jax.vmap(one, axis_name="pod")(pods, jnp.zeros((2,), bool))
    np.testing.assert_allclose(np.asarray(out),
                               [[2.0, 3.0], [2.0, 3.0]], rtol=1e-6)


def test_round_sync_mixed_arrivals_weighted_mean():
    """Timely pod at weight 1, delayed pod at w=α·2^(−a): the aggregate is
    (1·p₀ + w·p₁)/(1 + w) — also exercises num > 1 (no clamp effect)."""
    cfg = OppSyncConfig(scheme="async", axis="pod")
    pods = jnp.asarray([[2.0], [10.0]])
    arrived = jnp.asarray([True, False])

    def one(p, arr):
        return round_sync(cfg, _pod_state(p), arr).params["w"]

    out = jax.vmap(one, axis_name="pod")(pods, arrived)
    w = cfg.async_alpha * 2.0 ** (-cfg.async_a)
    want = (2.0 + w * 10.0) / (1.0 + w)
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-6)


_SUBPROCESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.opportunistic_sync import (OppSyncConfig, channel_trace,
                                               make_opp_sync_round)
    from repro.optim import sgd
    from repro.training import TrainState, create_train_state, make_train_step
    from repro.models import build_model
    from repro.configs import get_config

    N_PODS = 4
    mesh = jax.make_mesh((N_PODS,), ("pod",))
    cfg = OppSyncConfig(inner_steps=4, budget=2, outage_prob=0.5, rate0=1.0)

    model = build_model(get_config("llama3.2-1b").reduced())
    params = model.init(jax.random.PRNGKey(0))
    opt = sgd(1e-2)
    step = make_train_step(model, opt)

    state0 = create_train_state(params, opt, with_opt_sync=True,
                                tau_extra0=cfg.tau_extra0)
    # stack state across pods
    stack = lambda t: jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (N_PODS,) + a.shape), t)
    state = stack(state0)

    B, S = 2, 16
    rng = np.random.default_rng(0)
    batches = {
        "tokens": jnp.asarray(
            rng.integers(0, 500, (N_PODS, cfg.inner_steps, B, S)), jnp.int32),
        "labels": jnp.asarray(
            rng.integers(0, 500, (N_PODS, cfg.inner_steps, B, S)), jnp.int32),
    }
    rates, outages, arrived = channel_trace(cfg, jax.random.PRNGKey(1),
                                            N_PODS, rounds=3)
    state_spec = jax.tree_util.tree_map(lambda _: P("pod"), state)
    batch_spec = jax.tree_util.tree_map(lambda _: P("pod"), batches)
    one_round = make_opp_sync_round(cfg, step, mesh, state_spec, batch_spec)

    with jax.sharding.use_mesh(mesh) if hasattr(jax.sharding, "use_mesh") else mesh:
        for r in range(3):
            state, losses = one_round(
                state, batches,
                rates[r].T.reshape(cfg.inner_steps + 1, N_PODS),
                outages[r].reshape(cfg.inner_steps + 1, N_PODS), arrived[r])

    # after round_sync, all pods hold identical params
    p0 = jax.tree_util.tree_leaves(state.params)[3]
    assert np.allclose(np.asarray(p0[0]), np.asarray(p0[1]), atol=1e-6), "pods diverge"
    assert np.isfinite(np.asarray(losses)).all()
    # tau_extra reset to the eq.14 allowance after each round
    assert np.allclose(np.asarray(state.tau_extra), cfg.tau_extra0)
    print("OPP_SYNC_OK")
""")


def test_shard_map_round_four_pods():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_SCRIPT],
                         capture_output=True, text=True, env=env, timeout=600)
    assert "OPP_SYNC_OK" in out.stdout, out.stdout + "\n" + out.stderr
