"""Lossy-wire transport primitives (core/transport).

Pins the wire-format invariants the serving path leans on: chunk
round-trips are bitwise, XOR parity rebuilds *any* single missing data
chunk per group (k-of-(k+1) erasure), a BER=0 wire is bit-identical to
the unchunked encode/decode path, and the eq. 14 budget split schedules
exactly the hand-computed number of chunks per probe epoch.
"""
import zlib

import numpy as np
import pytest

from repro.core.transport import (Chunk, ChunkAssembler, ChunkedUploader,
                                  LossyWire, TransferLedger, TransportConfig,
                                  epoch_chunk_budget, make_chunks, reassemble,
                                  split_payload, xor_bytes)


def _payload(n: int, seed: int = 0) -> bytes:
    return np.random.default_rng(seed).integers(
        0, 256, size=n, dtype=np.uint8).tobytes()


# ---------------------------------------------------------------------------
# chunking + reassembly round trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,chunk_bytes", [(0, 16), (1, 16), (16, 16),
                                           (17, 16), (100, 16), (100, 7),
                                           (4096, 512)])
def test_chunk_round_trip(n, chunk_bytes):
    cfg = TransportConfig(chunk_bytes=chunk_bytes, parity_k=3).validate()
    payload = _payload(n)
    chunks = make_chunks(payload, cfg)
    data = {c.index: c.data for c in chunks if c.kind == "data"}
    n_data = chunks[0].n_data
    assert len(data) == n_data == len(split_payload(payload, chunk_bytes))
    assert reassemble(data, n_data, len(payload),
                      zlib.crc32(payload)) == payload
    # every chunk carries a valid CRC and the content address
    assert all(c.ok() and c.transfer_id == zlib.crc32(payload)
               for c in chunks)


def test_parity_layout_interleaved():
    # 7 data chunks at k=3 -> groups (0,1,2), (3,4,5), (6): parity closes
    # each group right after its last data chunk
    cfg = TransportConfig(chunk_bytes=16, parity_k=3)
    chunks = make_chunks(_payload(100), cfg)
    keys = [c.key for c in chunks]
    assert keys == [("data", 0), ("data", 1), ("data", 2), ("parity", 0),
                    ("data", 3), ("data", 4), ("data", 5), ("parity", 1),
                    ("data", 6), ("parity", 2)]
    # parity is the XOR of its group (zero-padded to chunk length)
    g0 = xor_bytes(chunks[0].data, chunks[1].data, chunks[2].data)
    assert chunks[3].data == g0


# ---------------------------------------------------------------------------
# erasure rescue: any single missing data chunk per group rebuilds
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("parity_k", [1, 2, 3, 4])
def test_every_single_drop_reconstructs(parity_k):
    cfg = TransportConfig(chunk_bytes=16, parity_k=parity_k)
    payload = _payload(100, seed=parity_k)
    chunks = make_chunks(payload, cfg)
    n_data = chunks[0].n_data
    for drop in range(n_data):
        asm = ChunkAssembler.for_chunk(chunks[0], cfg)
        for c in chunks:
            if c.key != ("data", drop):
                assert asm.add(c) == "accepted"
        assert not asm.complete()
        assert asm.try_reconstruct() == 1
        assert asm.complete() and asm.payload() == payload


def test_one_drop_per_group_simultaneously():
    # the maximal rescuable loss pattern: one data chunk out of *every*
    # group missing at once
    cfg = TransportConfig(chunk_bytes=16, parity_k=2)
    payload = _payload(100)
    chunks = make_chunks(payload, cfg)
    groups = sorted({c.index for c in chunks if c.kind == "parity"})
    dropped = {("data", g * cfg.parity_k) for g in groups
               if g * cfg.parity_k < chunks[0].n_data}
    asm = ChunkAssembler.for_chunk(chunks[0], cfg)
    for c in chunks:
        if c.key not in dropped:
            asm.add(c)
    assert asm.try_reconstruct() == len(dropped)
    assert asm.payload() == payload


def test_two_missing_in_one_group_is_unrecoverable():
    cfg = TransportConfig(chunk_bytes=16, parity_k=3)
    chunks = make_chunks(_payload(100), cfg)
    asm = ChunkAssembler.for_chunk(chunks[0], cfg)
    for c in chunks:
        if c.key not in {("data", 0), ("data", 1)}:   # same group
            asm.add(c)
    assert asm.try_reconstruct() == 0
    assert not asm.complete()


def test_corrupt_chunk_detected_not_banked():
    cfg = TransportConfig(chunk_bytes=16, parity_k=0)
    chunks = make_chunks(_payload(64), cfg)
    bad = Chunk(chunks[0].transfer_id, 0, "data", chunks[0].n_data,
                64, b"X" * 16, chunks[0].crc)
    asm = ChunkAssembler.for_chunk(chunks[0], cfg)
    assert asm.add(bad) == "corrupt"
    assert asm.add(chunks[0]) == "accepted"
    assert asm.add(chunks[0]) == "duplicate"


# ---------------------------------------------------------------------------
# BER=0 wire is bit-identical to the unchunked path
# ---------------------------------------------------------------------------

def test_ber0_bit_identity_to_unchunked_tree_codec():
    import jax.numpy as jnp

    from repro.serving.fl_server import decode_tree, encode_tree

    tree = {"w": jnp.arange(300, dtype=jnp.float32).reshape(30, 10),
            "b": jnp.ones((10,), jnp.float32) * 0.25}
    raw = encode_tree(tree)
    cfg = TransportConfig(chunk_bytes=128, parity_k=4)
    wire = LossyWire(cfg, np.random.default_rng(0))
    asm = None
    for c in make_chunks(raw, cfg):
        rx = wire.transmit(c)
        assert rx.data == c.data            # BER=0: the wire is a no-op
        if asm is None:
            asm = ChunkAssembler.for_chunk(rx, cfg)
        asm.add(rx)
    assert asm.complete() and asm.payload() == raw
    out = decode_tree(asm.payload(), tree)
    assert all((np.asarray(a) == np.asarray(b)).all()
               for a, b in zip([out["w"], out["b"]], [tree["w"], tree["b"]]))


def test_lossy_wire_corrupts_and_crc_detects():
    cfg = TransportConfig(chunk_bytes=64, parity_k=0, ber_bad=0.02,
                          wire_outage_prob=1.0, wire_persistence=1.0)
    wire = LossyWire(cfg, np.random.default_rng(1))
    chunks = make_chunks(_payload(1024), cfg)
    seen_corrupt = sum(not wire.transmit(c).ok() for c in chunks)
    assert seen_corrupt > 0                 # always-bad wire at 2% BER
    assert wire.corrupted == seen_corrupt   # CRC catches every flip


# ---------------------------------------------------------------------------
# budget-driven scheduling
# ---------------------------------------------------------------------------

def test_epoch_chunk_budget_hand_cases():
    # 0.5 s at 1024 bps = 512 bits = 64 B -> four 16 B chunks
    assert epoch_chunk_budget(0.5, 1024, 16) == 4
    assert epoch_chunk_budget(0.5, 1024, 64) == 1
    assert epoch_chunk_budget(0.5, 1024, 65) == 0
    assert epoch_chunk_budget(0.0, 1024, 16) == 0
    assert epoch_chunk_budget(0.5, 0.0, 16) == 0


def test_uploader_budget_schedule_matches_hand_computation():
    # tau_extra = 1 s over 2 probes -> tau_share = 0.5 s; at 1024 bps and
    # 16 B chunks each probe affords 4 chunks, charged at true airtime
    cfg = TransportConfig(chunk_bytes=16, parity_k=0)
    up = ChunkedUploader(cfg, tau_extra=1.0, n_probes=2)
    up.begin(_payload(100))                 # 7 data chunks
    assert len(up.chunks) == 7
    first = up.take_epoch(1024.0)
    assert [c.index for c in first] == [0, 1, 2, 3]
    # 64 B sent = 0.5 s airtime; 0.5 s allowance remains
    assert up.tau_left == pytest.approx(0.5)
    assert not up.idle                      # resumes next probe
    second = up.take_epoch(1024.0)
    assert [c.index for c in second] == [4, 5, 6]
    assert up.idle
    # spent airtime: 100 B * 8 / 1024 bps
    assert up.tau_left == pytest.approx(1.0 - 100 * 8 / 1024.0)


def test_uploader_rejects_overlapping_begin():
    cfg = TransportConfig(chunk_bytes=16, parity_k=0)
    up = ChunkedUploader(cfg, tau_extra=1e-9, n_probes=1)
    up.begin(_payload(100))
    up.take_epoch(1024.0)                   # budget affords nothing
    with pytest.raises(RuntimeError):
        up.begin(_payload(50))
    up.finish()
    up.begin(_payload(50))                  # idle again after finish


# ---------------------------------------------------------------------------
# ledger: cross-round resume
# ---------------------------------------------------------------------------

def test_ledger_resume_only_missing_chunks():
    cfg = TransportConfig(chunk_bytes=16, parity_k=0)
    payload = _payload(100)
    chunks = make_chunks(payload, cfg)
    led = TransferLedger()
    asm = led.assembler(7, chunks[0], cfg)
    for c in chunks[:4]:                    # round t: partial upload
        asm.add(c)
    # round t+1: same content -> same transfer_id -> same assembler
    asm2 = led.assembler(7, chunks[0], cfg)
    assert asm2 is asm
    missing = [c for c in chunks if c.key not in asm2.have()]
    assert [c.index for c in missing] == [4, 5, 6]
    for c in missing:
        asm2.add(c)
    assert asm2.payload() == payload
    led.pop(7, chunks[0].transfer_id)
    assert led.get(7, chunks[0].transfer_id) is None


def test_ledger_fifo_bound():
    cfg = TransportConfig(chunk_bytes=16, parity_k=0)
    led = TransferLedger(max_entries=2)
    firsts = [make_chunks(_payload(40, seed=s), cfg)[0] for s in range(3)]
    for ch in firsts:
        led.assembler(0, ch, cfg)
    assert len(led) == 2
    assert led.get(0, firsts[0].transfer_id) is None   # oldest evicted


def test_transport_config_validation():
    with pytest.raises(ValueError):
        TransportConfig(chunk_bytes=0).validate()
    with pytest.raises(ValueError):
        TransportConfig(parity_k=-1).validate()
    with pytest.raises(ValueError):
        TransportConfig(ber_bad=1.5).validate()
    TransportConfig().validate()            # defaults are valid
