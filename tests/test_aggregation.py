"""Aggregation scheme tests (FedAvg, FedAsync weighting)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import (aggregate_round, fedavg, fedasync_merge,
                                    fedasync_weight)


def tree(x):
    return {"a": jnp.full((3,), float(x)), "b": {"c": jnp.full((2, 2), float(x))}}


def test_fedavg_uniform():
    out = fedavg([tree(1.0), tree(3.0)])
    np.testing.assert_allclose(out["a"], 2.0)
    np.testing.assert_allclose(out["b"]["c"], 2.0)


def test_fedavg_weighted():
    out = fedavg([tree(0.0), tree(4.0)], weights=[3.0, 1.0])
    np.testing.assert_allclose(out["a"], 1.0)


def test_fedasync_weight_paper_values():
    # alpha=0.4, a=0.5, staleness 1 -> 0.4 * 2^-0.5
    assert fedasync_weight(1) == pytest.approx(0.4 / np.sqrt(2))
    assert fedasync_weight(0) == pytest.approx(0.4)
    assert fedasync_weight(3) < fedasync_weight(1)  # staler -> smaller


def test_fedasync_merge():
    g = tree(0.0)
    d = tree(1.0)
    out = fedasync_merge(g, d, staleness=1)
    w = fedasync_weight(1)
    np.testing.assert_allclose(out["a"], w, rtol=1e-6)


def test_aggregate_round_opt_uses_arrived_only():
    out = aggregate_round([tree(2.0)], [(tree(100.0), 1)], tree(0.0), "opt")
    np.testing.assert_allclose(out["a"], 2.0)


def test_aggregate_round_async_downweights_delayed():
    out = aggregate_round([tree(1.0)], [(tree(0.0), 1)], tree(5.0), "async")
    w = fedasync_weight(1)
    np.testing.assert_allclose(out["a"], 1.0 / (1.0 + w), rtol=1e-6)


def test_aggregate_round_empty_keeps_global():
    g = tree(7.0)
    out = aggregate_round([], [], g, "discard")
    np.testing.assert_allclose(out["a"], 7.0)


def test_aggregate_round_async_only_delayed_merges_not_replaces():
    """Regression: a round with ONLY delayed updates must apply the FedAsync
    server merge ω ← (1−α_t)·ω + α_t·ω_d, not normalized FedAvg (which would
    fully replace the global model with the stale update)."""
    g = tree(2.0)
    out = aggregate_round([], [(tree(10.0), 1)], g, "async")
    w = fedasync_weight(1)
    np.testing.assert_allclose(out["a"], (1 - w) * 2.0 + w * 10.0, rtol=1e-6)
    # two stragglers merge sequentially in arrival order
    out2 = aggregate_round([], [(tree(10.0), 1), (tree(0.0), 1)], g, "async")
    expect = (1 - w) * ((1 - w) * 2.0 + w * 10.0) + w * 0.0
    np.testing.assert_allclose(out2["a"], expect, rtol=1e-6)
    # the stale update must NOT dominate: far closer to ω than to ω_d
    assert abs(float(out["a"][0]) - 2.0) < abs(float(out["a"][0]) - 10.0)
