"""End-to-end behaviour tests for the paper's system (HSFL + OPT)."""
import numpy as np
import pytest

from repro.core.hsfl import HSFLConfig, run_hsfl
from repro.core.selection import schedule_users
from repro.core import latency as lat


def small_cfg(**kw):
    base = dict(rounds=3, n_uavs=12, k_select=4, n_train=800, n_test=200,
                steps_per_epoch=2, local_epochs=6, b=2, seed=0)
    base.update(kw)
    return HSFLConfig(**base)


@pytest.mark.parametrize("scheme,b", [("opt", 2), ("discard", 1), ("async", 1)])
def test_sim_runs_all_schemes(scheme, b):
    log = run_hsfl(small_cfg(scheme=scheme, b=b))
    assert len(log.rounds) == 3
    s = log.summary()
    assert s["avg_comm_mb"] > 0
    assert np.isfinite(s["final_acc"]) and 0.0 <= s["final_acc"] <= 1.0


def test_sim_learns_above_chance():
    log = run_hsfl(small_cfg(rounds=10, distribution="iid"))
    assert log.final_acc > 0.15         # 10 classes -> chance is 0.1


def test_opt_rescues_and_discard_drops():
    opt = run_hsfl(small_cfg(scheme="opt", rounds=6, seed=3))
    dis = run_hsfl(small_cfg(scheme="discard", b=1, rounds=6, seed=3))
    assert opt.summary()["snapshot_rescues"] >= 0
    assert dis.summary()["snapshot_rescues"] == 0
    # OPT transmits at least as many bytes (the b=2 budget)
    assert opt.avg_comm_mb >= dis.avg_comm_mb


def test_comm_overhead_grows_with_b():
    mbs = []
    for b in (1, 2, 4):
        log = run_hsfl(small_cfg(scheme="opt", b=b, rounds=4, seed=1))
        mbs.append(log.avg_comm_mb)
    assert mbs[0] < mbs[1] <= mbs[2] * 1.001


def test_round_log_accounting_consistent():
    log = run_hsfl(small_cfg(rounds=4))
    for r in log.rounds:
        assert (r.arrived_final + r.used_snapshot + r.dropped + r.delayed
                == r.selected)


def test_selection_respects_tau_and_caps():
    rng = np.random.default_rng(0)
    n = 20
    devices = [lat.DeviceProfile(flops_per_sec=5e8) for _ in range(n)]
    wls = [lat.WorkloadProfile(samples=200) for _ in range(n)]
    rates = rng.uniform(1e6, 1e8, n)
    sched = schedule_users(rates, devices, wls, 10e6, 2.5e6, b=2,
                           tau_max=9.0, k_select=8)
    assert len(sched) <= 8
    assert sum(u.mode == "SL" for u in sched) <= 4      # max_sl default K/2
    for u in sched:
        assert u.latency_s <= 9.0


def test_selection_empty_when_tau_tiny():
    devices = [lat.DeviceProfile(flops_per_sec=5e8)] * 5
    wls = [lat.WorkloadProfile(samples=200)] * 5
    sched = schedule_users([1e8] * 5, devices, wls, 10e6, 2.5e6, b=2,
                           tau_max=0.01, k_select=5)
    assert sched == []


def test_deterministic_given_seed():
    a = run_hsfl(small_cfg(rounds=3, seed=11))
    b = run_hsfl(small_cfg(rounds=3, seed=11))
    assert a.acc_curve == b.acc_curve
    assert a.avg_comm_mb == b.avg_comm_mb
