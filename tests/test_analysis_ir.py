"""IR auditor tests: walker, donation verifier, scaling gate, CLI wiring.

The acceptance fixtures mirror the two defect classes the auditors exist
for: an *undeclared O(K²) buffer* (a gram matrix materialized on the user
axis) and a *silently dropped donation* (a donated argument XLA cannot
alias).  Both must drive ``python -m repro.analysis --ir`` to exit 1 with
``path:line`` provenance.  The coverage tests pin the registry to the
live scheme registry and the on-disk kernel twins, so a new scheme or
kernel cannot ship without entering the IR sweep.
"""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.ir import alias_audit, jaxpr_audit, scaling
from repro.analysis.ir.programs import (EngineProgram, covered_kernel_twins,
                                        covered_schemes, engine_programs,
                                        program_names)

REPO = Path(__file__).resolve().parents[1]
HERE = "tests/test_analysis_ir.py"


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


# ---------------------------------------------------------------------------
# fixture programs
# ---------------------------------------------------------------------------

def _gram(x):
    g = x @ x.T            # materializes a K x K gram matrix
    return g.sum()


def quadratic_prog():
    """Undeclared O(K^2) buffer on the user axis."""
    return EngineProgram(
        name="fixture[gram]", family="fixture", path=HERE,
        build=lambda k: (_gram, (_sds((k, 8)),)))


def _rowsum(x):
    y = x * 2.0
    return y.sum(axis=1)


def linear_prog():
    return EngineProgram(
        name="fixture[rowsum]", family="fixture", path=HERE,
        build=lambda k: (_rowsum, (_sds((k, 8)),)))


def dropped_donation_prog():
    """Donated (2K,) input that can't alias the (K,) output."""
    def build(k):
        fn = jax.jit(lambda a, b: a[:k] + b, donate_argnums=(0,))
        return fn, (_sds((2 * k,)), _sds((k,)))
    return EngineProgram(
        name="fixture[drop]", family="fused_round", path=HERE,
        build=build, donate_argnums=(0,))


def kept_donation_prog():
    def build(k):
        fn = jax.jit(lambda a, b: a + b, donate_argnums=(0,))
        return fn, (_sds((k,)), _sds((k,)))
    return EngineProgram(
        name="fixture[keep]", family="fused_round", path=HERE,
        build=build, donate_argnums=(0,))


def _leaky(x, s):
    return x * s


def _explicit(x, s):
    return x.astype(jnp.float32) * s


def _bf16_prog(fn, name):
    return EngineProgram(
        name=name, family="kernel", path=HERE,
        build=lambda k: (fn, (_sds((k, 8), jnp.bfloat16), _sds((8,)))),
        compute_dtype="bf16")


# ---------------------------------------------------------------------------
# registry coverage: every scheme, both builders; every kernel twin
# ---------------------------------------------------------------------------

def test_registry_covers_every_registered_scheme():
    from repro.core.schemes import registered_schemes
    cov = covered_schemes()
    missing_fused = set(registered_schemes()) - cov["fused_round"]
    missing_device = set(registered_schemes()) - cov["device_round"]
    assert not missing_fused, f"schemes without fused IR: {missing_fused}"
    assert not missing_device, f"schemes without device IR: {missing_device}"


def test_registry_covers_every_kernel_twin():
    from repro.analysis.contracts import kernel_twin_packages
    on_disk = set(kernel_twin_packages(REPO))
    assert on_disk, "expected kernel twin packages on disk"
    assert on_disk <= covered_kernel_twins()


def test_registry_builds_avals_only():
    names = program_names()
    assert len(names) == len(set(names))
    for prog in engine_programs():
        fn, args = prog.build(4)
        assert callable(fn), prog.name
        for leaf in jax.tree_util.tree_leaves(args):
            assert isinstance(leaf, jax.ShapeDtypeStruct), prog.name


def test_committed_scaling_record_in_sync_with_registry():
    """analysis_scaling.json covers exactly the current registry."""
    committed = json.loads((REPO / "analysis_scaling.json").read_text())
    assert set(committed["programs"]) == set(program_names())
    assert committed["k_values"] == list(scaling.K_VALUES)
    for name, rec in committed["programs"].items():
        assert "error" not in rec, f"{name}: {rec.get('error')}"
        assert rec["total_exponent"] is not None, name


# ---------------------------------------------------------------------------
# jaxpr walker
# ---------------------------------------------------------------------------

def test_walker_peak_covers_known_buffer():
    audit = jaxpr_audit.audit_program(quadratic_prog(), k=64)
    assert audit.peak_bytes >= 64 * 64 * 4     # the gram matrix itself
    top = audit.top_buffers(3)
    assert any(b.site.path == HERE for b in top), \
        "peak provenance should anchor to this test file"


def test_walker_liveness_frees_dead_buffers():
    def two_temps(x):
        a = (x * 2.0).sum()
        b = (x * 3.0).sum()
        return a + b

    prog = EngineProgram(name="fixture[temps]", family="fixture",
                         path=HERE,
                         build=lambda k: (two_temps, (_sds((k,)),)))
    audit = jaxpr_audit.audit_program(prog, k=4096)
    # input + ONE temp live at a time (plus scalars), never both temps
    assert audit.peak_bytes < 2.5 * 4096 * 4


def test_walker_recurses_into_scan():
    def scanned(x):
        def body(c, _):
            return c, (c @ c.T).sum()
        return jax.lax.scan(body, x, None, length=3)

    prog = EngineProgram(name="fixture[scan]", family="fixture",
                         path=HERE,
                         build=lambda k: (scanned, (_sds((k, 8)),)))
    audit = jaxpr_audit.audit_program(prog, k=64)
    assert audit.peak_bytes >= 64 * 64 * 4     # gram inside the scan body


def test_trace_failure_is_a_finding():
    def boom(x):
        raise ValueError("builder exploded")

    prog = EngineProgram(name="fixture[boom]", family="fixture",
                         path=HERE, build=lambda k: (boom, (_sds((k,)),)))
    findings, audits = jaxpr_audit.run_jaxpr_audit([prog])
    assert audits == []
    assert len(findings) == 1 and findings[0].rule == "ir-trace"
    assert "exploded" in findings[0].message


# ---------------------------------------------------------------------------
# dtype promotion audit
# ---------------------------------------------------------------------------

def test_implicit_bf16_promotion_fires():
    fs = jaxpr_audit.dtype_promotions(_bf16_prog(_leaky, "fixture[leak]"))
    assert fs and all(f.rule == "ir-dtype" for f in fs)
    assert fs[0].path == HERE and fs[0].line > 0


def test_visible_cast_is_exempt():
    assert jaxpr_audit.dtype_promotions(
        _bf16_prog(_explicit, "fixture[cast]")) == []


def test_f32_program_skips_dtype_audit():
    prog = EngineProgram(
        name="fixture[f32]", family="kernel", path=HERE,
        build=lambda k: (_leaky, (_sds((k, 8), jnp.bfloat16), _sds((8,)))))
    assert jaxpr_audit.dtype_promotions(prog) == []


# ---------------------------------------------------------------------------
# donation/alias verifier
# ---------------------------------------------------------------------------

def test_dropped_donation_is_a_finding():
    findings, rec = alias_audit.audit_donation(dropped_donation_prog())
    assert len(findings) == 1 and findings[0].rule == "ir-alias"
    assert "dropped flat parameter" in findings[0].message
    assert rec["missing"] == [0]


def test_kept_donation_is_clean():
    findings, rec = alias_audit.audit_donation(kept_donation_prog())
    assert findings == []
    assert rec["missing"] == [] and rec["aliased"] == [0]


def test_donated_flat_indices_pytrees():
    tree = {"a": _sds((4,)), "b": [_sds((2,)), _sds((3,))]}
    got = alias_audit.donated_flat_indices((tree, _sds((5,))), (1,))
    assert got == [3]
    got = alias_audit.donated_flat_indices((tree, _sds((5,))), (0,))
    assert got == [0, 1, 2]


def test_alias_audit_skips_undonated_programs():
    findings, rec = alias_audit.audit_donation(quadratic_prog())
    assert findings == [] and "skipped" in rec


# ---------------------------------------------------------------------------
# K-scaling gate
# ---------------------------------------------------------------------------

def test_fit_exponent_recovers_powers():
    ks = (4, 16, 64, 256)
    assert scaling.fit_exponent(ks, [k * 7 for k in ks]) == \
        pytest.approx(1.0)
    assert scaling.fit_exponent(ks, [k * k for k in ks]) == \
        pytest.approx(2.0)
    assert scaling.fit_exponent(ks, [1024] * 4) == pytest.approx(0.0)
    assert scaling.fit_exponent(ks, [0, 0, 0, 0]) is None


def test_declared_budget_patterns():
    assert scaling.declared_budget("src/repro/core/fused_round.py") == 1.0
    assert scaling.declared_budget("<argument>") == 1.0
    assert scaling.declared_budget("tests/somewhere.py") is None


def test_gate_flags_undeclared_quadratic_buffer():
    findings, report = scaling.run_scaling_gate([quadratic_prog()])
    gram = [f for f in findings if f.rule == "ir-scaling"
            and "undeclared" in f.message and "O(K^2" in f.message]
    assert gram, [f.message for f in findings]
    assert gram[0].path == HERE and gram[0].line > 0


def test_gate_passes_declared_linear_buffer(monkeypatch):
    monkeypatch.setattr(
        scaling, "DECLARED_BUDGETS",
        scaling.DECLARED_BUDGETS + (("tests/", 1.0),))
    findings, report = scaling.run_scaling_gate([linear_prog()])
    assert findings == []
    rec = report["programs"]["fixture[rowsum]"]
    assert rec["total_exponent"] == pytest.approx(1.0, abs=0.1)


def test_gate_flags_drift_against_committed(tmp_path):
    _, report = scaling.run_scaling_gate([linear_prog()])
    committed = tmp_path / "analysis_scaling.json"
    stale = json.loads(json.dumps(report))
    stale["programs"]["fixture[rowsum]"]["total_exponent"] = 2.0
    committed.write_text(json.dumps(stale))
    drift = scaling._drift_findings(report, committed)
    assert len(drift) == 1 and "drifted" in drift[0].message


def test_gate_missing_committed_record_is_a_finding(tmp_path):
    _, report = scaling.run_scaling_gate([linear_prog()])
    drift = scaling._drift_findings(report, tmp_path / "nope.json")
    assert len(drift) == 1 and "--write-scaling" in drift[0].message


# ---------------------------------------------------------------------------
# CLI acceptance: fixtures must exit 1 with provenance
# ---------------------------------------------------------------------------

def _main_ir(monkeypatch, progs, *extra):
    from repro.analysis.__main__ import main
    monkeypatch.setattr("repro.analysis.ir.programs.engine_programs",
                        lambda: progs)
    return main(["--root", str(REPO), "--no-lint", "--no-contracts",
                 "--ir", "--baseline", "no_such_baseline.txt", *extra])


def test_cli_ir_quadratic_fixture_exits_1(monkeypatch, capsys):
    rc = _main_ir(monkeypatch, [quadratic_prog()])
    out = capsys.readouterr().out
    assert rc == 1
    assert "[ir-scaling]" in out
    assert f"{HERE}:" in out            # path:line provenance


def test_cli_ir_dropped_donation_exits_1(monkeypatch, capsys):
    rc = _main_ir(monkeypatch, [dropped_donation_prog()])
    out = capsys.readouterr().out
    assert rc == 1
    assert "[ir-alias]" in out
    assert "dropped flat parameter" in out
    assert HERE in out


def test_cli_ir_clean_fixture_exits_0(monkeypatch, capsys):
    monkeypatch.setattr(
        scaling, "DECLARED_BUDGETS",
        scaling.DECLARED_BUDGETS + (("tests/", 1.0),))
    rc = _main_ir(monkeypatch, [linear_prog(), kept_donation_prog()])
    out = capsys.readouterr().out
    assert rc == 0, out
    assert "clean" in out


def test_cli_write_scaling_round_trip(monkeypatch, capsys, tmp_path):
    from repro.analysis.__main__ import main
    monkeypatch.setattr(
        scaling, "DECLARED_BUDGETS",
        scaling.DECLARED_BUDGETS + (("tests/", 1.0),))
    monkeypatch.setattr("repro.analysis.ir.programs.engine_programs",
                        lambda: [linear_prog()])
    scaling_file = tmp_path / "scaling.json"
    rc = main(["--root", str(REPO), "--write-scaling",
               "--scaling-file", str(scaling_file)])
    assert rc == 0 and scaling_file.exists()
    rec = json.loads(scaling_file.read_text())
    assert "fixture[rowsum]" in rec["programs"]
    rc = main(["--root", str(REPO), "--no-lint", "--no-contracts", "--ir",
               "--baseline", "no_such_baseline.txt",
               "--scaling-file", str(scaling_file)])
    capsys.readouterr()
    assert rc == 0


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
