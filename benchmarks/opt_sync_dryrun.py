"""§Perf pair 3 — the paper's technique itself at pod scale.

Compares the cross-pod collective traffic of one communication round of:
  (a) per-step data parallelism: grads pmean'd over the pod axis every
      inner step (the "synchronous transmission" the paper argues against);
  (b) OpportunisticSync: local SGD for e inner steps, opportunistic
      snapshots (free: the snapshot is a local copy; the 'transmission' is
      deferred), one masked psum at the round boundary (Alg. 2's rescue).

Both programs are lowered at FULL llama3.2-1b size on a pod-only mesh (one
placeholder device per pod — cross-pod traffic is exactly what the HLO's
collectives show; intra-pod sharding is orthogonal and identical in both).

  PYTHONPATH=src python -m benchmarks.opt_sync_dryrun [--inner-steps 6]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs import get_config
from repro.core.opportunistic_sync import OppSyncConfig, make_opp_sync_round
from repro.models import build_model
from repro.optim import sgd
from repro.training import create_train_state, make_train_step
from repro.utils.hlo import collective_stats


def build_inputs(model, cfg, n_pods, B, S):
    state0 = jax.eval_shape(
        lambda k: create_train_state(model.init(k), sgd(1e-2),
                                     with_opt_sync=True,
                                     tau_extra0=cfg.tau_extra0),
        jax.random.PRNGKey(0))
    stack = lambda t: jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct((n_pods,) + a.shape, a.dtype), state0)
    state = stack(state0)
    batches = {
        "tokens": jax.ShapeDtypeStruct((n_pods, cfg.inner_steps, B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((n_pods, cfg.inner_steps, B, S), jnp.int32),
    }
    return state, batches


def lower_opp(model, cfg, mesh, state, batches, n_pods):
    train_step = make_train_step(model, sgd(1e-2))
    state_spec = jax.tree_util.tree_map(lambda _: P("pod"), state)
    batch_spec = jax.tree_util.tree_map(lambda _: P("pod"), batches)
    one_round = make_opp_sync_round(cfg, train_step, mesh, state_spec,
                                    batch_spec)
    rates = jax.ShapeDtypeStruct((cfg.inner_steps + 1, n_pods), jnp.float32)
    outs = jax.ShapeDtypeStruct((cfg.inner_steps + 1, n_pods), jnp.bool_)
    arr = jax.ShapeDtypeStruct((n_pods,), jnp.bool_)
    with mesh:
        return one_round.lower(state, batches, rates, outs, arr).compile()


def lower_dp(model, cfg, mesh, state, batches):
    """Per-step grad pmean over the pod axis (classic synchronous DP)."""
    base_step = make_train_step(model, sgd(1e-2))

    def dp_round(state, batches):
        sq = lambda t: jax.tree_util.tree_map(lambda a: a[0], t)
        ex = lambda t: jax.tree_util.tree_map(lambda a: a[None], t)
        st, bt = sq(state), sq(batches)

        def inner(st, batch):
            # grads synchronized across pods EVERY step
            from repro.training.step import loss_fn
            from repro.optim.sgd import apply_updates
            (loss, parts), grads = jax.value_and_grad(
                lambda p: loss_fn(model, p, batch), has_aux=True)(st.params)
            grads = jax.tree_util.tree_map(
                lambda g: jax.lax.pmean(g, "pod"), grads)
            opt = sgd(1e-2)
            updates, opt_state = opt.update(grads, st.opt_state, st.params)
            st = st._replace(params=apply_updates(st.params, updates),
                             opt_state=opt_state, step=st.step + 1)
            return st, loss

        st, losses = jax.lax.scan(inner, st, bt)
        return ex(st), ex(losses)

    state_spec = jax.tree_util.tree_map(lambda _: P("pod"), state)
    batch_spec = jax.tree_util.tree_map(lambda _: P("pod"), batches)
    fn = jax.jit(shard_map(dp_round, mesh=mesh,
                           in_specs=(state_spec, batch_spec),
                           out_specs=(state_spec, P("pod", None)),
                           check_rep=False))
    with mesh:
        return fn.lower(state, batches).compile()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--inner-steps", type=int, default=6)
    ap.add_argument("--budget", type=int, default=2)
    ap.add_argument("--n-pods", type=int, default=2)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--out", default="results/opt_sync_dryrun.jsonl")
    args = ap.parse_args()

    cfg = OppSyncConfig(inner_steps=args.inner_steps, budget=args.budget)
    mcfg = get_config(args.arch).replace(param_dtype="bfloat16",
                                         dtype="bfloat16")
    model = build_model(mcfg)
    mesh = jax.make_mesh((args.n_pods,), ("pod",))
    B, S = 4, 512     # per-pod microbatch; cross-pod traffic is param-bound
    state, batches = build_inputs(model, cfg, args.n_pods, B, S)

    rows = []
    for tag, lower in (("per_step_dp", lower_dp), ("opportunistic_sync",
                                                   lower_opp)):
        if tag == "per_step_dp":
            compiled = lower(model, cfg, mesh, state, batches)
        else:
            compiled = lower(model, cfg, mesh, state, batches, args.n_pods)
        st = collective_stats(compiled.as_text())
        # scan bodies appear once in HLO: per-step collectives run e times
        mult = args.inner_steps if tag == "per_step_dp" else 1
        in_loop = sum(v["bytes"] for v in st.values())
        row = {"tag": tag, "arch": args.arch, "e": args.inner_steps,
               "b": args.budget,
               "hlo_collective_bytes": in_loop,
               "per_round_collective_bytes": in_loop * mult,
               "detail": st}
        rows.append(row)
        print(f"{tag}: HLO coll bytes {in_loop/2**20:.1f} MiB x{mult} "
              f"= {in_loop*mult/2**30:.2f} GiB per round", flush=True)

    ratio = rows[0]["per_round_collective_bytes"] / \
        max(rows[1]["per_round_collective_bytes"], 1)
    print(f"cross-pod traffic reduction: {ratio:.1f}x "
          f"(expected ~e = {args.inner_steps} for grads-vs-params parity)")
    with open(args.out, "a") as f:
        for r in rows:
            f.write(json.dumps(r) + "\n")


if __name__ == "__main__":
    main()
