"""§Perf hillclimbing driver — hypothesis → change → re-lower → verdict.

Each experiment re-runs the dry-run for one (arch, shape) pair with a config
or option delta and reports the three roofline terms vs the baseline.  The
narrative log (napkin math + verdicts) lives in EXPERIMENTS.md §Perf; this
script is the measurement harness that produced it.

  PYTHONPATH=src python -m benchmarks.hillclimb --pair llama3-405b:train_4k
"""
from __future__ import annotations

import argparse
import json
from typing import Any, Dict, List, Optional

# NOTE: importing repro.launch.dryrun sets XLA_FLAGS to 512 host devices —
# this module is dry-run-only, exactly like repro.launch.dryrun itself.
from repro.launch import dryrun


def experiment(arch: str, shape: str, tag: str,
               opts: Optional[dict] = None,
               overrides: Optional[dict] = None,
               multi_pod: bool = False) -> Dict[str, Any]:
    rec = dryrun.run_one(arch, shape, multi_pod, opts=opts,
                         cfg_overrides=overrides, verbose=False)
    out = {"tag": tag, "arch": arch, "shape": shape,
           "opts": opts or {}, "overrides": overrides or {},
           "status": rec.get("status")}
    if rec.get("status") == "ok":
        out["roofline"] = rec["roofline"]
        out["bytes_per_device"] = rec["bytes_per_device"]
        out["collectives"] = rec.get("collectives_scan_hlo")
        r = rec["roofline"]
        print(f"[{tag}] compute={r['compute_s']*1e3:.1f}ms "
              f"mem={r['memory_s']*1e3:.1f}ms coll={r['collective_s']*1e3:.1f}ms "
              f"dom={r['dominant']} useful={r['useful_ratio']:.3f} "
              f"hbm/dev={out['bytes_per_device']/2**30:.2f}GiB", flush=True)
    else:
        print(f"[{tag}] {rec.get('status')}: {rec.get('error','')[:200]}",
              flush=True)
    return out


PAIRS: Dict[str, List[Dict[str, Any]]] = {
    # 1. worst memory pressure: 405B training on 256 chips (19.1 GiB/dev > 16)
    "llama3-405b:train_4k": [
        dict(tag="baseline_remat_full", opts={"remat": "full"}),
        dict(tag="remat_dots", opts={"remat": "dots"}),
        dict(tag="remat_none", opts={"remat": "none"}),
        dict(tag="fused_head", opts={"remat": "full", "fused_head": True}),
        dict(tag="fused_head_bf16_moments",
             opts={"remat": "full", "fused_head": True,
                   "adam_bf16_moments": True}),
    ],
    # 2. MoE decode: worst useful_ratio — dispatch strategy comparison
    "llama4-maverick-400b-a17b:decode_32k": [
        dict(tag="baseline_scatter", opts={"moe_dispatch": "scatter"}),
        dict(tag="dense_dispatch", opts={"moe_dispatch": "dense"}),
    ],
    "granite-moe-3b-a800m:train_4k": [
        dict(tag="baseline_scatter", opts={"moe_dispatch": "scatter"}),
        dict(tag="dense_dispatch", opts={"moe_dispatch": "dense"}),
        dict(tag="dense_fused_head",
             opts={"moe_dispatch": "dense", "fused_head": True}),
    ],
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", default=None,
                    help="arch:shape (default: all predefined)")
    ap.add_argument("--out", default="results/hillclimb.jsonl")
    args = ap.parse_args()

    pairs = {args.pair: PAIRS[args.pair]} if args.pair else PAIRS
    for pair, experiments in pairs.items():
        arch, shape = pair.split(":")
        print(f"=== hillclimb {arch} x {shape} ===", flush=True)
        for ex in experiments:
            rec = experiment(arch, shape, ex["tag"], ex.get("opts"),
                             ex.get("overrides"), ex.get("multi_pod", False))
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
