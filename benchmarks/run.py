"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_round,derived`` CSV (kernel rows report per-call
micros in the same column).  Default scale is CI-friendly
(short sims); EXPERIMENTS.md's full-scale numbers come from
``--rounds 100 --seeds 3`` runs (same code).

  PYTHONPATH=src python -m benchmarks.run                 # everything, short
  PYTHONPATH=src python -m benchmarks.run --only fig3b --rounds 100
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _us_per_round(r) -> float:
    # kernel/roofline rows still report us_per_call (per invocation);
    # simulation rows report us_per_round (see paper_experiments docstring)
    return r.get("us_per_round", r.get("us_per_call", 0.0))


def _print_csv(rows) -> None:
    for r in rows:
        derived = {k: v for k, v in r.items()
                   if k not in ("name", "us_per_round", "us_per_call",
                                "curve")}
        print(f"{r['name']},{_us_per_round(r):.1f},"
              f"\"{json.dumps(derived, sort_keys=True)}\"")
        sys.stdout.flush()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--seeds", type=int, default=1)
    ap.add_argument("--only", default=None,
                    choices=[None, "fig3a", "fig3b", "fig3c", "fig3d",
                             "beyond", "kernels", "roofline", "ablations"])
    ap.add_argument("--engine", default="sweep", choices=["sweep", "loop"],
                    help="fig3 panels: vectorized sweep engine (default) "
                         "or the per-cell loop")
    from repro.core.schemes import registered_schemes
    ap.add_argument("--scheme", default=None, choices=registered_schemes(),
                    help="also run this registered transmission scheme as "
                         "a one-scheme panel vs the opt reference "
                         "(repro.core.schemes registry)")
    ap.add_argument("--scheme-b", type=float, default=2.0,
                    help="transmission budget for the --scheme panel")
    ap.add_argument("--out", default=None, help="also append JSON rows here")
    args = ap.parse_args()
    seeds = tuple(range(args.seeds))

    from benchmarks import kernel_bench
    from benchmarks import paper_experiments as pe

    print("name,us_per_round,derived")
    all_rows = []

    def emit(rows):
        _print_csv(rows)
        all_rows.extend(rows)

    if args.only in (None, "fig3a"):
        emit(pe.fig3a_loss_by_distribution(args.rounds, seeds, args.engine))
    if args.only in (None, "fig3b"):
        emit(pe.fig3b_opt_vs_async(args.rounds, seeds, args.engine))
    if args.only in (None, "fig3c"):
        emit(pe.fig3c_budget_sweep(args.rounds, seeds, args.engine))
    if args.only in (None, "fig3d"):
        emit(pe.fig3d_tau_sweep(args.rounds, seeds, args.engine))
    if args.only in (None, "beyond"):
        emit(pe.beyond_paper_delta_codec(args.rounds, seeds, args.engine))
    if args.scheme:
        emit(pe.scheme_panel(args.scheme, args.rounds, seeds, args.engine,
                             b=args.scheme_b))
    if args.only == "ablations":     # beyond-paper ablations (EXPERIMENTS.md)
        emit(pe.ablation_schedule_placement(args.rounds, seeds))
        emit(pe.ablation_local_epochs(args.rounds, seeds))
    if args.only in (None, "kernels"):
        emit(kernel_bench.all_benches())
    if args.only in (None, "roofline"):
        path = "results/dryrun_singlepod.jsonl"
        if os.path.exists(path):
            from benchmarks import roofline
            emit(roofline.csv_rows(roofline.load(path)))
        else:
            print("# roofline: results/dryrun_singlepod.jsonl not found "
                  "(run repro.launch.dryrun --all first)", file=sys.stderr)

    if args.out:
        with open(args.out, "a") as f:
            for r in all_rows:
                f.write(json.dumps(r) + "\n")


if __name__ == "__main__":
    main()
