"""Paper-figure reproductions — one function per panel of Fig. 3.

Each returns a list of result dicts; benchmarks/run.py prints the CSV and
EXPERIMENTS.md records the full-scale numbers.  Claims under test (DESIGN.md
§1): C1 OPT>Async (accuracy + stability), C2 b=1->2 jump, C3 b sweep knee,
C4 τ_max cliff, C5 iid robustness.

Engines:

- ``sweep`` (default) — the vectorized sweep engine (core/sweep): each
  panel compiles to one program per scheme with seeds/distributions vmapped
  on a (mesh-shardable) sim axis, configs (b, τ_max) vmapped on a traced
  axis, and rounds scanned on-device.  Channel/batch RNG is jax.random
  (seeded, but not the host numpy stream — see EXPERIMENTS.md).
- ``loop`` — one ``run_hsfl`` per (scheme, seed, config) cell (the
  host-RNG reference engine; every panel, codec included, also runs here).

Timing fields: ``us_per_round`` is wall-µs per *simulated communication
round* (grid wall-clock / total rounds simulated — for sweep records this
is the panel-level amortized figure, identical across the panel's rows);
``rounds_per_sec`` is its reciprocal throughput.  (The pre-PR-2 field
``us_per_call`` reported the same per-round quantity under a misleading
name.)
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence

import numpy as np

from repro.api import Experiment
from repro.core.hsfl import HSFLConfig
from repro.core.sweep import (SweepSpec, fig3a_spec, fig3b_spec, fig3c_spec,
                              fig3d_spec)


def _curve(accs: np.ndarray, rounds: int) -> List[float]:
    """Mean accuracy curve, subsampled to ≤20 points.  accs: (S, rounds)."""
    stride = max(1, rounds // 20)
    return [round(float(accs[:, i].mean()), 4)
            for i in range(0, rounds, stride)]


def _record(tag: str, *, acc: np.ndarray, bytes_sent: np.ndarray,
            rescued: np.ndarray, dropped: np.ndarray, rounds: int,
            us_per_round: float, rounds_per_sec: float) -> Dict:
    """One CSV row from per-round metric arrays shaped (S, rounds)."""
    finals = acc[:, -5:].mean(axis=1)            # SimLog.final_acc
    return {
        "name": tag,
        "us_per_round": us_per_round,
        "rounds_per_sec": round(rounds_per_sec, 3),
        "final_acc": float(finals.mean()),
        "acc_std": float(finals.std()),
        "avg_comm_mb": float(bytes_sent.mean(axis=1).mean() / 1e6),
        "tail_std": float(np.std(acc[:, -10:], axis=1).mean()),
        "rescues": int(rescued.sum()),
        "drops": int(dropped.sum()),
        "curve": _curve(acc, rounds),
    }


# ---------------------------------------------------------------------------
# loop engine (the per-cell reference; also the codec path)
# ---------------------------------------------------------------------------

def _run(tag: str, rounds: int, seeds=(0,), **kw) -> Dict:
    t0 = time.time()
    accs, bytes_, resc, drop = [], [], [], []
    logs = (Experiment(HSFLConfig(rounds=rounds, **kw))
            .with_seeds(*seeds).run(engine="fused"))
    for log in (logs if isinstance(logs, list) else [logs]):
        accs.append([a for a in log.acc_curve if a == a])
        bytes_.append([r.bytes_sent for r in log.rounds])
        resc.append(sum(r.used_snapshot for r in log.rounds))
        drop.append(sum(r.dropped for r in log.rounds))
    elapsed = time.time() - t0
    n_rounds_total = rounds * len(seeds)
    return _record(tag, acc=np.asarray(accs), bytes_sent=np.asarray(bytes_),
                   rescued=np.asarray(resc), dropped=np.asarray(drop),
                   rounds=rounds,
                   us_per_round=elapsed / n_rounds_total * 1e6,
                   rounds_per_sec=n_rounds_total / max(elapsed, 1e-9))


# ---------------------------------------------------------------------------
# sweep engine: one compiled program per scheme group, records per cell
# ---------------------------------------------------------------------------

def _sweep_panel(specs: Sequence[SweepSpec], namer) -> List[Dict]:
    """Run SweepSpecs and emit one record per (group, distribution, config).

    ``namer(label, dist, cfg) -> tag or None`` (None skips the cell);
    ``label`` is the group label — the scheme, plus ``"+codec"`` for
    delta-codec groups, so codec × scheme grids name their rows apart.
    Wall-clock is amortized over every simulated round in the panel — the
    whole point of the sweep engine — so each record carries the same
    panel-level ``us_per_round``/``rounds_per_sec``.
    """
    t0 = time.time()
    results = [Experiment.from_spec(spec).run(engine="sweep")
               for spec in specs]
    elapsed = time.time() - t0
    rounds = results[0].rounds
    total_rounds = sum(r.n_simulations for r in results) * rounds
    upr = elapsed / max(total_rounds, 1) * 1e6
    rps = total_rounds / max(elapsed, 1e-9)

    out = []
    for res in results:
        for g in res.groups:
            dists = sorted({d for _, d in g.sims},
                           key=[d for _, d in g.sims].index)
            for dist in dists:
                rows = [i for i, (_, d) in enumerate(g.sims) if d == dist]
                for ci, cfg in enumerate(g.cfgs):
                    tag = namer(g.label or g.scheme, dist, cfg)
                    if tag is None:
                        continue
                    m = g.metrics
                    out.append(_record(
                        tag,
                        acc=m["test_acc"][rows, ci],
                        bytes_sent=m["bytes_sent"][rows, ci],
                        rescued=m["rescued"][rows, ci],
                        dropped=m["dropped"][rows, ci],
                        rounds=rounds, us_per_round=upr,
                        rounds_per_sec=rps))
    return out


def fig3a_loss_by_distribution(rounds: int = 60, seeds=(0, 1),
                               engine: str = "sweep") -> List[Dict]:
    """Fig. 3(a): OPT (b=2) vs discard across iid / non-iid / imbalanced."""
    if engine == "loop":
        out = []
        for dist in ("iid", "noniid", "imbalanced"):
            out.append(_run(f"fig3a_{dist}_opt_b2", rounds, seeds,
                            scheme="opt", b=2, distribution=dist))
            out.append(_run(f"fig3a_{dist}_discard_b1", rounds, seeds,
                            scheme="discard", b=1, distribution=dist))
        return out
    suffix = {"opt": "opt_b2", "discard": "discard_b1"}
    return _sweep_panel(
        fig3a_spec(rounds, seeds),
        lambda scheme, dist, cfg: f"fig3a_{dist}_{suffix[scheme]}")


def fig3b_opt_vs_async(rounds: int = 60, seeds=(0, 1),
                       engine: str = "sweep") -> List[Dict]:
    """Fig. 3(b): OPT-HSFL vs Async-HSFL (staleness-weighted) on non-iid."""
    if engine == "loop":
        return [
            _run("fig3b_opt_b2", rounds, seeds, scheme="opt", b=2),
            _run("fig3b_async", rounds, seeds, scheme="async", b=1),
            _run("fig3b_discard_b1", rounds, seeds, scheme="discard", b=1),
        ]
    tags = {"opt": "fig3b_opt_b2", "async": "fig3b_async",
            "discard": "fig3b_discard_b1"}
    return _sweep_panel(fig3b_spec(rounds, seeds),
                        lambda scheme, dist, cfg: tags[scheme])


def fig3c_budget_sweep(rounds: int = 60, seeds=(0,),
                       engine: str = "sweep") -> List[Dict]:
    """Fig. 3(c): accuracy & comm overhead vs transmission budget b."""
    if engine == "loop":
        return [_run(f"fig3c_b{b}", rounds, seeds, scheme="opt", b=b)
                for b in (1, 2, 3, 4, 5, 6)]
    return _sweep_panel(
        fig3c_spec(rounds, seeds),
        lambda scheme, dist, cfg: f"fig3c_b{int(cfg['b'])}")


def fig3d_tau_sweep(rounds: int = 60, seeds=(0,),
                    engine: str = "sweep") -> List[Dict]:
    """Fig. 3(d): accuracy & comm overhead vs one-round latency cap τ_max."""
    if engine == "loop":
        return [_run(f"fig3d_tau{tau}", rounds, seeds, scheme="opt", b=2,
                     tau_max=float(tau)) for tau in (7, 8, 9, 10, 11)]
    return _sweep_panel(
        fig3d_spec(rounds, seeds),
        lambda scheme, dist, cfg: f"fig3d_tau{int(cfg['tau_max'])}")


def ablation_schedule_placement(rounds: int = 40, seeds=(0,)) -> List[Dict]:
    """Beyond-paper ablation: WHEN to snapshot (Sec. III-B notes the epoch
    can be 'manually set by the system').  Later snapshots are fresher when
    they rescue, but have fewer retry opportunities under outages."""
    return [
        _run("abl_sched_default_e3", rounds, seeds, scheme="opt", b=2),
        _run("abl_sched_early_e1", rounds, seeds, scheme="opt", b=2,
             schedule_override=(1,)),
        _run("abl_sched_late_e5", rounds, seeds, scheme="opt", b=2,
             schedule_override=(5,)),
    ]


def ablation_local_epochs(rounds: int = 40, seeds=(0,)) -> List[Dict]:
    """Paper's conclusion: 'advantages more evident with longer local
    training'.  Compare the OPT-vs-discard gap at e=6 vs e=12."""
    out = []
    for e in (6, 12):
        out.append(_run(f"abl_e{e}_opt_b2", rounds, seeds, scheme="opt", b=2,
                        local_epochs=e))
        out.append(_run(f"abl_e{e}_discard", rounds, seeds, scheme="discard",
                        b=1, local_epochs=e))
    return out


def beyond_paper_delta_codec(rounds: int = 60, seeds=(0,),
                             engine: str = "sweep") -> List[Dict]:
    """Beyond-paper: int8 delta-codec compressed snapshots (kernels/delta_codec)
    shrink eq. 15's payload ~4x -> more opportunistic windows affordable at
    the same wireless budget.  ``use_delta_codec`` runs the codec end to
    end: snapshots are stored/rescued as quantized deltas and the payload
    ratio is derived from the actual int8+scale byte count.

    On the sweep engine the codec is a *group static*
    (``("opt", {"b": 2.0, "use_delta_codec": True})``), so the codec ×
    budget grid compiles as one codec program plus the uncompressed
    baseline — the panel that used to be loop-engine-only."""
    if engine == "loop":
        return [
            _run("beyond_codec_off_b2", rounds, seeds, scheme="opt", b=2),
            _run("beyond_codec_on_b2", rounds, seeds, scheme="opt", b=2,
                 use_delta_codec=True),
            _run("beyond_codec_on_b4", rounds, seeds, scheme="opt", b=4,
                 use_delta_codec=True),
        ]
    base = HSFLConfig(rounds=rounds, scheme="opt")
    spec = SweepSpec(base=base, seeds=tuple(seeds),
                     schemes=(("opt", {"b": 2.0}),
                              ("opt", {"b": 2.0, "use_delta_codec": True}),
                              ("opt", {"b": 4.0, "use_delta_codec": True})))
    return _sweep_panel(
        [spec],
        lambda label, dist, cfg: ("beyond_codec_"
                                  f"{'on' if label.endswith('+codec') else 'off'}"
                                  f"_b{int(cfg['b'])}"))


def scheme_panel(scheme: str, rounds: int = 60, seeds=(0,),
                 engine: str = "sweep", b: float = 2.0) -> List[Dict]:
    """Any *registered* transmission scheme (``repro.core.schemes``) as a
    one-scheme panel next to the opt reference — the ``--scheme`` hook of
    ``benchmarks/run.py``.  Runs on either engine through the Experiment
    facade, so a newly registered scheme is benchmarkable with zero code."""
    with_ref = scheme != "opt"
    if engine == "loop":
        out = [_run(f"scheme_{scheme}_b{int(b)}", rounds, seeds,
                    scheme=scheme, b=int(b))]
        if with_ref:
            out.append(_run(f"scheme_opt_b{int(b)}_ref", rounds, seeds,
                            scheme="opt", b=int(b)))
        return out
    ex = (Experiment(HSFLConfig(rounds=rounds)).with_seeds(*seeds)
          .with_scheme(scheme, b=float(b)))
    tags = {scheme: f"scheme_{scheme}_b{int(b)}"}
    if with_ref:
        ex = ex.with_scheme("opt", b=float(b))
        tags["opt"] = f"scheme_opt_b{int(b)}_ref"
    return _sweep_panel([ex.to_spec()], lambda label, dist, cfg: tags.get(label))
