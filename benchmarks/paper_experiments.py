"""Paper-figure reproductions — one function per panel of Fig. 3.

Each returns a list of result dicts; benchmarks/run.py prints the CSV and
EXPERIMENTS.md records the full-scale numbers.  Claims under test (DESIGN.md
§1): C1 OPT>Async (accuracy + stability), C2 b=1->2 jump, C3 b sweep knee,
C4 τ_max cliff, C5 iid robustness.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core.hsfl import HSFLConfig, run_hsfl


def _run(tag: str, rounds: int, seeds=(0,), **kw) -> Dict:
    t0 = time.time()
    finals, comms, tail_stds, curves = [], [], [], []
    rescues = drops = 0
    for seed in seeds:
        log = run_hsfl(HSFLConfig(rounds=rounds, seed=seed, **kw))
        s = log.summary()
        finals.append(s["final_acc"])
        comms.append(s["avg_comm_mb"])
        accs = [a for a in log.acc_curve if a == a]
        tail_stds.append(float(np.std(accs[-10:])))
        curves.append(accs)
        rescues += s["snapshot_rescues"]
        drops += s["drops"]
    n_rounds_total = rounds * len(seeds)
    return {
        "name": tag,
        "us_per_call": (time.time() - t0) / n_rounds_total * 1e6,
        "final_acc": float(np.mean(finals)),
        "acc_std": float(np.std(finals)),
        "avg_comm_mb": float(np.mean(comms)),
        "tail_std": float(np.mean(tail_stds)),
        "rescues": rescues,
        "drops": drops,
        "curve": [round(float(np.mean([c[i] for c in curves])), 4)
                  for i in range(0, rounds, max(1, rounds // 20))],
    }


def fig3a_loss_by_distribution(rounds: int = 60, seeds=(0, 1)) -> List[Dict]:
    """Fig. 3(a): OPT (b=2) vs discard across iid / non-iid / imbalanced."""
    out = []
    for dist in ("iid", "noniid", "imbalanced"):
        out.append(_run(f"fig3a_{dist}_opt_b2", rounds, seeds,
                        scheme="opt", b=2, distribution=dist))
        out.append(_run(f"fig3a_{dist}_discard_b1", rounds, seeds,
                        scheme="discard", b=1, distribution=dist))
    return out


def fig3b_opt_vs_async(rounds: int = 60, seeds=(0, 1)) -> List[Dict]:
    """Fig. 3(b): OPT-HSFL vs Async-HSFL (staleness-weighted) on non-iid."""
    return [
        _run("fig3b_opt_b2", rounds, seeds, scheme="opt", b=2),
        _run("fig3b_async", rounds, seeds, scheme="async", b=1),
        _run("fig3b_discard_b1", rounds, seeds, scheme="discard", b=1),
    ]


def fig3c_budget_sweep(rounds: int = 60, seeds=(0,)) -> List[Dict]:
    """Fig. 3(c): accuracy & comm overhead vs transmission budget b."""
    return [_run(f"fig3c_b{b}", rounds, seeds, scheme="opt", b=b)
            for b in (1, 2, 3, 4, 5, 6)]


def fig3d_tau_sweep(rounds: int = 60, seeds=(0,)) -> List[Dict]:
    """Fig. 3(d): accuracy & comm overhead vs one-round latency cap τ_max."""
    return [_run(f"fig3d_tau{tau}", rounds, seeds, scheme="opt", b=2,
                 tau_max=float(tau)) for tau in (7, 8, 9, 10, 11)]


def ablation_schedule_placement(rounds: int = 40, seeds=(0,)) -> List[Dict]:
    """Beyond-paper ablation: WHEN to snapshot (Sec. III-B notes the epoch
    can be 'manually set by the system').  Later snapshots are fresher when
    they rescue, but have fewer retry opportunities under outages."""
    return [
        _run("abl_sched_default_e3", rounds, seeds, scheme="opt", b=2),
        _run("abl_sched_early_e1", rounds, seeds, scheme="opt", b=2,
             schedule_override=(1,)),
        _run("abl_sched_late_e5", rounds, seeds, scheme="opt", b=2,
             schedule_override=(5,)),
    ]


def ablation_local_epochs(rounds: int = 40, seeds=(0,)) -> List[Dict]:
    """Paper's conclusion: 'advantages more evident with longer local
    training'.  Compare the OPT-vs-discard gap at e=6 vs e=12."""
    out = []
    for e in (6, 12):
        out.append(_run(f"abl_e{e}_opt_b2", rounds, seeds, scheme="opt", b=2,
                        local_epochs=e))
        out.append(_run(f"abl_e{e}_discard", rounds, seeds, scheme="discard",
                        b=1, local_epochs=e))
    return out


def beyond_paper_delta_codec(rounds: int = 60, seeds=(0,)) -> List[Dict]:
    """Beyond-paper: int8 delta-codec compressed snapshots (kernels/delta_codec)
    shrink eq. 15's payload ~4x -> more opportunistic windows affordable at
    the same wireless budget.  ``use_delta_codec`` runs the codec end to
    end: snapshots are stored/rescued as quantized deltas and the payload
    ratio is derived from the actual int8+scale byte count."""
    return [
        _run("beyond_codec_off_b2", rounds, seeds, scheme="opt", b=2),
        _run("beyond_codec_on_b2", rounds, seeds, scheme="opt", b=2,
             use_delta_codec=True),
        _run("beyond_codec_on_b4", rounds, seeds, scheme="opt", b=4,
             use_delta_codec=True),
    ]
