"""Fused vs host-loop HSFL round benchmark (the fig. 3 hot path).

Measures rounds/sec of ``HSFLSimulation.run_round`` at the paper's scale
(30 UAVs, K=10 selected, e=6 local epochs, b=2, OPT scheme) for:

  host          — the original Python control loop over OppTransmitter
  fused         — the single-jit device round (core/fused_round) on the
                  default forward policy: the *blocked* stacked-cohort
                  training step (kernels/fused_cnn ``*_k`` twins — the
                  user axis inside the kernels, not vmap) + donated round
                  carries.  ``--kernel``/``--precision``/``--block-k``
                  reroute it.
  fused_im2col  — the same round on the PR-1 step (forward_im2col +
                  autodiff): the compute floor the fused step is
                  measured against, *within the same run*
  fused_bf16    — blocked kernels at precision=bf16 (native AMX/AVX512
                  bf16 GEMMs under the tuned launch env; epoch-boundary
                  master casts).  Paired at fig3 scale AND at
                  ``--bf16-batch`` (the step is elementwise-bound at the
                  paper's toy batch=10 — bf16's GEMM win shows from
                  batch ~32 up; both rows are recorded honestly).
  fused_pallas  — the blocked Pallas kernel suite; interpret mode
                  off-TPU.  Blocked grids collapse interpret cost to one
                  Python-evaluated program per layer per step, so this
                  row now sits near the XLA path instead of 20x+ off.
  fused_vmapped — the PR-4 vmap-of-per-user-kernels step
                  (``batch_users=False``): the baseline the blocked
                  rows are paired against.
  fused_sharded — default policy, with the stacked-user axis sharded over
                  N forced host devices (bench-only XLA_FLAGS subprocess)
  fused_codec   — fused with int8 delta-codec snapshots

All ``fused*`` kernel/precision variants above are measured **paired**:
interleaved round-robin in ONE process (the container swings ±50%
between subprocesses — see Methodology).  A second paired run prices the
PR-9 Byzantine-robust aggregation: ``fused_trimmed`` (coordinate-wise
trimmed-mean, ``opt_trimmed``) against ``fused_mean`` (the masked
arithmetic mean, ``opt``) on the identical blocked round.  A ``step_bench`` child
additionally microbenchmarks the training *epoch* alone
(blocked-vs-vmapped for xla and pallas-interpret, f32-vs-bf16, the
``block_k`` tiling ladder) — the CI perf-guard reuses it.

Unless ``--no-tuned-env``, the tuned launch environment
(``repro.launch.env``: legacy XLA:CPU runtime flag, tcmalloc when
present) is exported to every measurement child; the BENCH record notes
which flags were applied.

plus the PR-2 *grid* engines, which time the whole Fig. 3(b) panel
(3 schemes × ``--grid-seeds`` seeds) instead of one round:

  grid_loop     — one **cold** ``run_hsfl`` per (scheme, seed) cell,
                  exactly what ``paper_experiments._run`` pays: dataset/sim
                  setup and fresh jit compiles per cell are inherent to the
                  loop engine (every ``HSFLSimulation`` builds new
                  closures) and are included in its wall
  grid_sweep    — the vectorized sweep engine (core/sweep): rounds scanned,
                  seeds vmapped (sharded over forced host devices in the
                  *_sharded* variant), channel realized on-device;
                  ``wall_s`` is end-to-end with compiles, with
                  ``steady_wall_s``/``compile_s`` split out since its per-
                  scheme programs are compiled once and reusable
  grid_sweep_codec — the same panel with int8 delta-codec snapshots
                  (``use_delta_codec``): compiles opt-codec + async only —
                  discard lowers onto the opt program at b=1
                  (``compiled_programs`` records the count)

Methodology: each engine runs in its own subprocess (so XLA device forcing
can't leak); per engine we run ``--warmup`` rounds first on the same
simulation instance so every K-bucket jit variant is compiled, then time
``--rounds`` rounds and report the mean.  Exception: every fused
kernel/precision comparison is measured *interleaved in one process*
(round of variant A, round of variant B, ..., repeated): the bench
container's throughput swings ±50% minute to minute, so sequential
subprocesses minutes apart cannot resolve step-level deltas — those rows
carry ``"paired": true``, and the ``step_bench`` rows additionally report
per-case *medians* over interleaved reps.  Results append to
BENCH_hsfl.json.

``--scheme`` runs the single-round engines under any *registered*
transmission scheme (the ``repro.core.schemes`` registry — the choices
list is dynamic, so a newly registered scheme is immediately benchable);
every row records its ``scheme`` label.

  PYTHONPATH=src python -m benchmarks.hsfl_round_bench
  PYTHONPATH=src python -m benchmarks.hsfl_round_bench --rounds 20 --devices 2
  PYTHONPATH=src python -m benchmarks.hsfl_round_bench --scheme deadline
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys


ENGINES = ("host", "fused", "fused_im2col", "fused_bf16", "fused_pallas",
           "fused_vmapped", "fused_codec", "fused_sharded",
           "grid_loop", "grid_sweep", "grid_sweep_codec")

# engine name -> HSFLConfig overrides (missing = CLI flags).  Entries may
# pin ``scheme`` too: the PR-9 robust-aggregate pair prices the fused
# coordinate-wise trimmed-mean against the masked arithmetic mean on the
# identical blocked round, interleaved in one process.
ENGINE_POLICY = {"fused_im2col": dict(kernel="im2col", precision="f32"),
                 "fused_bf16": dict(precision="bf16"),
                 "fused_pallas": dict(kernel="pallas"),
                 "fused_vmapped": dict(batch_users=False),
                 "fused_mean": dict(scheme="opt"),
                 "fused_trimmed": dict(scheme="opt_trimmed")}

# the default paired-variant set (round-robin, one process)
PAIR_VARIANTS = ("fused", "fused_im2col", "fused_bf16", "fused_pallas",
                 "fused_vmapped")


def measure_grid(engine: str, rounds: int, seeds: int) -> dict:
    """Wall-clock the whole fig3b grid: 3 schemes × seeds × rounds.

    ``grid_loop`` is exactly what ``paper_experiments._run`` does — a cold
    ``run_hsfl`` per (scheme, seed) cell, each paying dataset/sim setup and
    fresh jit compiles (the loop engine cannot amortize them across cells:
    every ``HSFLSimulation`` builds new closures).  ``grid_sweep`` reports
    the same end-to-end wall (``wall_s``, compiles included) plus the
    steady-state re-execution wall (``steady_wall_s``) and ``compile_s``
    separately, since the sweep's three programs are compiled once and
    reused for any number of seeds/configs/rounds.
    """
    import time

    import jax

    combos = (("opt", 2), ("async", 1), ("discard", 1))
    seed_list = tuple(range(seeds))
    base = dict(devices=len(jax.devices()), grid="fig3b",
                schemes=[s for s, _ in combos],
                sims=len(combos) * seeds, rounds_timed=rounds)

    if engine == "grid_loop":
        from repro.api import Experiment
        t0 = time.time()
        for scheme, b in combos:
            for sd in seed_list:
                (Experiment(scheme=scheme, b=b, seed=sd, rounds=rounds)
                 .run(engine="fused"))
        wall = time.time() - t0
        return dict(base, engine=engine, wall_s=round(wall, 2),
                    sim_rounds_per_sec=round(base["sims"] * rounds / wall, 3))

    from repro.api import Experiment
    from repro.core.sweep import fig3b_spec
    # grid_sweep_codec: the same fig3b panel with int8 delta-codec
    # snapshots — opt-codec + async compile; discard lowers onto opt@b=1
    spec = fig3b_spec(rounds, seed_list,
                      use_delta_codec=engine == "grid_sweep_codec")[0]
    res = Experiment.from_spec(spec).run(engine="sweep", timeit=True)
    steady = sum(g.run_s for g in res.groups)
    compile_s = sum(g.compile_s for g in res.groups)
    # background AOT compiles overlap execution, so the critical-path wall
    # is the compile total minus what was hidden behind running groups
    wall = steady + compile_s - res.compile_overlap_s
    return dict(base, engine=engine, wall_s=round(wall, 2),
                steady_wall_s=round(steady, 2),
                compile_s=round(compile_s, 2),
                compile_overlap_s=round(res.compile_overlap_s, 2),
                compiled_programs=res.n_programs,
                sim_rounds_per_sec=round(base["sims"] * rounds / steady, 3))


def measure_pair(warmup: int, rounds: int, kernel: str = "xla",
                 precision: str = "f32", scheme: str = "opt",
                 block_k: int = 0, variants=None,
                 batch_size: int = 0) -> dict:
    """Interleave every requested kernel/precision variant round-robin in
    ONE process, so all rows see the same container throttling — the only
    way this box can resolve step-level deltas (see module docstring).

    ``variants`` defaults to ``PAIR_VARIANTS``; the ``fused`` member uses
    the CLI ``--kernel``/``--precision``/``--block-k``, the rest take
    their ``ENGINE_POLICY`` override.  ``batch_size > 0`` reruns the pair
    at a non-paper batch (the bf16-vs-f32 operating-point rows); its rows
    are suffixed ``@b<N>`` so fig3-scale rows stay unambiguous."""
    import time

    import jax

    from repro.core.hsfl import HSFLConfig, HSFLSimulation

    names = tuple(variants) if variants else PAIR_VARIANTS
    base = dict(kernel=kernel, precision=precision, block_k=block_k)
    sims, state, policy = {}, {}, {}
    for name in names:
        over = {"scheme": scheme, **base, **ENGINE_POLICY.get(name, {})}
        if batch_size > 0:
            over["batch_size"] = batch_size
        cfg = HSFLConfig(b=2, rounds=warmup + rounds, **over)
        sims[name] = HSFLSimulation(cfg)
        state[name] = ([], 1)
        policy[name] = cfg
    for name, sim in sims.items():
        delayed, t = state[name]
        for _ in range(warmup):
            _, delayed = sim.run_round(t, delayed)
            t += 1
        jax.block_until_ready(sim.params)
        state[name] = (delayed, t)
    tot = {k: 0.0 for k in sims}
    sel = {k: 0 for k in sims}
    for _ in range(rounds):
        for name, sim in sims.items():
            delayed, t = state[name]
            t0 = time.time()
            log, delayed = sim.run_round(t, delayed)
            jax.block_until_ready(sim.params)
            tot[name] += time.time() - t0
            sel[name] += log.selected
            state[name] = (delayed, t + 1)
    rows = []
    suffix = f"@b{batch_size}" if batch_size > 0 else ""
    for name in names:
        cfg = policy[name]
        ms = tot[name] / rounds * 1e3
        rows.append({"engine": name + suffix, "ms_per_round": round(ms, 1),
                     "rounds_per_sec": round(1e3 / ms, 3),
                     "mean_selected": round(sel[name] / rounds, 1),
                     "scheme": cfg.scheme, "kernel": cfg.kernel,
                     "precision": cfg.precision, "block_k": cfg.block_k,
                     "batch_users": cfg.batch_users,
                     "batch_size": cfg.batch_size,
                     "paired": True, "devices": len(jax.devices())})
    return {"engine": "fused_pair", "rows": rows}


def measure_step_bench(reps: int = 30, warmup: int = 3,
                       bf16_batch: int = 32) -> dict:
    """Microbench the training *epoch* alone (no round machinery) at fig3
    scale: blocked vs vmapped grids for xla and pallas-interpret, bf16 vs
    f32, and the ``block_k`` tiling ladder — all interleaved per rep, with
    per-case medians (robust to container throttling spikes).

    The CI perf-guard replays the ``xla_blocked`` / ``xla_vmapped`` pair
    from this function and asserts blocked ≤ 1.3x vmapped.
    """
    import time

    import jax
    import jax.numpy as jnp

    from repro.kernels.fused_cnn import ForwardPolicy, make_stacked_epoch_fn
    from repro.models.cnn import init_cnn

    k, steps, bs, lr = 10, 4, 10, 0.01
    on_tpu = jax.default_backend() == "tpu"
    cases = {
        "xla_blocked": (ForwardPolicy(), bs),
        "xla_vmapped": (ForwardPolicy(batch_users=False), bs),
        "xla_blocked_bk5": (ForwardPolicy(block_k=5), bs),
        "xla_bf16": (ForwardPolicy(precision="bf16"), bs),
        "pallas_blocked": (ForwardPolicy(kernel="pallas",
                                         interpret=not on_tpu), bs),
        "pallas_blocked_bk5": (ForwardPolicy(kernel="pallas", block_k=5,
                                             interpret=not on_tpu), bs),
        "pallas_vmapped": (ForwardPolicy(kernel="pallas", batch_users=False,
                                         interpret=not on_tpu), bs),
        # the bf16 operating point: GEMM-bound from batch ~32 up
        f"xla_f32_b{bf16_batch}": (ForwardPolicy(), bf16_batch),
        f"xla_bf16_b{bf16_batch}": (ForwardPolicy(precision="bf16"),
                                    bf16_batch),
    }

    key = jax.random.PRNGKey(0)
    stacked = jax.vmap(init_cnn)(jax.random.split(key, k))
    data = {}
    for b in {b for _, b in cases.values()}:
        kx, ky = jax.random.split(jax.random.fold_in(key, b))
        data[b] = (jax.random.normal(kx, (k, steps, b, 28, 28, 1),
                                     jnp.float32),
                   jax.random.randint(ky, (k, steps, b), 0, 10))

    fns = {}
    for name, (pol, b) in cases.items():
        fn = jax.jit(make_stacked_epoch_fn(pol, lr))
        xs, ys = data[b]
        for _ in range(warmup):
            jax.block_until_ready(fn(stacked, xs, ys))
        fns[name] = (fn, xs, ys)

    times = {name: [] for name in cases}
    for _ in range(reps):
        for name, (fn, xs, ys) in fns.items():
            t0 = time.time()
            jax.block_until_ready(fn(stacked, xs, ys))
            times[name].append(time.time() - t0)

    med = {name: sorted(ts)[len(ts) // 2] * 1e3 for name, ts in times.items()}
    rows = [{"case": name, "ms_per_epoch": round(med[name], 2),
             "kernel": cases[name][0].kernel,
             "precision": cases[name][0].precision,
             "block_k": cases[name][0].block_k,
             "batch_users": cases[name][0].batch_users,
             "batch_size": cases[name][1]}
            for name in cases]
    ratios = {
        "xla_blocked_vs_vmapped":
            round(med["xla_vmapped"] / med["xla_blocked"], 2),
        "pallas_blocked_vs_vmapped":
            round(med["pallas_vmapped"] / med["pallas_blocked"], 2),
        "pallas_vs_xla_blocked":
            round(med["pallas_blocked"] / med["xla_blocked"], 2),
        "bf16_vs_f32": round(med["xla_blocked"] / med["xla_bf16"], 2),
        f"bf16_vs_f32_b{bf16_batch}":
            round(med[f"xla_f32_b{bf16_batch}"]
                  / med[f"xla_bf16_b{bf16_batch}"], 2),
    }
    return {"engine": "step_bench",
            "config": {"k": k, "steps_per_epoch": steps, "batch_size": bs,
                       "bf16_batch": bf16_batch, "reps": reps,
                       "stat": "median"},
            "rows": rows, "ratios": ratios}


def measure(engine: str, warmup: int, rounds: int,
            kernel: str = "xla", precision: str = "f32",
            scheme: str = "opt", block_k: int = 0) -> dict:
    import time

    import jax

    from repro.core.hsfl import HSFLConfig, HSFLSimulation

    if engine not in ENGINES:
        raise SystemExit(f"unknown engine {engine!r}; choose from {ENGINES}")
    over = dict(kernel=kernel, precision=precision, block_k=block_k,
                **ENGINE_POLICY.get(engine, {}))
    cfg = HSFLConfig(scheme=scheme, b=2, rounds=warmup + rounds,
                     use_fused_round=engine != "host",
                     use_delta_codec=engine == "fused_codec", **over)
    sim = HSFLSimulation(cfg)
    delayed, t = [], 1
    for _ in range(warmup):
        log, delayed = sim.run_round(t, delayed)
        t += 1
    jax.block_until_ready(sim.params)
    t0 = time.time()
    selected = 0
    for _ in range(rounds):
        log, delayed = sim.run_round(t, delayed)
        selected += log.selected
        t += 1
    jax.block_until_ready(sim.params)
    ms = (time.time() - t0) / rounds * 1e3
    return {"engine": engine, "ms_per_round": round(ms, 1),
            "rounds_per_sec": round(1e3 / ms, 3),
            "mean_selected": round(selected / rounds, 1),
            "scheme": cfg.scheme, "kernel": cfg.kernel,
            "precision": cfg.precision,
            "devices": len(jax.devices())}


def run_child(engine: str, args, devices: int = 1, tag: str = "",
              rounds: int | None = None, warmup: int | None = None,
              extra=()) -> dict:
    if args.no_tuned_env:
        env = dict(os.environ)
    else:
        from repro.launch.env import tuned_env
        env = tuned_env()
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    if devices > 1:
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                            f" --xla_force_host_platform_device_count={devices}")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.hsfl_round_bench",
         "--engine", engine,
         "--warmup", str(args.warmup if warmup is None else warmup),
         "--rounds", str(args.rounds if rounds is None else rounds),
         "--kernel", args.kernel, "--precision", args.precision,
         "--scheme", args.scheme, "--block-k", str(args.block_k),
         "--bf16-batch", str(args.bf16_batch),
         "--grid-rounds", str(args.grid_rounds),
         "--grid-seeds", str(args.grid_seeds)] + list(extra),
        capture_output=True, text=True, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    if out.returncode != 0:
        raise RuntimeError(f"{engine} failed:\n{out.stdout}\n{out.stderr}")
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    name = tag or engine
    if "rows" in rec:
        for row in rec["rows"]:
            if "ms_per_round" in row:
                print(f"{row['engine']:18s} {row['ms_per_round']:8.1f} "
                      f"ms/round ({row['rounds_per_sec']:.3f} rounds/s, "
                      f"paired)")
            else:
                print(f"{row['case']:18s} {row['ms_per_epoch']:8.2f} "
                      f"ms/epoch (step_bench)")
        return rec
    rec["engine"] = name
    if "ms_per_round" in rec:
        print(f"{name:18s} {rec['ms_per_round']:8.1f} ms/round "
              f"({rec['rounds_per_sec']:.3f} rounds/s, "
              f"devices={rec['devices']})")
    else:
        print(f"{name:18s} {rec['wall_s']:8.2f} s grid "
              f"({rec['sim_rounds_per_sec']:.3f} sim-rounds/s, "
              f"sims={rec['sims']}, devices={rec['devices']})")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--devices", type=int, default=2,
                    help="forced host devices for the sharded variants")
    ap.add_argument("--grid-rounds", type=int, default=8,
                    help="rounds per simulation for the fig3b grid engines")
    ap.add_argument("--grid-seeds", type=int, default=2,
                    help="seeds per scheme for the fig3b grid engines")
    ap.add_argument("--skip-grid", action="store_true",
                    help="only run the single-round engines")
    ap.add_argument("--kernel", default="xla",
                    choices=["xla", "pallas", "im2col"],
                    help="forward policy for the default fused engine "
                         "(kernels/fused_cnn.ForwardPolicy)")
    ap.add_argument("--precision", default="f32", choices=["f32", "bf16"],
                    help="compute precision for the default fused engine")
    ap.add_argument("--block-k", type=int, default=0,
                    help="user-tile size of the blocked kernel grid for "
                         "the default fused engine (0 = whole cohort in "
                         "one grid step)")
    ap.add_argument("--bf16-batch", type=int, default=32,
                    help="batch size for the second bf16-vs-f32 paired "
                         "run (the GEMM-bound operating point; the "
                         "paper's batch=10 round is elementwise-bound)")
    ap.add_argument("--no-tuned-env", action="store_true",
                    help="skip the tuned launch environment "
                         "(repro.launch.env) for all measurement children")
    ap.add_argument("--step-reps", type=int, default=30,
                    help="interleaved reps for the step_bench engine")
    ap.add_argument("--pair-variants", default="",
                    help="(internal) comma list of fused_pair variants")
    ap.add_argument("--pair-batch", type=int, default=0,
                    help="(internal) batch-size override for fused_pair")
    from repro.core.schemes import registered_schemes
    ap.add_argument("--scheme", default="opt", choices=registered_schemes(),
                    help="transmission scheme for the single-round engines "
                         "(any registered repro.core.schemes name); "
                         "recorded per row in BENCH_hsfl.json")
    ap.add_argument("--skip-policy-rows", action="store_true",
                    help="pair only fused vs fused_im2col and skip the "
                         "bf16 operating-point run and step_bench (CI "
                         "smoke size)")
    ap.add_argument("--out", default="BENCH_hsfl.json")
    ap.add_argument("--engine", default=None,
                    help="(internal) measure one engine and print JSON")
    args = ap.parse_args()

    if args.engine:
        if args.engine.startswith("grid_"):
            rec = measure_grid(args.engine, args.grid_rounds,
                               args.grid_seeds)
        elif args.engine == "fused_pair":
            variants = ([v for v in args.pair_variants.split(",") if v]
                        or None)
            rec = measure_pair(args.warmup, args.rounds,
                               kernel=args.kernel, precision=args.precision,
                               scheme=args.scheme, block_k=args.block_k,
                               variants=variants,
                               batch_size=args.pair_batch)
        elif args.engine == "step_bench":
            rec = measure_step_bench(reps=args.step_reps,
                                     bf16_batch=args.bf16_batch)
        else:
            rec = measure(args.engine, args.warmup, args.rounds,
                          kernel=args.kernel, precision=args.precision,
                          scheme=args.scheme, block_k=args.block_k)
        print(json.dumps(rec))
        return

    if not args.no_tuned_env:
        # children inherit the tuned env via run_child/tuned_env(); applying
        # it here too keeps a single source of truth for what was active
        from repro.launch.env import apply_tuned_env
        apply_tuned_env(verbose=True)

    recs = [run_child("host", args)]
    pair_extra = (["--pair-variants", "fused,fused_im2col"]
                  if args.skip_policy_rows else ())
    recs += run_child("fused_pair", args, extra=pair_extra)["rows"]
    step = None
    if not args.skip_policy_rows:
        # the bf16 operating point: same pair harness at --bf16-batch,
        # where the step is GEMM- rather than elementwise-bound
        recs += run_child(
            "fused_pair", args,
            extra=["--pair-variants", "fused,fused_bf16",
                   "--pair-batch", str(args.bf16_batch)])["rows"]
        # PR 9: price the Byzantine-robust aggregate — fused rounds under
        # coordinate-wise trimmed-mean vs the masked arithmetic mean,
        # identical blocked step, interleaved in one process
        recs += run_child(
            "fused_pair", args,
            extra=["--pair-variants", "fused_mean,fused_trimmed"])["rows"]
        step = run_child("step_bench", args)
    recs.append(run_child("fused_codec", args))
    if args.devices > 1:
        recs.append(run_child("fused_sharded", args, devices=args.devices))

    by = {r["engine"]: r for r in recs}
    host_ms = by["host"]["ms_per_round"]

    def ratio(num, den):
        return round(by[num]["ms_per_round"] / by[den]["ms_per_round"], 2)

    result = {
        "config": {"n_uavs": 30, "k_select": 10, "local_epochs": 6, "b": 2,
                   "scheme": args.scheme, "steps_per_epoch": 4,
                   "batch_size": 10, "block_k": args.block_k,
                   "rounds_timed": args.rounds, "warmup": args.warmup,
                   "tuned_env": not args.no_tuned_env},
        "engines": recs,
        "speedup_fused_vs_host": round(host_ms / by["fused"]["ms_per_round"],
                                       2),
        # the compute-floor comparison: blocked K-fused step vs the PR-1
        # autodiff step, same container, same run
        "speedup_fused_vs_im2col": ratio("fused_im2col", "fused"),
    }
    if not args.no_tuned_env:
        from repro.launch.env import TUNED_XLA_FLAGS
        result["config"]["xla_flags"] = sorted(TUNED_XLA_FLAGS)
    if "fused_vmapped" in by:
        # the tentpole: user axis inside the kernel grid vs PR-4's
        # vmap-of-per-user-kernels, full round, same process
        result["speedup_blocked_vs_vmapped"] = ratio("fused_vmapped",
                                                     "fused")
    if "fused_bf16" in by:
        result["round_bf16_vs_f32"] = ratio("fused", "fused_bf16")
    if "fused_pallas" in by:
        result["round_pallas_vs_xla"] = ratio("fused_pallas", "fused")
    if "fused_trimmed" in by:
        # robust-aggregation overhead: the masked sort network per
        # coordinate vs one masked mean, full fused round
        result["round_trimmed_vs_mean"] = ratio("fused_trimmed",
                                                "fused_mean")
    b32 = f"@b{args.bf16_batch}"
    if f"fused_bf16{b32}" in by:
        result[f"round_bf16_vs_f32{b32}"] = ratio(f"fused{b32}",
                                                  f"fused_bf16{b32}")
    if step is not None:
        result["step_bench"] = step
    if args.devices > 1:
        result["speedup_sharded_vs_host"] = round(
            host_ms / by["fused_sharded"]["ms_per_round"], 2)
    print(f"\nspeedup fused vs host: {result['speedup_fused_vs_host']}x")
    print(f"speedup fused (blocked K-fused) vs im2col step: "
          f"{result['speedup_fused_vs_im2col']}x")
    for key, label in (
            ("speedup_blocked_vs_vmapped", "blocked vs vmapped (round)"),
            ("round_bf16_vs_f32", "bf16 vs f32 (round, batch=10)"),
            (f"round_bf16_vs_f32{b32}",
             f"bf16 vs f32 (round, batch={args.bf16_batch})"),
            ("round_pallas_vs_xla", "pallas/xla round-time ratio"),
            ("round_trimmed_vs_mean",
             "trimmed-mean vs masked-mean (round)")):
        if key in result:
            print(f"{label}: {result[key]}x")
    if step is not None:
        for name, val in step["ratios"].items():
            print(f"step_bench {name}: {val}x")
    if "speedup_sharded_vs_host" in result:
        print(f"speedup sharded vs host: {result['speedup_sharded_vs_host']}x")

    if not args.skip_grid:
        # -- fig3b grid: loop of fused run_hsfl cells vs one sweep program --
        grid = [run_child("grid_loop", args),
                run_child("grid_sweep", args),
                run_child("grid_sweep_codec", args)]
        if args.devices > 1:
            grid.append(run_child("grid_sweep", args, devices=args.devices,
                                  tag="grid_sweep_sharded"))
        loop_w = grid[0]["wall_s"]
        gres = {
            "config": {"grid": "fig3b", "schemes": 3,
                       "seeds": args.grid_seeds,
                       "rounds_timed": args.grid_rounds,
                       "eval_every_round": True},
            "engines": grid,
            "speedup_sweep_vs_loop": round(loop_w / grid[1]["wall_s"], 2),
        }
        if args.devices > 1:
            gres["speedup_sweep_sharded_vs_loop"] = round(
                loop_w / grid[-1]["wall_s"], 2)
        print(f"speedup sweep vs loop (fig3b grid): "
              f"{gres['speedup_sweep_vs_loop']}x")
        if "speedup_sweep_sharded_vs_loop" in gres:
            print(f"speedup sweep sharded vs loop: "
                  f"{gres['speedup_sweep_sharded_vs_loop']}x")
        result["fig3b_grid"] = gres

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
