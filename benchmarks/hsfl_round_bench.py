"""Fused vs host-loop HSFL round benchmark (the fig. 3 hot path).

Measures rounds/sec of ``HSFLSimulation.run_round`` at the paper's scale
(30 UAVs, K=10 selected, e=6 local epochs, b=2, OPT scheme) for:

  host          — the original Python control loop over OppTransmitter
  fused         — the single-jit device round (core/fused_round)
  fused_sharded — same, with the stacked-user axis sharded over N forced
                  host devices (bench-only: XLA_FLAGS set in a subprocess)
  fused_codec   — fused with int8 delta-codec snapshots

Methodology: each engine runs in its own subprocess (so XLA device forcing
can't leak); per engine we run ``--warmup`` rounds first on the same
simulation instance so every K-bucket jit variant is compiled, then time
``--rounds`` rounds and report the mean.  Results append to BENCH_hsfl.json.

  PYTHONPATH=src python -m benchmarks.hsfl_round_bench
  PYTHONPATH=src python -m benchmarks.hsfl_round_bench --rounds 20 --devices 2
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys


ENGINES = ("host", "fused", "fused_codec", "fused_sharded")


def measure(engine: str, warmup: int, rounds: int) -> dict:
    import time

    import jax

    from repro.core.hsfl import HSFLConfig, HSFLSimulation

    if engine not in ENGINES:
        raise SystemExit(f"unknown engine {engine!r}; choose from {ENGINES}")
    cfg = HSFLConfig(scheme="opt", b=2, rounds=warmup + rounds,
                     use_fused_round=engine != "host",
                     use_delta_codec=engine == "fused_codec")
    sim = HSFLSimulation(cfg)
    delayed, t = [], 1
    for _ in range(warmup):
        log, delayed = sim.run_round(t, delayed)
        t += 1
    jax.block_until_ready(sim.params)
    t0 = time.time()
    selected = 0
    for _ in range(rounds):
        log, delayed = sim.run_round(t, delayed)
        selected += log.selected
        t += 1
    jax.block_until_ready(sim.params)
    ms = (time.time() - t0) / rounds * 1e3
    return {"engine": engine, "ms_per_round": round(ms, 1),
            "rounds_per_sec": round(1e3 / ms, 3),
            "mean_selected": round(selected / rounds, 1),
            "devices": len(jax.devices())}


def run_child(engine: str, args, devices: int = 1) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    if devices > 1:
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                            f" --xla_force_host_platform_device_count={devices}")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.hsfl_round_bench",
         "--engine", engine, "--warmup", str(args.warmup),
         "--rounds", str(args.rounds)],
        capture_output=True, text=True, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    if out.returncode != 0:
        raise RuntimeError(f"{engine} failed:\n{out.stdout}\n{out.stderr}")
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    print(f"{engine:14s} {rec['ms_per_round']:8.1f} ms/round "
          f"({rec['rounds_per_sec']:.3f} rounds/s, "
          f"devices={rec['devices']})")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--devices", type=int, default=2,
                    help="forced host devices for the sharded variant")
    ap.add_argument("--out", default="BENCH_hsfl.json")
    ap.add_argument("--engine", default=None,
                    help="(internal) measure one engine and print JSON")
    args = ap.parse_args()

    if args.engine:
        print(json.dumps(measure(args.engine, args.warmup, args.rounds)))
        return

    recs = [run_child("host", args),
            run_child("fused", args),
            run_child("fused_codec", args)]
    if args.devices > 1:
        recs.append(run_child("fused_sharded", args, devices=args.devices))

    host_ms = recs[0]["ms_per_round"]
    result = {
        "config": {"n_uavs": 30, "k_select": 10, "local_epochs": 6, "b": 2,
                   "scheme": "opt", "steps_per_epoch": 4, "batch_size": 10,
                   "rounds_timed": args.rounds, "warmup": args.warmup},
        "engines": recs,
        "speedup_fused_vs_host": round(host_ms / recs[1]["ms_per_round"], 2),
    }
    if args.devices > 1:
        result["speedup_sharded_vs_host"] = round(
            host_ms / recs[-1]["ms_per_round"], 2)
    print(f"\nspeedup fused vs host: {result['speedup_fused_vs_host']}x")
    if "speedup_sharded_vs_host" in result:
        print(f"speedup sharded vs host: {result['speedup_sharded_vs_host']}x")
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
